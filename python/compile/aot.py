"""AOT lowering: JAX → HLO **text** artifacts the Rust runtime loads.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (behind
the published ``xla`` crate) rejects; the text parser reassigns ids.

Artifacts (all under ``artifacts/``):
  fwd_bf16.hlo.txt    — serving forward, no quantization
  fwd_hif4.hlo.txt    — forward with HiF4 fake-quant activations (L1 kernel)
  fwd_nvfp4.hlo.txt   — forward with NVFP4 fake-quant activations
  train_step.hlo.txt  — one Adam training step
  qdq_hif4.hlo.txt    — standalone HiF4 quant-dequant (rust↔python codec
                        cross-check surface)
  qdq_nvfp4.hlo.txt   — standalone NVFP4 quant-dequant
  manifest.json       — parameter order/shapes + entry-point signatures

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see /opt/xla-example)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(quant):
    names = model.param_names()
    shapes = model.param_shapes()
    p_spec = {n: jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names}
    t_spec = jax.ShapeDtypeStruct((model.BATCH, model.SEQ), jnp.int32)

    def fn(params, tokens):
        return (model.forward(params, tokens, quant=quant),)

    return jax.jit(fn).lower(p_spec, t_spec)


def lower_train_step():
    names = model.param_names()
    shapes = model.param_shapes()
    p_spec = {n: jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names}
    s_spec = jax.ShapeDtypeStruct((), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((model.BATCH, model.SEQ), jnp.int32)

    def fn(params, m, v, step, tokens):
        new_p, new_m, new_v, new_step, loss = model.train_step(params, m, v, step, tokens)
        flat = []
        for n in sorted(new_p):
            flat.append(new_p[n])
        for n in sorted(new_m):
            flat.append(new_m[n])
        for n in sorted(new_v):
            flat.append(new_v[n])
        flat.append(new_step)
        flat.append(loss)
        return tuple(flat)

    return jax.jit(fn).lower(p_spec, p_spec, p_spec, s_spec, t_spec)


def lower_qdq(fmt, rows, cols):
    from .kernels import hif4 as kernels

    op = {"hif4": kernels.hif4_qdq, "nvfp4": kernels.nvfp4_qdq}[fmt]
    spec = jax.ShapeDtypeStruct((rows, cols), jnp.float32)

    def fn(x):
        return (op(x),)

    return jax.jit(fn).lower(spec)


QDQ_ROWS, QDQ_COLS = 8, 256


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--serve-format",
        default=None,
        help="optional manifest `format` key: default serving format for "
        "`serve --native` (hif4|nvfp4|mxfp4|mx4|bfp); omit for dense bf16",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    def emit(name, lowered):
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    emit("fwd_bf16.hlo.txt", lower_forward(None))
    emit("fwd_hif4.hlo.txt", lower_forward("hif4"))
    emit("fwd_nvfp4.hlo.txt", lower_forward("nvfp4"))
    emit("train_step.hlo.txt", lower_train_step())
    emit("qdq_hif4.hlo.txt", lower_qdq("hif4", QDQ_ROWS, QDQ_COLS))
    emit("qdq_nvfp4.hlo.txt", lower_qdq("nvfp4", QDQ_ROWS, QDQ_COLS))

    names = model.param_names()
    shapes = model.param_shapes()
    manifest = {
        "config": model.CONFIG,
        "batch": model.BATCH,
        "seq": model.SEQ,
        "param_order": names,
        "param_shapes": {n: list(shapes[n]) for n in names},
        "entrypoints": {
            "fwd": {
                "inputs": [f"param:{n}" for n in names] + ["tokens:i32[B,T]"],
                "outputs": ["logits:f32[B,T,V]"],
            },
            "train_step": {
                "inputs": [f"param:{n}" for n in names]
                + [f"m:{n}" for n in names]
                + [f"v:{n}" for n in names]
                + ["step:f32[]", "tokens:i32[B,T]"],
                "outputs": [f"param:{n}" for n in sorted(names)]
                + [f"m:{n}" for n in sorted(names)]
                + [f"v:{n}" for n in sorted(names)]
                + ["step:f32[]", "loss:f32[]"],
            },
            "qdq": {"rows": QDQ_ROWS, "cols": QDQ_COLS},
        },
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")

    # Flat-text twin for the Rust loader (no JSON crate in the image).
    lines = [
        f"batch {model.BATCH}",
        f"seq {model.SEQ}",
        f"vocab {model.CONFIG['vocab']}",
        # Attention geometry for the Rust native (PJRT-free) backend,
        # which rebuilds this model from the ParamStore (runtime/native.rs);
        # shapes alone cannot recover the head split or RoPE base.
        f"n_heads {model.CONFIG['n_heads']}",
        f"kv_heads {model.CONFIG['kv_heads']}",
        f"head_dim {model.CONFIG['head_dim']}",
        f"rope_base {model.CONFIG['rope_base']}",
        f"qdq {QDQ_ROWS} {QDQ_COLS}",
    ]
    # Optional default serving format for `serve --native` (any QuantKind
    # spelling: hif4|nvfp4|mxfp4|mx4|bfp); the CLI --format overrides.
    # Opt-in via --serve-format so a regenerated manifest never silently
    # flips the no-flag default away from dense bf16.
    if getattr(args, "serve_format", None):
        lines.append(f"format {args.serve_format}")
    for n in names:
        dims = " ".join(str(d) for d in shapes[n])
        lines.append(f"param {n} {dims}")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print("wrote manifest.txt")


if __name__ == "__main__":
    main()
