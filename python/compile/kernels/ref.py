"""Pure-jnp reference implementations (correctness oracles) of the 4-bit BFP
quantize-dequantize ops: HiF4 (Algorithm 1), NVFP4, MXFP4.

These mirror the bit-exact Rust codecs in ``rust/src/formats/`` and are the
ground truth the Pallas kernels are tested against (pytest + hypothesis).
All rounding is round-half-to-even, as the paper mandates.
"""

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

HIF4_GROUP = 64
NVFP4_GROUP = 16
MXFP4_GROUP = 32

def bf16_rne(x):
    """Round f32 -> bf16 -> f32 (RNE, exactly what hardware does)."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def round_half_even(x):
    """jnp.round is round-half-to-even."""
    return jnp.round(x)


def _floor_log2(x):
    """floor(log2(x)) for positive finite x, exact via frexp."""
    m, e = jnp.frexp(x)  # x = m * 2^e with m in [0.5, 1)
    return e - 1


def e2m1_quantize(x):
    """Round to the nearest E2M1 value (grid ±{0,.5,1,1.5,2,3,4,6}) with
    RNE ties; saturate ±6.

    Arithmetic form (Pallas-friendly, no table constants): within each
    binade the grid is uniform — step 0.5 below 2, step 1 in [2,4), step 2
    above — and round-half-even on `a/ulp` is exactly tie-to-even-mantissa
    because even multiples of the ulp are the even-code values.
    """
    a = jnp.abs(x)
    sign = jnp.where(x < 0, -1.0, 1.0)
    ulp = jnp.where(a < 2.0, 0.5, jnp.where(a < 4.0, 1.0, 2.0))
    q = jnp.round(a / ulp) * ulp
    q = jnp.minimum(q, 6.0)
    return sign * q


def s1p2_quantize(x):
    """Round onto the ±[0, 1.75] grid of step 0.25 (RNE), clamp to bounds."""
    q = round_half_even(x * 4.0)
    return jnp.clip(q, -7.0, 7.0) * 0.25


# ---------------------------------------------------------------------------
# E6M2 (HiF4 level-1 scale)
# ---------------------------------------------------------------------------

E6M2_MIN = 2.0 ** -48
E6M2_MAX = 2.0 ** 15 * 1.5


def e6m2_quantize(x):
    """Encode a positive scale into E6M2 (RNE, clamp to [MIN, MAX]).

    Returns the decoded f32 value (the paper's dedicated BF16->E6M2
    instruction followed by decode).
    """
    x = jnp.clip(x, E6M2_MIN, E6M2_MAX)
    e = _floor_log2(x)
    p2 = jnp.exp2(e.astype(jnp.float32))
    s = x / p2  # in [1, 2)
    q = round_half_even(s * 4.0) / 4.0
    carry = q >= 2.0
    q = jnp.where(carry, 1.0, q)
    p2 = jnp.where(carry, p2 * 2.0, p2)
    return jnp.clip(q * p2, E6M2_MIN, E6M2_MAX)


def e6m2_rec_bf16(scale):
    """The paper's E6M2_REC_to_BF16 instruction: bf16(1/scale). For E6M2
    inputs this equals the 4-entry-LUT hardware path exactly (proved by the
    exhaustive Rust test)."""
    return bf16_rne(1.0 / scale)


# ---------------------------------------------------------------------------
# HiF4 — Algorithm 1
# ---------------------------------------------------------------------------

ONE_SEVENTH_BF16 = float(jnp.asarray(1.0 / 7.0, jnp.bfloat16))


def hif4_qdq(x):
    """Quantize-dequantize the last axis in HiF4 groups of 64.

    x: (..., K) with K % 64 == 0, any float dtype. Returns f32 of the same
    shape. NaN/Inf anywhere in a group poisons the whole group (the E6M2
    scale is the format's only NaN channel).
    """
    orig_shape = x.shape
    assert orig_shape[-1] % HIF4_GROUP == 0, "K must be a multiple of 64"
    # The format consumes BF16 inputs (Algorithm 1).
    v = bf16_rne(x.astype(jnp.float32)).reshape(-1, HIF4_GROUP)

    bad = ~jnp.isfinite(v).all(axis=-1, keepdims=True)

    # Stage 1: three-level tree reduction (4 -> 2 -> global).
    v16 = jnp.max(jnp.abs(v).reshape(-1, 16, 4), axis=-1)  # (n, 16)
    v8 = jnp.max(v16.reshape(-1, 8, 2), axis=-1)  # (n, 8)
    vmax = jnp.max(v8, axis=-1, keepdims=True)  # (n, 1)

    # Stage 2: hierarchical scaling metadata.
    sf = bf16_rne(vmax * ONE_SEVENTH_BF16)
    scale = e6m2_quantize(sf)  # decoded E6M2, (n, 1)
    rec = e6m2_rec_bf16(scale)
    e1_8 = (v8 * rec > 4.0).astype(jnp.float32)  # (n, 8)
    l2_for16 = jnp.repeat(e1_8, 2, axis=-1)  # (n, 16)
    e1_16 = (v16 * rec * jnp.exp2(-l2_for16) >= 2.0).astype(jnp.float32)

    # Stage 3: in-group elements.
    l2 = jnp.repeat(e1_8, 8, axis=-1)  # (n, 64)
    l3 = jnp.repeat(e1_16, 4, axis=-1)  # (n, 64)
    scaled = v * rec * jnp.exp2(-(l2 + l3))
    q = s1p2_quantize(scaled)

    out = scale * jnp.exp2(l2 + l3) * q
    out = jnp.where(bad, jnp.nan, out)
    return out.reshape(orig_shape).astype(jnp.float32)


# ---------------------------------------------------------------------------
# NVFP4
# ---------------------------------------------------------------------------

E4M3_MAX = 448.0
NVFP4_PTS_TARGET = 2688.0


def e4m3_quantize(x):
    """Saturating FP8-E4M3 quantization (non-negative inputs), decoded back
    to f32, in explicit arithmetic.

    Not a dtype cast: the xla_extension 0.5.1 runtime behind the Rust PJRT
    loader implements `convert f32->f8e4m3fn` with round-toward-zero, so a
    cast would change semantics between the pytest (new XLA) and serving
    (old XLA) environments. Per-binade RNE on `a/ulp` is exactly the
    IEEE-style tie-to-even-mantissa rounding, as in `e2m1_quantize`.
    Overflow saturates at 448 (NVIDIA's cast); underflow below half the min
    subnormal (2^-10) rounds to zero — the NVFP4 scale failure modes.
    """
    a = jnp.clip(x, 0.0, E4M3_MAX)
    safe = jnp.where(a > 0, a, 1.0)
    e = jnp.clip(_floor_log2(safe), -6, 8)
    ulp = jnp.exp2(e.astype(jnp.float32) - 3.0)  # 3 mantissa bits; subnormal ulp = 2^-9
    q = jnp.round(a / ulp) * ulp
    return jnp.minimum(q, E4M3_MAX)


def nvfp4_qdq(x):
    """Quantize-dequantize the last axis in NVFP4 groups of 16 (direct
    cast). Same NaN-poisoning contract as hif4_qdq."""
    orig_shape = x.shape
    assert orig_shape[-1] % NVFP4_GROUP == 0, "K must be a multiple of 16"
    v = x.astype(jnp.float32).reshape(-1, NVFP4_GROUP)
    bad = ~jnp.isfinite(v).all(axis=-1, keepdims=True)
    amax = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    scale = e4m3_quantize(amax / 6.0)
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = e2m1_quantize(v * inv)
    out = scale * q
    out = jnp.where(bad, jnp.nan, out)
    return out.reshape(orig_shape)


def nvfp4_pts_qdq(x):
    """NVFP4 with software per-tensor scaling: pre-scale the tensor peak to
    2688 = 6×448, quantize, undo."""
    amax = jnp.max(jnp.abs(x))
    t = jnp.where((amax > 0) & jnp.isfinite(amax), NVFP4_PTS_TARGET / amax, 1.0)
    return nvfp4_qdq(x * t) / t


# ---------------------------------------------------------------------------
# MXFP4
# ---------------------------------------------------------------------------


def mxfp4_qdq(x):
    """Quantize-dequantize the last axis in MXFP4 groups of 32 (OCP rule:
    power-of-two scale 2^(floor(log2 amax) − 2))."""
    orig_shape = x.shape
    assert orig_shape[-1] % MXFP4_GROUP == 0, "K must be a multiple of 32"
    v = x.astype(jnp.float32).reshape(-1, MXFP4_GROUP)
    bad = ~jnp.isfinite(v).all(axis=-1, keepdims=True)
    amax = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    e = jnp.where(amax > 0, _floor_log2(jnp.where(amax > 0, amax, 1.0)) - 2, -126)
    # Clamp to the f32 normal range: XLA's exp2 flushes 2^-127 to zero.
    scale = jnp.exp2(jnp.clip(e, -126, 127).astype(jnp.float32))
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = e2m1_quantize(v * inv)
    out = scale * q
    out = jnp.where(bad, jnp.nan, out)
    return out.reshape(orig_shape)
