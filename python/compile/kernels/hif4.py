"""Layer-1 Pallas kernels: HiF4 / NVFP4 / MXFP4 quantize-dequantize and a
quantized matmul, structured for TPU even though this image executes them
under ``interpret=True`` on CPU (real-TPU lowering emits Mosaic custom-calls
the CPU PJRT plugin cannot run — see DESIGN.md §Hardware-Adaptation).

TPU structure notes (§Perf):
* quantization tiles are (TILE_ROWS, K) blocks whose last axis is a whole
  number of format groups, so every HiF4 unit lives inside one VMEM tile;
  metadata derivation is a single pass of reshapes/maxes (VPU-friendly,
  no gathers);
* the quantized matmul uses MXU-shaped (128, 128) output tiles: each grid
  step quantize-dequantizes an A-tile and a B-tile in VMEM and feeds
  ``jnp.dot`` (the MXU), accumulating over the K grid axis — the HBM↔VMEM
  schedule a GPU implementation would express with threadblocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_TILE_ROWS = 8


def _qdq_kernel(x_ref, o_ref, *, op):
    """Generic quant-dequant kernel body: one (tile_rows, K) VMEM block."""
    o_ref[...] = op(x_ref[...])


def _make_qdq(op, group, name):
    @functools.partial(jax.jit, static_argnames=("tile_rows",))
    def qdq(x, tile_rows=DEFAULT_TILE_ROWS):
        assert x.ndim == 2, "kernels take (rows, K)"
        rows, k = x.shape
        assert k % group == 0, f"K must be a multiple of {group}"
        tile = min(tile_rows, rows)
        assert rows % tile == 0, "rows must divide by the row tile"
        return pl.pallas_call(
            functools.partial(_qdq_kernel, op=op),
            out_shape=jax.ShapeDtypeStruct((rows, k), jnp.float32),
            grid=(rows // tile,),
            in_specs=[pl.BlockSpec((tile, k), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((tile, k), lambda i: (i, 0)),
            interpret=True,  # CPU-PJRT execution; Mosaic on real TPU
        )(x)

    qdq.__name__ = name
    return qdq


#: HiF4 quantize-dequantize over (rows, K) with K % 64 == 0.
hif4_qdq = _make_qdq(ref.hif4_qdq, ref.HIF4_GROUP, "hif4_qdq")
#: NVFP4 (direct cast) with K % 16 == 0.
nvfp4_qdq = _make_qdq(ref.nvfp4_qdq, ref.NVFP4_GROUP, "nvfp4_qdq")
#: MXFP4 with K % 32 == 0.
mxfp4_qdq = _make_qdq(ref.mxfp4_qdq, ref.MXFP4_GROUP, "mxfp4_qdq")


# ---------------------------------------------------------------------------
# Quantized matmul: C = qdq(A) @ qdq(B)ᵀ with per-tile quantization.
# ---------------------------------------------------------------------------


def _qmatmul_kernel(a_ref, b_ref, o_ref, *, op):
    """One (TM, TN) output tile; K grid axis accumulates into o_ref."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qa = op(a_ref[...])
    qb = op(b_ref[...])
    o_ref[...] += jnp.dot(qa, qb.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk", "fmt"))
def qmatmul_bt(a, b_t, tm=128, tn=128, tk=128, fmt="hif4"):
    """C = qdq(A) · qdq(Bᵀ)ᵀ — fake-quant matmul with quantization fused
    into the MXU tiles. ``b_t`` is (N, K) row-major (weights layout)."""
    m, k = a.shape
    n, k2 = b_t.shape
    assert k == k2
    op = {"hif4": ref.hif4_qdq, "nvfp4": ref.nvfp4_qdq, "mxfp4": ref.mxfp4_qdq}[fmt]
    group = {"hif4": 64, "nvfp4": 16, "mxfp4": 32}[fmt]
    tm, tn, tk = min(tm, m), min(tn, n), min(tk, k)
    assert m % tm == 0 and n % tn == 0 and k % tk == 0
    assert tk % group == 0, "K tile must hold whole quantization groups"
    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, op=op),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // tm, n // tn, k // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, s: (i, s)),
            pl.BlockSpec((tn, tk), lambda i, j, s: (j, s)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, s: (i, j)),
        interpret=True,
    )(a, b_t)


def vmem_bytes_qmatmul(tm, tn, tk):
    """Estimated VMEM working set of one qmatmul grid step (f32): A-tile +
    B-tile + their dequantized copies + the output tile. Used by the §Perf
    notes to check tiles fit the ~16 MiB/core VMEM budget."""
    return 4 * (2 * tm * tk + 2 * tn * tk + tm * tn)
