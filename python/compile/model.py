"""Layer-2 JAX model: a GQA + SwiGLU decoder-only transformer (the serving
configuration) with optional fake-quant activations via the L1 Pallas
kernels, plus an Adam train step. Both are AOT-lowered to HLO text by
``aot.py`` and driven from Rust via PJRT — Python never runs at request
time.

Weights are *inputs* to the lowered computations (a flat, name-sorted list;
see ``param_names``), so the Rust side can train, quantize (fake-quant the
weight arrays with its own codecs or GPTQ) and serve without re-lowering.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import hif4 as kernels

# The serving configuration (mirrors rust zoo llama3_tiny's shape class).
CONFIG = dict(
    vocab=320,
    d_model=64,
    n_layers=2,
    n_heads=4,
    kv_heads=2,
    head_dim=16,
    d_ff=128,
    max_seq=32,
    rope_base=10000.0,
)

BATCH = 8
SEQ = 32


def param_shapes(cfg=None):
    """Name → shape for every parameter, in the flat order used by AOT
    artifacts (sorted by name)."""
    c = cfg or CONFIG
    d, hd = c["d_model"], c["n_heads"] * c["head_dim"]
    kvd = c["kv_heads"] * c["head_dim"]
    shapes = {
        "embed": (c["vocab"], d),
        "head": (c["vocab"], d),
        "norm_f": (d,),
    }
    for l in range(c["n_layers"]):
        shapes[f"layer{l}.norm1"] = (d,)
        shapes[f"layer{l}.norm2"] = (d,)
        shapes[f"layer{l}.wq"] = (hd, d)
        shapes[f"layer{l}.wk"] = (kvd, d)
        shapes[f"layer{l}.wv"] = (kvd, d)
        shapes[f"layer{l}.wo"] = (d, hd)
        shapes[f"layer{l}.w1"] = (c["d_ff"], d)
        shapes[f"layer{l}.w2"] = (d, c["d_ff"])
        shapes[f"layer{l}.w3"] = (c["d_ff"], d)
    return shapes


def param_names(cfg=None):
    return sorted(param_shapes(cfg).keys())


def init_params(key, cfg=None):
    c = cfg or CONFIG
    shapes = param_shapes(c)
    params = {}
    for name in param_names(c):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith(("norm1", "norm2", "norm_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            sigma = (2.0 / (shape[0] + shape[-1])) ** 0.5
            params[name] = sigma * jax.random.normal(sub, shape, jnp.float32)
    return params


def rmsnorm(x, g):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + 1e-6) * g


def rope(x, heads, head_dim, base):
    """x: (B, T, heads*head_dim)."""
    b, t, _ = x.shape
    x = x.reshape(b, t, heads, head_dim)
    half = head_dim // 2
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    freq = base ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / head_dim)
    theta = pos * freq  # (T, half)
    cos, sin = jnp.cos(theta), jnp.sin(theta)
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    rot1 = x1 * cos - x2 * sin
    rot2 = x1 * sin + x2 * cos
    out = jnp.stack([rot1, rot2], axis=-1).reshape(b, t, heads, head_dim)
    return out.reshape(b, t, heads * head_dim)


def _maybe_q(x, quant):
    """Fake-quantize activations via the L1 Pallas kernel. The last axis
    must be a multiple of the group; the serving dims (64, 128, 256) are."""
    if quant is None:
        return x
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    q = {"hif4": kernels.hif4_qdq, "nvfp4": kernels.nvfp4_qdq, "mxfp4": kernels.mxfp4_qdq}[
        quant
    ](flat)
    return q.reshape(shape)


def forward(params, tokens, cfg=None, quant=None):
    """Logits for a (B, T) int32 token batch. ``quant`` ∈ {None, 'hif4',
    'nvfp4', 'mxfp4'} applies fake-quant to activations entering every
    attention/FFN linear (weights are expected pre-quantized by the caller,
    matching the paper's §IV 'simulated quantization')."""
    c = cfg or CONFIG
    b, t = tokens.shape
    x = params["embed"][tokens]  # (B, T, d)
    heads, kvh, hd = c["n_heads"], c["kv_heads"], c["head_dim"]
    group = heads // kvh
    causal = jnp.tril(jnp.ones((t, t), bool))

    for l in range(c["n_layers"]):
        h = rmsnorm(x, params[f"layer{l}.norm1"])
        hq = _maybe_q(h, quant)
        q = hq @ params[f"layer{l}.wq"].T
        k = hq @ params[f"layer{l}.wk"].T
        v = hq @ params[f"layer{l}.wv"].T
        q = rope(q, heads, hd, c["rope_base"])
        k = rope(k, kvh, hd, c["rope_base"])
        qh = q.reshape(b, t, heads, hd)
        kh = k.reshape(b, t, kvh, hd)
        vh = v.reshape(b, t, kvh, hd)
        # GQA: repeat KV heads across the query group.
        kh = jnp.repeat(kh, group, axis=2)
        vh = jnp.repeat(vh, group, axis=2)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) / (hd ** 0.5)
        scores = jnp.where(causal[None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, vh).reshape(b, t, heads * hd)
        ctx_q = _maybe_q(ctx, quant)
        x = x + ctx_q @ params[f"layer{l}.wo"].T

        h = rmsnorm(x, params[f"layer{l}.norm2"])
        hq = _maybe_q(h, quant)
        a = jax.nn.silu(hq @ params[f"layer{l}.w1"].T) * (hq @ params[f"layer{l}.w3"].T)
        aq = _maybe_q(a, quant)
        x = x + aq @ params[f"layer{l}.w2"].T

    h = rmsnorm(x, params["norm_f"])
    return h @ params["head"].T  # (B, T, vocab)


def loss_fn(params, tokens, cfg=None):
    """Causal LM loss: predict token t+1; last position masked."""
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def init_opt_state(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return dict(m=zeros, v={k: jnp.zeros_like(v) for k, v in params.items()}, step=jnp.zeros((), jnp.float32))


def train_step(params, m, v, step, tokens, lr=2e-3, cfg=None):
    """One Adam step. Flat pytree signature so the AOT artifact's parameter
    order is predictable. Returns (params', m', v', step', loss)."""
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, cfg)
    b1, b2, eps = 0.9, 0.999, 1e-8
    step = step + 1.0
    lr_t = lr * jnp.sqrt(1.0 - b2 ** step) / (1.0 - b1 ** step)
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        new_m[k] = b1 * m[k] + (1 - b1) * grads[k]
        new_v[k] = b2 * v[k] + (1 - b2) * grads[k] * grads[k]
        new_p[k] = params[k] - lr_t * new_m[k] / (jnp.sqrt(new_v[k]) + eps)
    return new_p, new_m, new_v, step, loss


@functools.partial(jax.jit, static_argnames=("quant",))
def forward_jit(params, tokens, quant=None):
    return forward(params, tokens, quant=quant)


train_step_jit = jax.jit(train_step)
