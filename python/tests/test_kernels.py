"""L1 correctness: Pallas kernels vs the pure-jnp oracle (exact equality),
plus hypothesis sweeps over shapes/scales and the format edge cases the
paper's analysis hinges on."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import hif4 as kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

KERNELS = {
    "hif4": (kernels.hif4_qdq, ref.hif4_qdq, 64),
    "nvfp4": (kernels.nvfp4_qdq, ref.nvfp4_qdq, 16),
    "mxfp4": (kernels.mxfp4_qdq, ref.mxfp4_qdq, 32),
}


@pytest.mark.parametrize("fmt", list(KERNELS))
def test_kernel_matches_ref_exactly(fmt):
    kern, oracle, group = KERNELS[fmt]
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.normal(size=(16, 4 * group)).astype(np.float32))
    got = np.asarray(kern(x))
    want = np.asarray(oracle(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fmt", list(KERNELS))
def test_zeros_and_sign_preservation(fmt):
    kern, _, group = KERNELS[fmt]
    x = jnp.zeros((4, group), jnp.float32)
    assert np.all(np.asarray(kern(x)) == 0.0)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, group)).astype(np.float32))
    out = np.asarray(kern(x))
    assert np.all(out * np.asarray(x) >= 0.0), "sign flips are impossible"
    assert np.all(np.isfinite(out))


@pytest.mark.parametrize("fmt", list(KERNELS))
def test_nan_poisons_group(fmt):
    kern, _, group = KERNELS[fmt]
    x = np.ones((2, 2 * group), np.float32)
    x[0, 0] = np.nan
    out = np.asarray(kern(jnp.asarray(x)))
    assert np.all(np.isnan(out[0, :group])), "NaN group poisoned"
    assert np.all(np.isfinite(out[0, group:])), "sibling group untouched"
    assert np.all(np.isfinite(out[1])), "other rows untouched"


@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 8]),
    groups=st.sampled_from([1, 2, 3]),
    log_sigma=st.integers(min_value=-8, max_value=8),
    seed=st.integers(min_value=0, max_value=2 ** 16),
    fmt=st.sampled_from(["hif4", "nvfp4", "mxfp4"]),
)
def test_hypothesis_kernel_vs_ref(rows, groups, log_sigma, seed, fmt):
    """Shape/scale sweep: kernel output must equal the oracle bit-for-bit."""
    kern, oracle, group = KERNELS[fmt]
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        (rng.normal(size=(rows, groups * group)) * 2.0 ** log_sigma).astype(np.float32)
    )
    got = np.asarray(kern(x, tile_rows=1))
    want = np.asarray(oracle(x))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2 ** 16),
    log_sigma=st.integers(min_value=-6, max_value=6),
)
def test_hif4_error_bound(seed, log_sigma):
    """The scaled-peak bound: every output within the HiF4 relative error
    envelope (element step ≤ 0.25 × 2^2 × scale; scale ≲ 1.15 × amax/7)."""
    rng = np.random.default_rng(seed)
    sigma = 2.0 ** log_sigma
    x = jnp.asarray((rng.normal(size=(4, 64)) * sigma).astype(np.float32))
    out = np.asarray(kernels.hif4_qdq(x))
    xb = np.asarray(ref.bf16_rne(x))
    amax = np.abs(xb).max(axis=-1, keepdims=True)
    # Worst-case absolute error: half an element step at the max micro-exp,
    # plus the scale slack; generous envelope 0.25 × amax.
    assert np.all(np.abs(out - xb) <= 0.25 * amax + 1e-30)


def test_hif4_dynamic_range_vs_nvfp4():
    """Table II: a 2^13 peak clips NVFP4 (scale > E4M3 max) but not HiF4."""
    x = np.ones((1, 64), np.float32)
    x[0, 0] = 8192.0
    hif4 = np.asarray(kernels.hif4_qdq(jnp.asarray(x)))
    nvfp4 = np.asarray(kernels.nvfp4_qdq(jnp.asarray(x)))
    assert abs(hif4[0, 0] - 8192.0) / 8192.0 < 0.1, "HiF4 keeps the peak"
    assert nvfp4[0, 0] == 2688.0, "NVFP4 clips at 6 x 448"


def test_nvfp4_pts_rescues_range():
    x = np.ones((1, 64), np.float32)
    x[0, 0] = 8192.0
    pts = np.asarray(ref.nvfp4_pts_qdq(jnp.asarray(x)))
    assert abs(pts[0, 0] - 8192.0) / 8192.0 < 0.05


def test_fig3_mse_ordering():
    """HiF4 < NVFP4 < MXFP4 on Gaussian data (the Fig 3 headline)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
    mse = lambda q: float(jnp.mean((q - x) ** 2))
    e_h = mse(kernels.hif4_qdq(x))
    e_n = mse(kernels.nvfp4_qdq(x))
    e_m = mse(kernels.mxfp4_qdq(x))
    assert e_h < e_n < e_m, (e_h, e_n, e_m)


def test_qmatmul_matches_dequant_matmul():
    """Fused quantized matmul == quantize-then-matmul, all formats."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    for fmt in ["hif4", "nvfp4", "mxfp4"]:
        got = np.asarray(kernels.qmatmul_bt(a, b, tm=8, tn=8, tk=64, fmt=fmt))
        op = KERNELS[fmt][1]
        want = np.asarray(op(a) @ op(b).T)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_bf16_input_matches_f32_of_same_values():
    """Algorithm 1 consumes BF16: a bf16 input and its exact f32 widening
    must quantize identically."""
    rng = np.random.default_rng(11)
    xb = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32)).astype(jnp.bfloat16)
    out_b = np.asarray(kernels.hif4_qdq(xb.astype(jnp.float32)))
    out_f = np.asarray(ref.hif4_qdq(xb))
    np.testing.assert_array_equal(out_b, out_f)
