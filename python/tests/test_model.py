"""L2 correctness: transformer forward shapes/causality, train step learns,
and the AOT lowering path produces parseable HLO text."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


def test_forward_shapes(params):
    tokens = jnp.zeros((model.BATCH, model.SEQ), jnp.int32)
    logits = model.forward(params, tokens)
    assert logits.shape == (model.BATCH, model.SEQ, model.CONFIG["vocab"])
    assert bool(jnp.isfinite(logits).all())


def test_causality(params):
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, 320, size=(1, model.SEQ)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 7) % 320
    l1 = model.forward(params, jnp.asarray(t1))
    l2 = model.forward(params, jnp.asarray(t2))
    np.testing.assert_array_equal(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]))
    assert not np.array_equal(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]))


def test_quantized_forward_differs_but_close(params):
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, 320, size=(model.BATCH, model.SEQ)),
        jnp.int32,
    )
    clean = model.forward(params, tokens)
    for quant in ["hif4", "nvfp4"]:
        q = model.forward(params, tokens, quant=quant)
        assert bool(jnp.isfinite(q).all()), quant
        diff = float(jnp.abs(q - clean).mean())
        scale = float(jnp.abs(clean).mean())
        assert 0.0 < diff < 0.5 * scale, (quant, diff, scale)


def test_train_step_learns(params):
    """A few Adam steps on a fixed batch must reduce the loss."""
    tokens = jnp.asarray(
        np.random.default_rng(2).integers(0, 50, size=(model.BATCH, model.SEQ)),
        jnp.int32,
    )
    opt = model.init_opt_state(params)
    p, m, v, step = params, opt["m"], opt["v"], opt["step"]
    losses = []
    for _ in range(8):
        p, m, v, step, loss = model.train_step_jit(p, m, v, step, tokens)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_aot_lowering_emits_hlo_text():
    text = aot.to_hlo_text(aot.lower_qdq("hif4", 4, 64))
    assert "HloModule" in text
    assert "ENTRY" in text


def test_param_order_is_stable():
    names = model.param_names()
    assert names == sorted(names)
    shapes = model.param_shapes()
    assert set(names) == set(shapes)
