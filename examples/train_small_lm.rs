//! End-to-end training driver: the **Rust coordinator drives the AOT
//! train-step executable** (L2 Adam + backprop, lowered from JAX) over the
//! synthetic corpus and logs the loss curve — Python never runs.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_small_lm -- [--steps 200] [--out data/served.params]
//! ```

use hif4::eval::tasks;
use hif4::runtime::artifact::Manifest;
use hif4::runtime::client::{tokens_literal, Runtime};
use hif4::tensor::Rng;
use hif4::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps: usize = args.get_parse("steps", 200);
    let out = args.get_or("out", "data/served.params").to_string();
    let artifacts = Path::new(args.get_or("artifacts", "artifacts"));

    let manifest = Manifest::load(artifacts)?;
    let runtime = Runtime::cpu()?;
    println!(
        "platform={}  model: {} params across {} arrays, B={} T={}",
        runtime.platform(),
        manifest.param_elems(),
        manifest.params.len(),
        manifest.batch,
        manifest.seq
    );
    let exe = runtime.load(&manifest.artifact("train_step.hlo.txt"))?;
    let mut params = manifest.init_params(1234);
    let n = params.order.len();

    // Adam state lives in Rust as plain buffers, round-tripping through the
    // executable every step.
    let mut m_state: Vec<Vec<f32>> =
        params.order.iter().map(|k| vec![0f32; params.params[k].1.len()]).collect();
    let mut v_state = m_state.clone();
    let mut step = 0f32;
    let mut rng = Rng::seed(99);

    let t0 = std::time::Instant::now();
    let mut curve = Vec::new();
    for s in 0..steps {
        let batch: Vec<Vec<usize>> = (0..manifest.batch)
            .map(|_| tasks::training_sequence(&mut rng, manifest.seq))
            .collect();
        let mut inputs = params.literals()?;
        for (name, buf) in params.order.iter().zip(&m_state) {
            let dims: Vec<i64> = params.params[name].0.iter().map(|d| *d as i64).collect();
            inputs.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        for (name, buf) in params.order.iter().zip(&v_state) {
            let dims: Vec<i64> = params.params[name].0.iter().map(|d| *d as i64).collect();
            inputs.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        inputs.push(xla::Literal::scalar(step));
        inputs.push(tokens_literal(&batch, manifest.seq)?);

        let outs = exe.run(&inputs)?;
        params.update_from_literals(&outs[..n])?;
        for (i, buf) in m_state.iter_mut().enumerate() {
            *buf = outs[n + i].to_vec::<f32>()?;
        }
        for (i, buf) in v_state.iter_mut().enumerate() {
            *buf = outs[2 * n + i].to_vec::<f32>()?;
        }
        step = outs[3 * n].to_vec::<f32>()?[0];
        let loss = outs[3 * n + 1].to_vec::<f32>()?[0];
        curve.push(loss);
        if s % 10 == 0 || s == steps - 1 {
            println!("step {s:4}  loss {loss:.4}");
        }
    }
    let dt = t0.elapsed();
    println!(
        "\ntrained {steps} steps in {dt:.2?} ({:.1} steps/s, {:.0} tokens/s)",
        steps as f64 / dt.as_secs_f64(),
        (steps * manifest.batch * manifest.seq) as f64 / dt.as_secs_f64()
    );
    println!(
        "loss: first5 {:.4}  last5 {:.4}",
        curve[..5.min(curve.len())].iter().sum::<f32>() / 5f32.min(curve.len() as f32),
        curve[curve.len().saturating_sub(5)..].iter().sum::<f32>()
            / 5f32.min(curve.len() as f32)
    );

    if let Some(parent) = Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    params.save(Path::new(&out))?;
    println!("saved trained parameters to {out}");
    Ok(())
}
