//! Full PTQ pipeline on one stand-in LLM: train → calibrate → quantize
//! (RTN direct-cast vs HiGPTQ) → evaluate — a single-model slice of the
//! Table III experiment with per-stage commentary.
//!
//! ```bash
//! cargo run --release --example ptq_pipeline -- [--steps 260] [--items 60]
//! ```

use hif4::eval::tasks::Task;
use hif4::formats::QuantKind;
use hif4::model::zoo;
use hif4::quant::experiment::{self, ExperimentConfig, QuantType};
use hif4::util::bench::Table;
use hif4::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let xcfg = ExperimentConfig {
        train_steps: args.get_parse("steps", 260),
        eval_items: args.get_parse("items", 60),
        ..Default::default()
    };

    let cfg = zoo::llama3_tiny();
    println!(
        "model: {} ({} params) — training {} steps on the synthetic corpus",
        cfg.name,
        cfg.param_count(),
        xcfg.train_steps
    );

    let suite = Task::small_suite();
    let t0 = std::time::Instant::now();
    let block = experiment::run_model(
        &cfg,
        &suite,
        &[
            QuantType::Bf16,
            QuantType::Direct(QuantKind::Nvfp4),
            QuantType::Pts(QuantKind::Nvfp4),
            QuantType::Direct(QuantKind::HiF4),
            QuantType::HiGptq(QuantKind::HiF4),
        ],
        &xcfg,
        7,
    );
    println!(
        "loss {:.3} -> {:.3}; full pipeline took {:.1?}",
        block.losses[0],
        block.losses.last().unwrap(),
        t0.elapsed()
    );

    let mut header: Vec<&str> = vec!["A-W Quant Type"];
    let names: Vec<&'static str> = suite.iter().map(|t| t.name()).collect();
    header.extend(names.iter());
    header.push("Mean");
    let mut t = Table::new(&format!("PTQ pipeline: {}", block.model_name), &header);
    for (i, row) in block.rows.iter().enumerate() {
        let mut cells = vec![row.label.clone()];
        cells.extend(row.task_acc.iter().map(|a| format!("{a:.2}")));
        cells.push(format!("{:.2}", row.mean));
        t.row(cells);
        if i > 0 {
            let drops = block.drops(i);
            let mut cells = vec!["  - Acc Drop".to_string()];
            cells.extend(drops.iter().map(|d| format!("{d:+.2}")));
            cells.push(format!("{:+.2}", row.mean - block.rows[0].mean));
            t.row(cells);
        }
    }
    t.print();
    println!("\nExpected shape (paper §IV.B): drop(HiF4) < drop(NVFP4); HiGPTQ improves HiF4.");
}
