//! End-to-end serving driver: start the coordinator on the BF16 and the
//! HiF4-quantized forward artifacts, fire batched requests from concurrent
//! clients, and report latency / throughput / BF16↔HiF4 agreement — the
//! serving analogue of the paper's deployment section.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_small_lm      # optional: trained params
//! cargo run --release --example serve_inference -- [--requests 200] [--clients 4]
//! ```

use hif4::eval::tasks::{self, Task};
use hif4::formats::{QuantKind, QuantScheme};
use hif4::runtime::artifact::{Manifest, ParamStore};
use hif4::server::batcher::BatchPolicy;
use hif4::server::protocol::Request;
use hif4::server::service::{Client, Server, ServerConfig};
use hif4::tensor::Rng;
use hif4::util::cli::Args;
use std::path::Path;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_requests: usize = args.get_parse("requests", 200);
    let n_clients: usize = args.get_parse("clients", 4);
    let artifacts = Path::new(args.get_or("artifacts", "artifacts")).to_path_buf();
    let params_path = args.get_or("params", "data/served.params").to_string();

    let manifest = Manifest::load(&artifacts)?;
    // Prefer trained parameters from train_small_lm; fall back to random.
    let params = match ParamStore::load(Path::new(&params_path)) {
        Ok(p) => {
            println!("serving trained parameters from {params_path}");
            p
        }
        Err(_) => {
            println!("no trained params at {params_path}; serving random init");
            manifest.init_params(5)
        }
    };

    let mut agreement_tokens: Vec<Vec<u32>> = Vec::new();
    for (artifact, label, quantize) in [
        ("fwd_bf16.hlo.txt", "BF16", false),
        ("fwd_hif4.hlo.txt", "HiF4 (weights+activations)", true),
    ] {
        let mut served = params.clone();
        if quantize {
            // Weight half of the simulated quantization; activations are
            // quantized in-graph by the artifact's Pallas-derived HLO.
            served.quantize_weights(&QuantScheme::direct(QuantKind::HiF4));
        }
        let cfg = ServerConfig {
            artifact: artifact.into(),
            policy: BatchPolicy { max_batch: manifest.batch, max_wait: Duration::from_millis(2) },
            workers: args.get_parse("workers", 2),
            resilience: Default::default(),
        };
        let server = Server::start(&artifacts, cfg, &served, "127.0.0.1:0")?;
        println!("\n[{label}] serving {artifact} on {}", server.addr);

        // Deterministic request stream: benchmark-style contexts.
        let reqs_per_client = n_requests / n_clients;
        let t0 = Instant::now();
        let tokens: Vec<Vec<(u64, u32)>> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for c in 0..n_clients {
                let addr = server.addr;
                handles.push(s.spawn(move || {
                    let mut rng = Rng::seed(1000 + c as u64);
                    let mut client = Client::connect(addr).unwrap();
                    let mut got = Vec::new();
                    // Pipeline in windows of 8 to exercise batching. With
                    // several PJRT workers, replies can arrive out of
                    // request order, so keep the id with each token.
                    let mut outstanding = 0usize;
                    for i in 0..reqs_per_client {
                        let item = Task::AgreeHard.item(&mut rng);
                        let req = Request::next_token(
                            (c * reqs_per_client + i) as u64,
                            item.context.clone(),
                        );
                        client.send(&req).unwrap();
                        outstanding += 1;
                        if outstanding == 8 {
                            for _ in 0..8 {
                                let resp = client.recv().unwrap();
                                got.push((resp.id, resp.token));
                            }
                            outstanding = 0;
                        }
                    }
                    for _ in 0..outstanding {
                        let resp = client.recv().unwrap();
                        got.push((resp.id, resp.token));
                    }
                    got
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let dt = t0.elapsed();
        let total: usize = tokens.iter().map(|t| t.len()).sum();
        println!(
            "  {total} requests in {dt:.2?}  ->  {:.1} req/s   {}",
            total as f64 / dt.as_secs_f64(),
            server.metrics.summary()
        );
        // Align by request id so the BF16/HiF4 comparison pairs the same
        // request regardless of worker-pool reply interleaving.
        let mut pairs: Vec<(u64, u32)> = tokens.into_iter().flatten().collect();
        pairs.sort_unstable_by_key(|(id, _)| *id);
        agreement_tokens.push(pairs.into_iter().map(|(_, t)| t).collect());
    }

    // Fidelity: how often does the HiF4-served model pick the same next
    // token as BF16? (Same seeds ⇒ same request streams.)
    let same = agreement_tokens[0]
        .iter()
        .zip(&agreement_tokens[1])
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "\nBF16 vs HiF4 next-token agreement: {}/{} = {:.1}%",
        same,
        agreement_tokens[0].len(),
        100.0 * same as f64 / agreement_tokens[0].len() as f64
    );
    let _ = tasks::VOCAB;
    Ok(())
}
