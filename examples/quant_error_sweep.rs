//! Fig 3 reproduction as a runnable example: quantization-error sweep over
//! Gaussian matrices with σ = 0.01 × 2^x, x ∈ [0, 17].
//!
//! ```bash
//! cargo run --release --example quant_error_sweep -- [--dim 1024] [--seed 42]
//! ```

use hif4::quant::sweep;
use hif4::util::bench::Table;
use hif4::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let dim: usize = args.get_parse("dim", 512);
    let seed: u64 = args.get_parse("seed", 42);

    println!("Fig 3 sweep: {dim}x{dim} Gaussian matrices, 18 sigma points (seed {seed})");
    let points = sweep::run(dim, sweep::PAPER_POINTS, seed);

    let mut t = Table::new(
        "Fig 3: MSE normalized to HiF4",
        &["x", "sigma", "HiF4", "NVFP4", "NVFP4+PTS", "MXFP4"],
    );
    for p in &points {
        t.row(vec![
            p.x.to_string(),
            format!("{:.3e}", p.sigma),
            format!("{:.3}", p.normalized[0]),
            format!("{:.3}", p.normalized[1]),
            format!("{:.3}", p.normalized[2]),
            format!("{:.3}", p.normalized[3]),
        ]);
    }
    t.print();

    let r = sweep::stable_ratios(&points);
    println!(
        "\nStable-region MSE ratio  HiF4 : NVFP4 : MXFP4 = 1 : {:.2} : {:.2}   (paper: 1 : 1.32 : 1.89)",
        r[1], r[3]
    );
}
