//! Quickstart: quantize a tensor with every 4-bit BFP format, inspect the
//! HiF4 unit structure, and compare quantization error.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hif4::formats::rounding::RoundMode;
use hif4::formats::{hif4 as hif4_fmt, mse, QuantKind, QuantScheme};
use hif4::tensor::{Matrix, Rng};
use hif4::util::bench::Table;

fn main() {
    // A Gaussian tensor, like one row of an activation matrix.
    let mut rng = Rng::seed(7);
    let x = Matrix::randn(1, 1024, 0.05, &mut rng);

    println!("== quantize one 64-element group and look inside ==");
    let (unit, trace) = hif4_fmt::quantize_trace(&x.data[..64], RoundMode::NearestEven);
    println!("  E6M2 scale      : {:#04x} = {:.6e}", unit.scale.0, unit.scale.to_f32());
    println!("  E1_8 (level-2)  : {:#010b}", unit.e1_8);
    println!("  E1_16 (level-3) : {:#018b}", unit.e1_16);
    println!(
        "  Vmax            : {:.6e} (scaled peak {:.3})",
        trace.vmax,
        trace.vmax * trace.rec
    );
    println!(
        "  wire size       : {} bytes for 64 values = {} bits/value",
        hif4_fmt::HiF4Unit::WIRE_BYTES,
        hif4_fmt::BITS_PER_VALUE
    );

    println!("\n== quant-dequant error across formats (sigma = 0.05 Gaussian) ==");
    let mut t = Table::new(
        "Quickstart: format comparison",
        &["format", "group", "bits/val", "MSE", "vs HiF4"],
    );
    let base = {
        let q = QuantScheme::direct(QuantKind::HiF4).quant_dequant_vec(&x.data);
        mse(&x.data, &q)
    };
    for f in QuantKind::ALL {
        let q = QuantScheme::direct(f).quant_dequant_vec(&x.data);
        let e = mse(&x.data, &q);
        t.row(vec![
            f.name().into(),
            f.group().to_string(),
            format!("{}", f.bits_per_value()),
            format!("{e:.3e}"),
            format!("{:.2}x", e / base),
        ]);
    }
    t.print();

    println!("\n== the NVFP4 range failure HiF4 is designed around ==");
    let mut wide = vec![2f32.powi(-14); 64];
    wide[0] = 2f32.powi(13);
    for f in [QuantKind::HiF4, QuantKind::Nvfp4] {
        let q = QuantScheme::direct(f).quant_dequant_vec(&wide);
        println!(
            "  {:6}: peak {:.3e} -> {:.3e}   tiny {:.3e} -> {:.3e}",
            f.name(),
            wide[0],
            q[0],
            wide[1],
            q[1]
        );
    }
}
