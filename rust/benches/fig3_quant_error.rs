//! Fig 3 regeneration: quantization-error comparison of 4-bit BFP formats
//! over Gaussian matrices, σ = 0.01 × 2^x for x ∈ [0, 17], MSE normalized
//! to HiF4. Paper headline: HiF4 : NVFP4 : MXFP4 = 1 : 1.32 : 1.89 with
//! NVFP4 direct-cast blowing up near its range bounds.
//!
//! HIF4_BENCH_QUICK=1 shrinks the matrices for CI runs.

use hif4::quant::sweep;
use hif4::util::bench::{BenchRunner, Table};

fn main() {
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    let dim = if quick { 128 } else { sweep::PAPER_DIM };
    println!("Fig 3: {dim}x{dim} Gaussian matrices, x in [0, 17], 3 seeds");

    // Average the normalized curves over 3 seeds like the paper's protocol.
    let seeds = [42u64, 43, 44];
    let mut acc: Vec<Vec<f64>> = vec![vec![0.0; 4]; sweep::PAPER_POINTS];
    let mut sigmas = vec![0.0f64; sweep::PAPER_POINTS];
    let t0 = std::time::Instant::now();
    for seed in seeds {
        let pts = sweep::run(dim, sweep::PAPER_POINTS, seed);
        for (i, p) in pts.iter().enumerate() {
            sigmas[i] = p.sigma;
            for (a, r) in acc[i].iter_mut().zip(&p.normalized) {
                *a += r / seeds.len() as f64;
            }
        }
    }
    println!("swept in {:.1?}", t0.elapsed());

    // Header labels derive from the scheme list (QuantScheme::label) so
    // they can never drift from the column order of sweep::run.
    let mut header = vec!["x".to_string(), "sigma".to_string()];
    header.extend(sweep::scheme_labels());
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new("Fig 3: MSE normalized to HiF4 (mean of 3 seeds)", &hdr);
    for (i, row) in acc.iter().enumerate() {
        let mut cells = vec![i.to_string(), format!("{:.3e}", sigmas[i])];
        cells.extend(row.iter().map(|r| format!("{r:.3}")));
        t.row(cells);
    }
    t.print();

    // Stable-region aggregate (paper excludes the NVFP4 fluctuation).
    let stable: Vec<&Vec<f64>> = acc.iter().filter(|r| r[1] <= r[2] * 1.5).collect();
    let mean = |k: usize| stable.iter().map(|r| r[k]).sum::<f64>() / stable.len() as f64;
    println!(
        "\nStable-region ratio  HiF4 : NVFP4 : MXFP4 = 1 : {:.2} : {:.2}   (paper: 1 : 1.32 : 1.89)",
        mean(1),
        mean(3)
    );
    println!(
        "Range-edge blow-up   x=17: NVFP4 direct = {:.2}x HiF4 vs PTS = {:.2}x (direct/PTS = {:.2})",
        acc[17][1],
        acc[17][2],
        acc[17][1] / acc[17][2]
    );

    // Throughput of the quantizers themselves.
    let r = BenchRunner::from_env();
    let mut rng = hif4::tensor::Rng::seed(1);
    let data: Vec<f32> = (0..dim * 64).map(|_| rng.normal() as f32).collect();
    for scheme in sweep::schemes() {
        let mut out = vec![0f32; data.len()];
        r.run(
            &format!("quant_dequant {} ({} elems)", scheme.label(), data.len()),
            Some(data.len() as u64),
            || scheme.quant_dequant(&data, &mut out),
        );
    }
}
