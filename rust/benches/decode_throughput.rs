//! Incremental-decode throughput: KV-cached generation vs O(T²)
//! full-recompute generation, f32 vs HiF4 cache, batch sizes 1/8/32.
//!
//! Writes `BENCH_decode.json` (tokens/s for prefill and decode, the
//! cached-vs-recompute speedup at the final context length, and the
//! KV-cache memory footprint per kind) so the serving perf trajectory is
//! machine-readable across PRs. Before timing anything it asserts the
//! correctness contract: cached greedy decode is token-identical to the
//! full-recompute reference for both cache kinds.
//!
//! `HIF4_BENCH_QUICK=1` shrinks the sequence/batch grid for CI smoke
//! runs; the full run generates to a context length ≥ 128 where the
//! O(T) cached path's win over full recompute is unambiguous.
//!
//! A long-context section pre-fills a HiF4 cache with synthetic rows
//! (skipping the O(T²) prefill) and times single-token decode steps
//! under both attention schedules — `fused` (tiled integer kernel over
//! the packed lane planes) and `replay` (dense f32 re-materialization
//! of every cached row per step) — at contexts up to 32k, asserting
//! greedy-token parity before timing and reporting the per-step
//! attention read traffic each path implies.

use hif4::dotprod::{set_kernel, simd_isa_label, Kernel};
use hif4::formats::QuantKind;
use hif4::model::attention::AttnPath;
use hif4::model::kv::{KvCache, KvCacheType};
use hif4::model::transformer::{greedy_from_row, CachedSeq, Transformer};
use hif4::model::zoo;
use hif4::runtime::native::{DecodeEngine, DecodeStream};
use hif4::util::threadpool;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    let (prompt_len, new_tokens, batches): (usize, usize, &[usize]) =
        if quick { (8, 24, &[1, 4]) } else { (32, 128, &[1, 8, 32]) };
    let context_len = prompt_len + new_tokens;
    let mut cfg = zoo::llama3_tiny();
    cfg.max_seq = context_len + 1;
    let model = Arc::new(Transformer::init(cfg, 91));
    let vocab = model.cfg.vocab;
    let prompt: Vec<usize> = (0..prompt_len).map(|i| 1 + (i * 7) % (vocab - 1)).collect();
    let nthreads = threadpool::threads();
    println!(
        "decode throughput — {}, prompt {prompt_len}, +{new_tokens} tokens \
         (context {context_len}), threads {nthreads}\n",
        model.cfg.name
    );

    // f32 + HiF4 always; the full run adds the other quantized cache
    // kinds so the JSON carries a per-format decode row for each.
    let mut kinds = vec![KvCacheType::F32, KvCacheType::HIF4];
    if !quick {
        kinds.extend(
            [QuantKind::Nvfp4, QuantKind::Mxfp4, QuantKind::Mx4, QuantKind::Bfp]
                .map(KvCacheType::Quant),
        );
    }
    let mut kind_json = Vec::new();
    for kind in kinds {
        // Correctness first: cached decode must equal full recompute.
        let cached_tokens = model.generate_greedy(&prompt, new_tokens, kind);
        let full_tokens = model.generate_greedy_full_recompute(&prompt, new_tokens, kind);
        assert_eq!(
            cached_tokens,
            full_tokens,
            "{} cached decode must be token-identical to full recompute",
            kind.label()
        );

        // Full-recompute generation (the no-cache baseline), batch 1.
        let t0 = Instant::now();
        std::hint::black_box(model.generate_greedy_full_recompute(&prompt, new_tokens, kind));
        let full_s = t0.elapsed().as_secs_f64();
        let full_tps = new_tokens as f64 / full_s;

        // Cached prefill + decode at each batch size.
        let engine = DecodeEngine::new(Arc::clone(&model), kind, context_len);
        let mut batch_json = Vec::new();
        let mut b1_decode_tps = 0f64;
        let mut cache_resident = 0usize;
        let mut cache_wire = 0usize;
        for &b in batches {
            let mut streams: Vec<DecodeStream> =
                (0..b).map(|_| engine.start(&prompt)).collect();
            // Step 1 is the prefill (plus the first generated token).
            let t0 = Instant::now();
            {
                let mut refs: Vec<&mut DecodeStream> = streams.iter_mut().collect();
                std::hint::black_box(engine.step(&mut refs));
            }
            let prefill_s = t0.elapsed().as_secs_f64();
            // Remaining steps are pure decode.
            let decode_steps = new_tokens - 1;
            let t0 = Instant::now();
            for _ in 0..decode_steps {
                let mut refs: Vec<&mut DecodeStream> = streams.iter_mut().collect();
                std::hint::black_box(engine.step(&mut refs));
            }
            let decode_s = t0.elapsed().as_secs_f64();
            let prefill_tps = (b * prompt_len) as f64 / prefill_s;
            let decode_tps = (b * decode_steps) as f64 / decode_s;
            if b == 1 {
                b1_decode_tps = decode_tps;
                cache_resident = streams[0].cache().resident_bytes();
                cache_wire = streams[0].cache().wire_bytes();
            }
            println!(
                "{:<5} batch {b:>2}: prefill {prefill_tps:9.1} tok/s   decode {decode_tps:9.1} \
                 tok/s   (full-recompute {full_tps:9.1} tok/s)",
                kind.label()
            );
            batch_json.push(format!(
                "\"b{b}\":{{\"batch\":{b},\"prefill_tps\":{prefill_tps:.2},\
                 \"decode_tps\":{decode_tps:.2}}}"
            ));
        }
        let speedup = b1_decode_tps / full_tps;
        println!(
            "{:<5} cached decode vs full recompute at T={context_len}: {speedup:.2}x, \
             cache {cache_resident} B resident / {cache_wire} B wire\n",
            kind.label()
        );
        kind_json.push(format!(
            "\"{}\":{{\"full_recompute_tps\":{full_tps:.2},\
             \"decode_speedup_vs_full_b1\":{speedup:.3},\
             \"cache_resident_bytes\":{cache_resident},\"cache_wire_bytes\":{cache_wire},\
             \"decode\":{{{}}}}}",
            kind.label(),
            batch_json.join(",")
        ));
    }

    // Per-kernel decode rows: the same model with HiF4-prepacked weights
    // (so every decode step runs the quantized GEMM) timed under each
    // plane backend. Tokens must be identical across kernels — the
    // backends are bit-identical — before anything is timed.
    let mut qcfg = zoo::llama3_tiny();
    qcfg.max_seq = context_len + 1;
    let mut qmodel = Transformer::init(qcfg, 91);
    qmodel.prepack_quantized_weights(QuantKind::HiF4);
    qmodel.release_dense_weights();
    let qmodel = Arc::new(qmodel);
    let qb = if quick { 2 } else { 8 };
    let prev_kernel = hif4::dotprod::kernel();
    let mut kernel_json = Vec::new();
    let mut reference_tokens: Option<Vec<usize>> = None;
    for kernel in [Kernel::Packed, Kernel::Simd] {
        set_kernel(kernel);
        let tokens = qmodel.generate_greedy(&prompt, new_tokens.min(8), KvCacheType::HIF4);
        if let Some(want) = &reference_tokens {
            assert_eq!(&tokens, want, "kernel backends must decode identical tokens");
        } else {
            reference_tokens = Some(tokens);
        }
        let engine = DecodeEngine::new(Arc::clone(&qmodel), KvCacheType::HIF4, context_len);
        let mut streams: Vec<DecodeStream> = (0..qb).map(|_| engine.start(&prompt)).collect();
        {
            let mut refs: Vec<&mut DecodeStream> = streams.iter_mut().collect();
            std::hint::black_box(engine.step(&mut refs)); // prefill
        }
        let decode_steps = new_tokens - 1;
        let t0 = Instant::now();
        for _ in 0..decode_steps {
            let mut refs: Vec<&mut DecodeStream> = streams.iter_mut().collect();
            std::hint::black_box(engine.step(&mut refs));
        }
        let decode_tps = (qb * decode_steps) as f64 / t0.elapsed().as_secs_f64();
        println!(
            "hif4-weights kernel {:<6} batch {qb:>2}: decode {decode_tps:9.1} tok/s",
            kernel.label()
        );
        kernel_json.push(format!(
            "\"{}\":{{\"batch\":{qb},\"decode_tps\":{decode_tps:.2}}}",
            kernel.label()
        ));
    }
    set_kernel(prev_kernel);
    println!();

    // Long-context decode: fused tiled attention over the packed KV lane
    // planes vs. per-step dense replay, at contexts far beyond what an
    // O(T²) prefill could reach in a bench. The cache is pre-filled with
    // synthetic rows (`KvCache::fill_synthetic` — deterministic, read
    // identically by both paths), then single-token decode steps are
    // timed against the full context. Greedy tokens must match between
    // the schedules before anything is timed.
    let long_contexts: &[usize] = if quick { &[256, 1024] } else { &[1024, 8192, 32768] };
    let long_steps = if quick { 4 } else { 16 };
    let long_kind = KvCacheType::HIF4;
    let mut long_json = Vec::new();
    for &t_ctx in long_contexts {
        let mut lcfg = zoo::llama3_tiny();
        lcfg.max_seq = t_ctx + long_steps + 1;
        let lmodel = Transformer::init(lcfg, 91);
        let run = |path: AttnPath| {
            let mut cache = KvCache::new(&lmodel.cfg, long_kind);
            cache.fill_synthetic(t_ctx, 7);
            let mut tok = 1usize;
            let mut toks = Vec::with_capacity(long_steps);
            let t0 = Instant::now();
            for _ in 0..long_steps {
                let tokens = [tok];
                let mut seqs = [CachedSeq { tokens: &tokens, cache: &mut cache }];
                let logits = lmodel.forward_cached_last_with(&mut seqs, path);
                tok = greedy_from_row(logits.row(0)).0;
                toks.push(tok);
            }
            (toks, long_steps as f64 / t0.elapsed().as_secs_f64())
        };
        let (replay_toks, replay_tps) = run(AttnPath::Replay);
        let (fused_toks, fused_tps) = run(AttnPath::Fused);
        assert_eq!(
            fused_toks, replay_toks,
            "fused and replay attention must decode identical tokens at T={t_ctx}"
        );
        // Per-step attention read traffic across both stores of every
        // layer: replay materializes each cached row as dense f32; fused
        // reads the resident planes (i8 lanes + f64 group scales).
        let kvd = lmodel.cfg.kv_heads() * lmodel.cfg.head_dim;
        let group = QuantKind::HiF4.group();
        let gpr = kvd.div_ceil(group);
        let stores = 2 * lmodel.cfg.n_layers;
        let replay_bytes = stores * t_ctx * kvd * 4;
        let fused_bytes = stores * t_ctx * gpr * (group + 8);
        let speedup = fused_tps / replay_tps;
        println!(
            "long-context {:<5} T={t_ctx:>6}: fused {fused_tps:9.1} tok/s   replay \
             {replay_tps:9.1} tok/s   ({speedup:.2}x, reads {fused_bytes} B vs {replay_bytes} B \
             per step)",
            long_kind.label()
        );
        long_json.push(format!(
            "\"c{t_ctx}\":{{\"context\":{t_ctx},\"steps\":{long_steps},\
             \"kind\":\"{}\",\"fused_tps\":{fused_tps:.2},\"replay_tps\":{replay_tps:.2},\
             \"fused_speedup\":{speedup:.3},\"fused_read_bytes_per_step\":{fused_bytes},\
             \"replay_read_bytes_per_step\":{replay_bytes}}}",
            long_kind.label()
        ));
    }
    println!();

    let json = format!(
        "{{\n  \"bench\": \"decode_throughput\",\n  \"quick\": {quick},\n  \
         \"threads\": {nthreads},\n  \"simd_isa\": \"{}\",\n  \
         \"prompt_len\": {prompt_len},\n  \"new_tokens\": {new_tokens},\n  \
         \"context_len\": {context_len},\n  \"parity\": true,\n  \
         \"kinds\": {{{}}},\n  \
         \"kernels\": {{{}}},\n  \
         \"long_context\": {{{}}}\n}}\n",
        simd_isa_label(),
        kind_json.join(","),
        kernel_json.join(","),
        long_json.join(",")
    );
    let path = "BENCH_decode.json";
    std::fs::write(path, &json).expect("write BENCH_decode.json");
    println!("wrote {path}");
}
