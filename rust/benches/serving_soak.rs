//! Serving soak under chaos: offered load vs goodput with deterministic
//! fault injection, plus a recovery-time probe — the resilience
//! counterpart of `benches/serving_throughput.rs`.
//!
//! Three phases against the native continuous-batching engine (same
//! model seed everywhere, so tokens are comparable across phases):
//!
//! 1. **baseline** — no faults: goodput and latency percentiles of the
//!    healthy server, plus the reference tokens per prompt;
//! 2. **chaos** — seeded worker panics + stalls, client garbage frames
//!    and dropped connections, a bounded queue forcing real shedding,
//!    and sprinkled 1ms deadlines forcing expiries. Retrying clients
//!    measure goodput under fire; every stream that completes must be
//!    token-identical to the baseline;
//! 3. **recovery** — a single guaranteed `panic_at_step`: wall time from
//!    the injected crash (first `Crashed` frame) until the retried
//!    request completes;
//! 4. **shared_prefix** — prefix cache on over small pages: every client
//!    re-sends a common 16-token system prefix plus a unique tail, so
//!    prefills attach refcounted shared pages instead of recomputing.
//!    Reports hit rate, resident bytes saved, and the same latency
//!    percentiles; survivors must stay token-identical to greedy decode.
//!
//! Writes `BENCH_serving.json` (offered/goodput/shed/expired/restarts/
//! retries, p50/p99/p999, recovery ms, prefix hit rate + bytes saved).
//! `HIF4_BENCH_QUICK=1` shrinks the request counts for CI.

use hif4::model::kv::KvCacheType;
use hif4::model::transformer::Transformer;
use hif4::model::zoo;
use hif4::server::batcher::BatchPolicy;
use hif4::server::faults::{quiet_injected_panics, FaultConfig, FaultPlan};
use hif4::server::protocol::{Request, Status};
use hif4::server::service::{
    Client, NativeServerConfig, ResilienceConfig, RetryPolicy, Server,
};
use hif4::util::bench::Table;
use hif4::util::json::Json;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

const MAX_PROMPT: usize = 32;
const N_NEW: u16 = 4;

fn start_server(model: Arc<Transformer>, resilience: ResilienceConfig) -> Server {
    start_server_tuned(model, resilience, |_| {})
}

/// `tune` adjusts the paging knobs (prefix cache, page height) on top of
/// the env-resolved defaults — the shared_prefix phase forces them on
/// regardless of the CI matrix leg.
fn start_server_tuned(
    model: Arc<Transformer>,
    resilience: ResilienceConfig,
    tune: impl FnOnce(&mut NativeServerConfig),
) -> Server {
    let mut cfg = NativeServerConfig {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        workers: 2,
        seq: MAX_PROMPT,
        kv: KvCacheType::F32,
        resilience,
        ..Default::default()
    };
    tune(&mut cfg);
    Server::start_native(model, cfg, "127.0.0.1:0").unwrap()
}

fn prompts(vocab: usize) -> Vec<Vec<usize>> {
    (0..8).map(|s| (0..6).map(|i| 1 + (i * 19 + s * 41) % (vocab - 1)).collect()).collect()
}

struct PhaseStats {
    offered: u64,
    completed: u64,
    expired: u64,
    retries: u64,
    elapsed: Duration,
    mismatches: u64,
}

/// Drive `n_requests` across `n_clients` retrying clients; verify every
/// completed stream against `reference` (tokens per prompt index). Every
/// 10th request carries a 1ms TTL (chaos phases expire it; the baseline
/// omits deadlines entirely when `with_deadlines` is false).
fn drive(
    server: &Server,
    n_clients: u64,
    n_requests: u64,
    reference: &[Vec<usize>],
    prompt_set: &[Vec<usize>],
    with_deadlines: bool,
) -> PhaseStats {
    let addr = server.addr;
    let t0 = Instant::now();
    let per_client = n_requests / n_clients;
    let results: Vec<(u64, u64, u64, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                s.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let policy = RetryPolicy {
                        max_retries: 12,
                        base: Duration::from_millis(2),
                        cap: Duration::from_millis(40),
                        seed: 0xB0_0000 + c,
                    };
                    let (mut ok, mut expired, mut retries) = (0u64, 0u64, 0u64);
                    let mut mismatches = 0u64;
                    for i in 0..per_client {
                        let pi = ((c + i) % prompt_set.len() as u64) as usize;
                        let mut req =
                            Request::generate(c * 10_000 + i, prompt_set[pi].clone(), N_NEW);
                        if with_deadlines && i % 10 == 9 {
                            req = req.with_deadline_ms(1);
                        }
                        match client.generate_retrying(&req, &policy) {
                            Ok((frames, r)) => {
                                retries += r as u64;
                                match frames.last().map(|f| f.status) {
                                    Some(Status::Ok) => {
                                        ok += 1;
                                        let got: Vec<usize> = frames
                                            .iter()
                                            .map(|f| f.token as usize)
                                            .collect();
                                        if got != reference[pi] {
                                            mismatches += 1;
                                        }
                                    }
                                    Some(Status::Expired) => expired += 1,
                                    _ => {}
                                }
                            }
                            Err(_) => {
                                // Connection-level loss even after retries:
                                // counted as non-goodput, keep driving.
                                let _ = client.reconnect();
                            }
                        }
                    }
                    (ok, expired, retries, mismatches)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut stats = PhaseStats {
        offered: per_client * n_clients,
        completed: 0,
        expired: 0,
        retries: 0,
        elapsed: t0.elapsed(),
        mismatches: 0,
    };
    for (ok, expired, retries, mismatches) in results {
        stats.completed += ok;
        stats.expired += expired;
        stats.retries += retries;
        stats.mismatches += mismatches;
    }
    stats
}

fn percentiles(server: &Server) -> (u64, u64, u64) {
    let m = &server.metrics;
    (m.percentile_us(0.50), m.percentile_us(0.99), m.percentile_us(0.999))
}

fn phase_json(server: &Server, st: &PhaseStats) -> Json {
    Json::obj(phase_fields(server, st))
}

fn phase_fields(server: &Server, st: &PhaseStats) -> Vec<(&'static str, Json)> {
    let (p50, p99, p999) = percentiles(server);
    let secs = st.elapsed.as_secs_f64().max(1e-9);
    let ord = Ordering::Relaxed;
    vec![
        ("offered", Json::num(st.offered as f64)),
        ("completed", Json::num(st.completed as f64)),
        ("expired", Json::num(st.expired as f64)),
        ("offered_rps", Json::num(st.offered as f64 / secs)),
        ("goodput_rps", Json::num(st.completed as f64 / secs)),
        ("shed_queue_full", Json::num(server.metrics.shed_queue_full.load(ord) as f64)),
        ("shed_kv_budget", Json::num(server.metrics.shed_kv_budget.load(ord) as f64)),
        (
            "shed_rate",
            Json::num(server.metrics.shed_total() as f64 / (st.offered as f64).max(1.0)),
        ),
        ("worker_restarts", Json::num(server.metrics.worker_restarts.load(ord) as f64)),
        ("client_retries", Json::num(st.retries as f64)),
        ("survivor_mismatches", Json::num(st.mismatches as f64)),
        ("p50_us", Json::num(p50 as f64)),
        ("p99_us", Json::num(p99 as f64)),
        ("p999_us", Json::num(p999 as f64)),
    ]
}

/// Recovery probe: sequential requests against a server whose fault plan
/// fires exactly one panic; returns ms from the first `Crashed` frame to
/// the next completed stream.
fn recovery_probe(
    model: Arc<Transformer>,
    reference: &[Vec<usize>],
    prompt_set: &[Vec<usize>],
) -> f64 {
    let faults = FaultConfig { panic_at_step: Some(8), ..Default::default() };
    let resilience = ResilienceConfig {
        faults: Some(Arc::new(FaultPlan::new(5, faults))),
        ..Default::default()
    };
    let server = start_server(model, resilience);
    let mut client = Client::connect(server.addr).unwrap();
    let policy = RetryPolicy {
        max_retries: 12,
        base: Duration::from_millis(1),
        cap: Duration::from_millis(10),
        seed: 1,
    };
    let mut crashed_at: Option<Instant> = None;
    let mut recovery = 0.0f64;
    for i in 0..40u64 {
        let pi = (i % prompt_set.len() as u64) as usize;
        let req = Request::generate(i, prompt_set[pi].clone(), N_NEW);
        // Plain generate so the Crashed frame is observable; retry by hand
        // to timestamp the crash → recovery window.
        match client.generate(&req) {
            Ok(frames) if frames.last().map(|f| f.status) == Some(Status::Ok) => {
                if let Some(t) = crashed_at.take() {
                    recovery = t.elapsed().as_secs_f64() * 1e3;
                    break;
                }
                let got: Vec<usize> = frames.iter().map(|f| f.token as usize).collect();
                assert_eq!(got, reference[pi], "pre-crash stream must match baseline");
            }
            Ok(_) => {
                crashed_at.get_or_insert_with(Instant::now);
                // Immediately retry through the policy: the supervisor is
                // restarting the worker concurrently.
                if let Ok((frames, _)) = client.generate_retrying(&req, &policy) {
                    if frames.last().map(|f| f.status) == Some(Status::Ok) {
                        if let Some(t) = crashed_at.take() {
                            recovery = t.elapsed().as_secs_f64() * 1e3;
                        }
                        break;
                    }
                }
            }
            Err(_) => {
                let _ = client.reconnect();
            }
        }
    }
    recovery
}

fn main() {
    quiet_injected_panics();
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    let (n_clients, n_requests) = if quick { (4u64, 80u64) } else { (8u64, 480u64) };

    let model = Arc::new(Transformer::init(zoo::llama3_tiny(), 5));
    let prompt_set = prompts(model.cfg.vocab);
    let reference: Vec<Vec<usize>> = prompt_set
        .iter()
        .map(|p| model.generate_greedy(p, N_NEW as usize, KvCacheType::F32))
        .collect();

    // Phase 1: healthy server.
    let baseline_server = start_server(Arc::clone(&model), ResilienceConfig::default());
    let base =
        drive(&baseline_server, n_clients, n_requests, &reference, &prompt_set, false);
    let base_json = phase_json(&baseline_server, &base);
    assert_eq!(base.mismatches, 0, "fault-free streams must match greedy decode");
    assert_eq!(base.completed, base.offered, "healthy server completes everything");

    // Phase 2: chaos — panics, stalls, bad clients, bounded queue,
    // sprinkled 1ms deadlines.
    let chaos_cfg = FaultConfig {
        panic_per_mille: 20,
        stall_per_mille: 40,
        stall_ms: 2,
        panic_at_step: Some(6),
        garbage_per_mille: 0, // framing chaos is covered by tests/chaos_soak.rs
        disconnect_per_mille: 0,
    };
    let resilience = ResilienceConfig {
        max_queue: 32,
        kv_budget_bytes: 1 << 30,
        faults: Some(Arc::new(FaultPlan::new(0x50AC, chaos_cfg))),
        ..Default::default()
    };
    let chaos_server = start_server(Arc::clone(&model), resilience);
    let chaos = drive(&chaos_server, n_clients, n_requests, &reference, &prompt_set, true);
    let chaos_json = phase_json(&chaos_server, &chaos);
    assert_eq!(chaos.mismatches, 0, "chaos survivors must be token-identical to baseline");
    assert!(
        chaos_server.metrics.worker_restarts.load(Ordering::Relaxed) >= 1,
        "panic_at_step guarantees at least one supervised restart"
    );
    chaos_server.metrics.record_retries(chaos.retries);

    // Phase 3: recovery time.
    let recovery_ms = recovery_probe(Arc::clone(&model), &reference, &prompt_set);

    // Phase 4: shared-prefix workload — dedup on, 8-row pages so the
    // 16-token system prefix is exactly two sharable chunks.
    let shared: Vec<usize> =
        (0..16).map(|i| 1 + (i * 13) % (model.cfg.vocab - 1)).collect();
    let prefix_prompts: Vec<Vec<usize>> = (0..8)
        .map(|s| {
            let mut p = shared.clone();
            p.extend((0..4).map(|i| 1 + (i * 7 + s * 31 + 5) % (model.cfg.vocab - 1)));
            p
        })
        .collect();
    let prefix_reference: Vec<Vec<usize>> = prefix_prompts
        .iter()
        .map(|p| model.generate_greedy(p, N_NEW as usize, KvCacheType::F32))
        .collect();
    let prefix_server =
        start_server_tuned(Arc::clone(&model), ResilienceConfig::default(), |cfg| {
            cfg.prefix_cache = true;
            cfg.page_rows = 8;
        });
    {
        // Warmup: one completed prefill registers the shared prefix, so
        // every driven request below can hit it.
        let mut c = Client::connect(prefix_server.addr).unwrap();
        let warm = c.generate(&Request::generate(999_999, shared.clone(), 1)).unwrap();
        assert_eq!(warm.last().map(|f| f.status), Some(Status::Ok), "warmup must complete");
    }
    let shared_st =
        drive(&prefix_server, n_clients, n_requests, &prefix_reference, &prefix_prompts, false);
    assert_eq!(shared_st.mismatches, 0, "prefix sharing must not change tokens");
    let pm = &prefix_server.metrics;
    assert!(
        pm.prefix_hits.load(Ordering::Relaxed) > 0,
        "a shared-prefix workload must hit the prefix cache"
    );
    assert!(pm.prefix_bytes_saved() > 0, "shared pages must show up as resident bytes saved");
    let mut shared_fields = phase_fields(&prefix_server, &shared_st);
    shared_fields.push(("prefix_hit_rate", Json::num(pm.prefix_hit_rate())));
    shared_fields.push((
        "prefix_hits",
        Json::num(pm.prefix_hits.load(Ordering::Relaxed) as f64),
    ));
    shared_fields.push((
        "prefix_misses",
        Json::num(pm.prefix_misses.load(Ordering::Relaxed) as f64),
    ));
    shared_fields.push(("resident_bytes_saved", Json::num(pm.prefix_bytes_saved() as f64)));
    shared_fields
        .push(("shared_refcount_high_water", Json::num(pm.shared_ref_high_water() as f64)));
    let shared_json = Json::obj(shared_fields);

    // Human-readable table + machine-readable artifact.
    let mut t = Table::new(
        "Serving soak: offered vs goodput",
        &["phase", "offered", "ok", "goodput r/s", "shed", "restarts", "p99 us"],
    );
    for (label, server, st) in [
        ("baseline", &baseline_server, &base),
        ("chaos", &chaos_server, &chaos),
        ("shared_prefix", &prefix_server, &shared_st),
    ] {
        let secs = st.elapsed.as_secs_f64().max(1e-9);
        t.row(vec![
            label.into(),
            st.offered.to_string(),
            st.completed.to_string(),
            format!("{:.1}", st.completed as f64 / secs),
            server.metrics.shed_total().to_string(),
            server.metrics.worker_restarts.load(Ordering::Relaxed).to_string(),
            server.metrics.percentile_us(0.99).to_string(),
        ]);
    }
    t.print();
    println!("recovery after injected crash: {recovery_ms:.1} ms");
    println!(
        "shared prefix: hit rate {:.3}, resident bytes saved {}",
        pm.prefix_hit_rate(),
        pm.prefix_bytes_saved()
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("serving_soak")),
        ("quick", Json::Bool(quick)),
        ("baseline", base_json),
        ("chaos", chaos_json),
        ("shared_prefix", shared_json),
        (
            "recovery",
            Json::obj(vec![
                ("injected_at_step", Json::num(8.0)),
                ("recovery_ms", Json::num(recovery_ms)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_serving.json", doc.render()).unwrap();
    println!("wrote BENCH_serving.json");
}
