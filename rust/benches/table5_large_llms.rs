//! Table V regeneration: the DeepSeek-V3.1 (MLA+MoE) and LongCat (MoE,
//! wide-distribution) stand-ins × 10 benchmarks × {BF16, NVFP4, NVFP4+PTS,
//! HiF4}, quantizing MLA_linear / MoE_linear (excluding the gate) /
//! FFN_linear per the paper's §IV.C policy.

use hif4::eval::tasks::Task;
use hif4::formats::QuantKind;
use hif4::model::zoo;
use hif4::quant::experiment::{run_model, ExperimentConfig, QuantType};
use hif4::util::bench::Table;

fn main() {
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    let xcfg = if quick {
        ExperimentConfig {
            train_steps: 60,
            eval_items: 20,
            eval_seeds: vec![1],
            ..Default::default()
        }
    } else {
        ExperimentConfig { train_steps: 320, ..Default::default() }
    };
    // Table V evaluates direct-cast types only (no HiGPTQ rows).
    let types = [
        QuantType::Bf16,
        QuantType::Direct(QuantKind::Nvfp4),
        QuantType::Pts(QuantKind::Nvfp4),
        QuantType::Direct(QuantKind::HiF4),
    ];
    let suite = Task::large_suite();

    let mut header: Vec<String> = vec!["Model".into(), "A-W Quant Type".into()];
    header.extend(suite.iter().map(|t| t.name().to_string()));
    header.push("Mean".into());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table V: DeepSeek/LongCat stand-ins x 10 benchmarks", &hdr);

    for (i, cfg) in zoo::large_llms().iter().enumerate() {
        let t0 = std::time::Instant::now();
        let block = run_model(cfg, &suite, &types, &xcfg, 500 + i as u64);
        eprintln!(
            "[{}] trained (loss {:.3} -> {:.3}) + evaluated in {:.1?}",
            cfg.name,
            block.losses[0],
            block.losses.last().unwrap(),
            t0.elapsed()
        );
        for (qi, row) in block.rows.iter().enumerate() {
            let mut cells = vec![
                if qi == 0 { block.model_name.clone() } else { String::new() },
                row.label.clone(),
            ];
            cells.extend(row.task_acc.iter().map(|a| format!("{a:.2}")));
            cells.push(format!("{:.2}", row.mean));
            t.row(cells);
            if qi > 0 {
                let mut cells = vec![String::new(), "- Acc Drop".into()];
                cells.extend(block.drops(qi).iter().map(|d| format!("{d:+.2}")));
                cells.push(format!("{:+.2}", row.mean - block.rows[0].mean));
                t.row(cells);
            }
        }
    }
    t.print();

    println!("\nExpected shape (paper §IV.C): HiF4 direct-cast tracks BF16 on both MoE/MLA");
    println!("stand-ins; NVFP4 (±PTS) degrades hard on the wide-distribution LongCat stand-in.");
}
