//! Fig 4 regeneration: the 64-length dot-product compute flows. Reports the
//! datapath inventory (multiplier counts — HiF4 eliminates six), verifies
//! bit-exactness against the dequantized reference, and measures simulator
//! throughput of both flows and the quantized GEMMs built on them.

use hif4::dotprod::{hif4_flow, nvfp4_flow, QuantizedMatrix};
use hif4::formats::rounding::RoundMode;
use hif4::formats::QuantKind;
use hif4::tensor::{Matrix, Rng};
use hif4::util::bench::{BenchRunner, Table};

fn main() {
    // Datapath inventory (the Fig 4 structural claim).
    let h = hif4_flow::stats();
    let n = nvfp4_flow::stats();
    let mut t = Table::new(
        "Fig 4: 64-length dot product datapath inventory",
        &["resource", "HiF4", "NVFP4"],
    );
    let rows: [(&str, usize, usize); 6] = [
        ("5-bit element multipliers (shared)", h.small_int_muls, n.small_int_muls),
        ("small FP scale multipliers", h.small_fp_muls, n.small_fp_muls),
        ("large INT multipliers", h.large_int_muls, n.large_int_muls),
        ("integer tree adders", h.int_adds, n.int_adds),
        ("FP accumulation adders", h.fp_adds, n.fp_adds),
        ("reduced integer width (bits)", h.final_int_bits as usize, n.final_int_bits as usize),
    ];
    for (name, a, b) in rows {
        t.row(vec![name.into(), a.to_string(), b.to_string()]);
    }
    t.print();
    println!(
        "multipliers eliminated by HiF4: {} (paper: six)\n",
        (n.small_fp_muls + n.large_int_muls) - (h.small_fp_muls + h.large_int_muls)
    );

    // Bit-exactness spot check + throughput.
    let r = BenchRunner::from_env();
    let mut rng = Rng::seed(5);
    let va: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
    let vb: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
    let ua = hif4::formats::hif4::quantize(&va, RoundMode::NearestEven);
    let ub = hif4::formats::hif4::quantize(&vb, RoundMode::NearestEven);
    assert_eq!(hif4_flow::dot(&ua, &ub), hif4_flow::dot_dequant_ref(&ua, &ub));
    let ga: Vec<_> = va
        .chunks(16)
        .map(|c| hif4::formats::nvfp4::quantize(c, RoundMode::NearestEven))
        .collect();
    let gb: Vec<_> = vb
        .chunks(16)
        .map(|c| hif4::formats::nvfp4::quantize(c, RoundMode::NearestEven))
        .collect();
    assert_eq!(nvfp4_flow::dot64(&ga, &gb), nvfp4_flow::dot64_dequant_ref(&ga, &gb));
    println!("bit-exactness vs dequantized reference: OK\n");

    r.run("HiF4 PE flow (64-elem dot)", Some(64), || {
        std::hint::black_box(hif4_flow::dot(&ua, &ub));
    });
    r.run("NVFP4 PE flow (64-elem dot)", Some(64), || {
        std::hint::black_box(nvfp4_flow::dot64(&ga, &gb));
    });

    // Quantized GEMM built from the PE flows. The entry points dispatch
    // on the process kernel backend (flow reference vs decode-once packed
    // planes — bit-identical; see benches/qgemm_throughput.rs for the
    // backend comparison).
    println!(
        "qgemm kernel backend: {} (simd isa: {})",
        hif4::dotprod::kernel().label(),
        hif4::dotprod::simd_isa_label()
    );
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    let (m, k, nn) = if quick { (16, 128, 16) } else { (64, 512, 64) };
    let a = Matrix::randn(m, k, 1.0, &mut rng);
    let b = Matrix::randn(nn, k, 1.0, &mut rng);
    let qa = QuantizedMatrix::quantize(QuantKind::HiF4, &a, RoundMode::NearestEven);
    let qb = QuantizedMatrix::quantize(QuantKind::HiF4, &b, RoundMode::NearestEven);
    let na = QuantizedMatrix::quantize(QuantKind::Nvfp4, &a, RoundMode::NearestEven);
    let nb = QuantizedMatrix::quantize(QuantKind::Nvfp4, &b, RoundMode::NearestEven);
    let flops = (2 * m * k * nn) as u64;
    r.run(&format!("HiF4 qgemm {m}x{k}x{nn} (flops)"), Some(flops), || {
        std::hint::black_box(qa.qgemm_bt(&qb));
    });
    r.run(&format!("NVFP4 qgemm {m}x{k}x{nn} (flops)"), Some(flops), || {
        std::hint::black_box(na.qgemm_bt(&nb));
    });

    // Parallel scaling of the blocked QGEMM: serial baseline vs the
    // row-banded kernel on N threads (bit-identical outputs; see
    // tests/parallel_parity.rs). On ≥4 cores the 4-thread run should be
    // ≥2x the threads=1 rate at these shapes.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let nthreads = cores.min(4).max(2);
    println!("\nparallel scaling ({cores} cores available):");
    let s1 = r.run(&format!("HiF4 qgemm {m}x{k}x{nn} threads=1"), Some(flops), || {
        std::hint::black_box(qa.qgemm_bt_threads(&qb, 1));
    });
    let sn = r.run(&format!("HiF4 qgemm {m}x{k}x{nn} threads={nthreads}"), Some(flops), || {
        std::hint::black_box(qa.qgemm_bt_threads(&qb, nthreads));
    });
    println!(
        "  HiF4 qgemm speedup: {:.2}x on {nthreads} threads",
        s1.mean.as_secs_f64() / sn.mean.as_secs_f64()
    );
    let s1 = r.run(&format!("NVFP4 qgemm {m}x{k}x{nn} threads=1"), Some(flops), || {
        std::hint::black_box(na.qgemm_bt_threads(&nb, 1));
    });
    let sn = r.run(&format!("NVFP4 qgemm {m}x{k}x{nn} threads={nthreads}"), Some(flops), || {
        std::hint::black_box(na.qgemm_bt_threads(&nb, nthreads));
    });
    println!(
        "  NVFP4 qgemm speedup: {:.2}x on {nthreads} threads",
        s1.mean.as_secs_f64() / sn.mean.as_secs_f64()
    );
}
