//! Serving bench, two engines through the full coordinator (router →
//! dynamic batcher → worker pool):
//!
//! * **native** (always runs, no artifacts): the rust-native transformer —
//!   BF16 dense vs real-quantized HiF4 with the flow kernel vs the packed
//!   kernel, so the decode-once payoff shows up as served req/s;
//! * **PJRT** (requires `make artifacts`): BF16 vs HiF4 vs NVFP4 forward
//!   artifacts per batching policy.

use hif4::dotprod::{set_kernel, Kernel};
use hif4::formats::{QuantKind, QuantScheme};
use hif4::model::kv::KvCacheType;
use hif4::model::transformer::Transformer;
use hif4::model::zoo;
use hif4::runtime::artifact::Manifest;
use hif4::server::batcher::BatchPolicy;
use hif4::server::protocol::Request;
use hif4::server::service::{Client, NativeServerConfig, Server, ServerConfig};
use hif4::tensor::Rng;
use hif4::util::bench::Table;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Drive `n_requests` pipelined requests against `server`; returns req/s.
fn drive(server: &Server, n_requests: usize, vocab: usize, seq: usize) -> f64 {
    let mut client = Client::connect(server.addr).unwrap();
    let mut rng = Rng::seed(9);
    let t0 = Instant::now();
    let window = 16usize;
    let (mut sent, mut recv) = (0usize, 0usize);
    while recv < n_requests {
        while sent < n_requests && sent - recv < window {
            let len = (3 + rng.below(6)).min(seq);
            let tokens: Vec<usize> = (0..len).map(|_| 1 + rng.below(vocab - 1)).collect();
            client.send(&Request::next_token(sent as u64, tokens)).unwrap();
            sent += 1;
        }
        client.recv().unwrap();
        recv += 1;
    }
    n_requests as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    let n_requests = if quick { 64 } else { 512 };
    let workers: usize = std::env::var("HIF4_SERVE_WORKERS")
        .ok()
        .and_then(|w| w.parse().ok())
        .unwrap_or(1);

    // ---- Native engine: always runs, exercises the packed QGEMM. ----
    let cfg = zoo::llama3_tiny(); // GQA + SwiGLU, the serving shape class
    let base = Transformer::init(cfg.clone(), 5);
    // The per-row sweep writes the process-wide knob; restore whatever
    // the user asked for (HIF4_KERNEL) before the PJRT section.
    let prev_kernel = hif4::dotprod::kernel();
    let mut t = Table::new(
        "Native serving: engine x kernel backend",
        &["engine", "kernel", "req/s", "mean lat", "mean batch"],
    );
    for (label, quantize, kernel) in [
        ("native-bf16", None, Kernel::Simd),
        ("native-hif4", Some(QuantKind::HiF4), Kernel::Flow),
        ("native-hif4", Some(QuantKind::HiF4), Kernel::Packed),
        // The SIMD-tiled microkernel, end to end through the server.
        ("native-hif4", Some(QuantKind::HiF4), Kernel::Simd),
        // One of the formats the packed layer gained in the unified
        // QuantTensor redesign, end to end through the server.
        ("native-mxfp4", Some(QuantKind::Mxfp4), Kernel::Simd),
    ] {
        let mut model = base.clone();
        if let Some(kind) = quantize {
            // Real-quantized serving: weight planes pack once, here, and
            // the dense f32 planes are freed like a real deployment.
            model.prepack_quantized_weights(kind);
            model.release_dense_weights();
        }
        set_kernel(kernel);
        let server = Server::start_native(
            Arc::new(model),
            NativeServerConfig {
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
                workers,
                seq: cfg.max_seq,
                kv: KvCacheType::F32,
                ..Default::default()
            },
            "127.0.0.1:0",
        )
        .unwrap();
        let rps = drive(&server, n_requests, cfg.vocab, cfg.max_seq);
        t.row(vec![
            label.into(),
            format!("{kernel:?}"),
            format!("{rps:.1}"),
            format!("{:.1}ms", server.metrics.mean_us() / 1000.0),
            format!("{:.2}", server.metrics.mean_batch_size()),
        ]);
    }
    set_kernel(prev_kernel);
    t.print();
    println!(
        "flow→packed→simd on the same quantized model shows the decode-once and \
         register-tiling payoffs in req/s.\n"
    );

    // ---- PJRT engine: needs lowered artifacts. ----
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("SKIP PJRT serving bench: artifacts/ missing — run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let params = manifest.init_params(5);

    let mut t = Table::new(
        "Serving: artifact x batching policy",
        &["artifact", "max_batch", "req/s", "mean lat", "p99 lat", "mean batch"],
    );
    for artifact in ["fwd_bf16.hlo.txt", "fwd_hif4.hlo.txt", "fwd_nvfp4.hlo.txt"] {
        for max_batch in [1usize, 8] {
            let mut served = params.clone();
            // The shared artifact-name sniffing rule (same as the server's
            // metrics tag), so rows can never mislabel their format.
            if let Some(fmt) = QuantKind::from_artifact_name(artifact) {
                served.quantize_weights(&QuantScheme::direct(fmt));
            }
            let cfg = ServerConfig {
                artifact: artifact.into(),
                policy: BatchPolicy { max_batch, max_wait: Duration::from_millis(2) },
                workers: std::env::var("HIF4_SERVE_WORKERS")
                    .ok()
                    .and_then(|w| w.parse().ok())
                    .unwrap_or(1),
                resilience: Default::default(),
            };
            let server = Server::start(dir, cfg, &served, "127.0.0.1:0").unwrap();
            let mut client = Client::connect(server.addr).unwrap();
            let mut rng = Rng::seed(9);
            let t0 = Instant::now();
            let window = 16usize;
            let mut sent = 0usize;
            let mut recv = 0usize;
            while recv < n_requests {
                while sent < n_requests && sent - recv < window {
                    let len = 3 + rng.below(6);
                    let tokens: Vec<usize> = (0..len).map(|_| 1 + rng.below(300)).collect();
                    client.send(&Request::next_token(sent as u64, tokens)).unwrap();
                    sent += 1;
                }
                client.recv().unwrap();
                recv += 1;
            }
            let dt = t0.elapsed();
            t.row(vec![
                artifact.into(),
                max_batch.to_string(),
                format!("{:.1}", n_requests as f64 / dt.as_secs_f64()),
                format!("{:.1}ms", server.metrics.mean_us() / 1000.0),
                format!("<{:.1}ms", server.metrics.percentile_us(0.99) as f64 / 1000.0),
                format!("{:.2}", server.metrics.mean_batch_size()),
            ]);
        }
    }
    t.print();
    println!("\nBatching (max_batch 8 vs 1) should multiply req/s at similar p99 —");
    println!("the dynamic-batching payoff; quantized artifacts add in-graph qdq cost on CPU.");
}
