//! Table I regeneration: E6M2 and S1P2 encoding details, derived from the
//! codecs (not hardcoded) + exhaustive encode/decode timing.

use hif4::formats::e6m2::{self, E6M2};
use hif4::formats::rounding::RoundMode;
use hif4::formats::s1p2::{self, S1P2};
use hif4::util::bench::{BenchRunner, Table};

fn main() {
    let mut t = Table::new(
        "Table I: E6M2 and S1P2 encoding details",
        &["property", "Unsigned FP8-E6M2", "Sign-Magnitude S1P2"],
    );
    t.row(vec!["Exponent Bias".into(), e6m2::BIAS.to_string(), "N/A".into()]);
    t.row(vec![
        "Unbiased Exp".into(),
        format!("[{}, {}]", e6m2::EXP_MIN, e6m2::EXP_MAX),
        "N/A".into(),
    ]);
    t.row(vec!["Infinity".into(), "N/A".into(), "N/A".into()]);
    t.row(vec![
        "Zero".into(),
        "N/A".into(),
        format!("{} / {}", S1P2::POS_ZERO.to_f32(), S1P2::NEG_ZERO.to_f32()),
    ]);
    t.row(vec!["NaN".into(), format!("{:#04x}", e6m2::NAN_BITS), "N/A".into()]);
    t.row(vec![
        "Max Value".into(),
        format!(
            "2^{} x {} = {:.5e}",
            E6M2::MAX.exponent(),
            1.0 + E6M2::MAX.mantissa() as f32 / 4.0,
            E6M2::MAX.to_f32()
        ),
        format!("±{}", s1p2::MAX_ABS),
    ]);
    t.row(vec![
        "Min Value".into(),
        format!("2^{} x 1.00 = {:.5e}", E6M2::MIN.exponent(), E6M2::MIN.to_f32()),
        format!("±{} (min pos)", s1p2::MIN_POS),
    ]);
    t.print();

    // Exhaustive verification counts as the "bench": every encoding must
    // roundtrip, and the REC LUT must equal bf16(1/x) on all 255 codes.
    let r = BenchRunner::from_env();
    r.run("E6M2 exhaustive roundtrip+REC (255 codes)", Some(255), || {
        for bits in 0u16..=254 {
            let v = E6M2(bits as u8);
            assert_eq!(E6M2::from_f32(v.to_f32(), RoundMode::NearestEven), v);
            assert!(v.reciprocal_bf16().is_finite());
        }
    });
    r.run("S1P2 exhaustive roundtrip (16 codes)", Some(16), || {
        for bits in 0u8..16 {
            let v = S1P2(bits);
            assert_eq!(
                S1P2::from_f32(v.to_f32(), RoundMode::NearestEven).signed_q(),
                v.signed_q()
            );
        }
    });
}
