//! The comparative accuracy battery as a release artifact: runs the
//! format × quant mode × zoo model × task matrix (plus held-out perplexity
//! and the per-layer sensitivity sweep) and writes the schema-versioned
//! `BENCH_accuracy.json` CI uploads, next to human-readable tables.
//!
//! HIF4_BENCH_QUICK=1 switches to the quick matrix — the same
//! configuration `tests/accuracy_battery.rs` diffs against the checked-in
//! golden file, so the uploaded quick artifact and the golden agree by
//! construction. Override the output path with HIF4_BENCH_OUT.

use hif4::eval::battery::{self, BatteryConfig};
use hif4::util::json::Json;

fn main() {
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    let cfg = if quick { BatteryConfig::quick() } else { BatteryConfig::full() };
    eprintln!(
        "accuracy battery [{}]: {} models x {} rows ({} formats x {} modes + {} fixed + bf16) x {} tasks",
        if quick { "quick" } else { "full" },
        cfg.models.len(),
        cfg.quant_types().len() + 1,
        cfg.formats.len(),
        cfg.modes.len(),
        cfg.fixed_formats.len(),
        cfg.tasks.len(),
    );
    let t0 = std::time::Instant::now();
    let doc = battery::run(&cfg);
    eprintln!("battery complete in {:.1?}", t0.elapsed());

    battery::print_tables(&doc);

    // Headline: HiF4-vs-NVFP4 mean-accuracy delta per mode, averaged over
    // models (positive = HiF4 better — the paper's claim).
    for (mi, mode) in
        doc.get("modes").and_then(Json::as_arr).unwrap_or(&[]).iter().enumerate()
    {
        let mode = mode.as_str().unwrap_or("?");
        let deltas: Vec<f64> = doc
            .get("models")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|m| {
                m.get("hif4_vs_nvfp4")
                    .and_then(Json::as_arr)
                    .and_then(|d| d.get(mi))
                    .and_then(|d| d.get("mean_delta"))
                    .and_then(Json::as_f64)
            })
            .collect();
        if !deltas.is_empty() {
            let mean = deltas.iter().sum::<f64>() / deltas.len() as f64;
            println!(
                "HiF4 - NVFP4 mean accuracy ({mode}, {} models): {mean:+.2} points",
                deltas.len()
            );
        }
    }

    let out = std::env::var("HIF4_BENCH_OUT").unwrap_or_else(|_| "BENCH_accuracy.json".into());
    std::fs::write(&out, doc.render()).expect("write battery artifact");
    println!("wrote {out}");
}
