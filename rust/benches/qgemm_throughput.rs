//! Flow-vs-packed quantized GEMM throughput at serving-like shapes.
//!
//! Times the reference flow kernel against the decode-once packed kernel
//! (single- and multi-thread), asserts their outputs are bit-identical,
//! and writes `BENCH_qgemm.json` (GFLOP/s + speedups) so the perf
//! trajectory is machine-readable across PRs. `HIF4_BENCH_QUICK=1`
//! shrinks to one small shape for CI smoke runs (build + run, no
//! thresholds enforced here).
//!
//! "Packed (end-to-end)" includes packing both operands fresh each call —
//! the worst case for the packed path; "packed (prepacked)" reuses the
//! planes, which is how the model/serving layers actually run (weights
//! pack once, activations per call).

use hif4::dotprod::packed::{
    hif4_gemm_bt_packed_threads, nvfp4_gemm_bt_packed_threads, PackedHiF4Matrix,
    PackedNvfp4Matrix,
};
use hif4::dotprod::qgemm::{
    hif4_gemm_bt_flow_threads, nvfp4_gemm_bt_flow_threads, HiF4Matrix, Nvfp4Matrix,
};
use hif4::formats::rounding::RoundMode;
use hif4::tensor::{Matrix, Rng};
use hif4::util::threadpool;
use std::time::Instant;

/// Best-of-`reps` wall time of `f`, in seconds (result is black-boxed).
fn secs<T, F: FnMut() -> T>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

struct KernelTimes {
    flow_s: f64,
    packed_s: f64,
    packed_prepacked_s: f64,
    pack_s: f64,
}

impl KernelTimes {
    fn row(&self, label: &str, flops: f64) -> String {
        let gf = |s: f64| flops / s / 1e9;
        println!(
            "{label:<28} flow {:8.3}s ({:6.3} GFLOP/s)  packed e2e {:8.3}s ({:6.3} GFLOP/s)  \
             prepacked {:8.3}s ({:6.3} GFLOP/s)  pack {:6.3}s  speedup {:5.2}x (e2e) {:5.2}x (prepacked)",
            self.flow_s,
            gf(self.flow_s),
            self.packed_s,
            gf(self.packed_s),
            self.packed_prepacked_s,
            gf(self.packed_prepacked_s),
            self.pack_s,
            self.flow_s / self.packed_s,
            self.flow_s / self.packed_prepacked_s,
        );
        // Inner JSON fields (no braces); the caller wraps them.
        format!(
            "\"flow_s\":{:.6},\"packed_s\":{:.6},\"packed_prepacked_s\":{:.6},\
             \"pack_s\":{:.6},\"flow_gflops\":{:.4},\"packed_gflops\":{:.4},\
             \"packed_prepacked_gflops\":{:.4},\"speedup\":{:.3},\"speedup_prepacked\":{:.3}",
            self.flow_s,
            self.packed_s,
            self.packed_prepacked_s,
            self.pack_s,
            gf(self.flow_s),
            gf(self.packed_s),
            gf(self.packed_prepacked_s),
            self.flow_s / self.packed_s,
            self.flow_s / self.packed_prepacked_s,
        )
    }
}

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

fn main() {
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    // Serving-like shape: decode activations (batch·seq = 512 rows) ×
    // d_ff-scale weights over a 4096 reduction.
    let (m, k, n) = if quick { (64, 512, 64) } else { (512, 4096, 512) };
    let reps_flow = if quick { 3 } else { 1 };
    let reps_packed = if quick { 5 } else { 3 };
    let nthreads = threadpool::threads();
    let flops = (2 * m * k * n) as f64;
    let mode = RoundMode::NearestEven;

    let mut rng = Rng::seed(17);
    let a = Matrix::randn(m, k, 1.0, &mut rng);
    let b = Matrix::randn(n, k, 1.0, &mut rng);

    println!("qgemm throughput — shape {m}x{k}x{n}, multi-thread = {nthreads}\n");

    // ---- HiF4 ----
    let qa = HiF4Matrix::quantize(&a, mode);
    let qb = HiF4Matrix::quantize(&b, mode);
    let pa = PackedHiF4Matrix::pack_threads(&qa, 1);
    let pb = PackedHiF4Matrix::pack_threads(&qb, 1);
    // Bit-identity of the two backends on the bench shape itself.
    let c_flow = hif4_gemm_bt_flow_threads(&qa, &qb, nthreads);
    let c_packed = hif4_gemm_bt_packed_threads(&pa, &pb, nthreads);
    let identical = bits(&c_flow) == bits(&c_packed);
    assert!(identical, "flow and packed kernels must agree bit for bit");
    drop((c_flow, c_packed));

    let mut hif4_json = Vec::new();
    for (label, threads) in [("single", 1usize), ("multi", nthreads)] {
        let flow_s =
            secs(reps_flow, || std::hint::black_box(hif4_gemm_bt_flow_threads(&qa, &qb, threads)));
        let prepacked_s = secs(reps_packed, || {
            std::hint::black_box(hif4_gemm_bt_packed_threads(&pa, &pb, threads))
        });
        // Pack cost at *this* thread count (the amortized one-time cost).
        let pack_s = secs(reps_packed, || {
            std::hint::black_box(PackedHiF4Matrix::pack_threads(&qa, threads));
            std::hint::black_box(PackedHiF4Matrix::pack_threads(&qb, threads));
        });
        let e2e_s = secs(reps_packed, || {
            let xa = PackedHiF4Matrix::pack_threads(&qa, threads);
            let xb = PackedHiF4Matrix::pack_threads(&qb, threads);
            std::hint::black_box(hif4_gemm_bt_packed_threads(&xa, &xb, threads));
        });
        let t = KernelTimes {
            flow_s,
            packed_s: e2e_s,
            packed_prepacked_s: prepacked_s,
            pack_s,
        };
        let fields = t.row(&format!("HiF4 {label} ({threads}t)"), flops);
        hif4_json.push(format!("\"{label}\":{{\"threads\":{threads},{fields}}}"));
    }

    // ---- NVFP4 ----
    let na = Nvfp4Matrix::quantize(&a, mode);
    let nb = Nvfp4Matrix::quantize(&b, mode);
    let npa = PackedNvfp4Matrix::pack_threads(&na, 1);
    let npb = PackedNvfp4Matrix::pack_threads(&nb, 1);
    let mut nvfp4_json = Vec::new();
    for (label, threads) in [("single", 1usize), ("multi", nthreads)] {
        let flow_s = secs(reps_flow, || {
            std::hint::black_box(nvfp4_gemm_bt_flow_threads(&na, &nb, threads))
        });
        let prepacked_s = secs(reps_packed, || {
            std::hint::black_box(nvfp4_gemm_bt_packed_threads(&npa, &npb, threads))
        });
        let pack_s = secs(reps_packed, || {
            std::hint::black_box(PackedNvfp4Matrix::pack_threads(&na, threads));
            std::hint::black_box(PackedNvfp4Matrix::pack_threads(&nb, threads));
        });
        let e2e_s = secs(reps_packed, || {
            let xa = PackedNvfp4Matrix::pack_threads(&na, threads);
            let xb = PackedNvfp4Matrix::pack_threads(&nb, threads);
            std::hint::black_box(nvfp4_gemm_bt_packed_threads(&xa, &xb, threads));
        });
        let t = KernelTimes {
            flow_s,
            packed_s: e2e_s,
            packed_prepacked_s: prepacked_s,
            pack_s,
        };
        let fields = t.row(&format!("NVFP4 {label} ({threads}t)"), flops);
        nvfp4_json.push(format!("\"{label}\":{{\"threads\":{threads},{fields}}}"));
    }

    let json = format!(
        "{{\n  \"bench\": \"qgemm_throughput\",\n  \"quick\": {quick},\n  \
         \"shape\": {{\"m\": {m}, \"k\": {k}, \"n\": {n}}},\n  \
         \"bit_identical\": {identical},\n  \
         \"hif4\": {{{}}},\n  \"nvfp4\": {{{}}}\n}}\n",
        hif4_json.join(","),
        nvfp4_json.join(",")
    );
    let path = "BENCH_qgemm.json";
    std::fs::write(path, &json).expect("write BENCH_qgemm.json");
    println!("\nwrote {path}");
}
