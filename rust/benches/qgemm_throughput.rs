//! Per-kernel quantized GEMM throughput at serving-like shapes, across
//! **all five block formats** through the unified `QuantizedMatrix` API.
//!
//! For every format: times the reference flow kernel against both plane
//! backends — the scalar packed kernel and the SIMD-tiled microkernel
//! (single- and multi-thread) — asserts all three outputs are
//! bit-identical, and writes `BENCH_qgemm.json` keyed by format spelling
//! with one row per kernel backend (GFLOP/s + speedups, plus the
//! detected SIMD lane ISA) so the perf trajectory is machine-readable
//! across PRs. The full run uses a 512×512×512 GEMM — the shape the
//! acceptance gate reads `simd` vs `packed` from; `HIF4_BENCH_QUICK=1`
//! shrinks to one small shape for CI smoke runs (build + run, no
//! thresholds enforced here).
//!
//! "e2e" packs both operands fresh each call — the worst case for the
//! plane backends; "prepacked" reuses the planes, which is how the
//! model/serving layers actually run (weights pack once, activations per
//! call).

use hif4::dotprod::{simd_isa_label, QuantizedMatrix};
use hif4::formats::rounding::RoundMode;
use hif4::formats::QuantKind;
use hif4::tensor::{Matrix, Rng};
use hif4::util::threadpool;
use std::time::Instant;

/// Best-of-`reps` wall time of `f`, in seconds (result is black-boxed).
fn secs<T, F: FnMut() -> T>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

fn main() {
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    // Full run: the 512×512×512 GEMM the acceptance gate reads (the flow
    // kernel is slow by design — per-element re-decode — so the shape is
    // modest; the plane backends are what the comparison is about).
    let (m, k, n) = if quick { (64, 512, 64) } else { (512, 512, 512) };
    let reps_flow = if quick { 3 } else { 1 };
    let reps_packed = if quick { 5 } else { 3 };
    let nthreads = threadpool::threads();
    let flops = (2 * m * k * n) as f64;
    let mode = RoundMode::NearestEven;

    let mut rng = Rng::seed(17);
    let a = Matrix::randn(m, k, 1.0, &mut rng);
    let b = Matrix::randn(n, k, 1.0, &mut rng);

    println!(
        "qgemm throughput — shape {m}x{k}x{n}, multi-thread = {nthreads}, simd isa = {}\n",
        simd_isa_label()
    );

    let mut format_json = Vec::new();
    for kind in QuantKind::ALL {
        let qa = QuantizedMatrix::quantize(kind, &a, mode);
        let qb = QuantizedMatrix::quantize(kind, &b, mode);
        let pa = qa.pack_threads(1);
        let pb = qb.pack_threads(1);
        // Bit-identity of the three backends on the bench shape itself —
        // any mismatch aborts before the JSON is written, so a written
        // `bit_identical` is true by construction.
        let c_flow = qa.qgemm_bt_flow_threads(&qb, nthreads);
        let c_packed = pa.qgemm_bt_packed_threads(&pb, nthreads);
        let c_simd = pa.qgemm_bt_simd_threads(&pb, nthreads);
        assert!(
            bits(&c_flow) == bits(&c_packed),
            "{kind}: flow and packed kernels must agree bit for bit"
        );
        assert!(
            bits(&c_packed) == bits(&c_simd),
            "{kind}: packed and simd kernels must agree bit for bit"
        );
        drop((c_flow, c_packed, c_simd));

        let mut rows_json = Vec::new();
        for (label, threads) in [("single", 1usize), ("multi", nthreads)] {
            let flow_s =
                secs(reps_flow, || std::hint::black_box(qa.qgemm_bt_flow_threads(&qb, threads)));
            let packed_s = secs(reps_packed, || {
                std::hint::black_box(pa.qgemm_bt_packed_threads(&pb, threads))
            });
            let simd_s = secs(reps_packed, || {
                std::hint::black_box(pa.qgemm_bt_simd_threads(&pb, threads))
            });
            // Pack cost at *this* thread count (the amortized one-time
            // cost) and the pack-fresh-each-call end-to-end variant on
            // the fastest plane backend.
            let pack_s = secs(reps_packed, || {
                std::hint::black_box(qa.pack_threads(threads));
                std::hint::black_box(qb.pack_threads(threads));
            });
            let e2e_s = secs(reps_packed, || {
                let xa = qa.pack_threads(threads);
                let xb = qb.pack_threads(threads);
                std::hint::black_box(xa.qgemm_bt_simd_threads(&xb, threads))
            });
            let gf = |s: f64| flops / s / 1e9;
            println!(
                "{:<28} flow {:8.3}s ({:6.3} GF/s)  packed {:8.3}s ({:6.3} GF/s)  \
                 simd {:8.3}s ({:6.3} GF/s)  pack {:6.3}s  simd-vs-packed {:5.2}x  \
                 simd-vs-flow {:5.2}x",
                format!("{} {label} ({threads}t)", kind.name()),
                flow_s,
                gf(flow_s),
                packed_s,
                gf(packed_s),
                simd_s,
                gf(simd_s),
                pack_s,
                packed_s / simd_s,
                flow_s / simd_s,
            );
            rows_json.push(format!(
                "\"{label}\":{{\"threads\":{threads},\
                 \"kernels\":{{\
                 \"flow\":{{\"s\":{flow_s:.6},\"gflops\":{:.4}}},\
                 \"packed\":{{\"s\":{packed_s:.6},\"gflops\":{:.4}}},\
                 \"simd\":{{\"s\":{simd_s:.6},\"gflops\":{:.4}}}}},\
                 \"pack_s\":{pack_s:.6},\"simd_e2e_s\":{e2e_s:.6},\
                 \"speedup_simd_vs_packed\":{:.3},\
                 \"speedup_simd_vs_flow\":{:.3},\
                 \"speedup_packed_vs_flow\":{:.3}}}",
                gf(flow_s),
                gf(packed_s),
                gf(simd_s),
                packed_s / simd_s,
                flow_s / simd_s,
                flow_s / packed_s,
            ));
        }
        format_json.push(format!(
            "\"{}\":{{\"label\":\"{}\",\"group\":{},\"bits_per_value\":{},{}}}",
            kind.spelling(),
            kind.name(),
            kind.group(),
            kind.bits_per_value(),
            rows_json.join(",")
        ));
        println!();
    }

    let json = format!(
        "{{\n  \"bench\": \"qgemm_throughput\",\n  \"quick\": {quick},\n  \
         \"shape\": {{\"m\": {m}, \"k\": {k}, \"n\": {n}}},\n  \
         \"bit_identical\": true,\n  \
         \"simd_isa\": \"{}\",\n  \
         \"formats\": {{{}}}\n}}\n",
        simd_isa_label(),
        format_json.join(",")
    );
    let path = "BENCH_qgemm.json";
    std::fs::write(path, &json).expect("write BENCH_qgemm.json");
    println!("wrote {path}");
}
