//! Flow-vs-packed quantized GEMM throughput at serving-like shapes,
//! across **all five block formats** through the unified
//! `QuantizedMatrix` API.
//!
//! For every format: times the reference flow kernel against the
//! decode-once packed kernel (single- and multi-thread), asserts their
//! outputs are bit-identical, and writes `BENCH_qgemm.json` keyed by
//! format spelling (GFLOP/s + speedups) so the perf trajectory is
//! machine-readable across PRs. `HIF4_BENCH_QUICK=1` shrinks to one
//! small shape for CI smoke runs (build + run, no thresholds enforced
//! here).
//!
//! "Packed (end-to-end)" includes packing both operands fresh each call —
//! the worst case for the packed path; "packed (prepacked)" reuses the
//! planes, which is how the model/serving layers actually run (weights
//! pack once, activations per call).

use hif4::dotprod::QuantizedMatrix;
use hif4::formats::rounding::RoundMode;
use hif4::formats::QuantKind;
use hif4::tensor::{Matrix, Rng};
use hif4::util::threadpool;
use std::time::Instant;

/// Best-of-`reps` wall time of `f`, in seconds (result is black-boxed).
fn secs<T, F: FnMut() -> T>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

struct KernelTimes {
    flow_s: f64,
    packed_s: f64,
    packed_prepacked_s: f64,
    pack_s: f64,
}

impl KernelTimes {
    fn row(&self, label: &str, flops: f64) -> String {
        let gf = |s: f64| flops / s / 1e9;
        println!(
            "{label:<28} flow {:8.3}s ({:6.3} GFLOP/s)  packed e2e {:8.3}s ({:6.3} GFLOP/s)  \
             prepacked {:8.3}s ({:6.3} GFLOP/s)  pack {:6.3}s  speedup {:5.2}x (e2e) {:5.2}x (prepacked)",
            self.flow_s,
            gf(self.flow_s),
            self.packed_s,
            gf(self.packed_s),
            self.packed_prepacked_s,
            gf(self.packed_prepacked_s),
            self.pack_s,
            self.flow_s / self.packed_s,
            self.flow_s / self.packed_prepacked_s,
        );
        // Inner JSON fields (no braces); the caller wraps them.
        format!(
            "\"flow_s\":{:.6},\"packed_s\":{:.6},\"packed_prepacked_s\":{:.6},\
             \"pack_s\":{:.6},\"flow_gflops\":{:.4},\"packed_gflops\":{:.4},\
             \"packed_prepacked_gflops\":{:.4},\"speedup\":{:.3},\"speedup_prepacked\":{:.3}",
            self.flow_s,
            self.packed_s,
            self.packed_prepacked_s,
            self.pack_s,
            gf(self.flow_s),
            gf(self.packed_s),
            gf(self.packed_prepacked_s),
            self.flow_s / self.packed_s,
            self.flow_s / self.packed_prepacked_s,
        )
    }
}

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

fn main() {
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    // Serving-like shape: decode activations (batch·seq = 512 rows) ×
    // d_ff-scale weights over a 4096 reduction. The flow kernels are slow
    // by design (per-element re-decode), so the full run uses a smaller
    // shape per format than the old HiF4-only bench did.
    let (m, k, n) = if quick { (64, 512, 64) } else { (256, 2048, 256) };
    let reps_flow = if quick { 3 } else { 1 };
    let reps_packed = if quick { 5 } else { 3 };
    let nthreads = threadpool::threads();
    let flops = (2 * m * k * n) as f64;
    let mode = RoundMode::NearestEven;

    let mut rng = Rng::seed(17);
    let a = Matrix::randn(m, k, 1.0, &mut rng);
    let b = Matrix::randn(n, k, 1.0, &mut rng);

    println!("qgemm throughput — shape {m}x{k}x{n}, multi-thread = {nthreads}\n");

    let mut format_json = Vec::new();
    for kind in QuantKind::ALL {
        let qa = QuantizedMatrix::quantize(kind, &a, mode);
        let qb = QuantizedMatrix::quantize(kind, &b, mode);
        let pa = qa.pack_threads(1);
        let pb = qb.pack_threads(1);
        // Bit-identity of the two backends on the bench shape itself —
        // any mismatch aborts before the JSON is written, so a written
        // `bit_identical` is true by construction.
        let c_flow = qa.qgemm_bt_flow_threads(&qb, nthreads);
        let c_packed = pa.qgemm_bt_threads(&pb, nthreads);
        assert!(
            bits(&c_flow) == bits(&c_packed),
            "{kind}: flow and packed kernels must agree bit for bit"
        );
        drop((c_flow, c_packed));

        let mut rows_json = Vec::new();
        for (label, threads) in [("single", 1usize), ("multi", nthreads)] {
            let flow_s =
                secs(reps_flow, || std::hint::black_box(qa.qgemm_bt_flow_threads(&qb, threads)));
            let prepacked_s =
                secs(reps_packed, || std::hint::black_box(pa.qgemm_bt_threads(&pb, threads)));
            // Pack cost at *this* thread count (the amortized one-time cost).
            let pack_s = secs(reps_packed, || {
                std::hint::black_box(qa.pack_threads(threads));
                std::hint::black_box(qb.pack_threads(threads));
            });
            let e2e_s = secs(reps_packed, || {
                let xa = qa.pack_threads(threads);
                let xb = qb.pack_threads(threads);
                std::hint::black_box(xa.qgemm_bt_threads(&xb, threads));
            });
            let t = KernelTimes {
                flow_s,
                packed_s: e2e_s,
                packed_prepacked_s: prepacked_s,
                pack_s,
            };
            let fields = t.row(&format!("{} {label} ({threads}t)", kind.name()), flops);
            rows_json.push(format!("\"{label}\":{{\"threads\":{threads},{fields}}}"));
        }
        format_json.push(format!(
            "\"{}\":{{\"label\":\"{}\",\"group\":{},\"bits_per_value\":{},{}}}",
            kind.spelling(),
            kind.name(),
            kind.group(),
            kind.bits_per_value(),
            rows_json.join(",")
        ));
        println!();
    }

    let json = format!(
        "{{\n  \"bench\": \"qgemm_throughput\",\n  \"quick\": {quick},\n  \
         \"shape\": {{\"m\": {m}, \"k\": {k}, \"n\": {n}}},\n  \
         \"bit_identical\": true,\n  \
         \"formats\": {{{}}}\n}}\n",
        format_json.join(",")
    );
    let path = "BENCH_qgemm.json";
    std::fs::write(path, &json).expect("write BENCH_qgemm.json");
    println!("wrote {path}");
}
