//! §III.B regeneration: the analytic area/power model of the 64-length PE.
//! Paper claims: HiF4 ≈ 1/3 of NVFP4's incremental area; ≈10% PE power
//! reduction. Both are *derived* from the gate-level block inventory.

use hif4::hwcost::{hif4_incremental, nvfp4_incremental, pe, shared_base};
use hif4::util::bench::Table;

fn main() {
    let mut t = Table::new(
        "PE area/power model (gate units; 1 = full-adder cell)",
        &["block", "area", "power"],
    );
    for (label, area, power) in pe::report_rows() {
        t.row(vec![label, format!("{area:.0}"), format!("{power:.0}")]);
    }
    t.print();

    println!("\nper-block breakdown:");
    for report in [shared_base(), hif4_incremental(), nvfp4_incremental()] {
        println!("  {}:", report.label);
        for b in &report.blocks {
            println!(
                "    {:44} {:4} x {:7.1} = {:8.1}",
                b.name,
                b.count,
                b.area,
                b.total_area()
            );
        }
    }

    let h = hif4_incremental().total_area();
    let n = nvfp4_incremental().total_area();
    let base = shared_base().total_power();
    let hp = base + hif4_incremental().total_power();
    let np = base + nvfp4_incremental().total_power();
    println!(
        "\nincremental area: HiF4 {h:.0} vs NVFP4 {n:.0}  ->  ratio {:.2}x  (paper: ~3x)",
        n / h
    );
    println!(
        "whole-PE power:   HiF4 {hp:.0} vs NVFP4 {np:.0}  ->  reduction {:.1}%  (paper: ~10%)",
        100.0 * (1.0 - hp / np)
    );
}
