//! Table II regeneration: typical values and features for HiF4 and NVFP4,
//! derived from the format constants and verified by quantizing probes.

use hif4::formats::{hif4 as hif4_fmt, nvfp4, QuantKind, QuantScheme};
use hif4::util::bench::Table;

fn main() {
    let mut t = Table::new(
        "Table II: typical values and features for HiF4 and NVFP4",
        &["property", "HiF4", "NVFP4"],
    );
    t.row(vec![
        "Storage Overhead".into(),
        format!("{} bits/value", hif4_fmt::BITS_PER_VALUE),
        format!("{} bits/value", nvfp4::BITS_PER_VALUE),
    ]);
    t.row(vec!["Group Size".into(), hif4_fmt::GROUP.to_string(), nvfp4::GROUP.to_string()]);
    t.row(vec!["Special Values".into(), "NaN and ±0".into(), "NaN and ±0".into()]);
    t.row(vec!["4-bit Element".into(), "S1P2 (E1M2)".into(), "E2M1".into()]);
    t.row(vec!["Significand Precision".into(), "3 bits".into(), "2 bits".into()]);
    t.row(vec!["Global Base Scale".into(), "E6M2".into(), "E4M3".into()]);
    t.row(vec![
        "Max Positive Value".into(),
        format!("{:.6e} (= 2^18 x 1.3125)", hif4_fmt::MAX_POSITIVE),
        format!("{:.6e} (= 2^11 x 1.3125)", nvfp4::MAX_POSITIVE),
    ]);
    t.row(vec![
        "Min Positive Value".into(),
        format!("{:.6e} (= 2^-50)", hif4_fmt::MIN_POSITIVE),
        format!("{:.6e} (= 2^-10)", nvfp4::MIN_POSITIVE),
    ]);
    t.row(vec![
        "Global Dynamic Range".into(),
        format!("{:.1} binades", (hif4_fmt::MAX_POSITIVE / hif4_fmt::MIN_POSITIVE).log2()),
        format!("{:.1} binades", (nvfp4::MAX_POSITIVE / nvfp4::MIN_POSITIVE).log2()),
    ]);
    t.row(vec![
        "Local Dynamic Range".into(),
        format!("{:.2} binades", (hif4_fmt::INTRA_MAX / hif4_fmt::INTRA_MIN_POS).log2()),
        format!("{:.2} binades", (6.0f32 / 0.5).log2()),
    ]);
    t.print();

    // Verify the extreme values actually survive a quantization roundtrip.
    // Min probes need a companion group peak that pins the scale to its
    // smallest value (the min positive value is a *format* extreme, reached
    // when the group scale bottoms out and the element is the smallest
    // nonzero code).
    println!("\nverification by roundtrip:");
    for (name, fmt, probe, peak) in [
        ("HiF4 max", QuantKind::HiF4, hif4_fmt::MAX_POSITIVE, None),
        ("HiF4 min", QuantKind::HiF4, hif4_fmt::MIN_POSITIVE, None),
        ("NVFP4 max", QuantKind::Nvfp4, nvfp4::MAX_POSITIVE, None),
        // Scale = E4M3 min subnormal 2^-9 requires amax = 6×2^-9.
        ("NVFP4 min", QuantKind::Nvfp4, nvfp4::MIN_POSITIVE, Some(6.0 * 2f32.powi(-9))),
    ] {
        let scheme = QuantScheme::direct(fmt);
        let mut v = vec![0f32; fmt.group()];
        v[0] = probe;
        if let Some(p) = peak {
            v[1] = p;
        }
        let q = scheme.quant_dequant_vec(&v);
        let verdict = if q[0] == probe { "exact" } else { "inexact" };
        println!("  {name:10}: {probe:.6e} -> {:.6e}  ({verdict})", q[0]);
        assert_eq!(q[0], probe, "{name} must roundtrip exactly");
    }
}
