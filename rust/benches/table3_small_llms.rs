//! Tables III + IV regeneration: 4 small-LLM stand-ins × 8 benchmarks ×
//! {BF16, NVFP4, NVFP4+PTS, HiF4, HiF4+HiGPTQ}, with Acc Drop rows and the
//! Table IV averages (w/ and w/o the NVFP4-crashed Mistral stand-in).
//!
//! Each model is genuinely trained on the synthetic corpus before PTQ (see
//! DESIGN.md §4 for the substitution rationale). HIF4_BENCH_QUICK=1 shrinks
//! training/eval for smoke runs.

use hif4::eval::tasks::Task;
use hif4::formats::QuantKind;
use hif4::model::zoo;
use hif4::quant::experiment::{run_model, ExperimentConfig, ModelBlock, QuantType};
use hif4::util::bench::Table;

fn main() {
    let quick = std::env::var("HIF4_BENCH_QUICK").is_ok();
    let xcfg = if quick {
        ExperimentConfig {
            train_steps: 60,
            eval_items: 20,
            eval_seeds: vec![1],
            ..Default::default()
        }
    } else {
        ExperimentConfig::default()
    };
    let types = [
        QuantType::Bf16,
        QuantType::Direct(QuantKind::Nvfp4),
        QuantType::Pts(QuantKind::Nvfp4),
        QuantType::Direct(QuantKind::HiF4),
        QuantType::HiGptq(QuantKind::HiF4),
    ];
    let suite = Task::small_suite();

    let mut blocks: Vec<ModelBlock> = Vec::new();
    for (i, cfg) in zoo::small_llms().iter().enumerate() {
        let t0 = std::time::Instant::now();
        let block = run_model(cfg, &suite, &types, &xcfg, 100 + i as u64);
        eprintln!(
            "[{}] trained (loss {:.3} -> {:.3}) + evaluated in {:.1?}",
            cfg.name,
            block.losses[0],
            block.losses.last().unwrap(),
            t0.elapsed()
        );
        blocks.push(block);
    }

    // Table III.
    let mut header: Vec<String> = vec!["Model".into(), "A-W Quant Type".into()];
    header.extend(suite.iter().map(|t| t.name().to_string()));
    header.push("Mean".into());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table III: 4 small LLM stand-ins x 8 benchmarks", &hdr);
    for block in &blocks {
        for (i, row) in block.rows.iter().enumerate() {
            let mut cells = vec![
                if i == 0 { block.model_name.clone() } else { String::new() },
                row.label.clone(),
            ];
            cells.extend(row.task_acc.iter().map(|a| format!("{a:.2}")));
            cells.push(format!("{:.2}", row.mean));
            t.row(cells);
            if i > 0 {
                let mut cells = vec![String::new(), "- Acc Drop".into()];
                cells.extend(block.drops(i).iter().map(|d| format!("{d:+.2}")));
                cells.push(format!("{:+.2}", row.mean - block.rows[0].mean));
                t.row(cells);
            }
        }
    }
    t.print();

    // Table IV: averages over models, with and without the crashed model
    // (the Mistral stand-in is index 3).
    let mut t4 = Table::new(
        "Table IV: average inference accuracy for small LLM stand-ins",
        &["# models", "BF16", "NVFP4", "NVFP4+PTS", "HiF4", "HiF4+HiGPTQ"],
    );
    let avg = |blocks: &[&ModelBlock], qi: usize| -> f64 {
        blocks.iter().map(|b| b.rows[qi].mean).sum::<f64>() / blocks.len() as f64
    };
    let all: Vec<&ModelBlock> = blocks.iter().collect();
    let wo: Vec<&ModelBlock> = blocks[..3].iter().collect();
    for (label, set) in [("4 (w/ Mistral*)", &all), ("3 (w/o Mistral*)", &wo)] {
        t4.row(vec![
            label.into(),
            format!("{:.2}", avg(set, 0)),
            format!("{:.2}", avg(set, 1)),
            format!("{:.2}", avg(set, 2)),
            format!("{:.2}", avg(set, 3)),
            format!("{:.2}", avg(set, 4)),
        ]);
        t4.row(vec![
            "  - Acc Drop".into(),
            "-".into(),
            format!("{:+.2}", avg(set, 1) - avg(set, 0)),
            format!("{:+.2}", avg(set, 2) - avg(set, 0)),
            format!("{:+.2}", avg(set, 3) - avg(set, 0)),
            format!("{:+.2}", avg(set, 4) - avg(set, 0)),
        ]);
    }
    t4.print();

    println!("\nExpected shape (paper §IV.B): |drop(HiF4)| < |drop(NVFP4+PTS)| < |drop(NVFP4)|;");
    println!("NVFP4 direct-cast crashes on the Mistral stand-in while HiF4 does not;");
    println!("HiGPTQ recovers further accuracy on every model.");
}
