//! Offline stub of the `xla-rs` PJRT surface.
//!
//! The real crate links the XLA/PJRT native runtime, which is not present
//! in the offline build image. This stub keeps the whole workspace
//! compiling and testable:
//!
//! * [`Literal`] is **fully functional** (host-side typed buffers with
//!   shapes) — everything that only marshals data works for real;
//! * [`PjRtClient::cpu`] returns a descriptive error, so every code path
//!   that would execute a compiled artifact fails fast with a clear
//!   message instead of crashing. Integration tests and benches already
//!   skip when `artifacts/` is absent, so `cargo test` stays green.
//!
//! Swapping in the real `xla` crate (on an image that has it) is a
//! one-line change in `rust/Cargo.toml`; no source edits are needed.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type (also what the real bindings surface on failure).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT runtime unavailable (offline stub build — \
             point the `xla` dependency in rust/Cargo.toml at the real \
             xla-rs crate to execute compiled artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element buffer of a literal (public only because [`NativeType`]
/// mentions it; construct literals via [`Literal::vec1`] / [`Literal::scalar`]).
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold in this stub (f32 and i32 cover
/// every call site in the workspace).
pub trait NativeType: Copy + Sized {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }

    fn unwrap(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }

    fn unwrap(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host-side typed array with a shape — functional in the stub.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { dims: Vec::new(), data: Data::F32(vec![x]) }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Shape of the literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Decompose a tuple literal (never constructed in the stub).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module text. The stub validates that the file is readable
/// and keeps the text, but cannot lower or execute it.
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation wrapper around a parsed module.
pub struct XlaComputation {
    _text_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text_len: proto.text.len() }
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always errors in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle (unreachable in the stub: the client
/// constructor already fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (unreachable in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(m.to_vec::<i32>().is_err(), "typed access is checked");
    }

    #[test]
    fn scalar_and_i32() {
        assert_eq!(Literal::scalar(2.5).to_vec::<f32>().unwrap(), vec![2.5]);
        let t = Literal::vec1(&[1i32, 2, 3]).reshape(&[3, 1]).unwrap();
        assert_eq!(t.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn runtime_entry_points_error_clearly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("offline stub"));
    }
}
