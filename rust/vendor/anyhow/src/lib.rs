//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build image has no registry access, so this path crate
//! provides the subset of the real API the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros and the
//! [`Context`] extension trait for `Result` and `Option`. Error values
//! carry a context chain; `{e}` prints the outermost message and `{e:#}`
//! prints the whole chain separated by `: ` like real anyhow.

use std::fmt;

/// A dynamic error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend a context message (becomes the new outermost entry).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps the blanket `From` below coherent with
// the reflexive `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/7bd91")?;
        Ok(())
    }

    #[test]
    fn from_std_error_and_context() {
        let e = io_fail().context("reading config").unwrap_err();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain[0], "reading config");
        assert!(chain.len() >= 2);
        // Plain display shows the outermost entry; alternate shows the chain.
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x * 2)
        }
        assert_eq!(f(3).unwrap(), 6);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        let e: Error = anyhow!("custom {}", 7);
        assert_eq!(format!("{e}"), "custom 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        let v = Some(5u32).with_context(|| "unused").unwrap();
        assert_eq!(v, 5);
    }
}
