//! Integration tests across the three layers: PJRT artifact loading, the
//! rust↔python codec cross-check (the L3 HiF4 implementation must agree
//! with the L1 Pallas kernel through the compiled HLO), the train-step
//! artifact, and the end-to-end TCP serving stack.
//!
//! These tests need `make artifacts` to have run; they are skipped (with a
//! loud message) when `artifacts/` is missing so `cargo test` stays green
//! in a fresh checkout.

use hif4::formats::{QuantKind, QuantScheme};
use hif4::runtime::artifact::Manifest;
use hif4::runtime::client::{literal_f32, tokens_literal, Runtime};
use hif4::server::batcher::BatchPolicy;
use hif4::server::protocol::Request;
use hif4::server::service::{Client, Server, ServerConfig};
use hif4::tensor::Rng;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn qdq_artifact_matches_rust_codec_bit_exactly() {
    // The decisive three-layer test: the HiF4 quantize-dequantize lowered
    // from the Pallas kernel (L1) and executed through PJRT (runtime) must
    // agree with the independent Rust codec (L3) bit-for-bit.
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let runtime = Runtime::cpu().unwrap();
    let (rows, cols) = (m.qdq_rows, m.qdq_cols);

    for (artifact, format) in
        [("qdq_hif4.hlo.txt", QuantKind::HiF4), ("qdq_nvfp4.hlo.txt", QuantKind::Nvfp4)]
    {
        let exe = runtime.load(&dir.join(artifact)).unwrap();
        let mut rng = Rng::seed(2024);
        for round in 0..6 {
            let sigma = 10f32.powi(round - 3);
            let data: Vec<f32> =
                (0..rows * cols).map(|_| rng.normal() as f32 * sigma).collect();
            let lit = xla::Literal::vec1(&data)
                .reshape(&[rows as i64, cols as i64])
                .unwrap();
            let out = exe.run(&[lit]).unwrap();
            let got = literal_f32(&out[0]).unwrap();
            let scheme = QuantScheme::direct(format);
            let mut want = vec![0f32; data.len()];
            for r in 0..rows {
                let (lo, hi) = (r * cols, (r + 1) * cols);
                scheme.quant_dequant(&data[lo..hi], &mut want[lo..hi]);
            }
            assert_eq!(got, want, "{artifact} mismatch at sigma={sigma}");
        }
    }
}

#[test]
fn forward_artifact_runs_and_is_causal() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let runtime = Runtime::cpu().unwrap();
    let exe = runtime.load(&dir.join("fwd_bf16.hlo.txt")).unwrap();
    let params = m.init_params(7);
    let mut inputs = params.literals().unwrap();

    let mut seqs: Vec<Vec<usize>> = (0..m.batch).map(|b| vec![b + 1, 5, 9, 2]).collect();
    inputs.push(tokens_literal(&seqs, m.seq).unwrap());
    let out1 = exe.run(&inputs).unwrap();
    let logits1 = literal_f32(&out1[0]).unwrap();
    assert_eq!(logits1.len(), m.batch * m.seq * m.vocab);
    assert!(logits1.iter().all(|x| x.is_finite()));

    // Change a *later* token of sequence 0: earlier logits must not move.
    seqs[0] = vec![1, 5, 9, 200];
    let mut inputs2 = params.literals().unwrap();
    inputs2.push(tokens_literal(&seqs, m.seq).unwrap());
    let logits2 = literal_f32(&exe.run(&inputs2).unwrap()[0]).unwrap();
    for pos in 0..3 {
        for v in 0..m.vocab {
            assert_eq!(
                logits1[pos * m.vocab + v],
                logits2[pos * m.vocab + v],
                "future token leaked into position {pos}"
            );
        }
    }
}

#[test]
fn quantized_forward_artifacts_differ_from_bf16() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let runtime = Runtime::cpu().unwrap();
    let params = m.init_params(13);
    let seqs: Vec<Vec<usize>> = (0..m.batch).map(|b| vec![b + 1, 17, 33, 250, 9]).collect();

    let mut outs = Vec::new();
    for art in ["fwd_bf16.hlo.txt", "fwd_hif4.hlo.txt", "fwd_nvfp4.hlo.txt"] {
        let exe = runtime.load(&dir.join(art)).unwrap();
        let mut inputs = params.literals().unwrap();
        inputs.push(tokens_literal(&seqs, m.seq).unwrap());
        outs.push(literal_f32(&exe.run(&inputs).unwrap()[0]).unwrap());
    }
    assert_ne!(outs[0], outs[1], "hif4 fake-quant must perturb logits");
    assert_ne!(outs[0], outs[2], "nvfp4 fake-quant must perturb logits");
    // Perturbation is bounded (4.5-bit formats on bf16-scale activations).
    let mad: f32 = outs[0]
        .iter()
        .zip(&outs[1])
        .map(|(a, b)| (a - b).abs())
        .sum::<f32>()
        / outs[0].len() as f32;
    let scale: f32 = outs[0].iter().map(|x| x.abs()).sum::<f32>() / outs[0].len() as f32;
    assert!(mad < 0.5 * scale, "hif4 perturbation too large: {mad} vs {scale}");
}

#[test]
fn train_step_artifact_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let runtime = Runtime::cpu().unwrap();
    let exe = runtime.load(&dir.join("train_step.hlo.txt")).unwrap();
    let mut params = m.init_params(21);
    let n = params.order.len();

    // Optimizer state: m, v zeros + step scalar.
    let zeros: Vec<Vec<f32>> = params
        .order
        .iter()
        .map(|name| vec![0f32; params.params[name].1.len()])
        .collect();
    let mut m_state = zeros.clone();
    let mut v_state = zeros;
    let mut step = 0f32;

    // Fixed batch: a repeating pattern the model can memorize.
    let seqs: Vec<Vec<usize>> =
        (0..m.batch).map(|_| (0..m.seq).map(|i| 1 + (i % 6)).collect()).collect();

    let mut losses = Vec::new();
    for _ in 0..5 {
        let mut inputs = params.literals().unwrap();
        for (name, buf) in params.order.iter().zip(&m_state) {
            let dims: Vec<i64> =
                params.params[name].0.iter().map(|d| *d as i64).collect();
            inputs.push(xla::Literal::vec1(buf).reshape(&dims).unwrap());
        }
        for (name, buf) in params.order.iter().zip(&v_state) {
            let dims: Vec<i64> =
                params.params[name].0.iter().map(|d| *d as i64).collect();
            inputs.push(xla::Literal::vec1(buf).reshape(&dims).unwrap());
        }
        inputs.push(xla::Literal::scalar(step));
        inputs.push(tokens_literal(&seqs, m.seq).unwrap());

        let outs = exe.run(&inputs).unwrap();
        assert_eq!(outs.len(), 3 * n + 2, "params + m + v + step + loss");
        params.update_from_literals(&outs[..n]).unwrap();
        for (i, buf) in m_state.iter_mut().enumerate() {
            *buf = outs[n + i].to_vec::<f32>().unwrap();
        }
        for (i, buf) in v_state.iter_mut().enumerate() {
            *buf = outs[2 * n + i].to_vec::<f32>().unwrap();
        }
        step = outs[3 * n].to_vec::<f32>().unwrap()[0];
        let loss = outs[3 * n + 1].to_vec::<f32>().unwrap()[0];
        losses.push(loss);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "train_step must reduce loss: {losses:?}"
    );
}

#[test]
fn end_to_end_tcp_serving() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let params = m.init_params(5);
    let cfg = ServerConfig {
        artifact: "fwd_bf16.hlo.txt".into(),
        policy: BatchPolicy { max_batch: m.batch, max_wait: std::time::Duration::from_millis(2) },
        workers: 2,
        resilience: Default::default(),
    };
    let server = Server::start(&dir, cfg, &params, "127.0.0.1:0").unwrap();

    let mut client = Client::connect(server.addr).unwrap();
    // Pipelined requests exercise the dynamic batcher.
    for id in 0..20u64 {
        let req = Request::next_token(id, vec![1 + (id as usize % 7), 5, 9]);
        client.send(&req).unwrap();
    }
    let mut got = Vec::new();
    for _ in 0..20 {
        let resp = client.recv().unwrap();
        assert!((resp.token as usize) < m.vocab);
        assert!(resp.logprob <= 0.0);
        got.push(resp.id);
    }
    got.sort_unstable();
    assert_eq!(got, (0..20).collect::<Vec<u64>>(), "every request answered once");
    assert!(server.metrics.batches.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    // Determinism: identical contexts get identical tokens.
    let r1 = client.call(&Request::next_token(100, vec![3, 5, 9])).unwrap();
    let r2 = client.call(&Request::next_token(101, vec![3, 5, 9])).unwrap();
    assert_eq!(r1.token, r2.token);
}
