//! Golden-file regression test for the quick accuracy battery.
//!
//! The battery is deterministic end to end (seeded training + seeded eval
//! + seeded held-out corpus + bit-identical kernels across thread counts
//! and backends), so every numeric cell of the quick matrix diffs against
//! `tests/golden/accuracy_golden.json` with a tight default tolerance.
//! Per-cell overrides live under the golden's optional `"tolerances"`
//! object (flattened dotted path → absolute tolerance) and survive
//! regeneration.
//!
//! Updating the golden: run `UPDATE_GOLDEN=1 cargo test --test
//! accuracy_battery` and commit the rewritten file. A checked-in
//! `{"status": "bootstrap"}` stub (or a missing file) also regenerates in
//! place, so the very first toolchain run mints the numbers.

use hif4::eval::battery::{self, BatteryConfig};
use hif4::util::bench::Table;
use hif4::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::PathBuf;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/accuracy_golden.json")
}

/// Default per-cell absolute tolerance. Accuracy cells are percentages and
/// ppl cells are O(1..vocab); both are pure functions of the seeds on
/// bit-identical kernels, so drift beyond float-noise means a real change.
const DEFAULT_TOL: f64 = 1e-9;

#[test]
fn quick_battery_matches_golden() {
    let path = golden_path();
    let golden = std::fs::read_to_string(&path)
        .ok()
        .map(|t| json::parse(&t).expect("golden file must parse as JSON"));

    let doc = battery::run(&BatteryConfig::quick());

    let bootstrap = match &golden {
        None => true,
        Some(g) => g.get("status").and_then(Json::as_str) == Some("bootstrap"),
    };
    if std::env::var("UPDATE_GOLDEN").is_ok() || bootstrap {
        // Regenerate in place, preserving any per-cell tolerance overrides.
        let mut out = doc;
        if let Some(tols) = golden.as_ref().and_then(|g| g.get("tolerances")) {
            if let Json::Obj(pairs) = &mut out {
                pairs.push(("tolerances".to_string(), tols.clone()));
            }
        }
        std::fs::write(&path, out.render()).expect("write golden");
        eprintln!(
            "accuracy golden (re)generated at {} — commit it to pin the battery",
            path.display()
        );
        return;
    }
    let golden = golden.unwrap();

    assert_eq!(
        golden.get("schema_version").and_then(Json::as_f64),
        doc.get("schema_version").and_then(Json::as_f64),
        "schema version drift — regenerate with UPDATE_GOLDEN=1"
    );

    let tol_overrides = golden.get("tolerances").map(Json::flatten_numbers).unwrap_or_default();
    let tol_for = |path: &str| {
        tol_overrides.iter().find(|(p, _)| p == path).map(|(_, t)| *t).unwrap_or(DEFAULT_TOL)
    };

    let mut gold_nums = golden.flatten_numbers();
    gold_nums.retain(|(p, _)| !p.starts_with("tolerances."));
    let got_nums = doc.flatten_numbers();
    let gold: BTreeMap<&str, f64> = gold_nums.iter().map(|(p, v)| (p.as_str(), *v)).collect();
    let got: BTreeMap<&str, f64> = got_nums.iter().map(|(p, v)| (p.as_str(), *v)).collect();

    // (cell, golden, got, tol) with NaN standing in for a missing side.
    let mut failures: Vec<(String, f64, f64, f64)> = Vec::new();
    for (path, gv) in &gold {
        match got.get(path) {
            None => failures.push((path.to_string(), *gv, f64::NAN, 0.0)),
            Some(cv) => {
                let tol = tol_for(path);
                if (gv - cv).abs() > tol {
                    failures.push((path.to_string(), *gv, *cv, tol));
                }
            }
        }
    }
    for (path, cv) in &got {
        if !gold.contains_key(path) {
            failures.push((path.to_string(), f64::NAN, *cv, 0.0));
        }
    }

    if !failures.is_empty() {
        let mut t = Table::new(
            "accuracy golden drift (NaN side = cell missing)",
            &["cell", "golden", "got", "|delta|", "tol"],
        );
        for (path, gv, cv, tol) in &failures {
            t.row(vec![
                path.clone(),
                format!("{gv}"),
                format!("{cv}"),
                format!("{:.3e}", (gv - cv).abs()),
                format!("{tol:.1e}"),
            ]);
        }
        t.print();
        panic!(
            "{} of {} battery cells drifted from tests/golden/accuracy_golden.json; \
             if intentional, rerun with UPDATE_GOLDEN=1 and commit the new golden \
             (or add a per-cell entry under its \"tolerances\" object)",
            failures.len(),
            gold.len()
        );
    }
}
