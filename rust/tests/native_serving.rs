//! End-to-end native serving: TCP listener → batcher → native worker pool
//! running the rust-native transformer (no PJRT, no artifacts) — including
//! the real-quantized configuration where HiF4 weight planes are packed
//! once at startup and every request runs the fixed-point QGEMM.

use hif4::formats::QuantKind;
use hif4::model::kv::KvCacheType;
use hif4::runtime::artifact::Manifest;
use hif4::runtime::native::transformer_from_store;
use hif4::server::batcher::{BatchPolicy, Pending};
use hif4::server::protocol::Request;
use hif4::server::service::{run_batch_native, Client, NativeServerConfig, Server};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// A complete 1-layer GQA+SwiGLU manifest (d=32, 4 heads × 8, kv 2).
/// Twin of the fixture in `src/runtime/native.rs`'s unit tests — keep the
/// two in sync when changing the geometry.
fn write_manifest(dir: &Path) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "batch 4\nseq 16\nvocab 96\nn_heads 4\nkv_heads 2\nhead_dim 8\nrope_base 10000\n\
         qdq 8 64\n\
         param embed 96 32\nparam head 96 32\nparam norm_f 32\n\
         param layer0.norm1 32\nparam layer0.norm2 32\n\
         param layer0.wq 32 32\nparam layer0.wk 16 32\nparam layer0.wv 16 32\n\
         param layer0.wo 32 32\n\
         param layer0.w1 64 32\nparam layer0.w2 32 64\nparam layer0.w3 64 32\n",
    )
    .unwrap();
}

fn manifest_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hif4_native_serving_{tag}"))
}

fn pending(id: u64, tokens: Vec<usize>) -> Pending<()> {
    Pending::untracked(Request::next_token(id, tokens), ())
}

#[test]
fn native_server_round_trips_and_matches_direct_execution() {
    let dir = manifest_dir("bf16");
    write_manifest(&dir);
    let manifest = Manifest::load(&dir).unwrap();
    let store = manifest.init_params(7);
    let model = Arc::new(transformer_from_store(&manifest, &store).unwrap());

    // Ground truth straight through the batch executor.
    let requests: Vec<Vec<usize>> = vec![vec![1, 5, 9], vec![2, 6, 10, 14], vec![3], vec![90, 4]];
    let direct: Vec<_> = requests
        .iter()
        .enumerate()
        .map(|(i, t)| pending(i as u64, t.clone()))
        .collect();
    let expected = run_batch_native(&model, &direct, manifest.seq);

    let cfg = NativeServerConfig {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
        workers: 2,
        seq: manifest.seq,
        kv: KvCacheType::F32,
        ..Default::default()
    };
    let mut server = Server::start_native(Arc::clone(&model), cfg, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    for (i, t) in requests.iter().enumerate() {
        let resp = client.call(&Request::next_token(i as u64, t.clone())).unwrap();
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.token, expected[i].token, "request {i} argmax");
        assert_eq!(resp.logprob, expected[i].logprob, "request {i} logprob");
    }
    assert!(!server.metrics.summary().is_empty());
    server.shutdown();
}

#[test]
fn native_server_serves_prepacked_hif4_deterministically() {
    let dir = manifest_dir("hif4");
    write_manifest(&dir);
    let manifest = Manifest::load(&dir).unwrap();
    let store = manifest.init_params(11);
    let mut model = transformer_from_store(&manifest, &store).unwrap();
    // Real-quantized serving: weight planes packed exactly once here, and
    // the dense f32 planes freed — forward must never touch them.
    model.prepack_quantized_weights(QuantKind::HiF4);
    model.release_dense_weights();
    let model = Arc::new(model);

    let cfg = NativeServerConfig {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        workers: 2,
        seq: manifest.seq,
        kv: KvCacheType::F32,
        ..Default::default()
    };
    let server = Server::start_native(Arc::clone(&model), cfg, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    let req = Request::next_token(1, vec![4, 8, 15, 16, 23, 42]);
    let first = client.call(&req).unwrap();
    assert!(first.logprob.is_finite());
    // Same request again (possibly on the other worker): byte-identical
    // answer — the packed planes are shared, read-only state.
    for i in 2..8u64 {
        let resp = client.call(&Request::next_token(i, req.tokens.clone())).unwrap();
        assert_eq!(resp.token, first.token);
        assert_eq!(resp.logprob.to_bits(), first.logprob.to_bits());
    }
    // And the server's answer matches direct in-process execution.
    let direct = run_batch_native(&model, &[pending(9, req.tokens.clone())], manifest.seq);
    assert_eq!(direct[0].token, first.token);
    assert_eq!(direct[0].logprob.to_bits(), first.logprob.to_bits());
}

#[test]
fn native_server_serves_every_block_format_end_to_end() {
    // The acceptance contract of the unified QuantTensor API: all five
    // formats run the packed integer QGEMM behind `serve --native`
    // through the same QuantizedMatrix surface, and the server's metrics
    // carry the format tag + resident wire bytes.
    for kind in QuantKind::ALL {
        let dir = manifest_dir(kind.spelling());
        write_manifest(&dir);
        let manifest = Manifest::load(&dir).unwrap();
        let store = manifest.init_params(17);
        let mut model = transformer_from_store(&manifest, &store).unwrap();
        model.prepack_quantized_weights(kind);
        model.release_dense_weights();
        let wire = model.quantized_weight_wire_bytes();
        assert!(wire > 0, "{kind}");
        let model = Arc::new(model);

        let cfg = NativeServerConfig {
            policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
            workers: 1,
            seq: manifest.seq,
            kv: KvCacheType::F32,
            ..Default::default()
        };
        let server = Server::start_native(Arc::clone(&model), cfg, "127.0.0.1:0").unwrap();
        let tag = server.metrics.format_tag().expect("native engine must tag its metrics");
        assert_eq!(tag.format, kind.spelling(), "{kind}");
        assert_eq!(tag.weight_wire_bytes, wire as u64, "{kind}");
        assert!(server.metrics.summary().contains(kind.spelling()), "{kind}");

        let mut client = Client::connect(server.addr).unwrap();
        let req = Request::next_token(1, vec![3, 1, 4, 1, 5]);
        let resp = client.call(&req).unwrap();
        assert!(resp.logprob.is_finite(), "{kind}");
        let direct = run_batch_native(&model, &[pending(2, req.tokens.clone())], manifest.seq);
        assert_eq!(direct[0].token, resp.token, "{kind}");
        assert_eq!(direct[0].logprob.to_bits(), resp.logprob.to_bits(), "{kind}");
    }
}

#[test]
fn manifest_format_key_parses_through_quant_kind() {
    // The optional manifest `format` key goes through the single
    // QuantKind parser and lands on Manifest::format.
    let dir = manifest_dir("fmtkey");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "batch 2\nseq 8\nvocab 16\nformat mxfp4\nparam embed 16 8\n",
    )
    .unwrap();
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.format, Some(QuantKind::Mxfp4));
    // A manifest without the key defaults to dense serving.
    let dir2 = manifest_dir("fmtkey_none");
    write_manifest(&dir2);
    assert_eq!(Manifest::load(&dir2).unwrap().format, None);
    // A bad spelling fails loudly with the shared error message.
    let dir3 = manifest_dir("fmtkey_bad");
    std::fs::create_dir_all(&dir3).unwrap();
    std::fs::write(
        dir3.join("manifest.txt"),
        "batch 2\nseq 8\nvocab 16\nformat int4\nparam embed 16 8\n",
    )
    .unwrap();
    let err = format!("{:#}", Manifest::load(&dir3).unwrap_err());
    assert!(err.contains("mxfp4"), "error must list valid names: {err}");
}

#[test]
fn native_server_streams_multi_token_generation() {
    let dir = manifest_dir("stream");
    write_manifest(&dir);
    let manifest = Manifest::load(&dir).unwrap();
    let store = manifest.init_params(13);
    let model = Arc::new(transformer_from_store(&manifest, &store).unwrap());

    let cfg = NativeServerConfig {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        workers: 1,
        seq: manifest.seq,
        kv: KvCacheType::F32,
        ..Default::default()
    };
    let server = Server::start_native(Arc::clone(&model), cfg, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    let prompt = vec![2usize, 4, 6];
    let n_new = 5u16;
    let stream = client.generate(&Request::generate(7, prompt.clone(), n_new)).unwrap();
    assert_eq!(stream.len(), n_new as usize);
    for (i, r) in stream.iter().enumerate() {
        assert_eq!(r.id, 7);
        assert_eq!(r.index, i as u16);
        assert_eq!(r.of, n_new);
        assert!((r.token as usize) < model.cfg.vocab);
    }
    // The streamed tokens are exactly the model's greedy continuation.
    let want = model.generate_greedy(&prompt, n_new as usize, KvCacheType::F32);
    let got: Vec<usize> = stream.iter().map(|r| r.token as usize).collect();
    assert_eq!(got, want, "server stream must equal in-process greedy decode");
}
