//! Deterministic race exploration over the two serving-tier allocators
//! (DESIGN.md §16): every merge order of scripted client/allocator
//! threads runs against fresh state, with a sequential reference model
//! checked after **every step**. A violated invariant panics with the
//! literal schedule, which replays the race forever.
//!
//! Race 1 — [`AdmissionGate`] reserve/rollback: interleaved
//! `try_enqueue`/`dequeued`/`release_kv` must keep the gate's counters
//! equal to a step-at-a-time sequential model, including the queue-slot
//! rollback when the KV budget sheds a request that already took a slot.
//!
//! Race 2 — [`PagePool`] alloc/free/evict vs prefix pins: allocation
//! pressure at the page cap must evict only unpinned cached prefixes,
//! keep the live-page accounting exact through freelist hits, fresh
//! mints, evictions and shared releases, and never disturb the bytes of
//! a page a reader has pinned.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use hif4::model::kv::KvCacheType;
use hif4::model::pages::{KvPage, PagePool, PageShape};
use hif4::server::batcher::{AdmissionGate, Shed};
use hif4::util::interleave::{explore, Script};

// ---------------------------------------------------------------------
// Race 1: AdmissionGate reserve / rollback vs a sequential model.
// ---------------------------------------------------------------------

const MAX_QUEUE: usize = 2;
const KV_BUDGET: usize = 10;
/// Per-client worst-case KV needs: two clients big enough that both
/// cannot hold reservations at once (6 + 6 > 10 forces a KvBudget shed
/// with a queue-slot rollback), one small enough to squeeze in beside
/// either (6 + 3 ≤ 10) and fill the queue for a QueueFull shed.
const NEEDS: [usize; 3] = [6, 6, 3];

struct GateWorld {
    gate: AdmissionGate,
    /// Sequential model of the gate's two counters.
    m_queued: usize,
    m_reserved: usize,
    /// Per-client reservation while admitted-and-unreleased.
    got: [Option<usize>; 3],
    /// First divergence between the gate and the model, reported by the
    /// invariant so the explorer prints the schedule that produced it.
    mismatch: Option<String>,
}

fn gate_client(
    t: usize,
    sheds_queue: &'static AtomicUsize,
    sheds_kv: &'static AtomicUsize,
) -> Script<GateWorld> {
    Script::new(["client-0", "client-1", "client-2"][t])
        .step(move |w: &mut GateWorld| {
            // Predict from the model *before* calling the gate: the gate
            // checks the queue cap first, then the KV budget.
            let queue_ok = w.m_queued < MAX_QUEUE;
            let kv_ok = w.m_reserved + NEEDS[t] <= KV_BUDGET;
            match w.gate.try_enqueue(NEEDS[t]) {
                Ok(r) => {
                    if !(queue_ok && kv_ok) || r != NEEDS[t] {
                        w.mismatch = Some(format!(
                            "client {t} admitted ({r} reserved) but model \
                             said queue_ok={queue_ok} kv_ok={kv_ok}"
                        ));
                        return;
                    }
                    w.m_queued += 1;
                    w.m_reserved += r;
                    w.got[t] = Some(r);
                }
                Err(Shed::QueueFull) => {
                    if queue_ok {
                        w.mismatch =
                            Some(format!("client {t} shed QueueFull at depth {}", w.m_queued));
                    }
                    sheds_queue.fetch_add(1, Ordering::SeqCst);
                }
                Err(Shed::KvBudget) => {
                    if !queue_ok || kv_ok {
                        w.mismatch = Some(format!(
                            "client {t} shed KvBudget (reserved {}) but model \
                             said queue_ok={queue_ok} kv_ok={kv_ok}",
                            w.m_reserved
                        ));
                    }
                    sheds_kv.fetch_add(1, Ordering::SeqCst);
                }
            }
        })
        .step(move |w: &mut GateWorld| {
            // A worker picked the admitted request up.
            if w.got[t].is_some() {
                w.gate.dequeued();
                w.m_queued -= 1;
            }
        })
        .step(move |w: &mut GateWorld| {
            // The request reached a terminal outcome: release the pages.
            if let Some(r) = w.got[t].take() {
                w.gate.release_kv(r);
                w.m_reserved -= r;
            }
        })
}

#[test]
fn admission_gate_matches_sequential_model_under_all_interleavings() {
    static SHEDS_QUEUE: AtomicUsize = AtomicUsize::new(0);
    static SHEDS_KV: AtomicUsize = AtomicUsize::new(0);
    let scripts = vec![
        gate_client(0, &SHEDS_QUEUE, &SHEDS_KV),
        gate_client(1, &SHEDS_QUEUE, &SHEDS_KV),
        gate_client(2, &SHEDS_QUEUE, &SHEDS_KV),
    ];
    let explored = explore(
        &scripts,
        || GateWorld {
            gate: AdmissionGate::new(MAX_QUEUE, KV_BUDGET),
            m_queued: 0,
            m_reserved: 0,
            got: [None; 3],
            mismatch: None,
        },
        |w| {
            if let Some(m) = &w.mismatch {
                return Err(m.clone());
            }
            if w.gate.queued() != w.m_queued {
                return Err(format!(
                    "gate queued {} != model {} (rollback lost?)",
                    w.gate.queued(),
                    w.m_queued
                ));
            }
            if w.gate.kv_reserved() != w.m_reserved {
                return Err(format!(
                    "gate kv_reserved {} != model {}",
                    w.gate.kv_reserved(),
                    w.m_reserved
                ));
            }
            if w.m_reserved > KV_BUDGET {
                return Err(format!("reserved {} exceeds budget {KV_BUDGET}", w.m_reserved));
            }
            if w.m_queued > MAX_QUEUE {
                return Err(format!("queued {} exceeds cap {MAX_QUEUE}", w.m_queued));
            }
            Ok(())
        },
        11,
        2000,
    );
    // 3 scripts x 3 steps: the full multinomial 9!/(3!3!3!) = 1680 merge
    // orders fit the budget, so exploration was exhaustive.
    assert_eq!(explored, 1680, "expected exhaustive exploration");
    // The schedule set must actually drive both shed paths — otherwise
    // the rollback equality above was never load-bearing.
    assert!(SHEDS_QUEUE.load(Ordering::SeqCst) > 0, "no schedule produced a QueueFull shed");
    assert!(SHEDS_KV.load(Ordering::SeqCst) > 0, "no schedule produced a KvBudget rollback");
}

// ---------------------------------------------------------------------
// Race 2: PagePool alloc/free/evict vs prefix-cache pins.
// ---------------------------------------------------------------------

const KVD: usize = 4;
const PAGE_ROWS: usize = 2;
const MAX_PAGES: usize = 4;
/// Two whole-chunk prefixes registered in the trie, plus a trailing
/// token so `lookup_prefix` (which covers at most `len - 1` tokens) can
/// reach both chunks.
const QUERY: [usize; 5] = [11, 12, 13, 14, 99];

/// The known-good bytes of cached chunk `c`: rows are filled with a
/// value unique per (chunk, row, column) so any clear-and-reuse of a
/// pinned page is caught byte-for-byte.
fn chunk_data(c: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(PAGE_ROWS * KVD);
    for r in 0..PAGE_ROWS {
        for j in 0..KVD {
            out.push((c * 100 + r * 10 + j) as f32);
        }
    }
    out
}

struct PoolWorld {
    pool: PagePool,
    /// Private pages the allocator script currently holds.
    held: Vec<KvPage>,
    /// Shared pages the reader script has pinned, tagged with the chunk
    /// index whose bytes they must keep.
    pinned: Vec<(usize, Arc<KvPage>)>,
    /// Sequential model of `live_pages()`.
    m_live: usize,
    mismatch: Option<String>,
}

impl PoolWorld {
    fn new() -> PoolWorld {
        let shape = PageShape::new(KvCacheType::F32, KVD, PAGE_ROWS);
        let pool = PagePool::new(shape, MAX_PAGES, true);
        let mut bundles = Vec::new();
        for c in 0..2 {
            let mut page = pool.alloc().expect("setup alloc under cap");
            let data = chunk_data(c);
            for r in 0..PAGE_ROWS {
                page.append_row(&shape, &data[r * KVD..(r + 1) * KVD]);
            }
            bundles.push(vec![Arc::new(page)]);
        }
        pool.register_prefix(&QUERY[..4], bundles);
        let m_live = pool.live_pages();
        PoolWorld { pool, held: Vec::new(), pinned: Vec::new(), m_live, mismatch: None }
    }

    /// One allocator step: take a page, updating the live model by what
    /// the pool observably did (eviction reuses a live page; freelist
    /// hits and fresh mints add one).
    fn alloc_step(&mut self, exhausted: &AtomicUsize, evicted: &AtomicUsize) {
        let ev0 = self.pool.prefix_evictions();
        match self.pool.alloc() {
            Ok(page) => {
                if self.pool.prefix_evictions() == ev0 {
                    self.m_live += 1;
                } else {
                    evicted.fetch_add(1, Ordering::SeqCst);
                }
                self.held.push(page);
            }
            Err(_) => {
                exhausted.fetch_add(1, Ordering::SeqCst);
            }
        }
    }
}

#[test]
fn page_pool_eviction_respects_pins_under_all_interleavings() {
    static EXHAUSTED: AtomicUsize = AtomicUsize::new(0);
    static EVICTED: AtomicUsize = AtomicUsize::new(0);
    static FULL_HITS: AtomicUsize = AtomicUsize::new(0);
    static PARTIAL_HITS: AtomicUsize = AtomicUsize::new(0);

    let allocator = Script::new("allocator")
        .step(|w: &mut PoolWorld| w.alloc_step(&EXHAUSTED, &EVICTED))
        .step(|w: &mut PoolWorld| w.alloc_step(&EXHAUSTED, &EVICTED))
        .step(|w: &mut PoolWorld| w.alloc_step(&EXHAUSTED, &EVICTED))
        .step(|w: &mut PoolWorld| {
            for page in w.held.drain(..) {
                w.pool.recycle(page);
                w.m_live -= 1;
            }
        });

    let reader = Script::new("reader")
        .step(|w: &mut PoolWorld| {
            // Pin whatever prefix is still cached. Depending on how many
            // allocator steps ran first, this sees both chunks or — after
            // an eviction — only the surviving root chunk.
            if let Some(hit) = w.pool.lookup_prefix(&QUERY) {
                if hit.cow.is_some() {
                    w.mismatch = Some("unexpected CoW seed for a whole-chunk query".into());
                }
                if hit.bundles.len() == 2 {
                    FULL_HITS.fetch_add(1, Ordering::SeqCst);
                } else {
                    PARTIAL_HITS.fetch_add(1, Ordering::SeqCst);
                }
                for (c, bundle) in hit.bundles.into_iter().enumerate() {
                    for arc in bundle {
                        w.pinned.push((c, arc));
                    }
                }
            } else {
                PARTIAL_HITS.fetch_add(1, Ordering::SeqCst);
            }
        })
        .step(|w: &mut PoolWorld| {
            // A second transient lookup: raises sharing degree, then
            // releases immediately. Shared pages must not be recycled.
            if let Some(hit) = w.pool.lookup_prefix(&QUERY) {
                for bundle in hit.bundles {
                    for arc in bundle {
                        let last = Arc::strong_count(&arc) == 1;
                        w.pool.release(arc);
                        if last {
                            w.m_live -= 1;
                        }
                    }
                }
            }
        })
        .step(|w: &mut PoolWorld| {
            // Drop the pins; only a last holder actually recycles.
            for (_, arc) in w.pinned.drain(..) {
                let last = Arc::strong_count(&arc) == 1;
                w.pool.release(arc);
                if last {
                    w.m_live -= 1;
                }
            }
        });

    let explored = explore(
        &[allocator, reader],
        PoolWorld::new,
        |w| {
            if let Some(m) = &w.mismatch {
                return Err(m.clone());
            }
            let live = w.pool.live_pages();
            if live != w.m_live {
                return Err(format!("pool live {live} != model {} (accounting leak)", w.m_live));
            }
            if live > MAX_PAGES + w.pool.overflow_allocs() {
                return Err(format!(
                    "live {live} exceeds cap {MAX_PAGES} + overflow {}",
                    w.pool.overflow_allocs()
                ));
            }
            // Nodes are only removed by eviction, so the two registered
            // chunks are always split between the trie and the eviction
            // counter.
            if w.pool.prefix_nodes() + w.pool.prefix_evictions() != 2 {
                return Err(format!(
                    "trie accounting broken: {} nodes + {} evictions != 2",
                    w.pool.prefix_nodes(),
                    w.pool.prefix_evictions()
                ));
            }
            // Pinned pages keep their bytes no matter what the allocator
            // does — eviction must skip referenced leaves.
            for (c, arc) in &w.pinned {
                if arc.f32_data() != chunk_data(*c).as_slice() {
                    return Err(format!("pinned chunk {c} page bytes were disturbed"));
                }
            }
            Ok(())
        },
        13,
        200,
    );
    // 4 + 3 steps: C(7, 3) = 35 merge orders, exhaustively explored.
    assert_eq!(explored, 35, "expected exhaustive exploration");
    // The matrix of outcomes proves the schedules drive the real races:
    // allocation blocked by pins, eviction of an unpinned chunk, and a
    // full-prefix hit before any eviction.
    assert!(EXHAUSTED.load(Ordering::SeqCst) > 0, "no schedule hit PagesExhausted under pins");
    assert!(EVICTED.load(Ordering::SeqCst) > 0, "no schedule evicted an unpinned prefix");
    assert!(FULL_HITS.load(Ordering::SeqCst) > 0, "no schedule saw the full two-chunk hit");
    assert!(PARTIAL_HITS.load(Ordering::SeqCst) > 0, "no schedule saw a post-eviction lookup");
}
