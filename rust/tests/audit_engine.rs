//! Fixture tests for the `hif4 audit` engine (DESIGN.md §16): every
//! rule R1–R5 fires on a minimal positive fixture and stays silent on
//! the remediated twin; the allow protocol round-trips (allow with a
//! reason suppresses, allow without a reason is a finding, a stale
//! allow is a finding, a typo'd id suppresses nothing); and the shipped
//! source tree itself audits clean — the self-audit that keeps the tool
//! honest.

use hif4::audit::{audit_source, run_audit, Finding};

fn rules(findings: &[Finding]) -> Vec<(&'static str, &'static str)> {
    findings.iter().map(|f| (f.rule, f.id)).collect()
}

// -------------------------------------------------------------- R1 --

#[test]
fn r1_unsafe_without_safety_comment_fires() {
    let src = "pub fn deref(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let f = audit_source("dotprod/x.rs", src);
    assert_eq!(rules(&f), vec![("R1", "safety")]);
    assert_eq!(f[0].line, 2);
}

#[test]
fn r1_adjacent_safety_comment_satisfies() {
    let src = "pub fn deref(p: *const u8) -> u8 {\n    \
               // SAFETY: caller guarantees p is valid for reads.\n    \
               unsafe { *p }\n}\n";
    assert!(audit_source("dotprod/x.rs", src).is_empty());
}

#[test]
fn r1_rustdoc_safety_section_satisfies() {
    let src = "/// Reads a raw pointer.\n///\n/// # Safety\n/// `p` must be valid.\n\
               pub unsafe fn deref(p: *const u8) -> u8 {\n    *p\n}\n";
    assert!(audit_source("dotprod/x.rs", src).is_empty());
}

// -------------------------------------------------------------- R2 --

#[test]
fn r2_unwrap_in_serving_tier_fires() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    assert_eq!(rules(&audit_source("server/x.rs", src)), vec![("R2", "panic")]);
    // The same code outside the serving tier is not R2's business.
    assert!(audit_source("eval/x.rs", src).is_empty());
}

#[test]
fn r2_scalar_index_fires_but_range_slicing_is_exempt() {
    let scalar = "pub fn f(v: &[u32]) -> u32 {\n    v[0]\n}\n";
    assert_eq!(rules(&audit_source("runtime/x.rs", scalar)), vec![("R2", "index")]);
    let range = "pub fn f(v: &[u32]) -> &[u32] {\n    &v[1..3]\n}\n";
    assert!(audit_source("runtime/x.rs", range).is_empty());
}

#[test]
fn r2_raw_lock_fires_and_lock_recover_passes() {
    let raw = "pub fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
    let found = rules(&audit_source("server/x.rs", raw));
    assert!(found.contains(&("R2", "lock")), "raw lock must fire: {found:?}");
    assert!(found.contains(&("R2", "panic")), "the unwrap fires too: {found:?}");
    let ok = "pub fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *crate::util::lock_recover(m)\n}\n";
    assert!(audit_source("server/x.rs", ok).is_empty());
}

#[test]
fn r2_is_suspended_inside_cfg_test() {
    let src = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u32>) -> u32 {\n        \
               x.unwrap()\n    }\n}\n";
    assert!(audit_source("server/x.rs", src).is_empty());
}

// -------------------------------------------------------------- R3 --

#[test]
fn r3_hash_collections_fire_in_bit_exact_modules() {
    let src = "use std::collections::HashMap;\n";
    assert_eq!(rules(&audit_source("model/x.rs", src)), vec![("R3", "hash-iter")]);
    // Outside the determinism scope the same import is fine.
    assert!(audit_source("server/x.rs", src).is_empty());
}

#[test]
fn r3_wall_clock_types_fire() {
    let src = "pub fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    let found = rules(&audit_source("formats/x.rs", src));
    assert!(found.iter().all(|&r| r == ("R3", "time")), "only time findings: {found:?}");
    assert!(!found.is_empty());
}

#[test]
fn r3_narrowing_cast_fires_only_when_operand_is_visibly_f64() {
    let narrowing = "pub fn f(a: f64) -> f32 {\n    (a * 0.5) as f32\n}\n";
    assert_eq!(rules(&audit_source("dotprod/x.rs", narrowing)), vec![("R3", "narrowing")]);
    // An integer-to-f32 cast is widening in spirit and must not fire.
    let widening = "pub fn f(n: usize) -> f32 {\n    (n + 1) as f32\n}\n";
    assert!(audit_source("dotprod/x.rs", widening).is_empty());
}

// -------------------------------------------------------------- R4 --

#[test]
fn r4_widening_dot_without_bound_comment_fires() {
    let src = "pub fn dot(a: &[i8], b: &[i8]) -> i32 {\n    \
               a.iter().zip(b).map(|(x, y)| *x as i32 * *y as i32).sum()\n}\n";
    assert_eq!(rules(&audit_source("quant/x.rs", src)), vec![("R4", "bound")]);
}

#[test]
fn r4_bound_comment_referencing_the_lane_cap_satisfies() {
    let src = "// BOUND: callers cap lanes at IDOT_I32_SAFE_LANES, so the sum fits i32.\n\
               pub fn dot(a: &[i8], b: &[i8]) -> i32 {\n    \
               a.iter().zip(b).map(|(x, y)| *x as i32 * *y as i32).sum()\n}\n";
    assert!(audit_source("quant/x.rs", src).is_empty());
}

// -------------------------------------------------------------- R5 --

#[test]
fn r5_env_read_fires_unless_site_is_registered() {
    let src = "pub fn f() -> bool {\n    std::env::var(\"HIF4_THREADS\").is_ok()\n}\n";
    // Registered (file, var) pair: the thread-count knob in its home.
    assert!(audit_source("util/threadpool.rs", src).is_empty());
    // Same read anywhere else is an unregistered knob.
    let f = audit_source("model/x.rs", src);
    assert_eq!(rules(&f), vec![("R5", "env")]);
    assert!(f[0].message.contains("HIF4_THREADS"), "names the variable: {}", f[0].message);
}

// --------------------------------------------------- allow protocol --

#[test]
fn allow_with_reason_suppresses_the_finding() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n    \
               // audit:allow(panic) -- x is Some by construction at every call site.\n    \
               x.unwrap()\n}\n";
    assert!(audit_source("server/x.rs", src).is_empty());
}

#[test]
fn allow_without_reason_is_itself_a_finding() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n    // audit:allow(panic)\n    x.unwrap()\n}\n";
    let f = audit_source("server/x.rs", src);
    assert_eq!(rules(&f), vec![("allow", "panic")]);
    assert!(f[0].message.contains("without a"), "demands a reason: {}", f[0].message);
}

#[test]
fn stale_allow_is_itself_a_finding() {
    let src = "// audit:allow(panic) -- legacy shim, since removed.\npub fn f() {}\n";
    let f = audit_source("server/x.rs", src);
    assert_eq!(rules(&f), vec![("allow", "panic")]);
    assert!(f[0].message.contains("stale"), "flags the dead allow: {}", f[0].message);
}

#[test]
fn typoed_allow_id_suppresses_nothing() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n    \
               // audit:allow(panics) -- typo'd id must not register.\n    x.unwrap()\n}\n";
    // The unknown id is ignored: the real finding still fires, and no
    // stale-allow finding appears for the typo.
    assert_eq!(rules(&audit_source("server/x.rs", src)), vec![("R2", "panic")]);
}

#[test]
fn allow_only_covers_its_own_id() {
    let src = "pub fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    \
               // audit:allow(panic) -- poisoning is unreachable here.\n    \
               *m.lock().unwrap()\n}\n";
    // The panic allow eats the unwrap but not the raw-lock finding.
    let found = rules(&audit_source("server/x.rs", src));
    assert_eq!(found, vec![("R2", "lock")]);
}

// -------------------------------------------------------- self-audit --

#[test]
fn shipped_source_tree_audits_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = run_audit(&root).expect("audit over src/ runs");
    assert!(report.files_scanned >= 50, "expected the full tree, got {}", report.files_scanned);
    assert!(
        report.clean(),
        "shipped tree must carry zero findings and zero stale allows:\n{}",
        report.render(true)
    );
    let json = report.to_json().render();
    assert!(json.contains("\"clean\""), "report JSON carries the clean flag: {json}");
}
