//! Packed-plane bit-equality across **all five block formats**: the
//! decode-once integer kernels must equal the element-wise flow partials
//! — and the flows equal the dequantized-f64 reference — **exactly**,
//! across ≥6 magnitude decades, on zero groups, under NaN-scale
//! poisoning, on ragged tail-group shapes, and for any thread count.
//! This is the contract that makes the kernel-backend selector a pure
//! performance knob for every format the unified `QuantizedMatrix` API
//! serves.

use hif4::dotprod::quant_tensor::{
    dot_dequant_ref, qgemm_bt_flow_threads, qgemm_bt_packed_threads, BfpFmt, BlockFormat,
    HiF4Fmt, Mx4Fmt, Mxfp4Fmt, Nvfp4Fmt, PackedQuantMat, QuantMat,
};
use hif4::dotprod::QuantizedMatrix;
use hif4::formats::rounding::RoundMode;
use hif4::formats::QuantKind;
use hif4::tensor::{Matrix, Rng};

const MODE: RoundMode = RoundMode::NearestEven;

/// f64 equality up to NaN identification (NaN payloads are unspecified
/// after arithmetic; everything else must match to the bit).
fn feq64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn feq32_all(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()))
}

/// One format's group-level parity: packed partial == flow partial ==
/// dequantized-f64 reference, 300 random group pairs over ≥6 decades.
fn group_parity<F: BlockFormat>(seed: u64) {
    let mut rng = Rng::seed(seed);
    for round in 0..300 {
        let sigma = 10f32.powi((round % 6) - 3);
        let va: Vec<f32> = (0..F::GROUP).map(|_| rng.normal() as f32 * sigma).collect();
        let vb: Vec<f32> = (0..F::GROUP).map(|_| rng.normal() as f32 * sigma).collect();
        let qa = QuantMat::<F>::quantize(&Matrix::from_vec(1, F::GROUP, va), MODE);
        let qb = QuantMat::<F>::quantize(&Matrix::from_vec(1, F::GROUP, vb), MODE);
        let pa = PackedQuantMat::pack(&qa);
        let pb = PackedQuantMat::pack(&qb);
        let packed = pa.dot_group(0, 0, &pb, 0, 0);
        let flow = F::dot_flow(&qa.row_groups(0)[0], &qb.row_groups(0)[0]);
        let reference = dot_dequant_ref::<F>(&qa.row_groups(0)[0], &qb.row_groups(0)[0]);
        assert!(
            feq64(packed, flow),
            "{} round {round} (σ={sigma}): packed {packed} vs flow {flow}",
            F::KIND
        );
        assert!(
            feq64(flow, reference),
            "{} round {round}: flow {flow} vs ref {reference}",
            F::KIND
        );
    }
}

#[test]
fn packed_dot_equals_flow_and_dequant_ref_across_decades_all_formats() {
    group_parity::<HiF4Fmt>(7001);
    group_parity::<Nvfp4Fmt>(7002);
    group_parity::<Mxfp4Fmt>(7003);
    group_parity::<Mx4Fmt>(7004);
    group_parity::<BfpFmt>(7005);
}

#[test]
fn zero_groups_dot_to_exact_zero_all_formats() {
    for kind in QuantKind::ALL {
        let g = kind.group();
        let z = QuantizedMatrix::quantize(kind, &Matrix::zeros(1, g), MODE);
        let pz = z.pack();
        let c = pz.qgemm_bt_threads(&pz, 1);
        assert_eq!(c.data[0], 0.0, "{kind}: zero groups must dot to zero exactly");
        let flow = z.qgemm_bt_flow_threads(&z, 1);
        assert_eq!(c.data[0].to_bits(), flow.data[0].to_bits(), "{kind}");
    }
}

#[test]
fn nan_scale_poisons_packed_dot_and_gemm_all_formats() {
    let mut rng = Rng::seed(7006);
    for kind in QuantKind::ALL {
        let g = kind.group();
        // Two groups per row; poison only A's second group.
        let k = 2 * g + g / 2; // ragged tail too
        let mut va: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        va[g + 1] = f32::NAN;
        let vb: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let qa = QuantizedMatrix::quantize(kind, &Matrix::from_vec(1, k, va), MODE);
        let qb = QuantizedMatrix::quantize(kind, &Matrix::from_vec(1, k, vb), MODE);
        // GEMM: every output touching the poisoned group is NaN on both
        // backends (here: the single output cell).
        let flow = qa.qgemm_bt_flow_threads(&qb, 1);
        let packed = qa.pack_threads(1).qgemm_bt_threads(&qb.pack_threads(1), 1);
        assert!(flow.data.iter().all(|x| x.is_nan()), "{kind} flow");
        assert!(packed.data.iter().all(|x| x.is_nan()), "{kind} packed");
    }
}

#[test]
fn packed_gemm_equals_flow_gemm_bitwise_all_formats() {
    // Ragged shapes: clean multiples, sub-group K, tails of every group
    // size (64/32/16), plus NVFP4's non-multiple-of-PE tails.
    let mut rng = Rng::seed(7007);
    for kind in QuantKind::ALL {
        for (m, k, n) in [(5, 130, 7), (16, 64, 16), (1, 200, 9), (4, 72, 6), (8, 40, 3)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            let qa = QuantizedMatrix::quantize(kind, &a, MODE);
            let qb = QuantizedMatrix::quantize(kind, &b, MODE);
            let flow = qa.qgemm_bt_flow_threads(&qb, 1);
            let pa = qa.pack_threads(1);
            let pb = qb.pack_threads(1);
            for threads in [1, 3, 4] {
                let packed = pa.qgemm_bt_threads(&pb, threads);
                assert!(
                    feq32_all(&flow.data, &packed.data),
                    "{kind} {m}x{k}x{n} threads={threads}"
                );
            }
            // The dispatching entry point agrees too, whatever the backend.
            let dispatched = qa.qgemm_bt_threads(&qb, 2);
            assert!(feq32_all(&flow.data, &dispatched.data), "{kind} {m}x{k}x{n} dispatch");
        }
    }
}

#[test]
fn qgemm_equals_dequantized_f32_gemm_all_formats() {
    // The fixed-point GEMM approximates the dequantize-then-f32-GEMM
    // simulated path up to f32 summation noise — the bridge between the
    // serving path and the paper's accuracy-table semantics, now for
    // every format.
    use hif4::tensor::gemm;
    let mut rng = Rng::seed(7008);
    for kind in QuantKind::ALL {
        let a = Matrix::randn(5, 130, 1.0, &mut rng);
        let b = Matrix::randn(7, 130, 1.0, &mut rng);
        let qa = QuantizedMatrix::quantize(kind, &a, MODE);
        let qb = QuantizedMatrix::quantize(kind, &b, MODE);
        let via_pe = qa.qgemm_bt(&qb);
        let via_dequant = gemm::matmul_bt(&qa.dequantize(), &qb.dequantize());
        for (x, y) in via_pe.data.iter().zip(&via_dequant.data) {
            assert!((x - y).abs() <= 2e-3 * (1.0 + x.abs()), "{kind}: {x} vs {y}");
        }
    }
}

#[test]
fn generic_kernels_match_enum_surface() {
    // The free generic kernels and the enum-dispatched methods are the
    // same code; pin it so nothing drifts between the two entry styles.
    let mut rng = Rng::seed(7009);
    let a = Matrix::randn(3, 100, 1.0, &mut rng);
    let b = Matrix::randn(4, 100, 1.0, &mut rng);
    let qa = QuantMat::<Mxfp4Fmt>::quantize(&a, MODE);
    let qb = QuantMat::<Mxfp4Fmt>::quantize(&b, MODE);
    let generic_flow = qgemm_bt_flow_threads(&qa, &qb, 1);
    let generic_packed =
        qgemm_bt_packed_threads(&PackedQuantMat::pack(&qa), &PackedQuantMat::pack(&qb), 1);
    let ea = QuantizedMatrix::quantize(QuantKind::Mxfp4, &a, MODE);
    let eb = QuantizedMatrix::quantize(QuantKind::Mxfp4, &b, MODE);
    let enum_flow = ea.qgemm_bt_flow_threads(&eb, 1);
    let enum_packed = ea.pack_threads(1).qgemm_bt_threads(&eb.pack_threads(1), 1);
    assert!(feq32_all(&generic_flow.data, &enum_flow.data));
    assert!(feq32_all(&generic_packed.data, &enum_packed.data));
    assert!(feq32_all(&generic_flow.data, &generic_packed.data));
}
