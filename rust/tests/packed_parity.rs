//! Packed-plane bit-equality: the decode-once integer kernels must equal
//! the element-wise PE flows — and the flows equal the dequantized-f64
//! reference — **exactly**, across scale decades, on zero units, and under
//! NaN-scale poisoning. This is the contract that makes the kernel-backend
//! selector a pure performance knob.

use hif4::dotprod::packed::{
    hif4_gemm_bt_packed_threads, nvfp4_gemm_bt_packed_threads, PackedHiF4Matrix,
    PackedNvfp4Matrix,
};
use hif4::dotprod::qgemm::{
    hif4_gemm_bt_flow_threads, hif4_gemm_bt_threads, nvfp4_gemm_bt_flow_threads, HiF4Matrix,
    Nvfp4Matrix,
};
use hif4::dotprod::{hif4_flow, nvfp4_flow};
use hif4::formats::rounding::RoundMode;
use hif4::tensor::{Matrix, Rng};

const MODE: RoundMode = RoundMode::NearestEven;

/// f64 equality up to NaN identification (NaN payloads are unspecified
/// after arithmetic; everything else must match to the bit).
fn feq64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn feq32_all(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()))
}

#[test]
fn hif4_packed_dot_equals_flow_and_dequant_ref_across_decades() {
    // ≥6 scale decades: sigma from 1e-3 to 1e2, 300 random unit pairs. The
    // three computations — packed integer dot, PE flow, dequantized f64
    // walk — must agree bit for bit.
    let mut rng = Rng::seed(7001);
    for round in 0..300 {
        let sigma = 10f32.powi((round % 6) - 3);
        let va: Vec<f32> = (0..64).map(|_| rng.normal() as f32 * sigma).collect();
        let vb: Vec<f32> = (0..64).map(|_| rng.normal() as f32 * sigma).collect();
        let qa = HiF4Matrix::quantize(&Matrix::from_vec(1, 64, va), MODE);
        let qb = HiF4Matrix::quantize(&Matrix::from_vec(1, 64, vb), MODE);
        let pa = PackedHiF4Matrix::pack(&qa);
        let pb = PackedHiF4Matrix::pack(&qb);
        let packed = pa.dot_unit(0, 0, &pb, 0, 0);
        let flow = hif4_flow::dot(&qa.row_units(0)[0], &qb.row_units(0)[0]);
        let reference = hif4_flow::dot_dequant_ref(&qa.row_units(0)[0], &qb.row_units(0)[0]);
        assert!(feq64(packed, flow), "round {round} (σ={sigma}): packed {packed} vs flow {flow}");
        assert!(feq64(flow, reference), "round {round}: flow {flow} vs ref {reference}");
    }
}

#[test]
fn nvfp4_packed_group_equals_flow_and_dequant_ref_across_decades() {
    let mut rng = Rng::seed(7002);
    for round in 0..300 {
        let sigma = 10f32.powi((round % 6) - 3);
        let va: Vec<f32> = (0..16).map(|_| rng.normal() as f32 * sigma).collect();
        let vb: Vec<f32> = (0..16).map(|_| rng.normal() as f32 * sigma).collect();
        let qa = Nvfp4Matrix::quantize(&Matrix::from_vec(1, 16, va), MODE);
        let qb = Nvfp4Matrix::quantize(&Matrix::from_vec(1, 16, vb), MODE);
        let pa = PackedNvfp4Matrix::pack(&qa);
        let pb = PackedNvfp4Matrix::pack(&qb);
        let packed = pa.dot_group(0, 0, &pb, 0, 0);
        let ga = &qa.row_groups(0)[0];
        let gb = &qb.row_groups(0)[0];
        let flow = nvfp4_flow::dot_group(ga, gb);
        let reference =
            nvfp4_flow::dot64_dequant_ref(core::slice::from_ref(ga), core::slice::from_ref(gb));
        assert!(feq64(packed, flow), "round {round} (σ={sigma})");
        assert!(feq64(flow, reference), "round {round}");
    }
}

#[test]
fn zero_units_dot_to_exact_positive_zero() {
    let z = HiF4Matrix::quantize(&Matrix::zeros(1, 64), MODE);
    let pz = PackedHiF4Matrix::pack(&z);
    let d = pz.dot_unit(0, 0, &pz, 0, 0);
    assert_eq!(d.to_bits(), 0f64.to_bits(), "zero units must dot to +0.0 exactly");
    assert_eq!(d.to_bits(), hif4_flow::dot(&z.row_units(0)[0], &z.row_units(0)[0]).to_bits());
}

#[test]
fn nan_scale_poisons_packed_dot_and_gemm() {
    let mut rng = Rng::seed(7003);
    let mut va: Vec<f32> = (0..130).map(|_| rng.normal() as f32).collect();
    va[70] = f32::NAN; // poisons A's second unit only
    let vb: Vec<f32> = (0..130).map(|_| rng.normal() as f32).collect();
    let qa = HiF4Matrix::quantize(&Matrix::from_vec(1, 130, va), MODE);
    let qb = HiF4Matrix::quantize(&Matrix::from_vec(2, 130, [vb.clone(), vb].concat()), MODE);
    assert!(qa.row_units(0)[1].scale.is_nan(), "unit 1 must be NaN-poisoned");
    let pa = PackedHiF4Matrix::pack(&qa);
    let pb = PackedHiF4Matrix::pack(&qb);
    assert!(pa.dot_unit(0, 1, &pb, 0, 1).is_nan());
    // Clean unit 0 still matches the flow exactly.
    assert_eq!(
        pa.dot_unit(0, 0, &pb, 0, 0).to_bits(),
        hif4_flow::dot(&qa.row_units(0)[0], &qb.row_units(0)[0]).to_bits()
    );
    // GEMM: every output touching the poisoned unit is NaN on both paths.
    let flow = hif4_gemm_bt_flow_threads(&qa, &qb, 1);
    let packed = hif4_gemm_bt_packed_threads(&pa, &pb, 1);
    assert!(flow.data.iter().all(|x| x.is_nan()));
    assert!(packed.data.iter().all(|x| x.is_nan()));
}

#[test]
fn hif4_packed_gemm_equals_flow_gemm_bitwise() {
    // Ragged shapes: clean multiples, sub-unit K, tails of the 64-group.
    let mut rng = Rng::seed(7004);
    for (m, k, n) in [(5, 130, 7), (16, 64, 16), (1, 200, 9), (23, 72, 11), (8, 40, 3)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(n, k, 1.0, &mut rng);
        let qa = HiF4Matrix::quantize(&a, MODE);
        let qb = HiF4Matrix::quantize(&b, MODE);
        let flow = hif4_gemm_bt_flow_threads(&qa, &qb, 1);
        let pa = PackedHiF4Matrix::pack(&qa);
        let pb = PackedHiF4Matrix::pack(&qb);
        for threads in [1, 3, 4] {
            let packed = hif4_gemm_bt_packed_threads(&pa, &pb, threads);
            assert!(feq32_all(&flow.data, &packed.data), "{m}x{k}x{n} threads={threads}");
        }
        // The dispatching entry point agrees too, whatever the backend.
        let dispatched = hif4_gemm_bt_threads(&qa, &qb, 2);
        assert!(feq32_all(&flow.data, &dispatched.data), "{m}x{k}x{n} dispatch");
    }
}

#[test]
fn nvfp4_packed_gemm_equals_flow_gemm_bitwise() {
    // 72 and 40 cols exercise the tail-group (non-multiple-of-PE) path.
    let mut rng = Rng::seed(7005);
    for (m, k, n) in [(5, 130, 7), (4, 72, 6), (3, 40, 5), (2, 256, 3)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(n, k, 1.0, &mut rng);
        let qa = Nvfp4Matrix::quantize(&a, MODE);
        let qb = Nvfp4Matrix::quantize(&b, MODE);
        let flow = nvfp4_gemm_bt_flow_threads(&qa, &qb, 1);
        let pa = PackedNvfp4Matrix::pack(&qa);
        let pb = PackedNvfp4Matrix::pack(&qb);
        for threads in [1, 3, 4] {
            let packed = nvfp4_gemm_bt_packed_threads(&pa, &pb, threads);
            assert!(feq32_all(&flow.data, &packed.data), "{m}x{k}x{n} threads={threads}");
        }
    }
}
