//! Packed-plane bit-equality across **all five block formats**: the
//! decode-once integer kernels — the scalar packed kernel *and* the
//! SIMD-tiled microkernel — must equal the element-wise flow partials,
//! and the flows equal the dequantized-f64 reference, **exactly**:
//! across ≥6 magnitude decades, on zero groups, under NaN-scale
//! poisoning, on ragged tail-group shapes, on randomized geometries
//! (property-tested, incl. degenerate 1-row/1-col), at adversarial
//! max-magnitude `k ≥ 16384`, and for any thread count. This is the
//! contract that makes the kernel-backend selector (`simd == packed ==
//! flow == dequant-f64`) a pure performance knob for every format the
//! unified `QuantizedMatrix` API serves.

use hif4::dotprod::quant_tensor::{
    dot_dequant_ref, qgemm_bt_flow_threads, qgemm_bt_packed_threads, qgemm_bt_simd_threads,
    BfpFmt, BlockFormat, HiF4Fmt, Mx4Fmt, Mxfp4Fmt, Nvfp4Fmt, PackedQuantMat, QuantMat,
};
use hif4::dotprod::QuantizedMatrix;
use hif4::formats::rounding::RoundMode;
use hif4::formats::QuantKind;
use hif4::tensor::{Matrix, Rng};
use hif4::util::proptest::{check, Gen};

const MODE: RoundMode = RoundMode::NearestEven;

/// f64 equality up to NaN identification (NaN payloads are unspecified
/// after arithmetic; everything else must match to the bit).
fn feq64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn feq32_all(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()))
}

/// One format's group-level parity: packed partial == flow partial ==
/// dequantized-f64 reference, 300 random group pairs over ≥6 decades.
fn group_parity<F: BlockFormat>(seed: u64) {
    let mut rng = Rng::seed(seed);
    for round in 0..300 {
        let sigma = 10f32.powi((round % 6) - 3);
        let va: Vec<f32> = (0..F::GROUP).map(|_| rng.normal() as f32 * sigma).collect();
        let vb: Vec<f32> = (0..F::GROUP).map(|_| rng.normal() as f32 * sigma).collect();
        let qa = QuantMat::<F>::quantize(&Matrix::from_vec(1, F::GROUP, va), MODE);
        let qb = QuantMat::<F>::quantize(&Matrix::from_vec(1, F::GROUP, vb), MODE);
        let pa = PackedQuantMat::pack(&qa);
        let pb = PackedQuantMat::pack(&qb);
        let packed = pa.dot_group(0, 0, &pb, 0, 0);
        let flow = F::dot_flow(&qa.row_groups(0)[0], &qb.row_groups(0)[0]);
        let reference = dot_dequant_ref::<F>(&qa.row_groups(0)[0], &qb.row_groups(0)[0]);
        assert!(
            feq64(packed, flow),
            "{} round {round} (σ={sigma}): packed {packed} vs flow {flow}",
            F::KIND
        );
        assert!(
            feq64(flow, reference),
            "{} round {round}: flow {flow} vs ref {reference}",
            F::KIND
        );
    }
}

#[test]
fn packed_dot_equals_flow_and_dequant_ref_across_decades_all_formats() {
    group_parity::<HiF4Fmt>(7001);
    group_parity::<Nvfp4Fmt>(7002);
    group_parity::<Mxfp4Fmt>(7003);
    group_parity::<Mx4Fmt>(7004);
    group_parity::<BfpFmt>(7005);
}

#[test]
fn zero_groups_dot_to_exact_zero_all_formats() {
    for kind in QuantKind::ALL {
        let g = kind.group();
        let z = QuantizedMatrix::quantize(kind, &Matrix::zeros(1, g), MODE);
        let pz = z.pack();
        let c = pz.qgemm_bt_packed_threads(&pz, 1);
        assert_eq!(c.data[0], 0.0, "{kind}: zero groups must dot to zero exactly");
        let simd = pz.qgemm_bt_simd_threads(&pz, 1);
        assert_eq!(c.data[0].to_bits(), simd.data[0].to_bits(), "{kind} simd");
        let flow = z.qgemm_bt_flow_threads(&z, 1);
        assert_eq!(c.data[0].to_bits(), flow.data[0].to_bits(), "{kind}");
    }
}

#[test]
fn nan_scale_poisons_packed_dot_and_gemm_all_formats() {
    let mut rng = Rng::seed(7006);
    for kind in QuantKind::ALL {
        let g = kind.group();
        // Two groups per row; poison only A's second group.
        let k = 2 * g + g / 2; // ragged tail too
        let mut va: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        va[g + 1] = f32::NAN;
        let vb: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let qa = QuantizedMatrix::quantize(kind, &Matrix::from_vec(1, k, va), MODE);
        let qb = QuantizedMatrix::quantize(kind, &Matrix::from_vec(1, k, vb), MODE);
        // GEMM: every output touching the poisoned group is NaN on every
        // backend (here: the single output cell).
        let flow = qa.qgemm_bt_flow_threads(&qb, 1);
        let pa = qa.pack_threads(1);
        let pb = qb.pack_threads(1);
        let packed = pa.qgemm_bt_packed_threads(&pb, 1);
        let simd = pa.qgemm_bt_simd_threads(&pb, 1);
        assert!(flow.data.iter().all(|x| x.is_nan()), "{kind} flow");
        assert!(packed.data.iter().all(|x| x.is_nan()), "{kind} packed");
        assert!(simd.data.iter().all(|x| x.is_nan()), "{kind} simd");
    }
}

#[test]
fn packed_gemm_equals_flow_gemm_bitwise_all_formats() {
    // Ragged shapes: clean multiples, sub-group K, tails of every group
    // size (64/32/16), plus NVFP4's non-multiple-of-PE tails. Both plane
    // backends (scalar packed and the SIMD-tiled microkernel) must equal
    // the flow for every thread count.
    let mut rng = Rng::seed(7007);
    for kind in QuantKind::ALL {
        for (m, k, n) in [(5, 130, 7), (16, 64, 16), (1, 200, 9), (4, 72, 6), (8, 40, 3)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            let qa = QuantizedMatrix::quantize(kind, &a, MODE);
            let qb = QuantizedMatrix::quantize(kind, &b, MODE);
            let flow = qa.qgemm_bt_flow_threads(&qb, 1);
            let pa = qa.pack_threads(1);
            let pb = qb.pack_threads(1);
            for threads in [1, 3, 4] {
                let packed = pa.qgemm_bt_packed_threads(&pb, threads);
                assert!(
                    feq32_all(&flow.data, &packed.data),
                    "{kind} {m}x{k}x{n} threads={threads}"
                );
                let simd = pa.qgemm_bt_simd_threads(&pb, threads);
                assert!(
                    feq32_all(&flow.data, &simd.data),
                    "{kind} {m}x{k}x{n} threads={threads} simd"
                );
            }
            // The dispatching entry points agree too, whatever backend
            // the process knob picked.
            let dispatched = qa.qgemm_bt_threads(&qb, 2);
            assert!(feq32_all(&flow.data, &dispatched.data), "{kind} {m}x{k}x{n} dispatch");
            let plane_dispatched = pa.qgemm_bt_threads(&pb, 2);
            assert!(
                feq32_all(&flow.data, &plane_dispatched.data),
                "{kind} {m}x{k}x{n} plane dispatch"
            );
        }
    }
}

/// Random GEMM geometries biased toward the awkward cases: `k % 64 != 0`
/// tail groups (for every group size) and single-row / single-column
/// degenerate matrices. Shrinks toward (1, 1, 1).
struct GeomGen;

impl Gen for GeomGen {
    type Value = (usize, usize, usize);

    fn generate(&self, rng: &mut Rng) -> (usize, usize, usize) {
        // m/n: 1..=10 with a heavy bias to 1 (the degenerate shapes).
        let dim = |rng: &mut Rng| if rng.below(4) == 0 { 1 } else { 1 + rng.below(10) };
        let m = dim(rng);
        let n = dim(rng);
        // k: 1..=320, biased off the 64-multiple grid so padded tails
        // dominate; keep exact multiples reachable too.
        let k = if rng.below(5) == 0 { 64 * (1 + rng.below(4)) } else { 1 + rng.below(320) };
        (m, k, n)
    }

    fn shrink(&self, v: &(usize, usize, usize)) -> Vec<(usize, usize, usize)> {
        let (m, k, n) = *v;
        let mut out = Vec::new();
        if m > 1 {
            out.push((1, k, n));
            out.push((m / 2, k, n));
        }
        if n > 1 {
            out.push((m, k, 1));
            out.push((m, k, n / 2));
        }
        if k > 1 {
            out.push((m, 1, n));
            out.push((m, k / 2, n));
        }
        out
    }
}

#[test]
fn simd_matches_packed_bitwise_on_random_geometries_property() {
    // The satellite property test: for ANY geometry — tails, degenerate
    // rows/cols, every QuantKind — the SIMD microkernel and the scalar
    // packed kernel agree bit for bit (and both match the flow).
    check(60, 7010, &GeomGen, |&(m, k, n)| {
        // Deterministic per-geometry data so shrinking stays meaningful.
        let mut rng = Rng::seed(31 * m as u64 + 7 * k as u64 + 13 * n as u64);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(n, k, 1.0, &mut rng);
        QuantKind::ALL.iter().all(|&kind| {
            let qa = QuantizedMatrix::quantize_threads(kind, &a, MODE, 1);
            let qb = QuantizedMatrix::quantize_threads(kind, &b, MODE, 1);
            let pa = qa.pack_threads(1);
            let pb = qb.pack_threads(1);
            let packed = pa.qgemm_bt_packed_threads(&pb, 1);
            let simd = pa.qgemm_bt_simd_threads(&pb, 1);
            let flow = qa.qgemm_bt_flow_threads(&qb, 1);
            feq32_all(&packed.data, &simd.data) && feq32_all(&flow.data, &packed.data)
        })
    });
}

#[test]
fn adversarial_max_magnitude_large_k_stays_exact() {
    // The overflow-audit regression (satellite of the i64-widening fix):
    // k ≥ 16384 with every element at the codec's peak magnitude drives
    // hundreds of max-lane groups through the kernels — any accumulator
    // that wrapped, saturated (e.g. a vpmaddubsw-style i16 path) or
    // reassociated the f64 stages would break the four-way bit equality.
    let k = 16384 + 40; // ragged tail on top, for every group size
    for kind in QuantKind::ALL {
        let va: Vec<f32> = (0..k).map(|i| if i % 2 == 0 { 7.0 } else { -7.0 }).collect();
        let vb: Vec<f32> = (0..k).map(|i| if i % 3 == 0 { -7.0 } else { 7.0 }).collect();
        let qa = QuantizedMatrix::quantize(kind, &Matrix::from_vec(1, k, va), MODE);
        let qb = QuantizedMatrix::quantize(kind, &Matrix::from_vec(1, k, vb), MODE);
        let flow = qa.qgemm_bt_flow_threads(&qb, 1);
        let pa = qa.pack_threads(1);
        let pb = qb.pack_threads(1);
        let packed = pa.qgemm_bt_packed_threads(&pb, 1);
        let simd = pa.qgemm_bt_simd_threads(&pb, 1);
        assert!(flow.data[0].is_finite(), "{kind}: max-magnitude GEMM must stay finite");
        assert_eq!(flow.data[0].to_bits(), packed.data[0].to_bits(), "{kind} packed");
        assert_eq!(flow.data[0].to_bits(), simd.data[0].to_bits(), "{kind} simd");
        // Self-product: every group partial is positive, so the result
        // bounds k from below — a wrapped integer would go negative.
        let self_packed = pa.qgemm_bt_packed_threads(&pa, 1);
        let self_simd = pa.qgemm_bt_simd_threads(&pa, 1);
        assert!(self_packed.data[0] > 0.0, "{kind}: self-dot must be positive");
        assert_eq!(self_packed.data[0].to_bits(), self_simd.data[0].to_bits(), "{kind}");
    }
}

#[test]
fn knob_dispatching_entries_follow_process_kernel() {
    // The test CI's kernel matrix actually varies: everything here routes
    // through the knob-dispatching entry points (`qgemm_bt`,
    // `qgemm_bt_threads` on both enum surfaces), so under
    // HIF4_KERNEL=simd the whole body runs the tiled microkernel and
    // under HIF4_KERNEL=packed the scalar plane kernel — and in both
    // legs every result must still equal the flow reference bit for bit.
    let mut rng = Rng::seed(7011);
    for kind in QuantKind::ALL {
        for (m, k, n) in [(6, 130, 9), (1, 96, 1), (11, 40, 5)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            let qa = QuantizedMatrix::quantize(kind, &a, MODE);
            let qb = QuantizedMatrix::quantize(kind, &b, MODE);
            let flow = qa.qgemm_bt_flow_threads(&qb, 1);
            let via_quantized = qa.qgemm_bt(&qb);
            assert!(feq32_all(&flow.data, &via_quantized.data), "{kind} {m}x{k}x{n} qgemm_bt");
            let pa = qa.pack();
            let pb = qb.pack();
            let via_planes = pa.qgemm_bt(&pb);
            assert!(feq32_all(&flow.data, &via_planes.data), "{kind} {m}x{k}x{n} planes");
            for threads in [1, 2, 5] {
                let c = pa.qgemm_bt_threads(&pb, threads);
                assert!(feq32_all(&flow.data, &c.data), "{kind} {m}x{k}x{n} threads={threads}");
            }
        }
    }
}

#[test]
fn simd_isa_meets_ci_requirement() {
    // CI's simd matrix leg sets HIF4_REQUIRE_SIMD=avx2: if the AVX2
    // microkernel silently compiled out, or runtime detection broke, this
    // fails loudly instead of the parity suite quietly passing on the
    // portable fallback. Unset (or empty) means "no requirement".
    if let Ok(want) = std::env::var("HIF4_REQUIRE_SIMD") {
        if !want.is_empty() {
            assert_eq!(
                hif4::dotprod::simd_isa_label(),
                want,
                "the SIMD lane ISA requirement was not met"
            );
        }
    }
}

#[test]
fn qgemm_equals_dequantized_f32_gemm_all_formats() {
    // The fixed-point GEMM approximates the dequantize-then-f32-GEMM
    // simulated path up to f32 summation noise — the bridge between the
    // serving path and the paper's accuracy-table semantics, now for
    // every format.
    use hif4::tensor::gemm;
    let mut rng = Rng::seed(7008);
    for kind in QuantKind::ALL {
        let a = Matrix::randn(5, 130, 1.0, &mut rng);
        let b = Matrix::randn(7, 130, 1.0, &mut rng);
        let qa = QuantizedMatrix::quantize(kind, &a, MODE);
        let qb = QuantizedMatrix::quantize(kind, &b, MODE);
        let via_pe = qa.qgemm_bt(&qb);
        let via_dequant = gemm::matmul_bt(&qa.dequantize(), &qb.dequantize());
        for (x, y) in via_pe.data.iter().zip(&via_dequant.data) {
            assert!((x - y).abs() <= 2e-3 * (1.0 + x.abs()), "{kind}: {x} vs {y}");
        }
    }
}

#[test]
fn generic_kernels_match_enum_surface() {
    // The free generic kernels and the enum-dispatched methods are the
    // same code; pin it so nothing drifts between the two entry styles.
    let mut rng = Rng::seed(7009);
    let a = Matrix::randn(3, 100, 1.0, &mut rng);
    let b = Matrix::randn(4, 100, 1.0, &mut rng);
    let qa = QuantMat::<Mxfp4Fmt>::quantize(&a, MODE);
    let qb = QuantMat::<Mxfp4Fmt>::quantize(&b, MODE);
    let pa = PackedQuantMat::pack(&qa);
    let pb = PackedQuantMat::pack(&qb);
    let generic_flow = qgemm_bt_flow_threads(&qa, &qb, 1);
    let generic_packed = qgemm_bt_packed_threads(&pa, &pb, 1);
    let generic_simd = qgemm_bt_simd_threads(&pa, &pb, 1);
    let ea = QuantizedMatrix::quantize(QuantKind::Mxfp4, &a, MODE);
    let eb = QuantizedMatrix::quantize(QuantKind::Mxfp4, &b, MODE);
    let enum_flow = ea.qgemm_bt_flow_threads(&eb, 1);
    let epa = ea.pack_threads(1);
    let epb = eb.pack_threads(1);
    let enum_packed = epa.qgemm_bt_packed_threads(&epb, 1);
    let enum_simd = epa.qgemm_bt_simd_threads(&epb, 1);
    assert!(feq32_all(&generic_flow.data, &enum_flow.data));
    assert!(feq32_all(&generic_packed.data, &enum_packed.data));
    assert!(feq32_all(&generic_simd.data, &enum_simd.data));
    assert!(feq32_all(&generic_flow.data, &generic_packed.data));
    assert!(feq32_all(&generic_flow.data, &generic_simd.data));
}
