//! Parallel/serial parity: every row-parallel kernel must produce
//! **bit-identical** output for any thread count. Each output row is
//! computed by exactly one thread with a fixed floating-point reduction
//! order, so `threads = 1` and `threads = N` must agree down to the last
//! bit — these tests pin that contract for the quantizers, the quantized
//! GEMMs (the flow kernel and both packed-plane backends — scalar and
//! the SIMD-tiled microkernel — plus the pack and dequantize stages)
//! across **all five block formats** of the unified `QuantizedMatrix`
//! API, the f32 GEMMs and the GPTQ pipeline.

use hif4::dotprod::QuantizedMatrix;
use hif4::formats::rounding::RoundMode;
use hif4::formats::QuantKind;
use hif4::quant::gptq::{gptq_quantize_with_hessian_threads, hessian_threads, GptqConfig};
use hif4::tensor::gemm::{matmul_bt_threads, matmul_naive, matmul_threads};
use hif4::tensor::{Matrix, Rng};

const MODE: RoundMode = RoundMode::NearestEven;
const THREAD_COUNTS: [usize; 4] = [2, 3, 4, 7];

/// Shapes exercising clean multiples, ragged tails of every group size
/// (64/32/16), sub-group K and more rows than any band count.
fn shapes() -> Vec<(usize, usize, usize)> {
    vec![(5, 130, 7), (16, 64, 16), (1, 200, 9), (23, 72, 11), (8, 40, 3)]
}

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn quantize_parity_all_formats() {
    let mut rng = Rng::seed(9001);
    for kind in QuantKind::ALL {
        for (m, k, _) in shapes() {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let serial = QuantizedMatrix::quantize_threads(kind, &a, MODE, 1);
            let sd = serial.dequantize_threads(1);
            for t in THREAD_COUNTS {
                let par = QuantizedMatrix::quantize_threads(kind, &a, MODE, t);
                // Group storage equality, observed through the decode
                // (the group types don't all expose PartialEq uniformly).
                assert_eq!(sd.data, par.dequantize_threads(1).data, "{kind} {m}x{k} threads={t}");
            }
        }
    }
}

#[test]
fn qgemm_parity_bit_identical_all_formats() {
    let mut rng = Rng::seed(9003);
    for kind in QuantKind::ALL {
        for (m, k, n) in shapes() {
            let ma = Matrix::randn(m, k, 1.0, &mut rng);
            let mb = Matrix::randn(n, k, 1.0, &mut rng);
            let a = QuantizedMatrix::quantize_threads(kind, &ma, MODE, 1);
            let b = QuantizedMatrix::quantize_threads(kind, &mb, MODE, 1);
            let serial = a.qgemm_bt_threads(&b, 1);
            for t in THREAD_COUNTS {
                let par = a.qgemm_bt_threads(&b, t);
                assert_eq!(bits(&serial), bits(&par), "{kind} {m}x{k}x{n} threads={t}");
            }
        }
    }
}

#[test]
fn packed_gemm_parity_bit_identical_all_formats() {
    // The packed fast path holds the same any-thread-count contract as
    // the flow kernels — for the GEMM *and* for packing itself.
    let mut rng = Rng::seed(9008);
    for kind in QuantKind::ALL {
        for (m, k, n) in shapes() {
            let ma = Matrix::randn(m, k, 1.0, &mut rng);
            let mb = Matrix::randn(n, k, 1.0, &mut rng);
            let qa = QuantizedMatrix::quantize_threads(kind, &ma, MODE, 1);
            let qb = QuantizedMatrix::quantize_threads(kind, &mb, MODE, 1);
            let pa = qa.pack_threads(1);
            let pb = qb.pack_threads(1);
            let serial = pa.qgemm_bt_packed_threads(&pb, 1);
            // The serial packed kernel equals the serial flow kernel exactly.
            assert_eq!(
                bits(&serial),
                bits(&qa.qgemm_bt_flow_threads(&qb, 1)),
                "{kind} {m}x{k}x{n} packed vs flow"
            );
            for t in THREAD_COUNTS {
                let pa_t = qa.pack_threads(t);
                let par = pa_t.qgemm_bt_packed_threads(&pb, t);
                assert_eq!(bits(&serial), bits(&par), "{kind} {m}x{k}x{n} threads={t}");
            }
        }
    }
}

#[test]
fn simd_gemm_parity_bit_identical_all_formats() {
    // The SIMD-tiled microkernel holds the identical contract: any
    // thread count, bit-identical — to itself, to the scalar packed
    // kernel, and (transitively) to the flow. Register tiling changes
    // which output elements share a pass, never the per-element
    // floating-point sequence.
    let mut rng = Rng::seed(9011);
    for kind in QuantKind::ALL {
        for (m, k, n) in shapes() {
            let ma = Matrix::randn(m, k, 1.0, &mut rng);
            let mb = Matrix::randn(n, k, 1.0, &mut rng);
            let qa = QuantizedMatrix::quantize_threads(kind, &ma, MODE, 1);
            let qb = QuantizedMatrix::quantize_threads(kind, &mb, MODE, 1);
            let pa = qa.pack_threads(1);
            let pb = qb.pack_threads(1);
            let serial = pa.qgemm_bt_simd_threads(&pb, 1);
            assert_eq!(
                bits(&serial),
                bits(&pa.qgemm_bt_packed_threads(&pb, 1)),
                "{kind} {m}x{k}x{n} simd vs packed"
            );
            for t in THREAD_COUNTS {
                let par = pa.qgemm_bt_simd_threads(&pb, t);
                assert_eq!(bits(&serial), bits(&par), "{kind} {m}x{k}x{n} threads={t}");
            }
        }
    }
}

#[test]
fn dequantize_parity_bit_identical_all_formats() {
    let mut rng = Rng::seed(9010);
    for kind in QuantKind::ALL {
        for (m, k, _) in shapes() {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let q = QuantizedMatrix::quantize_threads(kind, &a, MODE, 1);
            let d = q.dequantize_threads(1);
            for t in THREAD_COUNTS {
                assert_eq!(d.data, q.dequantize_threads(t).data, "{kind} {m}x{k} threads={t}");
            }
        }
    }
}

#[test]
fn f32_gemm_parity_bit_identical() {
    let mut rng = Rng::seed(9005);
    for (m, k, n) in shapes() {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let serial = matmul_threads(&a, &b, 1);
        let serial_bt = matmul_bt_threads(&a, &bt, 1);
        for t in THREAD_COUNTS {
            assert_eq!(serial.data, matmul_threads(&a, &b, t).data, "matmul {m}x{k}x{n} t={t}");
            assert_eq!(
                serial_bt.data,
                matmul_bt_threads(&a, &bt, t).data,
                "matmul_bt {m}x{k}x{n} t={t}"
            );
        }
        // And the parallel kernel still computes a correct product.
        let oracle = matmul_naive(&a, &b);
        for (x, y) in serial.data.iter().zip(&oracle.data) {
            assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }
}

#[test]
fn gptq_parity_bit_identical() {
    let mut rng = Rng::seed(9006);
    for fmt in [QuantKind::HiF4, QuantKind::Nvfp4] {
        let (out_f, in_f, samples) = (12, 96, 48);
        let w = Matrix::randn(out_f, in_f, 0.05, &mut rng);
        let x = Matrix::randn(samples, in_f, 1.0, &mut rng);
        let h_serial = hessian_threads(&x, 1);
        for t in THREAD_COUNTS {
            let h_par = hessian_threads(&x, t);
            assert_eq!(
                h_serial.iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
                h_par.iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
                "hessian threads={t}"
            );
        }
        let cfg = GptqConfig { format: fmt, mode: MODE, pts: false };
        let serial = gptq_quantize_with_hessian_threads(&w, &h_serial, &cfg, 1);
        for t in THREAD_COUNTS {
            let par = gptq_quantize_with_hessian_threads(&w, &h_serial, &cfg, t);
            assert_eq!(
                serial.weights.data.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                par.weights.data.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                "{fmt:?} weights threads={t}"
            );
            assert_eq!(
                serial.proxy_loss.to_bits(),
                par.proxy_loss.to_bits(),
                "{fmt:?} proxy loss threads={t}"
            );
        }
    }
}

#[test]
fn default_entry_points_match_explicit_serial() {
    // The knob-driven wrappers (whatever the ambient thread count) must
    // agree exactly with the explicit serial kernels.
    let mut rng = Rng::seed(9007);
    let a = Matrix::randn(33, 130, 1.0, &mut rng);
    let b = Matrix::randn(17, 130, 1.0, &mut rng);
    for kind in QuantKind::ALL {
        let qa = QuantizedMatrix::quantize(kind, &a, MODE);
        let qb = QuantizedMatrix::quantize(kind, &b, MODE);
        let qa1 = QuantizedMatrix::quantize_threads(kind, &a, MODE, 1);
        let qb1 = QuantizedMatrix::quantize_threads(kind, &b, MODE, 1);
        let c = qa.qgemm_bt(&qb);
        let c1 = qa1.qgemm_bt_threads(&qb1, 1);
        assert_eq!(c.data, c1.data, "{kind}");
    }
}
