//! Parallel/serial parity: every row-parallel kernel must produce
//! **bit-identical** output for any thread count. Each output row is
//! computed by exactly one thread with a fixed floating-point reduction
//! order, so `threads = 1` and `threads = N` must agree down to the last
//! bit — these tests pin that contract for the quantizers, the quantized
//! GEMMs (both the flow and the packed-plane kernel backends, plus the
//! pack and dequantize stages), the f32 GEMMs and the GPTQ pipeline.

use hif4::dotprod::packed::{
    hif4_gemm_bt_packed_threads, nvfp4_gemm_bt_packed_threads, PackedHiF4Matrix,
    PackedNvfp4Matrix,
};
use hif4::dotprod::qgemm::{
    hif4_gemm_bt_flow_threads, hif4_gemm_bt_threads, nvfp4_gemm_bt_flow_threads,
    nvfp4_gemm_bt_threads, HiF4Matrix, Nvfp4Matrix,
};
use hif4::formats::rounding::RoundMode;
use hif4::quant::gptq::{gptq_quantize_with_hessian_threads, hessian_threads, GptqConfig};
use hif4::tensor::gemm::{matmul_bt_threads, matmul_naive, matmul_threads};
use hif4::tensor::{Matrix, Rng};

const MODE: RoundMode = RoundMode::NearestEven;
const THREAD_COUNTS: [usize; 4] = [2, 3, 4, 7];

/// Shapes exercising clean multiples, ragged tails of both group sizes
/// (64 and 16), sub-unit K and more rows than any band count.
fn shapes() -> Vec<(usize, usize, usize)> {
    vec![(5, 130, 7), (16, 64, 16), (1, 200, 9), (23, 72, 11), (8, 40, 3)]
}

#[test]
fn hif4_quantize_parity() {
    let mut rng = Rng::seed(9001);
    for (m, k, _) in shapes() {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let serial = HiF4Matrix::quantize_threads(&a, MODE, 1);
        for t in THREAD_COUNTS {
            let par = HiF4Matrix::quantize_threads(&a, MODE, t);
            assert_eq!(serial.units, par.units, "{m}x{k} threads={t}");
            assert_eq!(serial.units_per_row, par.units_per_row);
        }
    }
}

#[test]
fn nvfp4_quantize_parity() {
    let mut rng = Rng::seed(9002);
    for (m, k, _) in shapes() {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let serial = Nvfp4Matrix::quantize_threads(&a, MODE, 1);
        for t in THREAD_COUNTS {
            let par = Nvfp4Matrix::quantize_threads(&a, MODE, t);
            assert_eq!(serial.groups, par.groups, "{m}x{k} threads={t}");
        }
    }
}

#[test]
fn hif4_qgemm_parity_bit_identical() {
    let mut rng = Rng::seed(9003);
    for (m, k, n) in shapes() {
        let a = HiF4Matrix::quantize_threads(&Matrix::randn(m, k, 1.0, &mut rng), MODE, 1);
        let b = HiF4Matrix::quantize_threads(&Matrix::randn(n, k, 1.0, &mut rng), MODE, 1);
        let serial = hif4_gemm_bt_threads(&a, &b, 1);
        for t in THREAD_COUNTS {
            let par = hif4_gemm_bt_threads(&a, &b, t);
            assert_eq!(
                serial.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                par.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "{m}x{k}x{n} threads={t}"
            );
        }
    }
}

#[test]
fn nvfp4_qgemm_parity_bit_identical() {
    let mut rng = Rng::seed(9004);
    for (m, k, n) in shapes() {
        let a = Nvfp4Matrix::quantize_threads(&Matrix::randn(m, k, 1.0, &mut rng), MODE, 1);
        let b = Nvfp4Matrix::quantize_threads(&Matrix::randn(n, k, 1.0, &mut rng), MODE, 1);
        let serial = nvfp4_gemm_bt_threads(&a, &b, 1);
        for t in THREAD_COUNTS {
            let par = nvfp4_gemm_bt_threads(&a, &b, t);
            assert_eq!(
                serial.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                par.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "{m}x{k}x{n} threads={t}"
            );
        }
    }
}

#[test]
fn hif4_packed_gemm_parity_bit_identical() {
    // The packed fast path holds the same any-thread-count contract as
    // the flow kernels — for the GEMM *and* for packing itself.
    let mut rng = Rng::seed(9008);
    for (m, k, n) in shapes() {
        let qa = HiF4Matrix::quantize_threads(&Matrix::randn(m, k, 1.0, &mut rng), MODE, 1);
        let qb = HiF4Matrix::quantize_threads(&Matrix::randn(n, k, 1.0, &mut rng), MODE, 1);
        let pa = PackedHiF4Matrix::pack_threads(&qa, 1);
        let pb = PackedHiF4Matrix::pack_threads(&qb, 1);
        let serial = hif4_gemm_bt_packed_threads(&pa, &pb, 1);
        // The serial packed kernel equals the serial flow kernel exactly.
        assert_eq!(
            serial.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            hif4_gemm_bt_flow_threads(&qa, &qb, 1)
                .data
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<u32>>(),
            "{m}x{k}x{n} packed vs flow"
        );
        for t in THREAD_COUNTS {
            let pa_t = PackedHiF4Matrix::pack_threads(&qa, t);
            let par = hif4_gemm_bt_packed_threads(&pa_t, &pb, t);
            assert_eq!(
                serial.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                par.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "{m}x{k}x{n} threads={t}"
            );
        }
    }
}

#[test]
fn nvfp4_packed_gemm_parity_bit_identical() {
    let mut rng = Rng::seed(9009);
    for (m, k, n) in shapes() {
        let qa = Nvfp4Matrix::quantize_threads(&Matrix::randn(m, k, 1.0, &mut rng), MODE, 1);
        let qb = Nvfp4Matrix::quantize_threads(&Matrix::randn(n, k, 1.0, &mut rng), MODE, 1);
        let pa = PackedNvfp4Matrix::pack_threads(&qa, 1);
        let pb = PackedNvfp4Matrix::pack_threads(&qb, 1);
        let serial = nvfp4_gemm_bt_packed_threads(&pa, &pb, 1);
        assert_eq!(
            serial.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            nvfp4_gemm_bt_flow_threads(&qa, &qb, 1)
                .data
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<u32>>(),
            "{m}x{k}x{n} packed vs flow"
        );
        for t in THREAD_COUNTS {
            let par = nvfp4_gemm_bt_packed_threads(&pa, &pb, t);
            assert_eq!(
                serial.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                par.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "{m}x{k}x{n} threads={t}"
            );
        }
    }
}

#[test]
fn dequantize_parity_bit_identical() {
    let mut rng = Rng::seed(9010);
    for (m, k, _) in shapes() {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let qh = HiF4Matrix::quantize_threads(&a, MODE, 1);
        let qn = Nvfp4Matrix::quantize_threads(&a, MODE, 1);
        let dh = qh.dequantize_threads(1);
        let dn = qn.dequantize_threads(1);
        for t in THREAD_COUNTS {
            assert_eq!(dh.data, qh.dequantize_threads(t).data, "hif4 {m}x{k} threads={t}");
            assert_eq!(dn.data, qn.dequantize_threads(t).data, "nvfp4 {m}x{k} threads={t}");
        }
    }
}

#[test]
fn f32_gemm_parity_bit_identical() {
    let mut rng = Rng::seed(9005);
    for (m, k, n) in shapes() {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let serial = matmul_threads(&a, &b, 1);
        let serial_bt = matmul_bt_threads(&a, &bt, 1);
        for t in THREAD_COUNTS {
            assert_eq!(serial.data, matmul_threads(&a, &b, t).data, "matmul {m}x{k}x{n} t={t}");
            assert_eq!(
                serial_bt.data,
                matmul_bt_threads(&a, &bt, t).data,
                "matmul_bt {m}x{k}x{n} t={t}"
            );
        }
        // And the parallel kernel still computes a correct product.
        let oracle = matmul_naive(&a, &b);
        for (x, y) in serial.data.iter().zip(&oracle.data) {
            assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }
}

#[test]
fn gptq_parity_bit_identical() {
    let mut rng = Rng::seed(9006);
    for fmt in [hif4::formats::Format::HiF4, hif4::formats::Format::Nvfp4] {
        let (out_f, in_f, samples) = (12, 96, 48);
        let w = Matrix::randn(out_f, in_f, 0.05, &mut rng);
        let x = Matrix::randn(samples, in_f, 1.0, &mut rng);
        let h_serial = hessian_threads(&x, 1);
        for t in THREAD_COUNTS {
            let h_par = hessian_threads(&x, t);
            assert_eq!(
                h_serial.iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
                h_par.iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
                "hessian threads={t}"
            );
        }
        let cfg = GptqConfig { format: fmt, mode: MODE, pts: false };
        let serial = gptq_quantize_with_hessian_threads(&w, &h_serial, &cfg, 1);
        for t in THREAD_COUNTS {
            let par = gptq_quantize_with_hessian_threads(&w, &h_serial, &cfg, t);
            assert_eq!(
                serial.weights.data.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                par.weights.data.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                "{fmt:?} weights threads={t}"
            );
            assert_eq!(
                serial.proxy_loss.to_bits(),
                par.proxy_loss.to_bits(),
                "{fmt:?} proxy loss threads={t}"
            );
        }
    }
}

#[test]
fn default_entry_points_match_explicit_serial() {
    // The knob-driven wrappers (whatever the ambient thread count) must
    // agree exactly with the explicit serial kernels.
    let mut rng = Rng::seed(9007);
    let a = Matrix::randn(33, 130, 1.0, &mut rng);
    let b = Matrix::randn(17, 130, 1.0, &mut rng);
    let qa = HiF4Matrix::quantize(&a, MODE);
    let qb = HiF4Matrix::quantize(&b, MODE);
    let qa1 = HiF4Matrix::quantize_threads(&a, MODE, 1);
    let qb1 = HiF4Matrix::quantize_threads(&b, MODE, 1);
    assert_eq!(qa.units, qa1.units);
    let c = hif4::dotprod::qgemm::hif4_gemm_bt(&qa, &qb);
    let c1 = hif4_gemm_bt_threads(&qa1, &qb1, 1);
    assert_eq!(c.data, c1.data);
}
