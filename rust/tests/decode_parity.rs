//! Decode parity: greedy generation through the KV-cached incremental
//! path must be **token-identical** to the full-recompute reference —
//! for the f32 cache (where the logits are bit-identical too), for the
//! HiF4 cache (against the full recompute that applies the same KV
//! codec via `QuantPolicy::kv`), across the model zoo's architecture
//! coverage, with prepacked fixed-point linears, and for any thread
//! count.

use hif4::formats::QuantKind;
use hif4::model::kv::{KvCache, KvCacheType};
use hif4::model::transformer::{CachedSeq, QuantPolicy, Transformer};
use hif4::model::zoo;
use hif4::tensor::Matrix;
use hif4::util::threadpool;

const N_NEW: usize = 10;

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

fn prompt(vocab: usize, n: usize, salt: usize) -> Vec<usize> {
    (0..n).map(|i| 1 + (i * 13 + salt * 7) % (vocab - 1)).collect()
}

/// Four zoo configs spanning MHA, GQA, wide-FFN GQA and MLA+MoE.
fn models() -> Vec<Transformer> {
    [zoo::llama2_tiny(), zoo::llama3_tiny(), zoo::qwen_tiny(), zoo::deepseek_tiny()]
        .into_iter()
        .enumerate()
        .map(|(i, cfg)| Transformer::init(cfg, 400 + i as u64))
        .collect()
}

#[test]
fn f32_cached_prefill_is_bitwise_identical_to_full_forward() {
    for (mi, m) in models().iter().enumerate() {
        let p = prompt(m.cfg.vocab, 12, mi);
        let full = m.forward(&[p.clone()], None, None, None);
        let mut cache = KvCache::new(&m.cfg, KvCacheType::F32);
        let cached = {
            let mut seqs = [CachedSeq { tokens: &p, cache: &mut cache }];
            m.forward_cached(&mut seqs)
        };
        assert_eq!(bits(&full), bits(&cached), "{}", m.cfg.name);
    }
}

#[test]
fn hif4_cached_prefill_matches_kv_codec_reference_bitwise() {
    let policy = QuantPolicy { act: None, kv: Some(KvCacheType::HIF4) };
    for (mi, m) in models().iter().enumerate() {
        let p = prompt(m.cfg.vocab, 12, mi);
        let reference = m.forward(&[p.clone()], Some(&policy), None, None);
        let mut cache = KvCache::new(&m.cfg, KvCacheType::HIF4);
        let cached = {
            let mut seqs = [CachedSeq { tokens: &p, cache: &mut cache }];
            m.forward_cached(&mut seqs)
        };
        assert_eq!(bits(&reference), bits(&cached), "{}", m.cfg.name);
    }
}

#[test]
fn greedy_decode_is_token_identical_to_full_recompute_f32() {
    for (mi, m) in models().iter().enumerate() {
        let p = prompt(m.cfg.vocab, 8, mi);
        let cached = m.generate_greedy(&p, N_NEW, KvCacheType::F32);
        let full = m.generate_greedy_full_recompute(&p, N_NEW, KvCacheType::F32);
        assert_eq!(cached, full, "{}", m.cfg.name);
    }
}

#[test]
fn greedy_decode_is_token_identical_to_full_recompute_all_quant_kinds() {
    // Every block format's KV codec holds the cached-vs-recompute
    // contract — the reference applies the same store encode/decode via
    // QuantPolicy::kv, so parity is by construction, pinned here.
    for (mi, m) in models().iter().enumerate() {
        let p = prompt(m.cfg.vocab, 8, mi);
        for kind in QuantKind::ALL.map(KvCacheType::Quant) {
            let cached = m.generate_greedy(&p, N_NEW, kind);
            let full = m.generate_greedy_full_recompute(&p, N_NEW, kind);
            assert_eq!(cached, full, "{} {kind:?}", m.cfg.name);
        }
    }
}

#[test]
fn greedy_decode_parity_survives_prepacked_fixed_point_linears() {
    // The serving configuration: real-quantized weights (decode-once
    // planes, fixed-point QGEMM) under both cache kinds.
    for (mi, mut m) in models().into_iter().enumerate() {
        m.prepack_quantized_weights(QuantKind::HiF4);
        let p = prompt(m.cfg.vocab, 8, mi);
        for kind in [KvCacheType::F32, KvCacheType::HIF4] {
            let cached = m.generate_greedy(&p, N_NEW, kind);
            let full = m.generate_greedy_full_recompute(&p, N_NEW, kind);
            assert_eq!(cached, full, "{} {kind:?}", m.cfg.name);
        }
    }
}

#[test]
fn greedy_decode_parity_holds_for_any_thread_count() {
    // The cached forward inherits the kernels' any-thread-count
    // determinism contract, so flipping the process knob mid-suite is
    // safe (results are invariant by construction) and this test needs
    // no serialization against the others.
    let m = Transformer::init(zoo::llama3_tiny(), 404);
    let p = prompt(m.cfg.vocab, 8, 0);
    let before = threadpool::threads();
    let mut results: Vec<(Vec<usize>, Vec<usize>)> = Vec::new();
    for t in [1usize, 2, 5] {
        threadpool::set_threads(t);
        results.push((
            m.generate_greedy(&p, N_NEW, KvCacheType::F32),
            m.generate_greedy(&p, N_NEW, KvCacheType::HIF4),
        ));
    }
    threadpool::set_threads(before);
    for (f, h) in &results[1..] {
        assert_eq!(f, &results[0].0, "f32 decode drifted across thread counts");
        assert_eq!(h, &results[0].1, "HiF4 decode drifted across thread counts");
    }
}

#[test]
fn hif4_cache_page_is_smaller_than_f32() {
    let m = Transformer::init(zoo::llama3_tiny(), 405);
    let p = prompt(m.cfg.vocab, 16, 1);
    let mut f32c = KvCache::new(&m.cfg, KvCacheType::F32);
    let mut hc = KvCache::new(&m.cfg, KvCacheType::HIF4);
    for cache in [&mut f32c, &mut hc] {
        let mut seqs = [CachedSeq { tokens: &p, cache }];
        m.forward_cached(&mut seqs);
    }
    assert_eq!(f32c.len(), p.len());
    assert_eq!(hc.len(), p.len());
    assert!(
        hc.resident_bytes() < f32c.resident_bytes(),
        "HiF4 planes ({}) must beat f32 ({}) resident",
        hc.resident_bytes(),
        f32c.resident_bytes()
    );
    assert!(
        hc.wire_bytes() * 2 < f32c.wire_bytes(),
        "the 4.5-bit unit wire form ({}) must be far below f32 ({})",
        hc.wire_bytes(),
        f32c.wire_bytes()
    );
}
