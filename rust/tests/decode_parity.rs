//! Decode parity: greedy generation through the KV-cached incremental
//! path must be **token-identical** to the full-recompute reference —
//! for the f32 cache (where the logits are bit-identical too), for the
//! HiF4 cache (against the full recompute that applies the same KV
//! codec via `QuantPolicy::kv`), across the model zoo's architecture
//! coverage, with prepacked fixed-point linears, and for any thread
//! count.
//!
//! The fused tiled-attention schedule rides the same suite: fused greedy
//! tokens must equal replay's for every block format and zoo config, the
//! fused logits must sit inside the DESIGN.md §14 tolerance envelope,
//! and the fused result must be bitwise invariant to the tile height.
//! The whole file also runs under CI's `HIF4_ATTN=fused` matrix leg, so
//! the knob-dispatching tests above exercise both schedules end to end.

use hif4::formats::QuantKind;
use hif4::model::attention::{attn_path, attn_tile_rows, set_attn_tile_rows, AttnPath};
use hif4::model::kv::{KvCache, KvCacheType};
use hif4::model::transformer::{greedy_from_row, CachedSeq, QuantPolicy, Transformer};
use hif4::model::zoo;
use hif4::tensor::Matrix;
use hif4::util::threadpool;

const N_NEW: usize = 10;

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

fn prompt(vocab: usize, n: usize, salt: usize) -> Vec<usize> {
    (0..n).map(|i| 1 + (i * 13 + salt * 7) % (vocab - 1)).collect()
}

/// Four zoo configs spanning MHA, GQA, wide-FFN GQA and MLA+MoE.
fn models() -> Vec<Transformer> {
    [zoo::llama2_tiny(), zoo::llama3_tiny(), zoo::qwen_tiny(), zoo::deepseek_tiny()]
        .into_iter()
        .enumerate()
        .map(|(i, cfg)| Transformer::init(cfg, 400 + i as u64))
        .collect()
}

#[test]
fn f32_cached_prefill_is_bitwise_identical_to_full_forward() {
    for (mi, m) in models().iter().enumerate() {
        let p = prompt(m.cfg.vocab, 12, mi);
        let full = m.forward(&[p.clone()], None, None, None);
        let mut cache = KvCache::new(&m.cfg, KvCacheType::F32);
        let cached = {
            let mut seqs = [CachedSeq { tokens: &p, cache: &mut cache }];
            m.forward_cached(&mut seqs)
        };
        assert_eq!(bits(&full), bits(&cached), "{}", m.cfg.name);
    }
}

#[test]
fn hif4_cached_prefill_matches_kv_codec_reference_bitwise() {
    // Bitwise equality against the QuantPolicy::kv recompute is a
    // replay-schedule contract (the fused path is tolerance-bounded, not
    // bit-exact — DESIGN.md §14), so this pins the replay path explicitly
    // rather than dispatching through the process-wide attention knob.
    let policy = QuantPolicy { act: None, kv: Some(KvCacheType::HIF4) };
    for (mi, m) in models().iter().enumerate() {
        let p = prompt(m.cfg.vocab, 12, mi);
        let reference = m.forward(&[p.clone()], Some(&policy), None, None);
        let mut cache = KvCache::new(&m.cfg, KvCacheType::HIF4);
        let cached = {
            let mut seqs = [CachedSeq { tokens: &p, cache: &mut cache }];
            m.forward_cached_with(&mut seqs, AttnPath::Replay)
        };
        assert_eq!(bits(&reference), bits(&cached), "{}", m.cfg.name);
    }
}

#[test]
fn greedy_decode_is_token_identical_to_full_recompute_f32() {
    for (mi, m) in models().iter().enumerate() {
        let p = prompt(m.cfg.vocab, 8, mi);
        let cached = m.generate_greedy(&p, N_NEW, KvCacheType::F32);
        let full = m.generate_greedy_full_recompute(&p, N_NEW, KvCacheType::F32);
        assert_eq!(cached, full, "{}", m.cfg.name);
    }
}

#[test]
fn greedy_decode_is_token_identical_to_full_recompute_all_quant_kinds() {
    // Every block format's KV codec holds the cached-vs-recompute
    // contract — the reference applies the same store encode/decode via
    // QuantPolicy::kv, so parity is by construction, pinned here.
    for (mi, m) in models().iter().enumerate() {
        let p = prompt(m.cfg.vocab, 8, mi);
        for kind in QuantKind::ALL.map(KvCacheType::Quant) {
            let cached = m.generate_greedy(&p, N_NEW, kind);
            let full = m.generate_greedy_full_recompute(&p, N_NEW, kind);
            assert_eq!(cached, full, "{} {kind:?}", m.cfg.name);
        }
    }
}

#[test]
fn greedy_decode_parity_survives_prepacked_fixed_point_linears() {
    // The serving configuration: real-quantized weights (decode-once
    // planes, fixed-point QGEMM) under both cache kinds.
    for (mi, mut m) in models().into_iter().enumerate() {
        m.prepack_quantized_weights(QuantKind::HiF4);
        let p = prompt(m.cfg.vocab, 8, mi);
        for kind in [KvCacheType::F32, KvCacheType::HIF4] {
            let cached = m.generate_greedy(&p, N_NEW, kind);
            let full = m.generate_greedy_full_recompute(&p, N_NEW, kind);
            assert_eq!(cached, full, "{} {kind:?}", m.cfg.name);
        }
    }
}

#[test]
fn greedy_decode_parity_holds_for_any_thread_count() {
    // The cached forward inherits the kernels' any-thread-count
    // determinism contract, so flipping the process knob mid-suite is
    // safe (results are invariant by construction) and this test needs
    // no serialization against the others.
    let m = Transformer::init(zoo::llama3_tiny(), 404);
    let p = prompt(m.cfg.vocab, 8, 0);
    let before = threadpool::threads();
    let mut results: Vec<(Vec<usize>, Vec<usize>, Vec<usize>)> = Vec::new();
    for t in [1usize, 2, 5] {
        threadpool::set_threads(t);
        results.push((
            m.generate_greedy(&p, N_NEW, KvCacheType::F32),
            m.generate_greedy(&p, N_NEW, KvCacheType::HIF4),
            m.generate_greedy_with(&p, N_NEW, KvCacheType::HIF4, AttnPath::Fused),
        ));
    }
    threadpool::set_threads(before);
    for (f, h, fu) in &results[1..] {
        assert_eq!(f, &results[0].0, "f32 decode drifted across thread counts");
        assert_eq!(h, &results[0].1, "HiF4 decode drifted across thread counts");
        assert_eq!(fu, &results[0].2, "fused HiF4 decode drifted across thread counts");
    }
}

#[test]
fn fused_greedy_tokens_are_identical_to_replay_for_every_format_and_model() {
    // The ISSUE's acceptance bar: the fused tiled-attention schedule and
    // the replay schedule decode the *same greedy tokens* for all five
    // block formats across the zoo's architecture coverage. The logits
    // differ in low bits (fused quantizes Q to 8-bit groups and
    // reassociates the softmax online); the argmax must not.
    for (mi, m) in models().iter().enumerate() {
        let p = prompt(m.cfg.vocab, 8, mi);
        for kind in QuantKind::ALL.map(KvCacheType::Quant) {
            let fused = m.generate_greedy_with(&p, N_NEW, kind, AttnPath::Fused);
            let replay = m.generate_greedy_with(&p, N_NEW, kind, AttnPath::Replay);
            assert_eq!(fused, replay, "{} {kind:?}", m.cfg.name);
        }
    }
}

#[test]
fn fused_prefill_logits_stay_inside_the_replay_tolerance_envelope() {
    // DESIGN.md §14: |fused − replay| ≤ 5e-2 · (1 + |replay|) per logit.
    // Checked for every format on the GQA config (heads sharing a KV
    // head share lane groups — the case the fused Q-masking has to get
    // right). The final row — the one greedy decode actually reads —
    // must also agree on its argmax; the token-identity contract for
    // full generations is pinned by the greedy tests above.
    let m = Transformer::init(zoo::llama3_tiny(), 410);
    let p = prompt(m.cfg.vocab, 12, 3);
    for kind in QuantKind::ALL.map(KvCacheType::Quant) {
        let run = |path: AttnPath| {
            let mut cache = KvCache::new(&m.cfg, kind);
            let mut seqs = [CachedSeq { tokens: &p, cache: &mut cache }];
            m.forward_cached_with(&mut seqs, path)
        };
        let fused = run(AttnPath::Fused);
        let replay = run(AttnPath::Replay);
        for r in 0..p.len() {
            for (a, b) in fused.row(r).iter().zip(replay.row(r)) {
                let tol = 5e-2 * (1.0 + b.abs());
                assert!((a - b).abs() <= tol, "{kind:?} row {r}: {a} vs {b} (tol {tol})");
            }
        }
        let last = p.len() - 1;
        assert_eq!(
            greedy_from_row(fused.row(last)).0,
            greedy_from_row(replay.row(last)).0,
            "{kind:?} final-row argmax diverged"
        );
    }
}

#[test]
fn fused_logits_are_bitwise_invariant_to_attention_tile_height() {
    // The fused path folds every visible position into the online-softmax
    // state one row at a time, so the f32 op sequence — and therefore the
    // logits, bit for bit — depends only on the position order, never on
    // where the tile boundaries fall. Mutating the process-wide tile knob
    // mid-suite is safe for the same reason: no other test's result
    // depends on the tile height.
    let m = Transformer::init(zoo::llama3_tiny(), 411);
    let p = prompt(m.cfg.vocab, 14, 5);
    let run = || {
        let mut cache = KvCache::new(&m.cfg, KvCacheType::HIF4);
        let mut seqs = [CachedSeq { tokens: &p, cache: &mut cache }];
        m.forward_cached_with(&mut seqs, AttnPath::Fused)
    };
    let before = attn_tile_rows();
    set_attn_tile_rows(64);
    let baseline = bits(&run());
    for tile in [16usize, 256, 1] {
        set_attn_tile_rows(tile);
        assert_eq!(bits(&run()), baseline, "tile height {tile} changed the fused logits");
    }
    set_attn_tile_rows(before);
}

#[test]
fn fused_single_token_tail_tile_matches_replay() {
    // Regression guard for the decode-step shape: one new token whose
    // visible context ends in a 1-row tail tile (prefill exactly one
    // tile, then decode — the tail tile holds only the just-appended
    // row). The greedy continuation must match replay's.
    let m = Transformer::init(zoo::llama3_tiny(), 412);
    let p = prompt(m.cfg.vocab, 8, 2);
    let before = attn_tile_rows();
    set_attn_tile_rows(8);
    let fused = m.generate_greedy_with(&p, 3, KvCacheType::HIF4, AttnPath::Fused);
    set_attn_tile_rows(before);
    let replay = m.generate_greedy_with(&p, 3, KvCacheType::HIF4, AttnPath::Replay);
    assert_eq!(fused, replay, "tail-tile decode diverged from replay");
}

#[test]
fn knob_dispatch_matches_the_explicit_path_apis() {
    // `generate_greedy` dispatches through the process-wide attention
    // knob; under CI's `HIF4_ATTN=fused` matrix leg this pins the fused
    // schedule end to end, under the default it pins replay-or-fused as
    // resolved. F32 caches must be knob-immune: the fused request
    // degrades to replay per sequence, bit for bit.
    let m = Transformer::init(zoo::llama3_tiny(), 413);
    let p = prompt(m.cfg.vocab, 8, 4);
    let knob = m.generate_greedy(&p, N_NEW, KvCacheType::HIF4);
    let explicit = m.generate_greedy_with(&p, N_NEW, KvCacheType::HIF4, attn_path());
    assert_eq!(knob, explicit, "knob dispatch must equal the explicit-path API");
    let run_f32 = |path: AttnPath| {
        let mut cache = KvCache::new(&m.cfg, KvCacheType::F32);
        let mut seqs = [CachedSeq { tokens: &p, cache: &mut cache }];
        m.forward_cached_with(&mut seqs, path)
    };
    assert_eq!(
        bits(&run_f32(AttnPath::Fused)),
        bits(&run_f32(AttnPath::Replay)),
        "f32 caches must replay bitwise regardless of the requested path"
    );
}

#[test]
fn hif4_cache_page_is_smaller_than_f32() {
    let m = Transformer::init(zoo::llama3_tiny(), 405);
    let p = prompt(m.cfg.vocab, 16, 1);
    let mut f32c = KvCache::new(&m.cfg, KvCacheType::F32);
    let mut hc = KvCache::new(&m.cfg, KvCacheType::HIF4);
    for cache in [&mut f32c, &mut hc] {
        let mut seqs = [CachedSeq { tokens: &p, cache }];
        m.forward_cached(&mut seqs);
    }
    assert_eq!(f32c.len(), p.len());
    assert_eq!(hc.len(), p.len());
    assert!(
        hc.resident_bytes() < f32c.resident_bytes(),
        "HiF4 planes ({}) must beat f32 ({}) resident",
        hc.resident_bytes(),
        f32c.resident_bytes()
    );
    assert!(
        hc.wire_bytes() * 2 < f32c.wire_bytes(),
        "the 4.5-bit unit wire form ({}) must be far below f32 ({})",
        hc.wire_bytes(),
        f32c.wire_bytes()
    );
}
