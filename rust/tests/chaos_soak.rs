//! Chaos soak: the serving tier under seeded fault injection. Workers
//! panic and stall on a deterministic schedule, chaos clients send
//! garbage frames and drop connections, invalid requests arrive mid-load
//! — and the acceptance contract holds: workers restart (never the
//! process), overload sheds with structured rejections, the server never
//! deadlocks, and every sequence that survives is token-identical to a
//! fault-free run (continuous-batching decode is bit-deterministic
//! regardless of batch composition, so a retry after a crash replays the
//! exact same tokens).

use hif4::model::kv::KvCacheType;
use hif4::model::transformer::Transformer;
use hif4::runtime::artifact::Manifest;
use hif4::runtime::native::transformer_from_store;
use hif4::server::batcher::BatchPolicy;
use hif4::server::faults::{quiet_injected_panics, ClientFault, FaultConfig, FaultPlan};
use hif4::server::protocol::{Request, Status};
use hif4::server::service::{Client, NativeServerConfig, ResilienceConfig, RetryPolicy, Server};
use std::io::Write as _;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Same 1-layer GQA+SwiGLU fixture as tests/native_serving.rs (d=32,
/// 4 heads × 8, kv 2, vocab 96, seq 16).
fn write_manifest(dir: &Path) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "batch 4\nseq 16\nvocab 96\nn_heads 4\nkv_heads 2\nhead_dim 8\nrope_base 10000\n\
         qdq 8 64\n\
         param embed 96 32\nparam head 96 32\nparam norm_f 32\n\
         param layer0.norm1 32\nparam layer0.norm2 32\n\
         param layer0.wq 32 32\nparam layer0.wk 16 32\nparam layer0.wv 16 32\n\
         param layer0.wo 32 32\n\
         param layer0.w1 64 32\nparam layer0.w2 32 64\nparam layer0.w3 64 32\n",
    )
    .unwrap();
}

fn start_server(
    tag: &str,
    workers: usize,
    max_batch: usize,
    resilience: ResilienceConfig,
) -> (Server, Arc<Transformer>) {
    let dir: PathBuf = std::env::temp_dir().join(format!("hif4_chaos_soak_{tag}"));
    write_manifest(&dir);
    let manifest = Manifest::load(&dir).unwrap();
    let store = manifest.init_params(31);
    let model = Arc::new(transformer_from_store(&manifest, &store).unwrap());
    let cfg = NativeServerConfig {
        policy: BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
        workers,
        seq: manifest.seq,
        kv: KvCacheType::F32,
        resilience,
        // Paging knobs from the environment: the CI chaos matrix runs
        // this soak with HIF4_PREFIX_CACHE both off and on.
        ..Default::default()
    };
    let server = Server::start_native(Arc::clone(&model), cfg, "127.0.0.1:0").unwrap();
    (server, model)
}

fn prompts() -> Vec<Vec<usize>> {
    (0..4).map(|s| (0..5).map(|i| 1 + (i * 13 + s * 31) % 90).collect()).collect()
}

#[test]
fn soak_with_panics_stalls_and_bad_clients_keeps_serving_deterministically() {
    quiet_injected_panics();
    // Worker chaos: ~3% of steps panic, ~5% stall 1ms, plus a guaranteed
    // panic when a worker reaches step 6 (so restarts happen on every
    // run, not just statistically). Client chaos: ~15% garbage frames,
    // ~10% dropped connections.
    let faults = Arc::new(FaultPlan::new(
        0xC0FFEE,
        FaultConfig {
            panic_per_mille: 30,
            stall_per_mille: 50,
            stall_ms: 1,
            panic_at_step: Some(6),
            garbage_per_mille: 150,
            disconnect_per_mille: 100,
        },
    ));
    let resilience = ResilienceConfig {
        max_queue: 64,
        kv_budget_bytes: 1 << 30,
        faults: Some(Arc::clone(&faults)),
        ..Default::default()
    };
    let (server, model) = start_server("soak", 2, 2, resilience);
    let prompts = prompts();
    let n_new = 4usize;
    let reference: Vec<Vec<usize>> =
        prompts.iter().map(|p| model.generate_greedy(p, n_new, KvCacheType::F32)).collect();

    // 6 chaos clients × 5 requests each, retrying through shed/crash.
    let (n_clients, per_client) = (6u64, 5u64);
    let addr = server.addr;
    let results: Vec<(usize, Vec<hif4::server::protocol::Response>, u32)> =
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_clients)
                .map(|c| {
                    let faults = Arc::clone(&faults);
                    let prompts = &prompts;
                    s.spawn(move || {
                        let mut client = Client::connect(addr).unwrap();
                        let policy = RetryPolicy {
                            max_retries: 12,
                            base: Duration::from_millis(2),
                            cap: Duration::from_millis(40),
                            seed: 0xC11E57 + c,
                        };
                        let mut out = Vec::new();
                        for i in 0..per_client {
                            // Client-side chaos on throwaway connections, so
                            // this client's own stream stays readable.
                            match faults.client_decide(c, i) {
                                Some(ClientFault::Garbage) => {
                                    if let Ok(mut raw) = TcpStream::connect(addr) {
                                        // Length prefix far past the 1MB frame
                                        // cap: unparseable by construction.
                                        let _ = raw.write_all(&(8u32 << 20).to_le_bytes());
                                        let _ = raw.write_all(b"chaos");
                                    }
                                }
                                Some(ClientFault::Disconnect) => {
                                    if let Ok(mut raw) = TcpStream::connect(addr) {
                                        // Half a frame, then hang up.
                                        let _ = raw.write_all(&[7u8, 0]);
                                    }
                                }
                                None => {}
                            }
                            let pi = ((c + i) % prompts.len() as u64) as usize;
                            let req = Request::generate(
                                c * 100 + i,
                                prompts[pi].clone(),
                                n_new as u16,
                            );
                            let (frames, retries) =
                                client.generate_retrying(&req, &policy).unwrap();
                            out.push((pi, frames, retries));
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });

    // Every request eventually completed, and every survivor's tokens are
    // exactly the fault-free greedy continuation.
    assert_eq!(results.len(), (n_clients * per_client) as usize);
    let mut total_retries = 0u64;
    for (pi, frames, retries) in &results {
        total_retries += *retries as u64;
        let last = frames.last().unwrap();
        assert_eq!(
            last.status,
            Status::Ok,
            "request on prompt {pi} must survive retries, ended {last:?}"
        );
        assert_eq!(frames.len(), n_new);
        let got: Vec<usize> = frames.iter().map(|r| r.token as usize).collect();
        assert_eq!(&got, &reference[*pi], "survivor tokens must match the fault-free run");
    }
    server.metrics.record_retries(total_retries);

    // The guaranteed step-6 panic means at least one supervised restart.
    let restarts = server.metrics.worker_restarts.load(Ordering::Relaxed);
    assert!(restarts >= 1, "panic_at_step must have tripped a restart");
    // Crashed attempts implied retries; shed may or may not have occurred
    // at this queue depth, but nothing may leak.
    assert_eq!(server.admission().kv_reserved(), 0, "terminal outcomes release reservations");
    assert_eq!(server.admission().queued(), 0);
    // The resilience counters surface in the operator summary.
    let summary = server.metrics.summary();
    assert!(summary.contains("restarts="), "{summary}");
    assert!(summary.contains(&format!("retries={total_retries}")), "{summary}");

    // And the server is still fully alive after the storm (the fault
    // plan stays active, so the probe retries like any chaos client).
    let mut probe = Client::connect(addr).unwrap();
    let policy = RetryPolicy { max_retries: 12, seed: 77, ..Default::default() };
    let (frames, _) = probe
        .generate_retrying(&Request::generate(9999, prompts[0].clone(), 2), &policy)
        .unwrap();
    assert_eq!(frames.last().unwrap().status, Status::Ok);
}

#[test]
fn queue_full_shed_is_structured_and_retries_eventually_complete() {
    quiet_injected_panics();
    // One worker, one slot, every step stalled 5ms, queue bounded at 1:
    // with one request decoding and one queued, further arrivals shed
    // with ShedQueueFull — and a retrying client gets through once the
    // backlog drains.
    let stall = FaultConfig { stall_per_mille: 1000, stall_ms: 5, ..Default::default() };
    let resilience = ResilienceConfig {
        max_queue: 1,
        faults: Some(Arc::new(FaultPlan::new(11, stall))),
        ..Default::default()
    };
    let (server, model) = start_server("queuefull", 1, 1, resilience);
    let prompt = vec![2usize, 4, 8, 16];
    let want = model.generate_greedy(&prompt, 10, KvCacheType::F32);

    let mut c1 = Client::connect(server.addr).unwrap();
    let mut c2 = Client::connect(server.addr).unwrap();
    let mut c3 = Client::connect(server.addr).unwrap();
    // c1 occupies the slot (10 tokens × ≥5ms/step), c2 occupies the one
    // queue seat, c3 must shed.
    c1.send(&Request::generate(1, prompt.clone(), 10)).unwrap();
    std::thread::sleep(Duration::from_millis(25));
    c2.send(&Request::generate(2, prompt.clone(), 10)).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    let shed = c3.generate(&Request::generate(3, prompt.clone(), 10)).unwrap();
    assert_eq!(shed.len(), 1, "shed answers one terminal frame");
    assert_eq!(shed[0].status, Status::ShedQueueFull);
    assert!(shed[0].status.retryable());

    // The retrying client eventually lands and decodes identically.
    let policy = RetryPolicy {
        max_retries: 30,
        base: Duration::from_millis(5),
        cap: Duration::from_millis(40),
        seed: 3,
    };
    let (frames, retries) = c3
        .generate_retrying(&Request::generate(4, prompt.clone(), 10), &policy)
        .unwrap();
    assert_eq!(frames.last().unwrap().status, Status::Ok, "after {retries} retries");
    let got: Vec<usize> = frames.iter().map(|r| r.token as usize).collect();
    assert_eq!(got, want, "post-shed retry matches the unloaded run");

    // The earlier admissions complete untouched by the shedding.
    for c in [&mut c1, &mut c2] {
        let frames = c.recv_stream().unwrap();
        assert_eq!(frames.last().unwrap().status, Status::Ok);
        let got: Vec<usize> = frames.iter().map(|r| r.token as usize).collect();
        assert_eq!(got, want);
    }

    let ord = Ordering::Relaxed;
    assert!(server.metrics.shed_queue_full.load(ord) >= 1);
    assert!(server.metrics.summary().contains("shed(queue="), "{}", server.metrics.summary());
    assert_eq!(server.admission().queued(), 0);
}

#[test]
fn malformed_and_oversized_requests_get_structured_errors_and_never_kill_the_server() {
    let (server, model) = start_server("malformed", 1, 2, ResilienceConfig::default());
    let prompt = vec![1usize, 3, 5];
    let want = model.generate_greedy(&prompt, 2, KvCacheType::F32);

    // Semantic failures answer Invalid and keep the connection usable.
    let mut client = Client::connect(server.addr).unwrap();
    let r = client.call(&Request::generate(1, prompt.clone(), 0)).unwrap();
    assert_eq!(r.status, Status::Invalid, "max_new == 0 must be rejected");
    assert!(!r.status.retryable(), "Invalid is the client's bug, not load");
    let r = client.call(&Request::generate(2, vec![1; 17], 2)).unwrap();
    assert_eq!(r.status, Status::Invalid, "over-context prompt (17 > seq 16) must be rejected");
    let frames = client.generate(&Request::generate(3, prompt.clone(), 2)).unwrap();
    assert_eq!(frames.last().unwrap().status, Status::Ok, "same connection still serves");
    let got: Vec<usize> = frames.iter().map(|r| r.token as usize).collect();
    assert_eq!(got, want);
    assert_eq!(server.metrics.rejected_invalid.load(Ordering::Relaxed), 2);
    assert!(server.metrics.summary().contains("invalid=2"), "{}", server.metrics.summary());

    // Framing failures (oversized length prefix, truncated frame) close
    // that connection — there is no way to resync — but never the server.
    let mut raw = TcpStream::connect(server.addr).unwrap();
    raw.write_all(&(8u32 << 20).to_le_bytes()).unwrap(); // 8MB ≫ 1MB cap
    raw.write_all(b"oversized").unwrap();
    drop(raw);
    let mut raw = TcpStream::connect(server.addr).unwrap();
    raw.write_all(&[12u8, 0]).unwrap(); // half a length prefix, then EOF
    drop(raw);

    let mut probe = Client::connect(server.addr).unwrap();
    let frames = probe.generate(&Request::generate(4, prompt, 2)).unwrap();
    assert_eq!(frames.last().unwrap().status, Status::Ok);
    let got: Vec<usize> = frames.iter().map(|r| r.token as usize).collect();
    assert_eq!(got, want, "the server survives framing garbage bit-identically");
}
