//! Continuous batching end to end: mid-flight admission into in-flight
//! decode batches, eviction on completion, and output that is
//! deterministic regardless of arrival order — at the [`DecodeEngine`]
//! level and through the full native server (listener → slot map →
//! streamed responses).

use hif4::formats::QuantKind;
use hif4::model::kv::KvCacheType;
use hif4::model::transformer::Transformer;
use hif4::model::zoo;
use hif4::runtime::artifact::Manifest;
use hif4::runtime::native::{transformer_from_store, DecodeEngine, DecodeStream};
use hif4::server::batcher::BatchPolicy;
use hif4::server::protocol::{Request, Status};
use hif4::server::service::{Client, NativeServerConfig, ResilienceConfig, Server};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn engine(kind: KvCacheType) -> DecodeEngine {
    let model = Arc::new(Transformer::init(zoo::llama3_tiny(), 37));
    DecodeEngine::new(model, kind, 64)
}

/// Drive `stream` alone for `n` steps, collecting tokens. These engines
/// prefill whole prompts (chunk 0), so every step yields a frame.
fn drive_solo(eng: &DecodeEngine, stream: &mut DecodeStream, n: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let r = eng.step(&mut [&mut *stream]);
        out.push(r[0].expect("whole-prompt prefill frames every step").0);
    }
    out
}

#[test]
fn mid_flight_admission_matches_solo_generation() {
    for kind in [KvCacheType::F32, KvCacheType::HIF4] {
        let eng = engine(kind);
        let (pa, pb) = (vec![1usize, 5, 9, 13], vec![2usize, 6, 10]);
        let solo_a = eng.model().generate_greedy(&pa, 6, kind);
        let solo_b = eng.model().generate_greedy(&pb, 4, kind);

        // A runs alone for 2 steps, then B is admitted mid-flight; A
        // finishes first and is evicted while B keeps decoding.
        let mut a = eng.start(&pa);
        let mut b = eng.start(&pb);
        let mut got_a: Vec<u32> = drive_solo(&eng, &mut a, 2);
        let mut got_b: Vec<u32> = Vec::new();
        for _ in 0..4 {
            let r = eng.step(&mut [&mut a, &mut b]);
            got_a.push(r[0].unwrap().0);
            got_b.push(r[1].unwrap().0);
        }
        assert_eq!(a.generated(), 6);
        drop(a); // eviction: the cache page is freed with the stream
        assert_eq!(got_a.iter().map(|&t| t as usize).collect::<Vec<_>>(), solo_a, "{kind:?}");
        assert_eq!(got_b.iter().map(|&t| t as usize).collect::<Vec<_>>(), solo_b, "{kind:?}");
        assert_eq!(b.generated(), 4);
        assert_eq!(got_b.len(), 4);
    }
}

#[test]
fn batch_composition_never_changes_a_streams_tokens() {
    // The same stream stepped inside batches of different shapes and
    // orders yields bit-identical tokens: admission order cannot matter.
    let eng = engine(KvCacheType::HIF4);
    let prompts: Vec<Vec<usize>> =
        (0..3).map(|s| (0..5).map(|i| 1 + (i * 11 + s * 3) % 300).collect()).collect();
    let solo: Vec<Vec<usize>> =
        prompts.iter().map(|p| eng.model().generate_greedy(p, 5, eng.kv())).collect();

    for order in [[0usize, 1, 2], [2, 1, 0], [1, 2, 0]] {
        let mut streams: Vec<DecodeStream> =
            order.iter().map(|&i| eng.start(&prompts[i])).collect();
        let mut got: Vec<Vec<u32>> = vec![Vec::new(); 3];
        for _ in 0..5 {
            let outs = {
                let mut refs: Vec<&mut DecodeStream> = streams.iter_mut().collect();
                eng.step(&mut refs)
            };
            for (slot, out) in outs.into_iter().enumerate() {
                got[order[slot]].push(out.unwrap().0);
            }
        }
        for (i, solo_i) in solo.iter().enumerate() {
            let got_i: Vec<usize> = got[i].iter().map(|&t| t as usize).collect();
            assert_eq!(&got_i, solo_i, "prompt {i} under order {order:?}");
        }
    }
}

// ---------------------------------------------------------------------
// Full-server tests (same manifest fixture as tests/native_serving.rs).
// ---------------------------------------------------------------------

fn write_manifest(dir: &Path) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "batch 4\nseq 16\nvocab 96\nn_heads 4\nkv_heads 2\nhead_dim 8\nrope_base 10000\n\
         qdq 8 64\n\
         param embed 96 32\nparam head 96 32\nparam norm_f 32\n\
         param layer0.norm1 32\nparam layer0.norm2 32\n\
         param layer0.wq 32 32\nparam layer0.wk 16 32\nparam layer0.wv 16 32\n\
         param layer0.wo 32 32\n\
         param layer0.w1 64 32\nparam layer0.w2 32 64\nparam layer0.w3 64 32\n",
    )
    .unwrap();
}

fn manifest_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hif4_continuous_batching_{tag}"))
}

fn start_server(tag: &str, kv: KvCacheType, max_batch: usize) -> (Server, Arc<Transformer>) {
    start_server_with(tag, kv, max_batch, ResilienceConfig::default())
}

fn start_server_with(
    tag: &str,
    kv: KvCacheType,
    max_batch: usize,
    resilience: ResilienceConfig,
) -> (Server, Arc<Transformer>) {
    start_server_tuned(tag, kv, max_batch, resilience, |_| {})
}

/// Full-control variant: `tune` adjusts the paging knobs
/// (`page_rows`, `prefix_cache`, `prefill_chunk`) after the defaults.
fn start_server_tuned(
    tag: &str,
    kv: KvCacheType,
    max_batch: usize,
    resilience: ResilienceConfig,
    tune: impl FnOnce(&mut NativeServerConfig),
) -> (Server, Arc<Transformer>) {
    let dir = manifest_dir(tag);
    write_manifest(&dir);
    let manifest = Manifest::load(&dir).unwrap();
    let store = manifest.init_params(23);
    let model = Arc::new(transformer_from_store(&manifest, &store).unwrap());
    let mut cfg = NativeServerConfig {
        policy: BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
        workers: 1,
        seq: manifest.seq,
        kv,
        resilience,
        ..Default::default()
    };
    tune(&mut cfg);
    let server = Server::start_native(Arc::clone(&model), cfg, "127.0.0.1:0").unwrap();
    (server, model)
}

#[test]
fn server_slot_reuse_outlives_many_generations() {
    // More requests than slots forces completion-eviction + slot reuse;
    // every stream must still match the in-process greedy reference.
    let (server, model) = start_server("reuse", KvCacheType::F32, 2);
    let prompts: Vec<Vec<usize>> =
        (0..5).map(|s| (0..4).map(|i| 1 + (i * 5 + s * 17) % 90).collect()).collect();
    let mut clients: Vec<Client> =
        prompts.iter().map(|_| Client::connect(server.addr).unwrap()).collect();
    for (i, (c, p)) in clients.iter_mut().zip(&prompts).enumerate() {
        c.send(&Request::generate(i as u64, p.clone(), 3)).unwrap();
    }
    for (i, (c, p)) in clients.iter_mut().zip(&prompts).enumerate() {
        let stream = c.recv_stream().unwrap();
        assert_eq!(stream.len(), 3, "request {i}");
        let want = model.generate_greedy(p, 3, KvCacheType::F32);
        let got: Vec<usize> = stream.iter().map(|r| r.token as usize).collect();
        assert_eq!(got, want, "request {i}");
    }
    let batches = server.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
    assert!(batches >= 5, "5 requests × 3 tokens need several decode steps, saw {batches}");
}

#[test]
fn deadline_expiry_mid_decode_frees_the_slot_and_its_reservation() {
    use hif4::server::faults::{FaultConfig, FaultPlan};
    // One slot, every decode step stalled 5ms: a request with a 40ms TTL
    // and a huge max_new must expire mid-decode — terminal Expired frame
    // carrying the tokens streamed so far — and the follow-up request
    // must find a free slot and decode token-identically to the
    // in-process greedy reference.
    let stall = FaultConfig { stall_per_mille: 1000, stall_ms: 5, ..Default::default() };
    let resilience = ResilienceConfig {
        kv_budget_bytes: 1 << 30, // real reservations, ample budget
        faults: Some(Arc::new(FaultPlan::new(3, stall))),
        ..Default::default()
    };
    let (server, model) = start_server_with("deadline", KvCacheType::F32, 1, resilience);
    let prompt = vec![3usize, 7, 11];

    let mut client = Client::connect(server.addr).unwrap();
    let doomed = Request::generate(1, prompt.clone(), 1024).with_deadline_ms(40);
    let stream = client.generate(&doomed).unwrap();
    let last = stream.last().unwrap();
    assert_eq!(last.status, Status::Expired, "must expire, got {stream:?}");
    assert!(stream.len() < 1024, "expiry must cut the stream short");
    assert_eq!(last.index as usize, stream.len() - 1, "Expired frame reports tokens streamed");
    // Determinism survives expiry: the streamed prefix is exactly the
    // greedy continuation's prefix.
    let emitted = stream.len() - 1;
    if emitted > 0 {
        let want = model.generate_greedy(&prompt, emitted, KvCacheType::F32);
        let got: Vec<usize> =
            stream[..emitted].iter().map(|r| r.token as usize).collect();
        assert_eq!(got, want, "tokens streamed before expiry match greedy decode");
    }

    // The slot and its worst-case KV reservation are free again: a
    // no-deadline request completes, token-identical to the reference.
    let survivor = client.generate(&Request::generate(2, prompt.clone(), 3)).unwrap();
    assert_eq!(survivor.last().unwrap().status, Status::Ok);
    let want = model.generate_greedy(&prompt, 3, KvCacheType::F32);
    let got: Vec<usize> = survivor.iter().map(|r| r.token as usize).collect();
    assert_eq!(got, want, "survivor after expiry matches greedy decode");

    let expired = server.metrics.deadlines_expired.load(std::sync::atomic::Ordering::Relaxed);
    assert!(expired >= 1, "expiry must be counted, saw {expired}");
    assert_eq!(server.admission().kv_reserved(), 0, "every reservation must be released");
    assert_eq!(server.admission().queued(), 0);
}

#[test]
fn kv_budget_shed_is_structured_and_survivors_are_token_identical() {
    // Fixture page cost at 4 rows/page: kvd 16 x f32 = 64 B/row, 256
    // B/page; 1 layer = 2 stores. A 2048-byte budget is 8 pages. A
    // (4-prompt, 3-new) request needs ceil(7/4) x 2 = 4 pages, but a
    // (4-prompt, 50-new) one needs ceil(54/4) x 2 = 28: the big request
    // sheds with a structured ShedKvBudget frame and the small one
    // decodes token-identically — overload degrades service, never
    // correctness.
    let resilience = ResilienceConfig { kv_budget_bytes: 2048, ..Default::default() };
    let (server, model) =
        start_server_tuned("kvshed", KvCacheType::F32, 2, resilience, |cfg| cfg.page_rows = 4);
    let prompt = vec![5usize, 9, 13, 17];

    let mut client = Client::connect(server.addr).unwrap();
    let big = client.generate(&Request::generate(1, prompt.clone(), 50)).unwrap();
    assert_eq!(big.len(), 1, "shed answers a single terminal frame");
    assert_eq!(big[0].status, Status::ShedKvBudget);
    assert!(big[0].status.retryable(), "shed must invite a retry");

    let small = client.generate(&Request::generate(2, prompt.clone(), 3)).unwrap();
    assert_eq!(small.last().unwrap().status, Status::Ok);
    let want = model.generate_greedy(&prompt, 3, KvCacheType::F32);
    let got: Vec<usize> = small.iter().map(|r| r.token as usize).collect();
    assert_eq!(got, want, "survivor alongside shed traffic matches greedy decode");

    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(server.metrics.shed_kv_budget.load(ord) >= 1);
    assert_eq!(server.metrics.shed_queue_full.load(ord), 0);
    assert_eq!(server.admission().kv_reserved(), 0, "shed + completion release everything");
}

#[test]
fn prefix_dedup_is_token_identical_across_every_format() {
    // Shared-prefix dedup on, chunked prefill on, small pages: a warm
    // request registers the shared prefix, two follow-ups attach its
    // pages by refcount (with a CoW tail) — and every streamed token
    // must still equal the in-process greedy reference, i.e. exactly
    // what sharing *off* produces, for f32 and all five block formats.
    let shared: Vec<usize> = vec![4, 9, 2, 7, 7, 3, 1, 8];
    let mut kinds = vec![KvCacheType::F32];
    kinds.extend(QuantKind::ALL.iter().map(|&k| KvCacheType::Quant(k)));
    for (fi, kind) in kinds.into_iter().enumerate() {
        let tag = format!("dedup{fi}");
        let (server, model) =
            start_server_tuned(&tag, kind, 2, ResilienceConfig::default(), |cfg| {
                cfg.prefix_cache = true;
                cfg.prefill_chunk = 2;
                cfg.page_rows = 4;
            });
        let mut client = Client::connect(server.addr).unwrap();
        // Warm the prefix index: registration happens when this
        // request's prefill completes, strictly before the next
        // request's listener-side lookup (same sequential client).
        let warm = client.generate(&Request::generate(0, shared.clone(), 2)).unwrap();
        assert_eq!(warm.last().unwrap().status, Status::Ok, "{kind:?} warmup");
        for (ri, suffix) in [[31usize, 5, 22], [11, 74, 3]].iter().enumerate() {
            let mut prompt = shared.clone();
            prompt.extend_from_slice(suffix);
            let req = Request::generate(1 + ri as u64, prompt.clone(), 4);
            let stream = client.generate(&req).unwrap();
            assert_eq!(stream.last().unwrap().status, Status::Ok, "{kind:?} suffix {ri}");
            let want = model.generate_greedy(&prompt, 4, kind);
            let got: Vec<usize> = stream.iter().map(|r| r.token as usize).collect();
            assert_eq!(got, want, "{kind:?} suffix {ri}: dedup must not change tokens");
        }
        let ord = std::sync::atomic::Ordering::Relaxed;
        assert!(
            server.metrics.prefix_hits.load(ord) > 0,
            "{kind:?}: the shared prefix must actually hit"
        );
    }
}

#[test]
fn server_output_is_independent_of_arrival_order() {
    for (tag, order) in [("order_fwd", [0usize, 1, 2]), ("order_rev", [2, 1, 0])] {
        let (server, model) = start_server(tag, KvCacheType::HIF4, 3);
        let prompts: Vec<Vec<usize>> =
            (0..3).map(|s| (0..3).map(|i| 2 + (i * 7 + s * 29) % 90).collect()).collect();
        let mut clients: Vec<(usize, Client)> = Vec::new();
        for &i in &order {
            let mut c = Client::connect(server.addr).unwrap();
            c.send(&Request::generate(i as u64, prompts[i].clone(), 4)).unwrap();
            clients.push((i, c));
        }
        for (i, c) in clients.iter_mut() {
            let stream = c.recv_stream().unwrap();
            let want = model.generate_greedy(&prompts[*i], 4, KvCacheType::HIF4);
            let got: Vec<usize> = stream.iter().map(|r| r.token as usize).collect();
            assert_eq!(got, want, "prompt {i} arriving under order {order:?}");
        }
    }
}
