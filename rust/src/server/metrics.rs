//! Serving metrics: lock-light latency histogram + throughput counters,
//! tagged with the engine's quantization configuration so every
//! `BENCH_decode`/serving row is attributable to a format.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// The quantization configuration a server's counters describe: weight
/// format label (a [`crate::formats::QuantKind`] spelling or `bf16`), the
/// KV-cache label, and the resident quantized-weight wire bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FormatTag {
    pub format: String,
    pub kv: String,
    pub weight_wire_bytes: u64,
}

/// Exponential-bucket latency histogram (1µs .. ~17s) + counters.
/// All atomic: writers never block each other or the readers.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// buckets[i] counts latencies in [2^i, 2^(i+1)) µs.
    buckets: [AtomicU64; 25],
    total_us: AtomicU64,
    /// (Re)bound at engine bring-up ([`Metrics::set_format_tag`]).
    format_tag: Mutex<Option<FormatTag>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Tag these counters with the serving quantization configuration.
    /// Every engine (re)construction calls this, and the **latest engine
    /// wins**: an in-process engine swap or `serve` restart sharing a
    /// `Metrics` handle overwrites the previous run's tag instead of
    /// reporting a stale format/KV/weight-bytes combination.
    pub fn set_format_tag(&self, format: &str, kv: &str, weight_wire_bytes: u64) {
        *self.format_tag.lock().unwrap() = Some(FormatTag {
            format: format.to_string(),
            kv: kv.to_string(),
            weight_wire_bytes,
        });
    }

    /// The active engine's quantization tag, if one is bound.
    pub fn format_tag(&self) -> Option<FormatTag> {
        self.format_tag.lock().unwrap().clone()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_latency(&self, lat: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        let us = lat.as_micros().max(1) as u64;
        self.total_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (63 - us.leading_zeros() as usize).min(24);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Percentile from the histogram (approximate: bucket upper bound).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << 25
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.total_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        let tag = match self.format_tag() {
            Some(t) => {
                format!("format={} kv={} weights_wire={}B ", t.format, t.kv, t.weight_wire_bytes)
            }
            None => String::new(),
        };
        format!(
            "{}requests={} responses={} batches={} mean_batch={:.2} lat(mean={:.0}us p50<{}us p99<{}us)",
            tag,
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_us(),
            self.percentile_us(0.5),
            self.percentile_us(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_buckets_and_percentiles() {
        let m = Metrics::new();
        for us in [10u64, 100, 100, 1000, 10_000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert_eq!(m.responses.load(Ordering::Relaxed), 5);
        // p50 falls in the 100µs bucket → upper bound 128.
        assert_eq!(m.percentile_us(0.5), 128);
        assert!(m.percentile_us(0.99) >= 8192);
        assert!((m.mean_us() - 2242.0).abs() < 1.0);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(0.99), 0);
        assert_eq!(m.mean_us(), 0.0);
        assert!(m.format_tag().is_none());
        assert!(!m.summary().contains("format="));
    }

    #[test]
    fn format_tag_tracks_engine_reconstruction() {
        let m = Metrics::new();
        m.set_format_tag("mxfp4", "f32", 1234);
        let t = m.format_tag().expect("tag set");
        assert_eq!((t.format.as_str(), t.kv.as_str(), t.weight_wire_bytes), ("mxfp4", "f32", 1234));
        let s = m.summary();
        assert!(s.contains("format=mxfp4") && s.contains("kv=f32") && s.contains("1234B"), "{s}");
        // An engine swap re-tags at construction: the latest engine wins,
        // so a restarted server can never report the previous run's
        // format/KV/weight-bytes combination.
        m.set_format_tag("bf16", "hif4", 0);
        let t = m.format_tag().expect("tag rebound");
        assert_eq!((t.format.as_str(), t.kv.as_str(), t.weight_wire_bytes), ("bf16", "hif4", 0));
        let s = m.summary();
        assert!(s.contains("format=bf16") && s.contains("kv=hif4"), "{s}");
        assert!(!s.contains("mxfp4"), "stale tag must not survive a swap: {s}");
    }
}
