//! Serving metrics: lock-light latency histogram + throughput counters,
//! tagged with the engine's quantization configuration so every
//! `BENCH_decode`/serving row is attributable to a format.
//!
//! The resilience counters (requests shed, deadlines expired, worker
//! restarts, client retries observed) make overload and failure behavior
//! a *measured* property: the chaos soak test asserts on them, and
//! `summary()` surfaces them next to the latency percentiles.

use super::protocol::Status;
use crate::util::lock_recover;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// The quantization configuration a server's counters describe: weight
/// format label (a [`crate::formats::QuantKind`] spelling or `bf16`), the
/// KV-cache label, and the resident quantized-weight wire bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FormatTag {
    pub format: String,
    pub kv: String,
    pub weight_wire_bytes: u64,
}

/// Exponential-bucket latency histogram (1µs .. ~17s) + counters.
/// All atomic: writers never block each other or the readers.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Requests shed at admission because the bounded queue was full.
    pub shed_queue_full: AtomicU64,
    /// Requests shed at admission because the KV-byte budget was spent.
    pub shed_kv_budget: AtomicU64,
    /// Requests rejected by protocol validation (`max_new == 0`, prompt
    /// beyond the model context, …).
    pub rejected_invalid: AtomicU64,
    /// Requests whose deadline passed before their stream completed
    /// (counted wherever enforcement caught them: queue or mid-decode).
    pub deadlines_expired: AtomicU64,
    /// Times a supervisor restarted a panicked worker (each restart also
    /// drained that worker's in-flight sequences to `Crashed` frames).
    pub worker_restarts: AtomicU64,
    /// Client-side retries reported back by in-process retrying clients
    /// (benches/tests); zero when only external clients are used.
    pub retries_observed: AtomicU64,
    /// Admissions whose prefix-cache lookup attached shared pages.
    pub prefix_hits: AtomicU64,
    /// Admissions whose lookup found nothing sharable (including when the
    /// prefix cache is disabled — every admission is then a miss).
    pub prefix_misses: AtomicU64,
    /// Page-pool occupancy gauges, sampled from the allocator by the
    /// serving loop ([`Metrics::set_page_gauges`]): live pages, lifetime
    /// high-water mark, free-list depth, shared-page refcount high-water
    /// mark, and resident bytes saved by prefix dedup.
    pages_live: AtomicU64,
    pages_high_water: AtomicU64,
    pages_free: AtomicU64,
    shared_ref_high_water: AtomicU64,
    prefix_bytes_saved: AtomicU64,
    /// buckets[i] counts latencies in [2^i, 2^(i+1)) µs.
    buckets: [AtomicU64; 25],
    total_us: AtomicU64,
    /// (Re)bound at engine bring-up ([`Metrics::set_format_tag`]).
    format_tag: Mutex<Option<FormatTag>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Tag these counters with the serving quantization configuration.
    /// Every engine (re)construction calls this, and the **latest engine
    /// wins**: an in-process engine swap or `serve` restart sharing a
    /// `Metrics` handle overwrites the previous run's tag instead of
    /// reporting a stale format/KV/weight-bytes combination.
    pub fn set_format_tag(&self, format: &str, kv: &str, weight_wire_bytes: u64) {
        *lock_recover(&self.format_tag) = Some(FormatTag {
            format: format.to_string(),
            kv: kv.to_string(),
            weight_wire_bytes,
        });
    }

    /// The active engine's quantization tag, if one is bound.
    pub fn format_tag(&self) -> Option<FormatTag> {
        lock_recover(&self.format_tag).clone()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// A request shed at admission with the given (shed-class) status.
    pub fn record_shed(&self, status: Status) {
        match status {
            Status::ShedQueueFull => self.shed_queue_full.fetch_add(1, Ordering::Relaxed),
            Status::ShedKvBudget => self.shed_kv_budget.fetch_add(1, Ordering::Relaxed),
            // Not a shed class; counted so a miswired call site still
            // shows up in the summary rather than vanishing.
            _ => self.rejected_invalid.fetch_add(1, Ordering::Relaxed),
        };
    }

    pub fn record_invalid(&self) {
        self.rejected_invalid.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_expired(&self) {
        self.deadlines_expired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold in retries a client performed for one logical request.
    pub fn record_retries(&self, n: u64) {
        if n > 0 {
            self.retries_observed.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Total requests shed at admission (both shed classes).
    pub fn shed_total(&self) -> u64 {
        self.shed_queue_full.load(Ordering::Relaxed) + self.shed_kv_budget.load(Ordering::Relaxed)
    }

    /// One prefix-cache lookup outcome at admission.
    pub fn record_prefix_lookup(&self, hit: bool) {
        if hit {
            self.prefix_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.prefix_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Prefix-cache hit rate over lookups so far (0.0 when none).
    pub fn prefix_hit_rate(&self) -> f64 {
        let hits = self.prefix_hits.load(Ordering::Relaxed);
        let total = hits + self.prefix_misses.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        hits as f64 / total as f64
    }

    /// Sample the page allocator's occupancy gauges (the serving loop
    /// calls this after each step; latest sample wins).
    pub fn set_page_gauges(
        &self,
        live: u64,
        high_water: u64,
        free: u64,
        shared_ref_high_water: u64,
        bytes_saved: u64,
    ) {
        self.pages_live.store(live, Ordering::Relaxed);
        self.pages_high_water.store(high_water, Ordering::Relaxed);
        self.pages_free.store(free, Ordering::Relaxed);
        self.shared_ref_high_water.store(shared_ref_high_water, Ordering::Relaxed);
        self.prefix_bytes_saved.store(bytes_saved, Ordering::Relaxed);
    }

    /// Resident bytes prefix dedup avoided allocating (latest sample).
    pub fn prefix_bytes_saved(&self) -> u64 {
        self.prefix_bytes_saved.load(Ordering::Relaxed)
    }

    /// Shared-page refcount high-water mark (latest sample).
    pub fn shared_ref_high_water(&self) -> u64 {
        self.shared_ref_high_water.load(Ordering::Relaxed)
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_latency(&self, lat: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        let us = lat.as_micros().max(1) as u64;
        self.total_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (63 - us.leading_zeros() as usize).min(24);
        // audit:allow(index) -- bucket is .min(24)-clamped into the 25-entry histogram.
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Percentile from the histogram (approximate: bucket upper bound).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << 25
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.total_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        let tag = match self.format_tag() {
            Some(t) => {
                format!("format={} kv={} weights_wire={}B ", t.format, t.kv, t.weight_wire_bytes)
            }
            None => String::new(),
        };
        format!(
            "{}requests={} responses={} batches={} mean_batch={:.2} \
             lat(mean={:.0}us p50<{}us p99<{}us) \
             shed(queue={} kv={}) invalid={} expired={} restarts={} retries={} \
             prefix(hit={} miss={} saved={}B shared_hw={}) pages(live={} hw={} free={})",
            tag,
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_us(),
            self.percentile_us(0.5),
            self.percentile_us(0.99),
            self.shed_queue_full.load(Ordering::Relaxed),
            self.shed_kv_budget.load(Ordering::Relaxed),
            self.rejected_invalid.load(Ordering::Relaxed),
            self.deadlines_expired.load(Ordering::Relaxed),
            self.worker_restarts.load(Ordering::Relaxed),
            self.retries_observed.load(Ordering::Relaxed),
            self.prefix_hits.load(Ordering::Relaxed),
            self.prefix_misses.load(Ordering::Relaxed),
            self.prefix_bytes_saved.load(Ordering::Relaxed),
            self.shared_ref_high_water.load(Ordering::Relaxed),
            self.pages_live.load(Ordering::Relaxed),
            self.pages_high_water.load(Ordering::Relaxed),
            self.pages_free.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_buckets_and_percentiles() {
        let m = Metrics::new();
        for us in [10u64, 100, 100, 1000, 10_000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert_eq!(m.responses.load(Ordering::Relaxed), 5);
        // p50 falls in the 100µs bucket → upper bound 128.
        assert_eq!(m.percentile_us(0.5), 128);
        assert!(m.percentile_us(0.99) >= 8192);
        assert!((m.mean_us() - 2242.0).abs() < 1.0);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(0.99), 0);
        assert_eq!(m.mean_us(), 0.0);
        assert!(m.format_tag().is_none());
        assert!(!m.summary().contains("format="));
        assert_eq!(m.shed_total(), 0);
    }

    #[test]
    fn resilience_counters_surface_in_summary() {
        let m = Metrics::new();
        m.record_shed(Status::ShedQueueFull);
        m.record_shed(Status::ShedQueueFull);
        m.record_shed(Status::ShedKvBudget);
        m.record_invalid();
        m.record_expired();
        m.record_worker_restart();
        m.record_retries(0); // no-op
        m.record_retries(3);
        assert_eq!(m.shed_total(), 3);
        let s = m.summary();
        assert!(s.contains("shed(queue=2 kv=1)"), "{s}");
        assert!(s.contains("invalid=1"), "{s}");
        assert!(s.contains("expired=1"), "{s}");
        assert!(s.contains("restarts=1"), "{s}");
        assert!(s.contains("retries=3"), "{s}");
    }

    #[test]
    fn prefix_and_page_counters_surface_in_summary() {
        let m = Metrics::new();
        m.record_prefix_lookup(true);
        m.record_prefix_lookup(true);
        m.record_prefix_lookup(false);
        assert!((m.prefix_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        m.set_page_gauges(12, 20, 8, 5, 4096);
        assert_eq!(m.prefix_bytes_saved(), 4096);
        assert_eq!(m.shared_ref_high_water(), 5);
        let s = m.summary();
        assert!(s.contains("prefix(hit=2 miss=1 saved=4096B shared_hw=5)"), "{s}");
        assert!(s.contains("pages(live=12 hw=20 free=8)"), "{s}");
        // Latest sample wins (gauges, not counters).
        m.set_page_gauges(3, 20, 17, 5, 4096);
        assert!(m.summary().contains("pages(live=3 hw=20 free=17)"));
        // Unsampled metrics read as zeroed gauges, not garbage.
        let empty = Metrics::new();
        assert_eq!(empty.prefix_hit_rate(), 0.0);
        assert!(empty.summary().contains("prefix(hit=0 miss=0"));
    }

    #[test]
    fn tail_percentile_p999_reads_the_slowest_bucket() {
        let m = Metrics::new();
        // 1000 fast responses and 10 slow outliers: p50/p99 stay in the
        // fast bucket, p999 must land on (the bucket of) the outliers.
        for _ in 0..1000 {
            m.record_latency(Duration::from_micros(100));
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_millis(100));
        }
        assert_eq!(m.percentile_us(0.5), 128);
        assert_eq!(m.percentile_us(0.99), 128);
        assert!(m.percentile_us(0.999) >= 1 << 17, "p999 sees the outlier");
    }

    #[test]
    fn format_tag_tracks_engine_reconstruction() {
        let m = Metrics::new();
        m.set_format_tag("mxfp4", "f32", 1234);
        let t = m.format_tag().expect("tag set");
        assert_eq!((t.format.as_str(), t.kv.as_str(), t.weight_wire_bytes), ("mxfp4", "f32", 1234));
        let s = m.summary();
        assert!(s.contains("format=mxfp4") && s.contains("kv=f32") && s.contains("1234B"), "{s}");
        // An engine swap re-tags at construction: the latest engine wins,
        // so a restarted server can never report the previous run's
        // format/KV/weight-bytes combination.
        m.set_format_tag("bf16", "hif4", 0);
        let t = m.format_tag().expect("tag rebound");
        assert_eq!((t.format.as_str(), t.kv.as_str(), t.weight_wire_bytes), ("bf16", "hif4", 0));
        let s = m.summary();
        assert!(s.contains("format=bf16") && s.contains("kv=hif4"), "{s}");
        assert!(!s.contains("mxfp4"), "stale tag must not survive a swap: {s}");
    }
}
