//! Serving coordinator: TCP protocol, request router, dynamic batcher.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod service;
