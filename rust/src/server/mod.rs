//! Serving coordinator: TCP protocol, request router, and two schedulers
//! in front of the execution engines (PJRT executables batch-then-drain;
//! the rust-native engine continuous-batching decode).
//!
//! Request lifecycle (all std threads, no async runtime):
//!
//! ```text
//! client ──TCP──▶ connection thread ──▶ request queue
//!                                             │
//!              PJRT path          [protocol]  │        native path
//!         batcher thread ◀────────────────────┴──────────────▶ decode loops
//!        (max_batch / max_wait)                     (ContinuousScheduler slot
//!               ▼                                    map: admit between steps,
//!       shared batch queue                           one greedy token per slot
//!      ▲            ▲  (free workers pull)           per step, streamed reply
//! worker 0 …   worker N-1   (own engine each)        frames, evict on done)
//!      └──▶ reply writer (per-connection lock)
//! ```
//!
//! [`protocol`] defines the length-prefixed binary frames (requests carry
//! `max_new` and a `deadline_ms` TTL, responses stream `index`/`of`-tagged
//! tokens with a terminal [`protocol::Status`]), [`batcher`] the drain
//! policy, the continuous-batching slot map and the bounded
//! [`batcher::AdmissionGate`], [`service`] the listener/scheduler/worker
//! assembly plus a blocking [`service::Client`] (with capped-backoff
//! retry), [`metrics`] the lock-light counters/histograms the `serve`
//! subcommand and the serving benches report, and [`faults`] the seeded
//! deterministic fault-injection harness the chaos soak test and
//! `benches/serving_soak.rs` drive.
//!
//! **Resilience model** (DESIGN.md §13): requests are validated and
//! admitted through a queue-depth + KV-byte gate (overload sheds with
//! structured rejections instead of blocking or OOMing), carry deadlines
//! enforced at admission, in the queue, and between decode steps, and
//! run under supervised workers — a panicking worker is restarted, its
//! in-flight sequences drained to `Crashed` responses, its locks
//! recovered rather than left poisoned.

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod protocol;
pub mod service;
