//! Serving coordinator: TCP protocol, request router, and two schedulers
//! in front of the execution engines (PJRT executables batch-then-drain;
//! the rust-native engine continuous-batching decode).
//!
//! Request lifecycle (all std threads, no async runtime):
//!
//! ```text
//! client ──TCP──▶ connection thread ──▶ request queue
//!                                             │
//!              PJRT path          [protocol]  │        native path
//!         batcher thread ◀────────────────────┴──────────────▶ decode loops
//!        (max_batch / max_wait)                     (ContinuousScheduler slot
//!               ▼                                    map: admit between steps,
//!       shared batch queue                           one greedy token per slot
//!      ▲            ▲  (free workers pull)           per step, streamed reply
//! worker 0 …   worker N-1   (own engine each)        frames, evict on done)
//!      └──▶ reply writer (per-connection lock)
//! ```
//!
//! [`protocol`] defines the length-prefixed binary frames (requests carry
//! `max_new`, responses stream `index`/`of`-tagged tokens), [`batcher`]
//! the drain policy plus the continuous-batching slot map, [`service`]
//! the listener/scheduler/worker assembly plus a blocking
//! [`service::Client`], and [`metrics`] the lock-light
//! counters/histograms the `serve` subcommand and the serving benches
//! report.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod service;
