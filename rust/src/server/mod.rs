//! Serving coordinator: TCP protocol, request router, dynamic batcher and
//! the worker pool (PJRT executables or the rust-native engine).
//!
//! Request lifecycle (all std threads, no async runtime):
//!
//! ```text
//! client ──TCP──▶ connection thread ──▶ request queue
//!                                             │ batcher thread
//!                                   [protocol]│ (max_batch / max_wait)
//!                                             ▼
//!                                     shared batch queue
//!                                    ▲            ▲  (free workers pull)
//!                               worker 0 …   worker N-1   (own engine each)
//!                                    └──▶ reply writer (per-connection lock)
//! ```
//!
//! [`protocol`] defines the length-prefixed binary frames, [`batcher`] the
//! drain policy and batch forwarding, [`service`] the listener/batcher/
//! worker-pool assembly plus a blocking [`service::Client`], and
//! [`metrics`] the lock-light counters/histograms the `serve` subcommand
//! and the serving bench report.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod service;
