//! Deterministic fault injection for the serving tier — the chaos
//! harness behind the soak test and `benches/serving_soak.rs`.
//!
//! A [`FaultPlan`] is a pure function of `(seed, worker, step)`: every
//! decode/batch worker consults it once per step ([`FaultPlan::trip`]),
//! and the plan decides — via an FNV-1a roll against per-mille rates —
//! whether that step panics (exercising the supervisor's
//! `catch_unwind`/restart path), stalls (exercising deadlines and
//! backpressure), or proceeds. Client-side faults
//! ([`FaultPlan::client_decide`]) drive the same determinism for garbage
//! frames, dropped connections and oversized payloads from chaos load
//! generators. Nothing here samples real entropy or wall-clock time, so
//! a chaos run replays bit-identically from its seed — the soak test's
//! "surviving sequences are token-identical to a fault-free run"
//! assertion depends on it.
//!
//! Injected panics carry the [`InjectedFault`] marker payload;
//! [`quiet_injected_panics`] installs a panic hook that keeps them out
//! of test/bench output while leaving genuine panics loud.

use std::panic;
use std::sync::Once;
use std::time::Duration;

/// Panic payload marking a fault-plan-injected worker panic. Supervisors
/// treat it like any other panic (restart + drain); the panic *hook*
/// uses it to tell deliberate chaos from real bugs.
#[derive(Debug, Clone, Copy)]
pub struct InjectedFault {
    pub worker: usize,
    pub step: u64,
}

/// Injection rates and triggers. All rates are per-mille (0..=1000) so a
/// plan spec stays integer-only and exactly reproducible.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultConfig {
    /// Per-mille chance a worker step panics.
    pub panic_per_mille: u16,
    /// Per-mille chance a worker step stalls for `stall_ms`.
    pub stall_per_mille: u16,
    /// Stall duration for slow-decode injection.
    pub stall_ms: u64,
    /// Guaranteed panic on exactly this global worker step (first worker
    /// to reach it) — the recovery-time measurement hook.
    pub panic_at_step: Option<u64>,
    /// Per-mille chance a chaos client sends a garbage (unparseable)
    /// frame instead of its request.
    pub garbage_per_mille: u16,
    /// Per-mille chance a chaos client drops its connection mid-request.
    pub disconnect_per_mille: u16,
}

/// What a worker step should do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    Panic,
    Stall(Duration),
}

/// What a chaos client should do instead of sending its request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientFault {
    /// Send bytes that cannot parse as a request frame.
    Garbage,
    /// Close the connection without sending.
    Disconnect,
}

/// Seeded, deterministic fault schedule (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    pub cfg: FaultConfig,
}

/// FNV-1a over the three words — the crate's standard cheap deterministic
/// mixer (shared with the retry-jitter computation in
/// [`crate::server::service::RetryPolicy`]).
pub fn mix64(seed: u64, a: u64, b: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for w in [seed, a, b] {
        for byte in w.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

impl FaultPlan {
    /// Panics if the panic+stall rates exceed 1000‰ (they partition one
    /// roll).
    pub fn new(seed: u64, cfg: FaultConfig) -> FaultPlan {
        assert!(
            cfg.panic_per_mille + cfg.stall_per_mille <= 1000,
            "panic ({}) + stall ({}) rates exceed 1000 per mille",
            cfg.panic_per_mille,
            cfg.stall_per_mille
        );
        assert!(
            cfg.garbage_per_mille + cfg.disconnect_per_mille <= 1000,
            "garbage ({}) + disconnect ({}) rates exceed 1000 per mille",
            cfg.garbage_per_mille,
            cfg.disconnect_per_mille
        );
        FaultPlan { seed, cfg }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The fault (if any) for `worker`'s step number `step`. Pure.
    pub fn decide(&self, worker: usize, step: u64) -> Option<Fault> {
        if self.cfg.panic_at_step == Some(step) {
            return Some(Fault::Panic);
        }
        let roll = (mix64(self.seed, worker as u64, step) % 1000) as u16;
        if roll < self.cfg.panic_per_mille {
            Some(Fault::Panic)
        } else if roll < self.cfg.panic_per_mille + self.cfg.stall_per_mille {
            Some(Fault::Stall(Duration::from_millis(self.cfg.stall_ms)))
        } else {
            None
        }
    }

    /// Act on [`FaultPlan::decide`]: sleep for a stall, `panic_any` an
    /// [`InjectedFault`] for a panic (callers run under the supervisor's
    /// `catch_unwind`, which restarts the worker and drains its
    /// in-flight sequences to `Crashed` responses).
    pub fn trip(&self, worker: usize, step: u64) {
        match self.decide(worker, step) {
            Some(Fault::Panic) => panic::panic_any(InjectedFault { worker, step }),
            Some(Fault::Stall(d)) => std::thread::sleep(d),
            None => {}
        }
    }

    /// The client-side fault (if any) for request number `req` on chaos
    /// connection `conn`. A distinct domain constant keeps client rolls
    /// uncorrelated with worker rolls under the same seed.
    pub fn client_decide(&self, conn: u64, req: u64) -> Option<ClientFault> {
        let roll = (mix64(self.seed ^ 0xC11E57, conn, req) % 1000) as u16;
        if roll < self.cfg.garbage_per_mille {
            Some(ClientFault::Garbage)
        } else if roll < self.cfg.garbage_per_mille + self.cfg.disconnect_per_mille {
            Some(ClientFault::Disconnect)
        } else {
            None
        }
    }

    /// Parse a CLI `--faults` spec: comma-separated `key=value` pairs
    /// with keys `seed`, `panic`, `stall`, `stall-ms`, `panic-at`,
    /// `garbage`, `disconnect` (rates in per-mille). Example:
    /// `seed=7,panic=5,stall=20,stall-ms=3`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut seed = 0u64;
        let mut cfg = FaultConfig::default();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec item {part:?} is not key=value"))?;
            let parse_u16 = |v: &str| -> Result<u16, String> {
                let n: u16 = v.parse().map_err(|e| format!("{key}={v}: {e}"))?;
                if n > 1000 {
                    return Err(format!("{key}={v}: rates are per-mille (0..=1000)"));
                }
                Ok(n)
            };
            match key.trim() {
                "seed" => seed = value.parse().map_err(|e| format!("seed={value}: {e}"))?,
                "panic" => cfg.panic_per_mille = parse_u16(value.trim())?,
                "stall" => cfg.stall_per_mille = parse_u16(value.trim())?,
                "stall-ms" => {
                    cfg.stall_ms = value.parse().map_err(|e| format!("stall-ms={value}: {e}"))?
                }
                "panic-at" => {
                    cfg.panic_at_step =
                        Some(value.parse().map_err(|e| format!("panic-at={value}: {e}"))?)
                }
                "garbage" => cfg.garbage_per_mille = parse_u16(value.trim())?,
                "disconnect" => cfg.disconnect_per_mille = parse_u16(value.trim())?,
                other => return Err(format!("unknown fault spec key {other:?}")),
            }
        }
        if cfg.panic_per_mille + cfg.stall_per_mille > 1000 {
            return Err("panic + stall rates exceed 1000 per mille".into());
        }
        if cfg.garbage_per_mille + cfg.disconnect_per_mille > 1000 {
            return Err("garbage + disconnect rates exceed 1000 per mille".into());
        }
        Ok(FaultPlan::new(seed, cfg))
    }
}

/// Install (once, process-wide) a panic hook that suppresses the default
/// backtrace spew for [`InjectedFault`] panics — chaos tests inject
/// hundreds of them by design — while delegating every other panic to
/// the previous hook unchanged.
pub fn quiet_injected_panics() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let previous = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            if info.payload().is::<InjectedFault>() {
                return; // deliberate chaos: the supervisor accounts for it
            }
            previous(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_and_seed_sensitive() {
        let cfg = FaultConfig {
            panic_per_mille: 50,
            stall_per_mille: 100,
            stall_ms: 2,
            ..Default::default()
        };
        let a = FaultPlan::new(7, cfg);
        let b = FaultPlan::new(7, cfg);
        let c = FaultPlan::new(8, cfg);
        let schedule =
            |p: &FaultPlan| (0..200).map(|s| p.decide(1, s)).collect::<Vec<Option<Fault>>>();
        assert_eq!(schedule(&a), schedule(&b), "same seed → same schedule");
        assert_ne!(schedule(&a), schedule(&c), "different seed → different schedule");
        // Rates roughly realize over a long horizon (rolls are per-mille).
        let n = 10_000u64;
        let panics = (0..n).filter(|&s| a.decide(0, s) == Some(Fault::Panic)).count();
        assert!((300..700).contains(&panics), "~50/1000 of {n}: got {panics}");
    }

    #[test]
    fn panic_at_step_fires_exactly_there() {
        let cfg = FaultConfig { panic_at_step: Some(17), ..Default::default() };
        let plan = FaultPlan::new(0, cfg);
        assert_eq!(plan.decide(3, 17), Some(Fault::Panic));
        assert_eq!(plan.decide(3, 16), None);
        assert_eq!(plan.decide(3, 18), None);
    }

    #[test]
    fn zero_rate_plan_never_fires() {
        let plan = FaultPlan::new(99, FaultConfig::default());
        for w in 0..4 {
            for s in 0..500 {
                assert_eq!(plan.decide(w, s), None);
                assert_eq!(plan.client_decide(w as u64, s), None);
                plan.trip(w, s); // must be a no-op, not a panic
            }
        }
    }

    #[test]
    fn client_rolls_are_uncorrelated_with_worker_rolls() {
        // Same rates on both sides: if the domains collided, every worker
        // panic step would also be a client garbage step.
        let cfg = FaultConfig {
            panic_per_mille: 100,
            garbage_per_mille: 100,
            ..Default::default()
        };
        let plan = FaultPlan::new(21, cfg);
        let worker: Vec<bool> = (0..2000).map(|s| plan.decide(0, s).is_some()).collect();
        let client: Vec<bool> = (0..2000).map(|s| plan.client_decide(0, s).is_some()).collect();
        assert_ne!(worker, client);
    }

    #[test]
    fn spec_parser_roundtrips_and_rejects_garbage() {
        let plan = FaultPlan::parse("seed=7,panic=5,stall=20,stall-ms=3,panic-at=100").unwrap();
        assert_eq!(plan.seed(), 7);
        assert_eq!(plan.cfg.panic_per_mille, 5);
        assert_eq!(plan.cfg.stall_per_mille, 20);
        assert_eq!(plan.cfg.stall_ms, 3);
        assert_eq!(plan.cfg.panic_at_step, Some(100));
        let client = FaultPlan::parse("garbage=10,disconnect=20").unwrap();
        assert_eq!(client.cfg.garbage_per_mille, 10);
        assert_eq!(client.cfg.disconnect_per_mille, 20);
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::new(0, FaultConfig::default()));
        assert!(FaultPlan::parse("panic").is_err(), "not key=value");
        assert!(FaultPlan::parse("panic=1001").is_err(), "rate above 1000");
        assert!(FaultPlan::parse("panic=600,stall=600").is_err(), "rates must partition a roll");
        assert!(FaultPlan::parse("wat=1").is_err(), "unknown key");
        assert!(FaultPlan::parse("seed=x").is_err(), "unparseable value");
    }

    #[test]
    fn injected_panics_are_catchable_and_typed() {
        quiet_injected_panics();
        let plan = FaultPlan::new(0, FaultConfig { panic_at_step: Some(0), ..Default::default() });
        let err = std::panic::catch_unwind(|| plan.trip(2, 0)).unwrap_err();
        let fault = err.downcast_ref::<InjectedFault>().expect("typed payload");
        assert_eq!((fault.worker, fault.step), (2, 0));
    }
}
