//! Batching machinery for the serving coordinator — two schedulers:
//!
//! * **Dynamic batcher** ([`run_batcher`] / [`next_batch`]): the
//!   batch-then-drain pipeline the PJRT path uses. Requests arrive on an
//!   MPSC queue; the batcher drains up to `max_batch` of them, waiting at
//!   most `max_wait` after the first request before dispatching a partial
//!   batch (latency/throughput knob). Complete batches go onto one shared
//!   queue that the PJRT workers (each owning its own executable) pull
//!   from whenever they are free — work-stealing-style load balancing, so
//!   a stalled worker never accumulates a backlog while others idle.
//!
//! * **Continuous-batching slot map** ([`ContinuousScheduler`]): the
//!   vLLM-style scheduler the native decode engine uses. A fixed-capacity
//!   slot map holds in-flight generation streams; new requests are
//!   **admitted into the lowest free slot between decode steps** (no
//!   drain barrier — a fresh sequence prefills in the same step its batch
//!   mates decode), and completed sequences are **evicted immediately**,
//!   freeing their slot (and per-sequence KV-cache page) for the next
//!   arrival. Iteration is by ascending slot id, so the step order is
//!   deterministic; per-sequence *outputs* are additionally independent
//!   of batch composition entirely (see
//!   [`crate::model::transformer::Transformer::forward_cached`]), which
//!   makes generation results independent of arrival order.

use super::protocol::{Request, Status};
use crate::model::pages::PrefixHit;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::time::{Duration, Instant};

/// Fixed-capacity slot map for continuous batching. Payload-agnostic:
/// the serving loop stores its in-flight stream state (`ActiveSeq`), the
/// tests store plain markers.
#[derive(Debug)]
pub struct ContinuousScheduler<T> {
    slots: Vec<Option<T>>,
    active: usize,
}

impl<T> ContinuousScheduler<T> {
    /// A scheduler with `capacity` slots (at least 1).
    pub fn new(capacity: usize) -> ContinuousScheduler<T> {
        let capacity = capacity.max(1);
        let mut slots = Vec::with_capacity(capacity);
        slots.resize_with(capacity, || None);
        ContinuousScheduler { slots, active: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn active_count(&self) -> usize {
        self.active
    }

    pub fn is_empty(&self) -> bool {
        self.active == 0
    }

    pub fn has_free(&self) -> bool {
        self.active < self.slots.len()
    }

    /// Admit into the lowest free slot; `None` when every slot is busy.
    pub fn admit(&mut self, item: T) -> Option<usize> {
        let slot = self.slots.iter().position(|s| s.is_none())?;
        // audit:allow(index) -- slot comes from position() over this same vec, in bounds by construction.
        self.slots[slot] = Some(item);
        self.active += 1;
        Some(slot)
    }

    pub fn get_mut(&mut self, slot: usize) -> Option<&mut T> {
        self.slots.get_mut(slot)?.as_mut()
    }

    /// Evict a completed sequence, freeing its slot for the next arrival.
    pub fn release(&mut self, slot: usize) -> Option<T> {
        let item = self.slots.get_mut(slot)?.take();
        if item.is_some() {
            self.active -= 1;
        }
        item
    }

    /// Active slots in ascending slot order (the deterministic step order).
    pub fn iter_active_mut(&mut self) -> impl Iterator<Item = (usize, &mut T)> + '_ {
        self.slots.iter_mut().enumerate().filter_map(|(i, s)| s.as_mut().map(|t| (i, t)))
    }
}

/// A request tagged with arrival time, its resolved deadline, the KV
/// units the admission gate reserved for it, an optional prefix-cache
/// hit, and a reply handle.
pub struct Pending<Reply> {
    pub request: Request,
    pub arrived: Instant,
    /// Absolute deadline resolved at admission (the request's own
    /// `deadline_ms`, else the server default TTL); `None` = no deadline.
    pub deadline: Option<Instant>,
    /// KV units (pages on the native path) that
    /// [`AdmissionGate::try_enqueue`] reserved for this request. Carried
    /// with the request so whichever path finishes it (completion,
    /// expiry, crash drain) releases exactly what was taken.
    pub kv_reserved: usize,
    /// Prefix-cache hit resolved at admission. Looked up on the listener
    /// thread so the gate can reserve only the uncovered suffix, and so
    /// the hit's `Arc` page pins ride with the request — the shared pages
    /// cannot be evicted between admission and worker attach.
    pub prefix: Option<PrefixHit>,
    pub reply: Reply,
}

impl<Reply> Pending<Reply> {
    /// An untracked pending entry (tests / internal batch helpers): no
    /// deadline, nothing reserved, no prefix hit.
    pub fn untracked(request: Request, reply: Reply) -> Pending<Reply> {
        Pending {
            request,
            arrived: Instant::now(),
            deadline: None,
            kv_reserved: 0,
            prefix: None,
            reply,
        }
    }

    /// Whether this request's deadline has passed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Why the admission gate refused a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shed {
    /// The bounded request queue is at `max_queue`.
    QueueFull,
    /// Admitting would push reserved KV bytes past the budget.
    KvBudget,
}

impl Shed {
    /// The wire status a shed maps to.
    pub fn status(self) -> Status {
        match self {
            Shed::QueueFull => Status::ShedQueueFull,
            Shed::KvBudget => Status::ShedKvBudget,
        }
    }
}

/// Bounded-admission gate: a queue-depth cap plus a KV capacity budget,
/// both enforced with lock-free reservation (CAS loops) so connection
/// threads shed load without serializing on a mutex. The gate is
/// *conservative*: the caller computes the request's worst-case KV need
/// in whatever unit the budget is denominated in — the native path
/// reserves **pages** via [`DecodeEngine::pages_for_rows`][pfr], net of
/// whole chunks a prefix-cache hit will attach instead of allocating —
/// and the reservation is released when the request reaches any terminal
/// outcome, so the sum of live streams' pages can never exceed the
/// budget. Either limit set to 0 disables that check
/// ([`AdmissionGate::unbounded`] disables both).
///
/// [pfr]: crate::runtime::native::DecodeEngine::pages_for_rows
#[derive(Debug)]
pub struct AdmissionGate {
    max_queue: usize,
    kv_budget: usize,
    queued: AtomicUsize,
    kv_reserved: AtomicUsize,
}

impl AdmissionGate {
    pub fn new(max_queue: usize, kv_budget: usize) -> AdmissionGate {
        AdmissionGate {
            max_queue,
            kv_budget,
            queued: AtomicUsize::new(0),
            kv_reserved: AtomicUsize::new(0),
        }
    }

    /// A gate that admits everything (both limits disabled).
    pub fn unbounded() -> AdmissionGate {
        AdmissionGate::new(0, 0)
    }

    /// The KV capacity budget this gate enforces (0 = disabled).
    pub fn kv_budget(&self) -> usize {
        self.kv_budget
    }

    /// Admit a request into the queue, reserving `need` worst-case KV
    /// units against the budget. Returns the reserved count (0 when the
    /// budget is disabled) to carry on the `Pending`; on shed, nothing is
    /// reserved and the caller answers with `Shed::status()`.
    pub fn try_enqueue(&self, need: usize) -> Result<usize, Shed> {
        if self.max_queue > 0 {
            let admit = self
                .queued
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |q| {
                    (q < self.max_queue).then_some(q + 1)
                });
            if admit.is_err() {
                return Err(Shed::QueueFull);
            }
        } else {
            self.queued.fetch_add(1, Ordering::SeqCst);
        }
        let need = if self.kv_budget > 0 { need } else { 0 };
        if need > 0 {
            let reserve = self
                .kv_reserved
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |r| {
                    r.checked_add(need).filter(|&total| total <= self.kv_budget)
                });
            if reserve.is_err() {
                // Roll the queue slot back: the request was never admitted.
                self.queued.fetch_sub(1, Ordering::SeqCst);
                return Err(Shed::KvBudget);
            }
        }
        Ok(need)
    }

    /// A previously admitted request left the queue (a worker picked it
    /// up, or it was dropped at shutdown).
    pub fn dequeued(&self) {
        let prev = self.queued.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev > 0, "dequeued() without a matching try_enqueue()");
    }

    /// Release a reservation made by [`AdmissionGate::try_enqueue`] —
    /// called with the `Pending`'s `kv_reserved` on every terminal
    /// outcome. Zero (no budget / nothing reserved) is a no-op.
    pub fn release_kv(&self, units: usize) {
        if units > 0 {
            let prev = self.kv_reserved.fetch_sub(units, Ordering::SeqCst);
            debug_assert!(prev >= units, "release_kv({units}) exceeds outstanding reservation");
        }
    }

    /// Requests currently between admission and worker pickup.
    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// KV units (pages on the native path) currently reserved for
    /// admitted-but-unfinished requests.
    pub fn kv_reserved(&self) -> usize {
        self.kv_reserved.load(Ordering::SeqCst)
    }
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard cap — the lowered executable's batch dimension.
    pub max_batch: usize,
    /// Max time to hold a non-empty partial batch.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Drain the next batch from `rx` under `policy`. Blocks for the first
/// request (or returns None when the queue is closed), then collects more
/// until the batch fills or `max_wait` elapses.
pub fn next_batch<R>(rx: &Receiver<Pending<R>>, policy: &BatchPolicy) -> Option<Vec<Pending<R>>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + policy.max_wait;
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(p) => batch.push(p),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// The batcher-thread loop: drain batches from `rx` under `policy` and
/// hand each batch to the shared worker queue `out` (every worker holds
/// the matching receiver behind a mutex and pulls when free, so load
/// balances to whichever worker is idle).
///
/// `out` should be a small-capacity [`SyncSender`] (the server uses a
/// rendezvous channel): batches are sealed at **handoff** time, not at
/// drain time — while every worker is busy the batcher keeps topping the
/// pending batch up from the request queue (up to `max_batch`), so
/// saturated workers always receive the fullest batch available instead
/// of eager `max_wait`-sized fragments padded to the lowered batch size.
///
/// Reports each *successfully handed-off* batch size to `on_batch`
/// (metrics hook) — a batch dropped because every worker died is not
/// counted. Returns when the request queue closes (after handing off any
/// final partial batch) or every worker is gone.
pub fn run_batcher<R, F: FnMut(usize)>(
    rx: &Receiver<Pending<R>>,
    policy: &BatchPolicy,
    out: &SyncSender<Vec<Pending<R>>>,
    mut on_batch: F,
) {
    let blocking_handoff = |batch: Vec<Pending<R>>, on_batch: &mut F| -> bool {
        let size = batch.len();
        if out.send(batch).is_err() {
            return false; // every worker has exited
        }
        on_batch(size);
        true
    };
    while let Some(mut batch) = next_batch(rx, policy) {
        loop {
            if batch.len() >= policy.max_batch {
                // Nothing more can join: wait for a worker.
                if !blocking_handoff(batch, &mut on_batch) {
                    return;
                }
                break;
            }
            let size = batch.len();
            match out.try_send(batch) {
                Ok(()) => {
                    on_batch(size);
                    break;
                }
                Err(TrySendError::Disconnected(_)) => return,
                Err(TrySendError::Full(b)) => {
                    // Every worker is busy: keep the batch open and top it
                    // up while waiting, rechecking every max_wait.
                    batch = b;
                    match rx.recv_timeout(policy.max_wait) {
                        Ok(p) => batch.push(p),
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => {
                            // No more requests will arrive: hand off the
                            // final batch (blocking) and finish.
                            let _ = blocking_handoff(batch, &mut on_batch);
                            return;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::{channel, sync_channel};

    fn req(id: u64) -> Pending<()> {
        Pending::untracked(Request::next_token(id, vec![1, 2]), ())
    }

    #[test]
    fn pending_deadline_expiry() {
        let mut p = req(1);
        let now = Instant::now();
        assert!(!p.expired(now), "no deadline → never expires");
        p.deadline = Some(now + Duration::from_millis(50));
        assert!(!p.expired(now));
        assert!(p.expired(now + Duration::from_millis(50)));
        assert!(p.expired(now + Duration::from_secs(1)));
    }

    #[test]
    fn gate_unbounded_admits_everything() {
        let gate = AdmissionGate::unbounded();
        assert_eq!(gate.kv_budget(), 0);
        for _ in 0..100 {
            // Whatever need the caller computes, a disabled budget
            // reserves nothing.
            assert_eq!(gate.try_enqueue(64), Ok(0));
        }
        assert_eq!(gate.queued(), 100);
        assert_eq!(gate.kv_reserved(), 0, "no budget → nothing reserved");
        for _ in 0..100 {
            gate.dequeued();
            gate.release_kv(0);
        }
        assert_eq!(gate.queued(), 0);
    }

    #[test]
    fn gate_sheds_on_queue_depth_and_recovers() {
        let gate = AdmissionGate::new(2, 0);
        assert!(gate.try_enqueue(0).is_ok());
        assert!(gate.try_enqueue(0).is_ok());
        assert_eq!(gate.try_enqueue(0), Err(Shed::QueueFull));
        assert_eq!(Shed::QueueFull.status(), Status::ShedQueueFull);
        // Draining one admits one again.
        gate.dequeued();
        assert!(gate.try_enqueue(0).is_ok());
        assert_eq!(gate.queued(), 2);
    }

    #[test]
    fn gate_reserves_worst_case_kv_and_rolls_back_on_shed() {
        // A 100-page budget with 40-page requests: two fit, the third
        // sheds without leaking its queue slot or reservation.
        let gate = AdmissionGate::new(0, 100);
        let reserved = gate.try_enqueue(40).unwrap();
        assert_eq!(reserved, 40);
        assert_eq!(gate.kv_reserved(), 40);
        // A second fits (80 ≤ 100); a third does not.
        assert_eq!(gate.try_enqueue(40), Ok(40));
        assert_eq!(gate.try_enqueue(40), Err(Shed::KvBudget));
        assert_eq!(Shed::KvBudget.status(), Status::ShedKvBudget);
        // The shed rolled its queue slot back too.
        assert_eq!(gate.queued(), 2, "shed request must not occupy a queue slot");
        assert_eq!(gate.kv_reserved(), 80, "shed request must not leak reservation");
        // Terminal outcome releases exactly what was reserved.
        gate.dequeued();
        gate.release_kv(reserved);
        assert_eq!(gate.kv_reserved(), 40);
        assert_eq!(gate.try_enqueue(40), Ok(40));
        // A prefix-discounted request (smaller need) still fits where a
        // cold one would shed — the dedup-aware admission property.
        assert_eq!(gate.try_enqueue(40), Err(Shed::KvBudget));
        assert_eq!(gate.try_enqueue(20), Ok(20));
    }

    #[test]
    fn gate_is_race_free_under_concurrent_admission() {
        use std::sync::Arc;
        // 8 threads hammer a gate with room for exactly 16 queue slots and
        // 16 two-page reservations; the accepted total must match the
        // limits exactly (no overshoot, no lost slots).
        let gate = Arc::new(AdmissionGate::new(16, 16 * 2));
        let accepted: usize = (0..8)
            .map(|_| {
                let gate = Arc::clone(&gate);
                std::thread::spawn(move || (0..64).filter(|_| gate.try_enqueue(2).is_ok()).count())
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .sum();
        assert_eq!(accepted, 16, "exactly the queue capacity admits");
        assert_eq!(gate.queued(), 16);
        assert_eq!(gate.kv_reserved(), 16 * 2);
    }

    #[test]
    fn scheduler_admits_into_lowest_free_slot() {
        let mut s: ContinuousScheduler<u64> = ContinuousScheduler::new(3);
        assert!(s.is_empty() && s.has_free());
        assert_eq!(s.admit(10), Some(0));
        assert_eq!(s.admit(11), Some(1));
        assert_eq!(s.admit(12), Some(2));
        assert_eq!(s.active_count(), 3);
        assert!(!s.has_free());
        assert_eq!(s.admit(13), None, "full map must refuse admission");
    }

    #[test]
    fn scheduler_eviction_frees_slots_for_reuse() {
        let mut s: ContinuousScheduler<u64> = ContinuousScheduler::new(2);
        s.admit(1);
        s.admit(2);
        assert_eq!(s.release(0), Some(1));
        assert_eq!(s.active_count(), 1);
        assert!(s.has_free());
        // Mid-flight admission: the freed slot is reused while slot 1 is
        // still in flight.
        assert_eq!(s.admit(3), Some(0));
        assert_eq!(s.release(0), Some(3));
        assert_eq!(s.release(1), Some(2));
        assert!(s.is_empty());
        assert_eq!(s.release(1), None, "double release is a no-op");
        assert_eq!(s.release(99), None, "out-of-range slot is a no-op");
    }

    #[test]
    fn scheduler_iterates_in_ascending_slot_order() {
        let mut s: ContinuousScheduler<&'static str> = ContinuousScheduler::new(4);
        s.admit("a");
        s.admit("b");
        s.admit("c");
        s.release(1);
        s.admit("d"); // lands in slot 1
        let seen: Vec<(usize, &str)> = s.iter_active_mut().map(|(i, t)| (i, *t)).collect();
        assert_eq!(seen, vec![(0, "a"), (1, "d"), (2, "c")]);
        if let Some(t) = s.get_mut(2) {
            *t = "c2";
        }
        let seen: Vec<&str> = s.iter_active_mut().map(|(_, t)| *t).collect();
        assert_eq!(seen, vec!["a", "d", "c2"]);
    }

    #[test]
    fn scheduler_capacity_floor_is_one() {
        let mut s: ContinuousScheduler<u8> = ContinuousScheduler::new(0);
        assert_eq!(s.capacity(), 1);
        assert_eq!(s.admit(1), Some(0));
        assert_eq!(s.admit(2), None);
    }

    #[test]
    fn fills_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) };
        let b1 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b1.len(), 4);
        assert_eq!(b1[0].request.id, 0);
        let b2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b2.len(), 4);
        let b3 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b3.len(), 2, "partial batch after queue drains");
    }

    #[test]
    fn partial_batch_after_timeout() {
        let (tx, rx) = channel();
        tx.send(req(1)).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4), "waited for more work");
        drop(tx);
    }

    #[test]
    fn closed_queue_yields_none() {
        let (tx, rx) = channel::<Pending<()>>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn late_arrivals_join_the_batch() {
        let (tx, rx) = channel();
        tx.send(req(1)).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            tx.send(req(2)).unwrap();
            tx // keep alive
        });
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(40) };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 2, "late request should join");
        drop(handle.join().unwrap());
    }

    #[test]
    fn run_batcher_drains_everything_in_order() {
        let (tx, rx) = channel();
        for i in 0..17 {
            tx.send(req(i)).unwrap();
        }
        drop(tx);
        // Enough capacity that the single-threaded test never blocks.
        let (btx, brx) = sync_channel::<Vec<Pending<()>>>(32);
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) };
        let mut sizes = Vec::new();
        run_batcher(&rx, &policy, &btx, |n| sizes.push(n));
        let got: Vec<u64> =
            brx.try_iter().flat_map(|b| b.into_iter().map(|p| p.request.id)).collect();
        assert_eq!(got, (0..17).collect::<Vec<u64>>(), "nothing lost, FIFO preserved");
        assert_eq!(sizes.iter().sum::<usize>(), 17);
        assert!(sizes.iter().all(|s| *s <= 4));
    }

    #[test]
    fn run_batcher_pulled_by_competing_workers() {
        // Two consumer threads share the batch queue behind a mutex (the
        // worker-pool pattern): every request is served exactly once and a
        // dead consumer never strands work.
        use std::sync::{Arc, Mutex};
        let (tx, rx) = channel();
        for i in 0..40 {
            tx.send(req(i)).unwrap();
        }
        drop(tx);
        // Rendezvous handoff, exactly like the server wires it.
        let (btx, brx) = sync_channel::<Vec<Pending<()>>>(0);
        let shared = Arc::new(Mutex::new(brx));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    let mut ids = Vec::new();
                    loop {
                        let batch = { shared.lock().unwrap().recv() };
                        let Ok(batch) = batch else { break };
                        ids.extend(batch.iter().map(|p| p.request.id));
                    }
                    ids
                })
            })
            .collect();
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) };
        let mut batches = 0usize;
        run_batcher(&rx, &policy, &btx, |_| batches += 1);
        drop(btx); // queue closed: workers drain and exit
        let mut got: Vec<u64> = workers.into_iter().flat_map(|w| w.join().unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, (0..40).collect::<Vec<u64>>(), "each request served exactly once");
        assert!(batches >= 10, "max_batch=4 over 40 requests");
    }

    #[test]
    fn run_batcher_stops_when_workers_are_gone() {
        let (tx, rx) = channel();
        for i in 0..5 {
            tx.send(req(i)).unwrap();
        }
        drop(tx);
        let (btx, brx) = sync_channel::<Vec<Pending<()>>>(0);
        drop(brx); // all workers dead before the first batch
        let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(200) };
        let mut counted = 0usize;
        run_batcher(&rx, &policy, &btx, |n| counted += n);
        assert_eq!(counted, 0, "dropped batches must not be counted as served");
    }

    #[test]
    fn property_batches_preserve_order_and_cover_all() {
        // Proptest-style invariant: for random request streams, batching
        // must preserve FIFO order and lose nothing.
        use crate::tensor::Rng;
        let mut rng = Rng::seed(99);
        for _ in 0..20 {
            let n = 1 + rng.below(30);
            let (tx, rx) = channel();
            for i in 0..n {
                tx.send(req(i as u64)).unwrap();
            }
            drop(tx);
            let policy = BatchPolicy {
                max_batch: 1 + rng.below(7),
                max_wait: Duration::from_micros(200),
            };
            let mut seen = Vec::new();
            while let Some(b) = next_batch(&rx, &policy) {
                assert!(b.len() <= policy.max_batch);
                seen.extend(b.iter().map(|p| p.request.id));
            }
            let want: Vec<u64> = (0..n as u64).collect();
            assert_eq!(seen, want);
        }
    }
}
