//! Dynamic batcher — the vLLM-router-style heart of the coordinator.
//!
//! Requests arrive on an MPSC queue; the batcher drains up to `max_batch`
//! of them, waiting at most `max_wait` after the first request before
//! dispatching a partial batch (latency/throughput knob). Batches go to the
//! worker that owns the PJRT executable.

use super::protocol::Request;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// A request tagged with arrival time and a reply handle.
pub struct Pending<Reply> {
    pub request: Request,
    pub arrived: Instant,
    pub reply: Reply,
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard cap — the lowered executable's batch dimension.
    pub max_batch: usize,
    /// Max time to hold a non-empty partial batch.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Drain the next batch from `rx` under `policy`. Blocks for the first
/// request (or returns None when the queue is closed), then collects more
/// until the batch fills or `max_wait` elapses.
pub fn next_batch<R>(rx: &Receiver<Pending<R>>, policy: &BatchPolicy) -> Option<Vec<Pending<R>>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + policy.max_wait;
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(p) => batch.push(p),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64) -> Pending<()> {
        Pending { request: Request { id, tokens: vec![1, 2] }, arrived: Instant::now(), reply: () }
    }

    #[test]
    fn fills_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) };
        let b1 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b1.len(), 4);
        assert_eq!(b1[0].request.id, 0);
        let b2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b2.len(), 4);
        let b3 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b3.len(), 2, "partial batch after queue drains");
    }

    #[test]
    fn partial_batch_after_timeout() {
        let (tx, rx) = channel();
        tx.send(req(1)).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4), "waited for more work");
        drop(tx);
    }

    #[test]
    fn closed_queue_yields_none() {
        let (tx, rx) = channel::<Pending<()>>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn late_arrivals_join_the_batch() {
        let (tx, rx) = channel();
        tx.send(req(1)).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            tx.send(req(2)).unwrap();
            tx // keep alive
        });
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(40) };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 2, "late request should join");
        drop(handle.join().unwrap());
    }

    #[test]
    fn property_batches_preserve_order_and_cover_all() {
        // Proptest-style invariant: for random request streams, batching
        // must preserve FIFO order and lose nothing.
        use crate::tensor::Rng;
        let mut rng = Rng::seed(99);
        for _ in 0..20 {
            let n = 1 + rng.below(30);
            let (tx, rx) = channel();
            for i in 0..n {
                tx.send(req(i as u64)).unwrap();
            }
            drop(tx);
            let policy = BatchPolicy {
                max_batch: 1 + rng.below(7),
                max_wait: Duration::from_micros(200),
            };
            let mut seen = Vec::new();
            while let Some(b) = next_batch(&rx, &policy) {
                assert!(b.len() <= policy.max_batch);
                seen.extend(b.iter().map(|p| p.request.id));
            }
            let want: Vec<u64> = (0..n as u64).collect();
            assert_eq!(seen, want);
        }
    }
}
