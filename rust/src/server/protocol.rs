//! Wire protocol of the serving coordinator: length-prefixed binary frames
//! over TCP (the offline image has no HTTP/serde crates; a purpose-built
//! frame format keeps the hot path allocation-light).
//!
//! Frame layout (little-endian):
//! ```text
//! request : u32 len | u64 id | u16 max_new | u16 n_tokens | n_tokens × u32
//! response: u32 len | u64 id | u32 token | f32 logprob | u32 latency_us
//!           | u16 index | u16 of
//! ```
//!
//! A request asks for `max_new` greedy continuation tokens; the
//! continuous-batching native engine **streams** one response frame per
//! generated token, tagged `index`/`of` so the client knows when the
//! stream is complete (`index + 1 == of`). The server may clamp `of`
//! below the requested `max_new` (never below 1, never above
//! [`MAX_NEW_CAP`]); the PJRT batch path always answers a single frame
//! (`of = 1`). Responses to different requests pipelined on one
//! connection may interleave — group by `id`.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Hard server-side cap on tokens generated per request, bounding KV-cache
/// growth for a single stream.
pub const MAX_NEW_CAP: u16 = 1024;

/// A generation request: score the context, then stream `max_new` greedy
/// continuation tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Greedy tokens to generate (engines clamp to `[1, MAX_NEW_CAP]`).
    pub max_new: u16,
}

/// One streamed token: the greedy next token + its log-probability +
/// server latency, at position `index` of a stream of `of`.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub token: u32,
    pub logprob: f32,
    pub latency_us: u32,
    /// Zero-based position of this token in the response stream.
    pub index: u16,
    /// Total frames this request's stream will carry.
    pub of: u16,
}

impl Request {
    /// Single next-token request (`max_new = 1`) — the classic scoring
    /// call every pre-decode client and the PJRT path use.
    pub fn next_token(id: u64, tokens: Vec<usize>) -> Request {
        Request { id, tokens, max_new: 1 }
    }

    /// Multi-token generation request.
    pub fn generate(id: u64, tokens: Vec<usize>, max_new: u16) -> Request {
        Request { id, tokens, max_new }
    }

    pub fn encode(&self) -> Vec<u8> {
        let body_len = 8 + 2 + 2 + 4 * self.tokens.len();
        let mut buf = Vec::with_capacity(4 + body_len);
        buf.extend_from_slice(&(body_len as u32).to_le_bytes());
        buf.extend_from_slice(&self.id.to_le_bytes());
        buf.extend_from_slice(&self.max_new.to_le_bytes());
        buf.extend_from_slice(&(self.tokens.len() as u16).to_le_bytes());
        for t in &self.tokens {
            buf.extend_from_slice(&(*t as u32).to_le_bytes());
        }
        buf
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Request> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("read frame length")?;
        let len = u32::from_le_bytes(len4) as usize;
        if len < 12 || len > 1 << 20 {
            bail!("bad request frame length {len}");
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body).context("read frame body")?;
        let id = u64::from_le_bytes(body[0..8].try_into()?);
        let max_new = u16::from_le_bytes(body[8..10].try_into()?);
        let n = u16::from_le_bytes(body[10..12].try_into()?) as usize;
        if body.len() != 12 + 4 * n {
            bail!("request frame length mismatch");
        }
        let tokens = body[12..]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect();
        Ok(Request { id, tokens, max_new })
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(4 + 24);
        buf.extend_from_slice(&24u32.to_le_bytes());
        buf.extend_from_slice(&self.id.to_le_bytes());
        buf.extend_from_slice(&self.token.to_le_bytes());
        buf.extend_from_slice(&self.logprob.to_le_bytes());
        buf.extend_from_slice(&self.latency_us.to_le_bytes());
        buf.extend_from_slice(&self.index.to_le_bytes());
        buf.extend_from_slice(&self.of.to_le_bytes());
        buf
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Response> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("read frame length")?;
        let len = u32::from_le_bytes(len4) as usize;
        if len != 24 {
            bail!("bad response frame length {len}");
        }
        let mut body = [0u8; 24];
        r.read_exact(&mut body)?;
        Ok(Response {
            id: u64::from_le_bytes(body[0..8].try_into()?),
            token: u32::from_le_bytes(body[8..12].try_into()?),
            logprob: f32::from_le_bytes(body[12..16].try_into()?),
            latency_us: u32::from_le_bytes(body[16..20].try_into()?),
            index: u16::from_le_bytes(body[20..22].try_into()?),
            of: u16::from_le_bytes(body[22..24].try_into()?),
        })
    }

    /// Whether this frame completes its stream.
    pub fn is_last(&self) -> bool {
        self.index + 1 >= self.of
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(&self.encode())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_roundtrip() {
        let req = Request { id: 42, tokens: vec![1, 2, 300, 7], max_new: 16 };
        let bytes = req.encode();
        let got = Request::read_from(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(got, req);
    }

    #[test]
    fn next_token_constructor_asks_for_one() {
        let req = Request::next_token(9, vec![1, 2]);
        assert_eq!(req.max_new, 1);
        let got = Request::read_from(&mut Cursor::new(req.encode())).unwrap();
        assert_eq!(got, req);
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response { id: 7, token: 123, logprob: -1.5, latency_us: 987, index: 2, of: 4 };
        let bytes = resp.encode();
        let got = Response::read_from(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(got, resp);
        assert!(!got.is_last());
        let last = Response { index: 3, ..resp };
        assert!(last.is_last());
    }

    #[test]
    fn rejects_garbage_length() {
        let mut bytes = vec![0xFF, 0xFF, 0xFF, 0x7F];
        bytes.extend_from_slice(&[0; 16]);
        assert!(Request::read_from(&mut Cursor::new(bytes)).is_err());
    }

    #[test]
    fn empty_token_request_roundtrip() {
        let req = Request { id: 0, tokens: vec![], max_new: 1 };
        let got = Request::read_from(&mut Cursor::new(req.encode())).unwrap();
        assert_eq!(got.tokens.len(), 0);
    }
}
