//! Wire protocol of the serving coordinator: length-prefixed binary frames
//! over TCP (the offline image has no HTTP/serde crates; a purpose-built
//! frame format keeps the hot path allocation-light).
//!
//! Frame layout (little-endian):
//! ```text
//! request : u32 len | u64 id | u16 max_new | u16 n_tokens
//!           | u32 deadline_ms | n_tokens × u32
//! response: u32 len | u64 id | u32 token | f32 logprob | u32 latency_us
//!           | u16 index | u16 of | u8 status
//! ```
//!
//! A request asks for `max_new` greedy continuation tokens; the
//! continuous-batching native engine **streams** one response frame per
//! generated token, tagged `index`/`of` so the client knows when the
//! stream is complete (`index + 1 == of`). The server may clamp `of`
//! below the requested `max_new` (never below 1, never above
//! [`MAX_NEW_CAP`]); the PJRT batch path always answers a single frame
//! (`of = 1`). Responses to different requests pipelined on one
//! connection may interleave — group by `id`.
//!
//! **Resilience extensions.** `deadline_ms` is a per-request TTL (0 = no
//! deadline beyond the server default); `status` reports how the stream
//! ended ([`Status`]): `Ok` token frames, or a single terminal error
//! frame when the request was shed at admission ([`Status::ShedQueueFull`]
//! / [`Status::ShedKvBudget`]), rejected as invalid, expired past its
//! deadline, or lost to a worker crash. A non-`Ok` frame always
//! terminates its stream. Both extensions are backward compatible: the
//! reader accepts the pre-deadline request body (12 + 4n bytes) and the
//! pre-status response body (24 bytes).

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Hard server-side cap on tokens generated per request, bounding KV-cache
/// growth for a single stream.
pub const MAX_NEW_CAP: u16 = 1024;

/// How a response stream ended (the last frame's `status` byte).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(u8)]
pub enum Status {
    /// A generated-token frame (streams of these end at `index+1 == of`).
    #[default]
    Ok = 0,
    /// Shed at admission: the bounded request queue was full.
    ShedQueueFull = 1,
    /// Shed at admission: the request's worst-case KV bytes exceeded the
    /// remaining KV budget.
    ShedKvBudget = 2,
    /// Rejected by validation (`max_new == 0`, prompt beyond the model
    /// context, …) — retrying the identical request cannot succeed.
    Invalid = 3,
    /// The request's deadline passed before the stream completed; the
    /// frame's `index` tells how many tokens were streamed first.
    Expired = 4,
    /// A worker crashed (or its engine failed) while this request was in
    /// flight; the sequence was drained, its slot and pages freed.
    Crashed = 5,
}

impl Status {
    pub fn from_u8(b: u8) -> Result<Status> {
        Ok(match b {
            0 => Status::Ok,
            1 => Status::ShedQueueFull,
            2 => Status::ShedKvBudget,
            3 => Status::Invalid,
            4 => Status::Expired,
            5 => Status::Crashed,
            other => bail!("unknown response status byte {other}"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::ShedQueueFull => "shed-queue-full",
            Status::ShedKvBudget => "shed-kv-budget",
            Status::Invalid => "invalid",
            Status::Expired => "expired",
            Status::Crashed => "crashed",
        }
    }

    /// Whether a client retry can succeed. Shed and crash outcomes are
    /// transient (load drains, workers restart); `Invalid` and `Expired`
    /// are definitive for the request as sent.
    pub fn retryable(self) -> bool {
        matches!(self, Status::ShedQueueFull | Status::ShedKvBudget | Status::Crashed)
    }
}

/// A generation request: score the context, then stream `max_new` greedy
/// continuation tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Greedy tokens to generate (engines clamp to `[1, MAX_NEW_CAP]`).
    pub max_new: u16,
    /// Per-request TTL in milliseconds from server-side arrival; 0 means
    /// "no request-specific deadline" (the server default, if any,
    /// applies). Enforced at admission, in the queue, and between decode
    /// steps — an expired stream ends with a [`Status::Expired`] frame.
    pub deadline_ms: u32,
}

/// One streamed token: the greedy next token + its log-probability +
/// server latency, at position `index` of a stream of `of`.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub token: u32,
    pub logprob: f32,
    pub latency_us: u32,
    /// Zero-based position of this token in the response stream.
    pub index: u16,
    /// Total frames this request's stream will carry.
    pub of: u16,
    /// [`Status::Ok`] for token frames; any other value terminates the
    /// stream (shed/invalid/expired/crashed).
    pub status: Status,
}

impl Request {
    /// Single next-token request (`max_new = 1`) — the classic scoring
    /// call every pre-decode client and the PJRT path use.
    pub fn next_token(id: u64, tokens: Vec<usize>) -> Request {
        Request { id, tokens, max_new: 1, deadline_ms: 0 }
    }

    /// Multi-token generation request.
    pub fn generate(id: u64, tokens: Vec<usize>, max_new: u16) -> Request {
        Request { id, tokens, max_new, deadline_ms: 0 }
    }

    /// `self` with a per-request TTL attached.
    pub fn with_deadline_ms(mut self, deadline_ms: u32) -> Request {
        self.deadline_ms = deadline_ms;
        self
    }

    /// Admission-time semantic validation (the frame itself already
    /// parsed). Rejects requests the engine could only fail on:
    /// `max_new == 0` (an empty stream can never terminate the protocol's
    /// `index+1 == of` contract) and prompts longer than the model
    /// context (`max_prompt`), which would silently truncate.
    pub fn validate(&self, max_prompt: usize) -> std::result::Result<(), Status> {
        if self.max_new == 0 {
            return Err(Status::Invalid);
        }
        if self.tokens.len() > max_prompt {
            return Err(Status::Invalid);
        }
        Ok(())
    }

    pub fn encode(&self) -> Vec<u8> {
        let body_len = 8 + 2 + 2 + 4 + 4 * self.tokens.len();
        let mut buf = Vec::with_capacity(4 + body_len);
        buf.extend_from_slice(&(body_len as u32).to_le_bytes());
        buf.extend_from_slice(&self.id.to_le_bytes());
        buf.extend_from_slice(&self.max_new.to_le_bytes());
        buf.extend_from_slice(&(self.tokens.len() as u16).to_le_bytes());
        buf.extend_from_slice(&self.deadline_ms.to_le_bytes());
        for t in &self.tokens {
            buf.extend_from_slice(&(*t as u32).to_le_bytes());
        }
        buf
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Request> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("read frame length")?;
        let len = u32::from_le_bytes(len4) as usize;
        if len < 12 || len > 1 << 20 {
            bail!("bad request frame length {len}");
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body).context("read frame body")?;
        let id = u64::from_le_bytes(body[0..8].try_into()?);
        let max_new = u16::from_le_bytes(body[8..10].try_into()?);
        let n = u16::from_le_bytes(body[10..12].try_into()?) as usize;
        // Two accepted layouts: the pre-deadline body (12 + 4n) and the
        // current one carrying deadline_ms (16 + 4n). Anything else is a
        // framing error.
        let (deadline_ms, tok_off) = if body.len() == 16 + 4 * n {
            (u32::from_le_bytes(body[12..16].try_into()?), 16)
        } else if body.len() == 12 + 4 * n {
            (0, 12)
        } else {
            bail!("request frame length mismatch");
        };
        let tokens = body[tok_off..]
            .chunks_exact(4)
            // audit:allow(panic) -- chunks_exact(4) yields exactly 4-byte slices; try_into cannot fail.
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect();
        Ok(Request { id, tokens, max_new, deadline_ms })
    }
}

impl Response {
    /// A terminal error frame: no token, `index` = tokens streamed before
    /// the failure, `of = index + 1` so [`Response::is_last`] holds for
    /// stream-agnostic readers too.
    pub fn error(id: u64, status: Status, index: u16) -> Response {
        debug_assert!(status != Status::Ok, "error frames carry a non-Ok status");
        Response {
            id,
            token: 0,
            logprob: 0.0,
            latency_us: 0,
            index,
            of: index.saturating_add(1),
            status,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(4 + 25);
        buf.extend_from_slice(&25u32.to_le_bytes());
        buf.extend_from_slice(&self.id.to_le_bytes());
        buf.extend_from_slice(&self.token.to_le_bytes());
        buf.extend_from_slice(&self.logprob.to_le_bytes());
        buf.extend_from_slice(&self.latency_us.to_le_bytes());
        buf.extend_from_slice(&self.index.to_le_bytes());
        buf.extend_from_slice(&self.of.to_le_bytes());
        buf.push(self.status as u8);
        buf
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Response> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("read frame length")?;
        let len = u32::from_le_bytes(len4) as usize;
        // 24: pre-status body (implicitly Ok). 25: current body.
        if len != 24 && len != 25 {
            bail!("bad response frame length {len}");
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        // audit:allow(index) -- len == 25 is checked above, so byte 24 exists.
        let status = if len == 25 { Status::from_u8(body[24])? } else { Status::Ok };
        Ok(Response {
            id: u64::from_le_bytes(body[0..8].try_into()?),
            token: u32::from_le_bytes(body[8..12].try_into()?),
            logprob: f32::from_le_bytes(body[12..16].try_into()?),
            latency_us: u32::from_le_bytes(body[16..20].try_into()?),
            index: u16::from_le_bytes(body[20..22].try_into()?),
            of: u16::from_le_bytes(body[22..24].try_into()?),
            status,
        })
    }

    /// Whether this frame completes its stream: the final token frame, or
    /// any terminal error frame.
    pub fn is_last(&self) -> bool {
        self.status != Status::Ok || self.index + 1 >= self.of
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(&self.encode())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_roundtrip() {
        let req = Request { id: 42, tokens: vec![1, 2, 300, 7], max_new: 16, deadline_ms: 250 };
        let bytes = req.encode();
        let got = Request::read_from(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(got, req);
    }

    #[test]
    fn next_token_constructor_asks_for_one() {
        let req = Request::next_token(9, vec![1, 2]);
        assert_eq!(req.max_new, 1);
        assert_eq!(req.deadline_ms, 0);
        let got = Request::read_from(&mut Cursor::new(req.encode())).unwrap();
        assert_eq!(got, req);
    }

    #[test]
    fn legacy_request_body_without_deadline_parses() {
        // The pre-deadline layout: u64 id | u16 max_new | u16 n | n × u32.
        let mut body = Vec::new();
        body.extend_from_slice(&7u64.to_le_bytes());
        body.extend_from_slice(&3u16.to_le_bytes());
        body.extend_from_slice(&2u16.to_le_bytes());
        body.extend_from_slice(&11u32.to_le_bytes());
        body.extend_from_slice(&12u32.to_le_bytes());
        let mut frame = (body.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&body);
        let got = Request::read_from(&mut Cursor::new(frame)).unwrap();
        assert_eq!(got, Request { id: 7, tokens: vec![11, 12], max_new: 3, deadline_ms: 0 });
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response {
            id: 7,
            token: 123,
            logprob: -1.5,
            latency_us: 987,
            index: 2,
            of: 4,
            status: Status::Ok,
        };
        let bytes = resp.encode();
        let got = Response::read_from(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(got, resp);
        assert!(!got.is_last());
        let last = Response { index: 3, ..resp };
        assert!(last.is_last());
    }

    #[test]
    fn legacy_response_body_without_status_parses_as_ok() {
        let resp = Response {
            id: 9,
            token: 4,
            logprob: -0.25,
            latency_us: 10,
            index: 0,
            of: 1,
            status: Status::Ok,
        };
        // Strip the status byte and rewrite the length prefix to 24.
        let mut bytes = resp.encode();
        bytes.truncate(4 + 24);
        bytes[0..4].copy_from_slice(&24u32.to_le_bytes());
        let got = Response::read_from(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(got, resp);
    }

    #[test]
    fn error_frames_terminate_their_stream() {
        let terminal = [
            Status::ShedQueueFull,
            Status::ShedKvBudget,
            Status::Invalid,
            Status::Expired,
            Status::Crashed,
        ];
        for status in terminal {
            let e = Response::error(3, status, 2);
            assert!(e.is_last(), "{status:?} must be terminal");
            assert_eq!(e.index, 2, "tokens-streamed-so-far survives");
            let got = Response::read_from(&mut Cursor::new(e.encode())).unwrap();
            assert_eq!(got, e, "{status:?} roundtrip");
            assert_eq!(got.status.label(), status.label());
        }
        // Even at index 0 of a longer advertised stream, a non-Ok status
        // terminates: is_last consults status before index/of.
        let mid = Response { of: 10, ..Response::error(1, Status::Expired, 0) };
        assert!(mid.is_last());
    }

    #[test]
    fn status_retryability_split() {
        assert!(Status::ShedQueueFull.retryable());
        assert!(Status::ShedKvBudget.retryable());
        assert!(Status::Crashed.retryable());
        assert!(!Status::Ok.retryable());
        assert!(!Status::Invalid.retryable());
        assert!(!Status::Expired.retryable());
        assert!(Status::from_u8(99).is_err());
        for s in [Status::Ok, Status::ShedKvBudget, Status::Crashed] {
            assert_eq!(Status::from_u8(s as u8).unwrap(), s);
        }
    }

    #[test]
    fn validation_rejects_unservable_requests() {
        let ok = Request::generate(1, vec![1, 2, 3], 4);
        assert!(ok.validate(8).is_ok());
        let zero = Request { max_new: 0, ..ok.clone() };
        assert_eq!(zero.validate(8), Err(Status::Invalid));
        let long = Request::generate(2, vec![0; 9], 1);
        assert_eq!(long.validate(8), Err(Status::Invalid));
        assert!(long.validate(9).is_ok());
    }

    #[test]
    fn rejects_garbage_length() {
        let mut bytes = vec![0xFF, 0xFF, 0xFF, 0x7F];
        bytes.extend_from_slice(&[0; 16]);
        assert!(Request::read_from(&mut Cursor::new(bytes)).is_err());
    }

    #[test]
    fn empty_token_request_roundtrip() {
        let req = Request { id: 0, tokens: vec![], max_new: 1, deadline_ms: 0 };
        let got = Request::read_from(&mut Cursor::new(req.encode())).unwrap();
        assert_eq!(got.tokens.len(), 0);
    }
}
