//! Wire protocol of the serving coordinator: length-prefixed binary frames
//! over TCP (the offline image has no HTTP/serde crates; a purpose-built
//! frame format keeps the hot path allocation-light).
//!
//! Frame layout (little-endian):
//! ```text
//! request : u32 len | u64 id | u16 n_tokens | n_tokens × u32
//! response: u32 len | u64 id | u32 token | f32 logprob | u32 latency_us
//! ```

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// A completion request: score the context, return the argmax next token.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<usize>,
}

/// The response: greedy next token + its log-probability + server latency.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub token: u32,
    pub logprob: f32,
    pub latency_us: u32,
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let body_len = 8 + 2 + 4 * self.tokens.len();
        let mut buf = Vec::with_capacity(4 + body_len);
        buf.extend_from_slice(&(body_len as u32).to_le_bytes());
        buf.extend_from_slice(&self.id.to_le_bytes());
        buf.extend_from_slice(&(self.tokens.len() as u16).to_le_bytes());
        for t in &self.tokens {
            buf.extend_from_slice(&(*t as u32).to_le_bytes());
        }
        buf
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Request> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("read frame length")?;
        let len = u32::from_le_bytes(len4) as usize;
        if len < 10 || len > 1 << 20 {
            bail!("bad request frame length {len}");
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body).context("read frame body")?;
        let id = u64::from_le_bytes(body[0..8].try_into()?);
        let n = u16::from_le_bytes(body[8..10].try_into()?) as usize;
        if body.len() != 10 + 4 * n {
            bail!("request frame length mismatch");
        }
        let tokens = body[10..]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect();
        Ok(Request { id, tokens })
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(4 + 20);
        buf.extend_from_slice(&20u32.to_le_bytes());
        buf.extend_from_slice(&self.id.to_le_bytes());
        buf.extend_from_slice(&self.token.to_le_bytes());
        buf.extend_from_slice(&self.logprob.to_le_bytes());
        buf.extend_from_slice(&self.latency_us.to_le_bytes());
        buf
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Response> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("read frame length")?;
        let len = u32::from_le_bytes(len4) as usize;
        if len != 20 {
            bail!("bad response frame length {len}");
        }
        let mut body = [0u8; 20];
        r.read_exact(&mut body)?;
        Ok(Response {
            id: u64::from_le_bytes(body[0..8].try_into()?),
            token: u32::from_le_bytes(body[8..12].try_into()?),
            logprob: f32::from_le_bytes(body[12..16].try_into()?),
            latency_us: u32::from_le_bytes(body[16..20].try_into()?),
        })
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(&self.encode())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_roundtrip() {
        let req = Request { id: 42, tokens: vec![1, 2, 300, 7] };
        let bytes = req.encode();
        let got = Request::read_from(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(got, req);
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response { id: 7, token: 123, logprob: -1.5, latency_us: 987 };
        let bytes = resp.encode();
        let got = Response::read_from(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(got, resp);
    }

    #[test]
    fn rejects_garbage_length() {
        let mut bytes = vec![0xFF, 0xFF, 0xFF, 0x7F];
        bytes.extend_from_slice(&[0; 16]);
        assert!(Request::read_from(&mut Cursor::new(bytes)).is_err());
    }

    #[test]
    fn empty_token_request_roundtrip() {
        let req = Request { id: 0, tokens: vec![] };
        let got = Request::read_from(&mut Cursor::new(req.encode())).unwrap();
        assert_eq!(got.tokens.len(), 0);
    }
}
