//! The serving coordinator: TCP listener → router → scheduler →
//! **worker pool** → per-connection reply writers. Thread-based (std
//! only); Python is nowhere on this path.
//!
//! Two execution **engines** behind one listener/queue front end:
//!
//! * **PJRT** ([`Server::start`]) — batch-then-drain: connection threads
//!   push requests onto one MPSC queue; a dedicated batcher thread drains
//!   them under the [`BatchPolicy`] onto a shared batch queue, which
//!   `workers` worker threads pull from whenever they are free. Each
//!   worker compiles its own copy of a lowered HLO artifact (the xla
//!   crate's PJRT handles are `!Send`, so each worker owns its *entire*
//!   PJRT lifecycle and only plain data crosses threads). Requests are
//!   answered with a single next token (`of = 1`).
//! * **Native** ([`Server::start_native`]) — **continuous batching**:
//!   `workers` decode loops share one [`DecodeEngine`] (read-only
//!   `Arc<Transformer>` + KV-cache policy) and pull requests straight off
//!   the shared queue *between decode steps*. Each loop owns a
//!   [`ContinuousScheduler`] slot map: new requests are admitted into
//!   free slots mid-flight (a fresh sequence prefills in the same step
//!   its batch mates decode), every active sequence advances one greedy
//!   token per step — streamed to its client immediately, tagged
//!   `index`/`of` — and completed sequences are evicted at once, freeing
//!   the slot and its KV-cache page. With
//!   [`Transformer::prepack_quantized_weights`] applied first, every step
//!   runs the real fixed-point QGEMM over weight planes packed exactly
//!   once (any of the five block formats, through the unified
//!   `QuantizedMatrix` API), and the KV cache itself can hold quantized
//!   planes (`NativeServerConfig::kv`) — quantized serving end to end
//!   with no XLA runtime required.
//!
//! **Resilience layer** ([`ResilienceConfig`], DESIGN.md §13). Both
//! engines share the same failure model:
//!
//! * *Validation*: inbound requests are checked at the listener
//!   ([`Request::validate`]) — `max_new == 0` or an over-context prompt
//!   answers a terminal [`Status::Invalid`] frame instead of reaching an
//!   engine.
//! * *Bounded admission*: an [`AdmissionGate`] caps queue depth and (on
//!   the native path) reserved KV **pages** from the global
//!   [`PagePool`] — dedup-aware: a prefix-cache hit reserves only the
//!   uncovered suffix; overload sheds with a structured
//!   [`Status::ShedQueueFull`] / [`Status::ShedKvBudget`] frame instead
//!   of blocking or OOMing.
//! * *Deadlines*: each request's TTL (its own `deadline_ms`, else the
//!   server default) is enforced at queue pickup and between decode
//!   steps; expired work answers [`Status::Expired`] (carrying how many
//!   tokens were streamed), frees its slot and recycles its KV page.
//! * *Panic isolation*: worker bodies run under `catch_unwind`; a panic
//!   (injected or genuine) drains that worker's in-flight sequences to
//!   [`Status::Crashed`] frames, releases their reservations, and
//!   restarts the loop with a clean slot map — the server never
//!   deadlocks or aborts. Locks shared with a panicking thread are
//!   recovered ([`lock_recover`]), not unwrapped.
//! * *Client retry*: [`Client::generate_retrying`] retries retryable
//!   outcomes (shed/crashed/connection loss) with capped exponential
//!   backoff and deterministic jitter ([`RetryPolicy`]).

use super::batcher::{run_batcher, AdmissionGate, BatchPolicy, ContinuousScheduler, Pending};
use super::faults::{mix64, FaultPlan};
use super::metrics::Metrics;
use super::protocol::{Request, Response, Status, MAX_NEW_CAP};
use crate::model::kv::KvCacheType;
use crate::model::pages::{PagePool, PageShape, PrefixHit, DEFAULT_PAGE_ROWS};
use crate::model::transformer::{greedy_from_row, Transformer};
use crate::runtime::artifact::{Manifest, ParamStore};
use crate::runtime::client::{literal_f32, tokens_literal, Executable, Runtime};
use crate::runtime::native::{DecodeEngine, DecodeStream};
use crate::util::lock_recover;
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Overload/failure knobs shared by both engines. The default is fully
/// permissive (no deadline, unbounded admission, no fault injection) —
/// exactly the pre-resilience behavior.
#[derive(Debug, Clone, Default)]
pub struct ResilienceConfig {
    /// Default per-request TTL applied when a request's own `deadline_ms`
    /// is 0; `None` = requests without a TTL never expire.
    pub request_timeout: Option<Duration>,
    /// Max requests between admission and worker pickup; 0 = unbounded.
    /// Beyond it, requests shed with [`Status::ShedQueueFull`].
    pub max_queue: usize,
    /// Budget for worst-case KV memory reserved by admitted-but-
    /// unfinished requests (native engine only); 0 = unbounded. The
    /// native path rounds it down to whole pages of the global
    /// [`PagePool`] (floor 1) and the gate reserves **pages**, net of
    /// whole chunks a prefix-cache hit shares. Beyond the budget,
    /// requests shed with [`Status::ShedKvBudget`].
    pub kv_budget_bytes: usize,
    /// Deterministic fault injection (chaos tests/benches; `--faults`).
    pub faults: Option<Arc<FaultPlan>>,
}

/// PJRT server configuration.
pub struct ServerConfig {
    /// Artifact to serve, e.g. "fwd_bf16.hlo.txt" or "fwd_hif4.hlo.txt".
    pub artifact: String,
    pub policy: BatchPolicy,
    /// Worker threads; each compiles its own copy of the executable
    /// and pulls batches from the shared queue when free. 0 is treated
    /// as 1.
    pub workers: usize,
    /// Deadlines/backpressure/fault-injection knobs (`kv_budget_bytes`
    /// is inert here — the PJRT path holds no KV cache).
    pub resilience: ResilienceConfig,
}

/// Native-engine server configuration.
pub struct NativeServerConfig {
    /// `policy.max_batch` is the continuous-batching slot count per
    /// decode loop; `max_wait` is unused by the native engine (admission
    /// happens between decode steps).
    pub policy: BatchPolicy,
    /// Decode loops sharing one `Arc<Transformer>`. 0 is treated as 1.
    pub workers: usize,
    /// Max *prompt* tokens per request (longer prompts are rejected at
    /// validation with [`Status::Invalid`]).
    pub seq: usize,
    /// KV-cache storage backend for every stream (`--kv-cache` /
    /// `HIF4_KV_CACHE`).
    pub kv: KvCacheType,
    /// Deadlines/backpressure/fault-injection knobs.
    pub resilience: ResilienceConfig,
    /// Shared-prefix dedup (`--prefix-cache` / `HIF4_PREFIX_CACHE`,
    /// default off): completed prefills register their whole-page chunks
    /// in the pool's prefix index; later requests sharing a prompt
    /// prefix attach those pages by refcount instead of recomputing and
    /// re-storing them. Greedy output is bit-identical either way.
    pub prefix_cache: bool,
    /// Prefill chunk budget in tokens per decode step (`--prefill-chunk`
    /// / `HIF4_PREFILL_CHUNK`; 0 = whole prompt in one step): long
    /// prompts prefill incrementally, interleaved with their batch
    /// mates' decode steps, instead of starving the batch.
    pub prefill_chunk: usize,
    /// Rows per fixed-size KV page (`--kv-page-rows` /
    /// `HIF4_KV_PAGE_ROWS`; default [`DEFAULT_PAGE_ROWS`]). Any value is
    /// group-aligned by construction (pages hold whole rows, rows hold
    /// whole plane groups).
    pub page_rows: usize,
}

impl Default for NativeServerConfig {
    /// Default serving configuration with the paging knobs resolved from
    /// the process environment (`HIF4_PREFIX_CACHE`, `HIF4_PREFILL_CHUNK`,
    /// `HIF4_KV_PAGE_ROWS`) — so tests/benches built with
    /// `..Default::default()` honor the CI matrix legs. CLI flags resolve
    /// in `main.rs` and override these.
    fn default() -> Self {
        NativeServerConfig {
            policy: BatchPolicy::default(),
            workers: 1,
            seq: 16,
            kv: KvCacheType::F32,
            resilience: ResilienceConfig::default(),
            prefix_cache: prefix_cache_from_env(),
            prefill_chunk: prefill_chunk_from_env(),
            page_rows: page_rows_from_env(),
        }
    }
}

/// Resolve the `HIF4_PREFIX_CACHE` env knob (`1`/`on`/`true`, case-
/// insensitive ⇒ enabled; unset/anything else ⇒ off).
pub fn prefix_cache_from_env() -> bool {
    std::env::var("HIF4_PREFIX_CACHE")
        .map(|v| {
            let v = v.to_ascii_lowercase();
            v == "1" || v == "on" || v == "true"
        })
        .unwrap_or(false)
}

/// Resolve the `HIF4_PREFILL_CHUNK` env knob (tokens per prefill step;
/// unset/unparsable/0 ⇒ whole-prompt prefill).
pub fn prefill_chunk_from_env() -> usize {
    std::env::var("HIF4_PREFILL_CHUNK").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// Resolve the `HIF4_KV_PAGE_ROWS` env knob (rows per KV page; default
/// [`DEFAULT_PAGE_ROWS`], floor 1).
pub fn page_rows_from_env() -> usize {
    std::env::var("HIF4_KV_PAGE_ROWS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_PAGE_ROWS)
        .max(1)
}

type ReplyHandle = Arc<Mutex<TcpStream>>;

/// One batch-then-drain worker's executor: turns a pending batch into
/// responses (the PJRT pipeline; the native engine runs the continuous
/// [`decode_worker_loop`] instead). Engines are constructed *inside*
/// their worker thread by an [`EngineFactory`] (PJRT handles are
/// `!Send`), so the engine itself never crosses threads.
trait BatchEngine {
    fn run(&mut self, pending: &[Pending<ReplyHandle>]) -> Result<Vec<Response>>;
}

/// Thread-safe constructor handed to every worker thread.
type EngineFactory = Arc<dyn Fn(usize) -> Result<Box<dyn BatchEngine>> + Send + Sync>;

/// PJRT engine: one compiled executable + parameter literals per worker.
struct PjrtEngine {
    exe: Executable,
    param_literals: Vec<xla::Literal>,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl BatchEngine for PjrtEngine {
    fn run(&mut self, pending: &[Pending<ReplyHandle>]) -> Result<Vec<Response>> {
        run_batch(&self.exe, &self.param_literals, pending, self.batch, self.seq, self.vocab)
    }
}

/// One continuous-batching slot: the original request (its reply handle
/// streams every token), the decode stream with its KV-cache page, and
/// stream-progress bookkeeping.
struct ActiveSeq {
    pending: Pending<ReplyHandle>,
    stream: DecodeStream,
    emitted: u16,
    of: u16,
}

/// Per-request admission plan: how many KV units (pages on the native
/// path) the gate must reserve, plus the prefix-cache hit (if any) whose
/// `Arc` clones pin the shared pages against eviction until a worker
/// attaches them. Runs on the listener thread so the reservation is
/// dedup-aware *before* `try_enqueue`.
type AdmissionPlan = Arc<dyn Fn(&Request) -> (usize, Option<PrefixHit>) + Send + Sync>;

/// Everything the listener needs to admit (or refuse) a request before
/// it touches the queue: the gate, the validation context, the default
/// TTL, and the engine-specific admission plan.
struct ListenerCtx {
    gate: Arc<AdmissionGate>,
    max_prompt: usize,
    default_timeout: Option<Duration>,
    plan: AdmissionPlan,
}

impl ListenerCtx {
    /// Resolve a request's absolute deadline from its own TTL (beats the
    /// server default) or the server default.
    fn deadline_for(&self, req: &Request, arrived: Instant) -> Option<Instant> {
        match req.deadline_ms {
            0 => self.default_timeout.map(|t| arrived + t),
            ms => Some(arrived + Duration::from_millis(ms as u64)),
        }
    }
}

/// A running server (listener + batcher + worker-pool threads).
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    gate: Arc<AdmissionGate>,
    stop: Arc<AtomicBool>,
    listener_thread: Option<JoinHandle<()>>,
    batcher_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Compile the artifact on `cfg.workers` dedicated worker threads, bind
    /// `addr` (port 0 for ephemeral) and start serving `params` via PJRT.
    pub fn start(
        artifacts_dir: &Path,
        cfg: ServerConfig,
        params: &ParamStore,
        addr: &str,
    ) -> Result<Server> {
        let manifest = Manifest::load(artifacts_dir)?;
        // One shared weight copy: every worker builds its literals from the
        // same Arc'd store instead of deep-cloning per worker (the factory
        // drops inside each worker after setup, so the store frees once
        // the last worker is ready).
        let shared_params = Arc::new(params.clone());
        let (batch, seq, vocab) = (manifest.batch, manifest.seq, manifest.vocab);
        let artifact_path: PathBuf = manifest.artifact(&cfg.artifact);
        let factory: EngineFactory = Arc::new(move |_wi| {
            let runtime = Runtime::cpu()?;
            let exe = runtime.load(&artifact_path)?;
            let param_literals = shared_params.literals()?;
            Ok(Box::new(PjrtEngine { exe, param_literals, batch, seq, vocab })
                as Box<dyn BatchEngine>)
        });
        // Clamp to the artifact's lowered batch dimension — a larger
        // max_batch would make run_batch truncate the token rows but still
        // index logits for every pending request (out of bounds).
        let mut policy = cfg.policy;
        policy.max_batch = policy.max_batch.clamp(1, manifest.batch);
        // Attribute the counters to the served artifact's format via the
        // shared sniffing rule (the PJRT path has no KV cache and no
        // resident quantized planes).
        let format = crate::formats::QuantKind::from_artifact_name(&cfg.artifact)
            .map(|k| k.spelling())
            .unwrap_or("bf16");
        // No KV cache on this path: the gate only bounds queue depth
        // (a zero budget disables KV reservations entirely).
        let gate = Arc::new(AdmissionGate::new(cfg.resilience.max_queue, 0));
        let server =
            start_engine(policy, cfg.workers.max(1), addr, factory, gate, &cfg.resilience, seq)?;
        // "f32": the PJRT path has no quantized cache, and the tag stays
        // inside the f32/QuantKind-spelling vocabulary every consumer of
        // the kv axis parses.
        server.metrics.set_format_tag(format, "f32", 0);
        Ok(server)
    }

    /// Serve the rust-native `model` with `cfg.workers` continuous-
    /// batching decode loops — no PJRT, no artifacts. Each loop admits
    /// requests into a [`ContinuousScheduler`] slot map between decode
    /// steps and streams one response frame per generated token.
    /// Quantized serving: call
    /// [`Transformer::prepack_quantized_weights`] before handing the
    /// model over, and every step runs the fixed-point QGEMM over weight
    /// planes packed once; `cfg.kv` additionally stores the KV cache in
    /// a quantized format.
    pub fn start_native(
        model: Arc<Transformer>,
        cfg: NativeServerConfig,
        addr: &str,
    ) -> Result<Server> {
        // Attribute every counter to the active quantization config: the
        // prepacked weight format (one QuantKind across linears by
        // construction), the KV-cache kind, and the resident quantized
        // weight bytes in the canonical wire form.
        let weight_format = model.quantized_weight_kind().map(|k| k.spelling()).unwrap_or("bf16");
        let weight_wire = model.quantized_weight_wire_bytes() as u64;
        // Every stream's cache draws fixed-size pages from one global
        // pool; the byte budget becomes a page cap (floor 1 so a tiny
        // budget still bounds rather than deadlocks admission).
        let kvd = model.cfg.kv_heads() * model.cfg.head_dim;
        let shape = PageShape::new(cfg.kv, kvd, cfg.page_rows.max(1));
        let max_pages = match cfg.resilience.kv_budget_bytes {
            0 => 0,
            budget => (budget / shape.page_bytes()).max(1),
        };
        let pool = Arc::new(PagePool::new(shape, max_pages, cfg.prefix_cache));
        let engine = Arc::new(
            DecodeEngine::new(model, cfg.kv, cfg.seq.max(1))
                .with_pool(Arc::clone(&pool))
                .with_prefill_chunk(cfg.prefill_chunk),
        );
        let metrics = Arc::new(Metrics::new());
        metrics.set_format_tag(weight_format, cfg.kv.label(), weight_wire);
        // One startup line naming the resolved attention schedule and
        // paging config — serving measurements must be attributable to
        // fused vs replay and to the dedup/prefill knobs (greedy tokens
        // are identical either way; throughput and residency are not).
        let cap = if max_pages == 0 { "unbounded".to_string() } else { max_pages.to_string() };
        let chunk = match cfg.prefill_chunk {
            0 => "whole-prompt".to_string(),
            n => format!("{n} tok"),
        };
        eprintln!(
            "native server: weights {weight_format}, kv {}, attention {}, page {}r/{}B \
             (max {cap}), prefix cache {}, prefill chunk {chunk}",
            cfg.kv.label(),
            engine.attn_label(),
            pool.page_rows(),
            pool.page_bytes(),
            if cfg.prefix_cache { "on" } else { "off" },
        );
        let stop = Arc::new(AtomicBool::new(false));
        // The gate's KV budget is denominated in *pages*: the listener's
        // admission plan asks the engine for the worst-case page count of
        // each request net of prefix-shared chunks.
        let gate = Arc::new(AdmissionGate::new(cfg.resilience.max_queue, max_pages));
        // Dedup-aware admission plan, run on the listener thread: the
        // prefix lookup both sizes the reservation (shared chunks are
        // free) and pins the hit pages via the Arc clones carried on the
        // Pending until the worker attaches them.
        let plan_engine = Arc::clone(&engine);
        let plan_metrics = Arc::clone(&metrics);
        let plan_pool = Arc::clone(&pool);
        let plan: AdmissionPlan = Arc::new(move |req: &Request| {
            let prompt = plan_engine.normalize_prompt(&req.tokens);
            let rows = prompt.len() + req.max_new.clamp(1, MAX_NEW_CAP) as usize;
            let pool = &plan_pool;
            let hit = if pool.prefix_enabled() { pool.lookup_prefix(&prompt) } else { None };
            plan_metrics.record_prefix_lookup(hit.is_some());
            let need = plan_engine.pages_for_rows(rows, hit.as_ref().map_or(0, |h| h.chunks()));
            (need, hit)
        });
        let (tx, rx) = channel::<Pending<ReplyHandle>>();
        let rx = Arc::new(Mutex::new(rx));
        let max_slots = cfg.policy.max_batch.max(1);
        let n_workers = cfg.workers.max(1);
        let mut worker_threads = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let wrx = Arc::clone(&rx);
            let wengine = Arc::clone(&engine);
            let wmetrics = Arc::clone(&metrics);
            let wgate = Arc::clone(&gate);
            let wfaults = cfg.resilience.faults.clone();
            let handle = std::thread::Builder::new()
                .name(format!("hif4-decode-{wi}"))
                .spawn(move || {
                    decode_worker_supervised(wengine, wrx, max_slots, wmetrics, wgate, wfaults, wi)
                })
                .context("spawn decode worker")?;
            worker_threads.push(handle);
        }
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let listen_metrics = Arc::clone(&metrics);
        let listen_stop = Arc::clone(&stop);
        let ctx = Arc::new(ListenerCtx {
            gate: Arc::clone(&gate),
            max_prompt: engine.max_prompt(),
            default_timeout: cfg.resilience.request_timeout,
            plan,
        });
        let listener_thread = std::thread::Builder::new()
            .name("hif4-listener".into())
            .spawn(move || listener_loop(listener, tx, listen_metrics, listen_stop, ctx))
            .context("spawn listener")?;
        Ok(Server {
            addr: local,
            metrics,
            gate,
            stop,
            listener_thread: Some(listener_thread),
            batcher_thread: None,
            worker_threads,
        })
    }

    /// The admission gate (tests/benches observe queue depth and
    /// outstanding KV reservations through it).
    pub fn admission(&self) -> &AdmissionGate {
        &self.gate
    }

    /// Signal shutdown (threads exit on their next poll/disconnect).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener out of accept() with a dummy connection.
        let _ = TcpStream::connect(self.addr);
    }
}

/// Shared pipeline bring-up: spawn `n_workers` worker threads (each
/// constructing its engine in-thread via `factory`), the batcher and the
/// listener, wired exactly as described in the module docs.
fn start_engine(
    policy: BatchPolicy,
    n_workers: usize,
    addr: &str,
    factory: EngineFactory,
    gate: Arc<AdmissionGate>,
    resilience: &ResilienceConfig,
    max_prompt: usize,
) -> Result<Server> {
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<Pending<ReplyHandle>>();

    // Worker pool: each worker owns its engine and pulls batches from one
    // shared queue when free.
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    // Rendezvous handoff: while every worker is busy the batcher blocks
    // here and the request queue keeps accumulating, so the next drain
    // coalesces the backlog into full batches (no padded fragments).
    let (batch_tx, batch_rx) = sync_channel::<Vec<Pending<ReplyHandle>>>(0);
    let batch_rx = Arc::new(Mutex::new(batch_rx));
    let mut worker_threads = Vec::with_capacity(n_workers);
    for wi in 0..n_workers {
        let wrx = Arc::clone(&batch_rx);
        let ready_tx = ready_tx.clone();
        let worker_metrics = Arc::clone(&metrics);
        let worker_factory = Arc::clone(&factory);
        let worker_gate = Arc::clone(&gate);
        let worker_faults = resilience.faults.clone();
        let handle = std::thread::Builder::new()
            .name(format!("hif4-worker-{wi}"))
            .spawn(move || {
                let setup = worker_factory(wi);
                // Engine built (or failed); release this worker's handle on
                // the factory and whatever setup state it captured.
                drop(worker_factory);
                match setup {
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                    Ok(engine) => {
                        let _ = ready_tx.send(Ok(()));
                        worker_loop(engine, wrx, worker_metrics, worker_gate, worker_faults, wi);
                    }
                }
            })
            .context("spawn worker")?;
        worker_threads.push(handle);
    }
    drop(ready_tx);
    drop(batch_rx); // workers hold the only receiver clones now
    drop(factory); // workers hold the remaining factory handles
    for _ in 0..n_workers {
        ready_rx.recv().context("worker died during setup")??;
    }

    // Batcher: drains the request queue into the shared batch queue.
    let batcher_metrics = Arc::clone(&metrics);
    let batcher_thread = std::thread::Builder::new()
        .name("hif4-batcher".into())
        .spawn(move || {
            run_batcher(&rx, &policy, &batch_tx, |n| {
                batcher_metrics.record_batch(n);
            });
        })
        .context("spawn batcher")?;

    // Listener: a thread per connection reads requests into the queue.
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let listen_metrics = Arc::clone(&metrics);
    let listen_stop = Arc::clone(&stop);
    let ctx = Arc::new(ListenerCtx {
        gate: Arc::clone(&gate),
        max_prompt,
        default_timeout: resilience.request_timeout,
        // The PJRT path has no KV cache: nothing to reserve, nothing to
        // dedup.
        plan: Arc::new(|_| (0, None)),
    });
    let listener_thread = std::thread::Builder::new()
        .name("hif4-listener".into())
        .spawn(move || listener_loop(listener, tx, listen_metrics, listen_stop, ctx))
        .context("spawn listener")?;

    Ok(Server {
        addr: local,
        metrics,
        gate,
        stop,
        listener_thread: Some(listener_thread),
        batcher_thread: Some(batcher_thread),
        worker_threads,
    })
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        // Join in pipeline order: closing the listener drops the request
        // queue, which stops the batcher, which closes the worker queues.
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.batcher_thread.take() {
            let _ = t.join();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Write one frame to a (shared) reply stream, recovering the lock if a
/// panicking thread poisoned it. A vanished client makes the write fail —
/// that is a silent drop by design: the frame has nowhere to go, and
/// per-frame logging under chaos (dropped-connection injection) would
/// drown real diagnostics.
fn send_frame(reply: &ReplyHandle, resp: &Response) {
    let mut stream = lock_recover(reply);
    if resp.write_to(&mut *stream).is_ok() {
        let _ = stream.flush();
    }
}

/// Terminal error frame for a request that never produced tokens.
fn send_error(reply: &ReplyHandle, id: u64, status: Status) {
    send_frame(reply, &Response::error(id, status, 0));
}

fn listener_loop(
    listener: TcpListener,
    tx: Sender<Pending<ReplyHandle>>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    ctx: Arc<ListenerCtx>,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let tx = tx.clone();
        let metrics = Arc::clone(&metrics);
        let ctx = Arc::clone(&ctx);
        let _ = std::thread::Builder::new().name("hif4-conn".into()).spawn(move || {
            let reader = match stream.try_clone() {
                Ok(r) => r,
                Err(e) => {
                    // Connection-scoped failure: drop this client, keep
                    // the server up.
                    eprintln!("serve: cannot clone connection stream: {e}");
                    return;
                }
            };
            let reply: ReplyHandle = Arc::new(Mutex::new(stream));
            let mut reader = std::io::BufReader::new(reader);
            // Read frames until the client hangs up (or sends a frame the
            // protocol cannot resync after — framing is length-prefixed,
            // so a malformed/oversized frame ends the connection; the
            // *semantic* failures below answer structured errors and keep
            // the connection).
            while let Ok(req) = Request::read_from(&mut reader) {
                metrics.record_request();
                let arrived = Instant::now();
                if req.validate(ctx.max_prompt).is_err() {
                    metrics.record_invalid();
                    send_error(&reply, req.id, Status::Invalid);
                    continue;
                }
                // Engine-specific sizing: pages needed net of any
                // prefix-cache hit (whose Arc clones ride on the Pending
                // to pin the shared pages until worker attach). On a
                // shed, dropping `prefix` releases the pins.
                let (need, prefix) = (ctx.plan)(&req);
                let kv_reserved = match ctx.gate.try_enqueue(need) {
                    Ok(units) => units,
                    Err(shed) => {
                        metrics.record_shed(shed.status());
                        send_error(&reply, req.id, shed.status());
                        continue;
                    }
                };
                let deadline = ctx.deadline_for(&req, arrived);
                let reply = Arc::clone(&reply);
                let pending =
                    Pending { request: req, arrived, deadline, kv_reserved, prefix, reply };
                if tx.send(pending).is_err() {
                    // Server shutting down: the request never reached a
                    // worker, so roll its admission back here.
                    ctx.gate.dequeued();
                    ctx.gate.release_kv(kv_reserved);
                    break;
                }
            }
        });
    }
}

/// Answer every request of a failed batch with a terminal `Crashed`
/// frame and release its admission reservation.
fn fail_batch(pending: &[Pending<ReplyHandle>], gate: &AdmissionGate) {
    for p in pending {
        gate.release_kv(p.kv_reserved);
        send_error(&p.reply, p.request.id, Status::Crashed);
    }
}

/// Worker lifecycle is purely channel-driven (exit when the batch queue
/// closes): the batcher may be blocked in a rendezvous `send`, so a worker
/// must never stop pulling before the channel closes or shutdown could
/// deadlock. Each batch executes under `catch_unwind`: a panicking engine
/// (or an injected fault) fails that batch to `Crashed` responses and the
/// worker keeps serving — the supervisor loop is this function itself.
fn worker_loop(
    mut engine: Box<dyn BatchEngine>,
    rx: Arc<Mutex<Receiver<Vec<Pending<ReplyHandle>>>>>,
    metrics: Arc<Metrics>,
    gate: Arc<AdmissionGate>,
    faults: Option<Arc<FaultPlan>>,
    worker: usize,
) {
    let mut step: u64 = 0;
    loop {
        // Lock only for the pull: whichever worker is free takes the next
        // batch (same pattern as util::threadpool::ThreadPool).
        let next = { lock_recover(&rx).recv() };
        let Ok(batch) = next else { break };
        for _ in 0..batch.len() {
            gate.dequeued();
        }
        // Deadline check at pickup: expired requests answer Expired
        // without spending a forward pass.
        let now = Instant::now();
        let mut pending = Vec::with_capacity(batch.len());
        for p in batch {
            if p.expired(now) {
                metrics.record_expired();
                gate.release_kv(p.kv_reserved);
                send_error(&p.reply, p.request.id, Status::Expired);
            } else {
                pending.push(p);
            }
        }
        if pending.is_empty() {
            continue;
        }
        let this_step = step;
        step += 1;
        let result = catch_unwind(AssertUnwindSafe(|| {
            if let Some(f) = &faults {
                f.trip(worker, this_step);
            }
            engine.run(&pending)
        }));
        match result {
            Ok(Ok(responses)) => {
                for (p, mut resp) in pending.iter().zip(responses) {
                    resp.latency_us = p.arrived.elapsed().as_micros() as u32;
                    metrics.record_latency(p.arrived.elapsed());
                    gate.release_kv(p.kv_reserved);
                    send_frame(&p.reply, &resp);
                }
            }
            Ok(Err(e)) => {
                // Engine-reported failure: fail fast for the affected
                // clients with structured Crashed frames (they can retry)
                // and keep the worker alive for the next batch.
                eprintln!("batch execution failed: {e:#}");
                fail_batch(&pending, &gate);
            }
            Err(_panic) => {
                // Panic isolation: the batch is poisoned, the worker is
                // not. Account the restart, drain the batch to Crashed,
                // keep pulling.
                metrics.record_worker_restart();
                fail_batch(&pending, &gate);
            }
        }
    }
}

/// Supervisor for one native decode worker: runs [`decode_worker_loop`]
/// under `catch_unwind` and, when a decode step panics (injected fault or
/// genuine bug), drains every in-flight sequence in this worker's slot
/// map to a terminal [`Status::Crashed`] frame — releasing its admission
/// reservation and dropping its stream (the cache's `Drop` clears each
/// page before returning it to the global pool, so a mid-append page
/// recycles wiped, never inconsistent) — and restarts the loop with a
/// clean slot map. The step counter survives restarts so a seeded fault
/// plan's schedule (`panic_at_step`, per-step rolls) is a single
/// deterministic timeline per worker.
fn decode_worker_supervised(
    engine: Arc<DecodeEngine>,
    rx: Arc<Mutex<Receiver<Pending<ReplyHandle>>>>,
    max_slots: usize,
    metrics: Arc<Metrics>,
    gate: Arc<AdmissionGate>,
    faults: Option<Arc<FaultPlan>>,
    worker: usize,
) {
    let mut sched: ContinuousScheduler<ActiveSeq> = ContinuousScheduler::new(max_slots);
    let mut step: u64 = 0;
    let mut closed = false;
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            decode_worker_loop(
                &engine,
                &rx,
                &mut sched,
                &metrics,
                &gate,
                faults.as_deref(),
                worker,
                &mut step,
                &mut closed,
            )
        }));
        match run {
            Ok(()) => return, // clean shutdown: queue closed, streams done
            Err(_panic) => {
                metrics.record_worker_restart();
                for slot in 0..max_slots {
                    if let Some(a) = sched.release(slot) {
                        gate.release_kv(a.pending.kv_reserved);
                        send_frame(
                            &a.pending.reply,
                            &Response::error(a.pending.request.id, Status::Crashed, a.emitted),
                        );
                    }
                }
            }
        }
    }
}

/// The continuous-batching decode loop (one per native worker):
///
/// ```text
/// loop {
///   admit  — idle: block for a request; busy: drain the queue
///            (non-blocking) into free slots (expired requests answer
///            Expired instead of taking a slot)
///   sweep  — evict slots whose deadline passed (Expired frame carrying
///            tokens-streamed-so-far; pages return to the pool, the
///            reservation frees)
///   fault  — consult the fault plan (chaos: maybe stall or panic)
///   step   — one engine step for every active slot: fresh/chunked slots
///            prefill (no frame — `None`), in-flight slots decode one
///            greedy token, via DecodeEngine::step
///   emit   — stream each produced token to its client immediately
///   evict  — release completed slots (pages return to the pool, the
///            reservation frees)
/// }
/// ```
///
/// Exits when the request queue closes *and* every in-flight stream has
/// completed, so shutdown never truncates a response stream. Panics
/// unwind into [`decode_worker_supervised`], which drains and restarts.
#[allow(clippy::too_many_arguments)]
fn decode_worker_loop(
    engine: &DecodeEngine,
    rx: &Mutex<Receiver<Pending<ReplyHandle>>>,
    sched: &mut ContinuousScheduler<ActiveSeq>,
    metrics: &Metrics,
    gate: &AdmissionGate,
    faults: Option<&FaultPlan>,
    worker: usize,
    step: &mut u64,
    closed: &mut bool,
) {
    // Bound on how long an idle worker holds the shared receiver lock: a
    // plain blocking `recv()` would park *inside* the lock and starve the
    // `try_recv` top-ups of workers with in-flight streams (their decode
    // loops would stall until a brand-new request arrived — a deadlock
    // for sequential clients). Between timeouts the lock is released, so
    // busy workers get through once per step.
    const IDLE_POLL: Duration = Duration::from_millis(1);
    loop {
        if sched.is_empty() {
            if *closed {
                return;
            }
            // Idle: poll for work with a bounded wait (see IDLE_POLL).
            let next = { lock_recover(rx).recv_timeout(IDLE_POLL) };
            match next {
                Ok(p) => admit_or_expire(engine, sched, p, metrics, gate),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
        // In flight: top the slot map up without blocking — admission
        // latency is at most one decode step.
        while !*closed && sched.has_free() {
            let next = { lock_recover(rx).try_recv() };
            match next {
                Ok(p) => admit_or_expire(engine, sched, p, metrics, gate),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => *closed = true,
            }
        }
        // Deadline sweep: evict expired streams *before* spending a
        // decode step on them. Dropping the stream returns its pages to
        // the global pool (shared prefix pages just drop a refcount).
        let now = Instant::now();
        let expired: Vec<usize> = sched
            .iter_active_mut()
            .filter(|(_, a)| a.pending.expired(now))
            .map(|(id, _)| id)
            .collect();
        for id in expired {
            if let Some(a) = sched.release(id) {
                metrics.record_expired();
                gate.release_kv(a.pending.kv_reserved);
                send_frame(
                    &a.pending.reply,
                    &Response::error(a.pending.request.id, Status::Expired, a.emitted),
                );
            }
        }
        if sched.is_empty() {
            continue;
        }
        // Fault-injection hook (None in production): a chaos plan may
        // stall this step (slow-decode) or panic it (→ supervisor).
        let this_step = *step;
        *step += 1;
        if let Some(f) = faults {
            f.trip(worker, this_step);
        }
        // One decode step over every active slot, in slot order.
        let mut ids: Vec<usize> = Vec::new();
        let outs = {
            let mut streams: Vec<&mut DecodeStream> = Vec::new();
            for (id, a) in sched.iter_active_mut() {
                ids.push(id);
                streams.push(&mut a.stream);
            }
            engine.step(&mut streams)
        };
        metrics.record_batch(ids.len());
        for (id, out) in ids.into_iter().zip(outs) {
            // `None` = the slot spent this step on a prefill chunk: no
            // token produced, nothing to emit, the stream stays active.
            let Some((token, logprob)) = out else { continue };
            let done = {
                let Some(a) = sched.get_mut(id) else {
                    // Unreachable by construction (ids came from the
                    // active set and nothing released since); skip rather
                    // than panic if it ever regresses.
                    debug_assert!(false, "stepped slot {id} is no longer active");
                    continue;
                };
                a.emitted += 1;
                let resp = Response {
                    id: a.pending.request.id,
                    token,
                    logprob,
                    latency_us: a.pending.arrived.elapsed().as_micros() as u32,
                    index: a.emitted - 1,
                    of: a.of,
                    status: Status::Ok,
                };
                // Stream immediately; a vanished client just means the
                // remaining (bounded) tokens go nowhere.
                send_frame(&a.pending.reply, &resp);
                a.emitted >= a.of
            };
            if done {
                // Dropping the released stream returns its private pages
                // to the pool's free list and un-pins its shared ones.
                if let Some(a) = sched.release(id) {
                    metrics.record_latency(a.pending.arrived.elapsed());
                    gate.release_kv(a.pending.kv_reserved);
                }
            }
        }
        // Publish pool occupancy after every step so the summary line
        // reflects live paging behavior, not just end-of-run state.
        if let Some(pool) = engine.pool() {
            metrics.set_page_gauges(
                pool.live_pages() as u64,
                pool.high_water() as u64,
                pool.free_pages() as u64,
                pool.shared_refcount_high_water() as u64,
                pool.bytes_saved() as u64,
            );
        }
    }
}

/// Queue pickup on the native path: account the dequeue, answer Expired
/// for requests whose deadline passed while queued, otherwise open a
/// decode stream in a free slot.
fn admit_or_expire(
    engine: &DecodeEngine,
    sched: &mut ContinuousScheduler<ActiveSeq>,
    p: Pending<ReplyHandle>,
    metrics: &Metrics,
    gate: &AdmissionGate,
) {
    gate.dequeued();
    if p.expired(Instant::now()) {
        metrics.record_expired();
        gate.release_kv(p.kv_reserved);
        send_error(&p.reply, p.request.id, Status::Expired);
        return;
    }
    admit_seq(engine, sched, p);
}

/// Open a decode stream for a request — attaching the shared prefix
/// pages its listener-side lookup pinned, if any — and admit it into a
/// free slot (the callers only admit when one exists). The pins on the
/// Pending are dropped once attached: the stream now holds its own Arcs.
fn admit_seq(
    engine: &DecodeEngine,
    sched: &mut ContinuousScheduler<ActiveSeq>,
    mut p: Pending<ReplyHandle>,
) {
    let of = p.request.max_new.clamp(1, MAX_NEW_CAP);
    let prefix = p.prefix.take();
    let stream = engine.start_with_prefix(&p.request.tokens, prefix.as_ref());
    let admitted = sched.admit(ActiveSeq { pending: p, stream, emitted: 0, of });
    debug_assert!(admitted.is_some(), "admit_seq requires a free slot");
}

/// Execute one padded batch and extract each request's next-token argmax.
pub fn run_batch(
    exe: &Executable,
    param_literals: &[xla::Literal],
    pending: &[Pending<impl Sized>],
    batch: usize,
    seq: usize,
    vocab: usize,
) -> Result<Vec<Response>> {
    // Pad the request list to the lowered batch size.
    let mut token_rows: Vec<Vec<usize>> = pending
        .iter()
        .map(|p| {
            let mut t = p.request.tokens.clone();
            t.truncate(seq);
            t
        })
        .collect();
    token_rows.resize_with(batch, || vec![0]);
    let tokens = tokens_literal(&token_rows, seq)?;
    // Borrow-based input list: parameter literals are built once per worker
    // lifetime, only the token literal is fresh per batch (§Perf).
    let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(param_literals.len() + 1);
    inputs.extend(param_literals.iter());
    inputs.push(&tokens);
    let outputs = exe.run(&inputs)?;
    let first = outputs.first().context("executable returned no outputs")?;
    let logits = literal_f32(first)?; // (batch, seq, vocab)
    anyhow::ensure!(
        logits.len() >= batch * seq * vocab,
        "logits output carries {} values, need {}x{}x{}",
        logits.len(),
        batch,
        seq,
        vocab
    );
    let mut responses = Vec::with_capacity(pending.len());
    for (bi, p) in pending.iter().enumerate() {
        let last = p.request.tokens.len().clamp(1, seq) - 1;
        let row = &logits[bi * seq * vocab + last * vocab..][..vocab];
        responses.push(response_from_logits(p.request.id, row));
    }
    Ok(responses)
}

/// Single-frame response (`of = 1`) from one logits row — the batch
/// paths' readout, sharing the greedy argmax/log-softmax with the decode
/// engine ([`greedy_from_row`]).
fn response_from_logits(id: u64, row: &[f32]) -> Response {
    let (token, logprob) = greedy_from_row(row);
    Response {
        id,
        token: token as u32,
        logprob,
        latency_us: 0,
        index: 0,
        of: 1,
        status: Status::Ok,
    }
}

/// Execute one batch on the rust-native model. No padding is needed —
/// the native forward handles ragged batches directly; requests truncate
/// to `seq` tokens, and out-of-vocab ids clamp to the last token so a
/// malformed request can never panic a worker (the lowered path is safe
/// by construction: XLA gathers clamp indices).
pub fn run_batch_native(
    model: &Transformer,
    pending: &[Pending<impl Sized>],
    seq: usize,
) -> Vec<Response> {
    let vocab = model.cfg.vocab;
    let token_rows: Vec<Vec<usize>> = pending
        .iter()
        .map(|p| {
            let mut t: Vec<usize> =
                p.request.tokens.iter().map(|&tok| tok.min(vocab - 1)).collect();
            t.truncate(seq);
            if t.is_empty() {
                t.push(0);
            }
            t
        })
        .collect();
    let logits = model.forward(&token_rows, None, None, None);
    let mut responses = Vec::with_capacity(pending.len());
    let mut base = 0usize;
    for (p, tokens) in pending.iter().zip(&token_rows) {
        let row = logits.row(base + tokens.len() - 1);
        responses.push(response_from_logits(p.request.id, row));
        base += tokens.len();
    }
    responses
}

/// Retry policy for [`Client::generate_retrying`]: capped exponential
/// backoff with deterministic jitter. Attempt `k` (0-based) sleeps
/// `min(base · 2^k, cap)` scaled by a jitter factor in `[0.5, 1.0)`
/// derived from `(seed, k)` — seeded, so chaos runs replay identically
/// while distinct clients (distinct seeds) still decorrelate.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = single shot).
    pub max_retries: u32,
    pub base: Duration,
    pub cap: Duration,
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 4,
            base: Duration::from_millis(20),
            cap: Duration::from_millis(500),
            seed: 0x5eed,
        }
    }
}

impl RetryPolicy {
    /// The backoff before retry number `attempt` (0-based).
    pub fn backoff(&self, attempt: u32) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(16));
        let capped = exp.min(self.cap);
        let jitter = 0.5 + (mix64(self.seed, attempt as u64, 0) % 1000) as f64 / 2000.0;
        capped.mul_f64(jitter)
    }
}

/// Blocking client for examples/benches: send requests, read responses.
pub struct Client {
    addr: std::net::SocketAddr,
    stream: TcpStream,
    reader: std::io::BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        Ok(Client { addr, stream, reader })
    }

    /// Drop the current connection and dial the server again (used by the
    /// retry loop after connection-level failures).
    pub fn reconnect(&mut self) -> Result<()> {
        *self = Client::connect(self.addr)?;
        Ok(())
    }

    /// Fire a request without waiting (pipelining).
    pub fn send(&mut self, req: &Request) -> Result<()> {
        self.stream.write_all(&req.encode())?;
        Ok(())
    }

    /// Read the next response.
    pub fn recv(&mut self) -> Result<Response> {
        Response::read_from(&mut self.reader)
    }

    /// Round-trip one request.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.send(req)?;
        self.recv()
    }

    /// Read one full response stream (frames until [`Response::is_last`]:
    /// the final token frame or any terminal error frame). Assumes a
    /// single outstanding request on this connection — streams of
    /// pipelined requests interleave and must be grouped by `id` instead.
    pub fn recv_stream(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        loop {
            let r = self.recv()?;
            let last = r.is_last();
            out.push(r);
            if last {
                return Ok(out);
            }
        }
    }

    /// Round-trip a generation request: send, then read the whole token
    /// stream.
    pub fn generate(&mut self, req: &Request) -> Result<Vec<Response>> {
        self.send(req)?;
        self.recv_stream()
    }

    /// [`Client::generate`] with resilience: on a retryable terminal
    /// status (shed/crashed) or a connection-level error, back off per
    /// `policy` (reconnecting after I/O errors) and try again, up to
    /// `policy.max_retries` times. Returns the final attempt's stream
    /// plus the number of retries performed; non-retryable outcomes
    /// (`Invalid`, `Expired`) and exhausted budgets return as-is. Decode
    /// is deterministic, so a retried stream's tokens are identical to
    /// what the failed attempt would have produced.
    pub fn generate_retrying(
        &mut self,
        req: &Request,
        policy: &RetryPolicy,
    ) -> Result<(Vec<Response>, u32)> {
        let mut retries = 0u32;
        loop {
            match self.generate(req) {
                Ok(frames) => {
                    let terminal =
                        frames.last().map(|r| r.status).unwrap_or(Status::Crashed);
                    if !terminal.retryable() || retries >= policy.max_retries {
                        return Ok((frames, retries));
                    }
                }
                Err(e) => {
                    if retries >= policy.max_retries {
                        return Err(e);
                    }
                    // The connection may be half-dead (server worker
                    // crashed mid-frame): re-dial before retrying. If the
                    // server itself is gone, surface that error.
                    std::thread::sleep(policy.backoff(retries));
                    retries += 1;
                    self.reconnect()?;
                    continue;
                }
            }
            std::thread::sleep(policy.backoff(retries));
            retries += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_backoff_is_capped_deterministic_and_jittered() {
        let p = RetryPolicy {
            max_retries: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(100),
            seed: 42,
        };
        for k in 0..8 {
            let d = p.backoff(k);
            assert_eq!(d, p.backoff(k), "same (seed, attempt) → same backoff");
            // Jitter keeps every sleep in [0.5, 1.0) × the capped
            // exponential envelope.
            let envelope = Duration::from_millis((10u64 << k).min(100));
            assert!(d >= envelope.mul_f64(0.5), "attempt {k}: {d:?} under floor");
            assert!(d < envelope, "attempt {k}: {d:?} over envelope {envelope:?}");
        }
        // Large attempt numbers must not overflow the shift.
        let _ = p.backoff(u32::MAX);
        // Different seeds decorrelate.
        let q = RetryPolicy { seed: 43, ..p };
        assert!((0..8).any(|k| p.backoff(k) != q.backoff(k)));
    }
}
