//! The serving coordinator: TCP listener → router → dynamic batcher →
//! **worker pool** → per-connection reply writers. Thread-based (std
//! only); Python is nowhere on this path.
//!
//! Pipeline: connection threads push requests onto one MPSC queue; a
//! dedicated batcher thread drains them under the [`BatchPolicy`] onto a
//! shared batch queue, which `workers` worker threads pull from whenever
//! they are free (idle workers pick up the next batch, so a stalled
//! worker never strands a backlog) — the data-parallel serving analogue
//! of the row-parallel QGEMM kernels.
//!
//! Two execution **engines** plug into the same pipeline:
//!
//! * **PJRT** ([`Server::start`]): each worker compiles its own copy of a
//!   lowered HLO artifact. The xla crate's PJRT handles are `!Send`
//!   (Rc-backed), so each worker thread owns its *entire* PJRT lifecycle —
//!   client, executable and parameter literals are created inside the
//!   worker from plain-data inputs, and only plain data crosses threads.
//! * **Native** ([`Server::start_native`]): workers share one
//!   `Arc<Transformer>` and run the rust-native forward. With
//!   [`Transformer::prepack_quantized_weights`] applied first, every
//!   request runs the real fixed-point QGEMM over weight planes packed
//!   exactly once — quantized serving with no decode tax and no XLA
//!   runtime required.

use super::batcher::{run_batcher, BatchPolicy, Pending};
use super::metrics::Metrics;
use super::protocol::{Request, Response};
use crate::model::transformer::Transformer;
use crate::runtime::artifact::{Manifest, ParamStore};
use crate::runtime::client::{literal_f32, tokens_literal, Executable, Runtime};
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// PJRT server configuration.
pub struct ServerConfig {
    /// Artifact to serve, e.g. "fwd_bf16.hlo.txt" or "fwd_hif4.hlo.txt".
    pub artifact: String,
    pub policy: BatchPolicy,
    /// Worker threads; each compiles its own copy of the executable
    /// and pulls batches from the shared queue when free. 0 is treated
    /// as 1.
    pub workers: usize,
}

/// Native-engine server configuration.
pub struct NativeServerConfig {
    pub policy: BatchPolicy,
    /// Worker threads sharing one `Arc<Transformer>`. 0 is treated as 1.
    pub workers: usize,
    /// Max tokens per request (requests truncate to this).
    pub seq: usize,
}

type ReplyHandle = Arc<Mutex<TcpStream>>;

/// One worker's executor: turns a pending batch into responses. Engines
/// are constructed *inside* their worker thread by an [`EngineFactory`]
/// (PJRT handles are `!Send`), so the engine itself never crosses threads.
trait BatchEngine {
    fn run(&mut self, pending: &[Pending<ReplyHandle>]) -> Result<Vec<Response>>;
}

/// Thread-safe constructor handed to every worker thread.
type EngineFactory = Arc<dyn Fn(usize) -> Result<Box<dyn BatchEngine>> + Send + Sync>;

/// PJRT engine: one compiled executable + parameter literals per worker.
struct PjrtEngine {
    exe: Executable,
    param_literals: Vec<xla::Literal>,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl BatchEngine for PjrtEngine {
    fn run(&mut self, pending: &[Pending<ReplyHandle>]) -> Result<Vec<Response>> {
        run_batch(&self.exe, &self.param_literals, pending, self.batch, self.seq, self.vocab)
    }
}

/// Native engine: the shared rust-native model (read-only, `Sync`).
struct NativeEngine {
    model: Arc<Transformer>,
    seq: usize,
}

impl BatchEngine for NativeEngine {
    fn run(&mut self, pending: &[Pending<ReplyHandle>]) -> Result<Vec<Response>> {
        Ok(run_batch_native(&self.model, pending, self.seq))
    }
}

/// A running server (listener + batcher + worker-pool threads).
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    listener_thread: Option<JoinHandle<()>>,
    batcher_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Compile the artifact on `cfg.workers` dedicated worker threads, bind
    /// `addr` (port 0 for ephemeral) and start serving `params` via PJRT.
    pub fn start(
        artifacts_dir: &Path,
        cfg: ServerConfig,
        params: &ParamStore,
        addr: &str,
    ) -> Result<Server> {
        let manifest = Manifest::load(artifacts_dir)?;
        // One shared weight copy: every worker builds its literals from the
        // same Arc'd store instead of deep-cloning per worker (the factory
        // drops inside each worker after setup, so the store frees once
        // the last worker is ready).
        let shared_params = Arc::new(params.clone());
        let (batch, seq, vocab) = (manifest.batch, manifest.seq, manifest.vocab);
        let artifact_path: PathBuf = manifest.artifact(&cfg.artifact);
        let factory: EngineFactory = Arc::new(move |_wi| {
            let runtime = Runtime::cpu()?;
            let exe = runtime.load(&artifact_path)?;
            let param_literals = shared_params.literals()?;
            Ok(Box::new(PjrtEngine { exe, param_literals, batch, seq, vocab })
                as Box<dyn BatchEngine>)
        });
        // Clamp to the artifact's lowered batch dimension — a larger
        // max_batch would make run_batch truncate the token rows but still
        // index logits for every pending request (out of bounds).
        let mut policy = cfg.policy;
        policy.max_batch = policy.max_batch.clamp(1, manifest.batch);
        start_engine(policy, cfg.workers.max(1), addr, factory)
    }

    /// Serve the rust-native `model` on `cfg.workers` worker threads —
    /// no PJRT, no artifacts. Quantized serving: call
    /// [`Transformer::prepack_quantized_weights`] before handing the
    /// model over, and every request runs the fixed-point QGEMM over
    /// weight planes packed once.
    pub fn start_native(
        model: Arc<Transformer>,
        cfg: NativeServerConfig,
        addr: &str,
    ) -> Result<Server> {
        let seq = cfg.seq.max(1);
        let factory: EngineFactory = Arc::new(move |_wi| {
            Ok(Box::new(NativeEngine { model: Arc::clone(&model), seq }) as Box<dyn BatchEngine>)
        });
        let mut policy = cfg.policy;
        policy.max_batch = policy.max_batch.max(1);
        start_engine(policy, cfg.workers.max(1), addr, factory)
    }

    /// Signal shutdown (threads exit on their next poll/disconnect).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener out of accept() with a dummy connection.
        let _ = TcpStream::connect(self.addr);
    }
}

/// Shared pipeline bring-up: spawn `n_workers` worker threads (each
/// constructing its engine in-thread via `factory`), the batcher and the
/// listener, wired exactly as described in the module docs.
fn start_engine(
    policy: BatchPolicy,
    n_workers: usize,
    addr: &str,
    factory: EngineFactory,
) -> Result<Server> {
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<Pending<ReplyHandle>>();

    // Worker pool: each worker owns its engine and pulls batches from one
    // shared queue when free.
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    // Rendezvous handoff: while every worker is busy the batcher blocks
    // here and the request queue keeps accumulating, so the next drain
    // coalesces the backlog into full batches (no padded fragments).
    let (batch_tx, batch_rx) = sync_channel::<Vec<Pending<ReplyHandle>>>(0);
    let batch_rx = Arc::new(Mutex::new(batch_rx));
    let mut worker_threads = Vec::with_capacity(n_workers);
    for wi in 0..n_workers {
        let wrx = Arc::clone(&batch_rx);
        let ready_tx = ready_tx.clone();
        let worker_metrics = Arc::clone(&metrics);
        let worker_factory = Arc::clone(&factory);
        let handle = std::thread::Builder::new()
            .name(format!("hif4-worker-{wi}"))
            .spawn(move || {
                let setup = worker_factory(wi);
                // Engine built (or failed); release this worker's handle on
                // the factory and whatever setup state it captured.
                drop(worker_factory);
                match setup {
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                    Ok(engine) => {
                        let _ = ready_tx.send(Ok(()));
                        worker_loop(engine, wrx, worker_metrics);
                    }
                }
            })
            .context("spawn worker")?;
        worker_threads.push(handle);
    }
    drop(ready_tx);
    drop(batch_rx); // workers hold the only receiver clones now
    drop(factory); // workers hold the remaining factory handles
    for _ in 0..n_workers {
        ready_rx.recv().context("worker died during setup")??;
    }

    // Batcher: drains the request queue into the shared batch queue.
    let batcher_metrics = Arc::clone(&metrics);
    let batcher_thread = std::thread::Builder::new()
        .name("hif4-batcher".into())
        .spawn(move || {
            run_batcher(&rx, &policy, &batch_tx, |n| {
                batcher_metrics.record_batch(n);
            });
        })
        .context("spawn batcher")?;

    // Listener: a thread per connection reads requests into the queue.
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let listen_metrics = Arc::clone(&metrics);
    let listen_stop = Arc::clone(&stop);
    let listener_thread = std::thread::Builder::new()
        .name("hif4-listener".into())
        .spawn(move || listener_loop(listener, tx, listen_metrics, listen_stop))
        .context("spawn listener")?;

    Ok(Server {
        addr: local,
        metrics,
        stop,
        listener_thread: Some(listener_thread),
        batcher_thread: Some(batcher_thread),
        worker_threads,
    })
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        // Join in pipeline order: closing the listener drops the request
        // queue, which stops the batcher, which closes the worker queues.
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.batcher_thread.take() {
            let _ = t.join();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn listener_loop(
    listener: TcpListener,
    tx: Sender<Pending<ReplyHandle>>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let tx = tx.clone();
        let metrics = Arc::clone(&metrics);
        let _ = std::thread::Builder::new().name("hif4-conn".into()).spawn(move || {
            let reader = stream.try_clone().expect("clone stream");
            let reply: ReplyHandle = Arc::new(Mutex::new(stream));
            let mut reader = std::io::BufReader::new(reader);
            // Read frames until the client hangs up.
            while let Ok(req) = Request::read_from(&mut reader) {
                metrics.record_request();
                let pending =
                    Pending { request: req, arrived: Instant::now(), reply: Arc::clone(&reply) };
                if tx.send(pending).is_err() {
                    break;
                }
            }
        });
    }
}

/// Worker lifecycle is purely channel-driven (exit when the batch queue
/// closes): the batcher may be blocked in a rendezvous `send`, so a worker
/// must never stop pulling before the channel closes or shutdown could
/// deadlock.
fn worker_loop(
    mut engine: Box<dyn BatchEngine>,
    rx: Arc<Mutex<Receiver<Vec<Pending<ReplyHandle>>>>>,
    metrics: Arc<Metrics>,
) {
    loop {
        // Lock only for the pull: whichever worker is free takes the next
        // batch (same pattern as util::threadpool::ThreadPool).
        let next = { rx.lock().unwrap().recv() };
        let Ok(pending) = next else { break };
        match engine.run(&pending) {
            Ok(responses) => {
                for (p, mut resp) in pending.iter().zip(responses) {
                    resp.latency_us = p.arrived.elapsed().as_micros() as u32;
                    metrics.record_latency(p.arrived.elapsed());
                    if let Ok(mut s) = p.reply.lock() {
                        let _ = resp.write_to(&mut *s);
                        let _ = s.flush();
                    }
                }
            }
            Err(e) => {
                eprintln!("batch execution failed: {e:#}");
                // Fail fast for the affected clients: close their
                // connections instead of leaving them blocked in recv()
                // waiting for replies that will never come.
                for p in &pending {
                    if let Ok(s) = p.reply.lock() {
                        let _ = s.shutdown(std::net::Shutdown::Both);
                    }
                }
            }
        }
    }
}

/// Execute one padded batch and extract each request's next-token argmax.
pub fn run_batch(
    exe: &Executable,
    param_literals: &[xla::Literal],
    pending: &[Pending<impl Sized>],
    batch: usize,
    seq: usize,
    vocab: usize,
) -> Result<Vec<Response>> {
    // Pad the request list to the lowered batch size.
    let mut token_rows: Vec<Vec<usize>> = pending
        .iter()
        .map(|p| {
            let mut t = p.request.tokens.clone();
            t.truncate(seq);
            t
        })
        .collect();
    token_rows.resize_with(batch, || vec![0]);
    let tokens = tokens_literal(&token_rows, seq)?;
    // Borrow-based input list: parameter literals are built once per worker
    // lifetime, only the token literal is fresh per batch (§Perf).
    let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(param_literals.len() + 1);
    inputs.extend(param_literals.iter());
    inputs.push(&tokens);
    let outputs = exe.run(&inputs)?;
    let logits = literal_f32(&outputs[0])?; // (batch, seq, vocab)
    let mut responses = Vec::with_capacity(pending.len());
    for (bi, p) in pending.iter().enumerate() {
        let last = p.request.tokens.len().clamp(1, seq) - 1;
        let row = &logits[bi * seq * vocab + last * vocab..][..vocab];
        responses.push(response_from_logits(p.request.id, row));
    }
    Ok(responses)
}

/// Argmax + log-softmax-at-argmax over one logits row.
fn response_from_logits(id: u64, row: &[f32]) -> Response {
    let (mut best, mut best_v) = (0usize, f32::NEG_INFINITY);
    for (t, v) in row.iter().enumerate() {
        if *v > best_v {
            best = t;
            best_v = *v;
        }
    }
    // log-softmax value at the argmax.
    let denom: f32 = row.iter().map(|v| (v - best_v).exp()).sum();
    Response { id, token: best as u32, logprob: -denom.ln(), latency_us: 0 }
}

/// Execute one batch on the rust-native model. No padding is needed —
/// the native forward handles ragged batches directly; requests truncate
/// to `seq` tokens, and out-of-vocab ids clamp to the last token so a
/// malformed request can never panic a worker (the lowered path is safe
/// by construction: XLA gathers clamp indices).
pub fn run_batch_native(
    model: &Transformer,
    pending: &[Pending<impl Sized>],
    seq: usize,
) -> Vec<Response> {
    let vocab = model.cfg.vocab;
    let token_rows: Vec<Vec<usize>> = pending
        .iter()
        .map(|p| {
            let mut t: Vec<usize> =
                p.request.tokens.iter().map(|&tok| tok.min(vocab - 1)).collect();
            t.truncate(seq);
            if t.is_empty() {
                t.push(0);
            }
            t
        })
        .collect();
    let logits = model.forward(&token_rows, None, None, None);
    let mut responses = Vec::with_capacity(pending.len());
    let mut base = 0usize;
    for (p, tokens) in pending.iter().zip(&token_rows) {
        let row = logits.row(base + tokens.len() - 1);
        responses.push(response_from_logits(p.request.id, row));
        base += tokens.len();
    }
    responses
}

/// Blocking client for examples/benches: send requests, read responses.
pub struct Client {
    stream: TcpStream,
    reader: std::io::BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Fire a request without waiting (pipelining).
    pub fn send(&mut self, req: &Request) -> Result<()> {
        self.stream.write_all(&req.encode())?;
        Ok(())
    }

    /// Read the next response.
    pub fn recv(&mut self) -> Result<Response> {
        Response::read_from(&mut self.reader)
    }

    /// Round-trip one request.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.send(req)?;
        self.recv()
    }
}
