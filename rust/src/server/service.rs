//! The serving coordinator: TCP listener → router → scheduler →
//! **worker pool** → per-connection reply writers. Thread-based (std
//! only); Python is nowhere on this path.
//!
//! Two execution **engines** behind one listener/queue front end:
//!
//! * **PJRT** ([`Server::start`]) — batch-then-drain: connection threads
//!   push requests onto one MPSC queue; a dedicated batcher thread drains
//!   them under the [`BatchPolicy`] onto a shared batch queue, which
//!   `workers` worker threads pull from whenever they are free. Each
//!   worker compiles its own copy of a lowered HLO artifact (the xla
//!   crate's PJRT handles are `!Send`, so each worker owns its *entire*
//!   PJRT lifecycle and only plain data crosses threads). Requests are
//!   answered with a single next token (`of = 1`).
//! * **Native** ([`Server::start_native`]) — **continuous batching**:
//!   `workers` decode loops share one [`DecodeEngine`] (read-only
//!   `Arc<Transformer>` + KV-cache policy) and pull requests straight off
//!   the shared queue *between decode steps*. Each loop owns a
//!   [`ContinuousScheduler`] slot map: new requests are admitted into
//!   free slots mid-flight (a fresh sequence prefills in the same step
//!   its batch mates decode), every active sequence advances one greedy
//!   token per step — streamed to its client immediately, tagged
//!   `index`/`of` — and completed sequences are evicted at once, freeing
//!   the slot and its KV-cache page. With
//!   [`Transformer::prepack_quantized_weights`] applied first, every step
//!   runs the real fixed-point QGEMM over weight planes packed exactly
//!   once (any of the five block formats, through the unified
//!   `QuantizedMatrix` API), and the KV cache itself can hold quantized
//!   planes (`NativeServerConfig::kv`) — quantized serving end to end
//!   with no XLA runtime required.

use super::batcher::{run_batcher, BatchPolicy, ContinuousScheduler, Pending};
use super::metrics::Metrics;
use super::protocol::{Request, Response, MAX_NEW_CAP};
use crate::model::kv::{KvCache, KvCacheType};
use crate::model::transformer::{greedy_from_row, Transformer};
use crate::runtime::artifact::{Manifest, ParamStore};
use crate::runtime::client::{literal_f32, tokens_literal, Executable, Runtime};
use crate::runtime::native::{DecodeEngine, DecodeStream};
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// PJRT server configuration.
pub struct ServerConfig {
    /// Artifact to serve, e.g. "fwd_bf16.hlo.txt" or "fwd_hif4.hlo.txt".
    pub artifact: String,
    pub policy: BatchPolicy,
    /// Worker threads; each compiles its own copy of the executable
    /// and pulls batches from the shared queue when free. 0 is treated
    /// as 1.
    pub workers: usize,
}

/// Native-engine server configuration.
pub struct NativeServerConfig {
    /// `policy.max_batch` is the continuous-batching slot count per
    /// decode loop; `max_wait` is unused by the native engine (admission
    /// happens between decode steps).
    pub policy: BatchPolicy,
    /// Decode loops sharing one `Arc<Transformer>`. 0 is treated as 1.
    pub workers: usize,
    /// Max *prompt* tokens per request (requests truncate to this).
    pub seq: usize,
    /// KV-cache storage backend for every stream (`--kv-cache` /
    /// `HIF4_KV_CACHE`).
    pub kv: KvCacheType,
}

type ReplyHandle = Arc<Mutex<TcpStream>>;

/// One batch-then-drain worker's executor: turns a pending batch into
/// responses (the PJRT pipeline; the native engine runs the continuous
/// [`decode_worker_loop`] instead). Engines are constructed *inside*
/// their worker thread by an [`EngineFactory`] (PJRT handles are
/// `!Send`), so the engine itself never crosses threads.
trait BatchEngine {
    fn run(&mut self, pending: &[Pending<ReplyHandle>]) -> Result<Vec<Response>>;
}

/// Thread-safe constructor handed to every worker thread.
type EngineFactory = Arc<dyn Fn(usize) -> Result<Box<dyn BatchEngine>> + Send + Sync>;

/// PJRT engine: one compiled executable + parameter literals per worker.
struct PjrtEngine {
    exe: Executable,
    param_literals: Vec<xla::Literal>,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl BatchEngine for PjrtEngine {
    fn run(&mut self, pending: &[Pending<ReplyHandle>]) -> Result<Vec<Response>> {
        run_batch(&self.exe, &self.param_literals, pending, self.batch, self.seq, self.vocab)
    }
}

/// One continuous-batching slot: the original request (its reply handle
/// streams every token), the decode stream with its KV-cache page, and
/// stream-progress bookkeeping.
struct ActiveSeq {
    pending: Pending<ReplyHandle>,
    stream: DecodeStream,
    emitted: u16,
    of: u16,
}

/// A running server (listener + batcher + worker-pool threads).
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    listener_thread: Option<JoinHandle<()>>,
    batcher_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Compile the artifact on `cfg.workers` dedicated worker threads, bind
    /// `addr` (port 0 for ephemeral) and start serving `params` via PJRT.
    pub fn start(
        artifacts_dir: &Path,
        cfg: ServerConfig,
        params: &ParamStore,
        addr: &str,
    ) -> Result<Server> {
        let manifest = Manifest::load(artifacts_dir)?;
        // One shared weight copy: every worker builds its literals from the
        // same Arc'd store instead of deep-cloning per worker (the factory
        // drops inside each worker after setup, so the store frees once
        // the last worker is ready).
        let shared_params = Arc::new(params.clone());
        let (batch, seq, vocab) = (manifest.batch, manifest.seq, manifest.vocab);
        let artifact_path: PathBuf = manifest.artifact(&cfg.artifact);
        let factory: EngineFactory = Arc::new(move |_wi| {
            let runtime = Runtime::cpu()?;
            let exe = runtime.load(&artifact_path)?;
            let param_literals = shared_params.literals()?;
            Ok(Box::new(PjrtEngine { exe, param_literals, batch, seq, vocab })
                as Box<dyn BatchEngine>)
        });
        // Clamp to the artifact's lowered batch dimension — a larger
        // max_batch would make run_batch truncate the token rows but still
        // index logits for every pending request (out of bounds).
        let mut policy = cfg.policy;
        policy.max_batch = policy.max_batch.clamp(1, manifest.batch);
        // Attribute the counters to the served artifact's format via the
        // shared sniffing rule (the PJRT path has no KV cache and no
        // resident quantized planes).
        let format = crate::formats::QuantKind::from_artifact_name(&cfg.artifact)
            .map(|k| k.spelling())
            .unwrap_or("bf16");
        let server = start_engine(policy, cfg.workers.max(1), addr, factory)?;
        // "f32": the PJRT path has no quantized cache, and the tag stays
        // inside the f32/QuantKind-spelling vocabulary every consumer of
        // the kv axis parses.
        server.metrics.set_format_tag(format, "f32", 0);
        Ok(server)
    }

    /// Serve the rust-native `model` with `cfg.workers` continuous-
    /// batching decode loops — no PJRT, no artifacts. Each loop admits
    /// requests into a [`ContinuousScheduler`] slot map between decode
    /// steps and streams one response frame per generated token.
    /// Quantized serving: call
    /// [`Transformer::prepack_quantized_weights`] before handing the
    /// model over, and every step runs the fixed-point QGEMM over weight
    /// planes packed once; `cfg.kv` additionally stores the KV cache in
    /// a quantized format.
    pub fn start_native(
        model: Arc<Transformer>,
        cfg: NativeServerConfig,
        addr: &str,
    ) -> Result<Server> {
        // Attribute every counter to the active quantization config: the
        // prepacked weight format (one QuantKind across linears by
        // construction), the KV-cache kind, and the resident quantized
        // weight bytes in the canonical wire form.
        let weight_format = model.quantized_weight_kind().map(|k| k.spelling()).unwrap_or("bf16");
        let weight_wire = model.quantized_weight_wire_bytes() as u64;
        let engine = Arc::new(DecodeEngine::new(model, cfg.kv, cfg.seq.max(1)));
        let metrics = Arc::new(Metrics::new());
        metrics.set_format_tag(weight_format, cfg.kv.label(), weight_wire);
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel::<Pending<ReplyHandle>>();
        let rx = Arc::new(Mutex::new(rx));
        let max_slots = cfg.policy.max_batch.max(1);
        let n_workers = cfg.workers.max(1);
        let mut worker_threads = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let wrx = Arc::clone(&rx);
            let wengine = Arc::clone(&engine);
            let wmetrics = Arc::clone(&metrics);
            let handle = std::thread::Builder::new()
                .name(format!("hif4-decode-{wi}"))
                .spawn(move || decode_worker_loop(wengine, wrx, max_slots, wmetrics))
                .context("spawn decode worker")?;
            worker_threads.push(handle);
        }
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let listen_metrics = Arc::clone(&metrics);
        let listen_stop = Arc::clone(&stop);
        let listener_thread = std::thread::Builder::new()
            .name("hif4-listener".into())
            .spawn(move || listener_loop(listener, tx, listen_metrics, listen_stop))
            .context("spawn listener")?;
        Ok(Server {
            addr: local,
            metrics,
            stop,
            listener_thread: Some(listener_thread),
            batcher_thread: None,
            worker_threads,
        })
    }

    /// Signal shutdown (threads exit on their next poll/disconnect).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener out of accept() with a dummy connection.
        let _ = TcpStream::connect(self.addr);
    }
}

/// Shared pipeline bring-up: spawn `n_workers` worker threads (each
/// constructing its engine in-thread via `factory`), the batcher and the
/// listener, wired exactly as described in the module docs.
fn start_engine(
    policy: BatchPolicy,
    n_workers: usize,
    addr: &str,
    factory: EngineFactory,
) -> Result<Server> {
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<Pending<ReplyHandle>>();

    // Worker pool: each worker owns its engine and pulls batches from one
    // shared queue when free.
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    // Rendezvous handoff: while every worker is busy the batcher blocks
    // here and the request queue keeps accumulating, so the next drain
    // coalesces the backlog into full batches (no padded fragments).
    let (batch_tx, batch_rx) = sync_channel::<Vec<Pending<ReplyHandle>>>(0);
    let batch_rx = Arc::new(Mutex::new(batch_rx));
    let mut worker_threads = Vec::with_capacity(n_workers);
    for wi in 0..n_workers {
        let wrx = Arc::clone(&batch_rx);
        let ready_tx = ready_tx.clone();
        let worker_metrics = Arc::clone(&metrics);
        let worker_factory = Arc::clone(&factory);
        let handle = std::thread::Builder::new()
            .name(format!("hif4-worker-{wi}"))
            .spawn(move || {
                let setup = worker_factory(wi);
                // Engine built (or failed); release this worker's handle on
                // the factory and whatever setup state it captured.
                drop(worker_factory);
                match setup {
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                    Ok(engine) => {
                        let _ = ready_tx.send(Ok(()));
                        worker_loop(engine, wrx, worker_metrics);
                    }
                }
            })
            .context("spawn worker")?;
        worker_threads.push(handle);
    }
    drop(ready_tx);
    drop(batch_rx); // workers hold the only receiver clones now
    drop(factory); // workers hold the remaining factory handles
    for _ in 0..n_workers {
        ready_rx.recv().context("worker died during setup")??;
    }

    // Batcher: drains the request queue into the shared batch queue.
    let batcher_metrics = Arc::clone(&metrics);
    let batcher_thread = std::thread::Builder::new()
        .name("hif4-batcher".into())
        .spawn(move || {
            run_batcher(&rx, &policy, &batch_tx, |n| {
                batcher_metrics.record_batch(n);
            });
        })
        .context("spawn batcher")?;

    // Listener: a thread per connection reads requests into the queue.
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let listen_metrics = Arc::clone(&metrics);
    let listen_stop = Arc::clone(&stop);
    let listener_thread = std::thread::Builder::new()
        .name("hif4-listener".into())
        .spawn(move || listener_loop(listener, tx, listen_metrics, listen_stop))
        .context("spawn listener")?;

    Ok(Server {
        addr: local,
        metrics,
        stop,
        listener_thread: Some(listener_thread),
        batcher_thread: Some(batcher_thread),
        worker_threads,
    })
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        // Join in pipeline order: closing the listener drops the request
        // queue, which stops the batcher, which closes the worker queues.
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.batcher_thread.take() {
            let _ = t.join();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn listener_loop(
    listener: TcpListener,
    tx: Sender<Pending<ReplyHandle>>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let tx = tx.clone();
        let metrics = Arc::clone(&metrics);
        let _ = std::thread::Builder::new().name("hif4-conn".into()).spawn(move || {
            let reader = stream.try_clone().expect("clone stream");
            let reply: ReplyHandle = Arc::new(Mutex::new(stream));
            let mut reader = std::io::BufReader::new(reader);
            // Read frames until the client hangs up.
            while let Ok(req) = Request::read_from(&mut reader) {
                metrics.record_request();
                let pending =
                    Pending { request: req, arrived: Instant::now(), reply: Arc::clone(&reply) };
                if tx.send(pending).is_err() {
                    break;
                }
            }
        });
    }
}

/// Worker lifecycle is purely channel-driven (exit when the batch queue
/// closes): the batcher may be blocked in a rendezvous `send`, so a worker
/// must never stop pulling before the channel closes or shutdown could
/// deadlock.
fn worker_loop(
    mut engine: Box<dyn BatchEngine>,
    rx: Arc<Mutex<Receiver<Vec<Pending<ReplyHandle>>>>>,
    metrics: Arc<Metrics>,
) {
    loop {
        // Lock only for the pull: whichever worker is free takes the next
        // batch (same pattern as util::threadpool::ThreadPool).
        let next = { rx.lock().unwrap().recv() };
        let Ok(pending) = next else { break };
        match engine.run(&pending) {
            Ok(responses) => {
                for (p, mut resp) in pending.iter().zip(responses) {
                    resp.latency_us = p.arrived.elapsed().as_micros() as u32;
                    metrics.record_latency(p.arrived.elapsed());
                    if let Ok(mut s) = p.reply.lock() {
                        let _ = resp.write_to(&mut *s);
                        let _ = s.flush();
                    }
                }
            }
            Err(e) => {
                eprintln!("batch execution failed: {e:#}");
                // Fail fast for the affected clients: close their
                // connections instead of leaving them blocked in recv()
                // waiting for replies that will never come.
                for p in &pending {
                    if let Ok(s) = p.reply.lock() {
                        let _ = s.shutdown(std::net::Shutdown::Both);
                    }
                }
            }
        }
    }
}

/// The continuous-batching decode loop (one per native worker):
///
/// ```text
/// loop {
///   admit  — idle: block for a request; busy: drain the queue
///            (non-blocking) into free slots
///   step   — one greedy token for every active slot (fresh slots
///            prefill, in-flight slots decode) via DecodeEngine::step
///   emit   — stream each token to its client immediately
///   evict  — release completed slots (drops the KV-cache page)
/// }
/// ```
///
/// Exits when the request queue closes *and* every in-flight stream has
/// completed, so shutdown never truncates a response stream.
fn decode_worker_loop(
    engine: Arc<DecodeEngine>,
    rx: Arc<Mutex<Receiver<Pending<ReplyHandle>>>>,
    max_slots: usize,
    metrics: Arc<Metrics>,
) {
    // Bound on how long an idle worker holds the shared receiver lock: a
    // plain blocking `recv()` would park *inside* the lock and starve the
    // `try_recv` top-ups of workers with in-flight streams (their decode
    // loops would stall until a brand-new request arrived — a deadlock
    // for sequential clients). Between timeouts the lock is released, so
    // busy workers get through once per step.
    const IDLE_POLL: Duration = Duration::from_millis(1);
    let mut sched: ContinuousScheduler<ActiveSeq> = ContinuousScheduler::new(max_slots);
    // Recycled KV-cache pages from evicted sequences: the next admission
    // reuses the allocation instead of growing a fresh one (bounded by
    // the slot count, so parked capacity never exceeds one full batch).
    // Page reuse is behavior-neutral — decode is bit-identical on a
    // recycled page (`runtime::native` unit tests) — and the cache's
    // byte accounting reports stored rows, not the parked capacity.
    let mut spare_pages: Vec<KvCache> = Vec::new();
    let mut closed = false;
    loop {
        if sched.is_empty() {
            if closed {
                return;
            }
            // Idle: poll for work with a bounded wait (see IDLE_POLL).
            let next = { rx.lock().unwrap().recv_timeout(IDLE_POLL) };
            match next {
                Ok(p) => admit_seq(&engine, &mut sched, p, &mut spare_pages),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
        // In flight: top the slot map up without blocking — admission
        // latency is at most one decode step.
        while !closed && sched.has_free() {
            let next = { rx.lock().unwrap().try_recv() };
            match next {
                Ok(p) => admit_seq(&engine, &mut sched, p, &mut spare_pages),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => closed = true,
            }
        }
        // One decode step over every active slot, in slot order.
        let mut ids: Vec<usize> = Vec::new();
        let outs = {
            let mut streams: Vec<&mut DecodeStream> = Vec::new();
            for (id, a) in sched.iter_active_mut() {
                ids.push(id);
                streams.push(&mut a.stream);
            }
            engine.step(&mut streams)
        };
        metrics.record_batch(ids.len());
        for (id, (token, logprob)) in ids.into_iter().zip(outs) {
            let done = {
                let a = sched.get_mut(id).expect("stepped slot is active");
                a.emitted += 1;
                let resp = Response {
                    id: a.pending.request.id,
                    token,
                    logprob,
                    latency_us: a.pending.arrived.elapsed().as_micros() as u32,
                    index: a.emitted - 1,
                    of: a.of,
                };
                // Stream immediately; a vanished client just means the
                // remaining (bounded) tokens go nowhere.
                if let Ok(mut s) = a.pending.reply.lock() {
                    let _ = resp.write_to(&mut *s);
                    let _ = s.flush();
                }
                a.emitted >= a.of
            };
            if done {
                if let Some(a) = sched.release(id) {
                    metrics.record_latency(a.pending.arrived.elapsed());
                    if spare_pages.len() < max_slots {
                        spare_pages.push(a.stream.into_cache());
                    }
                }
            }
        }
    }
}

/// Open a decode stream for a request — reusing a recycled cache page
/// when one is parked — and admit it into a free slot (the callers only
/// admit when one exists).
fn admit_seq(
    engine: &DecodeEngine,
    sched: &mut ContinuousScheduler<ActiveSeq>,
    p: Pending<ReplyHandle>,
    spare_pages: &mut Vec<KvCache>,
) {
    let of = p.request.max_new.clamp(1, MAX_NEW_CAP);
    let stream = engine.start_reusing(&p.request.tokens, spare_pages.pop());
    let admitted = sched.admit(ActiveSeq { pending: p, stream, emitted: 0, of });
    debug_assert!(admitted.is_some(), "admit_seq requires a free slot");
}

/// Execute one padded batch and extract each request's next-token argmax.
pub fn run_batch(
    exe: &Executable,
    param_literals: &[xla::Literal],
    pending: &[Pending<impl Sized>],
    batch: usize,
    seq: usize,
    vocab: usize,
) -> Result<Vec<Response>> {
    // Pad the request list to the lowered batch size.
    let mut token_rows: Vec<Vec<usize>> = pending
        .iter()
        .map(|p| {
            let mut t = p.request.tokens.clone();
            t.truncate(seq);
            t
        })
        .collect();
    token_rows.resize_with(batch, || vec![0]);
    let tokens = tokens_literal(&token_rows, seq)?;
    // Borrow-based input list: parameter literals are built once per worker
    // lifetime, only the token literal is fresh per batch (§Perf).
    let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(param_literals.len() + 1);
    inputs.extend(param_literals.iter());
    inputs.push(&tokens);
    let outputs = exe.run(&inputs)?;
    let logits = literal_f32(&outputs[0])?; // (batch, seq, vocab)
    let mut responses = Vec::with_capacity(pending.len());
    for (bi, p) in pending.iter().enumerate() {
        let last = p.request.tokens.len().clamp(1, seq) - 1;
        let row = &logits[bi * seq * vocab + last * vocab..][..vocab];
        responses.push(response_from_logits(p.request.id, row));
    }
    Ok(responses)
}

/// Single-frame response (`of = 1`) from one logits row — the batch
/// paths' readout, sharing the greedy argmax/log-softmax with the decode
/// engine ([`greedy_from_row`]).
fn response_from_logits(id: u64, row: &[f32]) -> Response {
    let (token, logprob) = greedy_from_row(row);
    Response { id, token: token as u32, logprob, latency_us: 0, index: 0, of: 1 }
}

/// Execute one batch on the rust-native model. No padding is needed —
/// the native forward handles ragged batches directly; requests truncate
/// to `seq` tokens, and out-of-vocab ids clamp to the last token so a
/// malformed request can never panic a worker (the lowered path is safe
/// by construction: XLA gathers clamp indices).
pub fn run_batch_native(
    model: &Transformer,
    pending: &[Pending<impl Sized>],
    seq: usize,
) -> Vec<Response> {
    let vocab = model.cfg.vocab;
    let token_rows: Vec<Vec<usize>> = pending
        .iter()
        .map(|p| {
            let mut t: Vec<usize> =
                p.request.tokens.iter().map(|&tok| tok.min(vocab - 1)).collect();
            t.truncate(seq);
            if t.is_empty() {
                t.push(0);
            }
            t
        })
        .collect();
    let logits = model.forward(&token_rows, None, None, None);
    let mut responses = Vec::with_capacity(pending.len());
    let mut base = 0usize;
    for (p, tokens) in pending.iter().zip(&token_rows) {
        let row = logits.row(base + tokens.len() - 1);
        responses.push(response_from_logits(p.request.id, row));
        base += tokens.len();
    }
    responses
}

/// Blocking client for examples/benches: send requests, read responses.
pub struct Client {
    stream: TcpStream,
    reader: std::io::BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Fire a request without waiting (pipelining).
    pub fn send(&mut self, req: &Request) -> Result<()> {
        self.stream.write_all(&req.encode())?;
        Ok(())
    }

    /// Read the next response.
    pub fn recv(&mut self) -> Result<Response> {
        Response::read_from(&mut self.reader)
    }

    /// Round-trip one request.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.send(req)?;
        self.recv()
    }

    /// Read one full response stream (frames until `index + 1 == of`).
    /// Assumes a single outstanding request on this connection — streams
    /// of pipelined requests interleave and must be grouped by `id`
    /// instead.
    pub fn recv_stream(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        loop {
            let r = self.recv()?;
            let last = r.is_last();
            out.push(r);
            if last {
                return Ok(out);
            }
        }
    }

    /// Round-trip a generation request: send, then read the whole token
    /// stream.
    pub fn generate(&mut self, req: &Request) -> Result<Vec<Response>> {
        self.send(req)?;
        self.recv_stream()
    }
}
