//! Global paged-KV allocation layer: fixed-size, group-aligned KV pages
//! ([`KvPage`]) handed out by one server-wide [`PagePool`], plus the
//! shared-prefix index ([`PrefixTrie`]) that lets thousands of requests
//! with a common system prompt attend against **one** resident copy of
//! its KV pages.
//!
//! # Page layout and the group-alignment invariant
//!
//! A page holds up to `page_rows` whole KV rows of one store (one
//! layer's K *or* V). A quantized row is `groups_per_row` whole
//! 64-element (format-`group()`-element) plane groups — `kvd` rounded up
//! to groups, zero-padded tail — so a page's lane plane is always a
//! multiple of the group and **no group ever straddles a page
//! boundary**. That holds for *any* `page_rows ≥ 1` by construction
//! (pages split on row boundaries, rows split on group boundaries); the
//! default of 64 rows mirrors the HiF4 unit geometry so one page of a
//! 64-wide head is exactly a 64×64 lane tile.
//!
//! # Sharing protocol (dedup + copy-on-write)
//!
//! Only **full** pages are ever shared, and shared pages are immutable:
//! a sequence's cache appends into its private tail page and freezes it
//! into an `Arc<KvPage>` the moment it fills. The [`PrefixTrie`] maps
//! hash-chained `page_rows`-token chunks of a prompt to the frozen page
//! *bundle* (every layer's K and V page for that chunk). Admission looks
//! the prompt up ([`PagePool::lookup_prefix`]); a hit attaches the
//! shared `Arc`s — refcount bumps, zero bytes copied — and decode
//! resumes at the first uncovered token. If the prompt diverges *inside*
//! a chunk, the covered row prefix of that chunk's pages is byte-copied
//! into fresh private pages (copy-on-write at the divergence page); the
//! shared original is untouched. Completed prefills register their own
//! full chunks back into the trie ([`PagePool::register_prefix`]), so
//! the first request with a given system prompt seeds the cache for
//! every follower.
//!
//! Correctness does not rest on the hash: every trie node stores its
//! exact chunk tokens and parent link, and lookups compare them
//! verbatim — a hash collision degrades to a miss, never a wrong
//! attach. Bitwise decode parity with sharing off is then structural:
//! attention always reads the quantize→decode rows from the store, and
//! a shared page holds exactly the bytes a private prefill would have
//! produced for the same tokens (encoding is deterministic).
//!
//! # Eviction
//!
//! The pool is bounded (`max_pages`, derived from the serving KV budget;
//! 0 = unbounded). `alloc()` serves from the free list, then mints fresh
//! pages up to the cap, then evicts **unreferenced** trie entries
//! (leaf-first LRU: cached prefixes no live sequence holds) to recycle
//! their pages, and only then reports [`PagesExhausted`] — which the
//! admission gate surfaces as a structured `ShedKvBudget` long before a
//! worker could hit it ([`crate::server::batcher::AdmissionGate`]
//! reserves pages up front). The one corner reservations cannot cover —
//! shared pages pinned by other admitted streams crowding the cap, since
//! the gate charges prefix hits only for their uncovered suffix — is
//! absorbed by [`PagePool::alloc_reserved`], which mints a bounded
//! overflow page instead of failing an admitted stream mid-decode.

use crate::dotprod::quant_tensor::encode_row_planes;
use crate::formats::QuantKind;
use crate::model::kv::KvCacheType;
use crate::util::lock_recover;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Default page height in KV rows — mirrors the 64-element HiF4 group
/// geometry (`--kv-page-rows` / `HIF4_KV_PAGE_ROWS` override it).
pub const DEFAULT_PAGE_ROWS: usize = 64;

/// The fixed geometry every page of one pool shares: cache kind, row
/// width (`kv_heads × head_dim`) and page height in rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageShape {
    pub kind: KvCacheType,
    pub kvd: usize,
    pub page_rows: usize,
}

impl PageShape {
    pub fn new(kind: KvCacheType, kvd: usize, page_rows: usize) -> PageShape {
        assert!(page_rows > 0, "page_rows must be positive");
        assert!(kvd > 0, "kvd must be positive");
        PageShape { kind, kvd, page_rows }
    }

    /// Plane groups per row for quantized kinds (0 for f32): `kvd`
    /// rounded up to whole format groups.
    pub fn groups_per_row(&self) -> usize {
        match self.kind {
            KvCacheType::F32 => 0,
            KvCacheType::Quant(q) => self.kvd.div_ceil(q.group()),
        }
    }

    /// Packed i8 lanes one row owns (groups_per_row × group; 0 for f32).
    pub fn row_lanes(&self) -> usize {
        match self.kind {
            KvCacheType::F32 => 0,
            KvCacheType::Quant(q) => self.groups_per_row() * q.group(),
        }
    }

    /// Resident bytes one stored row costs (same estimator the admission
    /// gate always used — [`KvCacheType::resident_row_bytes`]).
    pub fn row_bytes(&self) -> usize {
        self.kind.resident_row_bytes(self.kvd)
    }

    /// Resident bytes of one full page.
    pub fn page_bytes(&self) -> usize {
        self.page_rows * self.row_bytes()
    }
}

/// One fixed-size page of KV rows: up to `shape.page_rows` rows of one
/// store, in the store's native layout (f32 values, or decode-once i8
/// lane planes + f64 group scales). Private while filling; frozen into
/// an immutable `Arc<KvPage>` once full (the only form that is shared).
#[derive(Debug)]
pub struct KvPage {
    rows: usize,
    data: PageData,
}

#[derive(Debug)]
enum PageData {
    F32(Vec<f32>),
    Quant { lanes: Vec<i8>, scales: Vec<f64> },
}

impl KvPage {
    /// An empty page of `shape`'s geometry.
    pub fn empty(shape: &PageShape) -> KvPage {
        let data = match shape.kind {
            KvCacheType::F32 => PageData::F32(Vec::new()),
            KvCacheType::Quant(_) => PageData::Quant { lanes: Vec::new(), scales: Vec::new() },
        };
        KvPage { rows: 0, data }
    }

    /// Rows currently stored (≤ `shape.page_rows`).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Drop every row, keep the backing allocations (free-list reuse).
    pub fn clear(&mut self) {
        self.rows = 0;
        match &mut self.data {
            PageData::F32(d) => d.clear(),
            PageData::Quant { lanes, scales } => {
                lanes.clear();
                scales.clear();
            }
        }
    }

    /// Append one row (the caller guarantees room; quantized kinds encode
    /// through the format codec exactly like the unpaged store did).
    pub fn append_row(&mut self, shape: &PageShape, row: &[f32]) {
        assert_eq!(row.len(), shape.kvd, "KV row width must match kv_heads×head_dim");
        assert!(self.rows < shape.page_rows, "append into a full page");
        match (&mut self.data, shape.kind) {
            (PageData::F32(d), KvCacheType::F32) => d.extend_from_slice(row),
            (PageData::Quant { lanes, scales }, KvCacheType::Quant(q)) => {
                encode_row_planes(q, row, lanes, scales);
            }
            _ => panic!("page backend does not match its pool's cache kind"),
        }
        self.rows += 1;
    }

    /// Copy-on-write seed: byte-copy the first `rows` rows of `src` into
    /// this (empty) page. Pure plane/value copy — no re-encode, so the
    /// private copy is bit-identical to the shared original's prefix.
    pub fn copy_prefix_from(&mut self, shape: &PageShape, src: &KvPage, rows: usize) {
        assert_eq!(self.rows, 0, "copy_prefix_from targets an empty page");
        assert!(rows <= src.rows, "cannot copy rows the source never stored");
        match (&mut self.data, &src.data) {
            (PageData::F32(d), PageData::F32(s)) => {
                d.extend_from_slice(&s[..rows * shape.kvd]);
            }
            (
                PageData::Quant { lanes, scales },
                PageData::Quant { lanes: sl, scales: ss },
            ) => {
                lanes.extend_from_slice(&sl[..rows * shape.row_lanes()]);
                scales.extend_from_slice(&ss[..rows * shape.groups_per_row()]);
            }
            _ => panic!("copy_prefix_from across mismatched page backends"),
        }
        self.rows = rows;
    }

    /// Dense f32 values (f32 pages only).
    pub fn f32_data(&self) -> &[f32] {
        match &self.data {
            PageData::F32(d) => d,
            PageData::Quant { .. } => panic!("f32_data on a quantized page"),
        }
    }

    /// Packed i8 lanes (quantized pages only).
    pub fn lanes(&self) -> &[i8] {
        match &self.data {
            PageData::Quant { lanes, .. } => lanes,
            PageData::F32(_) => panic!("lanes on an f32 page"),
        }
    }

    /// Per-group f64 scales (quantized pages only).
    pub fn scales(&self) -> &[f64] {
        match &self.data {
            PageData::Quant { scales, .. } => scales,
            PageData::F32(_) => panic!("scales on an f32 page"),
        }
    }

    /// Bytes of the rows actually stored (length-derived, like the
    /// unpaged store's accounting — parked capacity never leaks in).
    pub fn resident_bytes(&self) -> usize {
        match &self.data {
            PageData::F32(d) => std::mem::size_of_val(d.as_slice()),
            PageData::Quant { lanes, scales } => {
                std::mem::size_of_val(lanes.as_slice()) + std::mem::size_of_val(scales.as_slice())
            }
        }
    }

    /// Bytes the backing allocations hold (≥ resident).
    pub fn capacity_bytes(&self) -> usize {
        match &self.data {
            PageData::F32(d) => d.capacity() * std::mem::size_of::<f32>(),
            PageData::Quant { lanes, scales } => {
                lanes.capacity() * std::mem::size_of::<i8>()
                    + scales.capacity() * std::mem::size_of::<f64>()
            }
        }
    }

    /// Serialized bytes of the stored rows (canonical packed wire form
    /// for quantized pages, dense f32 otherwise).
    pub fn wire_bytes(&self, shape: &PageShape) -> usize {
        match (&self.data, shape.kind) {
            (PageData::F32(d), _) => std::mem::size_of_val(d.as_slice()),
            (PageData::Quant { scales, .. }, KvCacheType::Quant(q)) => {
                scales.len() * q.wire_bytes_group()
            }
            _ => unreachable!("quantized page under an f32 shape"),
        }
    }
}

/// Structured allocation failure: the pool is at `max_pages` and nothing
/// is reclaimable. The serving tier never sees this mid-decode — the
/// admission gate reserves a stream's worst-case page count up front and
/// sheds with `ShedKvBudget` instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PagesExhausted {
    pub live: usize,
    pub max_pages: usize,
}

impl std::fmt::Display for PagesExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV page pool exhausted: {} of {} pages live", self.live, self.max_pages)
    }
}

impl std::error::Error for PagesExhausted {}

/// A prefix-cache hit: the shared page bundles covering a whole-chunk
/// token prefix, plus (optionally) a copy-on-write seed for the partial
/// chunk at the divergence point. Carrying the `Arc`s pins the pages —
/// between listener-side lookup and worker-side attach nothing can evict
/// them.
#[derive(Debug, Clone)]
pub struct PrefixHit {
    /// The exact tokens the hit covers (`chunks × page_rows` whole-chunk
    /// tokens, then `cow_rows` more when a CoW seed is present). The
    /// attach path re-verifies these against the real prompt.
    pub tokens: Vec<usize>,
    /// One bundle per covered chunk; bundle `s`-indexing is
    /// `layer*2 + {0: K, 1: V}`.
    pub bundles: Vec<Vec<Arc<KvPage>>>,
    /// Divergence-chunk seed: the shared bundle plus how many of its
    /// rows match the prompt (strictly less than a full chunk).
    pub cow: Option<(Vec<Arc<KvPage>>, usize)>,
    pub page_rows: usize,
}

impl PrefixHit {
    /// Whole chunks covered.
    pub fn chunks(&self) -> usize {
        self.bundles.len()
    }

    /// Total covered rows (whole chunks + CoW seed rows).
    pub fn rows(&self) -> usize {
        self.bundles.len() * self.page_rows + self.cow.as_ref().map_or(0, |(_, r)| *r)
    }

    /// Highest sharing degree across the attached pages (refcount
    /// high-water input for metrics). `strong_count` includes the trie's
    /// own reference and this hit's pin.
    pub fn max_refcount(&self) -> usize {
        self.bundles
            .iter()
            .chain(self.cow.iter().map(|(b, _)| b))
            .flat_map(|b| b.iter().map(Arc::strong_count))
            .max()
            .unwrap_or(0)
    }
}

/// FNV-style chained chunk hash: each chunk key folds its parent's key,
/// so equal keys imply (modulo collisions, which the exact-token compare
/// catches) equal full token paths — not just equal final chunks.
fn chunk_key(parent: u64, chunk: &[usize]) -> u64 {
    let mut h = parent ^ 0xcbf2_9ce4_8422_2325;
    for &t in chunk {
        h ^= t as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
        h ^= h >> 29;
    }
    h
}

/// One cached prefix chunk: its exact tokens, parent linkage (collision
/// safety + tree structure), the frozen page bundle, and an LRU stamp.
struct TrieNode {
    parent: Option<u64>,
    chunk: Vec<usize>,
    bundle: Vec<Arc<KvPage>>,
    children: Vec<u64>,
    last_used: u64,
}

/// Token-hash radix trie over `page_rows`-token chunks (the
/// `PrefixIndex`): node key = chained hash of the chunk path from the
/// root. Collisions are harmless — lookup verifies tokens and parent
/// linkage exactly.
struct PrefixTrie {
    page_rows: usize,
    nodes: BTreeMap<u64, TrieNode>,
    roots: Vec<u64>,
    clock: u64,
    /// Cached-chunk cap: beyond it, registration evicts the LRU
    /// unreferenced leaf first (bounds trie growth independently of the
    /// page cap).
    max_nodes: usize,
}

impl PrefixTrie {
    fn new(page_rows: usize) -> PrefixTrie {
        PrefixTrie {
            page_rows,
            nodes: BTreeMap::new(),
            roots: Vec::new(),
            clock: 0,
            max_nodes: 4096,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Walk whole chunks of `tokens[..limit]`, verifying each node's
    /// chunk tokens and parent key; returns the matched node keys in
    /// order plus the divergence CoW candidate (a child sharing the
    /// longest nonzero row prefix of the next, partial chunk).
    fn lookup(&mut self, tokens: &[usize], limit: usize) -> (Vec<u64>, Option<(u64, usize)>) {
        let pr = self.page_rows;
        let mut matched_keys = Vec::new();
        let mut parent: Option<u64> = None;
        let mut matched = 0usize;
        while matched + pr <= limit {
            let chunk = &tokens[matched..matched + pr];
            let key = chunk_key(parent.unwrap_or(0), chunk);
            match self.nodes.get(&key) {
                Some(n) if n.parent == parent && n.chunk == chunk => {
                    matched_keys.push(key);
                    parent = Some(key);
                    matched += pr;
                }
                _ => break,
            }
        }
        let stamp = self.tick();
        for k in &matched_keys {
            if let Some(n) = self.nodes.get_mut(k) {
                n.last_used = stamp;
            }
        }
        // Divergence chunk: among the children of the last matched node
        // (or the roots), the one sharing the longest row prefix with the
        // remaining tokens seeds a copy-on-write page.
        let rest = &tokens[matched..limit];
        let candidates: &[u64] = match parent {
            Some(p) => self.nodes.get(&p).map(|n| n.children.as_slice()).unwrap_or(&[]),
            None => &self.roots,
        };
        let mut cow: Option<(u64, usize)> = None;
        for &ck in candidates {
            let Some(n) = self.nodes.get(&ck) else { continue };
            if n.parent != parent {
                continue;
            }
            let cp = n.chunk.iter().zip(rest.iter()).take_while(|(a, b)| a == b).count();
            if cp > 0 && cp > cow.map_or(0, |(_, c)| c) {
                cow = Some((ck, cp));
            }
        }
        if let Some((ck, _)) = cow {
            let stamp = self.tick();
            if let Some(n) = self.nodes.get_mut(&ck) {
                n.last_used = stamp;
            }
        }
        (matched_keys, cow)
    }

    /// Insert the whole-chunk path of `tokens` with its page bundles
    /// (one per chunk). Existing nodes are touched, not replaced — the
    /// first registrant wins and later duplicates just refresh LRU.
    fn register(&mut self, tokens: &[usize], bundles: Vec<Vec<Arc<KvPage>>>) {
        let pr = self.page_rows;
        debug_assert!(tokens.len() >= bundles.len() * pr, "register covers whole chunks only");
        let stamp = self.tick();
        let mut parent: Option<u64> = None;
        for (ci, bundle) in bundles.into_iter().enumerate() {
            let chunk = tokens[ci * pr..(ci + 1) * pr].to_vec();
            let key = chunk_key(parent.unwrap_or(0), &chunk);
            match self.nodes.get_mut(&key) {
                Some(n) if n.parent == parent && n.chunk == chunk => {
                    n.last_used = stamp;
                }
                Some(_) => {
                    // Hash collision with a different path: leave the
                    // incumbent alone (lookups for this path will miss —
                    // correctness over coverage).
                    return;
                }
                None => {
                    if self.nodes.len() >= self.max_nodes && !self.evict_lru_leaf() {
                        return; // every node is mid-path; stop growing
                    }
                    self.nodes.insert(
                        key,
                        TrieNode {
                            parent,
                            chunk,
                            bundle,
                            children: Vec::new(),
                            last_used: stamp,
                        },
                    );
                    match parent {
                        Some(p) => {
                            if let Some(pn) = self.nodes.get_mut(&p) {
                                pn.children.push(key);
                            }
                        }
                        None => self.roots.push(key),
                    }
                }
            }
            parent = Some(key);
        }
    }

    fn unlink(&mut self, key: u64) -> Option<TrieNode> {
        let node = self.nodes.remove(&key)?;
        match node.parent {
            Some(p) => {
                if let Some(pn) = self.nodes.get_mut(&p) {
                    pn.children.retain(|&c| c != key);
                }
            }
            None => self.roots.retain(|&r| r != key),
        }
        Some(node)
    }

    /// A leaf is evictable when nothing outside the trie holds its pages
    /// (every bundle Arc has `strong_count == 1`).
    fn leaf_is_unreferenced(&self, key: u64) -> bool {
        self.nodes.get(&key).is_some_and(|n| {
            n.children.is_empty() && n.bundle.iter().all(|p| Arc::strong_count(p) == 1)
        })
    }

    /// Drop the least-recently-used unreferenced leaf (trie-capacity
    /// pressure; pages go back through the caller via the returned node).
    fn evict_lru_leaf(&mut self) -> bool {
        let victim = self
            .nodes
            .keys()
            .copied()
            .filter(|&k| self.leaf_is_unreferenced(k))
            .min_by_key(|&k| self.nodes[&k].last_used);
        match victim {
            Some(k) => {
                self.unlink(k);
                true
            }
            None => false,
        }
    }

    /// Page-pressure eviction: cascade-drop unreferenced leaves (LRU
    /// first) and hand their now-private pages back for recycling. Stops
    /// as soon as `want` pages are freed.
    fn evict_unreferenced(&mut self, want: usize) -> Vec<KvPage> {
        let mut freed = Vec::new();
        while freed.len() < want {
            let victim = self
                .nodes
                .keys()
                .copied()
                .filter(|&k| self.leaf_is_unreferenced(k))
                .min_by_key(|&k| self.nodes[&k].last_used);
            let Some(k) = victim else { break };
            let Some(node) = self.unlink(k) else { break };
            for arc in node.bundle {
                if let Ok(page) = Arc::try_unwrap(arc) {
                    freed.push(page);
                }
            }
        }
        freed
    }
}

/// Pool interior: the free list and the prefix trie live behind one lock
/// so allocation can evict cached prefixes inline without lock-order
/// hazards.
struct PoolInner {
    free: Vec<KvPage>,
    trie: Option<PrefixTrie>,
}

/// The global page allocator: every KV store of every stream on one
/// native server draws pages of one [`PageShape`] from here. Bounded by
/// `max_pages` (0 = unbounded), recycling through a free list, with the
/// shared-prefix index folded in when prefix caching is on.
pub struct PagePool {
    shape: PageShape,
    max_pages: usize,
    inner: Mutex<PoolInner>,
    /// Pages currently out of the pool (allocated and not yet recycled).
    live: AtomicUsize,
    high_water: AtomicUsize,
    freelist_hits: AtomicUsize,
    /// Whole shared pages attached via prefix hits (each one is a page
    /// of resident bytes a private prefill would have duplicated).
    shared_pages_attached: AtomicUsize,
    shared_ref_high_water: AtomicUsize,
    prefix_evictions: AtomicUsize,
    /// Pages minted beyond `max_pages` for reservation-backed streams
    /// when every cached prefix page was pinned (see [`PagePool::alloc_reserved`]).
    overflow_allocs: AtomicUsize,
}

impl PagePool {
    /// `max_pages == 0` means unbounded; `prefix_cache` turns the shared
    /// prefix index on.
    pub fn new(shape: PageShape, max_pages: usize, prefix_cache: bool) -> PagePool {
        let trie = prefix_cache.then(|| PrefixTrie::new(shape.page_rows));
        PagePool {
            shape,
            max_pages,
            inner: Mutex::new(PoolInner { free: Vec::new(), trie }),
            live: AtomicUsize::new(0),
            high_water: AtomicUsize::new(0),
            freelist_hits: AtomicUsize::new(0),
            shared_pages_attached: AtomicUsize::new(0),
            shared_ref_high_water: AtomicUsize::new(0),
            prefix_evictions: AtomicUsize::new(0),
            overflow_allocs: AtomicUsize::new(0),
        }
    }

    pub fn shape(&self) -> &PageShape {
        &self.shape
    }

    pub fn page_rows(&self) -> usize {
        self.shape.page_rows
    }

    pub fn page_bytes(&self) -> usize {
        self.shape.page_bytes()
    }

    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    pub fn prefix_enabled(&self) -> bool {
        lock_recover(&self.inner).trie.is_some()
    }

    fn note_alloc(&self) {
        let live = self.live.fetch_add(1, Ordering::Relaxed) + 1;
        self.high_water.fetch_max(live, Ordering::Relaxed);
    }

    /// Take one empty page: free list first, then a fresh allocation
    /// under the cap, then eviction of unreferenced cached prefixes —
    /// and only then [`PagesExhausted`].
    pub fn alloc(&self) -> Result<KvPage, PagesExhausted> {
        // All live-count transitions happen under the pool lock (the
        // atomics are for lock-free *reads* by metrics), so the cap is
        // exact under concurrent allocation.
        let mut inner = lock_recover(&self.inner);
        if let Some(mut page) = inner.free.pop() {
            page.clear();
            self.freelist_hits.fetch_add(1, Ordering::Relaxed);
            self.note_alloc();
            return Ok(page);
        }
        let live = self.live.load(Ordering::Relaxed);
        if self.max_pages == 0 || live < self.max_pages {
            self.note_alloc();
            return Ok(KvPage::empty(&self.shape));
        }
        // At the cap with an empty free list: reclaim cached prefixes
        // nothing references. Evicted pages were live (the trie held
        // them), so recycling one does not change the live count.
        if let Some(trie) = inner.trie.as_mut() {
            let mut freed = trie.evict_unreferenced(1);
            if let Some(mut page) = freed.pop() {
                self.prefix_evictions.fetch_add(1, Ordering::Relaxed);
                for extra in freed {
                    self.recycle_locked(&mut inner, extra);
                }
                page.clear();
                return Ok(page);
            }
        }
        Err(PagesExhausted { live, max_pages: self.max_pages })
    }

    /// Infallible allocation for reservation-backed streams. The gate
    /// reserves pages *net* of shared-prefix chunks, so shared pages
    /// pinned by admitted hits can transiently crowd the cap out from
    /// under a stream whose own reservation was honored. Rather than
    /// abort that stream mid-decode, mint an overflow page beyond
    /// `max_pages`: the overshoot is bounded by the pinned shared
    /// overhang (itself capped by the trie's node bound) and drains back
    /// under the cap as those streams retire. `overflow_allocs` counts
    /// every such mint.
    pub fn alloc_reserved(&self) -> KvPage {
        self.alloc().unwrap_or_else(|_| {
            self.overflow_allocs.fetch_add(1, Ordering::Relaxed);
            self.note_alloc();
            KvPage::empty(&self.shape)
        })
    }

    fn recycle_locked(&self, inner: &mut PoolInner, mut page: KvPage) {
        page.clear();
        inner.free.push(page);
        self.live.fetch_sub(1, Ordering::Relaxed);
    }

    /// Return a private page to the free list (allocation survives).
    pub fn recycle(&self, page: KvPage) {
        let mut inner = lock_recover(&self.inner);
        self.recycle_locked(&mut inner, page);
    }

    /// Return a possibly-shared page: the last holder recycles it, any
    /// earlier holder just drops its reference (the trie or another
    /// stream still owns the bytes).
    pub fn release(&self, page: Arc<KvPage>) {
        match Arc::try_unwrap(page) {
            Ok(page) => self.recycle(page),
            Err(_still_shared) => {
                // Another holder keeps the page live; this stream's claim
                // on the live count transfers to them. Shared pages were
                // counted once at their original alloc, so nothing to do.
            }
        }
    }

    /// Look a normalized prompt up in the prefix index. Covers at most
    /// `tokens.len() - 1` tokens — the final prompt token must always be
    /// fed through the model to produce the first logits row, so a
    /// full-prompt hit still leaves one token to prefill.
    pub fn lookup_prefix(&self, tokens: &[usize]) -> Option<PrefixHit> {
        let mut inner = lock_recover(&self.inner);
        let trie = inner.trie.as_mut()?;
        let limit = tokens.len().saturating_sub(1);
        let (keys, cow) = trie.lookup(tokens, limit);
        if keys.is_empty() && cow.is_none() {
            return None;
        }
        let pr = trie.page_rows;
        let bundles: Vec<Vec<Arc<KvPage>>> =
            keys.iter().map(|k| trie.nodes[k].bundle.iter().map(Arc::clone).collect()).collect();
        let mut tokens_covered: Vec<usize> = tokens[..keys.len() * pr].to_vec();
        let cow = cow.map(|(ck, rows)| {
            let n = &trie.nodes[&ck];
            tokens_covered.extend_from_slice(&n.chunk[..rows]);
            (n.bundle.iter().map(Arc::clone).collect::<Vec<_>>(), rows)
        });
        Some(PrefixHit { tokens: tokens_covered, bundles, cow, page_rows: pr })
    }

    /// Register a completed prefill's whole-chunk pages under its tokens.
    /// `bundles[c]` holds chunk `c`'s frozen pages (layer-major, K then
    /// V). No-op when prefix caching is off or the path collides.
    pub fn register_prefix(&self, tokens: &[usize], bundles: Vec<Vec<Arc<KvPage>>>) {
        if bundles.is_empty() {
            return;
        }
        let mut inner = lock_recover(&self.inner);
        if let Some(trie) = inner.trie.as_mut() {
            trie.register(tokens, bundles);
        }
    }

    /// Account a prefix-hit attach: `shared_pages` whole pages were
    /// reused instead of re-prefilled, at a peak sharing degree of
    /// `max_refcount`.
    pub fn note_attach(&self, shared_pages: usize, max_refcount: usize) {
        self.shared_pages_attached.fetch_add(shared_pages, Ordering::Relaxed);
        self.shared_ref_high_water.fetch_max(max_refcount, Ordering::Relaxed);
    }

    /// Pages currently allocated out of the pool.
    pub fn live_pages(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    /// Pages parked on the free list.
    pub fn free_pages(&self) -> usize {
        lock_recover(&self.inner).free.len()
    }

    /// Most pages ever simultaneously live.
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Allocations served from the free list (recycling effectiveness).
    pub fn freelist_hits(&self) -> usize {
        self.freelist_hits.load(Ordering::Relaxed)
    }

    /// Resident bytes prefix sharing avoided duplicating (whole shared
    /// pages attached × page bytes).
    pub fn bytes_saved(&self) -> usize {
        self.shared_pages_attached.load(Ordering::Relaxed) * self.shape.page_bytes()
    }

    /// Peak `Arc::strong_count` observed across prefix-hit attaches.
    pub fn shared_refcount_high_water(&self) -> usize {
        self.shared_ref_high_water.load(Ordering::Relaxed)
    }

    /// Cached prefix chunks evicted under page pressure.
    pub fn prefix_evictions(&self) -> usize {
        self.prefix_evictions.load(Ordering::Relaxed)
    }

    /// Cached prefix chunks currently resident in the index.
    pub fn prefix_nodes(&self) -> usize {
        lock_recover(&self.inner).trie.as_ref().map_or(0, |t| t.nodes.len())
    }

    /// Overflow pages minted beyond `max_pages` for reserved streams
    /// (only reachable with prefix caching on under a tight page cap).
    pub fn overflow_allocs(&self) -> usize {
        self.overflow_allocs.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Matrix, Rng};

    fn shape(kind: KvCacheType, page_rows: usize) -> PageShape {
        PageShape::new(kind, 16, page_rows)
    }

    fn full_page(pool: &PagePool, rows: &Matrix) -> Arc<KvPage> {
        let mut p = pool.alloc().unwrap();
        for r in 0..pool.page_rows() {
            p.append_row(pool.shape(), rows.row(r));
        }
        Arc::new(p)
    }

    #[test]
    fn page_shape_is_group_aligned_for_every_kind() {
        // The invariant the module docs promise: a page's lane plane is a
        // whole number of groups for any page height, so no group ever
        // straddles a page.
        for kind in QuantKind::ALL {
            for pr in [1usize, 3, 16, 64, 100] {
                let s = PageShape::new(KvCacheType::Quant(kind), 24, pr);
                assert_eq!(s.row_lanes() % kind.group(), 0, "{kind} pr={pr}");
                assert_eq!(s.page_bytes(), pr * s.row_bytes());
            }
        }
        let f = shape(KvCacheType::F32, 8);
        assert_eq!(f.groups_per_row(), 0);
        assert_eq!(f.page_bytes(), 8 * 16 * 4);
    }

    #[test]
    fn alloc_recycle_reuses_the_exact_allocation() {
        let pool = PagePool::new(shape(KvCacheType::HIF4, 4), 0, false);
        let mut rng = Rng::seed(3);
        let rows = Matrix::randn(4, 16, 1.0, &mut rng);
        let mut page = pool.alloc().unwrap();
        for r in 0..4 {
            page.append_row(pool.shape(), rows.row(r));
        }
        let cap = page.capacity_bytes();
        assert_eq!(page.resident_bytes(), 4 * pool.shape().row_bytes());
        assert!(cap >= page.resident_bytes());
        pool.recycle(page);
        assert_eq!(pool.live_pages(), 0);
        assert_eq!(pool.free_pages(), 1);
        // The recycled allocation comes back with identical capacity and
        // zero resident bytes — the free-list exact-byte check.
        let page = pool.alloc().unwrap();
        assert_eq!(pool.freelist_hits(), 1);
        assert_eq!(page.rows(), 0);
        assert_eq!(page.resident_bytes(), 0);
        assert_eq!(page.capacity_bytes(), cap, "free list must hand back the same allocation");
        assert_eq!(pool.high_water(), 1);
    }

    #[test]
    fn exhaustion_is_a_structured_error() {
        let pool = PagePool::new(shape(KvCacheType::F32, 2), 2, false);
        let a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        let err = pool.alloc().unwrap_err();
        assert_eq!(err, PagesExhausted { live: 2, max_pages: 2 });
        assert!(err.to_string().contains("2 of 2"));
        // Recycling frees a slot.
        pool.recycle(a);
        assert!(pool.alloc().is_ok());
    }

    #[test]
    fn reserved_alloc_overflows_the_cap_instead_of_failing() {
        let pool = PagePool::new(shape(KvCacheType::F32, 2), 1, false);
        let a = pool.alloc().unwrap();
        // Fallible alloc refuses; the reservation-backed path mints an
        // overflow page and keeps the live count honest for recycling.
        assert!(pool.alloc().is_err());
        let b = pool.alloc_reserved();
        assert_eq!(pool.overflow_allocs(), 1);
        assert_eq!(pool.live_pages(), 2);
        assert_eq!(pool.high_water(), 2);
        pool.recycle(a);
        pool.recycle(b);
        assert_eq!(pool.live_pages(), 0);
        assert_eq!(pool.free_pages(), 2);
        // Under the cap again the infallible path is an ordinary alloc.
        let _c = pool.alloc_reserved();
        assert_eq!(pool.overflow_allocs(), 1);
    }

    #[test]
    fn release_recycles_only_the_last_holder() {
        let pool = PagePool::new(shape(KvCacheType::HIF4, 2), 0, false);
        let mut rng = Rng::seed(4);
        let rows = Matrix::randn(2, 16, 1.0, &mut rng);
        let page = full_page(&pool, &rows);
        let other = Arc::clone(&page);
        pool.release(page);
        assert_eq!(pool.free_pages(), 0, "a shared page must not recycle early");
        pool.release(other);
        assert_eq!(pool.free_pages(), 1, "the last holder recycles");
        assert_eq!(pool.live_pages(), 0);
    }

    #[test]
    fn prefix_register_lookup_roundtrip_with_cow() {
        let pool = PagePool::new(shape(KvCacheType::HIF4, 4), 0, true);
        let mut rng = Rng::seed(5);
        let rows = Matrix::randn(4, 16, 1.0, &mut rng);
        // Register a 2-chunk prompt (8 tokens + 1 uncovered): bundles of
        // one page each (1 layer × K only, for the test's purposes).
        let tokens: Vec<usize> = (10..19).collect();
        let b0 = full_page(&pool, &rows);
        let b1 = full_page(&pool, &rows);
        pool.register_prefix(&tokens, vec![vec![Arc::clone(&b0)], vec![Arc::clone(&b1)]]);
        assert_eq!(pool.prefix_nodes(), 2);

        // Exact re-lookup: both chunks hit (limit excludes the last
        // token, which is exactly the uncovered one).
        let hit = pool.lookup_prefix(&tokens).expect("registered prefix must hit");
        assert_eq!(hit.chunks(), 2);
        assert_eq!(hit.rows(), 8);
        assert_eq!(hit.tokens, tokens[..8]);
        assert!(hit.cow.is_none());
        assert!(hit.max_refcount() >= 2, "trie + hit pin the pages");

        // A prompt sharing one chunk then diverging mid-chunk: one whole
        // chunk + a CoW seed of the common rows.
        let fork: Vec<usize> = vec![10, 11, 12, 13, 14, 15, 99, 98, 97];
        let hit = pool.lookup_prefix(&fork).expect("shared first chunk must hit");
        assert_eq!(hit.chunks(), 1);
        let (cow_bundle, cow_rows) = hit.cow.as_ref().expect("divergence inside chunk 2");
        assert_eq!(*cow_rows, 2, "tokens 14,15 match before 99 diverges");
        assert_eq!(cow_bundle.len(), 1);
        assert_eq!(hit.rows(), 6);
        assert_eq!(hit.tokens, fork[..6]);

        // A cold prompt misses outright.
        assert!(pool.lookup_prefix(&[1, 2, 3, 4, 5]).is_none());
        // Too short to cover even one chunk (limit = len-1 < page_rows)
        // and no divergence candidate → miss.
        assert!(pool.lookup_prefix(&[7, 7, 7]).is_none());
    }

    #[test]
    fn lookup_never_covers_the_final_token() {
        let pool = PagePool::new(shape(KvCacheType::F32, 2), 0, true);
        let mut rng = Rng::seed(6);
        let rows = Matrix::randn(2, 16, 1.0, &mut rng);
        let tokens = vec![1usize, 2, 3, 4];
        let bundles = vec![vec![full_page(&pool, &rows)], vec![full_page(&pool, &rows)]];
        pool.register_prefix(&tokens, bundles);
        // The exact same 4-token prompt: only chunk 1 plus a 1-row CoW
        // seed may be covered — row 4 (the last token) must stay
        // uncovered so the model still produces a logits row.
        let hit = pool.lookup_prefix(&tokens).expect("hit");
        assert_eq!(hit.chunks(), 1);
        assert_eq!(hit.cow.as_ref().map(|(_, r)| *r), Some(1));
        assert_eq!(hit.rows(), 3);
        assert!(hit.rows() < tokens.len());
    }

    #[test]
    fn unreferenced_prefixes_evict_under_page_pressure() {
        // Cap = 4 pages; two single-page chunks cached and released by
        // their registrant. New allocations beyond the cap must reclaim
        // them LRU-first instead of failing.
        let pool = PagePool::new(shape(KvCacheType::F32, 2), 4, true);
        let mut rng = Rng::seed(7);
        let rows = Matrix::randn(2, 16, 1.0, &mut rng);
        let a = full_page(&pool, &rows);
        let b = full_page(&pool, &rows);
        pool.register_prefix(&[1, 2], vec![vec![Arc::clone(&a)]]);
        pool.register_prefix(&[3, 4], vec![vec![Arc::clone(&b)]]);
        // Touch [3,4] so [1,2] is LRU.
        let _ = pool.lookup_prefix(&[3, 4, 9]);
        drop(a);
        drop(b);
        let _c = pool.alloc().unwrap();
        let _d = pool.alloc().unwrap();
        // Live = 4 (2 cached + 2 fresh): the next alloc evicts [1,2].
        let _e = pool.alloc().expect("eviction must free an unreferenced cached chunk");
        assert_eq!(pool.prefix_evictions(), 1);
        assert_eq!(pool.prefix_nodes(), 1);
        assert!(pool.lookup_prefix(&[1, 2, 9]).is_none(), "evicted chunk is gone");
        assert!(pool.lookup_prefix(&[3, 4, 9]).is_some(), "recently used chunk survives");
        // A pinned chunk never evicts: with [3,4] pinned and the pool
        // back at its cap, allocation fails structurally instead of
        // stealing pages a hit is still holding.
        let pin = pool.lookup_prefix(&[3, 4, 9]).unwrap();
        let err = pool.alloc().unwrap_err();
        assert_eq!(err.max_pages, 4);
        drop(pin);
    }

    #[test]
    fn cow_copy_is_bitwise_identical_to_the_source_prefix() {
        for kind in [KvCacheType::F32, KvCacheType::HIF4] {
            let s = shape(kind, 4);
            let pool = PagePool::new(s, 0, false);
            let mut rng = Rng::seed(8);
            let rows = Matrix::randn(4, 16, 0.9, &mut rng);
            let src = full_page(&pool, &rows);
            let mut dst = pool.alloc().unwrap();
            dst.copy_prefix_from(&s, &src, 3);
            assert_eq!(dst.rows(), 3);
            match kind {
                KvCacheType::F32 => {
                    assert_eq!(dst.f32_data(), &src.f32_data()[..3 * s.kvd]);
                }
                _ => {
                    assert_eq!(dst.lanes(), &src.lanes()[..3 * s.row_lanes()]);
                    let got: Vec<u64> = dst.scales().iter().map(|x| x.to_bits()).collect();
                    let shared = &src.scales()[..3 * s.groups_per_row()];
                    let want: Vec<u64> = shared.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want);
                }
            }
            // And the copy keeps accepting appends up to the page height.
            dst.append_row(&s, rows.row(3));
            assert_eq!(dst.rows(), 4);
        }
    }

    #[test]
    fn hash_collision_degrades_to_a_miss_not_a_wrong_attach() {
        // Force the collision arm structurally: insert a node, then
        // register a different chunk under the same key via the trie's
        // internals. Lookup must reject on exact-token compare.
        let mut trie = PrefixTrie::new(2);
        let pool = PagePool::new(shape(KvCacheType::F32, 2), 0, false);
        let mut rng = Rng::seed(9);
        let rows = Matrix::randn(2, 16, 1.0, &mut rng);
        let real_key = chunk_key(0, &[5, 6]);
        trie.nodes.insert(
            real_key,
            TrieNode {
                parent: None,
                chunk: vec![9, 9], // wrong tokens under [5,6]'s key
                bundle: vec![full_page(&pool, &rows)],
                children: Vec::new(),
                last_used: 0,
            },
        );
        trie.roots.push(real_key);
        let (keys, _) = trie.lookup(&[5, 6, 7], 2);
        assert!(keys.is_empty(), "token mismatch must read as a miss");
    }
}
