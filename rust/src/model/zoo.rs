//! The model zoo: tiny stand-ins for the paper's evaluation models,
//! architecture-matched per DESIGN.md §4:
//!
//! | paper model         | stand-in            | architecture features        |
//! |---------------------|---------------------|------------------------------|
//! | LLaMA2-7B           | `llama2_tiny`       | MHA + SwiGLU                 |
//! | LLaMA3-8B           | `llama3_tiny`       | GQA + SwiGLU                 |
//! | Qwen2.5-14B         | `qwen_tiny`         | GQA + wide SwiGLU            |
//! | Mistral-7B          | `mistral_tiny`      | GQA + SwiGLU + **outlier-    |
//! |                     |                     | widened weights** (crashes   |
//! |                     |                     | NVFP4 direct-cast, §IV.B)    |
//! | DeepSeek-V3.1 671B  | `deepseek_tiny`     | **MLA + MoE**                |
//! | LongCat 560B        | `longcat_tiny`      | MHA + **MoE** + outliers     |

use super::config::{Attention, Ffn, ModelConfig};

fn base(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.to_string(),
        vocab: 320,
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        head_dim: 16,
        attention: Attention::Mha,
        ffn: Ffn::SwiGlu,
        d_ff: 128,
        max_seq: 48,
        rope_base: 10000.0,
        outlier_scale: 1.0,
        outlier_frac: 0.0,
    }
}

/// LLaMA2-7B stand-in: classic MHA + SwiGLU.
pub fn llama2_tiny() -> ModelConfig {
    base("Llama2-tiny (MHA)")
}

/// LLaMA3-8B stand-in: GQA (4 heads, 2 KV heads).
pub fn llama3_tiny() -> ModelConfig {
    let mut c = base("Llama3-tiny (GQA)");
    c.attention = Attention::Gqa { kv_heads: 2 };
    c
}

/// Qwen2.5-14B stand-in: GQA with a wider FFN (its distributions are
/// "optimized during training" — more capacity, cleaner optima).
pub fn qwen_tiny() -> ModelConfig {
    let mut c = base("Qwen2.5-tiny (GQA)");
    c.attention = Attention::Gqa { kv_heads: 2 };
    c.d_ff = 192;
    c
}

/// Mistral-7B stand-in: GQA + post-training outlier widening far beyond
/// NVFP4's 22-binade global range (the §IV.B "inference crash" case).
pub fn mistral_tiny() -> ModelConfig {
    let mut c = base("Mistral-tiny (GQA, wide dist)");
    c.attention = Attention::Gqa { kv_heads: 2 };
    c.outlier_scale = 65536.0; // 2^16: pushes group scales past E4M3 max
    c.outlier_frac = 0.03;
    c
}

/// DeepSeek-V3.1 stand-in: MLA attention + MoE FFN.
pub fn deepseek_tiny() -> ModelConfig {
    let mut c = base("DeepSeek-tiny (MLA+MoE)");
    c.attention = Attention::Mla { kv_rank: 32 };
    c.ffn = Ffn::Moe { experts: 4, top_k: 2 };
    c.d_ff = 96;
    c
}

/// LongCat stand-in: MoE with outlier widening (quantization-sensitive,
/// NVFP4 crashes on hard tasks §IV.C).
pub fn longcat_tiny() -> ModelConfig {
    let mut c = base("LongCat-tiny (MoE, wide dist)");
    c.ffn = Ffn::Moe { experts: 4, top_k: 2 };
    c.d_ff = 96;
    c.outlier_scale = 65536.0;
    c.outlier_frac = 0.03;
    c
}

/// The Table III roster.
pub fn small_llms() -> Vec<ModelConfig> {
    vec![llama2_tiny(), llama3_tiny(), qwen_tiny(), mistral_tiny()]
}

/// The Table V roster.
pub fn large_llms() -> Vec<ModelConfig> {
    vec![deepseek_tiny(), longcat_tiny()]
}

/// The whole zoo with stable machine keys — the battery's model axis and
/// its bench-JSON spellings. Keys are permanent identifiers (golden files
/// pin them); display names stay free to change.
pub fn keyed() -> Vec<(&'static str, ModelConfig)> {
    vec![
        ("llama2", llama2_tiny()),
        ("llama3", llama3_tiny()),
        ("qwen", qwen_tiny()),
        ("mistral", mistral_tiny()),
        ("deepseek", deepseek_tiny()),
        ("longcat", longcat_tiny()),
    ]
}

/// Look one zoo model up by its [`keyed`] key (the CLI `--models` values).
pub fn by_key(key: &str) -> Option<ModelConfig> {
    keyed().into_iter().find(|(k, _)| *k == key).map(|(_, c)| c)
}

/// Deterministic per-model training seed, derived from the key (FNV-1a)
/// so every battery entry point — CLI, bench, golden test — trains
/// bit-identical weights for the same model regardless of roster order.
pub fn train_seed(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_covers_paper_architectures() {
        let small = small_llms();
        assert_eq!(small.len(), 4);
        assert!(matches!(small[0].attention, Attention::Mha));
        assert!(matches!(small[1].attention, Attention::Gqa { .. }));
        assert!(small[3].outlier_scale > 1000.0, "Mistral stand-in must be wide");
        let large = large_llms();
        assert!(matches!(large[0].attention, Attention::Mla { .. }));
        assert!(matches!(large[0].ffn, Ffn::Moe { .. }));
        assert!(matches!(large[1].ffn, Ffn::Moe { .. }));
    }

    #[test]
    fn keys_cover_rosters_and_seeds_are_stable() {
        let keyed = keyed();
        assert_eq!(keyed.len(), small_llms().len() + large_llms().len());
        // Keys are unique and each resolves through by_key to the same
        // config (by display name).
        for (k, cfg) in &keyed {
            assert_eq!(by_key(k).unwrap().name, cfg.name);
            assert_eq!(keyed.iter().filter(|(k2, _)| k2 == k).count(), 1, "dup key {k}");
        }
        assert!(by_key("gpt5").is_none());
        // Seeds: pure function of the key, distinct across the zoo.
        let mut seeds: Vec<u64> = keyed.iter().map(|(k, _)| train_seed(k)).collect();
        assert_eq!(train_seed("llama2"), train_seed("llama2"));
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), keyed.len(), "seed collision in the zoo");
    }

    #[test]
    fn params_in_tiny_range() {
        for c in small_llms().into_iter().chain(large_llms()) {
            let p = c.param_count();
            assert!(
                (50_000..5_000_000).contains(&p),
                "{} has {p} params",
                c.name
            );
        }
    }
}
