//! Training: cross-entropy loss, full manual backprop through the
//! transformer, and Adam — used to actually train the tiny stand-in LLMs
//! before the PTQ experiments (Tables III–V) and verified against numerical
//! gradients in the tests.

use super::config::Ffn;
use super::transformer::{
    causal_attention_bwd, gelu_grad, rmsnorm_bwd, rope_bwd, silu_grad, ForwardCache,
    Transformer,
};
use crate::tensor::gemm::matmul;
use crate::tensor::{Matrix, Rng};
use std::collections::BTreeMap;

/// Gradients keyed the same way as the weights.
#[derive(Debug, Default)]
pub struct Grads {
    /// Per-linear dW, keyed by `Linear::name`.
    pub linears: BTreeMap<String, Matrix>,
    pub embed: Matrix,
    pub norms: BTreeMap<String, Vec<f32>>,
}

/// Softmax cross-entropy against next-token targets. Returns (loss,
/// dlogits). Positions whose target is `usize::MAX` are masked out.
pub fn cross_entropy(logits: &Matrix, targets: &[usize]) -> (f32, Matrix) {
    assert_eq!(logits.rows, targets.len());
    let mut dl = Matrix::zeros(logits.rows, logits.cols);
    let mut loss = 0f64;
    let mut n = 0usize;
    for r in 0..logits.rows {
        if targets[r] == usize::MAX {
            continue;
        }
        n += 1;
    }
    let inv_n = 1.0 / n.max(1) as f32;
    for r in 0..logits.rows {
        let t = targets[r];
        if t == usize::MAX {
            continue;
        }
        let row = logits.row(r);
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
        let mut denom = 0f32;
        for x in row {
            denom += (x - maxv).exp();
        }
        let logp = row[t] - maxv - denom.ln();
        loss -= logp as f64;
        let drow = dl.row_mut(r);
        for (c, x) in row.iter().enumerate() {
            let p = (x - maxv).exp() / denom;
            drow[c] = (p - if c == t { 1.0 } else { 0.0 }) * inv_n;
        }
    }
    // audit:allow(narrowing) -- mean loss reports at f32; the accumulation itself stays f64.
    ((loss / n.max(1) as f64) as f32, dl)
}

impl Transformer {
    /// Full backward pass: consumes the forward cache and the dlogits,
    /// produces gradients for every parameter.
    pub fn backward(&self, cache: &ForwardCache, dlogits: &Matrix) -> Grads {
        let cfg = &self.cfg;
        let mut g = Grads {
            embed: Matrix::zeros(self.w.embed.rows, self.w.embed.cols),
            ..Default::default()
        };

        // Head: logits = normed_f · Whᵀ.
        g.linears
            .insert(self.w.head.name.clone(), matmul(&transpose_ref(dlogits), &cache.normed_f));
        let dnormed_f = matmul(dlogits, &self.w.head.w);
        let (mut dx, dgf) = rmsnorm_bwd(&dnormed_f, &cache.x_final, &self.w.norm_f, &cache.rms_f);
        g.norms.insert("norm_f".into(), dgf);

        for (li, layer) in self.w.layers.iter().enumerate().rev() {
            let lc = &cache.layers[li];
            let fc = lc.ffn.as_ref().expect("cache");
            // ---- FFN block backward (x2 = x1 + ffn(norm2(x1))) ----
            let dffn_out = &dx; // gradient w.r.t. ffn output
            let mut dqx = Matrix::zeros(fc.qx.rows, fc.qx.cols);
            match &fc.routing {
                None => {
                    let e = &layer.ffn[0];
                    let ec = fc.experts[0].as_ref().unwrap();
                    ffn_expert_bwd(e, ec, &fc.qx, dffn_out, cfg, &mut g, &mut dqx, 1.0, None);
                }
                Some((routing, per_expert_out)) => {
                    let gate = layer.gate.as_ref().unwrap();
                    let logits = fc.gate_logits.as_ref().unwrap();
                    let mut dgate_logits = Matrix::zeros(logits.rows, logits.cols);
                    for (ei, e) in layer.ffn.iter().enumerate() {
                        let Some(ec) = fc.experts[ei].as_ref() else { continue };
                        // dy_expert[r] = route_weight[r] × dffn_out[r]
                        let mut dyo = Matrix::zeros(dffn_out.rows, dffn_out.cols);
                        let mut used_any = false;
                        for (r, routes) in routing.iter().enumerate() {
                            for (i, w) in routes {
                                if *i == ei {
                                    crate::tensor::gemm::axpy(
                                        *w,
                                        dffn_out.row(r),
                                        dyo.row_mut(r),
                                    );
                                    used_any = true;
                                }
                            }
                        }
                        if used_any {
                            ffn_expert_bwd(
                                e, ec, &fc.qx, &dyo, cfg, &mut g, &mut dqx, 1.0, None,
                            );
                        }
                        // Router gradient: dweight_e[r] = dffn_out[r]·y_e[r].
                        if let Some(yo) = per_expert_out[ei].as_ref() {
                            for (r, routes) in routing.iter().enumerate() {
                                if routes.iter().any(|(i, _)| *i == ei) {
                                    let dwr = crate::tensor::gemm::dot(
                                        dffn_out.row(r),
                                        yo.row(r),
                                    );
                                    dgate_logits.data[r * logits.cols + ei] = dwr;
                                }
                            }
                        }
                    }
                    // Through the renormalized top-k softmax (treat the
                    // selection as constant): for selected set S of row r,
                    // dlogit_e = p_e(dw_e − Σ_{f∈S} p_f dw_f).
                    let mut dlog = Matrix::zeros(logits.rows, logits.cols);
                    for (r, routes) in routing.iter().enumerate() {
                        let dot: f32 = routes
                            .iter()
                            .map(|(i, p)| p * dgate_logits.data[r * logits.cols + i])
                            .sum();
                        for (i, p) in routes {
                            dlog.data[r * logits.cols + i] =
                                p * (dgate_logits.data[r * logits.cols + i] - dot);
                        }
                    }
                    accum_linear(&mut g, &gate.name, &matmul(&transpose_ref(&dlog), &lc.normed2));
                    // Router consumed the *unquantized* normed2.
                    let dnormed_extra = matmul(&dlog, &gate.w);
                    crate::tensor::gemm::axpy_mat(1.0, &dnormed_extra, &mut dqx);
                }
            }
            // qx == normed2 in training (no act quant). Norm backward:
            let (dx1_from_norm, dg2) = rmsnorm_bwd(&dqx, &lc.x_mid, &layer.norm2, &lc.rms2);
            g.norms.insert(format!("layer{li}.norm2"), dg2);
            // Residual: dx1 = dx (through residual) + dx1_from_norm.
            let mut dx1 = dx.clone();
            crate::tensor::gemm::axpy_mat(1.0, &dx1_from_norm, &mut dx1);

            // ---- Attention block backward (x1 = x + attn(norm1(x))) ----
            let ac = lc.attn.as_ref().expect("cache");
            let dattn_out = &dx1;
            // out = ctx · Woᵀ
            accum_linear(&mut g, &layer.wo.name, &matmul(&transpose_ref(dattn_out), &ac.ctx));
            let dctx = matmul(dattn_out, &layer.wo.w);
            let (mut dq, mut dk, dv) = causal_attention_bwd(
                &dctx,
                &ac.q,
                &ac.k,
                &ac.v,
                &ac.probs,
                &cache.seq_lens,
                cfg.n_heads,
                cfg.kv_heads(),
                cfg.head_dim,
            );
            // RoPE backward.
            rope_bwd(&mut dq, &cache.seq_lens, cfg.n_heads, cfg.head_dim, cfg.rope_base);
            rope_bwd(&mut dk, &cache.seq_lens, cfg.kv_heads(), cfg.head_dim, cfg.rope_base);
            // Projections.
            accum_linear(&mut g, &layer.wq.name, &matmul(&transpose_ref(&dq), &ac.qin));
            accum_linear(&mut g, &layer.wk.name, &matmul(&transpose_ref(&dk), &ac.kv_in));
            accum_linear(&mut g, &layer.wv.name, &matmul(&transpose_ref(&dv), &ac.kv_in));
            let mut dqin = matmul(&dq, &layer.wq.w);
            let dkv_in = {
                let mut t = matmul(&dk, &layer.wk.w);
                crate::tensor::gemm::axpy_mat(1.0, &matmul(&dv, &layer.wv.w), &mut t);
                t
            };
            match &layer.wdkv {
                Some(dkv_lin) => {
                    // kv_in = latent = qin · Wdkvᵀ.
                    accum_linear(
                        &mut g,
                        &dkv_lin.name,
                        &matmul(&transpose_ref(&dkv_in), &ac.qin),
                    );
                    crate::tensor::gemm::axpy_mat(1.0, &matmul(&dkv_in, &dkv_lin.w), &mut dqin);
                }
                None => {
                    crate::tensor::gemm::axpy_mat(1.0, &dkv_in, &mut dqin);
                }
            }
            let (dx_from_norm, dg1) = rmsnorm_bwd(&dqin, &lc.x_in, &layer.norm1, &lc.rms1);
            g.norms.insert(format!("layer{li}.norm1"), dg1);
            dx = dx1;
            crate::tensor::gemm::axpy_mat(1.0, &dx_from_norm, &mut dx);
        }

        // Embedding gradient.
        let mut row = 0usize;
        for seq in &cache.tokens {
            for &t in seq {
                crate::tensor::gemm::axpy(1.0, dx.row(row), g.embed.row_mut(t));
                row += 1;
            }
        }
        g
    }
}

/// FFN expert backward; accumulates dW and adds the input gradient into
/// `dqx`.
#[allow(clippy::too_many_arguments)]
fn ffn_expert_bwd(
    e: &super::transformer::FfnWeights,
    ec: &super::transformer::ExpertCache,
    qx: &Matrix,
    dy: &Matrix,
    cfg: &crate::model::config::ModelConfig,
    g: &mut Grads,
    dqx: &mut Matrix,
    scale: f32,
    _unused: Option<()>,
) {
    let _ = scale;
    // y = act · W2ᵀ
    accum_linear(g, &e.w2.name, &matmul(&transpose_ref(dy), &ec.act));
    let dact = matmul(dy, &e.w2.w);
    match (&e.w3, cfg.ffn) {
        (None, Ffn::Gelu) | (None, _) => {
            // act = gelu(h1)
            let mut dh1 = dact;
            for (d, h) in dh1.data.iter_mut().zip(&ec.h1.data) {
                *d *= gelu_grad(*h);
            }
            accum_linear(g, &e.w1.name, &matmul(&transpose_ref(&dh1), qx));
            crate::tensor::gemm::axpy_mat(1.0, &matmul(&dh1, &e.w1.w), dqx);
        }
        (Some(w3), _) => {
            // act = silu(h1) ⊙ h3.
            let h3 = ec.h3.as_ref().unwrap();
            let mut dh1 = dact.clone();
            let mut dh3 = dact;
            for i in 0..dh1.data.len() {
                let s = ec.h1.data[i];
                let silu_s = s / (1.0 + (-s).exp());
                dh3.data[i] *= silu_s;
                dh1.data[i] *= h3.data[i] * silu_grad(s);
            }
            accum_linear(g, &e.w1.name, &matmul(&transpose_ref(&dh1), qx));
            accum_linear(g, &w3.name, &matmul(&transpose_ref(&dh3), qx));
            crate::tensor::gemm::axpy_mat(1.0, &matmul(&dh1, &e.w1.w), dqx);
            crate::tensor::gemm::axpy_mat(1.0, &matmul(&dh3, &w3.w), dqx);
        }
    }
}

fn accum_linear(g: &mut Grads, name: &str, dw: &Matrix) {
    match g.linears.get_mut(name) {
        Some(acc) => crate::tensor::gemm::axpy_mat(1.0, dw, acc),
        None => {
            g.linears.insert(name.to_string(), dw.clone());
        }
    }
}

/// Cheap transpose wrapper (gradients are small at tiny-model scale).
fn transpose_ref(m: &Matrix) -> Matrix {
    m.transpose()
}

// ---------------------------------------------------------------------------
// Adam
// ---------------------------------------------------------------------------

/// Adam optimizer state over all parameters.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub step: u64,
    m: BTreeMap<String, Vec<f32>>,
    v: BTreeMap<String, Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            step: 0,
            m: BTreeMap::new(),
            v: BTreeMap::new(),
        }
    }

    fn update_buf(&mut self, key: &str, w: &mut [f32], g: &[f32], lr_t: f32) {
        let m = self.m.entry(key.to_string()).or_insert_with(|| vec![0.0; w.len()]);
        let v = self.v.entry(key.to_string()).or_insert_with(|| vec![0.0; w.len()]);
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        for i in 0..w.len() {
            m[i] = b1 * m[i] + (1.0 - b1) * g[i];
            v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
            w[i] -= lr_t * m[i] / (v[i].sqrt() + eps);
        }
    }

    /// Apply one Adam step to the model given gradients.
    pub fn apply(&mut self, model: &mut Transformer, grads: &Grads) {
        self.step += 1;
        let t = self.step as f32;
        let lr_t = self.lr * (1.0 - self.beta2.powf(t)).sqrt() / (1.0 - self.beta1.powf(t));
        // Linears: one pass over the model, updating those with gradients.
        let this = std::cell::RefCell::new(&mut *self);
        model.visit_linears_mut(&mut |lin| {
            if let Some(dw) = grads.linears.get(&lin.name) {
                this.borrow_mut().update_buf(&lin.name, &mut lin.w.data, &dw.data, lr_t);
            }
        });
        drop(this);
        // Embedding + norms.
        let mut embed = std::mem::take(&mut model.w.embed.data);
        self.update_buf("embed", &mut embed, &grads.embed.data, lr_t);
        model.w.embed.data = embed;
        for (name, dg) in &grads.norms {
            if name == "norm_f" {
                let mut nf = std::mem::take(&mut model.w.norm_f);
                self.update_buf(name, &mut nf, dg, lr_t);
                model.w.norm_f = nf;
            } else if let Some(rest) = name.strip_prefix("layer") {
                let (idx, which) = rest.split_once('.').unwrap();
                let li: usize = idx.parse().unwrap();
                let layer = &mut model.w.layers[li];
                let buf = if which == "norm1" { &mut layer.norm1 } else { &mut layer.norm2 };
                let mut b = std::mem::take(buf);
                self.update_buf(name, &mut b, dg, lr_t);
                *buf = b;
            }
        }
    }
}

/// One training step: forward, loss, backward, Adam update. Returns loss.
pub fn train_step(
    model: &mut Transformer,
    opt: &mut Adam,
    batch: &[Vec<usize>],
) -> f32 {
    // Targets: next token within each sequence; last position masked.
    let mut targets = Vec::new();
    for seq in batch {
        for i in 0..seq.len() {
            targets.push(if i + 1 < seq.len() { seq[i + 1] } else { usize::MAX });
        }
    }
    let mut cache = ForwardCache::new(model.cfg.n_layers);
    let logits = model.forward(batch, None, None, Some(&mut cache));
    let (loss, dlogits) = cross_entropy(&logits, &targets);
    let grads = model.backward(&cache, &dlogits);
    opt.apply(model, &grads);
    loss
}

/// Train for `steps` batches drawn by `sampler`; returns the loss curve.
pub fn train<F: FnMut(&mut Rng) -> Vec<Vec<usize>>>(
    model: &mut Transformer,
    steps: usize,
    lr: f32,
    seed: u64,
    mut sampler: F,
) -> Vec<f32> {
    let mut opt = Adam::new(lr);
    let mut rng = Rng::seed(seed);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let batch = sampler(&mut rng);
        losses.push(train_step(model, &mut opt, &batch));
    }
    losses
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{Attention, Ffn, ModelConfig};

    fn cfg(attn: Attention, ffn: Ffn) -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 24,
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            head_dim: 4,
            attention: attn,
            ffn,
            d_ff: 12,
            max_seq: 8,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    fn batch() -> Vec<Vec<usize>> {
        vec![vec![1, 4, 7, 2], vec![3, 9, 5]]
    }

    fn loss_of(model: &Transformer, batch: &[Vec<usize>]) -> f32 {
        let mut targets = Vec::new();
        for seq in batch {
            for i in 0..seq.len() {
                targets.push(if i + 1 < seq.len() { seq[i + 1] } else { usize::MAX });
            }
        }
        let logits = model.forward(batch, None, None, None);
        cross_entropy(&logits, &targets).0
    }

    /// Numerical gradient check on a sample of parameters of every variant.
    fn grad_check(attn: Attention, ffn: Ffn) {
        let mut model = Transformer::init(cfg(attn, ffn), 42);
        let b = batch();
        let mut targets = Vec::new();
        for seq in &b {
            for i in 0..seq.len() {
                targets.push(if i + 1 < seq.len() { seq[i + 1] } else { usize::MAX });
            }
        }
        let mut cache = ForwardCache::new(model.cfg.n_layers);
        let logits = model.forward(&b, None, None, Some(&mut cache));
        let (_, dlogits) = cross_entropy(&logits, &targets);
        let grads = model.backward(&cache, &dlogits);

        let eps = 1e-3f32;
        // Collect (name, flat index, analytic grad) probes across layers.
        let mut probes: Vec<(String, usize, f32)> = Vec::new();
        for (name, dw) in &grads.linears {
            for idx in [0usize, dw.data.len() / 2, dw.data.len() - 1] {
                probes.push((name.clone(), idx, dw.data[idx]));
            }
        }
        let embed_idx = model.cfg.d_model + 3;
        probes.push(("embed".into(), embed_idx, grads.embed.data[embed_idx]));
        for (name, idx, got) in probes {
            // Perturb the parameter ±eps.
            let perturb = |model: &mut Transformer, delta: f32| {
                if name == "embed" {
                    model.w.embed.data[idx] += delta;
                } else {
                    model.visit_linears_mut(&mut |lin| {
                        if lin.name == name {
                            lin.w.data[idx] += delta;
                        }
                    });
                }
            };
            perturb(&mut model, eps);
            let lp = loss_of(&model, &b);
            perturb(&mut model, -2.0 * eps);
            let lm = loss_of(&model, &b);
            perturb(&mut model, eps);
            let num = (lp - lm) / (2.0 * eps);
            let tol = 5e-2 * (1.0 + num.abs().max(got.abs()));
            assert!(
                (num - got).abs() <= tol,
                "{attn:?}/{ffn:?} {name}[{idx}]: numeric {num} vs analytic {got}"
            );
        }
    }

    #[test]
    fn grad_check_mha_swiglu() {
        grad_check(Attention::Mha, Ffn::SwiGlu);
    }

    #[test]
    fn grad_check_gqa_gelu() {
        grad_check(Attention::Gqa { kv_heads: 1 }, Ffn::Gelu);
    }

    #[test]
    fn grad_check_mla_swiglu() {
        grad_check(Attention::Mla { kv_rank: 6 }, Ffn::SwiGlu);
    }

    #[test]
    fn grad_check_moe() {
        grad_check(Attention::Mha, Ffn::Moe { experts: 3, top_k: 2 });
    }

    #[test]
    fn cross_entropy_gradient_sums_to_zero_rows() {
        let logits = Matrix::from_vec(2, 4, vec![0.1, 0.2, 0.3, 0.4, 1.0, -1.0, 0.0, 2.0]);
        let (loss, dl) = cross_entropy(&logits, &[2, usize::MAX]);
        assert!(loss > 0.0);
        let s: f32 = dl.row(0).iter().sum();
        assert!(s.abs() < 1e-6, "softmax-CE row gradient sums to 0");
        assert!(dl.row(1).iter().all(|x| *x == 0.0), "masked row has no grad");
    }

    #[test]
    fn training_reduces_loss() {
        // A tiny model must be able to memorize a repeating pattern fast.
        let mut model = Transformer::init(cfg(Attention::Mha, Ffn::SwiGlu), 5);
        let pattern = vec![vec![1usize, 2, 3, 4, 5, 6, 1, 2]];
        let losses = train(&mut model, 60, 3e-3, 6, |_| pattern.clone());
        let early: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let late: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(
            late < 0.5 * early,
            "loss should drop by >2x: early {early:.3} late {late:.3}"
        );
    }

    #[test]
    fn training_works_for_moe_and_mla() {
        for (attn, ffn) in [
            (Attention::Mla { kv_rank: 6 }, Ffn::SwiGlu),
            (Attention::Mha, Ffn::Moe { experts: 3, top_k: 2 }),
        ] {
            let mut model = Transformer::init(cfg(attn, ffn), 15);
            let pattern = vec![vec![1usize, 2, 3, 4, 5, 6, 1, 2]];
            let losses = train(&mut model, 50, 3e-3, 16, |_| pattern.clone());
            assert!(
                losses.last().unwrap() < &losses[0],
                "{attn:?}/{ffn:?}: {losses:?}"
            );
        }
    }
}

