//! Model architecture configuration — the zoo mirrors the paper's coverage:
//! MHA and GQA attention with multiple FFN forms for the small-LLM table
//! (Table III), plus MLA and MoE for the large-LLM table (Table V).

/// Attention variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Attention {
    /// Multi-Head Attention (LLaMA2-7B style).
    Mha,
    /// Grouped-Query Attention with `kv_heads` < heads (LLaMA3/Qwen style).
    Gqa { kv_heads: usize },
    /// Multi-head Latent Attention: K/V are up-projected from a shared
    /// low-rank latent (DeepSeek style). `kv_rank` is the latent width.
    Mla { kv_rank: usize },
}

/// Feed-forward variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ffn {
    /// SwiGLU: (silu(x·W1) ⊙ x·W3)·W2 — LLaMA/Mistral/Qwen style.
    SwiGlu,
    /// Plain GELU MLP: gelu(x·W1)·W2.
    Gelu,
    /// Mixture-of-Experts over SwiGLU experts with top-k routing; the
    /// gating network is *excluded* from quantization (§IV.C).
    Moe { experts: usize, top_k: usize },
}

/// Full model configuration.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Display name (appears in the benchmark tables).
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub attention: Attention,
    pub ffn: Ffn,
    /// FFN hidden width.
    pub d_ff: usize,
    pub max_seq: usize,
    /// RoPE base.
    pub rope_base: f32,
    /// Post-training weight-distribution widening: a handful of channels
    /// per linear layer are scaled by this factor after training, emulating
    /// the outlier channels of models with "broader numerical distributions"
    /// (the paper's Mistral-7B / LongCat cases that crash NVFP4 direct
    /// cast). 1.0 = disabled.
    pub outlier_scale: f32,
    /// Fraction of channels widened when `outlier_scale > 1`.
    pub outlier_frac: f32,
}

impl ModelConfig {
    /// Number of KV heads (equals heads for MHA/MLA).
    pub fn kv_heads(&self) -> usize {
        match self.attention {
            Attention::Gqa { kv_heads } => kv_heads,
            _ => self.n_heads,
        }
    }

    /// Total parameter count (exact, matching the weight allocator).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let hd = self.n_heads * self.head_dim;
        let kvd = self.kv_heads() * self.head_dim;
        let attn = match self.attention {
            Attention::Mla { kv_rank } => {
                // q: d→hd; latent down: d→r; k/v up: r→kvd each; out: hd→d.
                d * hd + d * kv_rank + 2 * kv_rank * kvd + hd * d
            }
            _ => d * hd + 2 * d * kvd + hd * d,
        };
        let ffn = match self.ffn {
            Ffn::SwiGlu => 3 * d * self.d_ff,
            Ffn::Gelu => 2 * d * self.d_ff,
            Ffn::Moe { experts, .. } => experts * 3 * d * self.d_ff + d * experts,
        };
        let per_layer = attn + ffn + 2 * d; // two RMSNorm gains
        self.vocab * d      // embedding
            + self.n_layers * per_layer
            + d                 // final norm
            + d * self.vocab // lm head
    }
}

/// Linear-layer category, used by the quantization policy (§IV.C quantizes
/// MLA_linear / MoE_linear excluding the gate / FFN_linear; embeddings and
/// the LM head are never quantized §IV.B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    AttnLinear,
    FfnLinear,
    MoeExpert,
    MoeGate,
    Embedding,
    LmHead,
}

impl LayerKind {
    /// Whether the paper's evaluation quantizes this layer class.
    pub fn quantized_by_paper(self) -> bool {
        matches!(self, LayerKind::AttnLinear | LayerKind::FfnLinear | LayerKind::MoeExpert)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ModelConfig {
        ModelConfig {
            name: "test".into(),
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            head_dim: 8,
            attention: Attention::Mha,
            ffn: Ffn::SwiGlu,
            d_ff: 64,
            max_seq: 32,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    #[test]
    fn kv_heads_by_variant() {
        let mut c = base();
        assert_eq!(c.kv_heads(), 4);
        c.attention = Attention::Gqa { kv_heads: 2 };
        assert_eq!(c.kv_heads(), 2);
        c.attention = Attention::Mla { kv_rank: 16 };
        assert_eq!(c.kv_heads(), 4);
    }

    #[test]
    fn param_count_positive_and_monotone() {
        let c = base();
        let p = c.param_count();
        assert!(p > 0);
        let mut bigger = base();
        bigger.n_layers = 4;
        assert!(bigger.param_count() > p);
    }

    #[test]
    fn paper_quantization_policy() {
        assert!(LayerKind::AttnLinear.quantized_by_paper());
        assert!(LayerKind::MoeExpert.quantized_by_paper());
        assert!(!LayerKind::MoeGate.quantized_by_paper());
        assert!(!LayerKind::Embedding.quantized_by_paper());
        assert!(!LayerKind::LmHead.quantized_by_paper());
    }
}
