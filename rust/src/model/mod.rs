//! Transformer model zoo: configs, a rust-native forward/backward substrate
//! (calibration, eval, and genuine training of the stand-in LLMs), and the
//! model zoo mirroring the paper's architecture coverage.

pub mod attention;
pub mod config;
pub mod kv;
pub mod pages;
pub mod train;
pub mod transformer;
pub mod zoo;
