//! Rust-native decoder-only transformer with full forward **and backward**
//! passes — the substrate that makes the Table III/V reproductions genuine:
//! the tiny stand-in LLMs are actually *trained* (Adam + cross-entropy) on
//! the synthetic corpus before PTQ, so BF16-vs-quantized accuracy drops are
//! measured, not simulated.
//!
//! This path is also the GPTQ calibration substrate (it records per-linear
//! inputs) and the fake-quant inference engine for the PTQ tables. Two
//! quantized-inference modes exist:
//!
//! * **Simulated** ([`Transformer::quantize_weights`] + a
//!   [`QuantPolicy`]): weights and activations are quantize→dequantized to
//!   f32 and the linears stay f32 GEMMs — the paper's accuracy-table
//!   semantics.
//! * **Real** ([`Transformer::prepack_quantized_weights`]): weights are
//!   quantized once into any [`QuantKind`]'s groups + decode-once integer
//!   operand planes held on each [`Linear`]; the forward pass then runs
//!   those linears through the fixed-point QGEMM (backend per
//!   [`crate::dotprod::kernel`]), quantizing activations on entry — the
//!   serving configuration, available for all five block formats through
//!   the unified [`QuantizedMatrix`] API.
//!
//! The *serving* path runs either the L2 JAX model via PJRT or this
//! rust-native model (`runtime/native.rs`, `server/`); see DESIGN.md.
//!
//! Architecture: token embedding → N × [RMSNorm → {MHA|GQA|MLA} + residual
//! → RMSNorm → {SwiGLU|GELU|MoE} + residual] → RMSNorm → LM head. RoPE on
//! q/k. All linears are `Matrix` in out×in layout (`y = x · Wᵀ`).

use super::attention::{attn_path, attn_tile_rows, fused_attention_seq, AttnPath, FusedAttnCall};
use super::config::{Attention, Ffn, LayerKind, ModelConfig};
use super::kv::{KvCache, KvCacheType};
use crate::dotprod::{Kernel, PackedQuantizedMatrix, QuantizedMatrix};
use crate::formats::rounding::RoundMode;
use crate::formats::{QuantKind, QuantScheme};
use crate::tensor::gemm::matmul_bt;
use crate::tensor::{Matrix, Rng};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Quantized weight operands a linear keeps alive across calls — one
/// format-generic pair for any [`QuantKind`]: the group form (for the
/// reference flow kernel) plus the decode-once integer planes (for the
/// packed fast path). Arc'd so cloning a quantized model shares rather
/// than re-packs.
#[derive(Debug, Clone)]
pub struct QuantWeights {
    pub units: Arc<QuantizedMatrix>,
    pub planes: Arc<PackedQuantizedMatrix>,
}

impl QuantWeights {
    /// The block format these operands are quantized in.
    pub fn kind(&self) -> QuantKind {
        self.units.kind()
    }
}

/// One named linear layer.
#[derive(Debug, Clone)]
pub struct Linear {
    /// Stable identifier, e.g. "layer2.ffn.w1".
    pub name: String,
    pub kind: LayerKind,
    /// out×in weights.
    pub w: Matrix,
    /// Real-quantized weight operands (see
    /// [`Transformer::prepack_quantized_weights`]): when set, the forward
    /// pass runs this linear through the fixed-point QGEMM instead of the
    /// dequantize-then-f32 simulated path, with the weight planes packed
    /// once and reused for every call/token.
    pub qw: Option<QuantWeights>,
}

impl Linear {
    fn new(name: String, kind: LayerKind, out: usize, inp: usize, rng: &mut Rng) -> Linear {
        // Xavier-ish init.
        let sigma = (2.0 / (out + inp) as f32).sqrt();
        Linear { name, kind, w: Matrix::randn(out, inp, sigma, rng), qw: None }
    }
}

/// Per-layer weights.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub norm1: Vec<f32>,
    pub wq: Linear,
    /// MHA/GQA: K projection from d_model. MLA: K up-projection from latent.
    pub wk: Linear,
    pub wv: Linear,
    /// MLA only: shared latent down-projection.
    pub wdkv: Option<Linear>,
    pub wo: Linear,
    pub norm2: Vec<f32>,
    /// SwiGLU/GELU weights, or per-expert weights for MoE.
    pub ffn: Vec<FfnWeights>,
    /// MoE router (never quantized).
    pub gate: Option<Linear>,
}

#[derive(Debug, Clone)]
pub struct FfnWeights {
    pub w1: Linear,
    pub w2: Linear,
    /// SwiGLU third projection (absent for GELU).
    pub w3: Option<Linear>,
}

/// Whole-model weights.
#[derive(Debug, Clone)]
pub struct Weights {
    pub embed: Matrix,
    pub layers: Vec<LayerWeights>,
    pub norm_f: Vec<f32>,
    pub head: Linear,
}

/// The model: config + weights.
#[derive(Debug, Clone)]
pub struct Transformer {
    pub cfg: ModelConfig,
    pub w: Weights,
}

/// Activation-quantization policy for fake-quant inference: which scheme
/// each linear kind uses (weights are quantized separately, see
/// [`Transformer::quantize_weights`]).
#[derive(Debug, Clone, Default)]
pub struct QuantPolicy {
    /// Scheme applied to *activations* entering quantized linears.
    pub act: Option<QuantScheme>,
    /// Quantize the attention K (post-RoPE) and V rows through the KV-cache
    /// codec of [`super::kv`] — the **full-recompute reference** for
    /// quantized-cache incremental decode: a forward with
    /// `kv: Some(KvCacheType::Quant(kind))` sees bit-identical K/V values
    /// to a cached decode that encoded the same rows on append, for any
    /// format. `None` / `Some(KvCacheType::F32)` are no-ops.
    pub kv: Option<KvCacheType>,
}

/// Calibration recorder: collects inputs of every quantized linear
/// (bounded row count) for GPTQ.
#[derive(Debug, Default)]
pub struct Calibration {
    pub max_rows: usize,
    pub inputs: BTreeMap<String, Matrix>,
}

impl Calibration {
    pub fn new(max_rows: usize) -> Calibration {
        Calibration { max_rows, inputs: BTreeMap::new() }
    }

    fn record(&mut self, name: &str, x: &Matrix) {
        let entry = self
            .inputs
            .entry(name.to_string())
            .or_insert_with(|| Matrix::zeros(0, x.cols));
        if entry.rows >= self.max_rows {
            return;
        }
        let take = (self.max_rows - entry.rows).min(x.rows);
        entry.data.extend_from_slice(&x.data[..take * x.cols]);
        entry.rows += take;
    }
}

impl Transformer {
    /// Deterministic random init.
    pub fn init(cfg: ModelConfig, seed: u64) -> Transformer {
        let mut rng = Rng::seed(seed);
        let d = cfg.d_model;
        let hd = cfg.n_heads * cfg.head_dim;
        let kvd = cfg.kv_heads() * cfg.head_dim;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let n = |part: &str| format!("layer{l}.{part}");
            let (wk_in, wv_in, wdkv) = match cfg.attention {
                Attention::Mla { kv_rank } => (
                    kv_rank,
                    kv_rank,
                    Some(Linear::new(n("attn.wdkv"), LayerKind::AttnLinear, kv_rank, d, &mut rng)),
                ),
                _ => (d, d, None),
            };
            let (n_ffn, ffn_kind, gate) = match cfg.ffn {
                Ffn::Moe { experts, .. } => (
                    experts,
                    LayerKind::MoeExpert,
                    Some(Linear::new(n("moe.gate"), LayerKind::MoeGate, experts, d, &mut rng)),
                ),
                _ => (1, LayerKind::FfnLinear, None),
            };
            let ffn = (0..n_ffn)
                .map(|e| {
                    let p = if n_ffn > 1 {
                        format!("layer{l}.moe.e{e}")
                    } else {
                        format!("layer{l}.ffn")
                    };
                    FfnWeights {
                        w1: Linear::new(format!("{p}.w1"), ffn_kind, cfg.d_ff, d, &mut rng),
                        w2: Linear::new(format!("{p}.w2"), ffn_kind, d, cfg.d_ff, &mut rng),
                        w3: match cfg.ffn {
                            Ffn::Gelu => None,
                            _ => Some(Linear::new(
                                format!("{p}.w3"),
                                ffn_kind,
                                cfg.d_ff,
                                d,
                                &mut rng,
                            )),
                        },
                    }
                })
                .collect();
            layers.push(LayerWeights {
                norm1: vec![1.0; d],
                wq: Linear::new(n("attn.wq"), LayerKind::AttnLinear, hd, d, &mut rng),
                wk: Linear::new(n("attn.wk"), LayerKind::AttnLinear, kvd, wk_in, &mut rng),
                wv: Linear::new(n("attn.wv"), LayerKind::AttnLinear, kvd, wv_in, &mut rng),
                wdkv,
                wo: Linear::new(n("attn.wo"), LayerKind::AttnLinear, d, hd, &mut rng),
                norm2: vec![1.0; d],
                ffn,
                gate,
            });
        }
        let w = Weights {
            embed: Matrix::randn(cfg.vocab, d, 0.02, &mut rng),
            layers,
            norm_f: vec![1.0; d],
            head: Linear::new("head".into(), LayerKind::LmHead, cfg.vocab, d, &mut rng),
        };
        Transformer { cfg, w }
    }

    /// Visit every linear (including gates/head) immutably.
    pub fn visit_linears<'a>(&'a self, f: &mut dyn FnMut(&'a Linear)) {
        for l in &self.w.layers {
            f(&l.wq);
            if let Some(d) = &l.wdkv {
                f(d);
            }
            f(&l.wk);
            f(&l.wv);
            f(&l.wo);
            for e in &l.ffn {
                f(&e.w1);
                f(&e.w2);
                if let Some(w3) = &e.w3 {
                    f(w3);
                }
            }
            if let Some(g) = &l.gate {
                f(g);
            }
        }
        f(&self.w.head);
    }

    /// Visit every linear mutably.
    pub fn visit_linears_mut(&mut self, f: &mut dyn FnMut(&mut Linear)) {
        for l in &mut self.w.layers {
            f(&mut l.wq);
            if let Some(d) = &mut l.wdkv {
                f(d);
            }
            f(&mut l.wk);
            f(&mut l.wv);
            f(&mut l.wo);
            for e in &mut l.ffn {
                f(&mut e.w1);
                f(&mut e.w2);
                if let Some(w3) = &mut e.w3 {
                    f(w3);
                }
            }
            if let Some(g) = &mut l.gate {
                f(g);
            }
        }
        f(&mut self.w.head);
    }

    /// Fake-quantize the weights of every paper-quantized linear in place
    /// with `scheme` (direct cast / RTN). GPTQ paths use
    /// [`crate::quant::gptq`] with calibration data instead. Each linear's
    /// rows quantize independently across the process-default thread count.
    pub fn quantize_weights(&mut self, scheme: &QuantScheme) {
        self.visit_linears_mut(&mut |lin| {
            if lin.kind.quantized_by_paper() {
                lin.w.data = scheme.quant_dequant_rows(&lin.w.data, lin.w.cols);
            }
        });
    }

    /// **Real**-quantize every paper-quantized linear: quantize its weights
    /// once into `kind` groups through the unified
    /// [`QuantizedMatrix`] API, pack them into decode-once integer operand
    /// planes, and keep both alive on the linear. From then on
    /// [`Transformer::forward`] runs those linears through the fixed-point
    /// QGEMM (activations quantized per call, weights packed once and
    /// amortized across every call/token) instead of the
    /// dequantize-then-f32 simulated path. Every block format runs this
    /// path — all five are group-scaled and integer-exact.
    pub fn prepack_quantized_weights(&mut self, kind: QuantKind) {
        let mode = RoundMode::NearestEven;
        self.visit_linears_mut(&mut |lin| {
            if !lin.kind.quantized_by_paper() {
                return;
            }
            let units = QuantizedMatrix::quantize(kind, &lin.w, mode);
            let planes = units.pack();
            lin.qw = Some(QuantWeights { units: Arc::new(units), planes: Arc::new(planes) });
        });
    }

    /// The block format the prepacked linears run in (`None` when the
    /// model serves dense f32 weights). Uniform across linears by
    /// construction — [`Transformer::prepack_quantized_weights`] applies
    /// one kind everywhere.
    pub fn quantized_weight_kind(&self) -> Option<QuantKind> {
        let mut kind = None;
        self.visit_linears(&mut |lin| {
            if kind.is_none() {
                kind = lin.qw.as_ref().map(|qw| qw.kind());
            }
        });
        kind
    }

    /// Total canonical wire bytes of the prepacked weight operands (the
    /// 4-bit resident footprint serving metrics report); 0 when dense.
    pub fn quantized_weight_wire_bytes(&self) -> usize {
        let mut total = 0usize;
        self.visit_linears(&mut |lin| {
            if let Some(qw) = &lin.qw {
                total += qw.units.wire_bytes();
            }
        });
        total
    }

    /// Free the dense f32 weights of every real-quantized linear (those
    /// with packed operands attached) — [`Transformer::forward`] never
    /// reads `w` once `qw` is set, but clones, GPTQ and the backward pass
    /// do, so this is an explicit opt-in for serving deployments where
    /// the ~4 bytes/elem dense plane would otherwise dominate resident
    /// weight memory next to the ~1.7 bytes/elem quantized operands.
    pub fn release_dense_weights(&mut self) {
        self.visit_linears_mut(&mut |lin| {
            if lin.qw.is_some() {
                lin.w = Matrix::zeros(0, 0);
            }
        });
    }

    /// `y = x · Wᵀ` through one linear: the real-quantized fixed-point
    /// path when packed weights are attached (activations quantize here,
    /// per call; the kernel backend follows [`crate::dotprod::kernel`]),
    /// the dense f32 GEMM otherwise.
    fn linear_fwd(&self, lin: &Linear, x: &Matrix) -> Matrix {
        let Some(qw) = &lin.qw else {
            return matmul_bt(x, &lin.w);
        };
        let qx = QuantizedMatrix::quantize(qw.kind(), x, RoundMode::NearestEven);
        match crate::dotprod::kernel() {
            // Both plane backends (scalar packed and the SIMD-tiled
            // microkernel) re-dispatch on the same knob inside qgemm_bt.
            Kernel::Packed | Kernel::Simd => qx.pack().qgemm_bt(&qw.planes),
            Kernel::Flow => qx.qgemm_bt_flow(&qw.units),
        }
    }

    /// Widen the weight distribution **without changing the function**
    /// (see [`ModelConfig::outlier_scale`]): the V→O and W3→W2 paths are
    /// linear, so scaling `wv, w3` by `1/s` and `wo, w2` by `s` leaves
    /// every output bit-identical in full precision while spreading the
    /// model's tensors across `2·log2(s)` extra binades — the broad
    /// post-training distribution of the paper's Mistral-7B / LongCat
    /// cases. With `s = 2^16`, `wv`/`w3` fall below NVFP4's 2^-10 global
    /// minimum (group scales underflow E4M3 to zero ⇒ tensors wiped) and
    /// `wo`/`w2` rise past 2688 (scales saturate ⇒ clipping): the §IV.B
    /// "inference crash". HiF4's 69-binade range covers both ends.
    pub fn inject_outliers(&mut self) {
        if self.cfg.outlier_scale <= 1.0 {
            return;
        }
        let s = self.cfg.outlier_scale;
        for layer in &mut self.w.layers {
            layer.wv.w.scale_inplace(1.0 / s);
            layer.wo.w.scale_inplace(s);
            for e in &mut layer.ffn {
                if let Some(w3) = &mut e.w3 {
                    w3.w.scale_inplace(1.0 / s);
                    e.w2.w.scale_inplace(s);
                }
            }
        }
    }

    /// Forward pass over a batch of token sequences (all the same length),
    /// returning logits (B·T × vocab). `policy` applies fake activation
    /// quantization; `calib` records linear inputs for GPTQ; `cache`
    /// collects intermediates for [`Transformer::backward`].
    pub fn forward(
        &self,
        tokens: &[Vec<usize>],
        policy: Option<&QuantPolicy>,
        mut calib: Option<&mut Calibration>,
        mut cache: Option<&mut ForwardCache>,
    ) -> Matrix {
        let bt: usize = tokens.iter().map(|s| s.len()).sum();
        let d = self.cfg.d_model;
        let mut x = Matrix::zeros(bt, d);
        let mut row = 0usize;
        for seq in tokens {
            for &t in seq {
                debug_assert!(t < self.cfg.vocab, "token {t} out of vocab");
                x.row_mut(row).copy_from_slice(self.w.embed.row(t));
                row += 1;
            }
        }
        let seq_lens: Vec<usize> = tokens.iter().map(|s| s.len()).collect();
        if let Some(c) = cache.as_deref_mut() {
            c.tokens = tokens.to_vec();
            c.seq_lens = seq_lens.clone();
            c.embedded = x.clone();
        }

        for (li, layer) in self.w.layers.iter().enumerate() {
            // ---- Attention block ----
            let (normed1, rms1) = rmsnorm_fwd(&x, &layer.norm1);
            let attn_out = self.attention_fwd(
                li,
                layer,
                &normed1,
                &seq_lens,
                policy,
                calib.as_deref_mut(),
                cache.as_deref_mut(),
            );
            let x1 = add(&x, &attn_out);
            // ---- FFN block ----
            let (normed2, rms2) = rmsnorm_fwd(&x1, &layer.norm2);
            let ffn_out = self.ffn_fwd(
                li,
                layer,
                &normed2,
                policy,
                calib.as_deref_mut(),
                cache.as_deref_mut(),
            );
            let x2 = add(&x1, &ffn_out);
            if let Some(c) = cache.as_deref_mut() {
                let lc = &mut c.layers[li];
                lc.x_in = x.clone();
                lc.rms1 = rms1;
                lc.normed1 = normed1;
                lc.x_mid = x1;
                lc.rms2 = rms2;
                lc.normed2 = normed2;
                x = x2;
            } else {
                x = x2;
            }
        }

        let (normed_f, rms_f) = rmsnorm_fwd(&x, &self.w.norm_f);
        let logits = self.linear_fwd(&self.w.head, &normed_f);
        if let Some(c) = cache {
            c.x_final = x;
            c.rms_f = rms_f;
            c.normed_f = normed_f;
        }
        logits
    }

    /// Quantize activation rows if the policy says so.
    fn maybe_quant_act(&self, x: &Matrix, policy: Option<&QuantPolicy>, kind: LayerKind) -> Matrix {
        match policy.and_then(|p| p.act) {
            Some(scheme) if kind.quantized_by_paper() => {
                let mut out = Matrix::zeros(x.rows, x.cols);
                for r in 0..x.rows {
                    scheme.quant_dequant(x.row(r), out.row_mut(r));
                }
                out
            }
            _ => x.clone(),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn attention_fwd(
        &self,
        li: usize,
        layer: &LayerWeights,
        normed: &Matrix,
        seq_lens: &[usize],
        policy: Option<&QuantPolicy>,
        mut calib: Option<&mut Calibration>,
        cache: Option<&mut ForwardCache>,
    ) -> Matrix {
        let cfg = &self.cfg;
        let qin = self.maybe_quant_act(normed, policy, LayerKind::AttnLinear);
        if let Some(c) = calib.as_deref_mut() {
            c.record(&layer.wq.name, &qin);
        }
        let q = self.linear_fwd(&layer.wq, &qin);
        // K/V input: d_model directly, or the MLA latent.
        let (kv_in, latent) = match &layer.wdkv {
            Some(dkv) => {
                if let Some(c) = calib.as_deref_mut() {
                    c.record(&dkv.name, &qin);
                }
                let lat = self.linear_fwd(dkv, &qin);
                let lat_q = self.maybe_quant_act(&lat, policy, LayerKind::AttnLinear);
                (lat_q, Some(lat))
            }
            None => (qin.clone(), None),
        };
        if let Some(c) = calib.as_deref_mut() {
            c.record(&layer.wk.name, &kv_in);
            c.record(&layer.wv.name, &kv_in);
        }
        let mut k = self.linear_fwd(&layer.wk, &kv_in);
        let v = self.linear_fwd(&layer.wv, &kv_in);
        let mut qr = q;
        rope_fwd(&mut qr, seq_lens, cfg.n_heads, cfg.head_dim, cfg.rope_base);
        rope_fwd(&mut k, seq_lens, cfg.kv_heads(), cfg.head_dim, cfg.rope_base);
        // KV-cache reference mode: run K (post-RoPE, like the cache stores
        // it) and V row-wise through the quantized KV codec.
        let v = if let Some(KvCacheType::Quant(kind)) = policy.and_then(|p| p.kv) {
            super::kv::qdq_rows(kind, &mut k);
            let mut vq = v;
            super::kv::qdq_rows(kind, &mut vq);
            vq
        } else {
            v
        };

        let (ctx, probs) = causal_attention_fwd(
            &qr,
            &k,
            &v,
            seq_lens,
            cfg.n_heads,
            cfg.kv_heads(),
            cfg.head_dim,
        );
        let ctx_q = self.maybe_quant_act(&ctx, policy, LayerKind::AttnLinear);
        if let Some(c) = calib.as_deref_mut() {
            c.record(&layer.wo.name, &ctx_q);
        }
        let out = self.linear_fwd(&layer.wo, &ctx_q);
        if let Some(c) = cache {
            let lc = &mut c.layers[li];
            lc.attn = Some(AttnCache { qin, q: qr, k, v, kv_in, latent, ctx, probs });
        }
        out
    }

    fn ffn_fwd(
        &self,
        li: usize,
        layer: &LayerWeights,
        normed: &Matrix,
        policy: Option<&QuantPolicy>,
        mut calib: Option<&mut Calibration>,
        cache: Option<&mut ForwardCache>,
    ) -> Matrix {
        let qx = self.maybe_quant_act(normed, policy, LayerKind::FfnLinear);
        match &layer.gate {
            None => {
                let e = &layer.ffn[0];
                if let Some(c) = calib.as_deref_mut() {
                    c.record(&e.w1.name, &qx);
                }
                let (out, fc) = ffn_expert_fwd(e, &qx, &self.cfg, policy, calib, self);
                if let Some(c) = cache {
                    c.layers[li].ffn = Some(FfnCache {
                        qx,
                        experts: vec![Some(fc)],
                        routing: None,
                        gate_logits: None,
                    });
                }
                out
            }
            Some(gate) => {
                // MoE: route on the *unquantized* normed input (gate is
                // excluded from quantization per §IV.C).
                let logits = matmul_bt(normed, &gate.w);
                let (top_k, experts_n) = match self.cfg.ffn {
                    Ffn::Moe { experts, top_k } => (top_k, experts),
                    _ => unreachable!(),
                };
                let routing = topk_softmax(&logits, top_k);
                let mut out = Matrix::zeros(qx.rows, self.cfg.d_model);
                let mut expert_caches: Vec<Option<ExpertCache>> = vec![None; experts_n];
                let mut per_expert_out: Vec<Option<Matrix>> = vec![None; experts_n];
                for (ei, e) in layer.ffn.iter().enumerate() {
                    // Dense-but-masked evaluation: tiny models, simpler
                    // backward; rows with zero weight contribute nothing.
                    let used = routing.iter().any(|r| r.iter().any(|(i, _)| *i == ei));
                    if !used {
                        continue;
                    }
                    if let Some(c) = calib.as_deref_mut() {
                        c.record(&e.w1.name, &qx);
                    }
                    let (eo, fc) =
                        ffn_expert_fwd(e, &qx, &self.cfg, policy, calib.as_deref_mut(), self);
                    for (r, routes) in routing.iter().enumerate() {
                        for (i, w) in routes {
                            if *i == ei {
                                crate::tensor::gemm::axpy(*w, eo.row(r), out.row_mut(r));
                            }
                        }
                    }
                    per_expert_out[ei] = Some(eo);
                    expert_caches[ei] = Some(fc);
                }
                if let Some(c) = cache {
                    c.layers[li].ffn = Some(FfnCache {
                        qx,
                        experts: expert_caches,
                        routing: Some((routing, per_expert_out)),
                        gate_logits: Some(logits),
                    });
                }
                out
            }
        }
    }

    // -----------------------------------------------------------------
    // Incremental decode (KV-cached autoregressive serving path)
    // -----------------------------------------------------------------

    /// Forward over the **new suffix** of one or more sequences, reading
    /// and appending each sequence's [`KvCache`] instead of recomputing
    /// the prefix — O(T) per generated token instead of O(T²) per
    /// generation. Returns logits for the new rows only (B·T_new × vocab,
    /// sequences concatenated in order).
    ///
    /// A fresh cache with the whole prompt as the suffix is a *prefill*;
    /// a one-token suffix is a *decode step*; the two mix freely in one
    /// call, which is what continuous batching exploits. Per-sequence
    /// results are **bit-identical** regardless of which other sequences
    /// share the batch and of the thread count: linears are
    /// row-independent and attention is per-sequence.
    ///
    /// Attention over quantized caches runs the process-wide
    /// [`attn_path`] knob's schedule (default
    /// [`AttnPath::Fused`] — the tiled integer kernel of
    /// [`super::attention`]); f32 caches always replay. Cached-vs-
    /// recompute equality contracts, per path (`tests/decode_parity.rs`):
    ///
    /// * **f32 cache** — bit-identical to the full forward (the replay
    ///   score/softmax/context loops reproduce
    ///   [`causal_attention_fwd`]'s exact operation order).
    /// * **quantized cache, [`AttnPath::Replay`]** — bit-identical to a
    ///   full recompute under [`QuantPolicy::kv`]`= Some(Quant(kind))`.
    /// * **quantized cache, [`AttnPath::Fused`]** — logits are
    ///   tolerance-bounded against replay (8-bit query rounding, online
    ///   softmax; DESIGN.md §14), greedy tokens identical.
    ///
    /// Quantized serving composes: with
    /// [`Transformer::prepack_quantized_weights`] applied, every linear
    /// here runs the fixed-point QGEMM over the prepacked weight planes.
    pub fn forward_cached(&self, seqs: &mut [CachedSeq<'_>]) -> Matrix {
        self.forward_cached_with(seqs, attn_path())
    }

    /// [`Transformer::forward_cached`] with the attention schedule given
    /// explicitly instead of read from the process-wide knob — the
    /// comparison surface the parity suites are built on (two paths in
    /// one process, no knob mutation, no cross-test races).
    pub fn forward_cached_with(&self, seqs: &mut [CachedSeq<'_>], attn: AttnPath) -> Matrix {
        let (x, _) = self.forward_cached_hidden(seqs, attn);
        let (normed_f, _) = rmsnorm_fwd(&x, &self.w.norm_f);
        self.linear_fwd(&self.w.head, &normed_f)
    }

    /// [`Transformer::forward_cached`], but projecting the LM head only
    /// for each sequence's **last** new row — one logits row per sequence
    /// (B × vocab). Greedy decode never reads the other rows, and the
    /// head is the largest linear in the model, so this is the serving
    /// fast path: a prompt-P prefill skips (P−1)·vocab·d of head work.
    /// Rows are bit-identical to the corresponding rows of
    /// [`Transformer::forward_cached`] (rmsnorm and the head linear are
    /// row-independent). Every sequence must feed ≥ 1 token.
    pub fn forward_cached_last(&self, seqs: &mut [CachedSeq<'_>]) -> Matrix {
        self.forward_cached_last_with(seqs, attn_path())
    }

    /// [`Transformer::forward_cached_last`] with an explicit attention
    /// schedule (see [`Transformer::forward_cached_with`]).
    pub fn forward_cached_last_with(&self, seqs: &mut [CachedSeq<'_>], attn: AttnPath) -> Matrix {
        let (x, new_lens) = self.forward_cached_hidden(seqs, attn);
        let d = self.cfg.d_model;
        let mut last = Matrix::zeros(new_lens.len(), d);
        let mut base = 0usize;
        for (si, &n) in new_lens.iter().enumerate() {
            debug_assert!(n > 0, "forward_cached_last needs a non-empty suffix per sequence");
            base += n;
            last.row_mut(si).copy_from_slice(x.row(base - 1));
        }
        let (normed_f, _) = rmsnorm_fwd(&last, &self.w.norm_f);
        self.linear_fwd(&self.w.head, &normed_f)
    }

    /// Shared body of the cached forwards: embed the new suffixes, run
    /// every layer against the caches (appending K/V), advance the
    /// caches, and return the final hidden states plus per-sequence
    /// suffix lengths.
    fn forward_cached_hidden(
        &self,
        seqs: &mut [CachedSeq<'_>],
        attn: AttnPath,
    ) -> (Matrix, Vec<usize>) {
        // Per-sequence (suffix length, cached prefix length) spans.
        let spans: Vec<(usize, usize)> =
            seqs.iter().map(|s| (s.tokens.len(), s.cache.len())).collect();
        let new_lens: Vec<usize> = spans.iter().map(|&(n, _)| n).collect();
        let bt: usize = new_lens.iter().sum();
        let d = self.cfg.d_model;
        let mut x = Matrix::zeros(bt, d);
        let mut row = 0usize;
        for s in seqs.iter() {
            debug_assert_eq!(
                s.cache.layers.len(),
                self.cfg.n_layers,
                "KV cache was built for a different model depth"
            );
            for &t in s.tokens {
                debug_assert!(t < self.cfg.vocab, "token {t} out of vocab");
                x.row_mut(row).copy_from_slice(self.w.embed.row(t));
                row += 1;
            }
        }
        for (li, layer) in self.w.layers.iter().enumerate() {
            let (normed1, _) = rmsnorm_fwd(&x, &layer.norm1);
            let attn_out = self.attention_cached(li, layer, &normed1, &spans, seqs, attn);
            let x1 = add(&x, &attn_out);
            let (normed2, _) = rmsnorm_fwd(&x1, &layer.norm2);
            let ffn_out = self.ffn_fwd(li, layer, &normed2, None, None, None);
            x = add(&x1, &ffn_out);
        }
        for (s, &n) in seqs.iter_mut().zip(&new_lens) {
            s.cache.advance(n);
        }
        (x, new_lens)
    }

    /// Cached attention: project the new rows, RoPE them at their absolute
    /// positions, append K/V to each sequence's cache pages, then score
    /// every new row against its full cached prefix — either through the
    /// fused tiled kernel on the packed planes ([`AttnPath::Fused`],
    /// quantized pages only) or by the replay loop below, which decodes
    /// the page dense and re-runs the exact two-pass softmax. The
    /// fallback is per sequence: an f32 page in a fused-path batch simply
    /// replays, and `spans` carries each sequence's (suffix, prefix)
    /// lengths.
    fn attention_cached(
        &self,
        li: usize,
        layer: &LayerWeights,
        normed: &Matrix,
        spans: &[(usize, usize)],
        seqs: &mut [CachedSeq<'_>],
        attn: AttnPath,
    ) -> Matrix {
        let cfg = &self.cfg;
        let (heads, hd) = (cfg.n_heads, cfg.head_dim);
        let kv_heads = cfg.kv_heads();
        let group = heads / kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let new_lens: Vec<usize> = spans.iter().map(|&(n, _)| n).collect();
        let starts: Vec<usize> = spans.iter().map(|&(_, s)| s).collect();
        let q = self.linear_fwd(&layer.wq, normed);
        let kv_in = match &layer.wdkv {
            Some(dkv) => self.linear_fwd(dkv, normed),
            None => normed.clone(),
        };
        let mut k = self.linear_fwd(&layer.wk, &kv_in);
        let v = self.linear_fwd(&layer.wv, &kv_in);
        let mut qr = q;
        rope_fwd_from(&mut qr, &new_lens, &starts, heads, hd, cfg.rope_base);
        rope_fwd_from(&mut k, &new_lens, &starts, kv_heads, hd, cfg.rope_base);

        let mut ctx = Matrix::zeros(qr.rows, heads * hd);
        let mut scores: Vec<f32> = Vec::new();
        let mut base = 0usize;
        for (si, s) in seqs.iter_mut().enumerate() {
            let (t_new, start) = spans[si];
            let lkv = &mut s.cache.layers[li];
            for r in base..base + t_new {
                lkv.k.append_row(k.row(r));
                lkv.v.append_row(v.row(r));
            }
            let t_ctx = start + t_new;
            if attn == AttnPath::Fused {
                let call = FusedAttnCall {
                    lkv: &*lkv,
                    start,
                    t_new,
                    qr: &qr,
                    base,
                    heads,
                    kv_heads,
                    hd,
                    scale,
                    tile_rows: attn_tile_rows(),
                };
                if fused_attention_seq(&call, &mut ctx) {
                    base += t_new;
                    continue;
                }
                // No packed planes (f32 page): fall through to replay.
            }
            let kd = lkv.k.dense(t_ctx);
            let vd = lkv.v.dense(t_ctx);
            for h in 0..heads {
                let kvh = h / group;
                for i in 0..t_new {
                    let p = start + i;
                    let qi = &qr.row(base + i)[h * hd..(h + 1) * hd];
                    // Same score → softmax → context operation order as
                    // [`causal_attention_fwd`], over positions j ≤ p.
                    scores.clear();
                    scores.resize(p + 1, 0.0);
                    let mut maxs = f32::NEG_INFINITY;
                    for (j, sc) in scores.iter_mut().enumerate() {
                        let kj = &kd.row(j)[kvh * hd..(kvh + 1) * hd];
                        let val = crate::tensor::gemm::dot(qi, kj) * scale;
                        *sc = val;
                        maxs = maxs.max(val);
                    }
                    let mut denom = 0f32;
                    for sc in scores.iter_mut() {
                        let e = (*sc - maxs).exp();
                        *sc = e;
                        denom += e;
                    }
                    let inv = 1.0 / denom;
                    for sc in scores.iter_mut() {
                        *sc *= inv;
                    }
                    let crow = &mut ctx.data[(base + i) * heads * hd + h * hd..][..hd];
                    for (j, w) in scores.iter().enumerate() {
                        let vj = &vd.row(j)[kvh * hd..(kvh + 1) * hd];
                        for (cc, vv) in crow.iter_mut().zip(vj) {
                            *cc += *w * *vv;
                        }
                    }
                }
            }
            base += t_new;
        }
        self.linear_fwd(&layer.wo, &ctx)
    }

    /// Greedy-generate `n_new` tokens for `prompt` with a KV cache of the
    /// given kind: one prefill, then one single-token decode step per
    /// token. Ties break to the lowest index (the serving responder's
    /// argmax). Attention runs the process-wide [`attn_path`] schedule.
    pub fn generate_greedy(&self, prompt: &[usize], n_new: usize, kind: KvCacheType) -> Vec<usize> {
        self.generate_greedy_with(prompt, n_new, kind, attn_path())
    }

    /// [`Transformer::generate_greedy`] with an explicit attention
    /// schedule (see [`Transformer::forward_cached_with`]).
    pub fn generate_greedy_with(
        &self,
        prompt: &[usize],
        n_new: usize,
        kind: KvCacheType,
        attn: AttnPath,
    ) -> Vec<usize> {
        assert!(!prompt.is_empty(), "generate_greedy needs a non-empty prompt");
        let mut cache = KvCache::new(&self.cfg, kind);
        let mut out = Vec::with_capacity(n_new);
        let mut feed: Vec<usize> = prompt.to_vec();
        for _ in 0..n_new {
            let logits = {
                let mut seqs = [CachedSeq { tokens: &feed, cache: &mut cache }];
                self.forward_cached_last_with(&mut seqs, attn)
            };
            let (next, _) = greedy_from_row(logits.row(0));
            out.push(next);
            feed = vec![next];
        }
        out
    }

    /// The O(T²) reference for [`Transformer::generate_greedy`]: recompute
    /// the whole prefix every step via [`Transformer::forward`], with
    /// [`QuantPolicy::kv`] reproducing the cache's K/V codec so both cache
    /// kinds are exactly comparable.
    pub fn generate_greedy_full_recompute(
        &self,
        prompt: &[usize],
        n_new: usize,
        kind: KvCacheType,
    ) -> Vec<usize> {
        assert!(!prompt.is_empty(), "generate_greedy needs a non-empty prompt");
        let policy = QuantPolicy { act: None, kv: Some(kind) };
        let mut ctx = prompt.to_vec();
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            let logits = self.forward(&[ctx.clone()], Some(&policy), None, None);
            let (next, _) = greedy_from_row(logits.row(logits.rows - 1));
            out.push(next);
            ctx.push(next);
        }
        out
    }
}

/// One sequence's share of a [`Transformer::forward_cached`] call: the new
/// suffix tokens plus a mutable borrow of its KV cache.
pub struct CachedSeq<'a> {
    pub tokens: &'a [usize],
    pub cache: &'a mut KvCache,
}

/// Greedy head readout shared by generation and the serving responder:
/// argmax (first index wins ties) plus the log-softmax value at the
/// argmax.
pub fn greedy_from_row(row: &[f32]) -> (usize, f32) {
    let (mut best, mut best_v) = (0usize, f32::NEG_INFINITY);
    for (t, v) in row.iter().enumerate() {
        if *v > best_v {
            best = t;
            best_v = *v;
        }
    }
    let denom: f32 = row.iter().map(|v| (v - best_v).exp()).sum();
    (best, -denom.ln())
}

/// One expert / plain FFN forward. Returns output and cache.
fn ffn_expert_fwd(
    e: &FfnWeights,
    qx: &Matrix,
    cfg: &ModelConfig,
    policy: Option<&QuantPolicy>,
    mut calib: Option<&mut Calibration>,
    model: &Transformer,
) -> (Matrix, ExpertCache) {
    let h1 = model.linear_fwd(&e.w1, qx);
    match (&e.w3, cfg.ffn) {
        (None, _) => {
            // GELU MLP.
            let act = gelu_fwd(&h1);
            let act_q = model.maybe_quant_act(&act, policy, LayerKind::FfnLinear);
            if let Some(c) = calib.as_deref_mut() {
                c.record(&e.w2.name, &act_q);
            }
            let out = model.linear_fwd(&e.w2, &act_q);
            (out, ExpertCache { h1, h3: None, act: act_q })
        }
        (Some(w3), _) => {
            // SwiGLU.
            let h3 = model.linear_fwd(w3, qx);
            let mut act = silu_fwd(&h1);
            for (a, b) in act.data.iter_mut().zip(&h3.data) {
                *a *= *b;
            }
            let act_q = model.maybe_quant_act(&act, policy, LayerKind::FfnLinear);
            if let Some(c) = calib.as_deref_mut() {
                c.record(&e.w2.name, &act_q);
            }
            let out = model.linear_fwd(&e.w2, &act_q);
            (out, ExpertCache { h1, h3: Some(h3), act: act_q })
        }
    }
}

// ---------------------------------------------------------------------------
// Caches
// ---------------------------------------------------------------------------

/// Everything backward needs, layer by layer.
#[derive(Debug, Default, Clone)]
pub struct ForwardCache {
    pub tokens: Vec<Vec<usize>>,
    pub seq_lens: Vec<usize>,
    pub embedded: Matrix,
    pub layers: Vec<LayerCache>,
    pub x_final: Matrix,
    pub rms_f: Vec<f32>,
    pub normed_f: Matrix,
}

impl ForwardCache {
    pub fn new(n_layers: usize) -> ForwardCache {
        ForwardCache { layers: vec![LayerCache::default(); n_layers], ..Default::default() }
    }
}

#[derive(Debug, Default, Clone)]
pub struct LayerCache {
    pub x_in: Matrix,
    pub rms1: Vec<f32>,
    pub normed1: Matrix,
    pub attn: Option<AttnCache>,
    pub x_mid: Matrix,
    pub rms2: Vec<f32>,
    pub normed2: Matrix,
    pub ffn: Option<FfnCache>,
}

#[derive(Debug, Clone)]
pub struct AttnCache {
    pub qin: Matrix,
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
    pub kv_in: Matrix,
    pub latent: Option<Matrix>,
    pub ctx: Matrix,
    /// Per (seq, head): T×T lower-triangular attention probabilities.
    pub probs: Vec<Matrix>,
}

#[derive(Debug, Clone)]
pub struct FfnCache {
    pub qx: Matrix,
    /// Per-expert caches (index-aligned; None = expert unused this batch).
    pub experts: Vec<Option<ExpertCache>>,
    /// MoE: per-row top-k (expert, weight) + per-expert dense outputs.
    #[allow(clippy::type_complexity)]
    pub routing: Option<(Vec<Vec<(usize, f32)>>, Vec<Option<Matrix>>)>,
    pub gate_logits: Option<Matrix>,
}

#[derive(Debug, Clone)]
pub struct ExpertCache {
    pub h1: Matrix,
    pub h3: Option<Matrix>,
    pub act: Matrix,
}

// ---------------------------------------------------------------------------
// Primitive ops
// ---------------------------------------------------------------------------

pub(crate) fn add(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = a.clone();
    for (x, y) in c.data.iter_mut().zip(&b.data) {
        *x += *y;
    }
    c
}

/// RMSNorm forward: y = x / rms(x) · g. Returns per-row rms.
pub fn rmsnorm_fwd(x: &Matrix, g: &[f32]) -> (Matrix, Vec<f32>) {
    let d = x.cols;
    let mut y = Matrix::zeros(x.rows, d);
    let mut rms = vec![0f32; x.rows];
    for r in 0..x.rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let rm = (ms + 1e-6).sqrt();
        rms[r] = rm;
        let inv = 1.0 / rm;
        for c in 0..d {
            y.data[r * d + c] = row[c] * inv * g[c];
        }
    }
    (y, rms)
}

/// RMSNorm backward. Returns (dx, dg).
pub fn rmsnorm_bwd(dy: &Matrix, x: &Matrix, g: &[f32], rms: &[f32]) -> (Matrix, Vec<f32>) {
    let d = x.cols;
    let mut dx = Matrix::zeros(x.rows, d);
    let mut dg = vec![0f32; d];
    for r in 0..x.rows {
        let xr = x.row(r);
        let dyr = dy.row(r);
        let inv = 1.0 / rms[r];
        // dg += dy ⊙ x/rms
        for c in 0..d {
            dg[c] += dyr[c] * xr[c] * inv;
        }
        // dx = g⊙dy/rms − x · (Σ g⊙dy⊙x) / (d·rms³)
        let mut dot = 0f32;
        for c in 0..d {
            dot += g[c] * dyr[c] * xr[c];
        }
        let k = dot / (d as f32 * rms[r] * rms[r] * rms[r]);
        for c in 0..d {
            dx.data[r * d + c] = g[c] * dyr[c] * inv - xr[c] * k;
        }
    }
    (dx, dg)
}

/// SiLU x·σ(x).
pub fn silu_fwd(x: &Matrix) -> Matrix {
    let mut y = x.clone();
    for v in y.data.iter_mut() {
        *v = *v / (1.0 + (-*v).exp());
    }
    y
}

/// d/dx SiLU = σ(x)(1 + x(1−σ(x))).
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// tanh-approx GELU.
pub fn gelu_fwd(x: &Matrix) -> Matrix {
    let mut y = x.clone();
    for v in y.data.iter_mut() {
        let x = *v;
        let t = (0.7978845608 * (x + 0.044715 * x * x * x)).tanh();
        *v = 0.5 * x * (1.0 + t);
    }
    y
}

pub fn gelu_grad(x: f32) -> f32 {
    let c = 0.7978845608f32;
    let u = c * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = c * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Rotary position embedding applied in place to (B·T × heads·head_dim).
pub fn rope_fwd(x: &mut Matrix, seq_lens: &[usize], heads: usize, head_dim: usize, base: f32) {
    let zeros = vec![0usize; seq_lens.len()];
    rope_fwd_from(x, seq_lens, &zeros, heads, head_dim, base);
}

/// [`rope_fwd`] with per-sequence absolute position offsets: sequence `s`'s
/// first row rotates as position `starts[s]` — the incremental-decode form
/// (cached rows were already rotated at their own positions, new rows pick
/// up where the cache ends). `starts = [0, ..]` is exactly [`rope_fwd`].
pub fn rope_fwd_from(
    x: &mut Matrix,
    seq_lens: &[usize],
    starts: &[usize],
    heads: usize,
    head_dim: usize,
    base: f32,
) {
    debug_assert_eq!(seq_lens.len(), starts.len());
    let mut row = 0usize;
    for (si, &t_len) in seq_lens.iter().enumerate() {
        for off_pos in 0..t_len {
            let pos = starts[si] + off_pos;
            let r = x.row_mut(row);
            for h in 0..heads {
                let off = h * head_dim;
                for i in 0..head_dim / 2 {
                    let theta = (pos as f32) / base.powf(2.0 * i as f32 / head_dim as f32);
                    let (s, c) = theta.sin_cos();
                    let a = r[off + 2 * i];
                    let b = r[off + 2 * i + 1];
                    r[off + 2 * i] = a * c - b * s;
                    r[off + 2 * i + 1] = a * s + b * c;
                }
            }
            row += 1;
        }
    }
}

/// RoPE backward = rotation by −θ (orthogonal transpose).
pub fn rope_bwd(dx: &mut Matrix, seq_lens: &[usize], heads: usize, head_dim: usize, base: f32) {
    let mut row = 0usize;
    for &t_len in seq_lens {
        for pos in 0..t_len {
            let r = dx.row_mut(row);
            for h in 0..heads {
                let off = h * head_dim;
                for i in 0..head_dim / 2 {
                    let theta = (pos as f32) / base.powf(2.0 * i as f32 / head_dim as f32);
                    let (s, c) = theta.sin_cos();
                    let a = r[off + 2 * i];
                    let b = r[off + 2 * i + 1];
                    r[off + 2 * i] = a * c + b * s;
                    r[off + 2 * i + 1] = -a * s + b * c;
                }
            }
            row += 1;
        }
    }
}

/// Causal softmax attention over per-sequence blocks with GQA head mapping.
/// Returns context (B·T × heads·head_dim) and per-(seq,head) prob matrices.
pub fn causal_attention_fwd(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    seq_lens: &[usize],
    heads: usize,
    kv_heads: usize,
    head_dim: usize,
) -> (Matrix, Vec<Matrix>) {
    let scale = 1.0 / (head_dim as f32).sqrt();
    let group = heads / kv_heads;
    let mut ctx = Matrix::zeros(q.rows, heads * head_dim);
    let mut probs = Vec::with_capacity(seq_lens.len() * heads);
    let mut base = 0usize;
    for &t_len in seq_lens {
        for h in 0..heads {
            let kvh = h / group;
            let mut p = Matrix::zeros(t_len, t_len);
            for i in 0..t_len {
                // scores over j ≤ i, then softmax.
                let qi = &q.row(base + i)[h * head_dim..(h + 1) * head_dim];
                let mut maxs = f32::NEG_INFINITY;
                for j in 0..=i {
                    let kj = &k.row(base + j)[kvh * head_dim..(kvh + 1) * head_dim];
                    let s = crate::tensor::gemm::dot(qi, kj) * scale;
                    p.data[i * t_len + j] = s;
                    maxs = maxs.max(s);
                }
                let mut denom = 0f32;
                for j in 0..=i {
                    let e = (p.data[i * t_len + j] - maxs).exp();
                    p.data[i * t_len + j] = e;
                    denom += e;
                }
                let inv = 1.0 / denom;
                for j in 0..=i {
                    p.data[i * t_len + j] *= inv;
                }
                // ctx_i = Σ_j p_ij · v_j
                let crow =
                    &mut ctx.data[(base + i) * heads * head_dim + h * head_dim..][..head_dim];
                for j in 0..=i {
                    let w = p.data[i * t_len + j];
                    let vj = &v.row(base + j)[kvh * head_dim..(kvh + 1) * head_dim];
                    for (cc, vv) in crow.iter_mut().zip(vj) {
                        *cc += w * vv;
                    }
                }
            }
            probs.push(p);
        }
        base += t_len;
    }
    (ctx, probs)
}

/// Backward of causal attention. Returns (dq, dk, dv).
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_bwd(
    dctx: &Matrix,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    probs: &[Matrix],
    seq_lens: &[usize],
    heads: usize,
    kv_heads: usize,
    head_dim: usize,
) -> (Matrix, Matrix, Matrix) {
    let scale = 1.0 / (head_dim as f32).sqrt();
    let group = heads / kv_heads;
    let mut dq = Matrix::zeros(q.rows, q.cols);
    let mut dk = Matrix::zeros(k.rows, k.cols);
    let mut dv = Matrix::zeros(v.rows, v.cols);
    let mut base = 0usize;
    let mut pi = 0usize;
    for &t_len in seq_lens {
        for h in 0..heads {
            let kvh = h / group;
            let p = &probs[pi];
            pi += 1;
            for i in 0..t_len {
                let dctx_i =
                    &dctx.data[(base + i) * heads * head_dim + h * head_dim..][..head_dim];
                // dp_ij = dctx_i · v_j ; dv_j += p_ij dctx_i
                let mut dp = vec![0f32; i + 1];
                for j in 0..=i {
                    let vj = &v.row(base + j)[kvh * head_dim..(kvh + 1) * head_dim];
                    dp[j] = crate::tensor::gemm::dot(dctx_i, vj);
                    let w = p.data[i * t_len + j];
                    let dvj = &mut dv.data[(base + j) * kv_heads * head_dim + kvh * head_dim..]
                        [..head_dim];
                    for (dd, cc) in dvj.iter_mut().zip(dctx_i) {
                        *dd += w * cc;
                    }
                }
                // softmax backward: ds_ij = p_ij (dp_ij − Σ_l p_il dp_il)
                let dot: f32 =
                    (0..=i).map(|j| p.data[i * t_len + j] * dp[j]).sum();
                for j in 0..=i {
                    let ds = p.data[i * t_len + j] * (dp[j] - dot) * scale;
                    if ds == 0.0 {
                        continue;
                    }
                    let kj = &k.row(base + j)[kvh * head_dim..(kvh + 1) * head_dim];
                    let qi = &q.row(base + i)[h * head_dim..(h + 1) * head_dim];
                    let dqi =
                        &mut dq.data[(base + i) * heads * head_dim + h * head_dim..][..head_dim];
                    for (dd, kk) in dqi.iter_mut().zip(kj) {
                        *dd += ds * kk;
                    }
                    let dkj = &mut dk.data[(base + j) * kv_heads * head_dim + kvh * head_dim..]
                        [..head_dim];
                    for (dd, qq) in dkj.iter_mut().zip(qi) {
                        *dd += ds * qq;
                    }
                }
            }
        }
        base += t_len;
    }
    (dq, dk, dv)
}

/// Top-k softmax routing: per row, the k largest logits with their
/// renormalized softmax weights.
pub fn topk_softmax(logits: &Matrix, k: usize) -> Vec<Vec<(usize, f32)>> {
    let mut out = Vec::with_capacity(logits.rows);
    for r in 0..logits.rows {
        let row = logits.row(r);
        let mut idx: Vec<usize> = (0..row.len()).collect();
        idx.sort_by(|a, b| row[*b].partial_cmp(&row[*a]).unwrap());
        let top = &idx[..k.min(idx.len())];
        let maxv = row[top[0]];
        let exps: Vec<f32> = top.iter().map(|i| (row[*i] - maxv).exp()).collect();
        let denom: f32 = exps.iter().sum();
        out.push(top.iter().zip(&exps).map(|(i, e)| (*i, e / denom)).collect());
    }
    out
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{Attention, Ffn};

    pub(crate) fn tiny_cfg(attn: Attention, ffn: Ffn) -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab: 48,
            d_model: 16,
            n_layers: 2,
            n_heads: 4,
            head_dim: 4,
            attention: attn,
            ffn,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    fn toks() -> Vec<Vec<usize>> {
        vec![vec![1, 5, 9, 13], vec![2, 6, 10, 14, 3, 7]]
    }

    #[test]
    fn forward_shapes_all_variants() {
        for (attn, ffn) in [
            (Attention::Mha, Ffn::SwiGlu),
            (Attention::Gqa { kv_heads: 2 }, Ffn::SwiGlu),
            (Attention::Gqa { kv_heads: 1 }, Ffn::Gelu),
            (Attention::Mla { kv_rank: 8 }, Ffn::SwiGlu),
            (Attention::Mha, Ffn::Moe { experts: 4, top_k: 2 }),
            (Attention::Mla { kv_rank: 8 }, Ffn::Moe { experts: 4, top_k: 2 }),
        ] {
            let m = Transformer::init(tiny_cfg(attn, ffn), 7);
            let logits = m.forward(&toks(), None, None, None);
            assert_eq!(logits.rows, 10, "{attn:?}/{ffn:?}");
            assert_eq!(logits.cols, 48);
            assert!(logits.data.iter().all(|x| x.is_finite()), "{attn:?}/{ffn:?}");
        }
    }

    #[test]
    fn causality_holds() {
        // Changing a later token must not affect earlier logits.
        let m = Transformer::init(tiny_cfg(Attention::Mha, Ffn::SwiGlu), 8);
        let a = m.forward(&[vec![1, 2, 3, 4]], None, None, None);
        let b = m.forward(&[vec![1, 2, 3, 40]], None, None, None);
        for r in 0..3 {
            for c in 0..48 {
                assert_eq!(a.at(r, c), b.at(r, c), "position {r} leaked future info");
            }
        }
        assert!(
            (0..48).any(|c| a.at(3, c) != b.at(3, c)),
            "last position must differ"
        );
    }

    #[test]
    fn batch_equals_individual() {
        let m = Transformer::init(tiny_cfg(Attention::Gqa { kv_heads: 2 }, Ffn::SwiGlu), 9);
        let s1 = vec![1, 2, 3];
        let s2 = vec![4, 5, 6, 7];
        let joint = m.forward(&[s1.clone(), s2.clone()], None, None, None);
        let a = m.forward(&[s1], None, None, None);
        let b = m.forward(&[s2], None, None, None);
        for r in 0..3 {
            for c in 0..48 {
                assert!((joint.at(r, c) - a.at(r, c)).abs() < 1e-5);
            }
        }
        for r in 0..4 {
            for c in 0..48 {
                assert!((joint.at(3 + r, c) - b.at(r, c)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn quant_policy_changes_outputs_but_stays_finite() {
        use crate::formats::{QuantKind, QuantScheme};
        let m = Transformer::init(tiny_cfg(Attention::Mha, Ffn::SwiGlu), 10);
        let clean = m.forward(&toks(), None, None, None);
        let mut qm = m.clone();
        qm.quantize_weights(&QuantScheme::direct(QuantKind::HiF4));
        let policy = QuantPolicy { act: Some(QuantScheme::direct(QuantKind::HiF4)), kv: None };
        let quant = qm.forward(&toks(), Some(&policy), None, None);
        assert!(quant.data.iter().all(|x| x.is_finite()));
        let diff: f32 =
            clean.data.iter().zip(&quant.data).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 0.0, "quantization must perturb logits");
        // ... but not beyond recognition for a 4.5-bit format.
        let denom: f32 = clean.data.iter().map(|x| x.abs()).sum();
        assert!(diff / denom < 0.5, "relative perturbation too large: {}", diff / denom);
    }

    #[test]
    fn prepacked_linears_track_simulated_quantization() {
        use crate::formats::{QuantKind, QuantScheme};
        let m = Transformer::init(tiny_cfg(Attention::Mha, Ffn::SwiGlu), 21);
        // Simulated: fake-quant weights + activations, f32 GEMMs.
        let mut sim = m.clone();
        sim.quantize_weights(&QuantScheme::direct(QuantKind::HiF4));
        let policy = QuantPolicy { act: Some(QuantScheme::direct(QuantKind::HiF4)), kv: None };
        let sim_logits = sim.forward(&toks(), Some(&policy), None, None);
        // Real: same quantized operands through the fixed-point QGEMM.
        let mut real = m.clone();
        real.prepack_quantized_weights(QuantKind::HiF4);
        let real_logits = real.forward(&toks(), None, None, None);
        assert!(real_logits.data.iter().all(|x| x.is_finite()));
        // Identical quantized operands; only GEMM accumulation precision
        // differs (f32 dot vs exact-f64 PE flow), slightly amplified by
        // depth — the paths must stay close in aggregate.
        let diff: f32 =
            sim_logits.data.iter().zip(&real_logits.data).map(|(a, b)| (a - b).abs()).sum();
        let denom: f32 = sim_logits.data.iter().map(|x| x.abs()).sum();
        assert!(diff / denom < 0.05, "real vs simulated drifted: {}", diff / denom);
        // And the real path genuinely quantizes (differs from the clean
        // model).
        let clean = m.forward(&toks(), None, None, None);
        let qdiff: f32 =
            clean.data.iter().zip(&real_logits.data).map(|(a, b)| (a - b).abs()).sum();
        assert!(qdiff > 0.0, "prepacked path must perturb logits");
    }

    #[test]
    fn prepacked_forward_is_deterministic_and_kernel_invariant() {
        use crate::dotprod::{set_kernel, Kernel};
        use crate::formats::QuantKind;
        let mut m = Transformer::init(tiny_cfg(Attention::Gqa { kv_heads: 2 }, Ffn::SwiGlu), 22);
        m.prepack_quantized_weights(QuantKind::HiF4);
        let a = m.forward(&toks(), None, None, None);
        let b = m.forward(&toks(), None, None, None);
        assert_eq!(a.data, b.data, "packed planes reused across calls must be deterministic");
        // Flow and packed backends are bit-identical end to end. This is
        // the only test that *writes* the process-wide knob (so readback
        // cannot race); concurrent readers are unaffected because the
        // backends agree bit for bit.
        let prev = crate::dotprod::kernel();
        set_kernel(Kernel::Flow);
        assert_eq!(crate::dotprod::kernel(), Kernel::Flow, "knob round-trip");
        let flow = m.forward(&toks(), None, None, None);
        set_kernel(Kernel::Packed);
        assert_eq!(crate::dotprod::kernel(), Kernel::Packed, "knob round-trip");
        let packed = m.forward(&toks(), None, None, None);
        set_kernel(Kernel::Simd);
        assert_eq!(crate::dotprod::kernel(), Kernel::Simd, "knob round-trip");
        let simd = m.forward(&toks(), None, None, None);
        set_kernel(prev);
        assert_eq!(
            flow.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            packed.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            "kernel backends must agree bit for bit"
        );
        assert_eq!(
            packed.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            simd.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            "the SIMD backend must agree with the scalar backends bit for bit"
        );
    }

    #[test]
    fn prepacked_linears_run_fixed_point_all_formats() {
        use crate::formats::QuantKind;
        let clean = Transformer::init(tiny_cfg(Attention::Mha, Ffn::Gelu), 23)
            .forward(&toks(), None, None, None);
        for kind in QuantKind::ALL {
            let mut m = Transformer::init(tiny_cfg(Attention::Mha, Ffn::Gelu), 23);
            m.prepack_quantized_weights(kind);
            assert_eq!(m.quantized_weight_kind(), Some(kind));
            assert!(m.quantized_weight_wire_bytes() > 0);
            let logits = m.forward(&toks(), None, None, None);
            assert!(logits.data.iter().all(|x| x.is_finite()), "{kind}");
            let diff: f32 = clean.data.iter().zip(&logits.data).map(|(a, b)| (a - b).abs()).sum();
            assert!(diff > 0.0, "{kind} prepacked path must perturb logits");
        }
    }

    #[test]
    fn prepacked_forward_deterministic_new_formats() {
        // Plane reuse is deterministic for the formats the packed layer
        // gained in this redesign. Kernel-backend invariance needs no
        // per-format model test: `linear_fwd` has a single format-generic
        // dispatch (exercised for both backends by the HiF4 test above,
        // the only test that writes the process knob — see the note in
        // `dotprod`'s tests), and flow==packed bit-identity per format is
        // pinned at the GEMM level by tests/packed_parity.rs.
        use crate::formats::QuantKind;
        for kind in [QuantKind::Mxfp4, QuantKind::Mx4, QuantKind::Bfp] {
            let mut m =
                Transformer::init(tiny_cfg(Attention::Gqa { kv_heads: 2 }, Ffn::SwiGlu), 24);
            m.prepack_quantized_weights(kind);
            let a = m.forward(&toks(), None, None, None);
            let b = m.forward(&toks(), None, None, None);
            assert_eq!(a.data, b.data, "{kind} planes reused across calls must be deterministic");
        }
    }

    #[test]
    fn calibration_records_inputs() {
        let m = Transformer::init(tiny_cfg(Attention::Mha, Ffn::SwiGlu), 11);
        let mut cal = Calibration::new(64);
        m.forward(&toks(), None, Some(&mut cal), None);
        assert!(cal.inputs.contains_key("layer0.attn.wq"));
        assert!(cal.inputs.contains_key("layer1.ffn.w2"));
        let x = &cal.inputs["layer0.attn.wq"];
        assert_eq!(x.cols, 16);
        assert_eq!(x.rows, 10);
    }

    #[test]
    fn outlier_injection_widens_distribution_function_preserving() {
        let mut cfg = tiny_cfg(Attention::Mha, Ffn::SwiGlu);
        cfg.outlier_scale = 4096.0;
        let m0 = Transformer::init(cfg.clone(), 12);
        let mut m1 = m0.clone();
        m1.inject_outliers();
        let mut amax0 = 0f32;
        let mut amax1 = 0f32;
        m0.visit_linears(&mut |l| amax0 = amax0.max(l.w.amax()));
        m1.visit_linears(&mut |l| amax1 = amax1.max(l.w.amax()));
        assert!(amax1 > 100.0 * amax0, "outliers must widen the range");
        // The widening is function-preserving: logits match to f32 noise.
        let l0 = m0.forward(&toks(), None, None, None);
        let l1 = m1.forward(&toks(), None, None, None);
        for (a, b) in l0.data.iter().zip(&l1.data) {
            assert!((a - b).abs() <= 1e-3 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn topk_softmax_properties() {
        let logits = Matrix::from_vec(2, 4, vec![1.0, 3.0, 2.0, 0.0, -1.0, -2.0, 5.0, 4.9]);
        let r = topk_softmax(&logits, 2);
        assert_eq!(r[0][0].0, 1); // argmax first
        assert_eq!(r[0][1].0, 2);
        let s: f32 = r[0].iter().map(|(_, w)| w).sum();
        assert!((s - 1.0).abs() < 1e-6, "renormalized");
        assert_eq!(r[1][0].0, 2);
    }

    fn bits(m: &Matrix) -> Vec<u32> {
        m.data.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn cached_prefill_is_bitwise_identical_to_full_forward() {
        for (attn, ffn) in [
            (Attention::Mha, Ffn::SwiGlu),
            (Attention::Gqa { kv_heads: 2 }, Ffn::Gelu),
            (Attention::Mla { kv_rank: 8 }, Ffn::Moe { experts: 4, top_k: 2 }),
        ] {
            let m = Transformer::init(tiny_cfg(attn, ffn), 31);
            let prompt = vec![1usize, 5, 9, 13, 2];
            let full = m.forward(&[prompt.clone()], None, None, None);
            let mut cache = KvCache::new(&m.cfg, KvCacheType::F32);
            let cached = {
                let mut seqs = [CachedSeq { tokens: &prompt, cache: &mut cache }];
                m.forward_cached(&mut seqs)
            };
            assert_eq!(bits(&full), bits(&cached), "{attn:?}/{ffn:?}");
            assert_eq!(cache.len(), prompt.len());
        }
    }

    #[test]
    fn cached_decode_step_matches_full_forward_row() {
        let m = Transformer::init(tiny_cfg(Attention::Gqa { kv_heads: 2 }, Ffn::SwiGlu), 32);
        let prompt = vec![3usize, 7, 11];
        let mut cache = KvCache::new(&m.cfg, KvCacheType::F32);
        {
            let mut seqs = [CachedSeq { tokens: &prompt, cache: &mut cache }];
            m.forward_cached(&mut seqs);
        }
        // Three incremental steps must reproduce the matching rows of a
        // full forward over the extended context, bit for bit.
        let extra = [4usize, 8, 12];
        let mut ctx = prompt.clone();
        for &t in &extra {
            let feed = [t];
            let step = {
                let mut seqs = [CachedSeq { tokens: &feed[..], cache: &mut cache }];
                m.forward_cached(&mut seqs)
            };
            ctx.push(t);
            let full = m.forward(&[ctx.clone()], None, None, None);
            assert_eq!(
                step.row(0).iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                full.row(full.rows - 1).iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "context length {}",
                ctx.len()
            );
        }
        assert_eq!(cache.len(), ctx.len());
    }

    #[test]
    fn hif4_cached_prefill_matches_kv_quant_reference_bitwise() {
        // Replay path explicitly: the bitwise cached-vs-recompute
        // contract belongs to replay attention (the fused path is
        // tolerance-bounded instead — see tests/decode_parity.rs). The
        // explicit `_with` call keeps this independent of the
        // process-wide HIF4_ATTN knob.
        let m = Transformer::init(tiny_cfg(Attention::Mha, Ffn::SwiGlu), 33);
        let prompt = vec![2usize, 6, 10, 14, 3, 7];
        let policy = QuantPolicy { act: None, kv: Some(KvCacheType::HIF4) };
        let reference = m.forward(&[prompt.clone()], Some(&policy), None, None);
        let mut cache = KvCache::new(&m.cfg, KvCacheType::HIF4);
        let cached = {
            let mut seqs = [CachedSeq { tokens: &prompt, cache: &mut cache }];
            m.forward_cached_with(&mut seqs, AttnPath::Replay)
        };
        assert_eq!(bits(&reference), bits(&cached));
        // And the HiF4 cache genuinely perturbs vs the clean forward.
        let clean = m.forward(&[prompt], None, None, None);
        assert!(bits(&clean) != bits(&cached), "HiF4 KV codec must be active");
    }

    #[test]
    fn fused_prefill_matches_replay_tokens_and_bounded_logits() {
        // The fused tiled path on the same model/cache: logits within
        // the §14 parity tolerance of replay, argmax rows identical.
        let m = Transformer::init(tiny_cfg(Attention::Gqa { kv_heads: 2 }, Ffn::SwiGlu), 33);
        let prompt = vec![2usize, 6, 10, 14, 3, 7];
        let run = |attn: AttnPath| {
            let mut cache = KvCache::new(&m.cfg, KvCacheType::HIF4);
            let mut seqs = [CachedSeq { tokens: &prompt, cache: &mut cache }];
            m.forward_cached_with(&mut seqs, attn)
        };
        let fused = run(AttnPath::Fused);
        let replay = run(AttnPath::Replay);
        assert!(bits(&fused) != bits(&replay), "fused path must actually engage");
        for r in 0..fused.rows {
            for (a, b) in fused.row(r).iter().zip(replay.row(r)) {
                assert!((a - b).abs() <= 5e-2 * (1.0 + b.abs()), "row {r}: {a} vs {b}");
            }
        }
        // The row greedy decode reads must agree on its argmax; whole
        // generations are pinned token-identical in tests/decode_parity.
        let last = fused.rows - 1;
        assert_eq!(
            greedy_from_row(fused.row(last)).0,
            greedy_from_row(replay.row(last)).0,
            "final-row argmax"
        );
    }

    #[test]
    fn batched_cached_forward_is_independent_per_sequence() {
        // A sequence's cached logits must not depend on its batch mates —
        // the property continuous batching relies on.
        let m = Transformer::init(tiny_cfg(Attention::Gqa { kv_heads: 2 }, Ffn::SwiGlu), 34);
        let (pa, pb) = (vec![1usize, 5, 9], vec![2usize, 6, 10, 14]);
        let mut ca_solo = KvCache::new(&m.cfg, KvCacheType::F32);
        let solo = {
            let mut seqs = [CachedSeq { tokens: &pa, cache: &mut ca_solo }];
            m.forward_cached(&mut seqs)
        };
        let mut ca = KvCache::new(&m.cfg, KvCacheType::F32);
        let mut cb = KvCache::new(&m.cfg, KvCacheType::F32);
        let joint = {
            let mut seqs = [
                CachedSeq { tokens: &pa, cache: &mut ca },
                CachedSeq { tokens: &pb, cache: &mut cb },
            ];
            m.forward_cached(&mut seqs)
        };
        for r in 0..pa.len() {
            assert_eq!(
                solo.row(r).iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                joint.row(r).iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "row {r} changed when batched"
            );
        }
    }

    #[test]
    fn forward_cached_last_matches_full_logits_rows() {
        let m = Transformer::init(tiny_cfg(Attention::Gqa { kv_heads: 2 }, Ffn::SwiGlu), 36);
        let (pa, pb) = (vec![1usize, 5, 9], vec![2usize, 6, 10, 14]);
        let full = {
            let mut ca = KvCache::new(&m.cfg, KvCacheType::F32);
            let mut cb = KvCache::new(&m.cfg, KvCacheType::F32);
            let mut seqs = [
                CachedSeq { tokens: &pa, cache: &mut ca },
                CachedSeq { tokens: &pb, cache: &mut cb },
            ];
            m.forward_cached(&mut seqs)
        };
        let last = {
            let mut ca = KvCache::new(&m.cfg, KvCacheType::F32);
            let mut cb = KvCache::new(&m.cfg, KvCacheType::F32);
            let mut seqs = [
                CachedSeq { tokens: &pa, cache: &mut ca },
                CachedSeq { tokens: &pb, cache: &mut cb },
            ];
            m.forward_cached_last(&mut seqs)
        };
        assert_eq!((last.rows, last.cols), (2, m.cfg.vocab));
        for (li, fr) in [(0, pa.len() - 1), (1, pa.len() + pb.len() - 1)] {
            assert_eq!(
                last.row(li).iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                full.row(fr).iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "sequence {li} last-row logits diverged"
            );
        }
    }

    #[test]
    fn greedy_generation_matches_full_recompute_both_cache_kinds() {
        let m = Transformer::init(tiny_cfg(Attention::Mha, Ffn::SwiGlu), 35);
        let prompt = vec![4usize, 8, 15];
        for kind in [KvCacheType::F32, KvCacheType::HIF4] {
            let cached = m.generate_greedy(&prompt, 6, kind);
            let full = m.generate_greedy_full_recompute(&prompt, 6, kind);
            assert_eq!(cached, full, "{kind:?}");
            assert_eq!(cached.len(), 6);
            assert!(cached.iter().all(|&t| t < m.cfg.vocab));
        }
    }

    #[test]
    fn greedy_from_row_breaks_ties_low() {
        let (t, lp) = greedy_from_row(&[0.5, 2.0, 2.0, -1.0]);
        assert_eq!(t, 1, "first max wins");
        assert!(lp < 0.0 && lp.is_finite());
    }
}
