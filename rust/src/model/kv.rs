//! Per-sequence KV cache for incremental decode — the serving-side memory
//! layer that makes generation O(T) per token instead of O(T²).
//!
//! Two storage backends sit behind one [`KvCache`] (the [`KvCacheType`]
//! knob, `--kv-cache` / `HIF4_KV_CACHE` on the CLI):
//!
//! * **F32** — the reference: appended K/V rows are kept verbatim, so
//!   cached decode is *bit-identical* to the full-recompute forward.
//! * **HiF4** — each appended row is encoded through Algorithm 1 in
//!   64-element groups along the head dimension ([`crate::formats::hif4`])
//!   and held as the decode-once integer lane planes of
//!   [`crate::dotprod::packed`]: the nibble/micro-exponent extraction is
//!   paid exactly once per cached value at append time, and attention
//!   scores read straight from the planes (one multiply per lane). The
//!   resident plane costs 9 bits/value (`i8` lane + amortized `f64` unit
//!   scale) vs 32 for f32 — and the canonical 36-byte unit wire form
//!   ([`KvCache::wire_bytes`], 4.5 bits/value) is what a paged or
//!   offloaded cache would persist.
//!
//! Keys are cached **post-RoPE** (their rotation depends only on the
//! absolute position, which never changes once cached). The HiF4
//! quantize→decode round trip here is the *same math* the full-recompute
//! reference applies via [`hif4_qdq_rows`], so the greedy-decode parity
//! suite (`tests/decode_parity.rs`) can pin cached-vs-recompute equality
//! down to the bit.

use crate::dotprod::packed::{self, HiF4Lanes};
use crate::formats::hif4;
use crate::formats::rounding::RoundMode;
use crate::model::config::ModelConfig;
use crate::tensor::Matrix;

/// Which storage backend a [`KvCache`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvCacheType {
    /// Dense f32 rows — bit-identical to full recompute.
    #[default]
    F32,
    /// HiF4 units encoded on append, held as decode-once lane planes.
    HiF4,
}

impl KvCacheType {
    /// Parse a CLI/env spelling (`f32` / `hif4`, case-insensitive).
    pub fn parse(s: &str) -> Option<KvCacheType> {
        match s.to_ascii_lowercase().as_str() {
            "f32" => Some(KvCacheType::F32),
            "hif4" => Some(KvCacheType::HiF4),
            _ => None,
        }
    }

    /// Canonical lower-case label (bench/JSON key).
    pub fn label(self) -> &'static str {
        match self {
            KvCacheType::F32 => "f32",
            KvCacheType::HiF4 => "hif4",
        }
    }
}

/// Per-sequence, per-layer K/V storage for incremental decode. One cache
/// is one sequence's "page": the continuous-batching scheduler owns one
/// per active slot and drops it on eviction.
#[derive(Debug, Clone)]
pub struct KvCache {
    kind: KvCacheType,
    len: usize,
    pub(crate) layers: Vec<LayerKv>,
}

/// One layer's K and V stores.
#[derive(Debug, Clone)]
pub(crate) struct LayerKv {
    pub k: KvStore,
    pub v: KvStore,
}

/// Append-only row store for one tensor (K or V) of one layer.
#[derive(Debug, Clone)]
pub(crate) enum KvStore {
    F32 { kvd: usize, data: Vec<f32> },
    HiF4 { kvd: usize, units_per_row: usize, lanes: Vec<HiF4Lanes>, scales: Vec<f64> },
}

/// A dense f32 view of the first `rows` cached rows: f32 stores borrow in
/// place, HiF4 stores decode their lane planes once per view.
pub(crate) struct KvDense<'a> {
    kvd: usize,
    data: DenseData<'a>,
}

enum DenseData<'a> {
    Borrowed(&'a [f32]),
    Owned(Vec<f32>),
}

impl KvDense<'_> {
    /// Row `r` as a kvd-wide slice.
    #[inline]
    pub(crate) fn row(&self, r: usize) -> &[f32] {
        let d = match &self.data {
            DenseData::Borrowed(s) => s,
            DenseData::Owned(v) => v.as_slice(),
        };
        &d[r * self.kvd..(r + 1) * self.kvd]
    }
}

impl KvStore {
    fn new(kind: KvCacheType, kvd: usize) -> KvStore {
        match kind {
            KvCacheType::F32 => KvStore::F32 { kvd, data: Vec::new() },
            KvCacheType::HiF4 => KvStore::HiF4 {
                kvd,
                units_per_row: kvd.div_ceil(hif4::GROUP),
                lanes: Vec::new(),
                scales: Vec::new(),
            },
        }
    }

    /// Append one position's row. HiF4 stores encode it through
    /// Algorithm 1 (64-element groups, zero-padded tail group — the same
    /// uniform tail handling as [`crate::dotprod::qgemm::HiF4Matrix`])
    /// and keep only the decode-once plane.
    pub(crate) fn append_row(&mut self, row: &[f32]) {
        match self {
            KvStore::F32 { kvd, data } => {
                assert_eq!(row.len(), *kvd, "KV row width must match kv_heads×head_dim");
                data.extend_from_slice(row);
            }
            KvStore::HiF4 { kvd, units_per_row, lanes, scales } => {
                assert_eq!(row.len(), *kvd, "KV row width must match kv_heads×head_dim");
                let mut buf = [0f32; hif4::GROUP];
                for u in 0..*units_per_row {
                    let start = u * hif4::GROUP;
                    let end = (start + hif4::GROUP).min(*kvd);
                    buf[..end - start].copy_from_slice(&row[start..end]);
                    buf[end - start..].fill(0.0);
                    let unit = hif4::quantize(&buf, RoundMode::NearestEven);
                    let (l, s) = packed::hif4_unit_plane(&unit);
                    lanes.push(l);
                    scales.push(s);
                }
            }
        }
    }

    /// Dense view of rows `0..rows` (see [`KvDense`]).
    pub(crate) fn dense(&self, rows: usize) -> KvDense<'_> {
        match self {
            KvStore::F32 { kvd, data } => {
                KvDense { kvd: *kvd, data: DenseData::Borrowed(&data[..rows * *kvd]) }
            }
            KvStore::HiF4 { kvd, units_per_row, lanes, scales } => {
                let mut out = vec![0f32; rows * *kvd];
                for r in 0..rows {
                    let row = &mut out[r * *kvd..(r + 1) * *kvd];
                    for u in 0..*units_per_row {
                        let start = u * hif4::GROUP;
                        let end = (start + hif4::GROUP).min(*kvd);
                        let i = r * *units_per_row + u;
                        lanes[i].decode_into(scales[i], &mut row[start..end]);
                    }
                }
                KvDense { kvd: *kvd, data: DenseData::Owned(out) }
            }
        }
    }

    fn resident_bytes(&self) -> usize {
        match self {
            KvStore::F32 { data, .. } => std::mem::size_of_val(data.as_slice()),
            KvStore::HiF4 { lanes, scales, .. } => {
                std::mem::size_of_val(lanes.as_slice()) + std::mem::size_of_val(scales.as_slice())
            }
        }
    }

    fn wire_bytes(&self) -> usize {
        match self {
            KvStore::F32 { data, .. } => std::mem::size_of_val(data.as_slice()),
            KvStore::HiF4 { lanes, .. } => lanes.len() * hif4::HiF4Unit::WIRE_BYTES,
        }
    }
}

impl KvCache {
    /// Empty cache for one sequence under `cfg`'s geometry.
    pub fn new(cfg: &ModelConfig, kind: KvCacheType) -> KvCache {
        let kvd = cfg.kv_heads() * cfg.head_dim;
        let layers = (0..cfg.n_layers)
            .map(|_| LayerKv { k: KvStore::new(kind, kvd), v: KvStore::new(kind, kvd) })
            .collect();
        KvCache { kind, len: 0, layers }
    }

    pub fn kind(&self) -> KvCacheType {
        self.kind
    }

    /// Number of positions cached so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes the cache keeps resident (decode-once planes for HiF4).
    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.resident_bytes() + l.v.resident_bytes()).sum()
    }

    /// Bytes of the serialized form (the 36-byte HiF4 unit wire layout —
    /// 4.5 bits/value — for HiF4 caches; same as resident for f32).
    pub fn wire_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.wire_bytes() + l.v.wire_bytes()).sum()
    }

    pub(crate) fn advance(&mut self, n: usize) {
        self.len += n;
    }
}

/// Quantize→dequantize every row of `m` through the HiF4 KV codec. Not a
/// reimplementation: the rows go through the *actual* cache store
/// ([`KvStore::append_row`] encode, [`KvStore::dense`] decode), so a
/// full-recompute forward with
/// [`super::transformer::QuantPolicy::kv`] set is a *bit-exact*
/// reference for HiF4-cached incremental decode by construction — the
/// two paths cannot drift apart.
pub fn hif4_qdq_rows(m: &mut Matrix) {
    let mut store = KvStore::new(KvCacheType::HiF4, m.cols);
    for r in 0..m.rows {
        store.append_row(m.row(r));
    }
    let dense = store.dense(m.rows);
    for r in 0..m.rows {
        m.row_mut(r).copy_from_slice(dense.row(r));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "kv-test".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 4,
            head_dim: 8,
            attention: crate::model::config::Attention::Gqa { kv_heads: 2 },
            ffn: crate::model::config::Ffn::SwiGlu,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    #[test]
    fn parse_and_label_roundtrip() {
        for kind in [KvCacheType::F32, KvCacheType::HiF4] {
            assert_eq!(KvCacheType::parse(kind.label()), Some(kind));
        }
        assert_eq!(KvCacheType::parse("HIF4"), Some(KvCacheType::HiF4));
        assert_eq!(KvCacheType::parse("bf16"), None);
    }

    #[test]
    fn f32_store_roundtrips_rows_exactly() {
        let c = cfg();
        let mut cache = KvCache::new(&c, KvCacheType::F32);
        let mut rng = Rng::seed(5);
        let rows = Matrix::randn(3, 16, 1.0, &mut rng);
        for r in 0..rows.rows {
            cache.layers[0].k.append_row(rows.row(r));
        }
        let dense = cache.layers[0].k.dense(3);
        for r in 0..rows.rows {
            assert_eq!(dense.row(r), rows.row(r));
        }
    }

    #[test]
    fn hif4_store_matches_qdq_reference_bitwise() {
        let c = cfg();
        let mut cache = KvCache::new(&c, KvCacheType::HiF4);
        let mut rng = Rng::seed(6);
        // 16-wide rows: one padded tail unit per row.
        let rows = Matrix::randn(4, 16, 0.7, &mut rng);
        for r in 0..rows.rows {
            cache.layers[1].v.append_row(rows.row(r));
        }
        let mut reference = rows.clone();
        hif4_qdq_rows(&mut reference);
        let dense = cache.layers[1].v.dense(4);
        for r in 0..rows.rows {
            let got: Vec<u32> = dense.row(r).iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> = reference.row(r).iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "row {r}");
        }
    }

    #[test]
    fn hif4_cache_is_smaller_resident_and_on_the_wire() {
        let c = cfg();
        let mut f32c = KvCache::new(&c, KvCacheType::F32);
        let mut hc = KvCache::new(&c, KvCacheType::HiF4);
        let mut rng = Rng::seed(7);
        let rows = Matrix::randn(8, 16, 1.0, &mut rng);
        for cache in [&mut f32c, &mut hc] {
            for layer in 0..2 {
                for r in 0..rows.rows {
                    cache.layers[layer].k.append_row(rows.row(r));
                    cache.layers[layer].v.append_row(rows.row(r));
                }
            }
            cache.advance(rows.rows);
        }
        assert_eq!(f32c.len(), 8);
        assert!(hc.resident_bytes() < f32c.resident_bytes());
        assert!(hc.wire_bytes() < hc.resident_bytes());
        // 16-wide rows pad to one 64-lane unit: 36 wire bytes vs 64 f32.
        assert_eq!(hc.wire_bytes(), 2 * 2 * 8 * hif4::HiF4Unit::WIRE_BYTES);
    }
}
