//! Per-sequence KV cache for incremental decode — the serving-side memory
//! layer that makes generation O(T) per token instead of O(T²).
//!
//! Two storage backends sit behind one [`KvCache`] (the [`KvCacheType`]
//! knob, `--kv-cache` / `HIF4_KV_CACHE` on the CLI):
//!
//! * **F32** — the reference: appended K/V rows are kept verbatim, so
//!   cached decode is *bit-identical* to the full-recompute forward.
//! * **Quant(kind)** — each appended row is encoded through the format
//!   codec of `kind` (any of the five block formats, grouped along the
//!   head dimension) and held as the decode-once integer lane planes of
//!   [`crate::dotprod::quant_tensor`]: the nibble/micro-exponent
//!   extraction is paid exactly once per cached value at append time, and
//!   attention reads straight from the planes (one multiply per lane).
//!   The resident plane costs 8 bits/value of lanes plus one amortized
//!   `f64` group scale vs 32 for f32 — and the canonical packed wire form
//!   ([`KvCache::wire_bytes`], `bits_per_value()` of the kind) is what a
//!   paged or offloaded cache would persist.
//!
//! Keys are cached **post-RoPE** (their rotation depends only on the
//! absolute position, which never changes once cached). The
//! quantize→decode round trip here is the *same code* the full-recompute
//! reference applies via [`qdq_rows`], so the greedy-decode parity suite
//! (`tests/decode_parity.rs`) can pin cached-vs-recompute equality down
//! to the bit for every format.

use crate::dotprod::quant_tensor::{decode_plane, encode_row_planes};
use crate::formats::QuantKind;
use crate::model::config::ModelConfig;
use crate::tensor::Matrix;

/// Which storage backend a [`KvCache`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvCacheType {
    /// Dense f32 rows — bit-identical to full recompute.
    #[default]
    F32,
    /// Block-quantized rows encoded on append, held as decode-once lane
    /// planes (any [`QuantKind`]).
    Quant(QuantKind),
}

impl KvCacheType {
    /// The HiF4-quantized cache (the paper's configuration), spelled out
    /// since it is the default quantized choice everywhere.
    pub const HIF4: KvCacheType = KvCacheType::Quant(QuantKind::HiF4);

    /// Parse a CLI/env spelling through the single [`QuantKind`] parser:
    /// `f32`, or any format spelling (`hif4`, `nvfp4`, `mxfp4`, `mx4`,
    /// `bfp`), case-insensitive.
    pub fn parse(s: &str) -> Result<KvCacheType, String> {
        if s.eq_ignore_ascii_case("f32") {
            return Ok(KvCacheType::F32);
        }
        s.parse::<QuantKind>()
            .map(KvCacheType::Quant)
            .map_err(|e| format!("{e} (or f32 for the unquantized cache)"))
    }

    /// Canonical lower-case label (bench/JSON key); round-trips through
    /// [`KvCacheType::parse`].
    pub fn label(self) -> &'static str {
        match self {
            KvCacheType::F32 => "f32",
            KvCacheType::Quant(kind) => kind.spelling(),
        }
    }

    /// Resident bytes one appended row of width `kvd` costs in a store of
    /// this kind — the admission gate's KV-budget unit. Mirrors the
    /// actual store layout (f32 values; decode-once lane planes padded to
    /// whole groups plus one f64 scale per group for quantized kinds), so
    /// gate reservations and [`KvCache::resident_bytes`] agree exactly;
    /// the `resident_row_bytes_matches_store` test pins the equality for
    /// every kind.
    pub fn resident_row_bytes(self, kvd: usize) -> usize {
        match self {
            KvCacheType::F32 => kvd * std::mem::size_of::<f32>(),
            KvCacheType::Quant(kind) => {
                let group = kind.group();
                kvd.div_ceil(group)
                    * (group * std::mem::size_of::<i8>() + std::mem::size_of::<f64>())
            }
        }
    }
}

/// Per-sequence, per-layer K/V storage for incremental decode. One cache
/// is one sequence's "page": the continuous-batching scheduler owns one
/// per active slot and drops it on eviction.
#[derive(Debug, Clone)]
pub struct KvCache {
    kind: KvCacheType,
    len: usize,
    pub(crate) layers: Vec<LayerKv>,
}

/// One layer's K and V stores.
#[derive(Debug, Clone)]
pub(crate) struct LayerKv {
    pub k: KvStore,
    pub v: KvStore,
}

/// Append-only row store for one tensor (K or V) of one layer.
#[derive(Debug, Clone)]
pub(crate) enum KvStore {
    F32 {
        kvd: usize,
        data: Vec<f32>,
    },
    Quant {
        quant: QuantKind,
        kvd: usize,
        groups_per_row: usize,
        lanes: Vec<i8>,
        scales: Vec<f64>,
    },
}

/// A dense f32 view of the first `rows` cached rows: f32 stores borrow in
/// place, quantized stores decode their lane planes once per view.
pub(crate) struct KvDense<'a> {
    kvd: usize,
    data: DenseData<'a>,
}

enum DenseData<'a> {
    Borrowed(&'a [f32]),
    Owned(Vec<f32>),
}

impl KvDense<'_> {
    /// Row `r` as a kvd-wide slice.
    #[inline]
    pub(crate) fn row(&self, r: usize) -> &[f32] {
        let d = match &self.data {
            DenseData::Borrowed(s) => s,
            DenseData::Owned(v) => v.as_slice(),
        };
        &d[r * self.kvd..(r + 1) * self.kvd]
    }
}

impl KvStore {
    fn new(kind: KvCacheType, kvd: usize) -> KvStore {
        match kind {
            KvCacheType::F32 => KvStore::F32 { kvd, data: Vec::new() },
            KvCacheType::Quant(quant) => KvStore::Quant {
                quant,
                kvd,
                groups_per_row: kvd.div_ceil(quant.group()),
                lanes: Vec::new(),
                scales: Vec::new(),
            },
        }
    }

    /// Append one position's row. Quantized stores encode it through the
    /// format codec (zero-padded tail group — the same uniform tail
    /// handling as the quantized matrices) and keep only the decode-once
    /// plane.
    pub(crate) fn append_row(&mut self, row: &[f32]) {
        match self {
            KvStore::F32 { kvd, data } => {
                assert_eq!(row.len(), *kvd, "KV row width must match kv_heads×head_dim");
                data.extend_from_slice(row);
            }
            KvStore::Quant { quant, kvd, lanes, scales, .. } => {
                assert_eq!(row.len(), *kvd, "KV row width must match kv_heads×head_dim");
                encode_row_planes(*quant, row, lanes, scales);
            }
        }
    }

    /// Dense view of rows `0..rows` (see [`KvDense`]).
    pub(crate) fn dense(&self, rows: usize) -> KvDense<'_> {
        match self {
            KvStore::F32 { kvd, data } => {
                KvDense { kvd: *kvd, data: DenseData::Borrowed(&data[..rows * *kvd]) }
            }
            KvStore::Quant { quant, kvd, groups_per_row, lanes, scales } => {
                let group = quant.group();
                let mut out = vec![0f32; rows * *kvd];
                for r in 0..rows {
                    let row = &mut out[r * *kvd..(r + 1) * *kvd];
                    for u in 0..*groups_per_row {
                        let start = u * group;
                        let end = (start + group).min(*kvd);
                        let i = r * *groups_per_row + u;
                        decode_plane(
                            *quant,
                            &lanes[i * group..(i + 1) * group],
                            scales[i],
                            &mut row[start..end],
                        );
                    }
                }
                KvDense { kvd: *kvd, data: DenseData::Owned(out) }
            }
        }
    }

    /// Row width this store was sized for (kv_heads × head_dim).
    pub(crate) fn kvd(&self) -> usize {
        match self {
            KvStore::F32 { kvd, .. } | KvStore::Quant { kvd, .. } => *kvd,
        }
    }

    /// Positions stored so far (rows appended since creation/[`clear`]).
    ///
    /// [`clear`]: KvStore::clear
    pub(crate) fn rows(&self) -> usize {
        match self {
            KvStore::F32 { kvd, data } => data.len() / (*kvd).max(1),
            KvStore::Quant { groups_per_row, scales, .. } => {
                scales.len() / (*groups_per_row).max(1)
            }
        }
    }

    /// Drop every stored row but keep the backing allocations — the
    /// slot-reuse path: a recycled cache page serves its next sequence
    /// without reallocating, while the byte accounting (stored length,
    /// never `Vec` capacity) immediately reports the emptied store as 0.
    fn clear(&mut self) {
        match self {
            KvStore::F32 { data, .. } => data.clear(),
            KvStore::Quant { lanes, scales, .. } => {
                lanes.clear();
                scales.clear();
            }
        }
    }

    /// Bytes of the rows actually stored (decode-once planes for
    /// quantized stores). Derived from the stored *length* — a recycled
    /// page's backing capacity, which can be much larger after
    /// reset/reuse churn, is reported by [`KvStore::capacity_bytes`]
    /// instead and never leaks into this number.
    fn resident_bytes(&self) -> usize {
        match self {
            KvStore::F32 { data, .. } => std::mem::size_of_val(data.as_slice()),
            KvStore::Quant { lanes, scales, .. } => {
                std::mem::size_of_val(lanes.as_slice()) + std::mem::size_of_val(scales.as_slice())
            }
        }
    }

    /// Bytes the backing allocations currently hold, stored or parked
    /// (`≥ resident_bytes` by construction).
    fn capacity_bytes(&self) -> usize {
        match self {
            KvStore::F32 { data, .. } => data.capacity() * std::mem::size_of::<f32>(),
            KvStore::Quant { lanes, scales, .. } => {
                lanes.capacity() * std::mem::size_of::<i8>()
                    + scales.capacity() * std::mem::size_of::<f64>()
            }
        }
    }

    /// Serialized bytes of the stored rows (canonical packed group wire
    /// layout for quantized stores; dense f32 for F32). Like
    /// [`KvStore::resident_bytes`], derived from the stored length only.
    fn wire_bytes(&self) -> usize {
        match self {
            KvStore::F32 { data, .. } => std::mem::size_of_val(data.as_slice()),
            KvStore::Quant { quant, scales, .. } => scales.len() * quant.wire_bytes_group(),
        }
    }
}

impl KvCache {
    /// Empty cache for one sequence under `cfg`'s geometry.
    pub fn new(cfg: &ModelConfig, kind: KvCacheType) -> KvCache {
        let kvd = cfg.kv_heads() * cfg.head_dim;
        let layers = (0..cfg.n_layers)
            .map(|_| LayerKv { k: KvStore::new(kind, kvd), v: KvStore::new(kind, kvd) })
            .collect();
        KvCache { kind, len: 0, layers }
    }

    pub fn kind(&self) -> KvCacheType {
        self.kind
    }

    /// Number of positions cached so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes the cache keeps resident (decode-once planes for quantized
    /// kinds). Reported from the **stored length** — rows actually held —
    /// never from the backing allocation capacity, so the number stays
    /// exact through reset/reuse churn (`wire_bytes ≤ resident_bytes ≤
    /// capacity_bytes` always; pinned by the slot-reuse unit test).
    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.resident_bytes() + l.v.resident_bytes()).sum()
    }

    /// Bytes of the serialized form (the format's canonical packed group
    /// wire layout for quantized caches; same as resident for f32).
    /// Stored-length-derived like [`KvCache::resident_bytes`].
    pub fn wire_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.wire_bytes() + l.v.wire_bytes()).sum()
    }

    /// Bytes currently parked in the backing allocations — after
    /// [`KvCache::reset`] this exceeds [`KvCache::resident_bytes`] (the
    /// whole point of recycling: the allocation survives, the contents
    /// don't count).
    pub fn capacity_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.capacity_bytes() + l.v.capacity_bytes()).sum()
    }

    /// Reset for slot reuse: forget every stored row in every layer but
    /// keep the backing allocations, so a recycled page appends its next
    /// sequence without re-growing. The byte accounting reports the
    /// stored content only — an emptied page is 0 bytes resident/wire
    /// even while its capacity is still parked.
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.k.clear();
            l.v.clear();
        }
        self.len = 0;
    }

    /// Does this page carry `cfg`'s geometry under `kind` storage? The
    /// slot-reuse guard: recycled pages only re-attach to an engine whose
    /// model/cache configuration they were built for.
    pub fn fits(&self, cfg: &ModelConfig, kind: KvCacheType) -> bool {
        let kvd = cfg.kv_heads() * cfg.head_dim;
        self.kind == kind
            && self.layers.len() == cfg.n_layers
            && self.layers.iter().all(|l| l.k.kvd() == kvd && l.v.kvd() == kvd)
    }

    pub(crate) fn advance(&mut self, n: usize) {
        self.len += n;
        // Appends happen store-by-store before the position count moves;
        // once it does, every store must actually hold the rows it claims.
        debug_assert!(
            self.layers.iter().all(|l| l.k.rows() == self.len && l.v.rows() == self.len),
            "advance({n}) out of step with the appended rows"
        );
    }
}

/// Quantize→dequantize every row of `m` through the `kind` KV codec. Not
/// a reimplementation: the rows go through the *actual* cache store
/// ([`KvStore::append_row`] encode, [`KvStore::dense`] decode), so a
/// full-recompute forward with
/// [`super::transformer::QuantPolicy::kv`] set is a *bit-exact*
/// reference for quantized-cache incremental decode by construction — the
/// two paths cannot drift apart, for any format.
pub fn qdq_rows(kind: QuantKind, m: &mut Matrix) {
    let mut store = KvStore::new(KvCacheType::Quant(kind), m.cols);
    for r in 0..m.rows {
        store.append_row(m.row(r));
    }
    let dense = store.dense(m.rows);
    for r in 0..m.rows {
        m.row_mut(r).copy_from_slice(dense.row(r));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::hif4;
    use crate::tensor::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "kv-test".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 4,
            head_dim: 8,
            attention: crate::model::config::Attention::Gqa { kv_heads: 2 },
            ffn: crate::model::config::Ffn::SwiGlu,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    #[test]
    fn parse_and_label_roundtrip() {
        let mut kinds = vec![KvCacheType::F32];
        kinds.extend(QuantKind::ALL.map(KvCacheType::Quant));
        for kind in kinds {
            assert_eq!(KvCacheType::parse(kind.label()), Ok(kind));
        }
        assert_eq!(KvCacheType::parse("HIF4"), Ok(KvCacheType::HIF4));
        let err = KvCacheType::parse("bf16").unwrap_err();
        assert!(err.contains("f32") && err.contains("mxfp4"), "{err}");
    }

    #[test]
    fn f32_store_roundtrips_rows_exactly() {
        let c = cfg();
        let mut cache = KvCache::new(&c, KvCacheType::F32);
        let mut rng = Rng::seed(5);
        let rows = Matrix::randn(3, 16, 1.0, &mut rng);
        for r in 0..rows.rows {
            cache.layers[0].k.append_row(rows.row(r));
        }
        let dense = cache.layers[0].k.dense(3);
        for r in 0..rows.rows {
            assert_eq!(dense.row(r), rows.row(r));
        }
    }

    #[test]
    fn quant_store_matches_qdq_reference_bitwise_all_formats() {
        let c = cfg();
        let mut rng = Rng::seed(6);
        // 16-wide rows: a padded tail group for HiF4/MXFP4, exact fit for
        // the 16-element formats.
        let rows = Matrix::randn(4, 16, 0.7, &mut rng);
        for kind in QuantKind::ALL {
            let mut cache = KvCache::new(&c, KvCacheType::Quant(kind));
            for r in 0..rows.rows {
                cache.layers[1].v.append_row(rows.row(r));
            }
            let mut reference = rows.clone();
            qdq_rows(kind, &mut reference);
            let dense = cache.layers[1].v.dense(4);
            for r in 0..rows.rows {
                let got: Vec<u32> = dense.row(r).iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = reference.row(r).iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "{kind} row {r}");
            }
        }
    }

    #[test]
    fn quant_cache_is_smaller_resident_and_on_the_wire() {
        let c = cfg();
        let mut f32c = KvCache::new(&c, KvCacheType::F32);
        let mut hc = KvCache::new(&c, KvCacheType::HIF4);
        let mut rng = Rng::seed(7);
        let rows = Matrix::randn(8, 16, 1.0, &mut rng);
        for cache in [&mut f32c, &mut hc] {
            for layer in 0..2 {
                for r in 0..rows.rows {
                    cache.layers[layer].k.append_row(rows.row(r));
                    cache.layers[layer].v.append_row(rows.row(r));
                }
            }
            cache.advance(rows.rows);
        }
        assert_eq!(f32c.len(), 8);
        assert!(hc.resident_bytes() < f32c.resident_bytes());
        assert!(hc.wire_bytes() < hc.resident_bytes());
        // 16-wide rows pad to one 64-lane unit: 36 wire bytes vs 64 f32.
        assert_eq!(hc.wire_bytes(), 2 * 2 * 8 * hif4::HiF4Unit::WIRE_BYTES);
    }

    #[test]
    fn byte_accounting_is_exact_through_slot_reuse() {
        // The slot-reuse lifecycle: fill a page, reset it for the next
        // sequence, refill with fewer rows. Resident/wire bytes must
        // track the *stored* rows exactly at every step — a recycled
        // page's parked capacity (from the longer first tenant) must
        // never inflate them — and `wire ≤ resident ≤ capacity` holds
        // throughout.
        let c = cfg();
        let mut rng = Rng::seed(8);
        let mut cache = KvCache::new(&c, KvCacheType::HIF4);
        assert!(cache.fits(&c, KvCacheType::HIF4));
        assert!(!cache.fits(&c, KvCacheType::F32));
        // Exact per-row costs for this geometry: kvd = 16 pads to one
        // 64-lane HiF4 group → 64 lane bytes + 8 scale bytes resident,
        // 36 canonical wire bytes; 2 layers × (K + V) = 4 stores.
        let resident_per_pos = 4 * (64 + 8);
        let wire_per_pos = 4 * hif4::HiF4Unit::WIRE_BYTES;
        let fill = |cache: &mut KvCache, rows: &Matrix| {
            for layer in 0..2 {
                for r in 0..rows.rows {
                    cache.layers[layer].k.append_row(rows.row(r));
                    cache.layers[layer].v.append_row(rows.row(r));
                }
            }
            cache.advance(rows.rows);
        };
        let first = Matrix::randn(8, 16, 1.0, &mut rng);
        fill(&mut cache, &first);
        assert_eq!(cache.resident_bytes(), 8 * resident_per_pos);
        assert_eq!(cache.wire_bytes(), 8 * wire_per_pos);
        assert!(cache.wire_bytes() <= cache.resident_bytes());
        assert!(cache.resident_bytes() <= cache.capacity_bytes());

        // Evict + recycle: contents gone, allocation parked.
        cache.reset();
        assert_eq!(cache.len(), 0);
        assert!(cache.is_empty());
        assert_eq!(cache.resident_bytes(), 0, "an emptied page stores nothing");
        assert_eq!(cache.wire_bytes(), 0);
        assert!(cache.capacity_bytes() >= 8 * resident_per_pos, "allocation must survive reset");

        // Second, shorter tenant: counts reflect it exactly — reporting
        // from capacity would claim the old 8-row footprint.
        let second = Matrix::randn(3, 16, 1.0, &mut rng);
        fill(&mut cache, &second);
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.resident_bytes(), 3 * resident_per_pos);
        assert_eq!(cache.wire_bytes(), 3 * wire_per_pos);
        assert!(cache.wire_bytes() <= cache.resident_bytes());
        assert!(cache.resident_bytes() < cache.capacity_bytes());

        // And the recycled page still decodes correctly (same codec path
        // as a fresh store).
        let mut reference = second.clone();
        qdq_rows(QuantKind::HiF4, &mut reference);
        let dense = cache.layers[1].v.dense(3);
        for r in 0..3 {
            assert_eq!(dense.row(r), reference.row(r), "row {r}");
        }

        // The f32 backend holds the same invariants (wire == resident).
        let mut f32c = KvCache::new(&c, KvCacheType::F32);
        fill(&mut f32c, &first);
        assert_eq!(f32c.resident_bytes(), 8 * 4 * 16 * 4);
        assert_eq!(f32c.wire_bytes(), f32c.resident_bytes());
        f32c.reset();
        assert_eq!(f32c.resident_bytes(), 0);
        assert!(f32c.capacity_bytes() > 0);
    }

    #[test]
    fn resident_row_bytes_matches_store() {
        // The admission gate budgets KV bytes with the static estimator;
        // if it ever drifted from what append_row actually stores, the
        // gate would over-admit (OOM risk) or under-admit (wasted
        // capacity). Pin exact agreement for every kind and both an
        // exact-fit and a padded-tail row width.
        let mut rng = Rng::seed(11);
        let mut kinds = vec![KvCacheType::F32];
        kinds.extend(QuantKind::ALL.map(KvCacheType::Quant));
        for kind in kinds {
            for kvd in [16usize, 24, 64] {
                let rows = Matrix::randn(5, kvd, 1.0, &mut rng);
                let mut store = KvStore::new(kind, kvd);
                for r in 0..rows.rows {
                    store.append_row(rows.row(r));
                }
                assert_eq!(
                    store.resident_bytes(),
                    5 * kind.resident_row_bytes(kvd),
                    "{} kvd={kvd}",
                    kind.label()
                );
            }
        }
    }
}
