//! Per-sequence KV cache for incremental decode — the serving-side memory
//! layer that makes generation O(T) per token instead of O(T²).
//!
//! Two storage backends sit behind one [`KvCache`] (the [`KvCacheType`]
//! knob, `--kv-cache` / `HIF4_KV_CACHE` on the CLI):
//!
//! * **F32** — the reference: appended K/V rows are kept verbatim, so
//!   cached decode is *bit-identical* to the full-recompute forward.
//! * **Quant(kind)** — each appended row is encoded through the format
//!   codec of `kind` (any of the five block formats, grouped along the
//!   head dimension) and held as the decode-once integer lane planes of
//!   [`crate::dotprod::quant_tensor`]: the nibble/micro-exponent
//!   extraction is paid exactly once per cached value at append time, and
//!   attention reads straight from the planes (one multiply per lane).
//!   The resident plane costs 8 bits/value of lanes plus one amortized
//!   `f64` group scale vs 32 for f32 — and the canonical packed wire form
//!   ([`KvCache::wire_bytes`], `bits_per_value()` of the kind) is what a
//!   paged or offloaded cache would persist.
//!
//! Keys are cached **post-RoPE** (their rotation depends only on the
//! absolute position, which never changes once cached). The
//! quantize→decode round trip here is the *same code* the full-recompute
//! reference applies via [`qdq_rows`], so the greedy-decode parity suite
//! (`tests/decode_parity.rs`) can pin replay-attention
//! cached-vs-recompute equality down to the bit for every format.
//!
//! # Tiled plane access
//!
//! Long-context attention does not have to pay the dense per-call decode
//! of [`KvStore::dense`]: the fused path ([`crate::model::attention`])
//! walks the planes through [`KvTiles`] — a borrowed, zero-copy tile
//! view over a store's packed lanes and group scales — scoring `QK^T`
//! on the integer lanes directly and decoding only the `V` column span
//! it needs per tile. The iterator covers rows `0..rows` in order, every
//! tile `tile_rows` long except a shorter final tail:
//!
//! ```
//! use hif4::model::kv::{KvCache, KvCacheType};
//! use hif4::model::zoo;
//!
//! // A quantized cache for one sequence, filled with 100 synthetic rows.
//! let cfg = zoo::llama2_tiny();
//! let mut cache = KvCache::new(&cfg, KvCacheType::HIF4);
//! cache.fill_synthetic(100, 7);
//!
//! // Walk layer 0's K planes in 48-row tiles: 48 + 48 + a 4-row tail.
//! let mut covered = 0;
//! for tile in cache.k_tiles(0, cache.len(), 48).expect("quantized caches tile") {
//!     assert_eq!(tile.start(), covered);
//!     covered += tile.rows();
//!     // Each tile row is one packed plane: an i8 lane per cached value
//!     // plus one f64 scale per group (lane index == column index)…
//!     assert_eq!(tile.row_lanes(0).len(), tile.groups_per_row() * tile.quant().group());
//!     assert_eq!(tile.row_scales(0).len(), tile.groups_per_row());
//!     // …and any column span decodes to f32 without touching the rest.
//!     let mut head = vec![0f32; tile.rows() * 16];
//!     tile.decode_cols(0..16, &mut head);
//! }
//! assert_eq!(covered, 100);
//! ```
//!
//! F32 stores have no planes to tile ([`KvCache::k_tiles`] returns
//! `None`), which is exactly the runtime signal the attention dispatcher
//! uses to fall back to replay.

use crate::dotprod::quant_tensor::{decode_plane, encode_row_planes};
use crate::formats::QuantKind;
use crate::model::config::ModelConfig;
use crate::tensor::Matrix;

/// Which storage backend a [`KvCache`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvCacheType {
    /// Dense f32 rows — bit-identical to full recompute.
    #[default]
    F32,
    /// Block-quantized rows encoded on append, held as decode-once lane
    /// planes (any [`QuantKind`]).
    Quant(QuantKind),
}

impl KvCacheType {
    /// The HiF4-quantized cache (the paper's configuration), spelled out
    /// since it is the default quantized choice everywhere.
    pub const HIF4: KvCacheType = KvCacheType::Quant(QuantKind::HiF4);

    /// Parse a CLI/env spelling through the single [`QuantKind`] parser:
    /// `f32`, or any format spelling (`hif4`, `nvfp4`, `mxfp4`, `mx4`,
    /// `bfp`), case-insensitive.
    pub fn parse(s: &str) -> Result<KvCacheType, String> {
        if s.eq_ignore_ascii_case("f32") {
            return Ok(KvCacheType::F32);
        }
        s.parse::<QuantKind>()
            .map(KvCacheType::Quant)
            .map_err(|e| format!("{e} (or f32 for the unquantized cache)"))
    }

    /// Canonical lower-case label (bench/JSON key); round-trips through
    /// [`KvCacheType::parse`].
    pub fn label(self) -> &'static str {
        match self {
            KvCacheType::F32 => "f32",
            KvCacheType::Quant(kind) => kind.spelling(),
        }
    }

    /// Resident bytes one appended row of width `kvd` costs in a store of
    /// this kind — the admission gate's KV-budget unit. Mirrors the
    /// actual store layout (f32 values; decode-once lane planes padded to
    /// whole groups plus one f64 scale per group for quantized kinds), so
    /// gate reservations and [`KvCache::resident_bytes`] agree exactly;
    /// the `resident_row_bytes_matches_store` test pins the equality for
    /// every kind.
    pub fn resident_row_bytes(self, kvd: usize) -> usize {
        match self {
            KvCacheType::F32 => kvd * std::mem::size_of::<f32>(),
            KvCacheType::Quant(kind) => {
                let group = kind.group();
                kvd.div_ceil(group)
                    * (group * std::mem::size_of::<i8>() + std::mem::size_of::<f64>())
            }
        }
    }
}

/// Per-sequence, per-layer K/V storage for incremental decode. One cache
/// is one sequence's "page": the continuous-batching scheduler owns one
/// per active slot and drops it on eviction.
#[derive(Debug, Clone)]
pub struct KvCache {
    kind: KvCacheType,
    len: usize,
    pub(crate) layers: Vec<LayerKv>,
}

/// One layer's K and V stores.
#[derive(Debug, Clone)]
pub(crate) struct LayerKv {
    pub k: KvStore,
    pub v: KvStore,
}

/// Append-only row store for one tensor (K or V) of one layer.
#[derive(Debug, Clone)]
pub(crate) enum KvStore {
    F32 {
        kvd: usize,
        data: Vec<f32>,
    },
    Quant {
        quant: QuantKind,
        kvd: usize,
        groups_per_row: usize,
        lanes: Vec<i8>,
        scales: Vec<f64>,
    },
}

/// A dense f32 view of the first `rows` cached rows: f32 stores borrow in
/// place, quantized stores decode their lane planes once per view.
pub(crate) struct KvDense<'a> {
    kvd: usize,
    data: DenseData<'a>,
}

enum DenseData<'a> {
    Borrowed(&'a [f32]),
    Owned(Vec<f32>),
}

impl KvDense<'_> {
    /// Row `r` as a kvd-wide slice.
    #[inline]
    pub(crate) fn row(&self, r: usize) -> &[f32] {
        let d = match &self.data {
            DenseData::Borrowed(s) => s,
            DenseData::Owned(v) => v.as_slice(),
        };
        &d[r * self.kvd..(r + 1) * self.kvd]
    }
}

/// Iterator over a quantized store's packed planes in row tiles — the
/// fused attention path's view of the KV cache (see the module docs for
/// a worked example). Yields [`KvTile`]s covering rows `0..rows` in
/// ascending order; every tile spans `tile_rows` rows except a shorter
/// final tail. Borrowed and zero-copy: no plane is decoded until a
/// consumer asks via [`KvTile::decode_cols`].
pub struct KvTiles<'a> {
    quant: QuantKind,
    kvd: usize,
    groups_per_row: usize,
    lanes: &'a [i8],
    scales: &'a [f64],
    rows: usize,
    tile_rows: usize,
    next: usize,
}

impl KvTiles<'_> {
    /// The format every tile's planes were encoded with.
    pub fn quant(&self) -> QuantKind {
        self.quant
    }

    /// Plane groups per row (`kvd` rounded up to whole groups) — the
    /// scratch-sizing constant consumers need before the first tile.
    pub fn groups_per_row(&self) -> usize {
        self.groups_per_row
    }
}

impl<'a> Iterator for KvTiles<'a> {
    type Item = KvTile<'a>;

    fn next(&mut self) -> Option<KvTile<'a>> {
        if self.next >= self.rows {
            return None;
        }
        let start = self.next;
        let rows = self.tile_rows.min(self.rows - start);
        self.next += rows;
        let g = self.groups_per_row;
        let row_lanes = g * self.quant.group();
        Some(KvTile {
            quant: self.quant,
            kvd: self.kvd,
            groups_per_row: g,
            start,
            rows,
            lanes: &self.lanes[start * row_lanes..(start + rows) * row_lanes],
            scales: &self.scales[start * g..(start + rows) * g],
        })
    }
}

/// One tile of packed KV planes: `rows` consecutive cached positions
/// starting at absolute position [`KvTile::start`], borrowed straight
/// from the store.
///
/// Layout contract (what the integer attention kernel scores against):
/// each tile-local row `r` owns `groups_per_row × group` i8 lanes
/// ([`KvTile::row_lanes`]) and `groups_per_row` f64 scales
/// ([`KvTile::row_scales`]); **lane index equals column index** within
/// the row (group `u` occupies lanes `u·group..(u+1)·group`, padding
/// beyond the row width `kvd` is zero lanes in the final group). A
/// column `c` therefore decodes as `scales[c / group] · lanes[c] /
/// LANE_UNIT`, which is what [`KvTile::decode_cols`] evaluates —
/// bit-identical to the dense whole-store decode.
pub struct KvTile<'a> {
    quant: QuantKind,
    kvd: usize,
    groups_per_row: usize,
    start: usize,
    rows: usize,
    lanes: &'a [i8],
    scales: &'a [f64],
}

impl KvTile<'_> {
    /// Absolute cache position of the tile's first row.
    pub fn start(&self) -> usize {
        self.start
    }

    /// Rows in this tile (`tile_rows`, except the shorter final tail).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// The format the planes were encoded with.
    pub fn quant(&self) -> QuantKind {
        self.quant
    }

    /// Plane groups per row (`kvd` rounded up to whole groups).
    pub fn groups_per_row(&self) -> usize {
        self.groups_per_row
    }

    /// Tile-local row `r`'s packed i8 lanes (`groups_per_row × group`
    /// long; lane index == column index, zero-padded past `kvd`).
    pub fn row_lanes(&self, r: usize) -> &[i8] {
        let w = self.groups_per_row * self.quant.group();
        &self.lanes[r * w..(r + 1) * w]
    }

    /// Tile-local row `r`'s per-group f64 scales (`groups_per_row` long).
    pub fn row_scales(&self, r: usize) -> &[f64] {
        &self.scales[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }

    /// Decode the column span `cols` of **every** tile row into `out`
    /// (row-major, `rows × cols.len()`), walking group boundaries so each
    /// value is `scale · lane / LANE_UNIT` — bit-identical to the same
    /// columns of [`KvStore::dense`]'s whole-row decode, since both run
    /// the per-element [`decode_plane`] kernel with the same scale. The
    /// fused attention path uses this for the `V` head slice only; `K`
    /// never decodes at all.
    pub fn decode_cols(&self, cols: std::ops::Range<usize>, out: &mut [f32]) {
        assert!(cols.end <= self.kvd, "column span exceeds row width");
        let w = cols.end - cols.start;
        assert_eq!(out.len(), self.rows * w, "decode_cols buffer must be rows × span");
        let group = self.quant.group();
        for r in 0..self.rows {
            let lanes = self.row_lanes(r);
            let scales = self.row_scales(r);
            let dst = &mut out[r * w..(r + 1) * w];
            let mut c = cols.start;
            while c < cols.end {
                let u = c / group;
                let stop = cols.end.min((u + 1) * group);
                let span = &mut dst[c - cols.start..stop - cols.start];
                decode_plane(self.quant, &lanes[c..stop], scales[u], span);
                c = stop;
            }
        }
    }
}

impl KvStore {
    fn new(kind: KvCacheType, kvd: usize) -> KvStore {
        match kind {
            KvCacheType::F32 => KvStore::F32 { kvd, data: Vec::new() },
            KvCacheType::Quant(quant) => KvStore::Quant {
                quant,
                kvd,
                groups_per_row: kvd.div_ceil(quant.group()),
                lanes: Vec::new(),
                scales: Vec::new(),
            },
        }
    }

    /// Append one position's row. Quantized stores encode it through the
    /// format codec (zero-padded tail group — the same uniform tail
    /// handling as the quantized matrices) and keep only the decode-once
    /// plane.
    pub(crate) fn append_row(&mut self, row: &[f32]) {
        match self {
            KvStore::F32 { kvd, data } => {
                assert_eq!(row.len(), *kvd, "KV row width must match kv_heads×head_dim");
                data.extend_from_slice(row);
            }
            KvStore::Quant { quant, kvd, lanes, scales, .. } => {
                assert_eq!(row.len(), *kvd, "KV row width must match kv_heads×head_dim");
                encode_row_planes(*quant, row, lanes, scales);
            }
        }
    }

    /// Dense view of rows `0..rows` (see [`KvDense`]).
    pub(crate) fn dense(&self, rows: usize) -> KvDense<'_> {
        match self {
            KvStore::F32 { kvd, data } => {
                KvDense { kvd: *kvd, data: DenseData::Borrowed(&data[..rows * *kvd]) }
            }
            KvStore::Quant { quant, kvd, groups_per_row, lanes, scales } => {
                let group = quant.group();
                let mut out = vec![0f32; rows * *kvd];
                for r in 0..rows {
                    let row = &mut out[r * *kvd..(r + 1) * *kvd];
                    for u in 0..*groups_per_row {
                        let start = u * group;
                        let end = (start + group).min(*kvd);
                        let i = r * *groups_per_row + u;
                        decode_plane(
                            *quant,
                            &lanes[i * group..(i + 1) * group],
                            scales[i],
                            &mut row[start..end],
                        );
                    }
                }
                KvDense { kvd: *kvd, data: DenseData::Owned(out) }
            }
        }
    }

    /// Tile the first `rows` stored rows into [`KvTiles`] of `tile_rows`
    /// each (shorter tail). Quantized stores only — an f32 store has no
    /// packed planes to walk and returns `None`, which is the attention
    /// dispatcher's replay-fallback signal.
    pub(crate) fn tiles(&self, rows: usize, tile_rows: usize) -> Option<KvTiles<'_>> {
        assert!(tile_rows > 0, "tile_rows must be positive");
        assert!(rows <= self.rows(), "cannot tile rows that were never appended");
        match self {
            KvStore::F32 { .. } => None,
            KvStore::Quant { quant, kvd, groups_per_row, lanes, scales } => Some(KvTiles {
                quant: *quant,
                kvd: *kvd,
                groups_per_row: *groups_per_row,
                lanes,
                scales,
                rows,
                tile_rows,
                next: 0,
            }),
        }
    }

    /// Row width this store was sized for (kv_heads × head_dim).
    pub(crate) fn kvd(&self) -> usize {
        match self {
            KvStore::F32 { kvd, .. } | KvStore::Quant { kvd, .. } => *kvd,
        }
    }

    /// Positions stored so far (rows appended since creation/[`clear`]).
    ///
    /// [`clear`]: KvStore::clear
    pub(crate) fn rows(&self) -> usize {
        match self {
            KvStore::F32 { kvd, data } => data.len() / (*kvd).max(1),
            KvStore::Quant { groups_per_row, scales, .. } => {
                scales.len() / (*groups_per_row).max(1)
            }
        }
    }

    /// Drop every stored row but keep the backing allocations — the
    /// slot-reuse path: a recycled cache page serves its next sequence
    /// without reallocating, while the byte accounting (stored length,
    /// never `Vec` capacity) immediately reports the emptied store as 0.
    fn clear(&mut self) {
        match self {
            KvStore::F32 { data, .. } => data.clear(),
            KvStore::Quant { lanes, scales, .. } => {
                lanes.clear();
                scales.clear();
            }
        }
    }

    /// Bytes of the rows actually stored (decode-once planes for
    /// quantized stores). Derived from the stored *length* — a recycled
    /// page's backing capacity, which can be much larger after
    /// reset/reuse churn, is reported by [`KvStore::capacity_bytes`]
    /// instead and never leaks into this number.
    fn resident_bytes(&self) -> usize {
        match self {
            KvStore::F32 { data, .. } => std::mem::size_of_val(data.as_slice()),
            KvStore::Quant { lanes, scales, .. } => {
                std::mem::size_of_val(lanes.as_slice()) + std::mem::size_of_val(scales.as_slice())
            }
        }
    }

    /// Bytes the backing allocations currently hold, stored or parked
    /// (`≥ resident_bytes` by construction).
    fn capacity_bytes(&self) -> usize {
        match self {
            KvStore::F32 { data, .. } => data.capacity() * std::mem::size_of::<f32>(),
            KvStore::Quant { lanes, scales, .. } => {
                lanes.capacity() * std::mem::size_of::<i8>()
                    + scales.capacity() * std::mem::size_of::<f64>()
            }
        }
    }

    /// Serialized bytes of the stored rows (canonical packed group wire
    /// layout for quantized stores; dense f32 for F32). Like
    /// [`KvStore::resident_bytes`], derived from the stored length only.
    fn wire_bytes(&self) -> usize {
        match self {
            KvStore::F32 { data, .. } => std::mem::size_of_val(data.as_slice()),
            KvStore::Quant { quant, scales, .. } => scales.len() * quant.wire_bytes_group(),
        }
    }
}

impl KvCache {
    /// Empty cache for one sequence under `cfg`'s geometry.
    pub fn new(cfg: &ModelConfig, kind: KvCacheType) -> KvCache {
        let kvd = cfg.kv_heads() * cfg.head_dim;
        let layers = (0..cfg.n_layers)
            .map(|_| LayerKv { k: KvStore::new(kind, kvd), v: KvStore::new(kind, kvd) })
            .collect();
        KvCache { kind, len: 0, layers }
    }

    pub fn kind(&self) -> KvCacheType {
        self.kind
    }

    /// Number of positions cached so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes the cache keeps resident (decode-once planes for quantized
    /// kinds). Reported from the **stored length** — rows actually held —
    /// never from the backing allocation capacity, so the number stays
    /// exact through reset/reuse churn (`wire_bytes ≤ resident_bytes ≤
    /// capacity_bytes` always; pinned by the slot-reuse unit test).
    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.resident_bytes() + l.v.resident_bytes()).sum()
    }

    /// Bytes of the serialized form (the format's canonical packed group
    /// wire layout for quantized caches; same as resident for f32).
    /// Stored-length-derived like [`KvCache::resident_bytes`].
    pub fn wire_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.wire_bytes() + l.v.wire_bytes()).sum()
    }

    /// Bytes currently parked in the backing allocations — after
    /// [`KvCache::reset`] this exceeds [`KvCache::resident_bytes`] (the
    /// whole point of recycling: the allocation survives, the contents
    /// don't count).
    pub fn capacity_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.capacity_bytes() + l.v.capacity_bytes()).sum()
    }

    /// Reset for slot reuse: forget every stored row in every layer but
    /// keep the backing allocations, so a recycled page appends its next
    /// sequence without re-growing. The byte accounting reports the
    /// stored content only — an emptied page is 0 bytes resident/wire
    /// even while its capacity is still parked.
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.k.clear();
            l.v.clear();
        }
        self.len = 0;
    }

    /// Does this page carry `cfg`'s geometry under `kind` storage? The
    /// slot-reuse guard: recycled pages only re-attach to an engine whose
    /// model/cache configuration they were built for.
    pub fn fits(&self, cfg: &ModelConfig, kind: KvCacheType) -> bool {
        let kvd = cfg.kv_heads() * cfg.head_dim;
        self.kind == kind
            && self.layers.len() == cfg.n_layers
            && self.layers.iter().all(|l| l.k.kvd() == kvd && l.v.kvd() == kvd)
    }

    /// Tile layer `layer`'s **K** planes over cached positions `0..rows`
    /// (see [`KvTiles`]; `None` for f32 caches). `rows` may be less than
    /// [`KvCache::len`] — attention scores a query at position `p`
    /// against rows `0..=p` only.
    pub fn k_tiles(&self, layer: usize, rows: usize, tile_rows: usize) -> Option<KvTiles<'_>> {
        self.layers[layer].k.tiles(rows, tile_rows)
    }

    /// Tile layer `layer`'s **V** planes (the `PV` side of
    /// [`KvCache::k_tiles`]).
    pub fn v_tiles(&self, layer: usize, rows: usize, tile_rows: usize) -> Option<KvTiles<'_>> {
        self.layers[layer].v.tiles(rows, tile_rows)
    }

    /// Append `rows` synthetic Gaussian K/V rows to every layer and
    /// advance the position count — a fixture for long-context benches
    /// and doctests that need a populated cache without paying an O(T²)
    /// model prefill. Deterministic in `seed`. The rows are *not* a real
    /// model's activations; use it only where both measured paths read
    /// the same cache (fused-vs-replay comparisons).
    pub fn fill_synthetic(&mut self, rows: usize, seed: u64) {
        let mut rng = crate::tensor::Rng::seed(seed);
        for l in &mut self.layers {
            let kvd = l.k.kvd();
            let k = Matrix::randn(rows, kvd, 1.0, &mut rng);
            let v = Matrix::randn(rows, kvd, 1.0, &mut rng);
            for r in 0..rows {
                l.k.append_row(k.row(r));
                l.v.append_row(v.row(r));
            }
        }
        self.advance(rows);
    }

    pub(crate) fn advance(&mut self, n: usize) {
        self.len += n;
        // Appends happen store-by-store before the position count moves;
        // once it does, every store must actually hold the rows it claims.
        debug_assert!(
            self.layers.iter().all(|l| l.k.rows() == self.len && l.v.rows() == self.len),
            "advance({n}) out of step with the appended rows"
        );
    }
}

/// Quantize→dequantize every row of `m` through the `kind` KV codec. Not
/// a reimplementation: the rows go through the *actual* cache store
/// ([`KvStore::append_row`] encode, [`KvStore::dense`] decode), so a
/// full-recompute forward with
/// [`super::transformer::QuantPolicy::kv`] set is a *bit-exact*
/// reference for quantized-cache incremental decode by construction — the
/// two paths cannot drift apart, for any format.
pub fn qdq_rows(kind: QuantKind, m: &mut Matrix) {
    let mut store = KvStore::new(KvCacheType::Quant(kind), m.cols);
    for r in 0..m.rows {
        store.append_row(m.row(r));
    }
    let dense = store.dense(m.rows);
    for r in 0..m.rows {
        m.row_mut(r).copy_from_slice(dense.row(r));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::hif4;
    use crate::tensor::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "kv-test".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 4,
            head_dim: 8,
            attention: crate::model::config::Attention::Gqa { kv_heads: 2 },
            ffn: crate::model::config::Ffn::SwiGlu,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    #[test]
    fn parse_and_label_roundtrip() {
        let mut kinds = vec![KvCacheType::F32];
        kinds.extend(QuantKind::ALL.map(KvCacheType::Quant));
        for kind in kinds {
            assert_eq!(KvCacheType::parse(kind.label()), Ok(kind));
        }
        assert_eq!(KvCacheType::parse("HIF4"), Ok(KvCacheType::HIF4));
        let err = KvCacheType::parse("bf16").unwrap_err();
        assert!(err.contains("f32") && err.contains("mxfp4"), "{err}");
    }

    #[test]
    fn f32_store_roundtrips_rows_exactly() {
        let c = cfg();
        let mut cache = KvCache::new(&c, KvCacheType::F32);
        let mut rng = Rng::seed(5);
        let rows = Matrix::randn(3, 16, 1.0, &mut rng);
        for r in 0..rows.rows {
            cache.layers[0].k.append_row(rows.row(r));
        }
        let dense = cache.layers[0].k.dense(3);
        for r in 0..rows.rows {
            assert_eq!(dense.row(r), rows.row(r));
        }
    }

    #[test]
    fn quant_store_matches_qdq_reference_bitwise_all_formats() {
        let c = cfg();
        let mut rng = Rng::seed(6);
        // 16-wide rows: a padded tail group for HiF4/MXFP4, exact fit for
        // the 16-element formats.
        let rows = Matrix::randn(4, 16, 0.7, &mut rng);
        for kind in QuantKind::ALL {
            let mut cache = KvCache::new(&c, KvCacheType::Quant(kind));
            for r in 0..rows.rows {
                cache.layers[1].v.append_row(rows.row(r));
            }
            let mut reference = rows.clone();
            qdq_rows(kind, &mut reference);
            let dense = cache.layers[1].v.dense(4);
            for r in 0..rows.rows {
                let got: Vec<u32> = dense.row(r).iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = reference.row(r).iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "{kind} row {r}");
            }
        }
    }

    #[test]
    fn quant_cache_is_smaller_resident_and_on_the_wire() {
        let c = cfg();
        let mut f32c = KvCache::new(&c, KvCacheType::F32);
        let mut hc = KvCache::new(&c, KvCacheType::HIF4);
        let mut rng = Rng::seed(7);
        let rows = Matrix::randn(8, 16, 1.0, &mut rng);
        for cache in [&mut f32c, &mut hc] {
            for layer in 0..2 {
                for r in 0..rows.rows {
                    cache.layers[layer].k.append_row(rows.row(r));
                    cache.layers[layer].v.append_row(rows.row(r));
                }
            }
            cache.advance(rows.rows);
        }
        assert_eq!(f32c.len(), 8);
        assert!(hc.resident_bytes() < f32c.resident_bytes());
        assert!(hc.wire_bytes() < hc.resident_bytes());
        // 16-wide rows pad to one 64-lane unit: 36 wire bytes vs 64 f32.
        assert_eq!(hc.wire_bytes(), 2 * 2 * 8 * hif4::HiF4Unit::WIRE_BYTES);
    }

    #[test]
    fn byte_accounting_is_exact_through_slot_reuse() {
        // The slot-reuse lifecycle: fill a page, reset it for the next
        // sequence, refill with fewer rows. Resident/wire bytes must
        // track the *stored* rows exactly at every step — a recycled
        // page's parked capacity (from the longer first tenant) must
        // never inflate them — and `wire ≤ resident ≤ capacity` holds
        // throughout.
        let c = cfg();
        let mut rng = Rng::seed(8);
        let mut cache = KvCache::new(&c, KvCacheType::HIF4);
        assert!(cache.fits(&c, KvCacheType::HIF4));
        assert!(!cache.fits(&c, KvCacheType::F32));
        // Exact per-row costs for this geometry: kvd = 16 pads to one
        // 64-lane HiF4 group → 64 lane bytes + 8 scale bytes resident,
        // 36 canonical wire bytes; 2 layers × (K + V) = 4 stores.
        let resident_per_pos = 4 * (64 + 8);
        let wire_per_pos = 4 * hif4::HiF4Unit::WIRE_BYTES;
        let fill = |cache: &mut KvCache, rows: &Matrix| {
            for layer in 0..2 {
                for r in 0..rows.rows {
                    cache.layers[layer].k.append_row(rows.row(r));
                    cache.layers[layer].v.append_row(rows.row(r));
                }
            }
            cache.advance(rows.rows);
        };
        let first = Matrix::randn(8, 16, 1.0, &mut rng);
        fill(&mut cache, &first);
        assert_eq!(cache.resident_bytes(), 8 * resident_per_pos);
        assert_eq!(cache.wire_bytes(), 8 * wire_per_pos);
        assert!(cache.wire_bytes() <= cache.resident_bytes());
        assert!(cache.resident_bytes() <= cache.capacity_bytes());

        // Evict + recycle: contents gone, allocation parked.
        cache.reset();
        assert_eq!(cache.len(), 0);
        assert!(cache.is_empty());
        assert_eq!(cache.resident_bytes(), 0, "an emptied page stores nothing");
        assert_eq!(cache.wire_bytes(), 0);
        assert!(cache.capacity_bytes() >= 8 * resident_per_pos, "allocation must survive reset");

        // Second, shorter tenant: counts reflect it exactly — reporting
        // from capacity would claim the old 8-row footprint.
        let second = Matrix::randn(3, 16, 1.0, &mut rng);
        fill(&mut cache, &second);
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.resident_bytes(), 3 * resident_per_pos);
        assert_eq!(cache.wire_bytes(), 3 * wire_per_pos);
        assert!(cache.wire_bytes() <= cache.resident_bytes());
        assert!(cache.resident_bytes() < cache.capacity_bytes());

        // And the recycled page still decodes correctly (same codec path
        // as a fresh store).
        let mut reference = second.clone();
        qdq_rows(QuantKind::HiF4, &mut reference);
        let dense = cache.layers[1].v.dense(3);
        for r in 0..3 {
            assert_eq!(dense.row(r), reference.row(r), "row {r}");
        }

        // The f32 backend holds the same invariants (wire == resident).
        let mut f32c = KvCache::new(&c, KvCacheType::F32);
        fill(&mut f32c, &first);
        assert_eq!(f32c.resident_bytes(), 8 * 4 * 16 * 4);
        assert_eq!(f32c.wire_bytes(), f32c.resident_bytes());
        f32c.reset();
        assert_eq!(f32c.resident_bytes(), 0);
        assert!(f32c.capacity_bytes() > 0);
    }

    #[test]
    fn resident_row_bytes_matches_store() {
        // The admission gate budgets KV bytes with the static estimator;
        // if it ever drifted from what append_row actually stores, the
        // gate would over-admit (OOM risk) or under-admit (wasted
        // capacity). Pin exact agreement for every kind and both an
        // exact-fit and a padded-tail row width.
        let mut rng = Rng::seed(11);
        let mut kinds = vec![KvCacheType::F32];
        kinds.extend(QuantKind::ALL.map(KvCacheType::Quant));
        for kind in kinds {
            for kvd in [16usize, 24, 64] {
                let rows = Matrix::randn(5, kvd, 1.0, &mut rng);
                let mut store = KvStore::new(kind, kvd);
                for r in 0..rows.rows {
                    store.append_row(rows.row(r));
                }
                assert_eq!(
                    store.resident_bytes(),
                    5 * kind.resident_row_bytes(kvd),
                    "{} kvd={kvd}",
                    kind.label()
                );
            }
        }
    }

    #[test]
    fn tiles_cover_rows_in_order_with_tail() {
        // 11 rows in 4-row tiles: 4 + 4 + 3 — every row exactly once,
        // starts ascending, and each tile's planes are the same bytes the
        // store holds for those rows.
        let mut rng = Rng::seed(20);
        for kind in QuantKind::ALL {
            let kvd = 24usize; // padded tail group for every format
            let rows = Matrix::randn(11, kvd, 1.0, &mut rng);
            let mut store = KvStore::new(KvCacheType::Quant(kind), kvd);
            for r in 0..rows.rows {
                store.append_row(rows.row(r));
            }
            let gpr = kvd.div_ceil(kind.group());
            let mut covered = 0usize;
            let mut sizes = Vec::new();
            for tile in store.tiles(11, 4).unwrap() {
                assert_eq!(tile.start(), covered, "{kind}");
                assert_eq!(tile.quant(), kind);
                assert_eq!(tile.groups_per_row(), gpr);
                for r in 0..tile.rows() {
                    assert_eq!(tile.row_lanes(r).len(), gpr * kind.group());
                    assert_eq!(tile.row_scales(r).len(), gpr);
                }
                covered += tile.rows();
                sizes.push(tile.rows());
            }
            assert_eq!(covered, 11, "{kind}");
            assert_eq!(sizes, vec![4, 4, 3], "{kind}");
            // Partial visibility: tiling fewer rows than stored stops early.
            let partial: usize = store.tiles(6, 4).unwrap().map(|t| t.rows()).sum();
            assert_eq!(partial, 6);
        }
        // F32 stores have nothing to tile — the replay-fallback signal.
        let store = KvStore::new(KvCacheType::F32, 16);
        assert!(store.tiles(0, 4).is_none());
    }

    #[test]
    fn decode_cols_is_bitwise_identical_to_dense() {
        // Any column span — group-aligned, group-crossing, or inside the
        // zero-padded tail group — must decode to exactly the bits the
        // whole-row dense view produces for those columns.
        let mut rng = Rng::seed(21);
        for kind in QuantKind::ALL {
            let kvd = 40usize;
            let rows = Matrix::randn(9, kvd, 0.8, &mut rng);
            let mut store = KvStore::new(KvCacheType::Quant(kind), kvd);
            for r in 0..rows.rows {
                store.append_row(rows.row(r));
            }
            let dense = store.dense(9);
            for span in [0..kvd, 0..16, 16..32, 12..29, 33..40] {
                let w = span.end - span.start;
                for tile in store.tiles(9, 4).unwrap() {
                    let mut out = vec![0f32; tile.rows() * w];
                    tile.decode_cols(span.clone(), &mut out);
                    for r in 0..tile.rows() {
                        let got: Vec<u32> =
                            out[r * w..(r + 1) * w].iter().map(|x| x.to_bits()).collect();
                        let want: Vec<u32> = dense.row(tile.start() + r)[span.clone()]
                            .iter()
                            .map(|x| x.to_bits())
                            .collect();
                        assert_eq!(got, want, "{kind} span {span:?} row {}", tile.start() + r);
                    }
                }
            }
        }
    }

    #[test]
    fn fill_synthetic_populates_every_layer_deterministically() {
        let c = cfg();
        let mut a = KvCache::new(&c, KvCacheType::HIF4);
        let mut b = KvCache::new(&c, KvCacheType::HIF4);
        a.fill_synthetic(10, 42);
        b.fill_synthetic(10, 42);
        assert_eq!(a.len(), 10);
        assert_eq!(a.resident_bytes(), b.resident_bytes());
        for layer in 0..c.n_layers {
            let da = a.layers[layer].k.dense(10);
            let db = b.layers[layer].k.dense(10);
            for r in 0..10 {
                assert_eq!(da.row(r), db.row(r), "layer {layer} row {r}");
            }
        }
        // Different seeds give different contents.
        let mut d = KvCache::new(&c, KvCacheType::HIF4);
        d.fill_synthetic(10, 43);
        let ra = a.layers[0].k.dense(10);
        let rd = d.layers[0].k.dense(10);
        assert_ne!(ra.row(0), rd.row(0));
        // And the f32 backend works too (used by replay-side bench runs).
        let mut f = KvCache::new(&c, KvCacheType::F32);
        f.fill_synthetic(5, 1);
        assert_eq!(f.len(), 5);
        assert!(f.k_tiles(0, 5, 2).is_none());
        assert!(a.k_tiles(0, 10, 4).is_some());
        assert!(a.v_tiles(1, 10, 4).is_some());
    }
}
