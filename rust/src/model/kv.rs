//! Per-sequence KV cache for incremental decode — the serving-side memory
//! layer that makes generation O(T) per token instead of O(T²).
//!
//! Two storage backends sit behind one [`KvCache`] (the [`KvCacheType`]
//! knob, `--kv-cache` / `HIF4_KV_CACHE` on the CLI):
//!
//! * **F32** — the reference: appended K/V rows are kept verbatim, so
//!   cached decode is *bit-identical* to the full-recompute forward.
//! * **Quant(kind)** — each appended row is encoded through the format
//!   codec of `kind` (any of the five block formats, grouped along the
//!   head dimension) and held as the decode-once integer lane planes of
//!   [`crate::dotprod::quant_tensor`]: the nibble/micro-exponent
//!   extraction is paid exactly once per cached value at append time, and
//!   attention reads straight from the planes (one multiply per lane).
//!   The resident plane costs 8 bits/value of lanes plus one amortized
//!   `f64` group scale vs 32 for f32 — and the canonical packed wire form
//!   ([`KvCache::wire_bytes`], `bits_per_value()` of the kind) is what a
//!   paged or offloaded cache would persist.
//!
//! # Paged storage
//!
//! Since the paged-KV subsystem ([`crate::model::pages`]), a store is not
//! one monolithic growable buffer but a chain of fixed-size
//! [`KvPage`]s: frozen full pages (immutable, `Arc`-shared when prefix
//! caching deduplicates a common prompt across sequences) plus one
//! private tail page that appends fill. Rows never straddle pages and a
//! quantized row is always whole plane groups, so the group-alignment
//! invariant — no 64-element group split across a page boundary — holds
//! for any page height. Standalone caches allocate pages privately; a
//! server wires every cache to one global [`PagePool`]
//! ([`KvCache::new_paged`]) for bounded, recycled, dedup-aware
//! allocation. Encoding is per-row and independent of page geometry, so
//! stored bytes — and therefore decode — are bit-identical for any
//! `page_rows`.
//!
//! Keys are cached **post-RoPE** (their rotation depends only on the
//! absolute position, which never changes once cached). The
//! quantize→decode round trip here is the *same code* the full-recompute
//! reference applies via [`qdq_rows`], so the greedy-decode parity suite
//! (`tests/decode_parity.rs`) can pin replay-attention
//! cached-vs-recompute equality down to the bit for every format.
//!
//! # Tiled plane access
//!
//! Long-context attention does not have to pay the dense per-call decode
//! of [`KvStore::dense`]: the fused path ([`crate::model::attention`])
//! walks the planes through [`KvTiles`] — a borrowed, zero-copy tile
//! view over a store's packed lanes and group scales — scoring `QK^T`
//! on the integer lanes directly and decoding only the `V` column span
//! it needs per tile. The iterator covers rows `0..rows` in order; a
//! tile spans up to `tile_rows` rows but never crosses a page boundary
//! (tiles clamp to the page, which is numerics-neutral: the online
//! softmax is bitwise invariant to tile height, DESIGN.md §14):
//!
//! ```
//! use hif4::model::kv::{KvCache, KvCacheType};
//! use hif4::model::zoo;
//!
//! // A quantized cache for one sequence, filled with 100 synthetic rows.
//! let cfg = zoo::llama2_tiny();
//! let mut cache = KvCache::new(&cfg, KvCacheType::HIF4);
//! cache.fill_synthetic(100, 7);
//!
//! // Walk layer 0's K planes in 48-row tiles. Pages are 64 rows by
//! // default, so the walk clamps at each boundary: 48 + 16 + 36.
//! let mut covered = 0;
//! for tile in cache.k_tiles(0, cache.len(), 48).expect("quantized caches tile") {
//!     assert_eq!(tile.start(), covered);
//!     covered += tile.rows();
//!     // Each tile row is one packed plane: an i8 lane per cached value
//!     // plus one f64 scale per group (lane index == column index)…
//!     assert_eq!(tile.row_lanes(0).len(), tile.groups_per_row() * tile.quant().group());
//!     assert_eq!(tile.row_scales(0).len(), tile.groups_per_row());
//!     // …and any column span decodes to f32 without touching the rest.
//!     let mut head = vec![0f32; tile.rows() * 16];
//!     tile.decode_cols(0..16, &mut head);
//! }
//! assert_eq!(covered, 100);
//! ```
//!
//! F32 stores have no planes to tile ([`KvCache::k_tiles`] returns
//! `None`), which is exactly the runtime signal the attention dispatcher
//! uses to fall back to replay.

use crate::dotprod::quant_tensor::decode_plane;
use crate::formats::QuantKind;
use crate::model::config::ModelConfig;
use crate::model::pages::{KvPage, PagePool, PageShape, PrefixHit, DEFAULT_PAGE_ROWS};
use crate::tensor::Matrix;
use std::sync::Arc;

/// Which storage backend a [`KvCache`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvCacheType {
    /// Dense f32 rows — bit-identical to full recompute.
    #[default]
    F32,
    /// Block-quantized rows encoded on append, held as decode-once lane
    /// planes (any [`QuantKind`]).
    Quant(QuantKind),
}

impl KvCacheType {
    /// The HiF4-quantized cache (the paper's configuration), spelled out
    /// since it is the default quantized choice everywhere.
    pub const HIF4: KvCacheType = KvCacheType::Quant(QuantKind::HiF4);

    /// Parse a CLI/env spelling through the single [`QuantKind`] parser:
    /// `f32`, or any format spelling (`hif4`, `nvfp4`, `mxfp4`, `mx4`,
    /// `bfp`), case-insensitive.
    pub fn parse(s: &str) -> Result<KvCacheType, String> {
        if s.eq_ignore_ascii_case("f32") {
            return Ok(KvCacheType::F32);
        }
        s.parse::<QuantKind>()
            .map(KvCacheType::Quant)
            .map_err(|e| format!("{e} (or f32 for the unquantized cache)"))
    }

    /// Canonical lower-case label (bench/JSON key); round-trips through
    /// [`KvCacheType::parse`].
    pub fn label(self) -> &'static str {
        match self {
            KvCacheType::F32 => "f32",
            KvCacheType::Quant(kind) => kind.spelling(),
        }
    }

    /// Resident bytes one appended row of width `kvd` costs in a store of
    /// this kind — the admission gate's KV-budget unit. Mirrors the
    /// actual store layout (f32 values; decode-once lane planes padded to
    /// whole groups plus one f64 scale per group for quantized kinds), so
    /// gate reservations and [`KvCache::resident_bytes`] agree exactly;
    /// the `resident_row_bytes_matches_store` test pins the equality for
    /// every kind.
    pub fn resident_row_bytes(self, kvd: usize) -> usize {
        match self {
            KvCacheType::F32 => kvd * std::mem::size_of::<f32>(),
            KvCacheType::Quant(kind) => {
                let group = kind.group();
                kvd.div_ceil(group)
                    * (group * std::mem::size_of::<i8>() + std::mem::size_of::<f64>())
            }
        }
    }
}

/// Per-sequence, per-layer K/V storage for incremental decode. The
/// continuous-batching scheduler owns one per active slot; its pages come
/// from the server's global [`PagePool`] (or private allocations for
/// standalone caches) and return there on drop/reset.
#[derive(Debug)]
pub struct KvCache {
    kind: KvCacheType,
    len: usize,
    page_rows: usize,
    pub(crate) layers: Vec<LayerKv>,
}

/// One layer's K and V stores.
#[derive(Debug)]
pub(crate) struct LayerKv {
    pub k: KvStore,
    pub v: KvStore,
}

/// Append-only row store for one tensor (K or V) of one layer: frozen
/// full pages (possibly shared via prefix dedup) + one private tail.
#[derive(Debug)]
pub(crate) struct KvStore {
    shape: PageShape,
    pool: Option<Arc<PagePool>>,
    /// Full pages in position order; page `p` holds rows
    /// `p·page_rows..(p+1)·page_rows`. Shared pages (refcount > 1) are
    /// only ever read.
    full: Vec<Arc<KvPage>>,
    /// The private page rows currently append into (`None` exactly when
    /// `rows` is a page multiple).
    tail: Option<KvPage>,
    /// Pool-less recycling: cleared pages parked across [`clear`] so a
    /// recycled standalone cache reuses its allocations.
    ///
    /// [`clear`]: KvStore::clear
    spare: Vec<KvPage>,
    rows: usize,
}

/// A dense f32 view of the first `rows` cached rows: f32 stores whose
/// span fits one page borrow in place, everything else copies/decodes
/// once per view.
pub(crate) struct KvDense<'a> {
    kvd: usize,
    data: DenseData<'a>,
}

enum DenseData<'a> {
    Borrowed(&'a [f32]),
    Owned(Vec<f32>),
}

impl KvDense<'_> {
    /// Row `r` as a kvd-wide slice.
    #[inline]
    pub(crate) fn row(&self, r: usize) -> &[f32] {
        let d = match &self.data {
            DenseData::Borrowed(s) => s,
            DenseData::Owned(v) => v.as_slice(),
        };
        &d[r * self.kvd..(r + 1) * self.kvd]
    }
}

/// Iterator over a quantized store's packed planes in row tiles — the
/// fused attention path's view of the KV cache (see the module docs for
/// a worked example). Yields [`KvTile`]s covering rows `0..rows` in
/// ascending order; every tile spans `tile_rows` rows except where a
/// page boundary (or the final tail) clamps it shorter. Borrowed and
/// zero-copy: no plane is decoded until a consumer asks via
/// [`KvTile::decode_cols`].
pub struct KvTiles<'a> {
    quant: QuantKind,
    kvd: usize,
    groups_per_row: usize,
    page_rows: usize,
    full: &'a [Arc<KvPage>],
    tail: Option<&'a KvPage>,
    rows: usize,
    tile_rows: usize,
    next: usize,
}

impl KvTiles<'_> {
    /// The format every tile's planes were encoded with.
    pub fn quant(&self) -> QuantKind {
        self.quant
    }

    /// Plane groups per row (`kvd` rounded up to whole groups) — the
    /// scratch-sizing constant consumers need before the first tile.
    pub fn groups_per_row(&self) -> usize {
        self.groups_per_row
    }
}

impl<'a> Iterator for KvTiles<'a> {
    type Item = KvTile<'a>;

    fn next(&mut self) -> Option<KvTile<'a>> {
        if self.next >= self.rows {
            return None;
        }
        let start = self.next;
        let pi = start / self.page_rows;
        let in_page = start % self.page_rows;
        // Clamp to the page: a tile reads one contiguous lane slice, and
        // pages are separate allocations. Numerics-neutral — the fused
        // kernel's online softmax is bitwise invariant to tile height.
        let rows = self.tile_rows.min(self.rows - start).min(self.page_rows - in_page);
        self.next += rows;
        let page: &'a KvPage = if pi < self.full.len() {
            &self.full[pi]
        } else {
            self.tail.expect("tiled rows beyond the stored pages")
        };
        let g = self.groups_per_row;
        let row_lanes = g * self.quant.group();
        Some(KvTile {
            quant: self.quant,
            kvd: self.kvd,
            groups_per_row: g,
            start,
            rows,
            lanes: &page.lanes()[in_page * row_lanes..(in_page + rows) * row_lanes],
            scales: &page.scales()[in_page * g..(in_page + rows) * g],
        })
    }
}

/// One tile of packed KV planes: `rows` consecutive cached positions
/// starting at absolute position [`KvTile::start`], borrowed straight
/// from one page of the store.
///
/// Layout contract (what the integer attention kernel scores against):
/// each tile-local row `r` owns `groups_per_row × group` i8 lanes
/// ([`KvTile::row_lanes`]) and `groups_per_row` f64 scales
/// ([`KvTile::row_scales`]); **lane index equals column index** within
/// the row (group `u` occupies lanes `u·group..(u+1)·group`, padding
/// beyond the row width `kvd` is zero lanes in the final group). A
/// column `c` therefore decodes as `scales[c / group] · lanes[c] /
/// LANE_UNIT`, which is what [`KvTile::decode_cols`] evaluates —
/// bit-identical to the dense whole-store decode.
pub struct KvTile<'a> {
    quant: QuantKind,
    kvd: usize,
    groups_per_row: usize,
    start: usize,
    rows: usize,
    lanes: &'a [i8],
    scales: &'a [f64],
}

impl KvTile<'_> {
    /// Absolute cache position of the tile's first row.
    pub fn start(&self) -> usize {
        self.start
    }

    /// Rows in this tile (`tile_rows`, except where a page boundary or
    /// the final tail clamps shorter).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// The format the planes were encoded with.
    pub fn quant(&self) -> QuantKind {
        self.quant
    }

    /// Plane groups per row (`kvd` rounded up to whole groups).
    pub fn groups_per_row(&self) -> usize {
        self.groups_per_row
    }

    /// Tile-local row `r`'s packed i8 lanes (`groups_per_row × group`
    /// long; lane index == column index, zero-padded past `kvd`).
    pub fn row_lanes(&self, r: usize) -> &[i8] {
        let w = self.groups_per_row * self.quant.group();
        &self.lanes[r * w..(r + 1) * w]
    }

    /// Tile-local row `r`'s per-group f64 scales (`groups_per_row` long).
    pub fn row_scales(&self, r: usize) -> &[f64] {
        &self.scales[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }

    /// Decode the column span `cols` of **every** tile row into `out`
    /// (row-major, `rows × cols.len()`), walking group boundaries so each
    /// value is `scale · lane / LANE_UNIT` — bit-identical to the same
    /// columns of [`KvStore::dense`]'s whole-row decode, since both run
    /// the per-element [`decode_plane`] kernel with the same scale. The
    /// fused attention path uses this for the `V` head slice only; `K`
    /// never decodes at all.
    pub fn decode_cols(&self, cols: std::ops::Range<usize>, out: &mut [f32]) {
        assert!(cols.end <= self.kvd, "column span exceeds row width");
        let w = cols.end - cols.start;
        assert_eq!(out.len(), self.rows * w, "decode_cols buffer must be rows × span");
        let group = self.quant.group();
        for r in 0..self.rows {
            let lanes = self.row_lanes(r);
            let scales = self.row_scales(r);
            let dst = &mut out[r * w..(r + 1) * w];
            let mut c = cols.start;
            while c < cols.end {
                let u = c / group;
                let stop = cols.end.min((u + 1) * group);
                let span = &mut dst[c - cols.start..stop - cols.start];
                decode_plane(self.quant, &lanes[c..stop], scales[u], span);
                c = stop;
            }
        }
    }
}

impl KvStore {
    fn new(kind: KvCacheType, kvd: usize) -> KvStore {
        KvStore::new_paged(PageShape::new(kind, kvd, DEFAULT_PAGE_ROWS), None)
    }

    fn new_paged(shape: PageShape, pool: Option<Arc<PagePool>>) -> KvStore {
        KvStore { shape, pool, full: Vec::new(), tail: None, spare: Vec::new(), rows: 0 }
    }

    /// One empty page: parked spare → pool → fresh private allocation.
    /// Pooled stores draw through [`PagePool::alloc_reserved`]: the
    /// admission gate reserves every stream's worst-case page count up
    /// front, so an exhausted pool here means shared-prefix pins crowded
    /// the cap — the pool mints a bounded overflow page rather than
    /// aborting an admitted stream mid-decode.
    fn fresh_page(&mut self) -> KvPage {
        if let Some(page) = self.spare.pop() {
            return page;
        }
        match &self.pool {
            Some(pool) => pool.alloc_reserved(),
            None => KvPage::empty(&self.shape),
        }
    }

    /// Append one position's row. Quantized stores encode it through the
    /// format codec (zero-padded tail group — the same uniform tail
    /// handling as the quantized matrices) and keep only the decode-once
    /// plane. A tail that fills freezes into an immutable full page
    /// immediately, so whole-chunk pages are sharable the moment their
    /// last row lands.
    pub(crate) fn append_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.shape.kvd, "KV row width must match kv_heads×head_dim");
        let mut tail = match self.tail.take() {
            Some(t) => t,
            None => self.fresh_page(),
        };
        tail.append_row(&self.shape, row);
        self.rows += 1;
        if tail.rows() == self.shape.page_rows {
            self.full.push(Arc::new(tail));
        } else {
            self.tail = Some(tail);
        }
    }

    /// Attach one shared full page (a prefix-cache hit): refcount bump,
    /// zero bytes copied. Only legal on a page-aligned, tail-less store.
    pub(crate) fn attach_full_page(&mut self, page: Arc<KvPage>) {
        debug_assert!(self.tail.is_none(), "attach after private appends");
        debug_assert_eq!(self.rows % self.shape.page_rows, 0);
        debug_assert_eq!(page.rows(), self.shape.page_rows, "only full pages are shared");
        self.rows += page.rows();
        self.full.push(page);
    }

    /// Copy-on-write attach at the divergence chunk: byte-copy the first
    /// `take` rows of the shared `src` page into a fresh private tail.
    pub(crate) fn attach_cow_page(&mut self, src: &KvPage, take: usize) {
        debug_assert!(self.tail.is_none(), "CoW attach after private appends");
        debug_assert!(take > 0 && take < self.shape.page_rows, "CoW is a partial chunk");
        let mut page = self.fresh_page();
        page.copy_prefix_from(&self.shape, src, take);
        self.rows += take;
        self.tail = Some(page);
    }

    /// Full page `p` for prefix registration (shared by `Arc::clone`).
    pub(crate) fn full_page(&self, p: usize) -> Arc<KvPage> {
        Arc::clone(&self.full[p])
    }

    fn page(&self, pi: usize) -> &KvPage {
        if pi < self.full.len() {
            &self.full[pi]
        } else {
            self.tail.as_ref().expect("page index beyond the stored pages")
        }
    }

    /// Dense view of rows `0..rows` (see [`KvDense`]).
    pub(crate) fn dense(&self, rows: usize) -> KvDense<'_> {
        assert!(rows <= self.rows, "cannot view rows that were never appended");
        let kvd = self.shape.kvd;
        match self.shape.kind {
            KvCacheType::F32 => {
                // Single-page spans borrow in place (the common short-
                // sequence case); page-crossing spans copy once.
                if rows == 0 {
                    return KvDense { kvd, data: DenseData::Borrowed(&[]) };
                }
                if (rows - 1) / self.shape.page_rows == 0 {
                    let d = &self.page(0).f32_data()[..rows * kvd];
                    return KvDense { kvd, data: DenseData::Borrowed(d) };
                }
                let mut out = Vec::with_capacity(rows * kvd);
                let mut r = 0;
                while r < rows {
                    let pi = r / self.shape.page_rows;
                    let take = (rows - r).min(self.shape.page_rows);
                    out.extend_from_slice(&self.page(pi).f32_data()[..take * kvd]);
                    r += take;
                }
                KvDense { kvd, data: DenseData::Owned(out) }
            }
            KvCacheType::Quant(quant) => {
                let group = quant.group();
                let gpr = self.shape.groups_per_row();
                let mut out = vec![0f32; rows * kvd];
                for r in 0..rows {
                    let page = self.page(r / self.shape.page_rows);
                    let lr = r % self.shape.page_rows;
                    let lanes = page.lanes();
                    let scales = page.scales();
                    let row = &mut out[r * kvd..(r + 1) * kvd];
                    for u in 0..gpr {
                        let start = u * group;
                        let end = (start + group).min(kvd);
                        let i = lr * gpr + u;
                        decode_plane(
                            quant,
                            &lanes[i * group..(i + 1) * group],
                            scales[i],
                            &mut row[start..end],
                        );
                    }
                }
                KvDense { kvd, data: DenseData::Owned(out) }
            }
        }
    }

    /// Tile the first `rows` stored rows into [`KvTiles`] of at most
    /// `tile_rows` each (page boundaries and the final tail clamp
    /// shorter). Quantized stores only — an f32 store has no packed
    /// planes to walk and returns `None`, which is the attention
    /// dispatcher's replay-fallback signal.
    pub(crate) fn tiles(&self, rows: usize, tile_rows: usize) -> Option<KvTiles<'_>> {
        assert!(tile_rows > 0, "tile_rows must be positive");
        assert!(rows <= self.rows, "cannot tile rows that were never appended");
        match self.shape.kind {
            KvCacheType::F32 => None,
            KvCacheType::Quant(quant) => Some(KvTiles {
                quant,
                kvd: self.shape.kvd,
                groups_per_row: self.shape.groups_per_row(),
                page_rows: self.shape.page_rows,
                full: &self.full,
                tail: self.tail.as_ref(),
                rows,
                tile_rows,
                next: 0,
            }),
        }
    }

    /// Row width this store was sized for (kv_heads × head_dim).
    pub(crate) fn kvd(&self) -> usize {
        self.shape.kvd
    }

    /// Positions stored so far (rows appended/attached since
    /// creation/[`clear`]).
    ///
    /// [`clear`]: KvStore::clear
    pub(crate) fn rows(&self) -> usize {
        self.rows
    }

    /// Drop every stored row, returning pages to their owner: pooled
    /// stores hand full pages back through [`PagePool::release`] (a
    /// shared page recycles only when its last holder lets go) and
    /// recycle the tail; standalone stores park cleared pages in the
    /// spare list so a recycled cache reuses its allocations. Byte
    /// accounting (stored length, never capacity) reports the emptied
    /// store as 0 immediately.
    fn clear(&mut self) {
        match &self.pool {
            Some(pool) => {
                for page in self.full.drain(..) {
                    pool.release(page);
                }
                if let Some(tail) = self.tail.take() {
                    pool.recycle(tail);
                }
                for page in self.spare.drain(..) {
                    pool.recycle(page);
                }
            }
            None => {
                for page in self.full.drain(..) {
                    // A standalone store's full pages are shared only if
                    // a prefix bundle still pins them; those drop here.
                    if let Ok(mut page) = Arc::try_unwrap(page) {
                        page.clear();
                        self.spare.push(page);
                    }
                }
                if let Some(mut tail) = self.tail.take() {
                    tail.clear();
                    self.spare.push(tail);
                }
            }
        }
        self.rows = 0;
    }

    /// Bytes of the rows actually stored (decode-once planes for
    /// quantized stores). Derived from the stored *length* — a recycled
    /// page's backing capacity, which can be much larger after
    /// reset/reuse churn, is reported by [`KvStore::capacity_bytes`]
    /// instead and never leaks into this number. Shared pages count in
    /// full for every holder (each sequence *reads* the whole page; the
    /// pool-level dedup savings are reported by
    /// [`PagePool::bytes_saved`]).
    fn resident_bytes(&self) -> usize {
        self.full.iter().map(|p| p.resident_bytes()).sum::<usize>()
            + self.tail.as_ref().map_or(0, |t| t.resident_bytes())
    }

    /// Bytes the backing allocations currently hold, stored or parked
    /// (`≥ resident_bytes` by construction).
    fn capacity_bytes(&self) -> usize {
        self.full.iter().map(|p| p.capacity_bytes()).sum::<usize>()
            + self.tail.as_ref().map_or(0, |t| t.capacity_bytes())
            + self.spare.iter().map(|p| p.capacity_bytes()).sum::<usize>()
    }

    /// Serialized bytes of the stored rows (canonical packed group wire
    /// layout for quantized stores; dense f32 for F32). Like
    /// [`KvStore::resident_bytes`], derived from the stored length only.
    fn wire_bytes(&self) -> usize {
        self.full.iter().map(|p| p.wire_bytes(&self.shape)).sum::<usize>()
            + self.tail.as_ref().map_or(0, |t| t.wire_bytes(&self.shape))
    }
}

impl Drop for KvStore {
    fn drop(&mut self) {
        // Pooled pages must flow back to the pool (free-list reuse and
        // exact live accounting); standalone pages just deallocate.
        if self.pool.is_some() {
            self.clear();
        }
    }
}

impl KvCache {
    /// Empty cache for one sequence under `cfg`'s geometry, with private
    /// page allocation at the default page height.
    pub fn new(cfg: &ModelConfig, kind: KvCacheType) -> KvCache {
        KvCache::new_paged(cfg, kind, DEFAULT_PAGE_ROWS, None)
    }

    /// Empty cache drawing `page_rows`-row pages from `pool` (or private
    /// allocations when `None`) — the serving path: every stream's cache
    /// on one server shares one bounded [`PagePool`].
    pub fn new_paged(
        cfg: &ModelConfig,
        kind: KvCacheType,
        page_rows: usize,
        pool: Option<Arc<PagePool>>,
    ) -> KvCache {
        let kvd = cfg.kv_heads() * cfg.head_dim;
        let shape = PageShape::new(kind, kvd, page_rows);
        if let Some(pool) = &pool {
            assert_eq!(*pool.shape(), shape, "cache geometry must match its pool");
        }
        let layers = (0..cfg.n_layers)
            .map(|_| LayerKv {
                k: KvStore::new_paged(shape, pool.clone()),
                v: KvStore::new_paged(shape, pool.clone()),
            })
            .collect();
        KvCache { kind, len: 0, page_rows, layers }
    }

    pub fn kind(&self) -> KvCacheType {
        self.kind
    }

    /// Number of positions cached so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Page height (rows per fixed-size page) this cache was built with.
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Bytes the cache keeps resident (decode-once planes for quantized
    /// kinds). Reported from the **stored length** — rows actually held —
    /// never from the backing allocation capacity, so the number stays
    /// exact through reset/reuse churn (`wire_bytes ≤ resident_bytes ≤
    /// capacity_bytes` always; pinned by the slot-reuse unit test).
    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.resident_bytes() + l.v.resident_bytes()).sum()
    }

    /// Bytes of the serialized form (the format's canonical packed group
    /// wire layout for quantized caches; same as resident for f32).
    /// Stored-length-derived like [`KvCache::resident_bytes`].
    pub fn wire_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.wire_bytes() + l.v.wire_bytes()).sum()
    }

    /// Bytes currently parked in the backing allocations — after
    /// [`KvCache::reset`] this exceeds [`KvCache::resident_bytes`] (the
    /// whole point of recycling: the allocation survives, the contents
    /// don't count).
    pub fn capacity_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.capacity_bytes() + l.v.capacity_bytes()).sum()
    }

    /// Reset for slot reuse: forget every stored row in every layer.
    /// Pooled pages return to the global pool; standalone pages park
    /// their allocations for the next tenant. The byte accounting
    /// reports the stored content only — an emptied page is 0 bytes
    /// resident/wire even while its capacity is still parked.
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.k.clear();
            l.v.clear();
        }
        self.len = 0;
    }

    /// Does this page carry `cfg`'s geometry under `kind` storage? The
    /// slot-reuse guard: recycled pages only re-attach to an engine whose
    /// model/cache configuration they were built for.
    pub fn fits(&self, cfg: &ModelConfig, kind: KvCacheType) -> bool {
        let kvd = cfg.kv_heads() * cfg.head_dim;
        self.kind == kind
            && self.layers.len() == cfg.n_layers
            && self.layers.iter().all(|l| l.k.kvd() == kvd && l.v.kvd() == kvd)
    }

    /// Attach a prefix-cache hit to this (empty) cache: shared full
    /// pages by refcount, plus a copy-on-write private copy of the
    /// partial divergence chunk. The hit's covered tokens are
    /// re-verified against `prompt` (exact compare, capped at
    /// `prompt.len() - 1` so the final token always prefills) — a stale
    /// or mismatched hit attaches only its verified prefix, never wrong
    /// rows. Returns the number of positions attached; the caller
    /// resumes prefill at that offset.
    pub fn attach_prefix(&mut self, hit: &PrefixHit, prompt: &[usize]) -> usize {
        assert!(self.is_empty(), "prefix attach must precede any append");
        if hit.page_rows != self.page_rows {
            return 0;
        }
        let stores = self.layers.len() * 2;
        if hit.bundles.iter().any(|b| b.len() != stores)
            || hit.cow.as_ref().is_some_and(|(b, _)| b.len() != stores)
        {
            return 0;
        }
        // Verified coverage: tokens the hit and the real prompt agree
        // on, leaving at least the final prompt token uncovered.
        let limit = prompt.len().saturating_sub(1);
        let common = hit
            .tokens
            .iter()
            .zip(prompt.iter())
            .take(limit)
            .take_while(|(a, b)| a == b)
            .count();
        let pr = self.page_rows;
        let whole = (common / pr).min(hit.chunks());
        for c in 0..whole {
            for (li, l) in self.layers.iter_mut().enumerate() {
                l.k.attach_full_page(Arc::clone(&hit.bundles[c][li * 2]));
                l.v.attach_full_page(Arc::clone(&hit.bundles[c][li * 2 + 1]));
            }
        }
        let mut attached = whole * pr;
        // Partial remainder: seed a CoW tail from the divergence chunk —
        // either the hit's explicit CoW bundle (when every whole chunk
        // matched) or the first unattached whole chunk (when the prompt
        // diverged earlier than the hit claimed).
        let take = common - attached;
        if take > 0 {
            let cow_src = if whole == hit.chunks() {
                hit.cow.as_ref().and_then(|(b, rows)| (take <= *rows).then_some(b))
            } else {
                Some(&hit.bundles[whole])
            };
            if let Some(bundle) = cow_src {
                for (li, l) in self.layers.iter_mut().enumerate() {
                    l.k.attach_cow_page(&bundle[li * 2], take);
                    l.v.attach_cow_page(&bundle[li * 2 + 1], take);
                }
                attached += take;
            }
        }
        self.len = attached;
        attached
    }

    /// The first `chunks` whole pages of every store, bundled per chunk
    /// for [`PagePool::register_prefix`] (bundle index = `layer·2 +
    /// {0: K, 1: V}`, matching [`KvCache::attach_prefix`]).
    pub fn prefix_bundles(&self, chunks: usize) -> Vec<Vec<Arc<KvPage>>> {
        (0..chunks)
            .map(|c| {
                self.layers
                    .iter()
                    .flat_map(|l| [l.k.full_page(c), l.v.full_page(c)])
                    .collect()
            })
            .collect()
    }

    /// Tile layer `layer`'s **K** planes over cached positions `0..rows`
    /// (see [`KvTiles`]; `None` for f32 caches). `rows` may be less than
    /// [`KvCache::len`] — attention scores a query at position `p`
    /// against rows `0..=p` only.
    pub fn k_tiles(&self, layer: usize, rows: usize, tile_rows: usize) -> Option<KvTiles<'_>> {
        self.layers[layer].k.tiles(rows, tile_rows)
    }

    /// Tile layer `layer`'s **V** planes (the `PV` side of
    /// [`KvCache::k_tiles`]).
    pub fn v_tiles(&self, layer: usize, rows: usize, tile_rows: usize) -> Option<KvTiles<'_>> {
        self.layers[layer].v.tiles(rows, tile_rows)
    }

    /// Append `rows` synthetic Gaussian K/V rows to every layer and
    /// advance the position count — a fixture for long-context benches
    /// and doctests that need a populated cache without paying an O(T²)
    /// model prefill. Deterministic in `seed`. The rows are *not* a real
    /// model's activations; use it only where both measured paths read
    /// the same cache (fused-vs-replay comparisons).
    pub fn fill_synthetic(&mut self, rows: usize, seed: u64) {
        let mut rng = crate::tensor::Rng::seed(seed);
        for l in &mut self.layers {
            let kvd = l.k.kvd();
            let k = Matrix::randn(rows, kvd, 1.0, &mut rng);
            let v = Matrix::randn(rows, kvd, 1.0, &mut rng);
            for r in 0..rows {
                l.k.append_row(k.row(r));
                l.v.append_row(v.row(r));
            }
        }
        self.advance(rows);
    }

    pub(crate) fn advance(&mut self, n: usize) {
        self.len += n;
        // Appends happen store-by-store before the position count moves;
        // once it does, every store must actually hold the rows it claims.
        debug_assert!(
            self.layers.iter().all(|l| l.k.rows() == self.len && l.v.rows() == self.len),
            "advance({n}) out of step with the appended rows"
        );
    }
}

/// Quantize→dequantize every row of `m` through the `kind` KV codec. Not
/// a reimplementation: the rows go through the *actual* cache store
/// ([`KvStore::append_row`] encode, [`KvStore::dense`] decode), so a
/// full-recompute forward with
/// [`super::transformer::QuantPolicy::kv`] set is a *bit-exact*
/// reference for quantized-cache incremental decode by construction — the
/// two paths cannot drift apart, for any format. (Row encoding is
/// independent of page geometry, so this pins the paged stores too.)
pub fn qdq_rows(kind: QuantKind, m: &mut Matrix) {
    let mut store = KvStore::new(KvCacheType::Quant(kind), m.cols);
    for r in 0..m.rows {
        store.append_row(m.row(r));
    }
    let dense = store.dense(m.rows);
    for r in 0..m.rows {
        m.row_mut(r).copy_from_slice(dense.row(r));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::hif4;
    use crate::tensor::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "kv-test".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 4,
            head_dim: 8,
            attention: crate::model::config::Attention::Gqa { kv_heads: 2 },
            ffn: crate::model::config::Ffn::SwiGlu,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    #[test]
    fn parse_and_label_roundtrip() {
        let mut kinds = vec![KvCacheType::F32];
        kinds.extend(QuantKind::ALL.map(KvCacheType::Quant));
        for kind in kinds {
            assert_eq!(KvCacheType::parse(kind.label()), Ok(kind));
        }
        assert_eq!(KvCacheType::parse("HIF4"), Ok(KvCacheType::HIF4));
        let err = KvCacheType::parse("bf16").unwrap_err();
        assert!(err.contains("f32") && err.contains("mxfp4"), "{err}");
    }

    #[test]
    fn f32_store_roundtrips_rows_exactly() {
        let c = cfg();
        let mut cache = KvCache::new(&c, KvCacheType::F32);
        let mut rng = Rng::seed(5);
        let rows = Matrix::randn(3, 16, 1.0, &mut rng);
        for r in 0..rows.rows {
            cache.layers[0].k.append_row(rows.row(r));
        }
        let dense = cache.layers[0].k.dense(3);
        for r in 0..rows.rows {
            assert_eq!(dense.row(r), rows.row(r));
        }
    }

    #[test]
    fn quant_store_matches_qdq_reference_bitwise_all_formats() {
        let c = cfg();
        let mut rng = Rng::seed(6);
        // 16-wide rows: a padded tail group for HiF4/MXFP4, exact fit for
        // the 16-element formats.
        let rows = Matrix::randn(4, 16, 0.7, &mut rng);
        for kind in QuantKind::ALL {
            let mut cache = KvCache::new(&c, KvCacheType::Quant(kind));
            for r in 0..rows.rows {
                cache.layers[1].v.append_row(rows.row(r));
            }
            let mut reference = rows.clone();
            qdq_rows(kind, &mut reference);
            let dense = cache.layers[1].v.dense(4);
            for r in 0..rows.rows {
                let got: Vec<u32> = dense.row(r).iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = reference.row(r).iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "{kind} row {r}");
            }
        }
    }

    #[test]
    fn paged_store_is_bitwise_identical_across_page_heights() {
        // Row encoding is per-row and page-independent: the same rows
        // stored under 4-row pages (crossing two boundaries) and under
        // the default single-page height must decode to identical bits —
        // the structural half of prefix-dedup parity.
        let c = cfg();
        let mut rng = Rng::seed(17);
        let rows = Matrix::randn(11, 16, 0.9, &mut rng);
        for kind in QuantKind::ALL.map(KvCacheType::Quant).into_iter().chain([KvCacheType::F32]) {
            let mut small = KvCache::new_paged(&c, kind, 4, None);
            let mut wide = KvCache::new(&c, kind);
            for cache in [&mut small, &mut wide] {
                for r in 0..rows.rows {
                    cache.layers[0].k.append_row(rows.row(r));
                }
            }
            let (ds, dw) = (small.layers[0].k.dense(11), wide.layers[0].k.dense(11));
            for r in 0..11 {
                let got: Vec<u32> = ds.row(r).iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = dw.row(r).iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "{} row {r}", kind.label());
            }
            assert_eq!(small.layers[0].k.resident_bytes(), wide.layers[0].k.resident_bytes());
            assert_eq!(small.layers[0].k.wire_bytes(), wide.layers[0].k.wire_bytes());
        }
    }

    #[test]
    fn quant_cache_is_smaller_resident_and_on_the_wire() {
        let c = cfg();
        let mut f32c = KvCache::new(&c, KvCacheType::F32);
        let mut hc = KvCache::new(&c, KvCacheType::HIF4);
        let mut rng = Rng::seed(7);
        let rows = Matrix::randn(8, 16, 1.0, &mut rng);
        for cache in [&mut f32c, &mut hc] {
            for layer in 0..2 {
                for r in 0..rows.rows {
                    cache.layers[layer].k.append_row(rows.row(r));
                    cache.layers[layer].v.append_row(rows.row(r));
                }
            }
            cache.advance(rows.rows);
        }
        assert_eq!(f32c.len(), 8);
        assert!(hc.resident_bytes() < f32c.resident_bytes());
        assert!(hc.wire_bytes() < hc.resident_bytes());
        // 16-wide rows pad to one 64-lane unit: 36 wire bytes vs 64 f32.
        assert_eq!(hc.wire_bytes(), 2 * 2 * 8 * hif4::HiF4Unit::WIRE_BYTES);
    }

    #[test]
    fn byte_accounting_is_exact_through_slot_reuse() {
        // The slot-reuse lifecycle: fill a page, reset it for the next
        // sequence, refill with fewer rows. Resident/wire bytes must
        // track the *stored* rows exactly at every step — a recycled
        // page's parked capacity (from the longer first tenant) must
        // never inflate them — and `wire ≤ resident ≤ capacity` holds
        // throughout.
        let c = cfg();
        let mut rng = Rng::seed(8);
        let mut cache = KvCache::new(&c, KvCacheType::HIF4);
        assert!(cache.fits(&c, KvCacheType::HIF4));
        assert!(!cache.fits(&c, KvCacheType::F32));
        // Exact per-row costs for this geometry: kvd = 16 pads to one
        // 64-lane HiF4 group → 64 lane bytes + 8 scale bytes resident,
        // 36 canonical wire bytes; 2 layers × (K + V) = 4 stores.
        let resident_per_pos = 4 * (64 + 8);
        let wire_per_pos = 4 * hif4::HiF4Unit::WIRE_BYTES;
        let fill = |cache: &mut KvCache, rows: &Matrix| {
            for layer in 0..2 {
                for r in 0..rows.rows {
                    cache.layers[layer].k.append_row(rows.row(r));
                    cache.layers[layer].v.append_row(rows.row(r));
                }
            }
            cache.advance(rows.rows);
        };
        let first = Matrix::randn(8, 16, 1.0, &mut rng);
        fill(&mut cache, &first);
        assert_eq!(cache.resident_bytes(), 8 * resident_per_pos);
        assert_eq!(cache.wire_bytes(), 8 * wire_per_pos);
        assert!(cache.wire_bytes() <= cache.resident_bytes());
        assert!(cache.resident_bytes() <= cache.capacity_bytes());

        // Evict + recycle: contents gone, allocation parked.
        cache.reset();
        assert_eq!(cache.len(), 0);
        assert!(cache.is_empty());
        assert_eq!(cache.resident_bytes(), 0, "an emptied page stores nothing");
        assert_eq!(cache.wire_bytes(), 0);
        assert!(cache.capacity_bytes() >= 8 * resident_per_pos, "allocation must survive reset");

        // Second, shorter tenant: counts reflect it exactly — reporting
        // from capacity would claim the old 8-row footprint.
        let second = Matrix::randn(3, 16, 1.0, &mut rng);
        fill(&mut cache, &second);
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.resident_bytes(), 3 * resident_per_pos);
        assert_eq!(cache.wire_bytes(), 3 * wire_per_pos);
        assert!(cache.wire_bytes() <= cache.resident_bytes());
        assert!(cache.resident_bytes() < cache.capacity_bytes());

        // And the recycled page still decodes correctly (same codec path
        // as a fresh store).
        let mut reference = second.clone();
        qdq_rows(QuantKind::HiF4, &mut reference);
        let dense = cache.layers[1].v.dense(3);
        for r in 0..3 {
            assert_eq!(dense.row(r), reference.row(r), "row {r}");
        }

        // The f32 backend holds the same invariants (wire == resident).
        let mut f32c = KvCache::new(&c, KvCacheType::F32);
        fill(&mut f32c, &first);
        assert_eq!(f32c.resident_bytes(), 8 * 4 * 16 * 4);
        assert_eq!(f32c.wire_bytes(), f32c.resident_bytes());
        f32c.reset();
        assert_eq!(f32c.resident_bytes(), 0);
        assert!(f32c.capacity_bytes() > 0);
    }

    #[test]
    fn resident_row_bytes_matches_store() {
        // The admission gate budgets KV pages with the static estimator;
        // if it ever drifted from what append_row actually stores, the
        // gate would over-admit (OOM risk) or under-admit (wasted
        // capacity). Pin exact agreement for every kind and both an
        // exact-fit and a padded-tail row width.
        let mut rng = Rng::seed(11);
        let mut kinds = vec![KvCacheType::F32];
        kinds.extend(QuantKind::ALL.map(KvCacheType::Quant));
        for kind in kinds {
            for kvd in [16usize, 24, 64] {
                let rows = Matrix::randn(5, kvd, 1.0, &mut rng);
                let mut store = KvStore::new(kind, kvd);
                for r in 0..rows.rows {
                    store.append_row(rows.row(r));
                }
                assert_eq!(
                    store.resident_bytes(),
                    5 * kind.resident_row_bytes(kvd),
                    "{} kvd={kvd}",
                    kind.label()
                );
            }
        }
    }

    #[test]
    fn tiles_cover_rows_in_order_with_tail() {
        // 11 rows in 4-row tiles (single 64-row page): 4 + 4 + 3 — every
        // row exactly once, starts ascending, and each tile's planes are
        // the same bytes the store holds for those rows.
        let mut rng = Rng::seed(20);
        for kind in QuantKind::ALL {
            let kvd = 24usize; // padded tail group for every format
            let rows = Matrix::randn(11, kvd, 1.0, &mut rng);
            let mut store = KvStore::new(KvCacheType::Quant(kind), kvd);
            for r in 0..rows.rows {
                store.append_row(rows.row(r));
            }
            let gpr = kvd.div_ceil(kind.group());
            let mut covered = 0usize;
            let mut sizes = Vec::new();
            for tile in store.tiles(11, 4).unwrap() {
                assert_eq!(tile.start(), covered, "{kind}");
                assert_eq!(tile.quant(), kind);
                assert_eq!(tile.groups_per_row(), gpr);
                for r in 0..tile.rows() {
                    assert_eq!(tile.row_lanes(r).len(), gpr * kind.group());
                    assert_eq!(tile.row_scales(r).len(), gpr);
                }
                covered += tile.rows();
                sizes.push(tile.rows());
            }
            assert_eq!(covered, 11, "{kind}");
            assert_eq!(sizes, vec![4, 4, 3], "{kind}");
            // Partial visibility: tiling fewer rows than stored stops early.
            let partial: usize = store.tiles(6, 4).unwrap().map(|t| t.rows()).sum();
            assert_eq!(partial, 6);
        }
        // F32 stores have nothing to tile — the replay-fallback signal.
        let store = KvStore::new(KvCacheType::F32, 16);
        assert!(store.tiles(0, 4).is_none());
    }

    #[test]
    fn tiles_clamp_at_page_boundaries_bitwise() {
        // An 11-row store under 4-row pages, walked with tile_rows 3:
        // tiles clamp at every page edge (3+1 | 3+1 | 3) yet decode the
        // exact same bits as the unclamped single-page walk.
        let mut rng = Rng::seed(22);
        for kind in QuantKind::ALL {
            let kvd = 24usize;
            let rows = Matrix::randn(11, kvd, 1.0, &mut rng);
            let shape = PageShape::new(KvCacheType::Quant(kind), kvd, 4);
            let mut store = KvStore::new_paged(shape, None);
            for r in 0..rows.rows {
                store.append_row(rows.row(r));
            }
            let sizes: Vec<usize> = store.tiles(11, 3).unwrap().map(|t| t.rows()).collect();
            assert_eq!(sizes, vec![3, 1, 3, 1, 3], "{kind}: clamp at rows 4 and 8");
            // Oversized tiles degrade to whole pages.
            let sizes: Vec<usize> = store.tiles(11, 48).unwrap().map(|t| t.rows()).collect();
            assert_eq!(sizes, vec![4, 4, 3], "{kind}");
            let dense = store.dense(11);
            let mut covered = 0usize;
            for tile in store.tiles(11, 3).unwrap() {
                assert_eq!(tile.start(), covered);
                let w = kvd;
                let mut out = vec![0f32; tile.rows() * w];
                tile.decode_cols(0..kvd, &mut out);
                for r in 0..tile.rows() {
                    let got: Vec<u32> =
                        out[r * w..(r + 1) * w].iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        dense.row(tile.start() + r).iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "{kind} row {}", tile.start() + r);
                }
                covered += tile.rows();
            }
            assert_eq!(covered, 11);
        }
    }

    #[test]
    fn decode_cols_is_bitwise_identical_to_dense() {
        // Any column span — group-aligned, group-crossing, or inside the
        // zero-padded tail group — must decode to exactly the bits the
        // whole-row dense view produces for those columns.
        let mut rng = Rng::seed(21);
        for kind in QuantKind::ALL {
            let kvd = 40usize;
            let rows = Matrix::randn(9, kvd, 0.8, &mut rng);
            let mut store = KvStore::new(KvCacheType::Quant(kind), kvd);
            for r in 0..rows.rows {
                store.append_row(rows.row(r));
            }
            let dense = store.dense(9);
            for span in [0..kvd, 0..16, 16..32, 12..29, 33..40] {
                let w = span.end - span.start;
                for tile in store.tiles(9, 4).unwrap() {
                    let mut out = vec![0f32; tile.rows() * w];
                    tile.decode_cols(span.clone(), &mut out);
                    for r in 0..tile.rows() {
                        let got: Vec<u32> =
                            out[r * w..(r + 1) * w].iter().map(|x| x.to_bits()).collect();
                        let want: Vec<u32> = dense.row(tile.start() + r)[span.clone()]
                            .iter()
                            .map(|x| x.to_bits())
                            .collect();
                        assert_eq!(got, want, "{kind} span {span:?} row {}", tile.start() + r);
                    }
                }
            }
        }
    }

    #[test]
    fn attach_prefix_shares_pages_bitwise_with_cow_isolation() {
        // Build a donor cache, bundle its whole chunks, attach them to a
        // fresh cache: shared rows decode to identical bits, the CoW
        // tail is private (appends never touch the donor), and a
        // mismatched prompt attaches only its verified prefix.
        let c = cfg();
        let tokens: Vec<usize> = (100..109).collect(); // 9 tokens, pr 4
        let mut donor = KvCache::new_paged(&c, KvCacheType::HIF4, 4, None);
        donor.fill_synthetic(9, 33); // rows 0..8 → 2 full chunks + tail
        let hit = PrefixHit {
            tokens: tokens[..8].to_vec(),
            bundles: donor.prefix_bundles(2),
            cow: None,
            page_rows: 4,
        };
        assert_eq!(hit.chunks(), 2);
        assert_eq!(hit.rows(), 8);

        // Full-hit attach: all 8 shared rows (prompt[8] stays uncovered).
        let mut taker = KvCache::new_paged(&c, KvCacheType::HIF4, 4, None);
        assert_eq!(taker.attach_prefix(&hit, &tokens), 8);
        assert_eq!(taker.len(), 8);
        for li in 0..c.n_layers {
            let (dd, dt) = (donor.layers[li].k.dense(8), taker.layers[li].k.dense(8));
            for r in 0..8 {
                assert_eq!(dd.row(r), dt.row(r), "layer {li} row {r}");
            }
        }
        assert!(taker.resident_bytes() > 0);

        // Divergence mid-chunk-2: 4 shared + 2 CoW rows; private appends
        // after the CoW must leave the donor's pages untouched.
        let mut fork_prompt = tokens[..6].to_vec();
        fork_prompt.extend([7usize, 8, 9]);
        let cow_hit = PrefixHit {
            tokens: tokens[..6].to_vec(),
            bundles: donor.prefix_bundles(1),
            cow: Some((donor.prefix_bundles(2).pop().unwrap(), 2)),
            page_rows: 4,
        };
        let mut forked = KvCache::new_paged(&c, KvCacheType::HIF4, 4, None);
        assert_eq!(forked.attach_prefix(&cow_hit, &fork_prompt), 6);
        let donor_before: Vec<u32> =
            donor.layers[0].k.dense(8).row(6).iter().map(|x| x.to_bits()).collect();
        let mut rng = Rng::seed(44);
        let private = Matrix::randn(1, 16, 1.0, &mut rng);
        for l in &mut forked.layers {
            l.k.append_row(private.row(0));
            l.v.append_row(private.row(0));
        }
        forked.advance(1);
        assert_eq!(forked.len(), 7);
        let donor_after: Vec<u32> =
            donor.layers[0].k.dense(8).row(6).iter().map(|x| x.to_bits()).collect();
        assert_eq!(donor_before, donor_after, "CoW must isolate private appends");
        // Shared prefix rows still bit-identical.
        let (dd, df) = (donor.layers[1].v.dense(6), forked.layers[1].v.dense(6));
        for r in 0..6 {
            assert_eq!(dd.row(r), df.row(r), "row {r}");
        }

        // A prompt that contradicts the hit's tokens attaches only the
        // verified prefix (here: one whole chunk + 1 CoW row from the
        // next bundle).
        let mut wrong = tokens[..5].to_vec();
        wrong.extend([1usize, 1, 1, 1]);
        let mut partial = KvCache::new_paged(&c, KvCacheType::HIF4, 4, None);
        assert_eq!(partial.attach_prefix(&hit, &wrong), 5);
        // Page-height mismatch refuses outright.
        let mut other = KvCache::new(&c, KvCacheType::HIF4);
        assert_eq!(other.attach_prefix(&hit, &tokens), 0);
    }

    #[test]
    fn pooled_cache_returns_pages_on_reset_and_drop() {
        let c = cfg();
        let shape = PageShape::new(KvCacheType::HIF4, 16, 4);
        let pool = Arc::new(PagePool::new(shape, 0, false));
        let mut cache = KvCache::new_paged(&c, KvCacheType::HIF4, 4, Some(Arc::clone(&pool)));
        cache.fill_synthetic(9, 55); // per store: 2 full + 1 tail page
        assert_eq!(pool.live_pages(), 4 * 3, "2 layers × (K+V) × 3 pages");
        cache.reset();
        assert_eq!(pool.live_pages(), 0, "reset returns every page");
        assert_eq!(pool.free_pages(), 12);
        cache.fill_synthetic(3, 56);
        assert_eq!(pool.live_pages(), 4);
        assert!(pool.freelist_hits() >= 4, "refill reuses recycled pages");
        drop(cache);
        assert_eq!(pool.live_pages(), 0, "drop returns every page");
    }

    #[test]
    fn fill_synthetic_populates_every_layer_deterministically() {
        let c = cfg();
        let mut a = KvCache::new(&c, KvCacheType::HIF4);
        let mut b = KvCache::new(&c, KvCacheType::HIF4);
        a.fill_synthetic(10, 42);
        b.fill_synthetic(10, 42);
        assert_eq!(a.len(), 10);
        assert_eq!(a.resident_bytes(), b.resident_bytes());
        for layer in 0..c.n_layers {
            let da = a.layers[layer].k.dense(10);
            let db = b.layers[layer].k.dense(10);
            for r in 0..10 {
                assert_eq!(da.row(r), db.row(r), "layer {layer} row {r}");
            }
        }
        // Different seeds give different contents.
        let mut d = KvCache::new(&c, KvCacheType::HIF4);
        d.fill_synthetic(10, 43);
        let ra = a.layers[0].k.dense(10);
        let rd = d.layers[0].k.dense(10);
        assert_ne!(ra.row(0), rd.row(0));
        // And the f32 backend works too (used by replay-side bench runs).
        let mut f = KvCache::new(&c, KvCacheType::F32);
        f.fill_synthetic(5, 1);
        assert_eq!(f.len(), 5);
        assert!(f.k_tiles(0, 5, 2).is_none());
        assert!(a.k_tiles(0, 10, 4).is_some());
        assert!(a.v_tiles(1, 10, 4).is_some());
    }
}
