//! Fused tiled attention over packed KV lane planes — the long-context
//! decode path.
//!
//! The replay path in [`super::transformer`] decodes every cached K/V
//! row back to f32 each step and re-runs the two-pass softmax; at 8k+
//! context that dense decode *is* the decode step. The fused path here
//! never materializes the cache: it walks the quantized store's planes
//! through [`super::kv::KvTiles`], scores `QK^T` with the exact integer
//! lane microkernels ([`crate::dotprod::quant_tensor::lane_dot`]),
//! streams the scores through an **online softmax** (running max /
//! denominator / output with rescaling corrections), and fuses the `PV`
//! product into the same pass — one tile of K/V in cache at a time,
//! flash-attention style.
//!
//! Numerics (the full contract is DESIGN.md §14):
//!
//! * Queries are quantized once per step to **8-bit absmax groups** on
//!   the K planes' group grid; `QK^T` partials are exact `i8·i8 → i32`
//!   integer dots, scaled in f64 in ascending group order. A given
//!   score is therefore **bit-identical for any tile size** and for the
//!   batched (`dot_1x4`) vs single (`dot`) microkernel shapes.
//! * The online-softmax state update is applied **per position**, not
//!   per tile, so the f32 operation sequence depends only on the score/
//!   value stream — logits are bit-invariant to `tile_rows` by
//!   construction (pinned by `tests/decode_parity.rs`).
//! * Against the replay path the result is *tolerance-bounded*, not
//!   bitwise: Q rounding and the reassociated accumulation differ — but
//!   greedy decode is token-identical (the parity suite's gate).
//!
//! Selection is the process-wide [`attn_path`] knob (`HIF4_ATTN` /
//! `--attn`, default [`AttnPath::Fused`]); f32 caches have no planes and
//! always replay, per sequence, at dispatch time.

use crate::dotprod::quant_tensor::{lane_dot, lane_dot_1x4, lane_unit, NR};
use crate::model::kv::{KvCacheType, LayerKv};
use crate::tensor::Matrix;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};

/// Which attention schedule the cached forward runs over quantized KV
/// pages. Purely a performance/precision-profile knob for greedy decode:
/// both paths emit the same greedy tokens (`tests/decode_parity.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnPath {
    /// Fused tiled attention on the packed lane planes (default for
    /// quantized caches): integer `QK^T`, online softmax, fused `PV`.
    Fused,
    /// Row-at-a-time replay: decode the cache dense, then the exact
    /// two-pass softmax — bit-identical to full recompute under the
    /// matching KV quantization policy, and the only path f32 caches
    /// can run.
    Replay,
}

impl AttnPath {
    /// Canonical lower-case label — the `HIF4_ATTN` / `--attn` spelling
    /// and the bench-JSON key.
    pub fn label(self) -> &'static str {
        match self {
            AttnPath::Fused => "fused",
            AttnPath::Replay => "replay",
        }
    }

    /// Parse the CLI/env spelling (`fused` / `replay`).
    pub fn parse(s: &str) -> Result<AttnPath, String> {
        match s {
            "fused" => Ok(AttnPath::Fused),
            "replay" => Ok(AttnPath::Replay),
            other => {
                Err(format!("unknown attention path {other:?} (expected \"fused\" or \"replay\")"))
            }
        }
    }
}

/// Process-wide attention-path override; 0 = not resolved yet.
static ATTN: AtomicU8 = AtomicU8::new(0);

const ATTN_FUSED: u8 = 1;
const ATTN_REPLAY: u8 = 2;

fn attn_from_tag(tag: u8) -> AttnPath {
    match tag {
        ATTN_REPLAY => AttnPath::Replay,
        _ => AttnPath::Fused,
    }
}

/// The process-wide attention path: `HIF4_ATTN` (`fused` / `replay`) if
/// set, else [`AttnPath::Fused`]; override with [`set_attn_path`] (the
/// CLI exposes `--attn`). Greedy tokens are identical either way, so
/// serving treats this as a throughput knob; tests that assert *logit*
/// bits never mutate it — they pass the path explicitly through
/// `forward_cached_with` instead, so concurrent tests cannot race.
pub fn attn_path() -> AttnPath {
    let tag = ATTN.load(Ordering::Relaxed);
    if tag != 0 {
        return attn_from_tag(tag);
    }
    let resolved = match std::env::var("HIF4_ATTN").ok().as_deref() {
        Some("replay") => ATTN_REPLAY,
        Some("fused") | None => ATTN_FUSED,
        Some(other) => {
            // A perf knob that silently ignores typos would corrupt
            // measurements; warn loudly (once — the resolution is cached)
            // and run the default. The CLI's `--attn` rejects outright.
            eprintln!(
                "warning: unrecognized HIF4_ATTN={other:?} \
                 (expected \"fused\" or \"replay\"); using fused"
            );
            ATTN_FUSED
        }
    };
    // Cache only if still unset so a racing set_attn_path() is never
    // clobbered (same pattern as dotprod::kernel).
    match ATTN.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => attn_from_tag(resolved),
        Err(current) => attn_from_tag(current),
    }
}

/// Override the process-wide attention path.
pub fn set_attn_path(p: AttnPath) {
    let v = match p {
        AttnPath::Fused => ATTN_FUSED,
        AttnPath::Replay => ATTN_REPLAY,
    };
    ATTN.store(v, Ordering::Relaxed);
}

/// The path a cache of `kind` actually runs when `requested` is asked
/// for: f32 caches have no packed planes to tile, so fused requests fall
/// back to [`AttnPath::Replay`] — per sequence, at dispatch time.
pub fn effective_attn_path(requested: AttnPath, kind: KvCacheType) -> AttnPath {
    match kind {
        KvCacheType::F32 => AttnPath::Replay,
        KvCacheType::Quant(_) => requested,
    }
}

/// Default KV tile height for the fused path — large enough to amortize
/// per-tile dispatch, small enough that a K+V tile of a tiny model stays
/// cache-resident.
pub const DEFAULT_ATTN_TILE_ROWS: usize = 128;

/// Fused-path tile height (rows of K/V per tile). Results are
/// **bit-invariant** to this value (see the module docs), so unlike the
/// path knob it is safe to flip anywhere, tests included.
static ATTN_TILE_ROWS: AtomicUsize = AtomicUsize::new(DEFAULT_ATTN_TILE_ROWS);

/// Current fused-path tile height.
pub fn attn_tile_rows() -> usize {
    ATTN_TILE_ROWS.load(Ordering::Relaxed)
}

/// Override the fused-path tile height (a pure performance knob).
pub fn set_attn_tile_rows(rows: usize) {
    assert!(rows > 0, "attention tile height must be positive");
    ATTN_TILE_ROWS.store(rows, Ordering::Relaxed);
}

/// One sequence's worth of fused-attention work: queries for the new
/// rows against the (already appended) cached K/V pages of one layer.
pub(crate) struct FusedAttnCall<'a> {
    /// The layer's K/V stores, with the new rows already appended.
    pub lkv: &'a LayerKv,
    /// Cached positions before this call's new rows.
    pub start: usize,
    /// New rows (queries) this call scores.
    pub t_new: usize,
    /// All projected + roped queries of the batch (`bt × heads·hd`).
    pub qr: &'a Matrix,
    /// First row of this sequence within `qr` / the context matrix.
    pub base: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub hd: usize,
    /// Score scale (`1/√hd`).
    pub scale: f32,
    /// KV tile height ([`attn_tile_rows`] at the call site).
    pub tile_rows: usize,
}

/// Run fused tiled attention for one sequence, writing each query's
/// context vector into its `ctx` row slice (rows must be zeroed).
/// Returns `false` without touching `ctx` when the stores carry no
/// packed planes (f32 cache) — the caller replays instead.
pub(crate) fn fused_attention_seq(call: &FusedAttnCall<'_>, ctx: &mut Matrix) -> bool {
    let c = call;
    let t_ctx = c.start + c.t_new;
    let k_tiles = match c.lkv.k.tiles(t_ctx, c.tile_rows) {
        Some(t) => t,
        None => return false,
    };
    let v_tiles = c.lkv.v.tiles(t_ctx, c.tile_rows).expect("K and V stores share a backend");
    let quant = k_tiles.quant();
    let group = quant.group();
    let gpr = k_tiles.groups_per_row();
    let gqa = c.heads / c.kv_heads;
    // 1/LANE_UNIT is a power of two: exact, so the K-side lane scaling
    // loses nothing.
    let inv_lu = 1.0 / lane_unit(quant);

    // Quantize the queries once: 8-bit absmax lanes on the K planes'
    // group grid. Each (row, head) owns a full gpr-group plane built
    // from a zeroed kvd-wide buffer with only its head span populated —
    // zero lanes mask out the other heads sharing a group (their
    // products contribute exactly 0 to the integer dot), and the
    // zero-padded tail mirrors the K planes' own padding.
    let mut q_lanes = vec![0i8; c.t_new * c.heads * gpr * group];
    let mut q_scales = vec![0f64; c.t_new * c.heads * gpr];
    let mut buf = vec![0f32; gpr * group];
    for i in 0..c.t_new {
        let qrow = c.qr.row(c.base + i);
        for h in 0..c.heads {
            let kvh = h / gqa;
            let (u_lo, u_hi) = head_groups(kvh, c.hd, group);
            buf.fill(0.0);
            buf[kvh * c.hd..(kvh + 1) * c.hd].copy_from_slice(&qrow[h * c.hd..(h + 1) * c.hd]);
            let qg = (i * c.heads + h) * gpr;
            for u in u_lo..=u_hi {
                q_scales[qg + u] = encode_q_group(
                    &buf[u * group..(u + 1) * group],
                    &mut q_lanes[(qg + u) * group..(qg + u + 1) * group],
                );
            }
        }
    }

    // Online-softmax state per (new row, head): running max, running
    // denominator; the running (unnormalized) output accumulates
    // directly in the caller's ctx row slices.
    let mut m = vec![f32::NEG_INFINITY; c.t_new * c.heads];
    let mut l = vec![0f32; c.t_new * c.heads];

    let mut vbuf: Vec<f32> = Vec::new();
    let mut sbuf: Vec<f32> = Vec::new();
    for (kt, vt) in k_tiles.zip(v_tiles) {
        debug_assert_eq!((kt.start(), kt.rows()), (vt.start(), vt.rows()));
        for kvh in 0..c.kv_heads {
            let (u_lo, u_hi) = head_groups(kvh, c.hd, group);
            // Decode this KV head's V column span once per tile; K never
            // decodes at all.
            vbuf.clear();
            vbuf.resize(kt.rows() * c.hd, 0.0);
            vt.decode_cols(kvh * c.hd..(kvh + 1) * c.hd, &mut vbuf);
            for h in kvh * gqa..(kvh + 1) * gqa {
                for i in 0..c.t_new {
                    let p = c.start + i;
                    if kt.start() > p {
                        // Tile is entirely in this query's future (later
                        // queries in the batch may still see it).
                        continue;
                    }
                    let n_vis = kt.rows().min(p + 1 - kt.start());
                    let qg = (i * c.heads + h) * gpr;
                    let qs = &q_scales[qg..qg + gpr];
                    // Integer QK^T over the visible tile rows: NR at a
                    // time through the register-reuse microkernel, then
                    // singles — each row's f64 scale walk is ascending-u
                    // and identical in both shapes, so a score never
                    // depends on where the tile boundary fell.
                    sbuf.clear();
                    sbuf.resize(n_vis, 0.0);
                    let mut r = 0usize;
                    while r + NR <= n_vis {
                        let mut acc = [0f64; NR];
                        for u in u_lo..=u_hi {
                            let qgl = &q_lanes[(qg + u) * group..(qg + u + 1) * group];
                            let span = u * group..(u + 1) * group;
                            let d = lane_dot_1x4(
                                qgl,
                                [
                                    &kt.row_lanes(r)[span.clone()],
                                    &kt.row_lanes(r + 1)[span.clone()],
                                    &kt.row_lanes(r + 2)[span.clone()],
                                    &kt.row_lanes(r + 3)[span],
                                ],
                            );
                            for (t, dt) in d.iter().enumerate() {
                                let ks = kt.row_scales(r + t)[u];
                                acc[t] += qs[u] * ks * inv_lu * (*dt as f64);
                            }
                        }
                        for (t, a) in acc.iter().enumerate() {
                            sbuf[r + t] = *a as f32 * c.scale;
                        }
                        r += NR;
                    }
                    while r < n_vis {
                        let mut acc = 0f64;
                        for u in u_lo..=u_hi {
                            let qgl = &q_lanes[(qg + u) * group..(qg + u + 1) * group];
                            let d = lane_dot(qgl, &kt.row_lanes(r)[u * group..(u + 1) * group]);
                            acc += qs[u] * kt.row_scales(r)[u] * inv_lu * (d as f64);
                        }
                        sbuf[r] = acc as f32 * c.scale;
                        r += 1;
                    }
                    // Stream the scored rows through the per-position
                    // online update, in ascending absolute position.
                    let st = i * c.heads + h;
                    let crow = &mut ctx.data[(c.base + i) * c.heads * c.hd + h * c.hd..][..c.hd];
                    for (r, &s) in sbuf.iter().enumerate() {
                        let vr = &vbuf[r * c.hd..(r + 1) * c.hd];
                        online_update(s, vr, &mut m[st], &mut l[st], crow);
                    }
                }
            }
        }
    }

    // Final normalization: context = o / l.
    for i in 0..c.t_new {
        for h in 0..c.heads {
            let inv = 1.0 / l[i * c.heads + h];
            let crow = &mut ctx.data[(c.base + i) * c.heads * c.hd + h * c.hd..][..c.hd];
            for x in crow {
                *x *= inv;
            }
        }
    }
    true
}

/// The plane groups (inclusive range) a KV head's column span
/// `[kvh·hd, (kvh+1)·hd)` intersects.
#[inline]
fn head_groups(kvh: usize, hd: usize, group: usize) -> (usize, usize) {
    (kvh * hd / group, ((kvh + 1) * hd - 1) / group)
}

/// Quantize one group-wide query span to 8-bit absmax lanes: `scale =
/// absmax/127`, `lane = round(x/scale)` (so `|lane| ≤ 127` exactly).
/// Returns the f64 scale; an all-zero (or non-finite) span encodes as
/// zero lanes with scale 0, contributing nothing to any dot.
fn encode_q_group(x: &[f32], lanes: &mut [i8]) -> f64 {
    let mut absmax = 0f32;
    for &v in x {
        absmax = absmax.max(v.abs());
    }
    if absmax == 0.0 || !absmax.is_finite() {
        lanes.fill(0);
        return 0.0;
    }
    let inv = 127.0 / absmax as f64;
    for (l, &v) in lanes.iter_mut().zip(x) {
        *l = (v as f64 * inv).round() as i8;
    }
    absmax as f64 / 127.0
}

/// One position's online-softmax step: fold score `s` and value row `v`
/// into the running (max `m`, denominator `l`, unnormalized output
/// `acc`) state. When `s` raises the max, the old state is rescaled by
/// `exp(m_old − s)` first; the very first position enters with
/// `m = −∞`, whose correction factor `exp(−∞) = 0` zeroes the empty
/// state exactly. The operation sequence depends only on the `(s, v)`
/// stream — never on how the stream was tiled.
#[inline]
fn online_update(s: f32, v: &[f32], m: &mut f32, l: &mut f32, acc: &mut [f32]) {
    if s > *m {
        let alpha = (*m - s).exp();
        *l *= alpha;
        for a in acc.iter_mut() {
            *a *= alpha;
        }
        *m = s;
    }
    let e = (s - *m).exp();
    *l += e;
    for (a, vv) in acc.iter_mut().zip(v) {
        *a += e * *vv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::QuantKind;
    use crate::model::config::{Attention, Ffn, ModelConfig};
    use crate::model::kv::KvCache;
    use crate::tensor::Rng;

    // NOTE: no test here (or anywhere) mutates the process-wide
    // attn-path knob — lib unit tests share one process, and several
    // assert logit *bits* through the knob-reading entry points. Tests
    // exercise paths via explicit arguments instead; only the CI
    // HIF4_ATTN matrix leg varies the knob, per process, from the
    // environment.

    fn cfg(attention: Attention) -> ModelConfig {
        ModelConfig {
            name: "attn-test".into(),
            vocab: 32,
            d_model: 64,
            n_layers: 1,
            n_heads: 4,
            head_dim: 16,
            attention,
            ffn: Ffn::SwiGlu,
            d_ff: 32,
            max_seq: 64,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    #[test]
    fn labels_parse_and_effective_path() {
        for p in [AttnPath::Fused, AttnPath::Replay] {
            assert_eq!(AttnPath::parse(p.label()), Ok(p));
        }
        let err = AttnPath::parse("flash").unwrap_err();
        assert!(err.contains("fused") && err.contains("replay"), "{err}");
        assert_eq!(
            effective_attn_path(AttnPath::Fused, KvCacheType::F32),
            AttnPath::Replay,
            "f32 caches have no planes to fuse over"
        );
        assert_eq!(effective_attn_path(AttnPath::Fused, KvCacheType::HIF4), AttnPath::Fused);
        assert_eq!(effective_attn_path(AttnPath::Replay, KvCacheType::HIF4), AttnPath::Replay);
        // The tile knob round-trips and rejects zero via assert — its
        // default matches the documented constant.
        assert_eq!(attn_tile_rows(), DEFAULT_ATTN_TILE_ROWS);
    }

    #[test]
    fn encode_q_group_is_half_step_accurate() {
        let mut rng = Rng::seed(31);
        let x = crate::tensor::Matrix::randn(1, 64, 1.5, &mut rng);
        let mut lanes = [0i8; 64];
        let s = encode_q_group(x.row(0), &mut lanes);
        assert!(s > 0.0);
        for (&v, &l) in x.row(0).iter().zip(&lanes) {
            assert!(l.unsigned_abs() <= 127);
            let err = (v as f64 - s * l as f64).abs();
            assert!(err <= s / 2.0 + 1e-12, "lane error {err} exceeds half a step {}", s / 2.0);
        }
        // All-zero spans: zero scale, zero lanes.
        let z = [0f32; 16];
        let mut zl = [7i8; 16];
        assert_eq!(encode_q_group(&z, &mut zl), 0.0);
        assert!(zl.iter().all(|&l| l == 0));
    }

    #[test]
    fn online_softmax_matches_two_pass_reference() {
        // The streaming update must agree with the classic two-pass
        // softmax-weighted sum to f32 roundoff, for any score order.
        let mut rng = Rng::seed(32);
        let n = 37;
        let scores = crate::tensor::Matrix::randn(1, n, 3.0, &mut rng);
        let vals = crate::tensor::Matrix::randn(n, 8, 1.0, &mut rng);
        let mut m = f32::NEG_INFINITY;
        let mut l = 0f32;
        let mut acc = [0f32; 8];
        for j in 0..n {
            online_update(scores.row(0)[j], vals.row(j), &mut m, &mut l, &mut acc);
        }
        let inv = 1.0 / l;
        let got: Vec<f32> = acc.iter().map(|a| a * inv).collect();
        // Two-pass reference.
        let maxs = scores.row(0).iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0f32;
        let weights: Vec<f32> = scores.row(0).iter().map(|s| (s - maxs).exp()).collect();
        for w in &weights {
            denom += w;
        }
        let mut want = [0f32; 8];
        for (j, w) in weights.iter().enumerate() {
            for (o, vv) in want.iter_mut().zip(vals.row(j)) {
                *o += (w / denom) * vv;
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-5 * (1.0 + w.abs()), "online {g} vs two-pass {w}");
        }
    }

    /// Replay-style reference attention for one sequence over dense
    /// (decoded) K/V — the same loop `Transformer::attention_cached`
    /// replays, minus the projections.
    fn reference_ctx(
        cache: &KvCache,
        qr: &Matrix,
        start: usize,
        heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Matrix {
        let t_new = qr.rows;
        let t_ctx = start + t_new;
        let gqa = heads / kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let kd = cache.layers[0].k.dense(t_ctx);
        let vd = cache.layers[0].v.dense(t_ctx);
        let mut ctx = Matrix::zeros(t_new, heads * hd);
        for h in 0..heads {
            let kvh = h / gqa;
            for i in 0..t_new {
                let p = start + i;
                let qi = &qr.row(i)[h * hd..(h + 1) * hd];
                let mut scores = vec![0f32; p + 1];
                let mut maxs = f32::NEG_INFINITY;
                for (j, sc) in scores.iter_mut().enumerate() {
                    let kj = &kd.row(j)[kvh * hd..(kvh + 1) * hd];
                    *sc = crate::tensor::gemm::dot(qi, kj) * scale;
                    maxs = maxs.max(*sc);
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxs).exp();
                    denom += *sc;
                }
                let crow = &mut ctx.data[i * heads * hd + h * hd..][..hd];
                for (j, w) in scores.iter().enumerate() {
                    let vj = &vd.row(j)[kvh * hd..(kvh + 1) * hd];
                    for (cc, vv) in crow.iter_mut().zip(vj) {
                        *cc += (w / denom) * *vv;
                    }
                }
            }
        }
        ctx
    }

    fn fused_ctx(
        cache: &KvCache,
        qr: &Matrix,
        start: usize,
        heads: usize,
        kv_heads: usize,
        hd: usize,
        tile_rows: usize,
    ) -> Matrix {
        let mut ctx = Matrix::zeros(qr.rows, heads * hd);
        let ok = fused_attention_seq(
            &FusedAttnCall {
                lkv: &cache.layers[0],
                start,
                t_new: qr.rows,
                qr,
                base: 0,
                heads,
                kv_heads,
                hd,
                scale: 1.0 / (hd as f32).sqrt(),
                tile_rows,
            },
            &mut ctx,
        );
        assert!(ok, "quantized caches must take the fused path");
        ctx
    }

    #[test]
    fn fused_matches_replay_reference_within_q_rounding_all_formats() {
        // 21 cached rows, 3 of them new queries — MHA and GQA, every
        // format. The fused path quantizes Q to 8 bits, so agreement
        // with the dense reference is tolerance-bounded, not bitwise;
        // the bound here is far above the analytic Q-rounding budget
        // (≈2⁻⁷ relative) and far below head-swapping territory.
        let mut rng = Rng::seed(33);
        for attention in [Attention::Mha, Attention::Gqa { kv_heads: 2 }] {
            let c = cfg(attention);
            let (heads, hd) = (c.n_heads, c.head_dim);
            let kv_heads = c.kv_heads();
            let kvd = kv_heads * hd;
            let (t_ctx, t_new) = (21, 3);
            let start = t_ctx - t_new;
            for kind in QuantKind::ALL {
                let mut cache = KvCache::new(&c, KvCacheType::Quant(kind));
                let krows = Matrix::randn(t_ctx, kvd, 0.9, &mut rng);
                let vrows = Matrix::randn(t_ctx, kvd, 0.9, &mut rng);
                for r in 0..t_ctx {
                    cache.layers[0].k.append_row(krows.row(r));
                    cache.layers[0].v.append_row(vrows.row(r));
                }
                let qr = Matrix::randn(t_new, heads * hd, 1.0, &mut rng);
                let fused = fused_ctx(&cache, &qr, start, heads, kv_heads, hd, 8);
                let want = reference_ctx(&cache, &qr, start, heads, kv_heads, hd);
                for (a, b) in fused.data.iter().zip(&want.data) {
                    assert!(
                        (a - b).abs() <= 2e-2 * (1.0 + b.abs()),
                        "{kind} {attention:?}: fused {a} vs replay {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_is_bitwise_invariant_to_tile_size() {
        // The per-position online update makes the f32 op sequence a
        // function of the (score, value) stream only — so any tile
        // height, including one that makes a single-row tail tile,
        // produces identical bits.
        let mut rng = Rng::seed(34);
        let c = cfg(Attention::Gqa { kv_heads: 2 });
        let (heads, hd) = (c.n_heads, c.head_dim);
        let kv_heads = c.kv_heads();
        let kvd = kv_heads * hd;
        let (t_ctx, t_new) = (29, 2);
        let start = t_ctx - t_new;
        let mut cache = KvCache::new(&c, KvCacheType::HIF4);
        let krows = Matrix::randn(t_ctx, kvd, 1.0, &mut rng);
        let vrows = Matrix::randn(t_ctx, kvd, 1.0, &mut rng);
        for r in 0..t_ctx {
            cache.layers[0].k.append_row(krows.row(r));
            cache.layers[0].v.append_row(vrows.row(r));
        }
        let qr = Matrix::randn(t_new, heads * hd, 1.0, &mut rng);
        let baseline = fused_ctx(&cache, &qr, start, heads, kv_heads, hd, 64);
        for tile_rows in [1, 3, 4, 7, 16, 29, 1000] {
            let got = fused_ctx(&cache, &qr, start, heads, kv_heads, hd, tile_rows);
            let gb: Vec<u32> = got.data.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = baseline.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "tile_rows={tile_rows} changed the logit bits");
        }
    }

    #[test]
    fn fused_refuses_f32_stores() {
        let c = cfg(Attention::Mha);
        let mut cache = KvCache::new(&c, KvCacheType::F32);
        cache.fill_synthetic(4, 9);
        let qr = Matrix::zeros(1, c.n_heads * c.head_dim);
        let mut ctx = Matrix::zeros(1, c.n_heads * c.head_dim);
        let ok = fused_attention_seq(
            &FusedAttnCall {
                lkv: &cache.layers[0],
                start: 3,
                t_new: 1,
                qr: &qr,
                base: 0,
                heads: c.n_heads,
                kv_heads: c.kv_heads(),
                hd: c.head_dim,
                scale: 1.0,
                tile_rows: 4,
            },
            &mut ctx,
        );
        assert!(!ok, "f32 caches must signal replay fallback");
        assert!(ctx.data.iter().all(|&x| x == 0.0), "fallback must not touch ctx");
    }
}
