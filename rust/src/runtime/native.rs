//! Native (PJRT-free) execution backend: rebuild the L2 transformer from a
//! [`Manifest`] + [`ParamStore`] and run it with the rust-native forward
//! pass — so the serving coordinator works, and the quantized serving path
//! exercises the real fixed-point QGEMM, even where no XLA runtime exists.
//!
//! The L2 model (`python/compile/model.py`) is a GQA + SwiGLU decoder with
//! flat parameter names (`embed`, `head`, `norm_f`, `layer{l}.wq` …); the
//! rust [`Transformer`] implements the same architecture with nested
//! weights, so this module is a pure renaming/reshaping bridge. Geometry
//! that shapes alone cannot recover (head split, RoPE base) comes from the
//! manifest's geometry keys (with `model.py CONFIG` defaults for older
//! manifests).
//!
//! For quantized serving, call
//! [`Transformer::prepack_quantized_weights`][prepack] on the result: the
//! weights become decode-once integer operand planes held across every
//! request — the serving-side payoff of the packed QGEMM layer.
//!
//! This module also hosts the [`DecodeEngine`] — the incremental-decode
//! executor the continuous-batching server loop drives: per-sequence
//! [`DecodeStream`]s carry a KV-cache page each ([`KvCacheType`] knob:
//! f32 or any block format encoded on append), and one
//! [`DecodeEngine::step`]
//! advances a mixed batch of prefilling and decoding sequences by one
//! greedy token through [`Transformer::forward_cached`]. Attention over
//! quantized pages follows the process-wide
//! [`attn_path`](crate::model::attention::attn_path) knob (`HIF4_ATTN`
//! / `--attn`, default fused — the tiled integer kernel over the packed
//! planes); f32 pages always replay. Greedy tokens are identical either
//! way, so the continuous-batching invariants below hold under both.
//!
//! [prepack]: crate::model::transformer::Transformer::prepack_quantized_weights

use crate::model::config::{Attention, Ffn, ModelConfig};
use crate::model::kv::{KvCache, KvCacheType};
use crate::model::transformer::{greedy_from_row, CachedSeq, Transformer};
use crate::runtime::artifact::{Manifest, ParamStore};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Incremental-decode executor: one shared read-only model + the KV-cache
/// policy, driving any number of per-sequence [`DecodeStream`]s.
pub struct DecodeEngine {
    model: Arc<Transformer>,
    kv: KvCacheType,
    max_prompt: usize,
}

/// One in-flight generation: the sanitized prompt, this sequence's
/// KV-cache page, and the next token to feed. Created by
/// [`DecodeEngine::start`], advanced one token per [`DecodeEngine::step`],
/// dropped (evicting the page) on completion.
pub struct DecodeStream {
    prompt: Vec<usize>,
    cache: KvCache,
    next: usize,
    generated: usize,
}

impl DecodeStream {
    /// Tokens generated so far.
    pub fn generated(&self) -> usize {
        self.generated
    }

    /// This sequence's cache page (for memory accounting).
    pub fn cache(&self) -> &KvCache {
        &self.cache
    }

    /// Surrender this stream's cache page for recycling: the serving
    /// loop parks released pages and hands them back to
    /// [`DecodeEngine::start_reusing`], so steady-state decode admits
    /// sequences without reallocating KV storage.
    pub fn into_cache(self) -> KvCache {
        self.cache
    }
}

impl DecodeEngine {
    /// `max_prompt` bounds the prompt length (requests truncate to it, as
    /// [`run_batch_native`][rbn] always did).
    ///
    /// [rbn]: crate::server::service::run_batch_native
    pub fn new(model: Arc<Transformer>, kv: KvCacheType, max_prompt: usize) -> DecodeEngine {
        DecodeEngine { model, kv, max_prompt: max_prompt.max(1) }
    }

    pub fn model(&self) -> &Transformer {
        &self.model
    }

    pub fn kv(&self) -> KvCacheType {
        self.kv
    }

    /// The prompt-length cap streams truncate to (the model context the
    /// admission gate validates against).
    pub fn max_prompt(&self) -> usize {
        self.max_prompt
    }

    /// Label of the attention schedule this engine's steps actually run
    /// (`"fused"` / `"replay"`): the process-wide knob resolved against
    /// the cache kind — an f32-cache engine reports `"replay"` whatever
    /// the knob says, since there are no packed planes to fuse over.
    /// Logged at server startup so a serving measurement is attributable.
    pub fn attn_label(&self) -> &'static str {
        crate::model::attention::effective_attn_path(crate::model::attention::attn_path(), self.kv)
            .label()
    }

    /// Worst-case resident KV bytes one cached position costs across all
    /// layers (K + V stores) under this engine's cache kind — the
    /// admission gate's per-token budget unit. Built on
    /// [`KvCacheType::resident_row_bytes`], which is pinned against the
    /// actual store layout, so `(prompt + max_new) × kv_bytes_per_token`
    /// is an exact upper bound on a stream's resident page size.
    pub fn kv_bytes_per_token(&self) -> usize {
        let cfg = &self.model.cfg;
        let kvd = cfg.kv_heads() * cfg.head_dim;
        cfg.n_layers * 2 * self.kv.resident_row_bytes(kvd)
    }

    /// Open a stream: clamp out-of-vocab ids to the last token, truncate
    /// to `max_prompt`, never empty — a malformed request can never panic
    /// the engine.
    pub fn start(&self, tokens: &[usize]) -> DecodeStream {
        self.start_reusing(tokens, None)
    }

    /// [`DecodeEngine::start`] with an optional recycled cache page: the
    /// page is reset (stored rows dropped, allocations kept) and reused,
    /// so admission after eviction churn skips the KV reallocation. A
    /// page from a different configuration (guarded by
    /// [`KvCache::fits`]) is dropped and a fresh one allocated —
    /// recycling can never change behavior, only allocation traffic;
    /// decode output is bit-identical either way (unit-tested below).
    pub fn start_reusing(&self, tokens: &[usize], page: Option<KvCache>) -> DecodeStream {
        let vocab = self.model.cfg.vocab;
        let mut prompt: Vec<usize> = tokens.iter().map(|&t| t.min(vocab - 1)).collect();
        prompt.truncate(self.max_prompt);
        if prompt.is_empty() {
            prompt.push(0);
        }
        let cache = match page {
            Some(mut page) if page.fits(&self.model.cfg, self.kv) => {
                page.reset();
                page
            }
            _ => KvCache::new(&self.model.cfg, self.kv),
        };
        DecodeStream { prompt, cache, next: 0, generated: 0 }
    }

    /// One continuous-batching step over a mixed batch: fresh streams
    /// prefill their whole prompt, in-flight streams feed their last
    /// token; every stream advances by one greedy token, returned as
    /// `(token, logprob)` in stream order. Per-stream results are
    /// **bit-identical regardless of batch composition** (row-independent
    /// linears, per-sequence attention — see
    /// [`Transformer::forward_cached`]), which is what makes scheduler
    /// output independent of arrival order.
    pub fn step(&self, streams: &mut [&mut DecodeStream]) -> Vec<(u32, f32)> {
        let mut seqs: Vec<CachedSeq<'_>> = Vec::with_capacity(streams.len());
        for s in streams.iter_mut() {
            let s: &mut DecodeStream = s;
            let feed: &[usize] = if s.cache.is_empty() {
                &s.prompt
            } else {
                std::slice::from_ref(&s.next)
            };
            seqs.push(CachedSeq { tokens: feed, cache: &mut s.cache });
        }
        // Last-row-only head readout: one logits row per stream.
        let logits = self.model.forward_cached_last(&mut seqs);
        drop(seqs);
        let mut out = Vec::with_capacity(streams.len());
        for (si, s) in streams.iter_mut().enumerate() {
            let (token, logprob) = greedy_from_row(logits.row(si));
            s.next = token;
            s.generated += 1;
            out.push((token as u32, logprob));
        }
        out
    }
}

/// Shape of a named manifest param.
fn shape<'a>(m: &'a Manifest, name: &str) -> Result<&'a [usize]> {
    m.params
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, d)| d.as_slice())
        .with_context(|| format!("manifest has no param {name}"))
}

/// Derive the rust-native [`ModelConfig`] equivalent of the lowered L2
/// model from manifest shapes + geometry keys.
pub fn config_from_manifest(m: &Manifest) -> Result<ModelConfig> {
    let embed = shape(m, "embed")?;
    anyhow::ensure!(embed.len() == 2, "embed must be 2-D");
    let (vocab, d_model) = (embed[0], embed[1]);
    anyhow::ensure!(vocab == m.vocab, "embed rows {} != manifest vocab {}", vocab, m.vocab);
    let mut n_layers = 0;
    while m.params.iter().any(|(n, _)| *n == format!("layer{n_layers}.wq")) {
        n_layers += 1;
    }
    anyhow::ensure!(n_layers > 0, "manifest has no layer0.wq — not a transformer manifest");
    let wq = shape(m, "layer0.wq")?;
    let wk = shape(m, "layer0.wk")?;
    anyhow::ensure!(wq.len() == 2 && wk.len() == 2, "wq/wk must be 2-D");
    anyhow::ensure!(
        wq[0] == m.n_heads * m.head_dim,
        "wq out dim {} != n_heads×head_dim {}×{}",
        wq[0],
        m.n_heads,
        m.head_dim
    );
    anyhow::ensure!(
        wk[0] == m.kv_heads * m.head_dim,
        "wk out dim {} != kv_heads×head_dim {}×{}",
        wk[0],
        m.kv_heads,
        m.head_dim
    );
    let w1 = shape(m, "layer0.w1")?;
    anyhow::ensure!(w1.len() == 2, "w1 must be 2-D");
    let d_ff = w1[0];
    let swiglu = m.params.iter().any(|(n, _)| n == "layer0.w3");
    Ok(ModelConfig {
        name: "l2-native".into(),
        vocab,
        d_model,
        n_layers,
        n_heads: m.n_heads,
        head_dim: m.head_dim,
        attention: if m.kv_heads == m.n_heads {
            Attention::Mha
        } else {
            Attention::Gqa { kv_heads: m.kv_heads }
        },
        ffn: if swiglu { Ffn::SwiGlu } else { Ffn::Gelu },
        d_ff,
        max_seq: m.seq,
        rope_base: m.rope_base,
        outlier_scale: 1.0,
        outlier_frac: 0.0,
    })
}

/// Build the rust-native transformer carrying the store's weights — the
/// exact parameters PJRT workers would receive as literals.
pub fn transformer_from_store(m: &Manifest, store: &ParamStore) -> Result<Transformer> {
    let cfg = config_from_manifest(m)?;
    let matrix = |name: &str| -> Result<crate::tensor::Matrix> {
        store.matrix(name).with_context(|| format!("store is missing 2-D param {name}"))
    };
    let gain = |name: &str| -> Result<Vec<f32>> {
        let (dims, data) =
            store.params.get(name).with_context(|| format!("store is missing param {name}"))?;
        anyhow::ensure!(dims.len() == 1, "{name} must be 1-D, got {dims:?}");
        Ok(data.clone())
    };
    let mut t = Transformer::init(cfg, 0);
    let take = |slot: &mut crate::tensor::Matrix, name: &str| -> Result<()> {
        let got = matrix(name)?;
        anyhow::ensure!(
            (got.rows, got.cols) == (slot.rows, slot.cols),
            "{name}: store shape {}x{} != model shape {}x{}",
            got.rows,
            got.cols,
            slot.rows,
            slot.cols
        );
        *slot = got;
        Ok(())
    };
    take(&mut t.w.embed, "embed")?;
    take(&mut t.w.head.w, "head")?;
    t.w.norm_f = gain("norm_f")?;
    for l in 0..t.cfg.n_layers {
        let p = |part: &str| format!("layer{l}.{part}");
        let layer = &mut t.w.layers[l];
        layer.norm1 = gain(&p("norm1"))?;
        layer.norm2 = gain(&p("norm2"))?;
        take(&mut layer.wq.w, &p("wq"))?;
        take(&mut layer.wk.w, &p("wk"))?;
        take(&mut layer.wv.w, &p("wv"))?;
        take(&mut layer.wo.w, &p("wo"))?;
        let ffn = &mut layer.ffn[0];
        take(&mut ffn.w1.w, &p("w1"))?;
        take(&mut ffn.w2.w, &p("w2"))?;
        if let Some(w3) = &mut ffn.w3 {
            take(&mut w3.w, &p("w3"))?;
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    /// A complete 1-layer GQA+SwiGLU manifest (d=32, 4 heads × 8, kv 2).
    /// Twin of the fixture in `tests/native_serving.rs` (integration
    /// tests can't reach a cfg(test) helper across the crate boundary) —
    /// keep the two in sync when changing the geometry.
    fn write_native_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "batch 4\nseq 16\nvocab 96\nn_heads 4\nkv_heads 2\nhead_dim 8\nrope_base 10000\n\
             qdq 8 64\n\
             param embed 96 32\nparam head 96 32\nparam norm_f 32\n\
             param layer0.norm1 32\nparam layer0.norm2 32\n\
             param layer0.wq 32 32\nparam layer0.wk 16 32\nparam layer0.wv 16 32\n\
             param layer0.wo 32 32\n\
             param layer0.w1 64 32\nparam layer0.w2 32 64\nparam layer0.w3 64 32\n",
        )
        .unwrap();
    }

    #[test]
    fn config_derivation_matches_manifest() {
        let dir = std::env::temp_dir().join("hif4_native_cfg_test");
        write_native_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let cfg = config_from_manifest(&m).unwrap();
        assert_eq!(cfg.vocab, 96);
        assert_eq!(cfg.d_model, 32);
        assert_eq!(cfg.n_layers, 1);
        assert_eq!(cfg.d_ff, 64);
        assert!(matches!(cfg.attention, Attention::Gqa { kv_heads: 2 }));
        assert!(matches!(cfg.ffn, Ffn::SwiGlu));
        assert_eq!(cfg.param_count(), m.param_elems());
    }

    #[test]
    fn recycled_cache_pages_decode_identically() {
        let dir = std::env::temp_dir().join("hif4_native_recycle_test");
        write_native_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let store = m.init_params(21);
        let model = Arc::new(transformer_from_store(&m, &store).unwrap());
        let engine = DecodeEngine::new(Arc::clone(&model), KvCacheType::HIF4, 16);
        // First tenant: a long sequence grows the page's allocations.
        let mut s1 = engine.start(&[1, 2, 3, 4, 5, 6, 7]);
        for _ in 0..6 {
            engine.step(&mut [&mut s1]);
        }
        let page = s1.into_cache();
        assert!(page.capacity_bytes() > 0);
        // Recycled vs fresh on a shorter prompt: bit-identical decode,
        // identical stored-length accounting, larger parked capacity.
        let prompt = [9usize, 4, 2];
        let mut recycled = engine.start_reusing(&prompt, Some(page));
        let mut fresh = engine.start(&prompt);
        assert_eq!(recycled.cache().resident_bytes(), 0, "reset page starts empty");
        for stepi in 0..4 {
            let a = engine.step(&mut [&mut recycled]);
            let b = engine.step(&mut [&mut fresh]);
            assert_eq!(a[0].0, b[0].0, "step {stepi} token");
            assert_eq!(a[0].1.to_bits(), b[0].1.to_bits(), "step {stepi} logprob");
        }
        assert_eq!(recycled.cache().resident_bytes(), fresh.cache().resident_bytes());
        assert_eq!(recycled.cache().wire_bytes(), fresh.cache().wire_bytes());
        assert!(recycled.cache().capacity_bytes() >= fresh.cache().capacity_bytes());
        // A page from a mismatched configuration is dropped, not misused.
        let f32_engine = DecodeEngine::new(model, KvCacheType::F32, 16);
        let s = f32_engine.start_reusing(&prompt, Some(recycled.into_cache()));
        assert_eq!(s.cache().kind(), KvCacheType::F32);
    }

    #[test]
    fn kv_bytes_per_token_matches_decoded_stream() {
        // The admission gate multiplies this estimator by (prompt +
        // max_new); it must equal the actual per-position resident cost
        // of a live stream for both cache backends.
        let dir = std::env::temp_dir().join("hif4_native_kvbytes_test");
        write_native_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let store = m.init_params(13);
        let model = Arc::new(transformer_from_store(&m, &store).unwrap());
        for kv in [KvCacheType::F32, KvCacheType::HIF4] {
            let engine = DecodeEngine::new(Arc::clone(&model), kv, 16);
            assert_eq!(engine.max_prompt(), 16);
            let per_token = engine.kv_bytes_per_token();
            // 1 layer, kvd = 2×8 = 16: f32 → 2×64 B; HiF4 (group 64,
            // padded) → 2×72 B.
            match kv {
                KvCacheType::F32 => assert_eq!(per_token, 2 * 16 * 4),
                _ => assert_eq!(per_token, 2 * (64 + 8)),
            }
            let mut s = engine.start(&[1, 2, 3]);
            for _ in 0..4 {
                engine.step(&mut [&mut s]);
            }
            // Prefill appended the 3 prompt rows, then 3 decode rows.
            assert_eq!(s.cache().len(), 6);
            assert_eq!(s.cache().resident_bytes(), 6 * per_token, "{}", kv.label());
        }
    }

    #[test]
    fn store_weights_reach_the_model() {
        let dir = std::env::temp_dir().join("hif4_native_store_test");
        write_native_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let store = m.init_params(7);
        let t = transformer_from_store(&m, &store).unwrap();
        assert_eq!(t.w.embed.data, store.params["embed"].1);
        assert_eq!(t.w.layers[0].wk.w.data, store.params["layer0.wk"].1);
        assert_eq!(t.w.norm_f, store.params["norm_f"].1);
        // And it actually runs.
        let logits = t.forward(&[vec![1, 2, 3]], None, None, None);
        assert_eq!((logits.rows, logits.cols), (3, 96));
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }
}
