//! Native (PJRT-free) execution backend: rebuild the L2 transformer from a
//! [`Manifest`] + [`ParamStore`] and run it with the rust-native forward
//! pass — so the serving coordinator works, and the quantized serving path
//! exercises the real fixed-point QGEMM, even where no XLA runtime exists.
//!
//! The L2 model (`python/compile/model.py`) is a GQA + SwiGLU decoder with
//! flat parameter names (`embed`, `head`, `norm_f`, `layer{l}.wq` …); the
//! rust [`Transformer`] implements the same architecture with nested
//! weights, so this module is a pure renaming/reshaping bridge. Geometry
//! that shapes alone cannot recover (head split, RoPE base) comes from the
//! manifest's geometry keys (with `model.py CONFIG` defaults for older
//! manifests).
//!
//! For quantized serving, call
//! [`Transformer::prepack_quantized_weights`][prepack] on the result: the
//! weights become decode-once integer operand planes held across every
//! request — the serving-side payoff of the packed QGEMM layer.
//!
//! This module also hosts the [`DecodeEngine`] — the incremental-decode
//! executor the continuous-batching server loop drives: per-sequence
//! [`DecodeStream`]s carry a paged KV cache each ([`KvCacheType`] knob:
//! f32 or any block format encoded on append; pages drawn from the
//! server's global [`PagePool`]), and one [`DecodeEngine::step`] advances
//! a mixed batch of prefilling and decoding sequences through
//! [`Transformer::forward_cached`]. Long prompts prefill in fixed-budget
//! **chunks** ([`DecodeEngine::with_prefill_chunk`]) interleaved with
//! other streams' decode steps — a step that only advanced a stream's
//! prefill yields `None` for it (no token frame); chunking is bit-exact
//! by the cached-forward contract (attention always reads the
//! quantize→decode store rows, append-then-attend), so the chunk size is
//! pure scheduling, never numerics. Prefix-cache hits attach shared pages
//! before prefill ([`DecodeEngine::start_with_prefix`]) and completed
//! prefills register their whole-page chunks for later sequences to
//! share. Attention over quantized pages follows the process-wide
//! [`attn_path`](crate::model::attention::attn_path) knob (`HIF4_ATTN` /
//! `--attn`, default fused — the tiled integer kernel over the packed
//! planes); f32 pages always replay. Greedy tokens are identical either
//! way, so the continuous-batching invariants below hold under both.
//!
//! [prepack]: crate::model::transformer::Transformer::prepack_quantized_weights

use crate::model::config::{Attention, Ffn, ModelConfig};
use crate::model::kv::{KvCache, KvCacheType};
use crate::model::pages::{PagePool, PrefixHit, DEFAULT_PAGE_ROWS};
use crate::model::transformer::{greedy_from_row, CachedSeq, Transformer};
use crate::runtime::artifact::{Manifest, ParamStore};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Incremental-decode executor: one shared read-only model + the KV-cache
/// policy (kind, page pool, prefill-chunk budget), driving any number of
/// per-sequence [`DecodeStream`]s.
pub struct DecodeEngine {
    model: Arc<Transformer>,
    kv: KvCacheType,
    max_prompt: usize,
    page_rows: usize,
    pool: Option<Arc<PagePool>>,
    prefill_chunk: usize,
}

/// One in-flight generation: the sanitized prompt, this sequence's paged
/// KV cache, the prefill frontier, and the next token to feed. Created by
/// [`DecodeEngine::start`] / [`DecodeEngine::start_with_prefix`], advanced
/// by [`DecodeEngine::step`], dropped (returning its pages to the pool) on
/// completion or eviction.
pub struct DecodeStream {
    prompt: Vec<usize>,
    cache: KvCache,
    /// Prompt positions already in the cache (attached prefix + fed
    /// chunks). The stream is prefilling while `fed < prompt.len()`.
    fed: usize,
    next: usize,
    generated: usize,
    registered: bool,
}

impl DecodeStream {
    /// Tokens generated so far.
    pub fn generated(&self) -> usize {
        self.generated
    }

    /// Still feeding prompt chunks (no token frames yet)?
    pub fn prefilling(&self) -> bool {
        self.fed < self.prompt.len()
    }

    /// This sequence's cache (for memory accounting).
    pub fn cache(&self) -> &KvCache {
        &self.cache
    }
}

impl DecodeEngine {
    /// `max_prompt` bounds the prompt length (requests truncate to it, as
    /// [`run_batch_native`][rbn] always did). The engine starts with
    /// private page allocation at the default page height and whole-prompt
    /// prefill; see [`DecodeEngine::with_pool`] and
    /// [`DecodeEngine::with_prefill_chunk`] for the serving configuration.
    ///
    /// [rbn]: crate::server::service::run_batch_native
    pub fn new(model: Arc<Transformer>, kv: KvCacheType, max_prompt: usize) -> DecodeEngine {
        DecodeEngine {
            model,
            kv,
            max_prompt: max_prompt.max(1),
            page_rows: DEFAULT_PAGE_ROWS,
            pool: None,
            prefill_chunk: 0,
        }
    }

    /// Draw every stream's pages from `pool` (the server's global,
    /// bounded, dedup-aware allocator). The pool's shape must match this
    /// engine's cache kind and geometry; the engine adopts its page
    /// height.
    pub fn with_pool(mut self, pool: Arc<PagePool>) -> DecodeEngine {
        let cfg = &self.model.cfg;
        assert_eq!(pool.shape().kind, self.kv, "pool kind must match the engine");
        assert_eq!(pool.shape().kvd, cfg.kv_heads() * cfg.head_dim, "pool kvd must match");
        self.page_rows = pool.page_rows();
        self.pool = Some(pool);
        self
    }

    /// Page height for pool-less engines (tests / standalone decode); a
    /// pooled engine takes its height from the pool.
    pub fn with_page_rows(mut self, page_rows: usize) -> DecodeEngine {
        assert!(self.pool.is_none(), "a pooled engine takes its page height from the pool");
        self.page_rows = page_rows.max(1);
        self
    }

    /// Prefill at most `chunk` prompt tokens per step (0 = whole prompt
    /// in one step, the pre-paging behavior). Bit-exact for any value;
    /// smaller chunks trade prefill latency for decode fairness under
    /// continuous batching.
    pub fn with_prefill_chunk(mut self, chunk: usize) -> DecodeEngine {
        self.prefill_chunk = chunk;
        self
    }

    pub fn model(&self) -> &Transformer {
        &self.model
    }

    pub fn kv(&self) -> KvCacheType {
        self.kv
    }

    /// The prompt-length cap streams truncate to (the model context the
    /// admission gate validates against).
    pub fn max_prompt(&self) -> usize {
        self.max_prompt
    }

    /// The global page pool, when serving-configured.
    pub fn pool(&self) -> Option<&Arc<PagePool>> {
        self.pool.as_ref()
    }

    /// Rows per KV page in this engine's caches.
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Per-step prefill token budget (0 = unchunked).
    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Label of the attention schedule this engine's steps actually run
    /// (`"fused"` / `"replay"`): the process-wide knob resolved against
    /// the cache kind — an f32-cache engine reports `"replay"` whatever
    /// the knob says, since there are no packed planes to fuse over.
    /// Logged at server startup so a serving measurement is attributable.
    pub fn attn_label(&self) -> &'static str {
        crate::model::attention::effective_attn_path(crate::model::attention::attn_path(), self.kv)
            .label()
    }

    /// Worst-case resident KV bytes one cached position costs across all
    /// layers (K + V stores) under this engine's cache kind. Built on
    /// [`KvCacheType::resident_row_bytes`], which is pinned against the
    /// actual store layout, so `(prompt + max_new) × kv_bytes_per_token`
    /// is an exact upper bound on a stream's resident cache size.
    pub fn kv_bytes_per_token(&self) -> usize {
        let cfg = &self.model.cfg;
        let kvd = cfg.kv_heads() * cfg.head_dim;
        cfg.n_layers * 2 * self.kv.resident_row_bytes(kvd)
    }

    /// Pages a stream holding `rows` cached positions needs from the
    /// pool, net of `shared_chunks` whole chunks it would attach from the
    /// prefix cache instead of allocating — the admission gate's
    /// dedup-aware reservation unit (`⌈rows / page_rows⌉` pages per
    /// store, 2 stores per layer).
    pub fn pages_for_rows(&self, rows: usize, shared_chunks: usize) -> usize {
        let per_store = rows.div_ceil(self.page_rows).saturating_sub(shared_chunks);
        per_store * self.model.cfg.n_layers * 2
    }

    /// The exact token sequence a request's stream will feed: clamp
    /// out-of-vocab ids to the last token, truncate to `max_prompt`,
    /// never empty — a malformed request can never panic the engine. The
    /// listener normalizes through this before a prefix-cache lookup so
    /// hit verification compares what decode will actually see.
    pub fn normalize_prompt(&self, tokens: &[usize]) -> Vec<usize> {
        let vocab = self.model.cfg.vocab;
        let mut prompt: Vec<usize> = tokens.iter().map(|&t| t.min(vocab - 1)).collect();
        prompt.truncate(self.max_prompt);
        if prompt.is_empty() {
            prompt.push(0);
        }
        prompt
    }

    /// Open a stream with a fresh (or pooled) cache and no shared prefix.
    pub fn start(&self, tokens: &[usize]) -> DecodeStream {
        self.start_with_prefix(tokens, None)
    }

    /// Open a stream, attaching a prefix-cache hit first when one is
    /// offered: shared whole pages by refcount plus a copy-on-write copy
    /// of the divergence chunk, so prefill resumes at the first uncovered
    /// position instead of position 0. The hit is re-verified
    /// token-by-token against the normalized prompt inside
    /// [`KvCache::attach_prefix`] — a stale hit degrades to a shorter
    /// attach (or none), never to wrong rows, and decode output is
    /// bit-identical with or without the hit.
    pub fn start_with_prefix(&self, tokens: &[usize], hit: Option<&PrefixHit>) -> DecodeStream {
        let prompt = self.normalize_prompt(tokens);
        let mut cache =
            KvCache::new_paged(&self.model.cfg, self.kv, self.page_rows, self.pool.clone());
        let mut fed = 0;
        if let Some(hit) = hit {
            fed = cache.attach_prefix(hit, &prompt);
            if fed > 0 {
                if let Some(pool) = &self.pool {
                    // Whole shared chunks only — the CoW tail is a private
                    // copy the stream allocated itself.
                    let shared = (fed / self.page_rows) * self.model.cfg.n_layers * 2;
                    pool.note_attach(shared, hit.max_refcount());
                }
            }
        }
        DecodeStream { prompt, cache, fed, next: 0, generated: 0, registered: false }
    }

    /// Register a freshly prefilled prompt's whole-page chunks in the
    /// pool's prefix index (idempotent per stream; no-op without a
    /// prefix-enabled pool or for prompts shorter than one page).
    fn maybe_register(&self, s: &mut DecodeStream) {
        if s.registered {
            return;
        }
        s.registered = true;
        let Some(pool) = &self.pool else { return };
        if !pool.prefix_enabled() {
            return;
        }
        let chunks = s.prompt.len() / self.page_rows;
        if chunks == 0 {
            return;
        }
        pool.register_prefix(&s.prompt[..chunks * self.page_rows], s.cache.prefix_bundles(chunks));
    }

    /// One continuous-batching step over a mixed batch: prefilling
    /// streams feed their next prompt chunk (all remaining tokens, or at
    /// most `prefill_chunk`), in-flight streams feed their last generated
    /// token. A stream whose prefill is still incomplete after this step
    /// yields `None` (its logits row belongs to a mid-prompt position —
    /// no token frame); every other stream advances by one greedy token,
    /// returned as `Some((token, logprob))` in stream order. Per-stream
    /// results are **bit-identical regardless of batch composition and
    /// chunking** (row-independent linears, per-sequence attention — see
    /// [`Transformer::forward_cached`]), which is what makes scheduler
    /// output independent of arrival order and prefill interleaving.
    pub fn step(&self, streams: &mut [&mut DecodeStream]) -> Vec<Option<(u32, f32)>> {
        let mut takes = Vec::with_capacity(streams.len());
        let mut seqs: Vec<CachedSeq<'_>> = Vec::with_capacity(streams.len());
        for s in streams.iter_mut() {
            let s: &mut DecodeStream = s;
            let feed: &[usize] = if s.fed < s.prompt.len() {
                let remaining = s.prompt.len() - s.fed;
                let take = match self.prefill_chunk {
                    0 => remaining,
                    chunk => chunk.min(remaining),
                };
                takes.push(take);
                &s.prompt[s.fed..s.fed + take]
            } else {
                takes.push(0);
                std::slice::from_ref(&s.next)
            };
            seqs.push(CachedSeq { tokens: feed, cache: &mut s.cache });
        }
        // Last-row-only head readout: one logits row per stream.
        let logits = self.model.forward_cached_last(&mut seqs);
        drop(seqs);
        let mut out = Vec::with_capacity(streams.len());
        for ((si, s), &take) in streams.iter_mut().enumerate().zip(&takes) {
            if take > 0 {
                s.fed += take;
                if s.fed < s.prompt.len() {
                    out.push(None);
                    continue;
                }
                // Prefill just completed: its whole pages are now frozen
                // and sharable, and this logits row is the first token.
                self.maybe_register(s);
            }
            let (token, logprob) = greedy_from_row(logits.row(si));
            s.next = token;
            s.generated += 1;
            out.push(Some((token as u32, logprob)));
        }
        out
    }
}

/// Shape of a named manifest param.
fn shape<'a>(m: &'a Manifest, name: &str) -> Result<&'a [usize]> {
    m.params
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, d)| d.as_slice())
        .with_context(|| format!("manifest has no param {name}"))
}

/// Derive the rust-native [`ModelConfig`] equivalent of the lowered L2
/// model from manifest shapes + geometry keys.
pub fn config_from_manifest(m: &Manifest) -> Result<ModelConfig> {
    let embed = shape(m, "embed")?;
    let &[vocab, d_model] = embed else {
        anyhow::bail!("embed must be 2-D, got {}-D", embed.len());
    };
    anyhow::ensure!(vocab == m.vocab, "embed rows {} != manifest vocab {}", vocab, m.vocab);
    let mut n_layers = 0;
    while m.params.iter().any(|(n, _)| *n == format!("layer{n_layers}.wq")) {
        n_layers += 1;
    }
    anyhow::ensure!(n_layers > 0, "manifest has no layer0.wq — not a transformer manifest");
    let wq = shape(m, "layer0.wq")?;
    let wk = shape(m, "layer0.wk")?;
    let (&[wq_out, _], &[wk_out, _]) = (wq, wk) else {
        anyhow::bail!("wq/wk must be 2-D, got {}-D/{}-D", wq.len(), wk.len());
    };
    anyhow::ensure!(
        wq_out == m.n_heads * m.head_dim,
        "wq out dim {} != n_heads×head_dim {}×{}",
        wq_out,
        m.n_heads,
        m.head_dim
    );
    anyhow::ensure!(
        wk_out == m.kv_heads * m.head_dim,
        "wk out dim {} != kv_heads×head_dim {}×{}",
        wk_out,
        m.kv_heads,
        m.head_dim
    );
    let w1 = shape(m, "layer0.w1")?;
    let &[d_ff, _] = w1 else {
        anyhow::bail!("w1 must be 2-D, got {}-D", w1.len());
    };
    let swiglu = m.params.iter().any(|(n, _)| n == "layer0.w3");
    Ok(ModelConfig {
        name: "l2-native".into(),
        vocab,
        d_model,
        n_layers,
        n_heads: m.n_heads,
        head_dim: m.head_dim,
        attention: if m.kv_heads == m.n_heads {
            Attention::Mha
        } else {
            Attention::Gqa { kv_heads: m.kv_heads }
        },
        ffn: if swiglu { Ffn::SwiGlu } else { Ffn::Gelu },
        d_ff,
        max_seq: m.seq,
        rope_base: m.rope_base,
        outlier_scale: 1.0,
        outlier_frac: 0.0,
    })
}

/// Build the rust-native transformer carrying the store's weights — the
/// exact parameters PJRT workers would receive as literals.
pub fn transformer_from_store(m: &Manifest, store: &ParamStore) -> Result<Transformer> {
    let cfg = config_from_manifest(m)?;
    let matrix = |name: &str| -> Result<crate::tensor::Matrix> {
        store.matrix(name).with_context(|| format!("store is missing 2-D param {name}"))
    };
    let gain = |name: &str| -> Result<Vec<f32>> {
        let (dims, data) =
            store.params.get(name).with_context(|| format!("store is missing param {name}"))?;
        anyhow::ensure!(dims.len() == 1, "{name} must be 1-D, got {dims:?}");
        Ok(data.clone())
    };
    let mut t = Transformer::init(cfg, 0);
    let take = |slot: &mut crate::tensor::Matrix, name: &str| -> Result<()> {
        let got = matrix(name)?;
        anyhow::ensure!(
            (got.rows, got.cols) == (slot.rows, slot.cols),
            "{name}: store shape {}x{} != model shape {}x{}",
            got.rows,
            got.cols,
            slot.rows,
            slot.cols
        );
        *slot = got;
        Ok(())
    };
    take(&mut t.w.embed, "embed")?;
    take(&mut t.w.head.w, "head")?;
    t.w.norm_f = gain("norm_f")?;
    for (l, layer) in t.w.layers.iter_mut().enumerate() {
        let p = |part: &str| format!("layer{l}.{part}");
        layer.norm1 = gain(&p("norm1"))?;
        layer.norm2 = gain(&p("norm2"))?;
        take(&mut layer.wq.w, &p("wq"))?;
        take(&mut layer.wk.w, &p("wk"))?;
        take(&mut layer.wv.w, &p("wv"))?;
        take(&mut layer.wo.w, &p("wo"))?;
        let ffn = layer.ffn.first_mut().context("transformer layer has no FFN block")?;
        take(&mut ffn.w1.w, &p("w1"))?;
        take(&mut ffn.w2.w, &p("w2"))?;
        if let Some(w3) = &mut ffn.w3 {
            take(&mut w3.w, &p("w3"))?;
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::pages::PageShape;
    use std::path::Path;

    /// A complete 1-layer GQA+SwiGLU manifest (d=32, 4 heads × 8, kv 2).
    /// Twin of the fixture in `tests/native_serving.rs` (integration
    /// tests can't reach a cfg(test) helper across the crate boundary) —
    /// keep the two in sync when changing the geometry.
    fn write_native_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "batch 4\nseq 16\nvocab 96\nn_heads 4\nkv_heads 2\nhead_dim 8\nrope_base 10000\n\
             qdq 8 64\n\
             param embed 96 32\nparam head 96 32\nparam norm_f 32\n\
             param layer0.norm1 32\nparam layer0.norm2 32\n\
             param layer0.wq 32 32\nparam layer0.wk 16 32\nparam layer0.wv 16 32\n\
             param layer0.wo 32 32\n\
             param layer0.w1 64 32\nparam layer0.w2 32 64\nparam layer0.w3 64 32\n",
        )
        .unwrap();
    }

    fn engine_from(dir: &Path, seed: u64, kv: KvCacheType) -> DecodeEngine {
        write_native_manifest(dir);
        let m = Manifest::load(dir).unwrap();
        let store = m.init_params(seed);
        let model = Arc::new(transformer_from_store(&m, &store).unwrap());
        DecodeEngine::new(model, kv, 16)
    }

    /// Run `prompt` to `n` generated tokens on a solo stream, collecting
    /// the emitted frames (prefill `None`s excluded).
    fn decode_n(engine: &DecodeEngine, prompt: &[usize], n: usize) -> Vec<(u32, f32)> {
        let mut s = engine.start(prompt);
        let mut out = Vec::new();
        while out.len() < n {
            if let Some(frame) = engine.step(&mut [&mut s])[0] {
                out.push(frame);
            }
        }
        out
    }

    #[test]
    fn config_derivation_matches_manifest() {
        let dir = std::env::temp_dir().join("hif4_native_cfg_test");
        write_native_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let cfg = config_from_manifest(&m).unwrap();
        assert_eq!(cfg.vocab, 96);
        assert_eq!(cfg.d_model, 32);
        assert_eq!(cfg.n_layers, 1);
        assert_eq!(cfg.d_ff, 64);
        assert!(matches!(cfg.attention, Attention::Gqa { kv_heads: 2 }));
        assert!(matches!(cfg.ffn, Ffn::SwiGlu));
        assert_eq!(cfg.param_count(), m.param_elems());
    }

    #[test]
    fn pooled_pages_recycle_through_the_free_list_bit_identically() {
        // The global allocator replaces the old per-worker spare-page
        // pool: a completed stream's pages return to the pool's free
        // list, the next stream reuses those exact allocations, and its
        // decode is bit-identical to a pool-less engine's.
        let dir = std::env::temp_dir().join("hif4_native_recycle_test");
        let private = engine_from(&dir, 21, KvCacheType::HIF4).with_page_rows(4);
        let m = Manifest::load(&dir).unwrap();
        let store = m.init_params(21);
        let model = Arc::new(transformer_from_store(&m, &store).unwrap());
        let shape = PageShape::new(KvCacheType::HIF4, 16, 4);
        let pool = Arc::new(PagePool::new(shape, 0, false));
        let pooled =
            DecodeEngine::new(model, KvCacheType::HIF4, 16).with_pool(Arc::clone(&pool));
        assert_eq!(pooled.page_rows(), 4);
        // First tenant grows the pool; dropping it returns every page.
        let reference = decode_n(&private, &[1, 2, 3, 4, 5, 6, 7], 6);
        let first = decode_n(&pooled, &[1, 2, 3, 4, 5, 6, 7], 6);
        assert_eq!(first, reference, "pooled == private, bitwise");
        assert_eq!(pool.live_pages(), 0, "completed stream returned its pages");
        let parked = pool.free_pages();
        assert!(parked > 0);
        // Second tenant: same tokens, recycled allocations, free-list hits.
        let second = decode_n(&pooled, &[1, 2, 3, 4, 5, 6, 7], 6);
        assert_eq!(second, reference, "recycled pages decode identically");
        assert_eq!(pool.free_pages(), parked);
        assert!(pool.freelist_hits() > 0, "reuse went through the free list");
        assert_eq!(pool.high_water(), parked, "no growth on the second tenant");
    }

    #[test]
    fn chunked_prefill_is_bitwise_identical_to_whole_prompt() {
        // Chunk size is scheduling, not numerics: every chunk budget
        // produces the same frames, and mid-prefill steps emit None.
        let dir = std::env::temp_dir().join("hif4_native_chunk_test");
        for kv in [KvCacheType::F32, KvCacheType::HIF4] {
            let whole = engine_from(&dir, 21, kv);
            let prompt = [5usize, 9, 2, 7, 7, 3, 1];
            let reference = decode_n(&whole, &prompt, 5);
            for chunk in [1usize, 2, 3, 5, 64] {
                let chunked = engine_from(&dir, 21, kv).with_prefill_chunk(chunk);
                let mut s = chunked.start(&prompt);
                let mut frames = Vec::new();
                let mut silent = 0;
                while frames.len() < 5 {
                    match chunked.step(&mut [&mut s])[0] {
                        Some(f) => frames.push(f),
                        None => silent += 1,
                    }
                }
                assert_eq!(frames, reference, "{} chunk={chunk}", kv.label());
                // 7 prompt tokens at chunk c: ⌈7/c⌉ steps, all but the
                // last silent.
                assert_eq!(silent, 7usize.div_ceil(chunk) - 1, "{} chunk={chunk}", kv.label());
                assert_eq!(s.generated(), 5);
            }
        }
    }

    #[test]
    fn prefix_hit_attaches_shared_pages_and_decodes_identically() {
        // A prefilled prompt registers its whole-page chunks; a second
        // stream with the same prompt attaches them (allocating only the
        // suffix), a diverging stream forks CoW mid-chunk — and both
        // decode bit-identically to a cold engine without any sharing.
        let dir = std::env::temp_dir().join("hif4_native_prefix_test");
        for kv in [KvCacheType::F32, KvCacheType::HIF4] {
            let cold = engine_from(&dir, 21, kv).with_page_rows(4);
            let m = Manifest::load(&dir).unwrap();
            let store = m.init_params(21);
            let model = Arc::new(transformer_from_store(&m, &store).unwrap());
            let shape = PageShape::new(kv, 16, 4);
            let pool = Arc::new(PagePool::new(shape, 0, true));
            let warm = DecodeEngine::new(model, kv, 16).with_pool(Arc::clone(&pool));
            let prompt: Vec<usize> = vec![5, 9, 2, 7, 7, 3, 1, 8, 4]; // 9 tokens → 2 chunks + 1
            // Donor prefill registers chunks (and keeps them alive in the
            // trie after the stream drops).
            let donor_frames = decode_n(&warm, &prompt, 3);
            assert_eq!(donor_frames, decode_n(&cold, &prompt, 3), "{}", kv.label());
            assert!(pool.prefix_nodes() > 0, "donor registered its chunks");
            let donor_live = pool.live_pages();
            assert!(donor_live > 0, "registered pages stay resident");

            // Same prompt again: 2 whole chunks attach shared (8 of 9
            // positions), only the suffix allocates.
            let hit = pool.lookup_prefix(&prompt).expect("identical prompt must hit");
            assert_eq!(hit.rows(), 8, "{}: covers all but the final token", kv.label());
            let mut s = warm.start_with_prefix(&prompt, Some(&hit));
            assert_eq!(s.cache().len(), 8);
            let mut frames = Vec::new();
            while frames.len() < 3 {
                if let Some(f) = warm.step(&mut [&mut s])[0] {
                    frames.push(f);
                }
            }
            assert_eq!(frames, donor_frames, "{}: shared-page decode is bitwise", kv.label());
            assert!(pool.bytes_saved() > 0, "dedup accounting observed the attach");
            assert!(pool.shared_refcount_high_water() >= 2);

            // Divergence inside chunk 2: 1 shared chunk + CoW rows, still
            // bit-identical to a cold run of the forked prompt.
            let mut forked: Vec<usize> = prompt[..6].to_vec();
            forked.extend([2usize, 2, 6]);
            let fhit = pool.lookup_prefix(&forked).expect("shared 6-token prefix must hit");
            assert_eq!(fhit.chunks(), 1);
            assert!(fhit.cow.is_some(), "divergence mid-chunk forks CoW");
            let mut f = warm.start_with_prefix(&forked, Some(&fhit));
            assert_eq!(f.cache().len(), 6);
            let mut fframes = Vec::new();
            while fframes.len() < 3 {
                if let Some(fr) = warm.step(&mut [&mut f])[0] {
                    fframes.push(fr);
                }
            }
            assert_eq!(fframes, decode_n(&cold, &forked, 3), "{}: CoW fork is bitwise", kv.label());
        }
    }

    #[test]
    fn pages_for_rows_is_the_gate_reservation_unit() {
        let dir = std::env::temp_dir().join("hif4_native_pagecount_test");
        let engine = engine_from(&dir, 13, KvCacheType::HIF4).with_page_rows(4);
        // 1 layer → 2 stores; 9 rows → 3 pages/store.
        assert_eq!(engine.pages_for_rows(9, 0), 6);
        assert_eq!(engine.pages_for_rows(8, 0), 4);
        assert_eq!(engine.pages_for_rows(1, 0), 2);
        assert_eq!(engine.pages_for_rows(0, 0), 0);
        // A 2-chunk prefix hit reserves only the suffix pages.
        assert_eq!(engine.pages_for_rows(9, 2), 2);
        assert_eq!(engine.pages_for_rows(8, 2), 0);
    }

    #[test]
    fn kv_bytes_per_token_matches_decoded_stream() {
        // The admission gate's byte accounting rides on this estimator;
        // it must equal the actual per-position resident cost of a live
        // stream for both cache backends.
        let dir = std::env::temp_dir().join("hif4_native_kvbytes_test");
        for kv in [KvCacheType::F32, KvCacheType::HIF4] {
            let engine = engine_from(&dir, 13, kv);
            assert_eq!(engine.max_prompt(), 16);
            let per_token = engine.kv_bytes_per_token();
            // 1 layer, kvd = 2×8 = 16: f32 → 2×64 B; HiF4 (group 64,
            // padded) → 2×72 B.
            match kv {
                KvCacheType::F32 => assert_eq!(per_token, 2 * 16 * 4),
                _ => assert_eq!(per_token, 2 * (64 + 8)),
            }
            let mut s = engine.start(&[1, 2, 3]);
            for _ in 0..4 {
                engine.step(&mut [&mut s]);
            }
            // Prefill appended the 3 prompt rows, then 3 decode rows.
            assert_eq!(s.cache().len(), 6);
            assert_eq!(s.cache().resident_bytes(), 6 * per_token, "{}", kv.label());
        }
    }

    #[test]
    fn store_weights_reach_the_model() {
        let dir = std::env::temp_dir().join("hif4_native_store_test");
        write_native_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let store = m.init_params(7);
        let t = transformer_from_store(&m, &store).unwrap();
        assert_eq!(t.w.embed.data, store.params["embed"].1);
        assert_eq!(t.w.layers[0].wk.w.data, store.params["layer0.wk"].1);
        assert_eq!(t.w.norm_f, store.params["norm_f"].1);
        // And it actually runs.
        let logits = t.forward(&[vec![1, 2, 3]], None, None, None);
        assert_eq!((logits.rows, logits.cols), (3, 96));
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }
}
