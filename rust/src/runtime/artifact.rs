//! Artifact manifest: the parameter order/shapes and entry-point dims that
//! `python/compile/aot.py` records next to the HLO files.
//!
//! Parsed from `manifest.txt` (a flat `key value...` format emitted
//! alongside `manifest.json`; the offline image has no JSON crate and a
//! hand-rolled parser for a format we also control would be redundancy,
//! not robustness).

use crate::tensor::{Matrix, Rng};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// Parameter (name, shape) in artifact input order.
    pub params: Vec<(String, Vec<usize>)>,
    /// Standalone qdq entry dims.
    pub qdq_rows: usize,
    pub qdq_cols: usize,
    /// Attention geometry of the lowered model, used by the native
    /// (PJRT-free) backend to rebuild the transformer
    /// ([`crate::runtime::native`]). Optional in older manifests; defaults
    /// mirror `python/compile/model.py`'s `CONFIG`.
    pub n_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub rope_base: f32,
    /// Default serving format for `serve --native` when `--format` is not
    /// given (the optional `format <spelling>` manifest key, parsed by the
    /// single [`crate::formats::QuantKind`] parser; absent = dense bf16).
    pub format: Option<crate::formats::QuantKind>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let mut batch = 0;
        let mut seq = 0;
        let mut vocab = 0;
        let mut qdq_rows = 0;
        let mut qdq_cols = 0;
        let mut params = Vec::new();
        // model.py CONFIG defaults, for manifests written before the
        // geometry keys existed.
        let mut n_heads = 4;
        let mut kv_heads = 2;
        let mut head_dim = 16;
        let mut rope_base = 10000.0f32;
        let mut format = None;
        for line in text.lines() {
            let mut it = line.split_whitespace();
            let Some(key) = it.next() else { continue };
            match key {
                "batch" => batch = it.next().context("batch")?.parse()?,
                "seq" => seq = it.next().context("seq")?.parse()?,
                "vocab" => vocab = it.next().context("vocab")?.parse()?,
                "n_heads" => n_heads = it.next().context("n_heads")?.parse()?,
                "kv_heads" => kv_heads = it.next().context("kv_heads")?.parse()?,
                "head_dim" => head_dim = it.next().context("head_dim")?.parse()?,
                "rope_base" => rope_base = it.next().context("rope_base")?.parse()?,
                "format" => {
                    let spec = it.next().context("format")?;
                    format = Some(
                        spec.parse::<crate::formats::QuantKind>()
                            .map_err(|e| anyhow::anyhow!("manifest format key: {e}"))?,
                    );
                }
                "qdq" => {
                    qdq_rows = it.next().context("qdq rows")?.parse()?;
                    qdq_cols = it.next().context("qdq cols")?.parse()?;
                }
                "param" => {
                    let name = it.next().context("param name")?.to_string();
                    let dims: Vec<usize> =
                        it.map(|d| d.parse().unwrap_or(0)).collect();
                    if dims.iter().any(|d| *d == 0) {
                        bail!("bad dims for param {name}");
                    }
                    params.push((name, dims));
                }
                _ => {}
            }
        }
        if batch == 0 || seq == 0 || params.is_empty() {
            bail!("incomplete manifest {path:?}");
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            batch,
            seq,
            vocab,
            params,
            qdq_rows,
            qdq_cols,
            n_heads,
            kv_heads,
            head_dim,
            rope_base,
            format,
        })
    }

    /// Path of a named artifact.
    pub fn artifact(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Total parameter element count.
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|(_, d)| d.iter().product::<usize>()).sum()
    }

    /// Initialize a parameter store with the same scheme as
    /// `model.init_params` (scaled normal; ones for norm gains).
    pub fn init_params(&self, seed: u64) -> ParamStore {
        let mut rng = Rng::seed(seed);
        let mut params = BTreeMap::new();
        for (name, dims) in &self.params {
            let n: usize = dims.iter().product();
            let mut data = vec![0f32; n];
            if name.contains("norm") {
                data.fill(1.0);
            } else if name == "embed" {
                rng.fill_normal(&mut data, 0.02);
            } else {
                let fan_out = dims.first().copied().unwrap_or(1);
                let fan_in = dims.last().copied().unwrap_or(1);
                let sigma = (2.0 / (fan_out + fan_in) as f32).sqrt();
                rng.fill_normal(&mut data, sigma);
            }
            params.insert(name.clone(), (dims.clone(), data));
        }
        ParamStore { order: self.params.iter().map(|(n, _)| n.clone()).collect(), params }
    }
}

/// Named parameter arrays in manifest order.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub order: Vec<String>,
    pub params: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl ParamStore {
    /// Convert to PJRT literals in artifact input order.
    pub fn literals(&self) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(self.order.len());
        for name in &self.order {
            let (dims, data) =
                self.params.get(name).with_context(|| format!("param {name} missing from store"))?;
            let dims_i64: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
            out.push(xla::Literal::vec1(data).reshape(&dims_i64)?);
        }
        Ok(out)
    }

    /// Replace parameter values from literals (train-step outputs).
    pub fn update_from_literals(&mut self, literals: &[xla::Literal]) -> Result<()> {
        for (name, lit) in self.order.clone().iter().zip(literals) {
            let data = lit.to_vec::<f32>()?;
            let entry = self.params.get_mut(name).context("unknown param")?;
            anyhow::ensure!(data.len() == entry.1.len(), "size mismatch for {name}");
            entry.1 = data;
        }
        Ok(())
    }

    /// Fake-quantize every attention/FFN weight matrix (2-D, non-norm,
    /// non-embedding/head) with `scheme` — the weight half of the paper's
    /// simulated quantization; activations are handled in-graph by the
    /// quantized forward artifact. Rows quantize independently, so each
    /// parameter fans out over the process-default thread count (serving
    /// startup inherits the parallel quantization path).
    pub fn quantize_weights(&mut self, scheme: &crate::formats::QuantScheme) {
        for (name, (dims, data)) in self.params.iter_mut() {
            if name == "embed" || name == "head" || name.contains("norm") {
                continue;
            }
            let &[_, cols] = dims.as_slice() else { continue };
            *data = scheme.quant_dequant_rows(data, cols);
        }
    }

    /// Save to a simple binary file (name, dims, f32 LE data per entry).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"HIF4PARM");
        buf.extend_from_slice(&(self.order.len() as u32).to_le_bytes());
        for name in &self.order {
            let (dims, data) =
                self.params.get(name).with_context(|| format!("param {name} missing from store"))?;
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for d in dims {
                buf.extend_from_slice(&(*d as u64).to_le_bytes());
            }
            for x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        std::fs::write(path, buf)?;
        Ok(())
    }

    /// Load from the binary format written by [`ParamStore::save`].
    pub fn load(path: &Path) -> Result<ParamStore> {
        let buf = std::fs::read(path)?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            anyhow::ensure!(*pos + n <= buf.len(), "truncated param file");
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        anyhow::ensure!(take(&mut pos, 8)? == b"HIF4PARM", "bad magic");
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut order = Vec::with_capacity(count);
        let mut params = BTreeMap::new();
        for _ in 0..count {
            let nlen = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())?;
            let ndims = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                dims.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize);
            }
            let n: usize = dims.iter().product();
            let mut data = Vec::with_capacity(n);
            let raw = take(&mut pos, n * 4)?;
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into()?));
            }
            order.push(name.clone());
            params.insert(name, (dims, data));
        }
        Ok(ParamStore { order, params })
    }

    /// View one 2-D parameter as a Matrix (copy).
    pub fn matrix(&self, name: &str) -> Option<Matrix> {
        let (dims, data) = self.params.get(name)?;
        let &[rows, cols] = dims.as_slice() else {
            return None;
        };
        Some(Matrix::from_vec(rows, cols, data.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        std::fs::write(
            dir.join("manifest.txt"),
            "batch 8\nseq 32\nvocab 320\nqdq 8 256\nparam embed 320 64\nparam head 320 64\nparam layer0.norm1 64\nparam layer0.wq 64 64\n",
        )
        .unwrap();
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("hif4_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch, 8);
        assert_eq!(m.seq, 32);
        assert_eq!(m.params.len(), 4);
        assert_eq!(m.params[3].1, vec![64, 64]);
        assert_eq!(m.param_elems(), 320 * 64 * 2 + 64 + 64 * 64);
    }

    #[test]
    fn param_store_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join("hif4_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let store = m.init_params(3);
        let path = dir.join("params.bin");
        store.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(store.order, loaded.order);
        for name in &store.order {
            assert_eq!(store.params[name], loaded.params[name], "{name}");
        }
    }

    #[test]
    fn weight_quantization_skips_protected_params() {
        let dir = std::env::temp_dir().join("hif4_quant_test");
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let mut store = m.init_params(4);
        let embed_before = store.params["embed"].1.clone();
        let wq_before = store.params["layer0.wq"].1.clone();
        store.quantize_weights(&crate::formats::QuantScheme::direct(
            crate::formats::QuantKind::HiF4,
        ));
        assert_eq!(store.params["embed"].1, embed_before, "embed protected");
        assert_ne!(store.params["layer0.wq"].1, wq_before, "wq quantized");
    }
}
