//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them —
//! the only compute path the serving stack uses (Python never runs at
//! request time).
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. HLO *text* is
//! the interchange format (xla_extension 0.5.1 rejects jax≥0.5 protos).

use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client (CPU in this image; the same wrapper drives TPU/GPU
/// plugins on hardware that has them).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu().context("create PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path must be utf-8")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {path:?}"))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled executable with a typed execute wrapper.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with the given inputs (borrowed literals — parameter
    /// literals are long-lived, only per-call inputs are fresh); returns
    /// the flattened tuple outputs (aot.py lowers with `return_tuple=True`).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<L>(inputs).context("execute")?;
        // An executable that produced no output buffer is an engine
        // error, not a panic: serving workers turn this into Crashed
        // responses for the affected batch and keep running.
        let buffer = result
            .first()
            .and_then(|device| device.first())
            .with_context(|| format!("{}: execute returned no output buffer", self.name))?;
        let literal = buffer.to_literal_sync().context("fetch result literal")?;
        literal.to_tuple().context("decompose result tuple")
    }
}

/// f32 matrix → PJRT literal of shape [rows, cols].
pub fn matrix_literal(m: &Matrix) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
}

/// f32 vector → literal of shape [n].
pub fn vec_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 scalar literal.
pub fn scalar_literal(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Token batch (B×T, i32) → literal. Rows longer than the lowered `seq`
/// are truncated (never a panic: the serving layer validates prompt
/// length at admission, so an over-long row here can only come from an
/// internal caller that already chose truncation semantics).
pub fn tokens_literal(tokens: &[Vec<usize>], seq: usize) -> Result<xla::Literal> {
    let b = tokens.len();
    let mut flat = Vec::with_capacity(b * seq);
    for row in tokens {
        for i in 0..seq {
            // Pad with token 0 (the corpus pad/BOS id).
            flat.push(*row.get(i).unwrap_or(&0) as i32);
        }
    }
    Ok(xla::Literal::vec1(&flat).reshape(&[b as i64, seq as i64])?)
}

/// Literal → f32 vec (any shape, row-major).
pub fn literal_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}
