//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The serving path never runs Python: `python/compile/aot.py` lowers the
//! JAX model (L2) to HLO text + a `manifest.txt` describing parameter
//! order/shapes and entry-point dims, and this layer drives the result —
//! [`artifact`] parses the manifest and owns the [`artifact::ParamStore`]
//! (init/save/load/quantize of the served weights), while [`client`]
//! wraps the PJRT client/executable handles behind typed literal helpers.
//!
//! In the offline build the `xla` dependency is a stub: artifacts still
//! parse and `ParamStore` round-trips, but creating a
//! [`client::Runtime`] reports that PJRT is unavailable (integration
//! tests and benches skip when `artifacts/` is missing for the same
//! reason). Point `rust/Cargo.toml` at the real xla-rs crate to execute.
//!
//! [`native`] is the PJRT-free alternative: it rebuilds the same model
//! from the [`artifact::ParamStore`] and serves it with the rust-native
//! forward pass (and, for quantized serving, the packed fixed-point
//! QGEMM), so the coordinator runs end to end even offline.

pub mod artifact;
pub mod client;
pub mod native;
