//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.

pub mod artifact;
pub mod client;
