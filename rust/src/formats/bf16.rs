//! Bit-exact BF16 (bfloat16) helpers.
//!
//! The paper's Algorithm 1 consumes BF16 vectors; the conversion pipeline
//! therefore needs an exact software BF16: f32→bf16 rounding (RNE, the mode
//! hardware implements), bf16→f32 widening (exact), and the BF16 constant
//! `(1/7)_BF16` used for the level-1 scale factor.

use super::rounding::RoundMode;

/// A bfloat16 value stored as its 16 raw bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    /// Largest finite bf16: 0x7F7F = 2^127 × 1.9921875 ≈ 3.3895e38.
    pub const MAX: Bf16 = Bf16(0x7F7F);

    /// Exact widening: bf16 is the top 16 bits of an f32.
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    /// Round an f32 to bf16 with round-half-to-even (hardware default).
    #[inline]
    pub fn from_f32(x: f32) -> Bf16 {
        Bf16(f32_to_bf16_bits(x, RoundMode::NearestEven))
    }

    /// Round an f32 to bf16 under an explicit rounding mode.
    #[inline]
    pub fn from_f32_mode(x: f32, mode: RoundMode) -> Bf16 {
        Bf16(f32_to_bf16_bits(x, mode))
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7F80) == 0x7F80 && (self.0 & 0x007F) != 0
    }
}

/// f32 → bf16 bits with the requested rounding on the dropped 16 bits.
fn f32_to_bf16_bits(x: f32, mode: RoundMode) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Preserve a quiet NaN payload.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lower = bits & 0xFFFF;
    let upper = (bits >> 16) as u16;
    let round_up = match mode {
        RoundMode::NearestEven => {
            lower > 0x8000 || (lower == 0x8000 && (upper & 1) == 1)
        }
        RoundMode::HalfAwayFromZero => lower >= 0x8000,
    };
    // Carry propagation on round-up is correct through exponent bumps and
    // saturates to infinity naturally.
    if round_up {
        upper.wrapping_add(1)
    } else {
        upper
    }
}

/// Round every element of `xs` to bf16 precision in-place (kept as f32).
pub fn quantize_bf16_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = Bf16::from_f32(*x).to_f32();
    }
}

/// `(1/7)` rounded to BF16, as used on line 8 of Algorithm 1.
pub fn one_seventh_bf16() -> f32 {
    Bf16::from_f32(1.0 / 7.0).to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -3.5, 1.75, 0.25] {
            assert_eq!(Bf16::from_f32(v).to_f32(), v, "{v} should be exact in bf16");
        }
    }

    #[test]
    fn rne_on_dropped_bits() {
        // bf16 has 7 mantissa bits: the grid at 1.0 has step 2^-7.
        // 1.0 + 2^-8 is exactly halfway; RNE keeps the even (1.0).
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0);
        // 1.0 + 3·2^-9 = 0.75 of a step: nearest is 1 + 2^-7.
        let y = 1.0 + 3.0 * 2f32.powi(-9);
        assert_eq!(Bf16::from_f32(y).to_f32(), 1.0 + 2f32.powi(-7));
    }

    #[test]
    fn rhaz_on_dropped_bits() {
        // Same halfway point, away-from-zero goes up.
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(
            Bf16::from_f32_mode(x, RoundMode::HalfAwayFromZero).to_f32(),
            1.0 + 2f32.powi(-7)
        );
    }

    #[test]
    fn one_seventh_value() {
        // bf16(1/7): 1/7 = 2^-3 × 1.142857..; 7-bit mantissa:
        // 0.142857×128 = 18.29 -> 18 => 2^-3 × (1 + 18/128) = 0.142578125.
        assert_eq!(one_seventh_bf16(), 0.142578125);
    }

    #[test]
    fn nan_and_saturation() {
        assert!(Bf16::from_f32(f32::NAN).is_nan());
        // Round-up can carry into the exponent.
        let just_under_2 = 1.9999999f32;
        assert_eq!(Bf16::from_f32(just_under_2).to_f32(), 2.0);
    }

    #[test]
    fn bulk_quantize() {
        let mut xs = vec![0.1f32, 0.2, 0.3];
        quantize_bf16_inplace(&mut xs);
        for x in &xs {
            assert_eq!(Bf16::from_f32(*x).to_f32(), *x);
        }
    }
}
