//! S1P2 — the 4-bit sign-magnitude in-group element of HiF4 (Table I).
//!
//! `SXPY` notation: `S` sign bit, `P` binary point, `X` integer bits, `Y`
//! fraction bits. S1P2 = sign + 1 integer bit + 2 fraction bits, i.e. a
//! uniform grid of step 0.25 over ±[0, 1.75]. Conceptually equal to E1M2 but
//! interpreted (and implemented) as a scaled integer, which is what lets the
//! HiF4 dot product stay in fixed-point arithmetic.

use super::rounding::{round_int, RoundMode};

/// An S1P2 value stored in its 4 raw bits (`s_mmm`, magnitude in quarters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct S1P2(pub u8);

/// Maximum representable magnitude (`S1.11` = 1.75).
pub const MAX_ABS: f32 = 1.75;
/// Smallest positive magnitude (`S0.01` = 0.25).
pub const MIN_POS: f32 = 0.25;
/// Grid step.
pub const STEP: f32 = 0.25;

impl S1P2 {
    pub const POS_ZERO: S1P2 = S1P2(0b0000);
    pub const NEG_ZERO: S1P2 = S1P2(0b1000);
    pub const MAX: S1P2 = S1P2(0b0111);
    pub const MIN: S1P2 = S1P2(0b1111);

    #[inline]
    pub fn sign_negative(self) -> bool {
        self.0 & 0b1000 != 0
    }

    /// Magnitude in quarter-units (0..=7).
    #[inline]
    pub fn magnitude_q(self) -> u8 {
        self.0 & 0b0111
    }

    /// Signed value in quarter-units (-7..=7); the integer the fixed-point
    /// dot-product datapath actually multiplies.
    #[inline]
    pub fn signed_q(self) -> i8 {
        let m = self.magnitude_q() as i8;
        if self.sign_negative() {
            -m
        } else {
            m
        }
    }

    /// Decode to f32 (exact).
    #[inline]
    pub fn to_f32(self) -> f32 {
        self.signed_q() as f32 * STEP
    }

    /// Quantize an f32 onto the S1P2 grid with saturation to ±1.75
    /// (Algorithm 1 stage 3: "clamped to the nearest representable bound,
    /// preserving the sign").
    pub fn from_f32(x: f32, mode: RoundMode) -> S1P2 {
        if x.is_nan() {
            // HiF4 signals NaN through the E6M2 scale, not the elements;
            // element conversion of NaN saturates to +max as a safe default.
            return S1P2::MAX;
        }
        let q = round_int(x / STEP, mode);
        let neg = q < 0.0 || (q == 0.0 && x.is_sign_negative());
        let mag = q.abs().min(7.0) as u8;
        S1P2(((neg as u8) << 3) | mag)
    }
}

/// Decode table of all 16 encodings, useful for exhaustive benches/tests.
pub fn all_values() -> [(u8, f32); 16] {
    core::array::from_fn(|i| (i as u8, S1P2(i as u8).to_f32()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_boundary_values() {
        assert_eq!(S1P2::MAX.to_f32(), 1.75);
        assert_eq!(S1P2::MIN.to_f32(), -1.75);
        assert_eq!(S1P2::POS_ZERO.to_f32(), 0.0);
        assert_eq!(S1P2::NEG_ZERO.to_f32(), -0.0);
        assert_eq!(S1P2(0b0001).to_f32(), MIN_POS);
    }

    #[test]
    fn exhaustive_roundtrip() {
        for bits in 0u8..16 {
            let v = S1P2(bits);
            let back = S1P2::from_f32(v.to_f32(), RoundMode::NearestEven);
            // -0.0 and +0.0 both map back to a zero encoding.
            assert_eq!(back.to_f32(), v.to_f32());
            assert_eq!(back.signed_q(), v.signed_q());
        }
    }

    #[test]
    fn saturation_preserves_sign() {
        assert_eq!(S1P2::from_f32(9.0, RoundMode::NearestEven), S1P2::MAX);
        assert_eq!(S1P2::from_f32(-9.0, RoundMode::NearestEven), S1P2::MIN);
        assert_eq!(S1P2::from_f32(1.76, RoundMode::NearestEven), S1P2::MAX);
    }

    #[test]
    fn rne_ties() {
        // 0.125 is a tie between 0 and 0.25 -> RNE keeps 0 (even).
        assert_eq!(S1P2::from_f32(0.125, RoundMode::NearestEven).to_f32(), 0.0);
        // 0.375 ties between 0.25 (odd q=1) and 0.5 (even q=2) -> 0.5.
        assert_eq!(S1P2::from_f32(0.375, RoundMode::NearestEven).to_f32(), 0.5);
        assert_eq!(
            S1P2::from_f32(0.125, RoundMode::HalfAwayFromZero).to_f32(),
            0.25
        );
    }

    #[test]
    fn signed_q_matches_value() {
        for bits in 0u8..16 {
            let v = S1P2(bits);
            assert_eq!(v.signed_q() as f32 * 0.25, v.to_f32());
        }
    }
}
