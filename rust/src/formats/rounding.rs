//! Rounding primitives shared by every codec in `formats/`.
//!
//! The paper (§II.B) mandates *round-half-to-even* (RNE) or
//! *round-half-away-from-zero* (RHAZ) for all BF16→HiF4 conversion steps.
//! Both are provided; RNE is the library default because it matches IEEE-754
//! hardware and the Pallas reference kernels.

/// Rounding mode for quantization steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RoundMode {
    /// Round half to even (IEEE-754 default; ties go to the even grid point).
    #[default]
    NearestEven,
    /// Round half away from zero (ties move away from zero).
    HalfAwayFromZero,
}

/// Round `x` to the nearest integer under the given mode.
///
/// `f32::round` is RHAZ; RNE uses `round_ties_even` semantics implemented
/// manually so behaviour is identical on every toolchain.
#[inline]
pub fn round_int(x: f32, mode: RoundMode) -> f32 {
    match mode {
        RoundMode::HalfAwayFromZero => x.round(),
        // Branchless intrinsic (roundeven); the format codecs call this per
        // element, so it is on the quantization hot path (§Perf).
        RoundMode::NearestEven => x.round_ties_even(),
    }
}

/// Round `x` onto a uniform grid of step `step` (e.g. 0.25 for S1P2).
#[inline]
pub fn round_to_grid(x: f32, step: f32, mode: RoundMode) -> f32 {
    round_int(x / step, mode) * step
}

/// Round a positive `x` to `mbits` significand bits (hidden bit excluded),
/// returning the rounded value. Used by the scalar mini-float codecs.
/// `x` must be finite and non-negative.
#[inline]
pub fn round_significand(x: f32, mbits: u32, mode: RoundMode) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return x;
    }
    let e = x.abs().log2().floor() as i32;
    // Guard against log2 edge cases at powers of two boundaries.
    let e = if x.abs() < 2f32.powi(e) {
        e - 1
    } else if x.abs() >= 2f32.powi(e + 1) {
        e + 1
    } else {
        e
    };
    let ulp = 2f32.powi(e - mbits as i32);
    round_int(x / ulp, mode) * ulp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rne_ties_go_even() {
        assert_eq!(round_int(0.5, RoundMode::NearestEven), 0.0);
        assert_eq!(round_int(1.5, RoundMode::NearestEven), 2.0);
        assert_eq!(round_int(2.5, RoundMode::NearestEven), 2.0);
        assert_eq!(round_int(-0.5, RoundMode::NearestEven), 0.0);
        assert_eq!(round_int(-1.5, RoundMode::NearestEven), -2.0);
        assert_eq!(round_int(-2.5, RoundMode::NearestEven), -2.0);
    }

    #[test]
    fn rhaz_ties_go_away() {
        assert_eq!(round_int(0.5, RoundMode::HalfAwayFromZero), 1.0);
        assert_eq!(round_int(1.5, RoundMode::HalfAwayFromZero), 2.0);
        assert_eq!(round_int(-0.5, RoundMode::HalfAwayFromZero), -1.0);
        assert_eq!(round_int(-2.5, RoundMode::HalfAwayFromZero), -3.0);
    }

    #[test]
    fn non_ties_are_nearest() {
        for mode in [RoundMode::NearestEven, RoundMode::HalfAwayFromZero] {
            assert_eq!(round_int(0.49, mode), 0.0);
            assert_eq!(round_int(0.51, mode), 1.0);
            assert_eq!(round_int(-1.2, mode), -1.0);
            assert_eq!(round_int(7.9, mode), 8.0);
        }
    }

    #[test]
    fn grid_quarter_steps() {
        // S1P2 grid: multiples of 0.25. 0.375 is a tie between 0.25 and 0.5.
        assert_eq!(round_to_grid(0.375, 0.25, RoundMode::NearestEven), 0.5); // 1.5 -> 2
        assert_eq!(round_to_grid(0.125, 0.25, RoundMode::NearestEven), 0.0); // 0.5 -> 0
        assert_eq!(round_to_grid(0.125, 0.25, RoundMode::HalfAwayFromZero), 0.25);
        assert_eq!(round_to_grid(-0.375, 0.25, RoundMode::NearestEven), -0.5);
        assert_eq!(round_to_grid(1.7, 0.25, RoundMode::NearestEven), 1.75);
    }

    #[test]
    fn significand_rounding() {
        // 3 significand bits after the hidden bit: grid of 1/8 in [1,2).
        assert_eq!(round_significand(1.0 + 1.0 / 16.0, 3, RoundMode::NearestEven), 1.0);
        assert_eq!(round_significand(1.0 + 3.0 / 16.0, 3, RoundMode::NearestEven), 1.25);
        // Exactly representable values survive.
        assert_eq!(round_significand(1.375, 3, RoundMode::NearestEven), 1.375);
    }
}
