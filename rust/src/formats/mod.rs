//! Bit-exact software implementations of every numeric format the paper
//! defines, compares against, or builds on (§I–§II):
//!
//! | module   | format                        | group | bits/value |
//! |----------|-------------------------------|-------|------------|
//! | [`hif4`] | HiF4 (the paper's format)     | 64    | 4.5        |
//! | [`nvfp4`]| NVFP4 (E4M3 scale + E2M1)     | 16    | 4.5        |
//! | [`mxfp4`]| OCP MXFP4 (E8M0 + E2M1)       | 32    | 4.25       |
//! | [`mx4`]  | MX4 shared micro-exponents    | 16    | 4.0        |
//! | [`bfp`]  | vanilla BFP (shared exponent) | 16    | 4.5        |
//!
//! Scalar building blocks: [`bf16`], [`e6m2`], [`s1p2`], [`e2m1`], [`e4m3`],
//! [`e8m0`], with shared [`rounding`].
//!
//! The uniform entry point is [`Quantizer`] (an alias of [`QuantScheme`]),
//! which quantize→dequantizes a tensor row padded into groups — the
//! "simulated quantization" semantics of the paper's LLM experiments —
//! and adds the per-tensor-scaling (PTS) wrapper NVFP4 needs.

pub mod bf16;
pub mod bfp;
pub mod e2m1;
pub mod e4m3;
pub mod e6m2;
pub mod e8m0;
pub mod hif4;
pub mod mx4;
pub mod mxfp4;
pub mod nvfp4;
pub mod rounding;
pub mod s1p2;

use rounding::RoundMode;

/// The block formats under evaluation, as a uniform enum (dyn-free dispatch
/// keeps the hot quantization loops monomorphic-ish and inlinable).
///
/// `QuantKind` is the **single** format authority of the crate: the one
/// parser ([`std::str::FromStr`], shared by the CLI, env knobs and
/// manifest keys), the one label source ([`std::fmt::Display`], which
/// every bench/eval/serving label derives from), and the dispatch key of
/// the unified quantized-tensor API
/// (`crate::dotprod::quant_tensor::QuantizedMatrix`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantKind {
    HiF4,
    Nvfp4,
    Mxfp4,
    Mx4,
    /// Vanilla 4-bit BFP (shared power-of-two exponent, no micro-exponents).
    Bfp,
}

impl QuantKind {
    /// Every supported block format, in the canonical reporting order.
    pub const ALL: [QuantKind; 5] =
        [QuantKind::HiF4, QuantKind::Nvfp4, QuantKind::Mxfp4, QuantKind::Mx4, QuantKind::Bfp];

    /// Canonical display label (also what [`std::fmt::Display`] prints).
    pub fn name(self) -> &'static str {
        match self {
            QuantKind::HiF4 => "HiF4",
            QuantKind::Nvfp4 => "NVFP4",
            QuantKind::Mxfp4 => "MXFP4",
            QuantKind::Mx4 => "MX4",
            QuantKind::Bfp => "BFP4",
        }
    }

    /// Canonical lower-case spelling — the CLI `--format` value, env-knob
    /// value, manifest key and bench-JSON key. The `FromStr` impl
    /// round-trips it.
    pub fn spelling(self) -> &'static str {
        match self {
            QuantKind::HiF4 => "hif4",
            QuantKind::Nvfp4 => "nvfp4",
            QuantKind::Mxfp4 => "mxfp4",
            QuantKind::Mx4 => "mx4",
            QuantKind::Bfp => "bfp",
        }
    }

    /// Block length of one quantization group.
    pub fn group(self) -> usize {
        match self {
            QuantKind::HiF4 => hif4::GROUP,
            QuantKind::Nvfp4 => nvfp4::GROUP,
            QuantKind::Mxfp4 => mxfp4::GROUP,
            QuantKind::Mx4 => mx4::GROUP,
            QuantKind::Bfp => bfp::GROUP,
        }
    }

    /// Average storage cost in bits/value including metadata.
    pub fn bits_per_value(self) -> f64 {
        match self {
            QuantKind::HiF4 => hif4::BITS_PER_VALUE,
            QuantKind::Nvfp4 => nvfp4::BITS_PER_VALUE,
            QuantKind::Mxfp4 => mxfp4::BITS_PER_VALUE,
            QuantKind::Mx4 => mx4::BITS_PER_VALUE,
            QuantKind::Bfp => bfp::BITS_PER_VALUE,
        }
    }

    /// Serialized bytes of one packed group (shared metadata + packed
    /// elements) — `group() × bits_per_value() / 8`, always whole bytes.
    pub fn wire_bytes_group(self) -> usize {
        match self {
            QuantKind::HiF4 => hif4::HiF4Unit::WIRE_BYTES, // 4B meta + 32B elems
            QuantKind::Nvfp4 => 9,                         // 1B E4M3 + 8B nibbles
            QuantKind::Mxfp4 => 17,                        // 1B E8M0 + 16B nibbles
            QuantKind::Mx4 => 8,                           // 1B E8M0 + 1B micro + 6B elems
            QuantKind::Bfp => 9,                           // 1B E8M0 + 8B nibbles
        }
    }

    /// Sniff the quantization format out of an artifact file name
    /// (`"fwd_hif4.hlo.txt"` → `HiF4`); `None` means dense bf16. Only the
    /// final path component is inspected, so a directory that happens to
    /// contain a format spelling (e.g. a checkout named `hif4/`) never
    /// mislabels a dense artifact. The one sniffing rule shared by the
    /// PJRT server, the CLI's artifact branch and the serving bench, so
    /// weight quantization and metrics tags can never disagree about the
    /// same file.
    pub fn from_artifact_name(name: &str) -> Option<QuantKind> {
        let base = name.rsplit(['/', '\\']).next().unwrap_or(name);
        let lower = base.to_ascii_lowercase();
        QuantKind::ALL.into_iter().find(|k| lower.contains(k.spelling()))
    }

    /// Quantize→dequantize one block (input length == `group()`).
    pub fn quant_dequant_block(self, v: &[f32], out: &mut [f32], mode: RoundMode) {
        match self {
            QuantKind::HiF4 => hif4::quant_dequant(v, out, mode),
            QuantKind::Nvfp4 => nvfp4::quant_dequant(v, out, mode),
            QuantKind::Mxfp4 => mxfp4::quant_dequant(v, out, mode),
            QuantKind::Mx4 => mx4::quant_dequant(v, out, mode),
            QuantKind::Bfp => bfp::quant_dequant(v, out, mode),
        }
    }
}

impl std::fmt::Display for QuantKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for QuantKind {
    type Err = String;

    /// The one format parser (CLI `--format`, env knobs, manifest keys).
    /// Accepts the canonical [`QuantKind::spelling`] case-insensitively
    /// (plus `bfp4` for the BFP label); the error lists every valid name.
    fn from_str(s: &str) -> Result<QuantKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "hif4" => Ok(QuantKind::HiF4),
            "nvfp4" => Ok(QuantKind::Nvfp4),
            "mxfp4" => Ok(QuantKind::Mxfp4),
            "mx4" => Ok(QuantKind::Mx4),
            "bfp" | "bfp4" => Ok(QuantKind::Bfp),
            other => Err(format!(
                "unknown quantization format {other:?}; expected one of hif4, nvfp4, mxfp4, \
                 mx4, bfp"
            )),
        }
    }
}

/// A quantization scheme = block format + optional per-tensor scaling,
/// exactly the configurations the paper's tables evaluate
/// (`NVFP4`, `NVFP4+PTS`, `HiF4`, …).
///
/// # Examples
///
/// Simulated quantization of a tensor (quantize → dequantize back to f32,
/// the semantics every LLM experiment in the paper uses):
///
/// ```
/// use hif4::formats::{mse, QuantKind, QuantScheme};
///
/// let scheme = QuantScheme::direct(QuantKind::HiF4);
/// let x: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) / 25.0).collect();
/// let q = scheme.quant_dequant_vec(&x);
///
/// assert_eq!(q.len(), x.len());
/// // Zeros are exact, signs never flip, and the 4.5-bit error is small.
/// assert!(q.iter().zip(&x).all(|(qi, xi)| qi * xi >= 0.0));
/// assert!(mse(&x, &q) < 1e-2);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QuantScheme {
    pub format: QuantKind,
    /// Software per-tensor scaling before/after quantization (§I: NVFP4's
    /// extra pipeline stage; a no-op for formats with enough global range).
    pub pts: bool,
    pub mode: RoundMode,
}

/// Uniform quantize→dequantize entry point — the name the crate docs use
/// for the "simulated quantization" interface ([`QuantScheme`] by another
/// name; `Quantizer::direct(QuantKind::HiF4)` reads better at call sites
/// that never touch PTS).
pub use self::QuantScheme as Quantizer;

impl QuantScheme {
    pub fn direct(format: QuantKind) -> Self {
        QuantScheme { format, pts: false, mode: RoundMode::NearestEven }
    }

    pub fn with_pts(format: QuantKind) -> Self {
        QuantScheme { format, pts: true, mode: RoundMode::NearestEven }
    }

    /// Scheme label, derived from the one [`QuantKind`] display impl
    /// (bench JSON, eval tables and `hif4 info` all agree by construction).
    pub fn label(&self) -> String {
        if self.pts {
            format!("{}+PTS", self.format)
        } else {
            self.format.to_string()
        }
    }

    /// Quantize→dequantize a whole tensor (groups run along the contiguous
    /// axis; the tail group is zero-padded, matching how linear-layer rows
    /// are blocked along the reduction dimension in the paper's setup).
    pub fn quant_dequant(&self, input: &[f32], out: &mut [f32]) {
        assert_eq!(input.len(), out.len());
        let t = if self.pts { nvfp4::pts_scale(input) } else { 1.0 };
        let g = self.format.group();
        let mut buf_in = vec![0f32; g];
        let mut buf_out = vec![0f32; g];
        for (ci, chunk) in input.chunks(g).enumerate() {
            let base = ci * g;
            if chunk.len() == g && t == 1.0 {
                self.format.quant_dequant_block(chunk, &mut buf_out, self.mode);
            } else {
                buf_in[..chunk.len()].copy_from_slice(chunk);
                buf_in[chunk.len()..].fill(0.0);
                if t != 1.0 {
                    for x in buf_in.iter_mut() {
                        *x *= t;
                    }
                }
                self.format.quant_dequant_block(&buf_in, &mut buf_out, self.mode);
            }
            let n = chunk.len();
            if t != 1.0 {
                for i in 0..n {
                    out[base + i] = buf_out[i] / t;
                }
            } else {
                out[base..base + n].copy_from_slice(&buf_out[..n]);
            }
        }
    }

    /// Convenience: allocate the output.
    pub fn quant_dequant_vec(&self, input: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; input.len()];
        self.quant_dequant(input, &mut out);
        out
    }

    /// Quantize→dequantize a row-major `rows × cols` buffer one row at a
    /// time (rows are independent — PTS, when enabled, is applied per
    /// row), fanned out over the process-default thread count weighted by
    /// the quantizers' per-element cost. The shared core behind RTN
    /// weight quantization everywhere (`Transformer`, `ParamStore`,
    /// `quant::gptq::rtn_quantize`).
    pub fn quant_dequant_rows(&self, src: &[f32], cols: usize) -> Vec<f32> {
        use crate::util::threadpool::{threads_for, QUANT_WORK_PER_ELEM};
        self.quant_dequant_rows_threads(src, cols, threads_for(src.len() * QUANT_WORK_PER_ELEM))
    }

    /// [`QuantScheme::quant_dequant_rows`] with an explicit thread count
    /// (identical output for any count).
    pub fn quant_dequant_rows_threads(&self, src: &[f32], cols: usize, threads: usize) -> Vec<f32> {
        let mut out = vec![0f32; src.len()];
        if src.is_empty() {
            return out;
        }
        assert!(cols > 0 && src.len() % cols == 0, "buffer must be whole rows");
        crate::util::threadpool::parallel_row_bands(&mut out, cols, threads, |first_row, band| {
            for (i, orow) in band.chunks_mut(cols).enumerate() {
                let r = first_row + i;
                self.quant_dequant(&src[r * cols..(r + 1) * cols], orow);
            }
        });
        out
    }
}

/// Mean squared error between a tensor and its quantized version — the
/// metric of Fig 3.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn all_formats_roundtrip_zero() {
        for f in QuantKind::ALL {
            let scheme = QuantScheme::direct(f);
            let v = vec![0f32; 100]; // non-multiple of any group size
            let out = scheme.quant_dequant_vec(&v);
            assert!(out.iter().all(|x| *x == 0.0), "{}", f.name());
        }
    }

    #[test]
    fn kind_spelling_parse_display_roundtrip() {
        for k in QuantKind::ALL {
            assert_eq!(k.spelling().parse::<QuantKind>(), Ok(k));
            assert_eq!(k.to_string(), k.name());
            // Wire bytes agree with the advertised bits/value exactly.
            assert_eq!(
                k.wire_bytes_group() as f64 * 8.0,
                k.bits_per_value() * k.group() as f64,
                "{k}"
            );
        }
        assert!("fp8".parse::<QuantKind>().unwrap_err().contains("hif4"));
    }

    #[test]
    fn artifact_name_sniffing() {
        assert_eq!(QuantKind::from_artifact_name("fwd_hif4.hlo.txt"), Some(QuantKind::HiF4));
        assert_eq!(QuantKind::from_artifact_name("fwd_NVFP4.hlo.txt"), Some(QuantKind::Nvfp4));
        assert_eq!(QuantKind::from_artifact_name("qdq_mxfp4.hlo.txt"), Some(QuantKind::Mxfp4));
        assert_eq!(QuantKind::from_artifact_name("fwd_bf16.hlo.txt"), None);
        // "mxfp4" must not be mis-sniffed as MX4 (no spelling is a
        // substring of another's artifact token).
        assert_eq!(QuantKind::from_artifact_name("fwd_mx4.hlo.txt"), Some(QuantKind::Mx4));
        // Only the file name counts: a checkout directory named after the
        // crate must not turn a dense artifact quantized.
        assert_eq!(QuantKind::from_artifact_name("/home/u/hif4/artifacts/fwd_bf16.hlo.txt"), None);
        assert_eq!(
            QuantKind::from_artifact_name("/srv/hif4/fwd_nvfp4.hlo.txt"),
            Some(QuantKind::Nvfp4)
        );
    }

    #[test]
    fn tail_padding_matches_full_group() {
        // Quantizing a prefix that is not a multiple of the group must equal
        // quantizing the zero-padded group (blocking invariant).
        let mut rng = Rng::seed(23);
        let v: Vec<f32> = (0..100).map(|_| rng.normal() as f32).collect();
        for f in [QuantKind::HiF4, QuantKind::Nvfp4, QuantKind::Mxfp4] {
            let scheme = QuantScheme::direct(f);
            let out = scheme.quant_dequant_vec(&v);
            let g = f.group();
            let tail_start = (v.len() / g) * g;
            let mut padded = v[tail_start..].to_vec();
            padded.resize(g, 0.0);
            let mut full = vec![0f32; g];
            f.quant_dequant_block(&padded, &mut full, RoundMode::NearestEven);
            for (i, o) in out[tail_start..].iter().enumerate() {
                assert_eq!(*o, full[i], "{} tail elem {i}", f.name());
            }
        }
    }

    #[test]
    fn pts_invariant_for_in_range_tensors() {
        // For a tensor already centered in NVFP4's range PTS changes little;
        // for an out-of-range tensor it must dramatically reduce MSE.
        let mut rng = Rng::seed(29);
        let big: Vec<f32> = (0..256).map(|_| rng.normal() as f32 * 10000.0).collect();
        let direct = QuantScheme::direct(QuantKind::Nvfp4).quant_dequant_vec(&big);
        let pts = QuantScheme::with_pts(QuantKind::Nvfp4).quant_dequant_vec(&big);
        let e_direct = mse(&big, &direct);
        let e_pts = mse(&big, &pts);
        assert!(
            e_pts < e_direct * 0.2,
            "PTS should rescue out-of-range tensors: direct {e_direct} pts {e_pts}"
        );
    }

    #[test]
    fn fig3_mse_ordering_gaussian() {
        // The headline ordering of Fig 3 on σ=1 Gaussian data:
        // HiF4 < NVFP4 < MXFP4.
        let mut rng = Rng::seed(31);
        let v: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let e_hif4 = mse(&v, &QuantScheme::direct(QuantKind::HiF4).quant_dequant_vec(&v));
        let e_nvfp4 = mse(&v, &QuantScheme::direct(QuantKind::Nvfp4).quant_dequant_vec(&v));
        let e_mxfp4 = mse(&v, &QuantScheme::direct(QuantKind::Mxfp4).quant_dequant_vec(&v));
        assert!(e_hif4 < e_nvfp4, "HiF4 {e_hif4} < NVFP4 {e_nvfp4}");
        assert!(e_nvfp4 < e_mxfp4, "NVFP4 {e_nvfp4} < MXFP4 {e_mxfp4}");
    }
}
