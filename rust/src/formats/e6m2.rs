//! E6M2 — the unsigned 8-bit floating-point level-1 scale of HiF4 (Table I).
//!
//! Layout: `eeeeee_mm` — 6 exponent bits (bias 48), 2 mantissa bits, one
//! hidden integer bit fixed to 1. **Normal mode only**: no zero, no infinity,
//! no subnormals. The all-ones encoding `111111_11` is NaN. Value:
//! `X = 2^E × 1.M` with unbiased `E ∈ [-48, 15]`.
//!
//! Also implements the paper's `E6M2_REC_to_BF16` instruction (§II.B): the
//! reciprocal of an E6M2 scale computed from a 4-entry LUT indexed by the
//! 2-bit mantissa plus an exponent subtraction — exactly as the suggested
//! hardware does.

use super::rounding::RoundMode;

/// Exponent bias of E6M2.
pub const BIAS: i32 = 48;
/// Smallest unbiased exponent.
pub const EXP_MIN: i32 = -48;
/// Largest unbiased exponent.
pub const EXP_MAX: i32 = 15;
/// NaN encoding (`111111_11`).
pub const NAN_BITS: u8 = 0xFF;

/// An E6M2 value stored as its 8 raw bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct E6M2(pub u8);

impl E6M2 {
    /// Minimum representable value: `000000_00` = 2^-48 × 1.00.
    pub const MIN: E6M2 = E6M2(0x00);
    /// Maximum non-NaN value: `111111_10` = 2^15 × 1.50.
    pub const MAX: E6M2 = E6M2(0xFE);
    pub const NAN: E6M2 = E6M2(NAN_BITS);

    #[inline]
    pub fn is_nan(self) -> bool {
        self.0 == NAN_BITS
    }

    /// Unbiased exponent field.
    #[inline]
    pub fn exponent(self) -> i32 {
        ((self.0 >> 2) as i32) - BIAS
    }

    /// 2-bit mantissa field (fraction numerator over 4).
    #[inline]
    pub fn mantissa(self) -> u32 {
        (self.0 & 0x3) as u32
    }

    /// Decode to f32. Exact: every E6M2 value is representable in f32.
    #[inline]
    pub fn to_f32(self) -> f32 {
        if self.is_nan() {
            return f32::NAN;
        }
        let sig = 1.0 + self.mantissa() as f32 / 4.0;
        exp2i(self.exponent()) * sig
    }

    /// Encode a non-negative finite f32 into E6M2 under `mode`, clamping to
    /// [MIN, MAX] (the format has no zero: underflow clamps to MIN, which is
    /// the behaviour Algorithm 1 relies on for all-zero groups).
    pub fn from_f32(x: f32, mode: RoundMode) -> E6M2 {
        if x.is_nan() {
            return E6M2::NAN;
        }
        debug_assert!(x >= 0.0, "E6M2 is unsigned, got {x}");
        if x <= E6M2::MIN.to_f32() {
            return E6M2::MIN;
        }
        if x >= E6M2::MAX.to_f32() {
            return E6M2::MAX;
        }
        // Normalize: find e with x = 2^e * s, s in [1, 2).
        let mut e = x.log2().floor() as i32;
        if x < exp2i(e) {
            e -= 1;
        } else if x >= exp2i(e + 1) {
            e += 1;
        }
        // Round significand to a 2-bit fraction (grid of 1/4).
        let s = x / exp2i(e);
        let q = super::rounding::round_int(s * 4.0, mode) / 4.0;
        let (e, q) = if q >= 2.0 { (e + 1, 1.0) } else { (e, q) };
        // Clamp exponent into range after rounding carry.
        if e < EXP_MIN {
            return E6M2::MIN;
        }
        if e > EXP_MAX {
            return E6M2::MAX;
        }
        let m = ((q - 1.0) * 4.0) as u8;
        let enc = (((e + BIAS) as u8) << 2) | (m & 0x3);
        // `111111_11` would alias NaN; clamp to MAX instead.
        if enc == NAN_BITS {
            E6M2::MAX
        } else {
            E6M2(enc)
        }
    }

    /// The paper's `E6M2_REC_to_BF16` instruction: reciprocal of this scale,
    /// returned as a BF16 value (widened to f32).
    ///
    /// Hardware realization (§II.B): a 4-entry LUT indexed by the 2-bit
    /// mantissa yields the BF16 significand of `1 / 1.M`, and the output
    /// exponent is derived by subtraction. Because E6M2 has no subnormals
    /// this is exact w.r.t. RNE-rounding the true reciprocal.
    pub fn reciprocal_bf16(self) -> f32 {
        if self.is_nan() {
            return f32::NAN;
        }
        // LUT: bf16(1 / 1.M) for M = 0..3 (7-bit bf16 mantissa), each stored
        // normalized to [1, 2) with its exponent offset. 1/1.0 = 1.0
        // (offset 0); 1/1.25 = 0.8, 1/1.5 = 0.666.., 1/1.75 = 0.5714..
        // (offset -1, normalized ×2).
        const LUT_SIG: [f32; 4] = [
            1.0,        // 1/1.00 = 1.0            => 2^0  * 1.0
            1.6015625,  // 1/1.25 = 0.8    -> bf16  => 2^-1 * (1 + 77/128)
            1.3359375,  // 1/1.50 = 0.6667 -> bf16  => 2^-1 * (1 + 43/128)
            1.140625,   // 1/1.75 = 0.5714 -> bf16  => 2^-1 * (1 + 18/128)
        ];
        const LUT_EXP: [i32; 4] = [0, -1, -1, -1];
        let m = self.mantissa() as usize;
        LUT_SIG[m] * exp2i(-self.exponent() + LUT_EXP[m])
    }
}

/// Exact 2^e for the E6M2 exponent range (|e| ≤ 50 fits f32 normals).
#[inline]
pub fn exp2i(e: i32) -> f32 {
    f32::from_bits((((e + 127) as u32) & 0xFF) << 23)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::bf16::Bf16;

    #[test]
    fn table1_boundary_values() {
        // Table I rows for E6M2.
        assert_eq!(E6M2::MIN.to_f32(), exp2i(-48) * 1.0);
        assert_eq!(E6M2::MAX.to_f32(), exp2i(15) * 1.5);
        assert!(E6M2::NAN.to_f32().is_nan());
        assert_eq!(E6M2::MIN.exponent(), -48);
        assert_eq!(E6M2::MAX.exponent(), 15);
    }

    #[test]
    fn decode_all_256_encodings() {
        let mut prev = f32::NEG_INFINITY;
        for bits in 0u16..=255 {
            let v = E6M2(bits as u8);
            if v.is_nan() {
                continue;
            }
            let f = v.to_f32();
            assert!(f.is_finite() && f > 0.0);
            assert!(f > prev, "E6M2 must be monotone in its encoding");
            prev = f;
        }
    }

    #[test]
    fn encode_roundtrips_every_code() {
        for bits in 0u16..=254 {
            let v = E6M2(bits as u8);
            let back = E6M2::from_f32(v.to_f32(), RoundMode::NearestEven);
            assert_eq!(back, v, "roundtrip failed for code {bits:#04x}");
        }
    }

    #[test]
    fn encode_clamps() {
        assert_eq!(E6M2::from_f32(0.0, RoundMode::NearestEven), E6M2::MIN);
        assert_eq!(E6M2::from_f32(1e30, RoundMode::NearestEven), E6M2::MAX);
        assert_eq!(E6M2::from_f32(f32::NAN, RoundMode::NearestEven), E6M2::NAN);
        // Just above MAX midpoint still clamps to MAX, never to the NaN code.
        let just_above = exp2i(15) * 1.74;
        assert_eq!(E6M2::from_f32(just_above, RoundMode::NearestEven), E6M2::MAX);
    }

    #[test]
    fn encode_rounds_to_nearest() {
        // 1.0 encodes exactly; 1.1 is nearer to 1.0 than 1.25.
        let q = E6M2::from_f32(1.1, RoundMode::NearestEven).to_f32();
        assert_eq!(q, 1.0);
        let q = E6M2::from_f32(1.2, RoundMode::NearestEven).to_f32();
        assert_eq!(q, 1.25);
        // Tie at 1.125: RNE picks 1.0 (even mantissa 0b00), RHAZ picks 1.25.
        assert_eq!(E6M2::from_f32(1.125, RoundMode::NearestEven).to_f32(), 1.0);
        assert_eq!(
            E6M2::from_f32(1.125, RoundMode::HalfAwayFromZero).to_f32(),
            1.25
        );
    }

    #[test]
    fn reciprocal_matches_bf16_of_true_reciprocal() {
        // The 4-entry LUT + exponent subtraction must agree with RNE-rounding
        // the exact reciprocal to BF16, for every non-NaN encoding.
        for bits in 0u16..=254 {
            let v = E6M2(bits as u8);
            let lut = v.reciprocal_bf16();
            let want = Bf16::from_f32(1.0 / v.to_f32()).to_f32();
            assert_eq!(lut, want, "REC mismatch for code {bits:#04x}");
        }
    }
}
