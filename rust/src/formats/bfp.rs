//! Vanilla 4-bit BFP — Microsoft Floating Point style baseline (§I, [9]).
//!
//! Group of 16 sign-magnitude S1P2 elements sharing one 8-bit power-of-two
//! exponent, no micro-exponents ⇒ (8 + 64)/16 = 4.5 bits/value. This is the
//! baseline MX4 was compared against in the intro ("MX4 delivers even lower
//! accuracy than the vanilla 4-bit BFP format").

use super::e8m0::E8M0;
use super::rounding::RoundMode;
use super::s1p2::S1P2;

/// Elements per group.
pub const GROUP: usize = 16;
/// Average storage cost.
pub const BITS_PER_VALUE: f64 = 4.5;
/// S1P2's largest power-of-two exponent: 1.75 = 1.75 × 2^0.
pub const EMAX_ELEM: i32 = 0;

/// A packed vanilla-BFP group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BfpGroup {
    pub scale: E8M0,
    /// 16 S1P2 nibbles packed two per byte.
    pub elems: [u8; 8],
}

impl BfpGroup {
    #[inline]
    pub fn elem(&self, i: usize) -> S1P2 {
        let b = self.elems[i / 2];
        S1P2(if i % 2 == 0 { b & 0x0F } else { b >> 4 })
    }

    #[inline]
    pub fn decode(&self, i: usize) -> f32 {
        self.scale.to_f32() * self.elem(i).to_f32()
    }

    pub fn decode_all(&self, out: &mut [f32]) {
        for i in 0..GROUP {
            out[i] = self.decode(i);
        }
    }
}

/// Quantize 16 values with a single shared power-of-two exponent.
pub fn quantize(v: &[f32], mode: RoundMode) -> BfpGroup {
    assert_eq!(v.len(), GROUP);
    if v.iter().any(|x| !x.is_finite()) {
        return BfpGroup { scale: E8M0::NAN, elems: [0; 8] };
    }
    let amax = v.iter().fold(0f32, |m, x| m.max(x.abs()));
    let scale = E8M0::from_amax(amax, EMAX_ELEM);
    let s = scale.to_f32();
    let inv = 1.0 / s;
    let mut g = BfpGroup { scale, elems: [0; 8] };
    for i in 0..GROUP {
        let q = S1P2::from_f32(v[i] * inv, mode);
        let b = &mut g.elems[i / 2];
        if i % 2 == 0 {
            *b = (*b & 0xF0) | (q.0 & 0x0F);
        } else {
            *b = (*b & 0x0F) | ((q.0 & 0x0F) << 4);
        }
    }
    g
}

/// Quantize→dequantize (simulated quantization).
pub fn quant_dequant(v: &[f32], out: &mut [f32], mode: RoundMode) {
    let g = quantize(v, mode);
    if g.scale.is_nan() {
        out[..GROUP].fill(f32::NAN);
        return;
    }
    g.decode_all(out);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qd(v: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; GROUP];
        quant_dequant(v, &mut out, RoundMode::NearestEven);
        out
    }

    #[test]
    fn zeros_and_grid() {
        assert!(qd(&[0.0; GROUP]).iter().all(|x| *x == 0.0));
        // Peak 1.75 with scale 1: grid of 0.25 reproduces exactly.
        let v: [f32; GROUP] = core::array::from_fn(|i| ((i % 8) as f32) * 0.25 - 1.0);
        let out = qd(&v);
        for (a, b) in v.iter().zip(&out) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn shared_exponent_coarseness() {
        // With one big outlier the rest of the group loses resolution:
        // scale 2^6 (peak 100 → floor log2 = 6), step = 0.25×64 = 16.
        let mut v = [1.0f32; GROUP];
        v[0] = 100.0;
        let out = qd(&v);
        assert_eq!(out[1], 0.0, "small values wiped out by the shared exponent");
    }
}
