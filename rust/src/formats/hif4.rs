//! HiF4 — the paper's 4-bit block floating-point format (§II, Fig 2).
//!
//! One unit = **32 bits of shared scaling metadata + 64 × 4-bit S1P2
//! elements** = 4.5 bits/value. The metadata is a three-level scaling
//! hierarchy:
//!
//! * level 1: one unsigned [`E6M2`] global base scale (8 bits),
//! * level 2: `E1_8` — 8 × 1-bit micro-exponents, one per 8 elements,
//! * level 3: `E1_16` — 16 × 1-bit micro-exponents, one per 4 elements.
//!
//! Value of element `i` (0-based here; the paper is 1-based):
//!
//! ```text
//! V_i = E6M2 × 2^(E1_8[i/8] + E1_16[i/4]) × S1P2_i            (eq. 2)
//! ```
//!
//! Conversion from BF16 follows **Algorithm 1** exactly, including the
//! `(1/7)_BF16` reciprocal constant, the `E6M2_REC_to_BF16` LUT reciprocal,
//! the strict `> 4` level-2 and `>= 2` level-3 thresholds, and clamping
//! S1P2 overflow to the representable bound.

use super::bf16::{one_seventh_bf16, Bf16};
use super::e6m2::{exp2i, E6M2};
use super::rounding::RoundMode;
use super::s1p2::S1P2;

/// Elements per HiF4 unit.
pub const GROUP: usize = 64;
/// Elements covered by one level-2 micro-exponent.
pub const L2_SPAN: usize = 8;
/// Elements covered by one level-3 micro-exponent.
pub const L3_SPAN: usize = 4;
/// Metadata bits per unit.
pub const META_BITS: usize = 32;
/// Average storage cost in bits/value: (32 + 64×4) / 64.
pub const BITS_PER_VALUE: f64 = 4.5;
/// Largest magnitude the intra-group structure represents: 2^(1+1) × 1.75.
pub const INTRA_MAX: f32 = 7.0;
/// Smallest positive intra-group magnitude: 2^0 × 0.25.
pub const INTRA_MIN_POS: f32 = 0.25;
/// Max positive value of the whole format: 2^15×1.5 × 4 × 1.75 = 2^18×1.3125.
pub const MAX_POSITIVE: f32 = 344064.0;
/// Min positive value: 2^-48 × 0.25 = 2^-50.
pub const MIN_POSITIVE: f32 = 8.881784e-16;

/// A packed HiF4 unit: 32-bit metadata + 64 S1P2 nibbles (32 bytes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HiF4Unit {
    /// Level-1 global base scale.
    pub scale: E6M2,
    /// Level-2 micro-exponents, bit `j` covers elements `[8j, 8j+8)`.
    pub e1_8: u8,
    /// Level-3 micro-exponents, bit `k` covers elements `[4k, 4k+4)`.
    pub e1_16: u16,
    /// 64 S1P2 elements packed two per byte (low nibble = even index).
    pub elems: [u8; 32],
}

impl HiF4Unit {
    /// Level-2 micro-exponent for element `i` (0 or 1).
    #[inline]
    pub fn l2(&self, i: usize) -> u32 {
        ((self.e1_8 >> (i / L2_SPAN)) & 1) as u32
    }

    /// Level-3 micro-exponent for element `i` (0 or 1).
    #[inline]
    pub fn l3(&self, i: usize) -> u32 {
        ((self.e1_16 >> (i / L3_SPAN)) & 1) as u32
    }

    /// S1P2 element `i`.
    #[inline]
    pub fn elem(&self, i: usize) -> S1P2 {
        let byte = self.elems[i / 2];
        S1P2(if i % 2 == 0 { byte & 0x0F } else { byte >> 4 })
    }

    #[inline]
    pub fn set_elem(&mut self, i: usize, v: S1P2) {
        let b = &mut self.elems[i / 2];
        if i % 2 == 0 {
            *b = (*b & 0xF0) | (v.0 & 0x0F);
        } else {
            *b = (*b & 0x0F) | ((v.0 & 0x0F) << 4);
        }
    }

    /// Decode element `i` per eq. (2). Exact in f32.
    #[inline]
    pub fn decode(&self, i: usize) -> f32 {
        if self.scale.is_nan() {
            return f32::NAN;
        }
        self.scale.to_f32() * exp2i((self.l2(i) + self.l3(i)) as i32) * self.elem(i).to_f32()
    }

    /// Decode the whole unit into `out[0..64]`.
    pub fn decode_all(&self, out: &mut [f32]) {
        assert!(
            out.len() >= GROUP,
            "HiF4 unit decodes {} elements; buffer holds {}",
            GROUP,
            out.len()
        );
        if self.scale.is_nan() {
            out[..GROUP].fill(f32::NAN);
            return;
        }
        let s = self.scale.to_f32();
        for i in 0..GROUP {
            out[i] = s * exp2i((self.l2(i) + self.l3(i)) as i32) * self.elem(i).to_f32();
        }
    }

    /// Serialized wire size in bytes (4 metadata + 32 element bytes).
    pub const WIRE_BYTES: usize = 36;

    /// Pack into the 36-byte wire layout of Fig 2 (metadata little-endian:
    /// E6M2, E1_8, E1_16; then 32 element bytes).
    pub fn to_bytes(&self) -> [u8; Self::WIRE_BYTES] {
        let mut b = [0u8; Self::WIRE_BYTES];
        b[0] = self.scale.0;
        b[1] = self.e1_8;
        b[2..4].copy_from_slice(&self.e1_16.to_le_bytes());
        b[4..].copy_from_slice(&self.elems);
        b
    }

    pub fn from_bytes(b: &[u8; Self::WIRE_BYTES]) -> HiF4Unit {
        HiF4Unit {
            scale: E6M2(b[0]),
            e1_8: b[1],
            e1_16: u16::from_le_bytes([b[2], b[3]]),
            elems: b[4..36].try_into().unwrap(),
        }
    }
}

/// Intermediate values of Algorithm 1, exposed for tests and for the
/// hardware-flow documentation benches.
#[derive(Debug, Clone)]
pub struct ConversionTrace {
    /// Stage-1 level-3 local peak magnitudes (16 values over spans of 4).
    pub v16: [f32; 16],
    /// Stage-1 level-2 local peak magnitudes (8 values over spans of 8).
    pub v8: [f32; 8],
    /// Stage-1 global peak magnitude.
    pub vmax: f32,
    /// Line 8: high-precision scale factor `Vmax × (1/7)_BF16`, in BF16.
    pub sf_bf16: f32,
    /// Line 10: `E6M2_REC_to_BF16(E6M2)`.
    pub rec: f32,
}

/// Algorithm 1: convert 64 values into a HiF4 unit. Inputs are first
/// rounded to BF16 (stage 0 — the paper's pipeline consumes BF16; the
/// Pallas kernel and the Rust codec must agree bit-for-bit, see the
/// `qdq_artifact_matches_rust_codec_bit_exactly` integration test).
/// Returns the unit and the intermediate trace.
pub fn quantize_trace(v: &[f32], mode: RoundMode) -> (HiF4Unit, ConversionTrace) {
    assert_eq!(
        v.len(),
        GROUP,
        "HiF4 quantizes exactly {} elements per unit, got {}",
        GROUP,
        v.len()
    );
    let mut v64 = [0f32; GROUP];
    for (o, x) in v64.iter_mut().zip(v) {
        *o = Bf16::from_f32(*x).to_f32();
    }
    let v64 = &v64[..];

    // NaN/Inf in the input poisons the whole unit via the NaN scale, the
    // only NaN channel the format has.
    if v64.iter().any(|x| !x.is_finite()) {
        let unit = HiF4Unit { scale: E6M2::NAN, e1_8: 0, e1_16: 0, elems: [0; 32] };
        let trace = ConversionTrace {
            v16: [0.0; 16],
            v8: [0.0; 8],
            vmax: f32::NAN,
            sf_bf16: f32::NAN,
            rec: f32::NAN,
        };
        return (unit, trace);
    }

    // ---- Stage 1 (lines 1-7): three-level tree reduction of |V|. ----
    let mut v16 = [0f32; 16];
    for i in 0..16 {
        let s = &v64[4 * i..4 * i + 4];
        v16[i] = s.iter().fold(0f32, |m, x| m.max(x.abs()));
    }
    let mut v8 = [0f32; 8];
    for i in 0..8 {
        v8[i] = v16[2 * i].max(v16[2 * i + 1]);
    }
    let vmax = v8.iter().fold(0f32, |m, x| m.max(*x));

    // ---- Stage 2 (lines 8-14): hierarchical scaling metadata. ----
    // Line 8: SF = Vmax × (1/7)_BF16, product rounded to BF16 (the paper's
    // high-precision scale factor is a BF16 quantity).
    let sf_bf16 = Bf16::from_f32_mode(vmax * one_seventh_bf16(), mode).to_f32();
    // Line 9: dedicated BF16→E6M2 instruction.
    let scale = E6M2::from_f32(sf_bf16, mode);
    // Line 10: E6M2_REC via the 4-entry LUT.
    let rec = scale.reciprocal_bf16();
    // Line 11: E1_8 = (V8 × REC > 4) ? 1 : 0 — strict comparison per paper.
    let mut e1_8 = 0u8;
    for i in 0..8 {
        if v8[i] * rec > 4.0 {
            e1_8 |= 1 << i;
        }
    }
    // Lines 12-14: E1_16[k] = (V16[k] × REC × 2^-E1_8[k/2] >= 2) ? 1 : 0.
    let mut e1_16 = 0u16;
    for k in 0..16 {
        let l2 = (e1_8 >> (k / 2)) & 1;
        if v16[k] * rec * exp2i(-(l2 as i32)) >= 2.0 {
            e1_16 |= 1 << k;
        }
    }

    // ---- Stage 3 (lines 15-18): in-group elements. ----
    let mut unit = HiF4Unit { scale, e1_8, e1_16, elems: [0; 32] };
    for i in 0..GROUP {
        let l2 = (e1_8 >> (i / L2_SPAN)) & 1;
        let l3 = (e1_16 >> (i / L3_SPAN)) & 1;
        // Line 16: V64_scaled = V64 × REC × 2^-E1_8 × 2^-E1_16.
        // (BF16 × BF16 products are exact in f32; 2^-E1 is a power of two.)
        let scaled = v64[i] * rec * exp2i(-((l2 + (l3 as u8)) as i32));
        // Line 18: BF16→S1P2 with round + clamp.
        unit.set_elem(i, S1P2::from_f32(scaled, mode));
    }

    let trace = ConversionTrace { v16, v8, vmax, sf_bf16, rec };
    (unit, trace)
}

/// Algorithm 1 without the trace.
pub fn quantize(v64: &[f32], mode: RoundMode) -> HiF4Unit {
    quantize_trace(v64, mode).0
}

/// Quantize→dequantize 64 values (the "simulated quantization" the paper's
/// LLM experiments use).
pub fn quant_dequant(v64: &[f32], out: &mut [f32], mode: RoundMode) {
    let unit = quantize(v64, mode);
    unit.decode_all(out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn qd(v: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; GROUP];
        quant_dequant(v, &mut out, RoundMode::NearestEven);
        out
    }

    #[test]
    fn zeros_stay_zero() {
        let v = vec![0f32; GROUP];
        let out = qd(&v);
        assert!(out.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn table2_extreme_values() {
        // MAX_POSITIVE must survive a roundtrip exactly.
        let mut v = vec![0f32; GROUP];
        v[0] = MAX_POSITIVE;
        let out = qd(&v);
        assert_eq!(out[0], MAX_POSITIVE);
        assert_eq!(MAX_POSITIVE, exp2i(18) * 1.3125);
        assert_eq!(MIN_POSITIVE, exp2i(-50));
    }

    #[test]
    fn peak_maps_near_seven_times_scale() {
        // Algorithm 1 normalizes the group peak towards the intra-group
        // upper bound 7 — full utilization of the local dynamic range.
        let mut rng = Rng::seed(7);
        let mut v: Vec<f32> = (0..GROUP).map(|_| rng.normal() as f32).collect();
        v[13] = 3.0; // make the peak unambiguous
        let (unit, trace) = quantize_trace(&v, RoundMode::NearestEven);
        assert!(!unit.scale.is_nan());
        // E6M2's 2-bit mantissa bounds the normalization slack: the scaled
        // peak lands in (3.4, 8.1] (7 × (1 ± 12.5% rounding slack)).
        let peak_scaled = trace.vmax * trace.rec;
        assert!(peak_scaled <= 8.1 && peak_scaled > 3.4, "peak_scaled={peak_scaled}");
    }

    #[test]
    fn representable_values_roundtrip_exactly() {
        // Any tensor that already lies on a HiF4 grid must roundtrip with
        // zero error when the peak hits the bound 7×scale.
        let scale = 0.5f32; // exactly representable in E6M2 (2^-1 × 1.0)
        let mut v = vec![0f32; GROUP];
        // Elements in the first span get l2=1, l3=1 if peak big enough.
        for (i, x) in v.iter_mut().enumerate() {
            *x = scale * ((i % 7) as f32) * 0.25; // ≤ 1.5×scale, l2=l3=0 grid
        }
        v[0] = scale * 7.0; // peak → SF = scale exactly.
        let out = qd(&v);
        // Peak element: scaled = 7.0 → needs l2=1,l3=1 → 7/4 = 1.75 exact.
        assert_eq!(out[0], v[0]);
        // Elements in spans with micro-exponents 0 stay on the 0.25×scale grid.
        for i in 8..GROUP {
            assert!(
                (out[i] - v[i]).abs() <= 0.125 * scale + 1e-7,
                "i={} in={} out={}",
                i,
                v[i],
                out[i]
            );
        }
    }

    #[test]
    fn nan_poisons_unit() {
        let mut v = vec![1.0f32; GROUP];
        v[5] = f32::NAN;
        let unit = quantize(&v, RoundMode::NearestEven);
        assert!(unit.scale.is_nan());
        let out = qd(&v);
        assert!(out.iter().all(|x| x.is_nan()));
    }

    #[test]
    fn micro_exponents_capture_outliers() {
        // One hot span of big values + tiny elsewhere: micro-exponents must
        // differ between spans.
        let mut v = vec![0.01f32; GROUP];
        for x in v.iter_mut().take(8) {
            *x = 5.0;
        }
        let (unit, _) = quantize_trace(&v, RoundMode::NearestEven);
        assert_eq!(unit.e1_8 & 1, 1, "hot span should set its level-2 bit");
        assert_eq!(unit.e1_8 >> 1, 0, "cold spans should not");
        // Relative error on the hot span stays small (3-bit significand).
        let out = qd(&v);
        for i in 0..8 {
            let rel = (out[i] - v[i]).abs() / v[i];
            assert!(rel < 0.08, "i={i} rel={rel}");
        }
    }

    #[test]
    fn wire_roundtrip() {
        let mut rng = Rng::seed(42);
        let v: Vec<f32> = (0..GROUP).map(|_| rng.normal() as f32 * 3.0).collect();
        let unit = quantize(&v, RoundMode::NearestEven);
        let back = HiF4Unit::from_bytes(&unit.to_bytes());
        assert_eq!(unit, back);
    }

    #[test]
    fn storage_cost_is_4_5_bits() {
        let total_bits = HiF4Unit::WIRE_BYTES * 8;
        assert_eq!(total_bits as f64 / GROUP as f64, BITS_PER_VALUE);
    }

    #[test]
    fn quantization_error_bounded_gaussian() {
        // Quantization error of a Gaussian group must be well below σ and
        // every output within the clamp bound of the input peak.
        let mut rng = Rng::seed(3);
        for _ in 0..50 {
            let v: Vec<f32> = (0..GROUP).map(|_| (rng.normal() as f32) * 0.01).collect();
            let out = qd(&v);
            let mse: f32 =
                v.iter().zip(&out).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / GROUP as f32;
            assert!(mse.sqrt() < 0.01 * 0.25, "rmse too big: {}", mse.sqrt());
        }
    }

    #[test]
    fn huge_and_tiny_values_direct_cast_survive() {
        // The 69-binade global range (Table II) means direct cast handles
        // magnitudes NVFP4 cannot. Peak 2^17, tiny 2^-40.
        let mut v = vec![2f32.powi(-40); GROUP];
        v[0] = 2f32.powi(17);
        let out = qd(&v);
        let rel = (out[0] - v[0]).abs() / v[0];
        assert!(rel < 0.1, "huge peak rel err {rel}");
        // Tiny values quantize to 0 relative to this peak — but no NaN/Inf.
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
