//! MX4 — Microsoft/Meta's shared-micro-exponent 4-bit format (§I, Fig 1).
//!
//! Group of 16: one shared 8-bit exponent + 8 × 1-bit micro-exponents (one
//! per sub-group of 2) + 16 × 3-bit sign-magnitude elements (S1P1) ⇒
//! (8 + 8 + 48)/16 = 4 bits/value. Implemented for the intro's comparison
//! claims (MX4 underperforms even vanilla BFP because the 3-bit element has
//! only a 2-bit significand); exercised by the ablation bench.

use super::e8m0::{floor_log2, E8M0};
use super::rounding::{round_int, RoundMode};

/// Elements per MX4 group.
pub const GROUP: usize = 16;
/// Elements per micro-exponent.
pub const SUB: usize = 2;
/// Average storage cost.
pub const BITS_PER_VALUE: f64 = 4.0;
/// S1P1 max magnitude: 1.5 (sign + 1 integer + 1 fraction bit).
pub const ELEM_MAX: f32 = 1.5;
/// S1P1 grid step.
pub const ELEM_STEP: f32 = 0.5;
/// Largest power-of-two exponent of S1P1: 1.5 = 1.5 × 2^0.
pub const EMAX_ELEM: i32 = 0;

/// A packed MX4 group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mx4Group {
    /// Shared power-of-two scale.
    pub scale: E8M0,
    /// Micro-exponent bits: bit `j` covers elements `[2j, 2j+2)`. A set bit
    /// means the sub-group uses the *finer* scale 2^(E-1) (one extra bit of
    /// effective precision for small sub-groups).
    pub micro: u8,
    /// 16 × 3-bit S1P1 elements, stored one per byte (`s_mm`).
    pub elems: [u8; 16],
}

impl Mx4Group {
    /// Signed element value in halves (-3..=3).
    #[inline]
    pub fn signed_h(&self, i: usize) -> i8 {
        let e = self.elems[i];
        let m = (e & 0b011) as i8;
        if e & 0b100 != 0 {
            -m
        } else {
            m
        }
    }

    #[inline]
    pub fn micro_down(&self, i: usize) -> i32 {
        ((self.micro >> (i / SUB)) & 1) as i32
    }

    #[inline]
    pub fn decode(&self, i: usize) -> f32 {
        self.scale.to_f32() * 2f32.powi(-self.micro_down(i)) * (self.signed_h(i) as f32 * ELEM_STEP)
    }

    pub fn decode_all(&self, out: &mut [f32]) {
        for i in 0..GROUP {
            out[i] = self.decode(i);
        }
    }
}

/// Quantize 16 values into an MX4 group.
///
/// Shared exponent from the group peak (OCP-style rule with S1P1's emax=0);
/// each sub-group of 2 drops to the finer scale when its own peak fits.
pub fn quantize(v: &[f32], mode: RoundMode) -> Mx4Group {
    assert_eq!(v.len(), GROUP, "MX4 quantizes exactly 16 elements");
    if v.iter().any(|x| !x.is_finite()) {
        return Mx4Group { scale: E8M0::NAN, micro: 0, elems: [0; 16] };
    }
    let amax = v.iter().fold(0f32, |m, x| m.max(x.abs()));
    if amax == 0.0 {
        return Mx4Group { scale: E8M0(0), micro: 0, elems: [0; 16] };
    }
    // Scale so the peak lies in (0.75, 1.5]: E = floor(log2(amax)) keeps
    // peak/2^E in [1, 2) which can clip at 1.5; follow the OCP convention
    // (clip the top lobe) like MXFP4 does.
    let e = floor_log2(amax) - EMAX_ELEM;
    let scale = E8M0(e.clamp(-127, 127).wrapping_add(127) as u8);
    let s = scale.to_f32();
    let mut g = Mx4Group { scale, micro: 0, elems: [0; 16] };
    for j in 0..GROUP / SUB {
        let sub = &v[SUB * j..SUB * j + SUB];
        let speak = sub.iter().fold(0f32, |m, x| m.max(x.abs()));
        // Fine scale (2^(E-1)) iff the sub-group still fits: peak ≤ 1.5×2^(E-1).
        if speak <= ELEM_MAX * s * 0.5 {
            g.micro |= 1 << j;
        }
        let eff = s * if g.micro >> j & 1 == 1 { 0.5 } else { 1.0 };
        for k in 0..SUB {
            let i = SUB * j + k;
            let q = round_int(v[i] / (eff * ELEM_STEP), mode);
            let neg = q < 0.0;
            let mag = (q.abs() as u8).min(3);
            g.elems[i] = ((neg as u8) << 2) | mag;
        }
    }
    g
}

/// Quantize→dequantize (simulated quantization).
pub fn quant_dequant(v: &[f32], out: &mut [f32], mode: RoundMode) {
    let g = quantize(v, mode);
    if g.scale.is_nan() {
        out[..GROUP].fill(f32::NAN);
        return;
    }
    g.decode_all(out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn qd(v: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; GROUP];
        quant_dequant(v, &mut out, RoundMode::NearestEven);
        out
    }

    #[test]
    fn zeros_stay_zero() {
        assert!(qd(&[0.0; GROUP]).iter().all(|x| *x == 0.0));
    }

    #[test]
    fn exact_grid_roundtrip() {
        // Values on the coarse grid with peak 1.5 reproduce exactly.
        let v: [f32; GROUP] = core::array::from_fn(|i| ((i % 4) as f32) * 0.5 - 0.5);
        let out = qd(&v);
        for (a, b) in v.iter().zip(&out) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn micro_exponent_helps_small_subgroups() {
        let mut v = [0.11f32; GROUP];
        v[0] = 1.5; // peak: scale 2^0, coarse step 0.5.
        let g = quantize(&v, RoundMode::NearestEven);
        assert_eq!(g.micro & 1, 0, "peak sub-group must stay coarse");
        assert_eq!(g.micro >> 1, 0x7F, "small sub-groups go fine");
        let out = qd(&v);
        // Fine step is 0.25 → 0.11 rounds to 0.25·0 or 0.25; coarse would
        // round to 0 always.
        assert!(out[2] == 0.0 || out[2] == 0.25);
    }

    #[test]
    fn worse_than_4bit_formats_on_gaussian() {
        // The intro's claim: MX4's 3-bit element hurts accuracy.
        let mut rng = Rng::seed(17);
        let n = 128 * GROUP;
        let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut mx4 = 0f64;
        let mut out = vec![0f32; GROUP];
        for c in v.chunks(GROUP) {
            quant_dequant(c, &mut out, RoundMode::NearestEven);
            for (a, b) in c.iter().zip(&out) {
                mx4 += ((a - b) as f64).powi(2);
            }
        }
        let mut hif4 = 0f64;
        let mut out64 = vec![0f32; crate::formats::hif4::GROUP];
        for c in v.chunks(crate::formats::hif4::GROUP) {
            crate::formats::hif4::quant_dequant(c, &mut out64, RoundMode::NearestEven);
            for (a, b) in c.iter().zip(&out64) {
                hif4 += ((a - b) as f64).powi(2);
            }
        }
        assert!(mx4 > 2.0 * hif4, "MX4 mse {mx4} should be far above HiF4 {hif4}");
    }
}
