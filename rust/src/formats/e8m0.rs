//! E8M0 — the OCP MX power-of-two shared scale (8-bit exponent, no mantissa).
//!
//! Used by MXFP4 (group 32) and, conceptually, by MX4 / vanilla BFP's shared
//! exponents. Encodes 2^(e-127) for e ∈ [0, 254]; 0xFF is NaN.

/// An E8M0 scale in its 8 raw bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct E8M0(pub u8);

/// Exponent bias.
pub const BIAS: i32 = 127;

impl E8M0 {
    pub const NAN: E8M0 = E8M0(0xFF);
    pub const ONE: E8M0 = E8M0(127);

    #[inline]
    pub fn is_nan(self) -> bool {
        self.0 == 0xFF
    }

    /// Unbiased exponent.
    #[inline]
    pub fn exponent(self) -> i32 {
        self.0 as i32 - BIAS
    }

    /// Decode to f32. Exponents beyond f32's normal range saturate into
    /// subnormals/infinity like `powi` would; MX usage keeps |e| small.
    #[inline]
    pub fn to_f32(self) -> f32 {
        if self.is_nan() {
            return f32::NAN;
        }
        2f32.powi(self.exponent())
    }

    /// Encode the power-of-two scale for a group with peak magnitude `amax`
    /// and element format max-exponent `emax_elem`, per the OCP MX spec:
    /// `shared_exp = floor(log2(amax)) - emax_elem`, clamped to range.
    /// `amax == 0` (all-zero group) maps to the smallest scale.
    pub fn from_amax(amax: f32, emax_elem: i32) -> E8M0 {
        if amax.is_nan() {
            return E8M0::NAN;
        }
        if amax <= 0.0 {
            return E8M0(0);
        }
        let e = floor_log2(amax) - emax_elem;
        E8M0(e.clamp(-BIAS, 127).wrapping_add(BIAS) as u8)
    }
}

/// Exact floor(log2(|x|)) for finite positive x via bit inspection
/// (handles subnormals; avoids float log precision traps).
pub fn floor_log2(x: f32) -> i32 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32;
    if exp != 0 {
        exp - 127
    } else {
        // Subnormal: value = mantissa × 2^-149.
        let m = bits & 0x7F_FFFF;
        -149 + (31 - m.leading_zeros()) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_basics() {
        assert_eq!(E8M0::ONE.to_f32(), 1.0);
        assert_eq!(E8M0(128).to_f32(), 2.0);
        assert_eq!(E8M0(126).to_f32(), 0.5);
        assert!(E8M0::NAN.to_f32().is_nan());
    }

    #[test]
    fn floor_log2_exact() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(1.99), 0);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(0.5), -1);
        assert_eq!(floor_log2(0.9999), -1);
        assert_eq!(floor_log2(6.0), 2);
        assert_eq!(floor_log2(2f32.powi(-126)), -126);
        // Subnormals (constructed from bits: debug-mode powi(-130)
        // round-trips through 1/2^130 = 1/inf = 0).
        assert_eq!(floor_log2(f32::from_bits(0x0040_0000)), -127); // 2^-127
        assert_eq!(floor_log2(f32::from_bits(0x0008_0000)), -130); // 2^-130
        assert_eq!(floor_log2(f32::from_bits(0x0000_0001)), -149); // min sub
    }

    #[test]
    fn from_amax_mx_rule() {
        // E2M1 emax = 2 (6 = 1.5 × 2^2). amax = 6 -> floor(log2 6)=2 -> e=0.
        assert_eq!(E8M0::from_amax(6.0, 2).to_f32(), 1.0);
        // amax = 1.0 -> 0 - 2 = -2 -> scale 0.25.
        assert_eq!(E8M0::from_amax(1.0, 2).to_f32(), 0.25);
        // amax = 0 -> smallest scale, elements all quantize to 0 anyway.
        assert_eq!(E8M0::from_amax(0.0, 2).0, 0);
    }
}
