//! E2M1 — the 4-bit element format of NVFP4 and MXFP4 (OCP FP4).
//!
//! sign + 2 exponent bits (bias 1) + 1 mantissa bit, with subnormals:
//! representable magnitudes {0, 0.5, 1, 1.5, 2, 3, 4, 6}. Max 6, min positive
//! 0.5 ⇒ dynamic range log2(6/0.5) = 3.58 binades (§I). No NaN/Inf in the
//! element itself (NVFP4 signals NaN via its scale).

use super::rounding::RoundMode;

/// An E2M1 value in its 4 raw bits (`s_ee_m`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct E2M1(pub u8);

/// The 8 non-negative representable magnitudes, in encoding order.
pub const MAGNITUDES: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
/// Largest magnitude.
pub const MAX_ABS: f32 = 6.0;
/// Smallest positive magnitude.
pub const MIN_POS: f32 = 0.5;

impl E2M1 {
    pub const POS_ZERO: E2M1 = E2M1(0b0000);
    pub const MAX: E2M1 = E2M1(0b0111);
    pub const MIN: E2M1 = E2M1(0b1111);

    #[inline]
    pub fn sign_negative(self) -> bool {
        self.0 & 0b1000 != 0
    }

    /// Magnitude code 0..=7 indexing [`MAGNITUDES`].
    #[inline]
    pub fn mag_code(self) -> usize {
        (self.0 & 0b0111) as usize
    }

    /// Decode to f32 (exact).
    #[inline]
    pub fn to_f32(self) -> f32 {
        let m = MAGNITUDES[self.mag_code()];
        if self.sign_negative() {
            -m
        } else {
            m
        }
    }

    /// The signed integer the NVFP4 fixed-point datapath multiplies: the
    /// magnitude in half-units (value × 2), range -12..=12 (fits S3P1's
    /// 5-bit signed integer view used in Fig 4).
    #[inline]
    pub fn signed_halves(self) -> i8 {
        let m = (MAGNITUDES[self.mag_code()] * 2.0) as i8;
        if self.sign_negative() {
            -m
        } else {
            m
        }
    }

    /// Quantize with round-to-nearest (RNE/RHAZ on the non-uniform grid) and
    /// saturation to ±6.
    ///
    /// Arithmetic form (hot path, §Perf): within each binade the grid is
    /// uniform — step 0.5 below 2, 1 in [2,4), 2 above — and rounding
    /// `a/ulp` to an integer is exactly tie-to-even-mantissa because even
    /// multiples of the ulp are the even-code values (same derivation as
    /// the Pallas kernel's `e2m1_quantize`).
    pub fn from_f32(x: f32, mode: RoundMode) -> E2M1 {
        if x.is_nan() {
            return E2M1::MAX;
        }
        let neg = x.is_sign_negative();
        let a = x.abs();
        let ulp = if a < 2.0 {
            0.5
        } else if a < 4.0 {
            1.0
        } else {
            2.0
        };
        let q = (super::rounding::round_int(a / ulp, mode) * ulp).min(MAX_ABS);
        // Value → code (halves: 0,1,2,3,4,6,8,12 → codes 0..7).
        let h = (q * 2.0) as u32;
        let code = match h {
            0..=3 => h,
            4 => 4,
            6 => 5,
            8 => 6,
            _ => 7,
        } as u8;
        E2M1(((neg as u8) << 3) | code)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_all_magnitudes() {
        for (code, want) in MAGNITUDES.iter().enumerate() {
            assert_eq!(E2M1(code as u8).to_f32(), *want);
            assert_eq!(E2M1(code as u8 | 0b1000).to_f32(), -*want);
        }
    }

    #[test]
    fn exhaustive_roundtrip() {
        for bits in 0u8..16 {
            let v = E2M1(bits);
            let back = E2M1::from_f32(v.to_f32(), RoundMode::NearestEven);
            assert_eq!(back.to_f32(), v.to_f32());
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(E2M1::from_f32(100.0, RoundMode::NearestEven).to_f32(), 6.0);
        assert_eq!(E2M1::from_f32(-7.0, RoundMode::NearestEven).to_f32(), -6.0);
    }

    #[test]
    fn nearest_rounding() {
        assert_eq!(E2M1::from_f32(0.2, RoundMode::NearestEven).to_f32(), 0.0);
        assert_eq!(E2M1::from_f32(0.3, RoundMode::NearestEven).to_f32(), 0.5);
        assert_eq!(E2M1::from_f32(2.4, RoundMode::NearestEven).to_f32(), 2.0);
        assert_eq!(E2M1::from_f32(2.6, RoundMode::NearestEven).to_f32(), 3.0);
        assert_eq!(E2M1::from_f32(5.1, RoundMode::NearestEven).to_f32(), 6.0);
    }

    #[test]
    fn tie_handling() {
        // 2.5 ties between 2 (code 4, m=0 even) and 3 (code 5, m=1 odd).
        assert_eq!(E2M1::from_f32(2.5, RoundMode::NearestEven).to_f32(), 2.0);
        assert_eq!(E2M1::from_f32(2.5, RoundMode::HalfAwayFromZero).to_f32(), 3.0);
        // 0.25 ties between 0 (even) and 0.5 (odd).
        assert_eq!(E2M1::from_f32(0.25, RoundMode::NearestEven).to_f32(), 0.0);
        // 5.0 ties between 4 (code 6 even) and 6 (code 7 odd).
        assert_eq!(E2M1::from_f32(5.0, RoundMode::NearestEven).to_f32(), 4.0);
    }

    #[test]
    fn signed_halves_match() {
        for bits in 0u8..16 {
            let v = E2M1(bits);
            assert_eq!(v.signed_halves() as f32 * 0.5, v.to_f32());
        }
    }

    #[test]
    fn dynamic_range_is_3_58_binades() {
        let binades = (MAX_ABS / MIN_POS).log2();
        assert!((binades - 3.58).abs() < 0.01);
    }
}
