//! NVFP4 — NVIDIA Blackwell's proprietary 4-bit BFP format (§I, Table II).
//!
//! Group of 16 [`E2M1`] elements sharing one FP8-[`E4M3`] scale ⇒ 4.5
//! bits/value. The scale normalizes each group's peak magnitude to 6 (E2M1's
//! upper bound). Global dynamic range is only 22 binades ([-10, 11]); tensors
//! exceeding it need software **per-tensor scaling** (PTS): pre-scale the
//! tensor so its peak magnitude is 2688 = 6 × 448 before quantizing, undo the
//! scale at dequantization. Both direct-cast and PTS paths are implemented —
//! Fig 3 and the LLM tables evaluate both.

use super::e2m1::{self, E2M1};
use super::e4m3::E4M3;
use super::rounding::RoundMode;

/// Elements per NVFP4 group.
pub const GROUP: usize = 16;
/// Average storage cost (16×4 + 8)/16.
pub const BITS_PER_VALUE: f64 = 4.5;
/// Peak magnitude PTS normalizes a tensor to: 6 × 448.
pub const PTS_TARGET: f32 = 2688.0;
/// Max positive value: 448 × 6 = 2^11 × 1.3125 (Table II).
pub const MAX_POSITIVE: f32 = 2688.0;
/// Min positive value: 2^-9 (min subnormal scale) × 0.5 = 2^-10 (Table II).
pub const MIN_POSITIVE: f32 = 0.0009765625;

/// A packed NVFP4 group: one E4M3 scale + 16 E2M1 nibbles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Nvfp4Group {
    pub scale: E4M3,
    /// 16 E2M1 elements packed two per byte (low nibble = even index).
    pub elems: [u8; 8],
}

impl Nvfp4Group {
    #[inline]
    pub fn elem(&self, i: usize) -> E2M1 {
        let b = self.elems[i / 2];
        E2M1(if i % 2 == 0 { b & 0x0F } else { b >> 4 })
    }

    #[inline]
    pub fn set_elem(&mut self, i: usize, v: E2M1) {
        let b = &mut self.elems[i / 2];
        if i % 2 == 0 {
            *b = (*b & 0xF0) | (v.0 & 0x0F);
        } else {
            *b = (*b & 0x0F) | ((v.0 & 0x0F) << 4);
        }
    }

    /// Decode element `i`: scale × element.
    #[inline]
    pub fn decode(&self, i: usize) -> f32 {
        self.scale.to_f32() * self.elem(i).to_f32()
    }

    pub fn decode_all(&self, out: &mut [f32]) {
        assert!(
            out.len() >= GROUP,
            "NVFP4 group decodes {} elements; buffer holds {}",
            GROUP,
            out.len()
        );
        let s = self.scale.to_f32();
        for i in 0..GROUP {
            out[i] = s * self.elem(i).to_f32();
        }
    }
}

/// Quantize 16 values into an NVFP4 group (direct cast).
///
/// Scale = saturating E4M3 cast of `amax / 6`. The two range-failure modes
/// the paper highlights are faithfully reproduced:
/// * `amax/6 > 448` → the scale saturates at 448 and elements clip at ±6;
/// * `amax/6` below half the min subnormal → the scale rounds to **zero**
///   and the whole group decodes to zero.
pub fn quantize(v: &[f32], mode: RoundMode) -> Nvfp4Group {
    assert_eq!(
        v.len(),
        GROUP,
        "NVFP4 quantizes exactly {} elements per group, got {}",
        GROUP,
        v.len()
    );
    if v.iter().any(|x| !x.is_finite()) {
        return Nvfp4Group { scale: E4M3::NAN, elems: [0; 8] };
    }
    let amax = v.iter().fold(0f32, |m, x| m.max(x.abs()));
    let scale = E4M3::from_f32(amax / e2m1::MAX_ABS, mode);
    let s = scale.to_f32();
    let inv = if s > 0.0 { 1.0 / s } else { 0.0 };
    let mut g = Nvfp4Group { scale, elems: [0; 8] };
    for i in 0..GROUP {
        g.set_elem(i, E2M1::from_f32(v[i] * inv, mode));
    }
    g
}

/// Quantize→dequantize one group in place (simulated quantization).
pub fn quant_dequant(v: &[f32], out: &mut [f32], mode: RoundMode) {
    let g = quantize(v, mode);
    if g.scale.is_nan() {
        out[..GROUP].fill(f32::NAN);
        return;
    }
    g.decode_all(out);
}

/// Compute the per-tensor scale PTS applies before NVFP4 quantization:
/// `t` s.t. `amax(tensor) × t = 2688`; identity for empty/zero tensors.
pub fn pts_scale(tensor: &[f32]) -> f32 {
    let amax = tensor.iter().fold(0f32, |m, x| m.max(x.abs()));
    if amax > 0.0 && amax.is_finite() {
        PTS_TARGET / amax
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn qd(v: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; GROUP];
        quant_dequant(v, &mut out, RoundMode::NearestEven);
        out
    }

    #[test]
    fn zeros_stay_zero() {
        assert!(qd(&[0.0; GROUP]).iter().all(|x| *x == 0.0));
    }

    #[test]
    fn table2_constants() {
        assert_eq!(MAX_POSITIVE, 2f32.powi(11) * 1.3125);
        assert_eq!(MIN_POSITIVE, 2f32.powi(-10));
        // Table II counts exponent span [-10, 11] ⇒ ~22 binades.
        let binades = (MAX_POSITIVE / MIN_POSITIVE).log2();
        assert!((binades - 21.39).abs() < 0.01, "≈22 binades global range, got {binades}");
    }

    #[test]
    fn peak_normalizes_to_six() {
        let mut v = [0.5f32; GROUP];
        v[3] = 48.0; // amax/6 = 8, exactly representable in E4M3.
        let g = quantize(&v, RoundMode::NearestEven);
        assert_eq!(g.scale.to_f32(), 8.0);
        assert_eq!(g.elem(3).to_f32(), 6.0);
        assert_eq!(g.decode(3), 48.0);
    }

    #[test]
    fn overflow_crash_mode() {
        // amax = 2^13: scale saturates at 448, peak clips at 448×6=2688.
        let mut v = [1.0f32; GROUP];
        v[0] = 8192.0;
        let out = qd(&v);
        assert_eq!(out[0], 2688.0, "clipped to the NVFP4 max");
        let rel = (out[0] - v[0]).abs() / v[0];
        assert!(rel > 0.5, "catastrophic clipping is the expected failure");
    }

    #[test]
    fn underflow_crash_mode() {
        // amax/6 < 2^-10 → scale quantizes to zero → group wiped out.
        let v = [2f32.powi(-14); GROUP];
        let out = qd(&v);
        assert!(out.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn pts_rescues_overflow() {
        let mut v = vec![1.0f32; GROUP];
        v[0] = 8192.0;
        let t = pts_scale(&v);
        assert_eq!(t * 8192.0, PTS_TARGET);
        let scaled: Vec<f32> = v.iter().map(|x| x * t).collect();
        let mut out = vec![0f32; GROUP];
        quant_dequant(&scaled, &mut out, RoundMode::NearestEven);
        let back: Vec<f32> = out.iter().map(|x| x / t).collect();
        let rel = (back[0] - v[0]).abs() / v[0];
        assert!(rel < 0.05, "PTS must rescue the peak, rel={rel}");
    }

    #[test]
    fn gaussian_error_reasonable() {
        let mut rng = Rng::seed(11);
        for _ in 0..50 {
            let v: Vec<f32> = (0..GROUP).map(|_| rng.normal() as f32).collect();
            let out = qd(&v);
            for (a, b) in v.iter().zip(&out) {
                assert!((a - b).abs() <= 0.3 * a.abs().max(0.6), "{a} -> {b}");
            }
        }
    }

    #[test]
    fn nan_poisons_group() {
        let mut v = [1.0f32; GROUP];
        v[7] = f32::INFINITY;
        assert!(qd(&v).iter().all(|x| x.is_nan()));
    }
}
