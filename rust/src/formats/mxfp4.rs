//! MXFP4 — the OCP Microscaling 4-bit format (§I, Fig 1).
//!
//! Group of 32 [`E2M1`] elements sharing one power-of-two [`E8M0`] scale ⇒
//! 4.25 bits/value. Quantization follows the OCP MX spec / Microscaling
//! paper [13]: `shared_exp = floor(log2(amax)) − emax(E2M1)`, elements
//! round-to-nearest with saturation. The power-of-two scale cannot normalize
//! the group peak to E2M1's upper bound (up to 1 binade of the intra-group
//! range is wasted) — the effect Fig 3's 1.89× MSE ratio quantifies.

use super::e2m1::E2M1;
use super::e8m0::E8M0;
use super::rounding::RoundMode;

/// Elements per MXFP4 group.
pub const GROUP: usize = 32;
/// Average storage cost (32×4 + 8)/32.
pub const BITS_PER_VALUE: f64 = 4.25;
/// E2M1's largest power-of-two exponent: 6 = 1.5 × 2^2.
pub const EMAX_ELEM: i32 = 2;

/// A packed MXFP4 group: one E8M0 scale + 32 E2M1 nibbles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mxfp4Group {
    pub scale: E8M0,
    pub elems: [u8; 16],
}

impl Mxfp4Group {
    #[inline]
    pub fn elem(&self, i: usize) -> E2M1 {
        let b = self.elems[i / 2];
        E2M1(if i % 2 == 0 { b & 0x0F } else { b >> 4 })
    }

    #[inline]
    pub fn set_elem(&mut self, i: usize, v: E2M1) {
        let b = &mut self.elems[i / 2];
        if i % 2 == 0 {
            *b = (*b & 0xF0) | (v.0 & 0x0F);
        } else {
            *b = (*b & 0x0F) | ((v.0 & 0x0F) << 4);
        }
    }

    #[inline]
    pub fn decode(&self, i: usize) -> f32 {
        self.scale.to_f32() * self.elem(i).to_f32()
    }

    pub fn decode_all(&self, out: &mut [f32]) {
        assert!(out.len() >= GROUP);
        let s = self.scale.to_f32();
        for i in 0..GROUP {
            out[i] = s * self.elem(i).to_f32();
        }
    }
}

/// Quantize 32 values into an MXFP4 group per the OCP MX rule.
pub fn quantize(v: &[f32], mode: RoundMode) -> Mxfp4Group {
    assert_eq!(v.len(), GROUP, "MXFP4 quantizes exactly 32 elements");
    if v.iter().any(|x| !x.is_finite()) {
        return Mxfp4Group { scale: E8M0::NAN, elems: [0; 16] };
    }
    let amax = v.iter().fold(0f32, |m, x| m.max(x.abs()));
    let scale = E8M0::from_amax(amax, EMAX_ELEM);
    let s = scale.to_f32();
    let inv = 1.0 / s; // power of two: exact
    let mut g = Mxfp4Group { scale, elems: [0; 16] };
    for i in 0..GROUP {
        g.set_elem(i, E2M1::from_f32(v[i] * inv, mode));
    }
    g
}

/// Quantize→dequantize (simulated quantization).
pub fn quant_dequant(v: &[f32], out: &mut [f32], mode: RoundMode) {
    let g = quantize(v, mode);
    if g.scale.is_nan() {
        out[..GROUP].fill(f32::NAN);
        return;
    }
    g.decode_all(out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn qd(v: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; GROUP];
        quant_dequant(v, &mut out, RoundMode::NearestEven);
        out
    }

    #[test]
    fn zeros_stay_zero() {
        assert!(qd(&[0.0; GROUP]).iter().all(|x| *x == 0.0));
    }

    #[test]
    fn pow2_peak_is_exact() {
        let mut v = [0.5f32; GROUP];
        v[0] = 4.0; // floor(log2 4)=2 → scale=1 → elements 4 and 0.5 exact.
        let out = qd(&v);
        assert_eq!(out[0], 4.0);
        assert_eq!(out[1], 0.5);
    }

    #[test]
    fn scale_wastes_up_to_one_binade() {
        // amax = 7.9: floor(log2)=2 → scale 2^0; 7.9 clips to 6 — the
        // power-of-two scale cannot normalize the peak to 6.
        let mut v = [0.5f32; GROUP];
        v[0] = 7.9;
        let g = quantize(&v, RoundMode::NearestEven);
        assert_eq!(g.scale.to_f32(), 1.0);
        assert_eq!(g.decode(0), 6.0, "peak clipped");
    }

    #[test]
    fn wide_global_range() {
        // E8M0 spans 2^-127..2^127: no NVFP4-style overflow crash.
        let mut v = [1.0f32; GROUP];
        v[0] = 2f32.powi(20);
        let out = qd(&v);
        let rel = (out[0] - v[0]).abs() / v[0];
        assert!(rel < 0.34, "no catastrophic clipping, rel={rel}");
    }

    #[test]
    fn gaussian_mse_worse_than_nvfp4() {
        // Fig 3: MXFP4 ≈ 1.89×, NVFP4 ≈ 1.32× HiF4's MSE. Check ordering.
        let mut rng = Rng::seed(5);
        let n = 64 * GROUP;
        let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mse = |f: &dyn Fn(&[f32], &mut [f32])| -> f64 {
            let mut acc = 0f64;
            let mut out = vec![0f32; GROUP.max(crate::formats::nvfp4::GROUP)];
            for chunk in v.chunks(GROUP) {
                f(chunk, &mut out);
                for (a, b) in chunk.iter().zip(&out) {
                    acc += ((a - b) as f64).powi(2);
                }
            }
            acc / n as f64
        };
        let mx = mse(&|c, o| quant_dequant(c, o, RoundMode::NearestEven));
        let mut nv_acc = 0f64;
        let mut out = vec![0f32; crate::formats::nvfp4::GROUP];
        for chunk in v.chunks(crate::formats::nvfp4::GROUP) {
            crate::formats::nvfp4::quant_dequant(chunk, &mut out, RoundMode::NearestEven);
            for (a, b) in chunk.iter().zip(&out) {
                nv_acc += ((a - b) as f64).powi(2);
            }
        }
        let nv = nv_acc / n as f64;
        assert!(mx > nv, "MXFP4 MSE {mx} should exceed NVFP4 MSE {nv}");
    }

    #[test]
    fn nan_poisons_group() {
        let mut v = [1.0f32; GROUP];
        v[31] = f32::NAN;
        assert!(qd(&v).iter().all(|x| x.is_nan()));
    }
}
