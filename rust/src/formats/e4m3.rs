//! E4M3 — the FP8 scale format of NVFP4 (and general FP8 support).
//!
//! OCP FP8-E4M3: sign + 4 exponent bits (bias 7) + 3 mantissa bits, with
//! subnormals; max finite 448, min positive subnormal 2^-9; `S.1111.111` is
//! NaN (no infinity). NVFP4 uses it *unsigned* as a per-16-group scale —
//! amax/6 is cast with saturation, which is exactly where the paper's
//! "PTS required" critique bites: tensors whose group scales exceed 448 (or
//! underflow to zero) lose information.

use super::rounding::{round_int, RoundMode};

/// Exponent bias.
pub const BIAS: i32 = 7;
/// Max finite magnitude (0x7E = 448).
pub const MAX_FINITE: f32 = 448.0;
/// Min positive subnormal = 2^-6 × 1/8 = 2^-9.
pub const MIN_SUBNORMAL: f32 = 0.001953125;
/// Min positive normal = 2^-6.
pub const MIN_NORMAL: f32 = 0.015625;

/// An E4M3 value in its 8 raw bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct E4M3(pub u8);

impl E4M3 {
    pub const POS_ZERO: E4M3 = E4M3(0x00);
    pub const MAX: E4M3 = E4M3(0x7E);
    pub const NAN: E4M3 = E4M3(0x7F);

    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7F) == 0x7F
    }

    #[inline]
    pub fn sign_negative(self) -> bool {
        self.0 & 0x80 != 0
    }

    /// Decode to f32 (exact).
    pub fn to_f32(self) -> f32 {
        if self.is_nan() {
            return f32::NAN;
        }
        let e = ((self.0 >> 3) & 0x0F) as i32;
        let m = (self.0 & 0x07) as f32;
        let mag = if e == 0 {
            // Subnormal: 2^(1-bias) × (m/8).
            2f32.powi(1 - BIAS) * (m / 8.0)
        } else {
            2f32.powi(e - BIAS) * (1.0 + m / 8.0)
        };
        if self.sign_negative() {
            -mag
        } else {
            mag
        }
    }

    /// Encode with saturation (NVIDIA's saturating cast: out-of-range maps
    /// to ±448, never NaN; NaN in → NaN out).
    pub fn from_f32(x: f32, mode: RoundMode) -> E4M3 {
        if x.is_nan() {
            return E4M3::NAN;
        }
        let neg = x.is_sign_negative();
        let sign = (neg as u8) << 7;
        let a = x.abs();
        if a >= MAX_FINITE {
            return E4M3(sign | E4M3::MAX.0);
        }
        if a < MIN_NORMAL {
            // Subnormal grid: step 2^-9.
            let q = round_int(a / MIN_SUBNORMAL, mode).min(8.0);
            if q >= 8.0 {
                // Rounded up into the normal range.
                return E4M3(sign | 0x08);
            }
            return E4M3(sign | q as u8);
        }
        // Normal: find exponent (exact bit inspection, §Perf), round the
        // 3-bit mantissa.
        let e = super::e8m0::floor_log2(a);
        let s = a / super::e6m2::exp2i(e);
        let mut q = round_int(s * 8.0, mode); // in eighths, [8, 16]
        let mut ee = e;
        if q >= 16.0 {
            q = 8.0;
            ee += 1;
        }
        if ee > 8 {
            return E4M3(sign | E4M3::MAX.0);
        }
        let enc = (((ee + BIAS) as u8) << 3) | ((q as u8) - 8);
        if (enc & 0x7F) == 0x7F {
            // Would alias NaN (448 + rounding up to "480"): saturate.
            E4M3(sign | E4M3::MAX.0)
        } else {
            E4M3(sign | enc)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_values() {
        assert_eq!(E4M3::MAX.to_f32(), 448.0);
        assert!(E4M3::NAN.to_f32().is_nan());
        assert_eq!(E4M3(0x01).to_f32(), MIN_SUBNORMAL);
        assert_eq!(E4M3(0x08).to_f32(), MIN_NORMAL);
        assert_eq!(E4M3::POS_ZERO.to_f32(), 0.0);
    }

    #[test]
    fn exhaustive_roundtrip() {
        for bits in 0u16..=255 {
            let v = E4M3(bits as u8);
            if v.is_nan() {
                continue;
            }
            let back = E4M3::from_f32(v.to_f32(), RoundMode::NearestEven);
            assert_eq!(back.to_f32(), v.to_f32(), "code {bits:#04x}");
        }
    }

    #[test]
    fn monotone_decode() {
        let mut prev = -1.0f32;
        for bits in 0u8..0x7F {
            let f = E4M3(bits).to_f32();
            assert!(f > prev, "non-monotone at {bits:#04x}");
            prev = f;
        }
    }

    #[test]
    fn saturating_cast() {
        assert_eq!(E4M3::from_f32(1e9, RoundMode::NearestEven).to_f32(), 448.0);
        assert_eq!(E4M3::from_f32(-1e9, RoundMode::NearestEven).to_f32(), -448.0);
        // 464 is the tie midpoint between 448 and the NaN slot; saturate.
        assert_eq!(E4M3::from_f32(460.0, RoundMode::NearestEven).to_f32(), 448.0);
    }

    #[test]
    fn underflow_to_zero() {
        // Below half the min subnormal rounds to zero — the NVFP4 scale
        // underflow failure mode in Fig 3.
        assert_eq!(E4M3::from_f32(MIN_SUBNORMAL / 4.0, RoundMode::NearestEven).to_f32(), 0.0);
        assert_eq!(
            E4M3::from_f32(MIN_SUBNORMAL * 0.75, RoundMode::NearestEven).to_f32(),
            MIN_SUBNORMAL
        );
    }

    #[test]
    fn rounding_in_normals() {
        // 3.2 between 3.0 (m=+4/8 at e=1) grid step 0.25: 3.25 closer.
        let q = E4M3::from_f32(3.2, RoundMode::NearestEven).to_f32();
        assert_eq!(q, 3.25);
        // Tie: 3.125 between 3.0 and 3.25; 3.0 has even mantissa code (100),
        // 3.25 odd (101) -> RNE picks 3.0.
        assert_eq!(E4M3::from_f32(3.125, RoundMode::NearestEven).to_f32(), 3.0);
    }
}
