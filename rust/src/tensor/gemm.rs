//! f32 GEMM — the compute substrate for the rust-native model forward
//! (calibration + eval paths) and for GPTQ's Hessian accumulation.
//!
//! `C = A (m×k) · B (k×n)`. The hot paths are `matmul` / `matmul_bt`:
//! cache-blocked kernels whose output rows are fanned out over contiguous
//! row bands via [`crate::util::threadpool::parallel_row_bands`], so the
//! whole model stack (transformer forward/backward, eval, GPTQ
//! calibration) inherits multi-core speed transparently. Each output row
//! is computed by exactly one thread with a fixed reduction order, so any
//! thread count returns **bit-identical** matrices (`matmul_threads(a, b,
//! 1) == matmul_threads(a, b, n)` exactly); `matmul_naive` is kept as the
//! correctness oracle. The default entry points take the process-wide
//! thread knob (`HIF4_THREADS` / `--threads`) and stay serial for small
//! problems where spawn cost would dominate.

use super::matrix::Matrix;
use crate::util::threadpool::{self, parallel_row_bands};

/// Naive triple loop — correctness oracle for property tests.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "inner dims must agree");
    let mut c = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut acc = 0f32;
            for p in 0..a.cols {
                acc += a.at(i, p) * b.at(p, j);
            }
            c.data[i * b.cols + j] = acc;
        }
    }
    c
}

/// Cache-blocked GEMM with an i-k-j loop order (unit-stride inner loop over
/// both B and C rows — autovectorizes well per core), parallelized over
/// C-row bands with the process-default thread count.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_threads(a, b, threadpool::threads_for(a.rows * a.cols * b.cols))
}

/// [`matmul`] with an explicit thread count. Bit-identical for every
/// `threads` value: each C row's reduction runs on one thread in a fixed
/// (ascending-p) order.
pub fn matmul_threads(a: &Matrix, b: &Matrix, threads: usize) -> Matrix {
    assert_eq!(a.cols, b.rows, "inner dims must agree");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    const KB: usize = 256;
    const JB: usize = 512;
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        for j0 in (0..n).step_by(JB) {
            let j1 = (j0 + JB).min(n);
            for p0 in (0..k).step_by(KB) {
                let p1 = (p0 + KB).min(k);
                for i in 0..rows {
                    let arow = &a.data[(first_row + i) * k..(first_row + i + 1) * k];
                    let crow = &mut band[i * n..(i + 1) * n];
                    for p in p0..p1 {
                        let av = arow[p];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.data[p * n..(p + 1) * n];
                        for j in j0..j1 {
                            crow[j] += av * brow[j];
                        }
                    }
                }
            }
        }
    });
    c
}

/// `C = A · Bᵀ` with B given row-major (so B's rows are the reduction
/// vectors — the natural layout for weight matrices stored out_features ×
/// in_features, as linear layers do). Row-parallel like [`matmul`].
pub fn matmul_bt(a: &Matrix, b_t: &Matrix) -> Matrix {
    matmul_bt_threads(a, b_t, threadpool::threads_for(a.rows * a.cols * b_t.rows))
}

/// [`matmul_bt`] with an explicit thread count (bit-identical for every
/// value — one `dot` per output element either way).
pub fn matmul_bt_threads(a: &Matrix, b_t: &Matrix, threads: usize) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "inner dims must agree");
    let (m, k, n) = (a.rows, a.cols, b_t.rows);
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    // Block over B rows so a panel of B stays cache-hot across the band.
    const JB: usize = 64;
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        for j0 in (0..n).step_by(JB) {
            let j1 = (j0 + JB).min(n);
            for i in 0..rows {
                let arow = &a.data[(first_row + i) * k..(first_row + i + 1) * k];
                let crow = &mut band[i * n..(i + 1) * n];
                for j in j0..j1 {
                    let brow = &b_t.data[j * k..(j + 1) * k];
                    crow[j] = dot(arow, brow);
                }
            }
        }
    });
    c
}

/// Unrolled dot product (4-way accumulators to break the dependency chain).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// y += alpha * x (axpy).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * *xi;
    }
}

/// Y += alpha * X over whole matrices.
#[inline]
pub fn axpy_mat(alpha: f32, x: &Matrix, y: &mut Matrix) {
    debug_assert_eq!((x.rows, x.cols), (y.rows, y.cols));
    axpy(alpha, &x.data, &mut y.data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::seed(12);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 128, 32)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            assert_close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn bt_matches_naive() {
        let mut rng = Rng::seed(13);
        let a = Matrix::randn(9, 31, 1.0, &mut rng);
        let b = Matrix::randn(31, 14, 1.0, &mut rng);
        let bt = b.transpose();
        assert_close(&matmul_bt(&a, &bt), &matmul_naive(&a, &b), 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::seed(14);
        let a = Matrix::randn(6, 6, 1.0, &mut rng);
        assert_close(&matmul(&a, &Matrix::eye(6)), &a, 1e-6);
    }

    #[test]
    fn dot_unrolled_matches_scalar() {
        let mut rng = Rng::seed(15);
        for n in [0, 1, 7, 8, 9, 63, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-3, "n={n}");
        }
    }
}
