//! Tensor substrate: dense matrices, deterministic RNG, GEMM kernels.
//!
//! Everything rust-native builds on this layer: [`Matrix`] is a plain
//! row-major `Vec<f32>` with explicit shapes (no broadcasting, no strides
//! — predictable layout is what lets the quantizers and the parallel
//! kernels band rows safely), [`Rng`] is a seeded SplitMix64 so every
//! table and figure regenerates bit-identically, and [`gemm`] holds the
//! cache-blocked, row-parallel f32 matmul kernels the transformer
//! forward/backward, GPTQ calibration and eval paths share. The parallel
//! kernels are deterministic: any thread count returns bit-identical
//! results (see `tests/parallel_parity.rs`).

pub mod gemm;
pub mod matrix;
pub mod rng;

pub use matrix::Matrix;
pub use rng::Rng;
