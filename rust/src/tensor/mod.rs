//! Tensor substrate: dense matrices, deterministic RNG, gemm kernels.

pub mod gemm;
pub mod matrix;
pub mod rng;

pub use matrix::Matrix;
pub use rng::Rng;
