//! Row-major f32 matrix — the tensor substrate every rust-side component
//! (quantizers, GPTQ, the rust-native transformer, the eval harness) builds
//! on. Deliberately minimal: contiguous storage, explicit shapes, no
//! broadcasting magic.

use super::rng::Rng;

/// A dense row-major matrix of f32.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Matrix {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// N(0, sigma²) entries.
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Peak |x| over the whole matrix.
    pub fn amax(&self) -> f32 {
        self.data.iter().fold(0f32, |m, x| m.max(x.abs()))
    }

    /// Frobenius-mean squared error against another matrix.
    pub fn mse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        crate::formats::mse(&self.data, &other.data)
    }

    /// Scale all entries in place.
    pub fn scale_inplace(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 0), 1.0);
        assert_eq!(m.at(1, 2), 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seed(8);
        let m = Matrix::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn eye_and_amax() {
        let i = Matrix::eye(4);
        assert_eq!(i.at(2, 2), 1.0);
        assert_eq!(i.at(2, 3), 0.0);
        assert_eq!(i.amax(), 1.0);
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Rng::seed(10);
        let m = Matrix::randn(100, 100, 0.02, &mut rng);
        let mean: f64 = m.data.iter().map(|x| *x as f64).sum::<f64>() / m.len() as f64;
        let var: f64 =
            m.data.iter().map(|x| (*x as f64 - mean).powi(2)).sum::<f64>() / m.len() as f64;
        assert!(mean.abs() < 1e-3);
        assert!((var.sqrt() - 0.02).abs() < 1e-3);
    }
}
