//! Deterministic RNG substrate (no external crates in the offline image).
//!
//! SplitMix64 for uniform bits + Box–Muller for Gaussians. Every experiment
//! seeds explicitly, so all tables/figures regenerate bit-identically.

/// SplitMix64 PRNG with cached Gaussian (Box–Muller produces pairs).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    cached_normal: Option<f64>,
}

impl Rng {
    pub fn seed(seed: u64) -> Rng {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), cached_normal: None }
    }

    /// Next raw 64 bits (SplitMix64).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * core::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// Fill a slice with N(0, sigma²) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = (self.normal() as f32) * sigma;
        }
    }

    /// Sample from a categorical distribution given cumulative weights.
    pub fn categorical(&mut self, cumulative: &[f64]) -> usize {
        let total = *cumulative.last().expect("non-empty");
        let u = self.uniform() * total;
        match cumulative.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(cumulative.len() - 1),
            Err(i) => i.min(cumulative.len() - 1),
        }
    }

    /// Fork a child RNG (stable stream splitting for parallel workers).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed(1);
        let mut b = Rng::seed(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::seed(2);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::seed(4);
        let cum = [1.0, 3.0, 6.0]; // weights 1, 2, 3
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&cum)] += 1;
        }
        assert!((counts[0] as f64 / 30_000.0 - 1.0 / 6.0).abs() < 0.02);
        assert!((counts[2] as f64 / 30_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn forks_are_independent_streams() {
        let mut parent = Rng::seed(9);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let a: Vec<u64> = (0..10).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..10).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
