//! Line-model lexer for the audit pass: a light, hand-rolled scan of
//! Rust source (registry parsers like `syn` are unavailable offline)
//! that splits every line into *code* and *comment* halves and tracks
//! `#[cfg(test)]` regions.
//!
//! The split is what makes the rule patterns in [`super::rules`] honest:
//! string/char-literal *contents* are blanked out of the code half (so a
//! pattern constant like a quoted `".unwrap()"` in this very module can
//! never fire a rule), block and line comments land in the comment half
//! (where `SAFETY:` / `BOUND:` / `audit:allow` annotations live), and
//! lines inside a `#[cfg(test)]` item are marked so panic-freedom rules
//! skip test code.
//!
//! Known, deliberate coarseness: the lexer is line-oriented and does not
//! build an AST. Lifetimes vs char literals are disambiguated by
//! lookahead (`'a'` consumes three chars, `'a` one); nested block
//! comments and raw strings (`r#"…"#`) are tracked across lines;
//! everything else is a per-line pattern target.

/// One source line, split for rule matching.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// The original text.
    pub raw: String,
    /// Code with comments removed and string/char contents blanked
    /// (delimiters are kept so subscript/paren matching still pairs up).
    pub code: String,
    /// Comment text (line-comment tail and/or block-comment content).
    pub comment: String,
    /// Inside a `#[cfg(test)]` item (attribute line included).
    pub in_test: bool,
}

/// Multi-line lexer state.
enum State {
    Normal,
    /// Nested block comment depth.
    Block(u32),
    /// Inside a `"…"` string.
    Str,
    /// Inside a raw string; the payload is the `#` count.
    RawStr(usize),
}

/// Lex `content` into the per-line model.
pub fn lex(content: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = State::Normal;
    for (li, raw) in content.lines().enumerate() {
        let chars: Vec<char> = raw.chars().collect();
        let n = chars.len();
        let mut code = String::new();
        let mut comment = String::new();
        let mut i = 0;
        while i < n {
            let c = chars[i];
            let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
            match state {
                State::Normal => {
                    if c == '/' && nxt == '/' {
                        comment.extend(&chars[i + 2..]);
                        i = n;
                    } else if c == '/' && nxt == '*' {
                        state = State::Block(1);
                        i += 2;
                    } else if c == '"' {
                        state = State::Str;
                        code.push('"');
                        i += 1;
                    } else if c == 'r' && (nxt == '"' || nxt == '#') {
                        let mut j = i + 1;
                        let mut hashes = 0;
                        while j < n && chars[j] == '#' {
                            hashes += 1;
                            j += 1;
                        }
                        if j < n && chars[j] == '"' {
                            state = State::RawStr(hashes);
                            code.push('"');
                            i = j + 1;
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    } else if c == 'b' && nxt == '"' {
                        state = State::Str;
                        code.push('"');
                        i += 2;
                    } else if c == '\'' {
                        // Char literal vs lifetime: `'x'`/`'\n'` close with a
                        // quote; a lifetime is just `'ident`.
                        if nxt == '\\' {
                            let mut j = i + 2;
                            if j < n && chars[j] == '\\' {
                                j += 1;
                            }
                            j += 1;
                            if j < n && chars[j] == '\'' {
                                j += 1;
                            }
                            code.push_str("' '");
                            i = j;
                        } else if i + 2 < n && chars[i + 2] == '\'' {
                            code.push_str("' '");
                            i += 3;
                        } else {
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
                State::Block(depth) => {
                    if c == '*' && nxt == '/' {
                        state = if depth == 1 { State::Normal } else { State::Block(depth - 1) };
                        i += 2;
                    } else if c == '/' && nxt == '*' {
                        state = State::Block(depth + 1);
                        i += 2;
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                }
                State::Str => {
                    if c == '\\' {
                        i += 2;
                    } else if c == '"' {
                        state = State::Normal;
                        code.push('"');
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                State::RawStr(hashes) => {
                    let closes = c == '"'
                        && i + hashes < n
                        && chars[i + 1..i + 1 + hashes].iter().all(|&h| h == '#');
                    if closes {
                        state = State::Normal;
                        code.push('"');
                        i += 1 + hashes;
                    } else {
                        i += 1;
                    }
                }
            }
        }
        out.push(Line { number: li + 1, raw: raw.to_string(), code, comment, in_test: false });
    }
    mark_test_regions(&mut out);
    out
}

/// Mark lines inside `#[cfg(test)]` items: the attribute arms a pending
/// flag; the next `{` opens the region, which closes when brace depth
/// returns to its opening level.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut test_depth: Option<i64> = None;
    for line in lines.iter_mut() {
        line.in_test = test_depth.is_some() || pending;
        for ch in line.code.chars() {
            if ch == '{' {
                depth += 1;
                if pending && test_depth.is_none() {
                    test_depth = Some(depth);
                    pending = false;
                }
            } else if ch == '}' {
                if test_depth == Some(depth) {
                    test_depth = None;
                }
                depth -= 1;
            }
        }
        if line.code.contains("cfg(test") {
            pending = true;
            line.in_test = true;
        }
    }
}

/// True when `word` occurs in `code` delimited by non-identifier chars.
pub fn word_in(code: &str, word: &str) -> bool {
    let cv: Vec<char> = code.chars().collect();
    let wv: Vec<char> = word.chars().collect();
    if wv.is_empty() || cv.len() < wv.len() {
        return false;
    }
    let ident = |c: char| c.is_ascii_alphanumeric() || c == '_';
    for start in 0..=cv.len() - wv.len() {
        if cv[start..start + wv.len()] != wv[..] {
            continue;
        }
        let before_ok = start == 0 || !ident(cv[start - 1]);
        let after = start + wv.len();
        let after_ok = after >= cv.len() || !ident(cv[after]);
        if before_ok && after_ok {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_split() {
        let src = "let x = \".unwrap()\"; // audit note\nlet y = 1; /* block */ let z = 2;";
        let lines = lex(src);
        assert!(!lines[0].code.contains("unwrap"));
        assert_eq!(lines[0].comment, " audit note");
        assert!(lines[1].code.contains("let z"));
        assert_eq!(lines[1].comment, " block ");
    }

    #[test]
    fn block_comments_span_lines_and_nest() {
        let src = "/* a /* b */\nstill comment */ let x = 1;";
        let lines = lex(src);
        assert!(lines[0].code.is_empty());
        assert!(lines[1].code.contains("let x"));
        assert!(lines[1].comment.contains("still comment"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let p = r#\"contains .unwrap() and \"quotes\"\"#; foo();";
        let lines = lex(src);
        assert!(!lines[0].code.contains("unwrap"));
        assert!(lines[0].code.contains("foo()"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let src = "fn f<'a>(x: &'a str) { let c = '\"'; let d = 'z'; g(x) }";
        let lines = lex(src);
        // The quote char literal must not open a string state.
        assert!(lines[0].code.contains("g(x)"));
    }

    #[test]
    fn cfg_test_region_tracking() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}";
        let lines = lex(src);
        assert!(!lines[0].in_test);
        assert!(lines[1].in_test);
        assert!(lines[3].in_test);
        assert!(lines[4].in_test);
        assert!(!lines[5].in_test);
    }

    #[test]
    fn word_boundaries() {
        assert!(word_in("let x: HashMap<u8, u8>", "HashMap"));
        assert!(!word_in("let x: MyHashMapLike", "HashMap"));
        assert!(word_in("unsafe { f() }", "unsafe"));
    }
}
