//! `hif4 audit` — the in-tree invariant checker.
//!
//! The compiler cannot see the contracts this reproduction rests on:
//! integer dots that must never wrap (`IDOT_I32_SAFE_LANES`, DESIGN.md
//! §11), bit-identical results for any thread/tile/page count, a serving
//! tier that must never panic an admitted stream (§13), and process
//! knobs as the only environment coupling. This module makes each of
//! them a build-time failure: a hand-rolled lexer ([`lexer`]) feeds five
//! lexical rules ([`rules`]) over `src/`, and CI fails on any finding.
//!
//! ```text
//! hif4 audit [--fix-hints] [--json] [--root DIR] [--out FILE]
//! ```
//!
//! Scope is the crate source tree (`src/`): integration tests and
//! benches exercise the contracts rather than carrying them. Every rule
//! is suppressible per-site via `audit:allow(<id>) -- <reason>`, and the
//! tool verifies each allow is load-bearing — a stale allow is itself a
//! finding, so suppressions cannot outlive the code they excused. The
//! full rule catalog and allow protocol live in DESIGN.md §16; the
//! self-audit test (`tests/audit_engine.rs`) pins the shipped tree to
//! zero findings.

pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use rules::{audit_source, Finding, ALLOW_IDS, KNOB_SITES};

use crate::util::bench::Table;
use crate::util::json::Json;

/// The result of auditing a source tree.
#[derive(Debug)]
pub struct Report {
    /// Scanned root directory.
    pub root: PathBuf,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// All findings, in (file, line) order.
    pub findings: Vec<Finding>,
}

impl Report {
    /// True when the tree carries zero findings (and therefore zero
    /// stale allows — those are findings too).
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable report (the CI artifact).
    pub fn to_json(&self) -> Json {
        let findings = self.findings.iter().map(|f| {
            Json::obj(vec![
                ("rule", Json::str(f.rule)),
                ("id", Json::str(f.id)),
                ("file", Json::str(f.file.as_str())),
                ("line", Json::num(f.line as f64)),
                ("message", Json::str(&f.message)),
                ("hint", Json::str(f.hint)),
            ])
        });
        Json::obj(vec![
            ("root", Json::str(self.root.display().to_string())),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("findings", Json::arr(findings)),
            ("clean", Json::Bool(self.clean())),
        ])
    }

    /// Human-readable table; `fix_hints` appends a remediation line per
    /// finding.
    pub fn render(&self, fix_hints: bool) -> String {
        let mut out = String::new();
        if self.clean() {
            out.push_str(&format!(
                "audit: clean — {} files under {} pass R1–R5\n",
                self.files_scanned,
                self.root.display()
            ));
            return out;
        }
        let mut table = Table::new(
            &format!("audit: {} finding(s)", self.findings.len()),
            &["rule", "site", "id", "message"],
        );
        for f in &self.findings {
            table.row(vec![
                f.rule.to_string(),
                format!("{}:{}", f.file, f.line),
                f.id.to_string(),
                f.message.clone(),
            ]);
        }
        out.push_str(&table.render());
        if fix_hints {
            out.push('\n');
            for f in &self.findings {
                out.push_str(&format!("{}:{}: hint: {}\n", f.file, f.line, f.hint));
            }
        }
        out
    }
}

/// Recursively collect `.rs` files under `dir`, sorted by relative path
/// so reports (and CI artifacts) are byte-stable across filesystems.
fn collect_sources(root: &Path) -> Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
        let entries =
            std::fs::read_dir(dir).with_context(|| format!("read_dir {}", dir.display()))?;
        for entry in entries {
            let path = entry?.path();
            if path.is_dir() {
                walk(&path, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
        Ok(())
    }
    let mut files = Vec::new();
    walk(root, &mut files)?;
    files.sort();
    Ok(files)
}

/// Audit every `.rs` file under `root` (the crate's `src/` tree).
pub fn run_audit(root: &Path) -> Result<Report> {
    anyhow::ensure!(root.is_dir(), "audit root {} is not a directory", root.display());
    let files = collect_sources(root)?;
    let mut findings = Vec::new();
    for path in &files {
        let content =
            std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        findings.extend(audit_source(&rel, &content));
    }
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(Report { root: root.to_path_buf(), files_scanned: files.len(), findings })
}
