//! The audit rule set (R1–R5) and the inline-allow protocol.
//!
//! Every rule is a lexical pattern over the [`super::lexer`] line model,
//! scoped to the module trees where the invariant it guards actually
//! holds (see `DESIGN.md` §16 for the catalog and rationale):
//!
//! * **R1 `safety`** — every `unsafe` token carries an adjacent
//!   `SAFETY:` (or rustdoc `# Safety`) comment.
//! * **R2 `panic`/`index`/`lock`** — panic-freedom in `server/` and
//!   `runtime/`: no `unwrap`/`expect`/`panic!`/`todo!`/`unimplemented!`,
//!   no scalar slice subscripts (ranged `a[i..j]` slicing is exempt —
//!   the repo idiom keeps it next to explicit length checks), and
//!   `util::lock_recover` instead of raw `Mutex::lock`, all outside
//!   `#[cfg(test)]`.
//! * **R3 `hash-iter`/`time`/`narrowing`** — determinism in the
//!   bit-exact modules (`dotprod/`, `model/`, `formats/`): no
//!   `HashMap`/`HashSet` (iteration order is randomized), no
//!   `Instant`/`SystemTime` in result paths, no visibly-f64 `as f32`
//!   narrowing casts.
//! * **R4 `bound`** — every widening `i32` dot-accumulation site (two
//!   `as i32` casts multiplied on one line, or an `_mm256_madd_epi16`
//!   call) sits in a function whose comments carry a `BOUND:` note
//!   referencing `IDOT_I32_SAFE_LANES` or `lanes_idot_exact` (the §11
//!   overflow audit).
//! * **R5 `env`** — `env::var` reads only at the registered process-knob
//!   sites in [`KNOB_SITES`], so no hidden nondeterminism enters kernels.
//!
//! A finding is suppressed by an inline annotation on the flagged line
//! or a contiguous comment block directly above it, written as
//! `audit:allow(<id>) -- <reason>` inside a comment. The reason is
//! mandatory, and the tool verifies every allow is load-bearing: an
//! allow that suppresses nothing is itself a finding (`stale-allow`).

use super::lexer::{lex, word_in, Line};

/// One audit violation (or allow-protocol error).
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule tag: `R1`–`R5`, or `allow` for allow-protocol errors.
    pub rule: &'static str,
    /// Allow id the finding can be suppressed under.
    pub id: &'static str,
    /// Path relative to the scanned root.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub message: String,
    /// One-line remediation suggestion (`--fix-hints`).
    pub hint: &'static str,
}

/// Every valid `audit:allow(<id>)` id. Unknown ids are ignored outright:
/// a typo'd allow simply fails to suppress, so the underlying finding
/// still surfaces the problem.
pub const ALLOW_IDS: &[&str] =
    &["safety", "panic", "index", "lock", "hash-iter", "time", "narrowing", "bound", "env"];

/// The registered process-knob sites: the only (file, variable) pairs
/// where an `env::var` read is legitimate. Adding a knob means adding a
/// row here — which is exactly the point: the knob inventory is code.
pub const KNOB_SITES: &[(&str, &str)] = &[
    ("util/threadpool.rs", "HIF4_THREADS"),
    ("util/bench.rs", "HIF4_BENCH_QUICK"),
    ("dotprod/mod.rs", "HIF4_KERNEL"),
    ("model/attention.rs", "HIF4_ATTN"),
    ("server/service.rs", "HIF4_PREFIX_CACHE"),
    ("server/service.rs", "HIF4_PREFILL_CHUNK"),
    ("server/service.rs", "HIF4_KV_PAGE_ROWS"),
    ("main.rs", "HIF4_KV_CACHE"),
];

fn hint_for(id: &str) -> &'static str {
    match id {
        "safety" => "add an adjacent `// SAFETY: ...` (or `/// # Safety`) comment stating the invariant",
        "panic" => "return a structured error (anyhow) or annotate why the panic is unreachable",
        "index" => "use .get()/.first()/slice patterns, or annotate the bounds invariant",
        "lock" => "use util::lock_recover so a poisoned mutex cannot panic the serving tier",
        "hash-iter" => "use BTreeMap/BTreeSet: iteration order must be deterministic here",
        "time" => "wall-clock types are banned in bit-exact result paths; use a logical clock",
        "narrowing" => "keep the f64 accumulation, or annotate why the f64->f32 cast is exact",
        "bound" => "add a `// BOUND:` comment referencing IDOT_I32_SAFE_LANES or lanes_idot_exact",
        "env" => "register the knob in audit::rules::KNOB_SITES (and document it), or read it at a registered site",
        _ => "",
    }
}

/// A parsed `audit:allow` annotation.
struct Allow {
    line_idx: usize,
    id: &'static str,
    reason: String,
    used: bool,
}

fn parse_allows(lines: &[Line]) -> Vec<Allow> {
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let Some(pos) = line.comment.find("audit:allow(") else { continue };
        let rest = &line.comment[pos + "audit:allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        let id_text = &rest[..close];
        let Some(&id) = ALLOW_IDS.iter().find(|&&k| k == id_text) else { continue };
        let tail = rest[close + 1..].trim_start();
        let reason = tail.strip_prefix("--").map(|r| r.trim().to_string()).unwrap_or_default();
        out.push(Allow { line_idx: idx, id, reason, used: false });
    }
    out
}

/// Find an allow with `id` covering `idx`: on the line itself or in the
/// contiguous run of comment-only lines directly above it.
fn allow_covering(lines: &[Line], allows: &[Allow], idx: usize, id: &str) -> Option<usize> {
    let mut covered = vec![idx];
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let line = &lines[k];
        if !line.comment.is_empty() && line.code.trim().is_empty() {
            covered.push(k);
        } else {
            break;
        }
    }
    allows.iter().position(|a| a.id == id && covered.contains(&a.line_idx))
}

/// Comment text of `idx`'s own line plus the contiguous comment/attribute
/// block directly above it.
fn comment_block_above(lines: &[Line], idx: usize) -> String {
    let mut texts = Vec::new();
    if !lines[idx].comment.is_empty() {
        texts.push(lines[idx].comment.clone());
    }
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let line = &lines[k];
        let code = line.code.trim();
        let comment_only = !line.comment.is_empty() && code.is_empty();
        let attr_only = code.starts_with("#[");
        if comment_only || attr_only {
            if !line.comment.is_empty() {
                texts.push(line.comment.clone());
            }
        } else {
            break;
        }
    }
    texts.join("\n")
}

/// True when `code` contains a `fn` item declaration (not a call).
fn has_fn_decl(code: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    let ident = |c: char| c.is_ascii_alphanumeric() || c == '_';
    for i in 0..chars.len().saturating_sub(2) {
        if chars[i] != 'f' || chars[i + 1] != 'n' {
            continue;
        }
        if i > 0 && ident(chars[i - 1]) {
            continue;
        }
        let mut j = i + 2;
        if j >= chars.len() || !chars[j].is_whitespace() {
            continue;
        }
        while j < chars.len() && chars[j].is_whitespace() {
            j += 1;
        }
        if j < chars.len() && (chars[j].is_ascii_alphabetic() || chars[j] == '_') {
            return true;
        }
    }
    false
}

fn enclosing_fn(lines: &[Line], idx: usize) -> Option<usize> {
    (0..=idx).rev().find(|&k| has_fn_decl(&lines[k].code))
}

/// R4 satisfaction: any comment between the enclosing `fn` and the site
/// (or in the block above the `fn`) says `BOUND:` and names the i32-safe
/// lane cap or the exact i64 fallback.
fn bound_comment_ok(lines: &[Line], idx: usize) -> bool {
    let Some(fn_idx) = enclosing_fn(lines, idx) else { return false };
    let mut texts: Vec<String> = lines[fn_idx..=idx]
        .iter()
        .filter(|l| !l.comment.is_empty())
        .map(|l| l.comment.clone())
        .collect();
    texts.push(comment_block_above(lines, fn_idx));
    let joined = texts.join("\n");
    joined.contains("BOUND:")
        && (joined.contains("IDOT_I32_SAFE_LANES") || joined.contains("lanes_idot_exact"))
}

/// True when `code` has a scalar (non-range) subscript expression: a `[`
/// preceded by an identifier char, `)` or `]`, whose bracket contents are
/// non-empty and contain no `..`.
fn scalar_index(code: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    let n = chars.len();
    let ident = |c: char| c.is_ascii_alphanumeric() || c == '_';
    for i in 0..n {
        if chars[i] != '[' {
            continue;
        }
        let prev = if i > 0 { chars[i - 1] } else { '\0' };
        if !(ident(prev) || prev == ')' || prev == ']') {
            continue;
        }
        let mut depth = 1;
        let mut j = i + 1;
        while j < n && depth > 0 {
            match chars[j] {
                '[' => depth += 1,
                ']' => depth -= 1,
                _ => {}
            }
            j += 1;
        }
        let inner: String = if depth == 0 {
            chars[i + 1..j - 1].iter().collect()
        } else {
            chars[i + 1..].iter().collect()
        };
        if inner.trim().is_empty() || inner.contains("..") {
            continue;
        }
        return true;
    }
    false
}

/// True when a digit-dot-digit float literal occurs in `text`.
fn has_float_literal(text: &str) -> bool {
    let chars: Vec<char> = text.chars().collect();
    chars.windows(3).any(|w| w[0].is_ascii_digit() && w[1] == '.' && w[2].is_ascii_digit())
}

/// An ` as f32` cast whose operand is visibly f64-typed: a paren group
/// containing a float literal / `f64`, or any operand on a line that
/// also mentions `f64`. Purely lexical — an identifier of f64 type with
/// no `f64` spelled on the line is out of reach, which is the documented
/// trade-off of a parser-free audit.
fn narrowing_cast(code: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    let pat: Vec<char> = " as f32".chars().collect();
    let n = chars.len();
    if n < pat.len() {
        return false;
    }
    for start in 0..=n - pat.len() {
        if chars[start..start + pat.len()] != pat[..] {
            continue;
        }
        let mut k = start;
        while k > 0 && chars[k - 1] == ' ' {
            k -= 1;
        }
        if k == 0 {
            continue;
        }
        if chars[k - 1] == ')' {
            let mut depth = 1;
            let mut j = k - 1;
            while j > 0 && depth > 0 {
                j -= 1;
                match chars[j] {
                    ')' => depth += 1,
                    '(' => depth -= 1,
                    _ => {}
                }
            }
            let group: String = chars[j..k].iter().collect();
            if has_float_literal(&group) || word_in(&group, "f64") {
                return true;
            }
        } else if word_in(code, "f64") {
            return true;
        }
    }
    false
}

/// Extract the quoted variable name after an `env::var(` call.
fn env_var_name(raw: &str) -> Option<&str> {
    let pos = raw.find("env::var")?;
    let rest = &raw[pos..];
    let open = rest.find('"')?;
    let tail = &rest[open + 1..];
    let close = tail.find('"')?;
    Some(&tail[..close])
}

const PANIC_PATTERNS: &[&str] = &[".unwrap()", ".expect(", "panic!(", "todo!(", "unimplemented!("];

/// Audit one source file (given as text); `rel` is the path relative to
/// the scanned root and selects rule scopes. Findings come back in line
/// order, allow-protocol errors (stale allows) last.
pub fn audit_source(rel: &str, content: &str) -> Vec<Finding> {
    let lines = lex(content);
    let mut allows = parse_allows(&lines);
    let mut hits: Vec<(&'static str, &'static str, usize, String)> = Vec::new();

    let in_r2 = rel.starts_with("server/") || rel.starts_with("runtime/");
    let in_r3 =
        rel.starts_with("dotprod/") || rel.starts_with("model/") || rel.starts_with("formats/");

    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        // R1 applies everywhere, tests included: unsafe is unsafe.
        if word_in(code, "unsafe") {
            let block = comment_block_above(&lines, idx);
            if !block.contains("SAFETY") && !block.contains("# Safety") {
                hits.push((
                    "R1",
                    "safety",
                    idx,
                    "unsafe without an adjacent SAFETY: comment".to_string(),
                ));
            }
        }
        if line.in_test {
            continue;
        }
        if in_r2 {
            if let Some(pat) = PANIC_PATTERNS.iter().find(|p| code.contains(*p)) {
                let what = pat.trim_start_matches('.').trim_end_matches('(');
                hits.push(("R2", "panic", idx, format!("{what} in the panic-free serving tier")));
            }
            if scalar_index(code) {
                hits.push((
                    "R2",
                    "index",
                    idx,
                    "scalar slice index in the panic-free serving tier".to_string(),
                ));
            }
            if code.contains(".lock()") {
                hits.push((
                    "R2",
                    "lock",
                    idx,
                    "raw Mutex::lock in the serving tier (poison panics)".to_string(),
                ));
            }
        }
        if in_r3 {
            if word_in(code, "HashMap") || word_in(code, "HashSet") {
                hits.push((
                    "R3",
                    "hash-iter",
                    idx,
                    "HashMap/HashSet in a bit-exact module".to_string(),
                ));
            }
            if word_in(code, "Instant") || word_in(code, "SystemTime") {
                hits.push(("R3", "time", idx, "wall-clock type in a bit-exact module".to_string()));
            }
            if narrowing_cast(code) {
                hits.push((
                    "R3",
                    "narrowing",
                    idx,
                    "f64→f32 narrowing cast in a bit-exact module".to_string(),
                ));
            }
        }
        let widening_dot = (code.matches("as i32").count() >= 2 && code.contains('*'))
            || code.contains("_mm256_madd_epi16(");
        if widening_dot && !bound_comment_ok(&lines, idx) {
            hits.push((
                "R4",
                "bound",
                idx,
                "widening i32 dot accumulation without a BOUND: annotation".to_string(),
            ));
        }
        if code.contains("env::var") {
            let var = env_var_name(&line.raw).unwrap_or("?");
            let registered = KNOB_SITES.iter().any(|(sfx, v)| rel.ends_with(sfx) && *v == var);
            if !registered {
                hits.push(("R5", "env", idx, format!("unregistered env read of {var}")));
            }
        }
    }

    let mut findings = Vec::new();
    for (rule, id, idx, message) in hits {
        match allow_covering(&lines, &allows, idx, id) {
            Some(ai) => {
                allows[ai].used = true;
                if allows[ai].reason.is_empty() {
                    findings.push(Finding {
                        rule: "allow",
                        id,
                        file: rel.to_string(),
                        line: lines[allows[ai].line_idx].number,
                        message: format!("audit:allow({id}) without a `-- <reason>`"),
                        hint: "every allow must state why the invariant holds anyway",
                    });
                }
            }
            None => findings.push(Finding {
                rule,
                id,
                file: rel.to_string(),
                line: lines[idx].number,
                message,
                hint: hint_for(id),
            }),
        }
    }
    for allow in &allows {
        if !allow.used {
            findings.push(Finding {
                rule: "allow",
                id: allow.id,
                file: rel.to_string(),
                line: lines[allow.line_idx].number,
                message: format!("stale audit:allow({}) suppresses nothing", allow.id),
                hint: "remove the allow: the pattern it excuses no longer fires here",
            });
        }
    }
    findings
}
