//! `hif4` CLI — the L3 coordinator entrypoint.
//!
//! ```text
//! hif4 serve   --artifact fwd_hif4.hlo.txt --addr 127.0.0.1:7401 [--params p.bin]
//!              [--workers 2]                 # worker pool size
//!              [--native --format mxfp4]     # PJRT-free rust-native engine:
//!                                            # continuous-batching decode over
//!                                            # prepacked fixed-point linears
//!                                            # (bf16 or any block format:
//!                                            # hif4|nvfp4|mxfp4|mx4|bfp)
//!              [--kv-cache f32|hif4|...]     # KV-cache storage (native engine;
//!                                            # HIF4_KV_CACHE env default)
//!              [--request-timeout-ms 0]      # default per-request TTL
//!                                            # (0 = none; requests may carry
//!                                            # their own deadline_ms)
//!              [--max-queue 0]               # bounded admission: queue depth
//!                                            # cap (0 = unbounded)
//!              [--kv-budget-mb 0]            # bounded admission: reserved KV
//!                                            # budget, native engine (0 =
//!                                            # unbounded; rounded to whole
//!                                            # pages, reserved page-wise)
//!              [--prefix-cache]              # shared-prefix dedup over the
//!                                            # global page pool (native;
//!                                            # HIF4_PREFIX_CACHE env default)
//!              [--prefill-chunk 0]           # prefill tokens per decode step
//!                                            # (native; 0 = whole prompt;
//!                                            # HIF4_PREFILL_CHUNK env default)
//!              [--kv-page-rows 64]           # rows per KV page (native;
//!                                            # HIF4_KV_PAGE_ROWS env default)
//!              [--faults seed=1,panic=5,...] # seeded fault injection (chaos
//!                                            # drills; see server::faults)
//! hif4 sweep   --dim 512                       # Fig 3 series
//! hif4 eval    --battery [--quick]             # accuracy battery: format x
//!              [--models llama2,deepseek]      # quant mode x zoo model x task
//!              [--out BENCH_accuracy.json]     # (+ ppl + layer sensitivity),
//!                                              # JSON artifact + tables
//! hif4 hwcost                                  # §III.B area/power table
//! hif4 dotprod                                 # Fig 4 inventory + exactness
//! hif4 quantize --in w.bin --format hif4       # quantize a raw f32 tensor
//! hif4 audit    [--fix-hints] [--json]         # in-tree invariant checker
//!               [--root DIR] [--out FILE]      # (rules R1-R5; the CI gate)
//! hif4 info                                    # formats summary
//! ```
//!
//! Every subcommand honours `--threads N` (or `HIF4_THREADS`) for the
//! data-parallel GEMM/quantization kernels, `--kernel
//! simd|packed|flow` (or `HIF4_KERNEL`) for the quantized-GEMM backend
//! (bit-identical results; `simd` — the default — is the register-tiled
//! microkernel whose lane ISA is CPU-detected once at startup: AVX2
//! where available, the portable unrolled-scalar kernel otherwise), and
//! `--attn fused|replay` (or `HIF4_ATTN`) for the attention schedule
//! over quantized KV caches (`fused` — the default — streams the packed
//! lane planes through the tiled integer kernel; greedy tokens are
//! identical on both paths, f32 caches always replay).

use anyhow::Result;
use hif4::formats::{mse, QuantKind, QuantScheme};
use hif4::model::kv::KvCacheType;
use hif4::quant::sweep;
use hif4::runtime::artifact::{Manifest, ParamStore};
use hif4::server::batcher::BatchPolicy;
use hif4::server::faults::FaultPlan;
use hif4::server::service::{
    page_rows_from_env, prefill_chunk_from_env, prefix_cache_from_env, NativeServerConfig,
    ResilienceConfig, Server, ServerConfig,
};
use hif4::util::bench::Table;
use hif4::util::cli::Args;
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::from_env();
    if let Some(t) = args.get("threads") {
        let t: usize = t.parse().map_err(|e| anyhow::anyhow!("--threads {t}: {e}"))?;
        anyhow::ensure!(t > 0, "--threads must be positive");
        hif4::util::threadpool::set_threads(t);
    }
    if let Some(k) = args.get("kernel") {
        match k {
            "flow" => hif4::dotprod::set_kernel(hif4::dotprod::Kernel::Flow),
            "packed" => hif4::dotprod::set_kernel(hif4::dotprod::Kernel::Packed),
            "simd" => hif4::dotprod::set_kernel(hif4::dotprod::Kernel::Simd),
            other => anyhow::bail!("--kernel must be simd, packed or flow, got {other}"),
        }
    }
    if let Some(a) = args.get("attn") {
        let path = hif4::model::attention::AttnPath::parse(a)
            .map_err(|e| anyhow::anyhow!("--attn: {e}"))?;
        hif4::model::attention::set_attn_path(path);
    }
    match args.subcommand() {
        Some("serve") => serve(&args),
        Some("sweep") => {
            let dim = args.get_parse("dim", 512);
            let pts = sweep::run(dim, sweep::PAPER_POINTS, args.get_parse("seed", 42));
            // Header labels come from the scheme list itself (QuantScheme::
            // label), so the table can never disagree with the data order.
            let mut header = vec!["x".to_string(), "sigma".to_string()];
            header.extend(sweep::scheme_labels());
            let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
            let mut t = Table::new("Fig 3 sweep", &hdr);
            for p in &pts {
                let mut cells = vec![p.x.to_string(), format!("{:.3e}", p.sigma)];
                cells.extend(p.normalized.iter().map(|r| format!("{r:.3}")));
                t.row(cells);
            }
            t.print();
            Ok(())
        }
        Some("hwcost") => {
            let mut t = Table::new("PE area/power (gate units)", &["block", "area", "power"]);
            for (label, area, power) in hif4::hwcost::pe::report_rows() {
                t.row(vec![label, format!("{area:.0}"), format!("{power:.0}")]);
            }
            t.print();
            Ok(())
        }
        Some("dotprod") => {
            let h = hif4::dotprod::hif4_flow::stats();
            let n = hif4::dotprod::nvfp4_flow::stats();
            println!(
                "HiF4 : {} small-FP + {} large-INT multipliers, {} int adds, S12P4 output",
                h.small_fp_muls, h.large_int_muls, h.int_adds
            );
            println!(
                "NVFP4: {} small-FP + {} large-INT multipliers, {} int adds + {} FP adds",
                n.small_fp_muls, n.large_int_muls, n.int_adds, n.fp_adds
            );
            Ok(())
        }
        Some("audit") => audit(&args),
        Some("eval") => eval(&args),
        Some("quantize") => quantize(&args),
        Some("info") | None => {
            let mut t = Table::new(
                "4-bit BFP formats implemented",
                &["format", "group", "bits/value", "scale", "element"],
            );
            let details = [
                "E6M2 + E1_8 + E1_16",
                "FP8-E4M3",
                "E8M0 (pow-2)",
                "E8M0 + 8x E1",
                "E8M0 (pow-2)",
            ];
            let elems = ["S1P2", "E2M1", "E2M1", "S1P1", "S1P2"];
            // Positional zip over parallel arrays: a new QuantKind must
            // extend both, or rows would silently vanish/shift.
            assert_eq!(details.len(), QuantKind::ALL.len());
            assert_eq!(elems.len(), QuantKind::ALL.len());
            for ((f, scale), elem) in QuantKind::ALL.iter().zip(details).zip(elems) {
                t.row(vec![
                    f.name().into(),
                    f.group().to_string(),
                    f.bits_per_value().to_string(),
                    scale.into(),
                    elem.into(),
                ]);
            }
            t.print();
            println!(
                "\nqgemm kernel backend: {} (simd isa: {})",
                hif4::dotprod::kernel().label(),
                hif4::dotprod::simd_isa_label()
            );
            println!(
                "attention path: {} (quantized KV caches; f32 caches always replay)",
                hif4::model::attention::attn_path().label()
            );
            println!(
                "\nsubcommands: serve | sweep | eval | hwcost | dotprod | quantize | audit | info"
            );
            Ok(())
        }
        Some(other) => {
            anyhow::bail!("unknown subcommand {other}; try `hif4 info`");
        }
    }
}

/// `hif4 audit [--fix-hints] [--json] [--root DIR] [--out FILE]` — run
/// the in-tree invariant checker (R1–R5, see `hif4::audit`) over the
/// crate source and exit nonzero on any finding or stale allow.
fn audit(args: &Args) -> Result<()> {
    let root = match args.get("root") {
        Some(r) => Path::new(r).to_path_buf(),
        // Work from either the workspace root or rust/.
        None if Path::new("src/lib.rs").is_file() => Path::new("src").to_path_buf(),
        None => Path::new("rust/src").to_path_buf(),
    };
    let report = hif4::audit::run_audit(&root)?;
    let json = report.to_json().render();
    if let Some(out) = args.get("out") {
        std::fs::write(out, &json)
            .map_err(|e| anyhow::anyhow!("write audit report {out}: {e}"))?;
    }
    if args.flag("json") {
        println!("{json}");
    } else {
        print!("{}", report.render(args.flag("fix-hints")));
    }
    anyhow::ensure!(
        report.clean(),
        "{} audit finding(s) — run `hif4 audit --fix-hints` for remediation",
        report.findings.len()
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let dir = Path::new(args.get_or("artifacts", "artifacts"));
    let manifest = Manifest::load(dir)?;
    let params = match args.get("params") {
        Some(p) => ParamStore::load(Path::new(p))?,
        None => manifest.init_params(args.get_parse("seed", 5)),
    };
    let policy = BatchPolicy {
        max_batch: args.get_parse("max-batch", manifest.batch),
        max_wait: std::time::Duration::from_millis(args.get_parse("max-wait-ms", 2)),
    };
    let workers = args.get_parse("workers", 1);
    let addr = args.get_or("addr", "127.0.0.1:7401");
    // Resilience knobs (DESIGN.md §13): TTL, bounded admission, and the
    // (chaos-drill-only) fault plan. All default off = pre-resilience
    // behavior.
    let timeout_ms: u64 = args.get_parse("request-timeout-ms", 0);
    let resilience = ResilienceConfig {
        request_timeout: (timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(timeout_ms)),
        max_queue: args.get_parse("max-queue", 0),
        kv_budget_bytes: args.get_parse::<usize>("kv-budget-mb", 0) * (1 << 20),
        faults: match args.get("faults") {
            Some(spec) => {
                let plan =
                    FaultPlan::parse(spec).map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
                eprintln!("WARNING: fault injection active ({spec}) — chaos drills only");
                Some(Arc::new(plan))
            }
            None => None,
        },
    };
    let server = if args.flag("native") {
        // PJRT-free engine: rebuild the L2 model from the store and serve
        // it rust-natively with continuous-batching decode; quantized
        // formats run the real fixed-point path with weight planes packed
        // once at startup. `--format` accepts bf16 or any QuantKind
        // spelling (all five block formats run the packed QGEMM); when
        // absent, the manifest's own `format` key decides, else bf16.
        let mut model = hif4::runtime::native::transformer_from_store(&manifest, &params)?;
        let fmt = match args.get("format") {
            // Case-insensitive like every QuantKind spelling (and the
            // --kv-cache f32 escape).
            Some(s) if s.eq_ignore_ascii_case("bf16") => None,
            Some(s) => Some(s.parse::<QuantKind>().map_err(|e| {
                anyhow::anyhow!("--format: {e} (or bf16 for the unquantized model)")
            })?),
            None => manifest.format,
        };
        if let Some(kind) = fmt {
            model.prepack_quantized_weights(kind);
        }
        // Serving never reads the dense plane of a prepacked linear; free
        // it so the 4-bit format's memory win survives into deployment.
        model.release_dense_weights();
        // KV-cache storage knob: --kv-cache beats HIF4_KV_CACHE beats f32.
        let kv_spec = args
            .get("kv-cache")
            .map(str::to_string)
            .or_else(|| std::env::var("HIF4_KV_CACHE").ok());
        let kv = match kv_spec {
            Some(s) => KvCacheType::parse(&s)
                .map_err(|e| anyhow::anyhow!("--kv-cache / HIF4_KV_CACHE: {e}"))?,
            None => KvCacheType::F32,
        };
        // Paging knobs: each CLI flag beats its env default (flags are
        // presence-only for --prefix-cache, so the env can only enable).
        let cfg = NativeServerConfig {
            policy,
            workers,
            seq: manifest.seq,
            kv,
            resilience,
            prefix_cache: args.flag("prefix-cache") || prefix_cache_from_env(),
            prefill_chunk: args.get_parse("prefill-chunk", prefill_chunk_from_env()),
            page_rows: args.get_parse("kv-page-rows", page_rows_from_env()).max(1),
        };
        Server::start_native(Arc::new(model), cfg, addr)?
    } else {
        let artifact = args.get_or("artifact", "fwd_bf16.hlo.txt").to_string();
        let mut served = params;
        // Same sniffing rule the server's metrics tag uses, so the
        // quantized weights and the reported format can never disagree.
        if let Some(kind) = QuantKind::from_artifact_name(&artifact) {
            served.quantize_weights(&QuantScheme::direct(kind));
        }
        let cfg = ServerConfig { artifact, policy, workers, resilience };
        Server::start(dir, cfg, &served, addr)?
    };
    println!("serving on {} — Ctrl-C to stop", server.addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        println!("{}", server.metrics.summary());
    }
}

fn eval(args: &Args) -> Result<()> {
    use hif4::eval::battery::{self, BatteryConfig};
    anyhow::ensure!(
        args.flag("battery"),
        "only the accuracy battery is implemented: `hif4 eval --battery` \
         (add --quick for the CI subset, --models for a zoo selection)"
    );
    let mut cfg = if args.flag("quick") { BatteryConfig::quick() } else { BatteryConfig::full() };
    if let Some(models) = args.get("models") {
        cfg.models =
            models.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
        anyhow::ensure!(!cfg.models.is_empty(), "--models: empty selection");
        let known: Vec<&str> = hif4::model::zoo::keyed().iter().map(|(k, _)| *k).collect();
        for key in &cfg.models {
            anyhow::ensure!(
                hif4::model::zoo::by_key(key).is_some(),
                "--models: unknown zoo key {key:?} (known: {})",
                known.join(", ")
            );
        }
    }
    let doc = battery::run(&cfg);
    battery::print_tables(&doc);
    let out = args.get_or("out", "BENCH_accuracy.json");
    std::fs::write(out, doc.render())?;
    println!("wrote {out}");
    Ok(())
}

fn quantize(args: &Args) -> Result<()> {
    let input = args.get("in").ok_or_else(|| anyhow::anyhow!("--in <f32le file> required"))?;
    // The same single QuantKind parser as `serve --native --format` and
    // the manifest key — one error message, listing every valid name.
    let fmt: QuantKind =
        args.get_or("format", "hif4").parse().map_err(|e| anyhow::anyhow!("--format: {e}"))?;
    let bytes = std::fs::read(input)?;
    let data: Vec<f32> =
        bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
    let scheme =
        if args.flag("pts") { QuantScheme::with_pts(fmt) } else { QuantScheme::direct(fmt) };
    let q = scheme.quant_dequant_vec(&data);
    println!("{} elements, {}: MSE {:.6e}", data.len(), scheme.label(), mse(&data, &q));
    if let Some(out) = args.get("out") {
        let mut buf = Vec::with_capacity(q.len() * 4);
        for x in &q {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(out, buf)?;
        println!("wrote dequantized tensor to {out}");
    }
    Ok(())
}
